//! Bench: regenerate Figure 10 via the simulator/model and time it.

use sonic_moe::bench::{figures, Bencher};

fn main() {
    figures::fig10().print();
    let mut b = Bencher::new("simulator/fig10_activation_memory");
    b.iter(|| figures::fig10());
    println!("{}", b.report());
}
