//! Runtime breakdown by kernel semantics (Figure 5): group each method's
//! launched kernels into the paper's categories and report per-category
//! milliseconds plus achieved bandwidth / TFLOPS annotations.

use super::configs::MoeShape;
use super::gemm::{Class, Kernel};
use super::hw::GpuSpec;
use super::methods::{kernel_graph, Method, Pass, Routing};

/// Figure 5's kernel categories.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Category {
    Router,
    Gather,
    GroupedGemm,
    Activation,
    Aggregation,
    DsCompute,
}

impl Category {
    pub const ALL: [Category; 6] = [
        Category::Router,
        Category::Gather,
        Category::GroupedGemm,
        Category::Activation,
        Category::Aggregation,
        Category::DsCompute,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            Category::Router => "router related",
            Category::Gather => "gather/scatter",
            Category::GroupedGemm => "grouped GEMM",
            Category::Activation => "SwiGLU/dSwiGLU",
            Category::Aggregation => "expert aggregation",
            Category::DsCompute => "dS compute",
        }
    }
}

fn categorize(k: &Kernel) -> Category {
    match k.name {
        "gather X" | "gather dO" | "gather dO (dW2)" | "gather X (dW1)" | "scatter Y" => {
            Category::Gather
        }
        "SwiGLU" | "dSwiGLU" | "dSwiGLU+dS+A'" => Category::Activation,
        "aggregate O" | "aggregate dX" => Category::Aggregation,
        "dS=<dO,Y>" => Category::DsCompute,
        "router" => Category::Router,
        _ => Category::GroupedGemm,
    }
}

/// One category's totals.
#[derive(Debug, Clone, Copy, Default)]
pub struct CatTime {
    pub ms: f64,
    pub bytes: f64,
    pub flops: f64,
}

/// Router cost model: score GEMM (T x d x E) + top-K + metadata, shared
/// by every method (SonicMoE's optimized top-K vs torch.topk differ via
/// `topk_eff`).
fn router_kernels(s: &MoeShape, topk_eff: f64) -> Vec<Kernel> {
    vec![
        Kernel {
            name: "router",
            class: Class::GroupedGemm {
                flops: 2.0 * (s.t * s.d * s.e) as f64,
                main_read: 2.0 * (s.t * s.d + s.d * s.e) as f64,
                epi_read: 0.0,
                epi_write: 4.0 * (s.t * s.e) as f64,
                k_dim: s.d,
                n_dim: s.e,
                tiles: s.t / 128 + 1,
                overlap: false,
                gathered_read: 0.0,
                scatter_store: false,
                eff_scale: 1.0,
            },
        },
        Kernel {
            name: "router",
            class: Class::MemBound {
                // top-K reads the (T, E) scores, writes (T, K) pairs
                read: 4.0 * (s.t * s.e) as f64,
                write: 8.0 * (s.t * s.k) as f64,
                gathered_read: 0.0,
                eff_scale: topk_eff,
            },
        },
    ]
}

/// Full fwd+bwd breakdown for one method (Figure 5 bar).
pub fn breakdown(m: Method, s: &MoeShape, hw: &GpuSpec) -> Vec<(Category, CatTime)> {
    let r = Routing::uniform(s, hw.tile.0);
    let mut ks = Vec::new();
    let topk_eff = if m == Method::SonicMoE { 1.0 } else { 0.4 }; // App. D: torch.topk ~40% of router time
    ks.extend(router_kernels(s, topk_eff));
    ks.extend(kernel_graph(m, s, &r, Pass::Forward));
    ks.extend(kernel_graph(m, s, &r, Pass::Backward));

    let mut agg: std::collections::HashMap<Category, CatTime> = Default::default();
    for k in &ks {
        let c = categorize(k);
        let e = agg.entry(c).or_default();
        e.ms += k.time_s(hw) * 1e3;
        match &k.class {
            Class::GroupedGemm { flops, main_read, epi_read, epi_write, .. } => {
                e.flops += flops;
                e.bytes += main_read + epi_read + epi_write;
            }
            Class::MemBound { read, write, .. } => e.bytes += read + write,
        }
    }
    let mut out: Vec<(Category, CatTime)> = Category::ALL
        .iter()
        .filter_map(|c| agg.get(c).map(|&t| (*c, t)))
        .collect();
    out.sort_by(|a, b| b.1.ms.partial_cmp(&a.1.ms).unwrap());
    out
}

/// Total fwd+bwd time including router (ms).
pub fn total_ms(m: Method, s: &MoeShape, hw: &GpuSpec) -> f64 {
    breakdown(m, s, hw).iter().map(|(_, t)| t.ms).sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::simulator::hw::H100;

    fn s7b() -> MoeShape {
        MoeShape::new(24576, 1536, 256, 128, 8)
    }

    #[test]
    fn sonic_has_no_separate_gather_or_ds_categories() {
        let cats: Vec<Category> = breakdown(Method::SonicMoE, &s7b(), &H100)
            .iter()
            .map(|(c, _)| *c)
            .collect();
        assert!(!cats.contains(&Category::Gather));
        assert!(!cats.contains(&Category::DsCompute));
        assert!(!cats.contains(&Category::Activation));
        assert!(cats.contains(&Category::GroupedGemm));
        assert!(cats.contains(&Category::Router));
    }

    #[test]
    fn scatter_moe_pays_for_gathers_and_ds() {
        let b = breakdown(Method::ScatterMoE, &s7b(), &H100);
        let cats: Vec<Category> = b.iter().map(|(c, _)| *c).collect();
        assert!(cats.contains(&Category::Gather));
        assert!(cats.contains(&Category::DsCompute));
        assert!(cats.contains(&Category::Activation));
    }

    #[test]
    fn totals_ordered_like_figure5() {
        let s = s7b();
        let sonic = total_ms(Method::SonicMoE, &s, &H100);
        let scatter = total_ms(Method::ScatterMoE, &s, &H100);
        let momoe = total_ms(Method::MoMoE, &s, &H100);
        let mega = total_ms(Method::MegaBlocks, &s, &H100);
        assert!(sonic < scatter && sonic < momoe && sonic < mega);
        // MegaBlocks is the slowest in Figure 5a
        assert!(mega > scatter);
    }
}
