//! Per-method MoE kernel graphs (Appendix B / Table 1 mechanized).
//!
//! Each method is described by feature flags straight out of Table 1;
//! [`kernel_graph`] assembles the forward/backward kernel sequence a
//! method launches for a given shape and routing outcome. Baselines
//! differ from SonicMoE *only* through these mechanisms:
//!
//! - gather fused with the GEMM load vs a separate gather kernel
//!   (costs an extra 2TKd read + 2TKd write per gathered operand);
//! - SwiGLU/dSwiGLU fused in the epilogue vs separate kernels;
//! - dS via `<dA', A>` inside the dH epilogue vs a separate
//!   `<dO, Y>` kernel (extra 2·2TKd traffic, needs Y cached);
//! - MMA overlapped with epilogue IO (Ping-Pong / TMEM) vs not;
//! - scatter fused with the store (st.global penalty, Figure 16) vs
//!   contiguous store + gather-and-sum aggregation (Figure 17);
//! - GEMM backend efficiency (Triton without warp specialization,
//!   block-sparse formats) as a multiplier on achievable MMA efficiency.

use super::configs::MoeShape;
use super::gemm::{Class, Kernel};

pub const BF16: f64 = 2.0;
pub const F32: f64 = 4.0;

/// Routing outcome fed to the model: per-expert token counts.
#[derive(Debug, Clone)]
pub struct Routing {
    pub counts: Vec<usize>,
    pub m_tile: usize,
}

impl Routing {
    /// Uniform routing (the iso-FLOPs assumption of Section 2.2).
    pub fn uniform(shape: &MoeShape, m_tile: usize) -> Routing {
        let per = shape.t * shape.k / shape.e;
        let mut counts = vec![per; shape.e];
        let rem = shape.t * shape.k - per * shape.e;
        for c in counts.iter_mut().take(rem) {
            *c += 1;
        }
        Routing { counts, m_tile }
    }

    /// From real per-expert counts (e.g. `routing::Decision::g`).
    pub fn from_counts(counts: Vec<usize>, m_tile: usize) -> Routing {
        Routing { counts, m_tile }
    }

    /// Realistic routing: multinomial draw of T*K assignments over E
    /// experts with mild popularity skew — produces the non-tile-aligned
    /// counts (and hence padding waste) a real TC router yields. This is
    /// what the figure benches feed the methods, while the cuBLAS bound
    /// keeps `uniform` (perfect balance by definition).
    pub fn sampled(shape: &MoeShape, m_tile: usize, rng: &mut crate::util::prng::Prng, skew: f64) -> Routing {
        let weights: Vec<f64> =
            (0..shape.e).map(|i| (-skew * ((i + 1) as f64).ln()).exp()).collect();
        let mut counts = vec![0usize; shape.e];
        for _ in 0..shape.t * shape.k {
            counts[rng.categorical(&weights)] += 1;
        }
        Routing { counts, m_tile }
    }

    pub fn rows(&self) -> usize {
        self.counts.iter().sum()
    }

    /// Rows after tile padding — what the grouped GEMM actually computes.
    pub fn rows_padded(&self) -> usize {
        let m = self.m_tile;
        self.counts.iter().map(|&c| (c + m - 1) / m * m).sum()
    }

    pub fn m_tiles(&self) -> usize {
        self.rows_padded() / self.m_tile
    }
}

/// MoE kernel implementations compared in the paper.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Method {
    SonicMoE,
    ScatterMoE,
    MoMoE,
    MegaBlocks,
    Megatron,
    /// DeepGEMM grouped GEMM + our optimized gather/aggregation kernels.
    DeepGemmPlus,
    /// DeepGEMM grouped GEMM + PyTorch gather/aggregation.
    DeepGemmPt,
    /// Dense cuBLAS BMM upper bound (perfect balance, no gather).
    CublasBmm,
    /// Triton official MoE example (inference-oriented: no H store).
    TritonEx,
}

impl Method {
    pub const MAIN: [Method; 7] = [
        Method::SonicMoE,
        Method::ScatterMoE,
        Method::MoMoE,
        Method::MegaBlocks,
        Method::Megatron,
        Method::DeepGemmPlus,
        Method::DeepGemmPt,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            Method::SonicMoE => "SonicMoE",
            Method::ScatterMoE => "ScatterMoE",
            Method::MoMoE => "MoMoE",
            Method::MegaBlocks => "MegaBlocks",
            Method::Megatron => "Megatron",
            Method::DeepGemmPlus => "DeepGEMM++",
            Method::DeepGemmPt => "DeepGEMM-pt",
            Method::CublasBmm => "cuBLAS BMM",
            Method::TritonEx => "triton ex.",
        }
    }

    fn feats(&self) -> Feats {
        match self {
            Method::SonicMoE => Feats {
                gather_fused_fwd: true,
                gather_fused_bwd: true,
                gather_once: false,
                swiglu_fused: true,
                ds_from_da: true,
                ds_in_dh_epilogue: true,
                overlap: true,
                scatter_fused: false,
                agg_eff: 1.0,
                gemm_eff: 1.0,
                stores_h: true,
            },
            Method::ScatterMoE => Feats {
                gather_fused_fwd: true,
                gather_fused_bwd: false,
                gather_once: true, // autograd saves the gathered buffers
                swiglu_fused: false,
                ds_from_da: false,
                ds_in_dh_epilogue: false,
                overlap: false,
                scatter_fused: true,
                agg_eff: 0.40, // torch.bmm fwd aggregation (Fig 20: ~2.9x slower)
                gemm_eff: 0.90, // Triton, no TMA / warp specialization
                stores_h: true,
            },
            Method::MoMoE => Feats {
                gather_fused_fwd: true,
                gather_fused_bwd: false,
                gather_once: false,
                swiglu_fused: true,
                ds_from_da: false,
                ds_in_dh_epilogue: false,
                overlap: false,
                scatter_fused: true,
                agg_eff: 0.95, // torch.sum over contiguous Y
                gemm_eff: 0.88,
                stores_h: true,
            },
            Method::MegaBlocks => Feats {
                gather_fused_fwd: false,
                gather_fused_bwd: false,
                gather_once: false, // binned gather/scatter per op
                swiglu_fused: false,
                ds_from_da: false,
                ds_in_dh_epilogue: false,
                overlap: false,
                scatter_fused: false,
                agg_eff: 0.95,
                gemm_eff: 0.80, // block-sparse matmul backend
                stores_h: true,
            },
            Method::Megatron => Feats {
                gather_fused_fwd: false,
                gather_fused_bwd: false,
                gather_once: true,
                swiglu_fused: true,
                ds_from_da: true,
                ds_in_dh_epilogue: false,
                overlap: false,
                scatter_fused: false,
                agg_eff: 0.95,
                gemm_eff: 0.97, // CUTLASS grouped GEMM
                stores_h: true,
            },
            Method::DeepGemmPlus => Feats {
                gather_fused_fwd: false,
                gather_fused_bwd: false,
                gather_once: true,
                swiglu_fused: false,
                ds_from_da: true,
                ds_in_dh_epilogue: false,
                overlap: false,
                scatter_fused: false,
                agg_eff: 1.0, // our optimized aggregation kernel
                gemm_eff: 0.98,
                stores_h: true,
            },
            Method::DeepGemmPt => Feats {
                gather_fused_fwd: false,
                gather_fused_bwd: false,
                gather_once: true,
                swiglu_fused: false,
                ds_from_da: true,
                ds_in_dh_epilogue: false,
                overlap: false,
                scatter_fused: false,
                agg_eff: 0.45, // torch fallback kernels
                gemm_eff: 0.98,
                stores_h: true,
            },
            Method::CublasBmm => Feats {
                gather_fused_fwd: true, // no gather at all (dense bound)
                gather_fused_bwd: true,
                gather_once: false,
                swiglu_fused: false,
                ds_from_da: true,
                ds_in_dh_epilogue: false,
                overlap: true,
                scatter_fused: false,
                agg_eff: 1.0,
                gemm_eff: 1.12, // dense BMM: no tensormap updates, ideal scheduling
                stores_h: true,
            },
            Method::TritonEx => Feats {
                gather_fused_fwd: true,
                gather_fused_bwd: false,
                gather_once: false,
                swiglu_fused: true,
                ds_from_da: false,
                ds_in_dh_epilogue: false,
                overlap: false,
                scatter_fused: false,
                agg_eff: 0.95,
                gemm_eff: 0.92, // Triton with TMA on Blackwell
                stores_h: false, // inference: only A is stored
            },
        }
    }
}

#[derive(Debug, Clone, Copy)]
struct Feats {
    gather_fused_fwd: bool,
    gather_fused_bwd: bool,
    /// Without fused gathers, gather each operand once and reuse the
    /// materialized copy (Megatron/MegaBlocks/DeepGEMM cache gathered
    /// X_e forward and gathered dO backward; ScatterMoE/MoMoE re-gather
    /// per consumer kernel).
    gather_once: bool,
    swiglu_fused: bool,
    ds_from_da: bool,
    ds_in_dh_epilogue: bool,
    overlap: bool,
    scatter_fused: bool,
    agg_eff: f64,
    gemm_eff: f64,
    stores_h: bool,
}

/// Which pass to assemble.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Pass {
    Forward,
    Backward,
}

/// A separate gather kernel over `rows` rows of width `w` (read gathered
/// + write packed).
fn gather_kernel(name: &'static str, rows: f64, w: f64) -> Kernel {
    Kernel {
        name,
        class: Class::MemBound {
            read: BF16 * rows * w,
            write: BF16 * rows * w,
            gathered_read: BF16 * rows * w,
            eff_scale: 1.0,
        },
    }
}

/// Assemble the kernel sequence a method launches for one pass.
///
/// For `CublasBmm`, pass a perfectly uniform `Routing` whose counts are
/// already tile multiples to get the paper's dense upper bound.
pub fn kernel_graph(m: Method, s: &MoeShape, r: &Routing, pass: Pass) -> Vec<Kernel> {
    let f = m.feats();
    let rows = r.rows() as f64; // real routed rows (model FLOPs)
    let rp = r.rows_padded() as f64; // hardware rows (padding waste)
    let (t, d, n, e) = (s.t as f64, s.d as f64, s.n as f64, s.e as f64);
    let tiles = r.m_tiles();
    let mut ks: Vec<Kernel> = Vec::new();

    match pass {
        Pass::Forward => {
            if !f.gather_fused_fwd {
                ks.push(gather_kernel("gather X", rows, d));
            }
            // A kernel: up-proj grouped GEMM (M=rp, K=d, N=2n)
            let gathered = if f.gather_fused_fwd && m != Method::CublasBmm {
                BF16 * rp * d
            } else {
                0.0
            };
            let h_store = if f.stores_h { BF16 * rp * 2.0 * n } else { 0.0 };
            let a_store = BF16 * rp * n;
            let (epi_w, act_kernel) = if f.swiglu_fused {
                (h_store + a_store, None)
            } else {
                // unfused: GEMM stores H; separate SwiGLU kernel
                (
                    h_store.max(BF16 * rp * 2.0 * n),
                    Some(Kernel {
                        name: "SwiGLU",
                        class: Class::MemBound {
                            read: BF16 * rp * 2.0 * n,
                            write: BF16 * rp * n,
                            gathered_read: 0.0,
                            eff_scale: 1.0,
                        },
                    }),
                )
            };
            ks.push(Kernel {
                name: "up-proj A",
                class: Class::GroupedGemm {
                    flops: 2.0 * rp * d * 2.0 * n,
                    main_read: BF16 * (rp * d + e * d * 2.0 * n),
                    epi_read: 0.0,
                    epi_write: epi_w,
                    k_dim: s.d,
                    n_dim: 2 * s.n,
                    tiles,
                    overlap: f.overlap,
                    gathered_read: gathered,
                    scatter_store: false,
                    eff_scale: f.gemm_eff,
                },
            });
            if let Some(k) = act_kernel {
                ks.push(k);
            }
            // Y kernel: down-proj grouped GEMM (M=rp, K=n, N=d)
            ks.push(Kernel {
                name: "down-proj Y",
                class: Class::GroupedGemm {
                    flops: 2.0 * rp * n * d,
                    main_read: BF16 * (rp * n + e * n * d),
                    epi_read: 0.0,
                    epi_write: BF16 * rp * d,
                    k_dim: s.n,
                    n_dim: s.d,
                    tiles,
                    overlap: f.overlap,
                    gathered_read: 0.0,
                    scatter_store: f.scatter_fused,
                    eff_scale: f.gemm_eff,
                },
            });
            if m == Method::MegaBlocks {
                // block-sparse path scatters back before reducing
                ks.push(gather_kernel("scatter Y", rows, d));
            }
            // O kernel: expert aggregation (gather-and-sum or post-scatter
            // reduction — both stream T*K rows and write T rows)
            ks.push(Kernel {
                name: "aggregate O",
                class: Class::MemBound {
                    read: BF16 * rows * d + F32 * rows,
                    write: BF16 * t * d,
                    gathered_read: if f.scatter_fused { 0.0 } else { BF16 * rows * d },
                    eff_scale: f.agg_eff,
                },
            });
        }
        Pass::Backward => {
            // dH kernel: dA' = gather(dO) @ W2^T (M=rp, K=d, N=n)
            if !f.gather_fused_bwd {
                ks.push(gather_kernel("gather dO", rows, d));
            }
            let gathered = if f.gather_fused_bwd { BF16 * rp * d } else { 0.0 };
            let (epi_r, epi_w) = if f.ds_in_dh_epilogue {
                // fused: load H, write dH + A' + dS
                (BF16 * rp * 2.0 * n, BF16 * rp * 2.0 * n + BF16 * rp * n + F32 * rp)
            } else {
                // plain GEMM epilogue stores dA'
                (0.0, BF16 * rp * n)
            };
            ks.push(Kernel {
                name: "down-proj act dH",
                class: Class::GroupedGemm {
                    flops: 2.0 * rp * d * n,
                    main_read: BF16 * (rp * d + e * n * d),
                    epi_read: epi_r,
                    epi_write: epi_w,
                    k_dim: s.d,
                    n_dim: s.n,
                    tiles,
                    overlap: f.overlap,
                    gathered_read: gathered,
                    scatter_store: false,
                    eff_scale: f.gemm_eff,
                },
            });
            if !f.ds_in_dh_epilogue {
                if f.ds_from_da {
                    // separate kernel: dS = <dA', A>, dSwiGLU, A'
                    ks.push(Kernel {
                        name: "dSwiGLU+dS+A'",
                        class: Class::MemBound {
                            read: BF16 * (rp * n + rp * 2.0 * n),
                            write: BF16 * (rp * 2.0 * n + rp * n) + F32 * rp,
                            gathered_read: 0.0,
                            eff_scale: 1.0,
                        },
                    });
                } else {
                    // dS = <dO, Y>: reload both TKd-sized tensors
                    ks.push(Kernel {
                        name: "dS=<dO,Y>",
                        class: Class::MemBound {
                            read: 2.0 * BF16 * rows * d,
                            write: F32 * rows,
                            gathered_read: BF16 * rows * d,
                            eff_scale: 1.0,
                        },
                    });
                    ks.push(Kernel {
                        name: "dSwiGLU",
                        class: Class::MemBound {
                            read: BF16 * (rp * n + rp * 2.0 * n),
                            write: BF16 * rp * 2.0 * n,
                            gathered_read: 0.0,
                            eff_scale: 1.0,
                        },
                    });
                }
            }
            // dW2: varlen-K grouped GEMM (A'^T dO), gather on K dim.
            // Methods that materialized gathered dO for the dH kernel
            // reuse that buffer here (gather_once).
            if !f.gather_fused_bwd && !f.gather_once {
                ks.push(gather_kernel("gather dO (dW2)", rows, d));
            }
            ks.push(Kernel {
                name: "down-proj weight dW2",
                class: Class::GroupedGemm {
                    flops: 2.0 * rp * n * d,
                    main_read: BF16 * (rp * n + rp * d),
                    epi_read: 0.0,
                    epi_write: F32 * e * n * d,
                    k_dim: (r.rows_padded() / s.e).max(1),
                    n_dim: s.d,
                    tiles: (s.e * ((s.n + 127) / 128)).max(1),
                    overlap: f.overlap,
                    gathered_read: if f.gather_fused_bwd { BF16 * rp * d } else { 0.0 },
                    scatter_store: false,
                    eff_scale: f.gemm_eff,
                },
            });
            // dX~ kernel: dH @ W1^T (M=rp, K=2n, N=d)
            ks.push(Kernel {
                name: "up-proj act dX~",
                class: Class::GroupedGemm {
                    flops: 2.0 * rp * 2.0 * n * d,
                    main_read: BF16 * (rp * 2.0 * n + e * d * 2.0 * n),
                    epi_read: 0.0,
                    epi_write: BF16 * rp * d,
                    k_dim: 2 * s.n,
                    n_dim: s.d,
                    tiles,
                    overlap: f.overlap,
                    gathered_read: 0.0,
                    scatter_store: f.scatter_fused,
                    eff_scale: f.gemm_eff,
                },
            });
            // dW1: varlen-K grouped GEMM (X^T dH), gather X on K dim.
            // gather_once methods cached the gathered X_e from the
            // forward pass (charged in the memory model) — no kernel.
            if !f.gather_fused_bwd && !f.gather_once {
                ks.push(gather_kernel("gather X (dW1)", rows, d));
            }
            ks.push(Kernel {
                name: "up-proj weight dW1",
                class: Class::GroupedGemm {
                    flops: 2.0 * rp * d * 2.0 * n,
                    main_read: BF16 * (rp * d + rp * 2.0 * n),
                    epi_read: 0.0,
                    epi_write: F32 * e * d * 2.0 * n,
                    k_dim: (r.rows_padded() / s.e).max(1),
                    n_dim: 2 * s.n,
                    tiles: (s.e * ((s.d + 127) / 128)).max(1),
                    overlap: f.overlap,
                    gathered_read: if f.gather_fused_bwd { BF16 * rp * d } else { 0.0 },
                    scatter_store: false,
                    eff_scale: f.gemm_eff,
                },
            });
            // dX aggregation
            ks.push(Kernel {
                name: "aggregate dX",
                class: Class::MemBound {
                    read: BF16 * rows * d,
                    write: BF16 * t * d,
                    gathered_read: if f.scatter_fused { 0.0 } else { BF16 * rows * d },
                    eff_scale: f.agg_eff,
                },
            });
        }
    }
    ks
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::simulator::gemm::total_time_s;
    use crate::simulator::hw::{B300, H100};

    fn shape7b() -> MoeShape {
        MoeShape::new(24576, 1536, 256, 128, 8)
    }

    fn tflops(m: Method, s: &MoeShape, pass: Pass, hw: &super::super::hw::GpuSpec) -> f64 {
        let r = Routing::uniform(s, 128);
        let ks = kernel_graph(m, s, &r, pass);
        let t = total_time_s(&ks, hw);
        let mf = match pass {
            Pass::Forward => s.flops_fwd(),
            Pass::Backward => s.flops_bwd(),
        };
        crate::simulator::gemm::model_tflops(mf, t)
    }

    #[test]
    fn sonic_beats_all_baselines_fwd_and_bwd() {
        let s = shape7b();
        for pass in [Pass::Forward, Pass::Backward] {
            let sonic = tflops(Method::SonicMoE, &s, pass, &H100);
            for m in [
                Method::ScatterMoE,
                Method::MoMoE,
                Method::MegaBlocks,
                Method::Megatron,
                Method::DeepGemmPlus,
                Method::DeepGemmPt,
            ] {
                let b = tflops(m, &s, pass, &H100);
                assert!(sonic > b, "{:?} {:?}: sonic {sonic:.0} <= {b:.0}", m, pass);
            }
        }
    }

    #[test]
    fn sonic_within_cublas_upper_bound() {
        // Figure 1: SonicMoE forward ~88% of the cuBLAS BMM bound. The
        // bound runs perfectly balanced dense BMMs; SonicMoE sees the
        // *sampled* (imbalanced, non-tile-aligned) routing.
        let s = MoeShape::new(32768, 4096, 512, 128, 8);
        let mut rng = crate::util::prng::Prng::new(0);
        let r = Routing::sampled(&s, 128, &mut rng, 0.3);
        let sonic = {
            let ks = kernel_graph(Method::SonicMoE, &s, &r, Pass::Forward);
            crate::simulator::gemm::model_tflops(s.flops_fwd(), total_time_s(&ks, &H100))
        };
        let cublas = tflops(Method::CublasBmm, &s, Pass::Forward, &H100);
        let ratio = sonic / cublas;
        assert!(ratio > 0.75 && ratio < 1.0, "ratio {ratio:.2}");
    }

    #[test]
    fn paper_magnitudes_h100_7b() {
        // Figure 11a: SonicMoE ~500+ TFLOPS on the fine-grained 7B;
        // ScatterMoE bwd ~1.83x lower; DeepGEMM-pt fwd ~1.43x lower.
        let s = shape7b();
        let sonic_f = tflops(Method::SonicMoE, &s, Pass::Forward, &H100);
        assert!(sonic_f > 420.0 && sonic_f < 750.0, "sonic fwd {sonic_f:.0}");
        let sonic_b = tflops(Method::SonicMoE, &s, Pass::Backward, &H100);
        let scatter_b = tflops(Method::ScatterMoE, &s, Pass::Backward, &H100);
        let gain = sonic_b / scatter_b;
        assert!(gain > 1.4 && gain < 2.6, "bwd gain over ScatterMoE {gain:.2}");
        // "+43% fwd over a highly optimized DeepGEMM baseline" == DG++;
        // the torch-glue variant (DeepGEMM-pt) is strictly worse.
        let dgpp_f = tflops(Method::DeepGemmPlus, &s, Pass::Forward, &H100);
        let gain_f = sonic_f / dgpp_f;
        assert!(gain_f > 1.2 && gain_f < 2.2, "fwd gain over DeepGEMM++ {gain_f:.2}");
        let dgpt_f = tflops(Method::DeepGemmPt, &s, Pass::Forward, &H100);
        assert!(dgpt_f < dgpp_f, "DeepGEMM-pt should trail DeepGEMM++");
    }

    #[test]
    fn b300_beats_h100_and_deepgemm_gap_grows_with_granularity() {
        let s = MoeShape::new(32768, 4096, 2048, 64, 4); // coarse, 120B
        let s_fine = MoeShape::new(32768, 4096, 512, 256, 16); // fine
        let g_coarse = tflops(Method::SonicMoE, &s, Pass::Forward, &B300)
            / tflops(Method::DeepGemmPlus, &s, Pass::Forward, &B300);
        let g_fine = tflops(Method::SonicMoE, &s_fine, Pass::Forward, &B300)
            / tflops(Method::DeepGemmPlus, &s_fine, Pass::Forward, &B300);
        assert!(g_fine > g_coarse, "fine {g_fine:.3} vs coarse {g_coarse:.3}");
        assert!(tflops(Method::SonicMoE, &s, Pass::Forward, &B300)
            > tflops(Method::SonicMoE, &s, Pass::Forward, &H100));
    }

    #[test]
    fn padding_increases_hardware_rows_not_model_flops() {
        let s = MoeShape::new(1024, 64, 32, 16, 2);
        let mut counts = vec![0usize; 16];
        // skewed: counts not tile multiples
        let mut left = s.t * s.k;
        for (i, c) in counts.iter_mut().enumerate() {
            let take = (left / (16 - i)).max(1).min(left);
            *c = take;
            left -= take;
        }
        let r = Routing::from_counts(counts, 128);
        assert!(r.rows_padded() >= r.rows());
        assert_eq!(r.rows(), s.t * s.k);
    }
}
