"""Shared MoE layer configuration for the L1 kernels and L2 model."""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    """Static shape configuration of one MoE layer.

    Follows the paper's notation (Table 3):

    - ``T``: tokens per microbatch
    - ``d``: model embedding dimension
    - ``n``: expert intermediate dimension (SwiGLU, so up-proj is ``2n``)
    - ``E``: total experts
    - ``K``: activated experts per token
    - ``m_tile``: grouped-GEMM M-dimension tile size (paper default 128)

    Derived static capacities (AOT-friendly — everything the kernels touch
    has a shape that depends only on this config, never on routing):

    - ``cap``:     ``T*K`` routed-token slots before per-expert padding
    - ``cap_pad``: upper bound on packed slots once every expert's count is
                   padded up to a multiple of ``m_tile``
    - ``max_tiles``: ``cap_pad / m_tile`` — static grid size for the
                   grouped-GEMM kernels (the persistent-tile-scheduler
                   analogue; unused tail tiles are masked)
    """

    T: int
    d: int
    n: int
    E: int
    K: int
    m_tile: int = 128

    def __post_init__(self) -> None:
        if self.K > self.E:
            raise ValueError(f"K={self.K} must be <= E={self.E}")
        for name in ("T", "d", "n", "E", "K", "m_tile"):
            v = getattr(self, name)
            if v <= 0:
                raise ValueError(f"{name} must be positive, got {v}")

    @property
    def cap(self) -> int:
        return self.T * self.K

    @property
    def cap_pad(self) -> int:
        # Each expert can waste at most (m_tile - 1) padded rows, and the
        # total must itself be a tile multiple so the static grid divides.
        raw = self.T * self.K + self.E * (self.m_tile - 1)
        return ((raw + self.m_tile - 1) // self.m_tile) * self.m_tile

    @property
    def max_tiles(self) -> int:
        return self.cap_pad // self.m_tile

    @property
    def granularity(self) -> float:
        """G = d/n — the paper's expert granularity."""
        return self.d / self.n

    @property
    def activation_ratio(self) -> float:
        """rho = K/E — the paper's MoE activation (sparsity) ratio."""
        return self.K / self.E

    def flops_fwd(self) -> int:
        """Model FLOPs of one forward pass: 6*T*K*n*d (Section 3.2)."""
        return 6 * self.T * self.K * self.n * self.d

    def flops_bwd(self) -> int:
        """Model FLOPs of one backward pass: 12*T*K*n*d (Section 3.2)."""
        return 12 * self.T * self.K * self.n * self.d

    def sonic_activation_bytes(self, dtype_bytes: int = 2) -> int:
        """SonicMoE cached activations per layer: 2Td + 4TKn (Section 3.2).

        Only X (T*d) and H (T*K*2n) are cached, at ``dtype_bytes`` each
        (BF16 in the paper), plus routing metadata which the paper treats
        as negligible and we account separately in the rust memory model.
        """
        return dtype_bytes * (self.T * self.d + 2 * self.T * self.K * self.n)
