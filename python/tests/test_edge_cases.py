"""Edge cases: degenerate routings, extreme shapes, failure modes."""

import jax.numpy as jnp
import numpy as np
import pytest

from compile.kernels import MoEConfig
from compile.kernels import aggregation, grouped_gemm, metadata, ref, router

from .conftest import random_moe_inputs


def _forward(cfg, x, w1, w2, pi, s):
    meta = metadata.build_metadata(cfg, jnp.asarray(pi), jnp.asarray(s))
    _, a = grouped_gemm.up_proj_swiglu(cfg, x, w1, meta)
    y = grouped_gemm.down_proj(cfg, a, w2, meta)
    return aggregation.expert_aggregate(cfg, y, meta)


def test_all_tokens_to_one_expert(rng):
    cfg = MoEConfig(T=16, d=8, n=4, E=4, K=1, m_tile=4)
    x, w1, w2, _, _ = random_moe_inputs(rng, cfg)
    pi = np.zeros((cfg.T, cfg.E), np.float32)
    pi[:, 2] = 1.0
    s = pi * 0.7
    o = _forward(cfg, x, w1, w2, pi, s)
    want = ref.moe_forward_dense(x, w1, w2, pi, s)
    np.testing.assert_allclose(np.asarray(o), np.asarray(want), rtol=1e-4, atol=1e-5)


def test_no_tokens_routed_anywhere(rng):
    cfg = MoEConfig(T=8, d=8, n=4, E=4, K=1, m_tile=4)
    x, w1, w2, _, _ = random_moe_inputs(rng, cfg)
    pi = np.zeros((cfg.T, cfg.E), np.float32)
    s = np.zeros_like(pi)
    o = _forward(cfg, x, w1, w2, pi, s)
    assert np.abs(np.asarray(o)).max() == 0.0


def test_k_equals_e_dense_equivalence(rng):
    cfg = MoEConfig(T=8, d=8, n=4, E=4, K=4, m_tile=4)
    x, w1, w2, pi, s = random_moe_inputs(rng, cfg)
    assert pi.sum() == cfg.T * cfg.E  # every expert active
    o = _forward(cfg, x, w1, w2, pi, s)
    want = ref.moe_forward_dense(x, w1, w2, pi, s)
    np.testing.assert_allclose(np.asarray(o), np.asarray(want), rtol=1e-4, atol=1e-5)


def test_m_tile_larger_than_tokens(rng):
    """m_tile > T_e for every expert: a single mostly-padding tile each."""
    cfg = MoEConfig(T=8, d=8, n=4, E=4, K=1, m_tile=16)
    x, w1, w2, pi, s = random_moe_inputs(rng, cfg)
    o = _forward(cfg, x, w1, w2, pi, s)
    want = ref.moe_forward_dense(x, w1, w2, pi, s)
    np.testing.assert_allclose(np.asarray(o), np.asarray(want), rtol=1e-4, atol=1e-5)


def test_config_validation():
    with pytest.raises(ValueError):
        MoEConfig(T=8, d=8, n=4, E=4, K=5, m_tile=4)  # K > E
    with pytest.raises(ValueError):
        MoEConfig(T=0, d=8, n=4, E=4, K=2, m_tile=4)


def test_router_rejects_unknown_subroutine(rng):
    scores = jnp.asarray(rng.random((8, 4)).astype(np.float32))
    with pytest.raises(ValueError):
        router.token_rounding(scores, 2, 4, subroutine="bogus")


def test_tr_with_sharp_onehot_scores(rng):
    """Near-one-hot scores: every token strongly prefers one expert —
    rounding must still produce tile multiples without NaNs."""
    t, e, k, m = 32, 4, 1, 8
    pref = rng.integers(0, e, size=t)
    logits = np.full((t, e), -20.0, np.float32)
    logits[np.arange(t), pref] = 20.0
    scores = np.exp(logits - logits.max(1, keepdims=True))
    scores /= scores.sum(1, keepdims=True)
    dec = router.token_rounding(jnp.asarray(scores), k, m)
    g = np.asarray(dec.g)
    assert np.all(g % m == 0)
    assert np.isfinite(np.asarray(dec.scores)).all()


def test_grad_through_empty_expert(rng):
    """An expert receiving zero tokens must get exactly-zero weight grads."""
    import jax
    from compile import moe_layer

    cfg = MoEConfig(T=16, d=8, n=4, E=4, K=1, m_tile=4)
    x, w1, w2, _, _ = random_moe_inputs(rng, cfg)
    pi = np.zeros((cfg.T, cfg.E), np.float32)
    pi[:, 0] = 1.0  # experts 1..3 empty
    s = pi * 0.5

    def loss(w1, w2):
        o = moe_layer.moe_compute(cfg, x, w1, w2, jnp.asarray(pi), jnp.asarray(s))
        return jnp.sum(o**2)

    g1, g2 = jax.grad(loss, argnums=(0, 1))(jnp.asarray(w1), jnp.asarray(w2))
    assert np.abs(np.asarray(g1)[1:]).max() == 0.0
    assert np.abs(np.asarray(g2)[1:]).max() == 0.0
    assert np.abs(np.asarray(g1)[0]).max() > 0.0
