//! Front-tier statistics: routing/failover counters plus per-replica
//! gauges, rendered as the `stats` JSON body and as `sonic_front_*`
//! Prometheus series for the `metrics` poll.

use std::collections::BTreeMap;
use std::time::Instant;

use crate::util::json::Json;
use crate::util::stats::{Percentiles, Reservoir};

/// Point-in-time view of one replica for the `stats`/`metrics`
/// replies (snapshotted outside the stats lock).
#[derive(Debug, Clone)]
pub struct ReplicaGauge {
    /// Replica address (`host:port`), the Prometheus `replica` label.
    pub addr: String,
    /// Model tag ("" = serves any model).
    pub model: String,
    /// Breaker state label (`healthy` / `degraded` / `dead`).
    pub state: &'static str,
    /// Peak-EWMA latency estimate (ms; 0 until the first sample).
    pub ewma_ms: f64,
    /// Requests currently relayed through the replica.
    pub in_flight: usize,
}

/// Aggregate front-tier statistics (behind one `Mutex` in the shared
/// state, like [`crate::gateway::GatewayStats`]).
#[derive(Debug, Clone)]
pub struct FrontStats {
    /// `score` requests received from clients.
    pub requests: u64,
    /// `generate` requests received from clients.
    pub gen_requests: u64,
    /// `score` replies relayed back (success or upstream error frame).
    pub relayed_ok: u64,
    /// `generate` streams relayed to their terminal frame.
    pub gen_done: u64,
    /// Relay attempts that failed on transport and were retried.
    pub retries: u64,
    /// Requests answered by a replica other than the first choice.
    pub failovers: u64,
    /// Requests shed with `no_healthy_replica`.
    pub shed_no_healthy: u64,
    /// Requests that exhausted every retry attempt (`exec_failed`).
    pub exhausted: u64,
    /// Pinned streams terminated with `replica_lost`.
    pub replica_lost_streams: u64,
    /// Breaker transitions into `Dead`.
    pub breaker_trips: u64,
    /// Breaker recoveries (`Dead` -> `Healthy` on a half-open probe).
    pub breaker_recoveries: u64,
    /// Health probes issued.
    pub probes: u64,
    /// Health probes that failed or timed out.
    pub probe_failures: u64,
    /// `reload` broadcasts relayed.
    pub reloads: u64,
    /// Scripted replica kills fired (`--fault-kill-replica-after` or a
    /// drill's injected kill).
    pub injected_replica_kills: u64,
    /// Scripted probe stalls fired (`--fault-stall-replica-after`).
    pub injected_replica_stalls: u64,
    /// End-to-end latency of requests that failed over (ms).
    failover_ms: Reservoir,
    /// When this front started (the `uptime_seconds` gauge).
    started: Instant,
}

impl Default for FrontStats {
    fn default() -> Self {
        FrontStats {
            requests: 0,
            gen_requests: 0,
            relayed_ok: 0,
            gen_done: 0,
            retries: 0,
            failovers: 0,
            shed_no_healthy: 0,
            exhausted: 0,
            replica_lost_streams: 0,
            breaker_trips: 0,
            breaker_recoveries: 0,
            probes: 0,
            probe_failures: 0,
            reloads: 0,
            injected_replica_kills: 0,
            injected_replica_stalls: 0,
            failover_ms: Reservoir::new(4096),
            started: Instant::now(),
        }
    }
}

impl FrontStats {
    /// Record the end-to-end latency of a request that succeeded on a
    /// non-first replica (the failover cost clients actually paid).
    pub fn record_failover(&mut self, latency_ms: f64) {
        self.failovers += 1;
        self.failover_ms.add(latency_ms);
    }

    /// Failover-latency percentiles; `None` until a failover happened.
    pub fn failover_percentiles(&self) -> Option<Percentiles> {
        if self.failover_ms.is_empty() { None } else { Some(self.failover_ms.percentiles()) }
    }

    /// Seconds since the front started.
    pub fn uptime_seconds(&self) -> f64 {
        self.started.elapsed().as_secs_f64()
    }

    /// Snapshot as the `stats` wire reply body: counters, failover
    /// percentiles (omitted for an empty window) and one object per
    /// replica under `"replicas"`.
    pub fn to_json(&self, replicas: &[ReplicaGauge]) -> Json {
        let mut m = BTreeMap::new();
        let mut num = |k: &str, v: f64| {
            m.insert(k.to_string(), Json::Num(v));
        };
        num("uptime_seconds", self.started.elapsed().as_secs_f64());
        num("requests", self.requests as f64);
        num("gen_requests", self.gen_requests as f64);
        num("relayed_ok", self.relayed_ok as f64);
        num("gen_done", self.gen_done as f64);
        num("retries", self.retries as f64);
        num("failovers", self.failovers as f64);
        num("shed_no_healthy", self.shed_no_healthy as f64);
        num("exhausted", self.exhausted as f64);
        num("replica_lost_streams", self.replica_lost_streams as f64);
        num("breaker_trips", self.breaker_trips as f64);
        num("breaker_recoveries", self.breaker_recoveries as f64);
        num("probes", self.probes as f64);
        num("probe_failures", self.probe_failures as f64);
        num("reloads", self.reloads as f64);
        num("injected_replica_kills", self.injected_replica_kills as f64);
        num("injected_replica_stalls", self.injected_replica_stalls as f64);
        if let Some(p) = self.failover_percentiles() {
            num("failover_p50_ms", p.p50);
            num("failover_p95_ms", p.p95);
            num("failover_p99_ms", p.p99);
        }
        m.insert(
            "replicas".to_string(),
            Json::Arr(
                replicas
                    .iter()
                    .map(|r| {
                        let mut o = BTreeMap::new();
                        o.insert("addr".to_string(), Json::Str(r.addr.clone()));
                        o.insert("model".to_string(), Json::Str(r.model.clone()));
                        o.insert("state".to_string(), Json::Str(r.state.to_string()));
                        o.insert("ewma_ms".to_string(), Json::Num(r.ewma_ms));
                        o.insert("in_flight".to_string(), Json::Num(r.in_flight as f64));
                        Json::Obj(o)
                    })
                    .collect(),
            ),
        );
        Json::Obj(m)
    }

    /// The `stats` body in Prometheus text exposition format: counters
    /// with `_total` suffixes, per-replica gauges labeled
    /// `replica="host:port"`, and the failover-latency summary.
    pub fn to_prometheus(&self, replicas: &[ReplicaGauge]) -> String {
        use std::fmt::Write as _;
        let mut out = String::with_capacity(4096);
        let mut metric = |name: &str, kind: &str, help: &str, value: f64| {
            let _ = writeln!(out, "# HELP sonic_front_{name} {help}");
            let _ = writeln!(out, "# TYPE sonic_front_{name} {kind}");
            let _ = writeln!(out, "sonic_front_{name} {value}");
        };
        metric(
            "uptime_seconds",
            "gauge",
            "Seconds since the front started.",
            self.started.elapsed().as_secs_f64(),
        );
        metric("requests_total", "counter", "Score requests received.", self.requests as f64);
        metric(
            "gen_requests_total",
            "counter",
            "Generate requests received.",
            self.gen_requests as f64,
        );
        metric("relayed_ok_total", "counter", "Score replies relayed.", self.relayed_ok as f64);
        metric(
            "gen_done_total",
            "counter",
            "Generate streams relayed to their terminal frame.",
            self.gen_done as f64,
        );
        metric("retries_total", "counter", "Relay attempts retried.", self.retries as f64);
        metric(
            "failovers_total",
            "counter",
            "Requests answered by a non-first replica.",
            self.failovers as f64,
        );
        metric(
            "shed_no_healthy_total",
            "counter",
            "Requests shed with no_healthy_replica.",
            self.shed_no_healthy as f64,
        );
        metric(
            "exhausted_total",
            "counter",
            "Requests that exhausted every retry attempt.",
            self.exhausted as f64,
        );
        metric(
            "replica_lost_streams_total",
            "counter",
            "Pinned streams terminated with replica_lost.",
            self.replica_lost_streams as f64,
        );
        metric(
            "breaker_trips_total",
            "counter",
            "Circuit-breaker transitions into dead.",
            self.breaker_trips as f64,
        );
        metric(
            "breaker_recoveries_total",
            "counter",
            "Half-open recoveries (dead -> healthy).",
            self.breaker_recoveries as f64,
        );
        metric("probes_total", "counter", "Health probes issued.", self.probes as f64);
        metric(
            "probe_failures_total",
            "counter",
            "Health probes failed or timed out.",
            self.probe_failures as f64,
        );
        metric("reloads_total", "counter", "Reload broadcasts relayed.", self.reloads as f64);
        metric(
            "injected_replica_kills_total",
            "counter",
            "Scripted replica kills fired.",
            self.injected_replica_kills as f64,
        );
        metric(
            "injected_replica_stalls_total",
            "counter",
            "Scripted probe stalls fired.",
            self.injected_replica_stalls as f64,
        );
        metric("replicas", "gauge", "Configured replicas.", replicas.len() as f64);
        let mut series = |name: &str, help: &str, render: &dyn Fn(&ReplicaGauge) -> String| {
            let _ = writeln!(out, "# HELP sonic_front_{name} {help}");
            let _ = writeln!(out, "# TYPE sonic_front_{name} gauge");
            for r in replicas {
                let _ = writeln!(out, "{}", render(r));
            }
        };
        series("replica_up", "1 when the replica is routable (not dead).", &|r| {
            let up = if r.state == "dead" { 0 } else { 1 };
            format!("sonic_front_replica_up{{replica=\"{}\"}} {up}", r.addr)
        });
        series("replica_state", "Breaker state as a one-hot labeled gauge.", &|r| {
            format!("sonic_front_replica_state{{replica=\"{}\",state=\"{}\"}} 1", r.addr, r.state)
        });
        series("replica_ewma_ms", "Peak-EWMA latency estimate (ms).", &|r| {
            format!("sonic_front_replica_ewma_ms{{replica=\"{}\"}} {}", r.addr, r.ewma_ms)
        });
        series("replica_in_flight", "Requests currently relayed through the replica.", &|r| {
            format!("sonic_front_replica_in_flight{{replica=\"{}\"}} {}", r.addr, r.in_flight)
        });
        if let Some(p) = self.failover_percentiles() {
            let _ = writeln!(
                out,
                "# HELP sonic_front_failover_ms End-to-end latency of failed-over requests (ms)."
            );
            let _ = writeln!(out, "# TYPE sonic_front_failover_ms summary");
            for (q, v) in [("0.5", p.p50), ("0.95", p.p95), ("0.99", p.p99)] {
                let _ = writeln!(out, "sonic_front_failover_ms{{quantile=\"{q}\"}} {v}");
            }
            let _ = writeln!(out, "sonic_front_failover_ms_count {}", p.n);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn gauges() -> Vec<ReplicaGauge> {
        vec![
            ReplicaGauge {
                addr: "127.0.0.1:7070".into(),
                model: "".into(),
                state: "healthy",
                ewma_ms: 2.5,
                in_flight: 1,
            },
            ReplicaGauge {
                addr: "127.0.0.1:7071".into(),
                model: "moe-8e".into(),
                state: "dead",
                ewma_ms: 40.0,
                in_flight: 0,
            },
        ]
    }

    #[test]
    fn json_snapshot_counts_and_replicas() {
        let mut s = FrontStats::default();
        s.requests = 4;
        s.relayed_ok = 3;
        s.retries = 2;
        s.breaker_trips = 1;
        s.record_failover(12.0);
        let j = s.to_json(&gauges());
        assert_eq!(j.get("requests").unwrap().as_usize().unwrap(), 4);
        assert_eq!(j.get("failovers").unwrap().as_usize().unwrap(), 1);
        assert_eq!(j.get("failover_p95_ms").unwrap().as_f64().unwrap(), 12.0);
        assert_eq!(j.get("failover_p99_ms").unwrap().as_f64().unwrap(), 12.0);
        assert!(j.get("uptime_seconds").unwrap().as_f64().unwrap() >= 0.0);
        let reps = j.get("replicas").unwrap().as_arr().unwrap();
        assert_eq!(reps.len(), 2);
        assert_eq!(reps[0].get("state").unwrap().as_str().unwrap(), "healthy");
        assert_eq!(reps[1].get("model").unwrap().as_str().unwrap(), "moe-8e");
        assert_eq!(reps[1].get("state").unwrap().as_str().unwrap(), "dead");
    }

    #[test]
    fn empty_failover_window_omits_percentiles() {
        let s = FrontStats::default();
        let j = s.to_json(&gauges());
        assert!(j.get("failover_p99_ms").is_err());
        assert!(j.get("retries").is_ok());
        let text = s.to_prometheus(&gauges());
        assert!(!text.contains("sonic_front_failover_ms{"));
        assert!(text.contains("sonic_front_retries_total 0"));
    }

    #[test]
    fn prometheus_exposition_labels_replicas() {
        let mut s = FrontStats::default();
        s.breaker_trips = 2;
        s.breaker_recoveries = 1;
        s.injected_replica_kills = 1;
        s.record_failover(7.5);
        let text = s.to_prometheus(&gauges());
        for needle in [
            "# TYPE sonic_front_uptime_seconds gauge",
            "# TYPE sonic_front_breaker_trips_total counter",
            "sonic_front_breaker_trips_total 2",
            "sonic_front_breaker_recoveries_total 1",
            "sonic_front_injected_replica_kills_total 1",
            "sonic_front_replicas 2",
            "sonic_front_replica_up{replica=\"127.0.0.1:7070\"} 1",
            "sonic_front_replica_up{replica=\"127.0.0.1:7071\"} 0",
            "sonic_front_replica_state{replica=\"127.0.0.1:7071\",state=\"dead\"} 1",
            "sonic_front_replica_ewma_ms{replica=\"127.0.0.1:7070\"} 2.5",
            "sonic_front_replica_in_flight{replica=\"127.0.0.1:7070\"} 1",
            "sonic_front_failover_ms{quantile=\"0.99\"} 7.5",
            "sonic_front_failover_ms_count 1",
        ] {
            assert!(text.contains(needle), "exposition body missing {needle:?}:\n{text}");
        }
    }
}
