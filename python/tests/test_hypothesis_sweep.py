"""Hypothesis sweeps: random shapes/routings through the full kernel
pipeline vs the dense oracle, plus router invariants under adversarial
score distributions.

Kept small (interpret-mode kernels on a 1-core box): the generators pick
from factored shape grids rather than free integers.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property sweeps need the hypothesis package")
from hypothesis import given, settings, strategies as st

from compile.kernels import MoEConfig
from compile.kernels import aggregation, backward, grouped_gemm, metadata, ref, router


SETTINGS = dict(max_examples=15, deadline=None, derandomize=True)


@st.composite
def moe_cfgs(draw):
    e = draw(st.sampled_from([2, 4, 8]))
    k = draw(st.integers(1, min(e, 3)))
    m = draw(st.sampled_from([4, 8]))
    t = draw(st.sampled_from([8, 16, 32]))
    d = draw(st.sampled_from([4, 8, 12]))
    n = draw(st.sampled_from([2, 4, 6]))
    return MoEConfig(T=t, d=d, n=n, E=e, K=k, m_tile=m)


def _inputs(cfg, seed):
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(cfg.T, cfg.d)).astype(np.float32)
    w1 = rng.normal(size=(cfg.E, cfg.d, 2 * cfg.n)).astype(np.float32) * 0.3
    w2 = rng.normal(size=(cfg.E, cfg.n, cfg.d)).astype(np.float32) * 0.3
    logits = rng.normal(size=(cfg.T, cfg.E)).astype(np.float32)
    scores = np.exp(logits - logits.max(1, keepdims=True))
    scores /= scores.sum(1, keepdims=True)
    return x, w1, w2, scores.astype(np.float32)


@settings(**SETTINGS)
@given(cfg=moe_cfgs(), seed=st.integers(0, 2**16), use_tr=st.booleans())
def test_pipeline_forward_any_shape(cfg, seed, use_tr):
    x, w1, w2, scores = _inputs(cfg, seed)
    if use_tr:
        dec = router.token_rounding(jnp.asarray(scores), cfg.K, cfg.m_tile)
    else:
        dec = router.tc_topk(jnp.asarray(scores), cfg.K)
    meta = metadata.build_metadata(cfg, dec.pi, dec.scores)
    _, a_packed = grouped_gemm.up_proj_swiglu(cfg, x, w1, meta)
    y_packed = grouped_gemm.down_proj(cfg, a_packed, w2, meta)
    o = aggregation.expert_aggregate(cfg, y_packed, meta)
    want = ref.moe_forward_dense(x, w1, w2, dec.pi, dec.scores)
    np.testing.assert_allclose(np.asarray(o), np.asarray(want), rtol=2e-4, atol=2e-4)


@settings(**SETTINGS)
@given(cfg=moe_cfgs(), seed=st.integers(0, 2**16))
def test_pipeline_backward_any_shape(cfg, seed):
    x, w1, w2, scores = _inputs(cfg, seed)
    rng = np.random.default_rng(seed + 1)
    do = rng.normal(size=(cfg.T, cfg.d)).astype(np.float32)
    dec = router.tc_topk(jnp.asarray(scores), cfg.K)
    meta = metadata.build_metadata(cfg, dec.pi, dec.scores)
    h_packed, _ = grouped_gemm.up_proj_swiglu(cfg, x, w1, meta)
    dh, ap, _ = backward.down_proj_bwd_act(cfg, do, w2, h_packed, meta)
    dw2 = backward.down_proj_bwd_weight(cfg, do, ap, meta)
    dw1 = backward.up_proj_bwd_weight(cfg, x, dh, meta)
    dxt = backward.up_proj_bwd_act(cfg, dh, w1, meta)
    dx = aggregation.grad_aggregate(cfg, dxt, meta)
    wdx, wdw1, wdw2, _ = ref.moe_backward_dense(
        x, w1, w2, np.asarray(dec.pi), np.asarray(dec.scores), do
    )
    np.testing.assert_allclose(np.asarray(dx), np.asarray(wdx), rtol=2e-3, atol=2e-4)
    np.testing.assert_allclose(np.asarray(dw1), np.asarray(wdw1), rtol=2e-3, atol=2e-4)
    np.testing.assert_allclose(np.asarray(dw2), np.asarray(wdw2), rtol=2e-3, atol=2e-4)


@settings(**SETTINGS)
@given(
    seed=st.integers(0, 2**16),
    t=st.sampled_from([16, 32, 64]),
    e=st.sampled_from([4, 8, 16]),
    k=st.integers(1, 4),
    m=st.sampled_from([4, 8, 16]),
    sub=st.sampled_from(list(router.SUBROUTINES)),
    sharp=st.floats(0.1, 20.0),  # score temperature: uniform .. one-hot
)
def test_router_invariants_any_distribution(seed, t, e, k, m, sub, sharp):
    k = min(k, e)
    rng = np.random.default_rng(seed)
    logits = rng.normal(size=(t, e)).astype(np.float32) * sharp
    scores = np.exp(logits - logits.max(1, keepdims=True))
    scores = (scores / scores.sum(1, keepdims=True)).astype(np.float32)
    dec = router.token_rounding(
        jnp.asarray(scores), k, m, subroutine=sub, key=jax.random.PRNGKey(seed)
    )
    g = np.asarray(dec.g)
    f = np.asarray(dec.f)
    pi = np.asarray(dec.pi)
    assert np.all(g % m == 0)
    assert np.all(np.abs(g - f) < m)
    np.testing.assert_array_equal(pi.sum(0).astype(int), g)
    assert np.all(pi.sum(1) <= e)


@settings(**SETTINGS)
@given(cfg=moe_cfgs(), seed=st.integers(0, 2**16))
def test_tr_metadata_zero_padding(cfg, seed):
    """With TR routing the packed layout has zero padding rows — the
    tile-quantization saving, asserted structurally."""
    _, _, _, scores = _inputs(cfg, seed)
    dec = router.token_rounding(jnp.asarray(scores), cfg.K, cfg.m_tile)
    meta = metadata.build_metadata(cfg, dec.pi, dec.scores)
    np.testing.assert_array_equal(np.asarray(meta.p), np.asarray(meta.f))
    assert float(np.asarray(meta.slot_valid).sum()) == float(np.asarray(meta.f).sum())
