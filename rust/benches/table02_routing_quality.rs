//! Table 2 (scaled-down): routing-method quality comparison.
//!
//! Trains the `small` AOT model with each routing method on the
//! synthetic corpus, then evaluates with TC top-K routing — exactly the
//! paper's protocol. Expect TR ≈ TC and an EC train/val gap; absolute
//! perplexities are not comparable to the 20B-token FineWeb runs
//! (DESIGN.md "Substitutions").
//!
//! `SONIC_BENCH_STEPS` controls the training length (default 150).

use sonic_moe::bench::Table;
use sonic_moe::coordinator::quality::{bench_steps, train_and_eval};
use sonic_moe::runtime::artifacts_available;

fn main() {
    if !artifacts_available("artifacts") {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    let steps = bench_steps();
    let mut t = Table::new(
        &format!("Table 2 (scaled down): routing quality, small config, {steps} steps"),
        &["method", "train CE", "val CE (TC eval)", "val PPL", "train-val gap"],
    );
    for (label, router) in [
        ("TR (NR-f)", "tr"),
        ("TC top-K", "tc"),
        ("TC (token drop)", "trdown"),
        ("EC", "ec"),
    ] {
        match train_and_eval("small", router, steps, 3e-3, 0) {
            Ok(r) => t.row(&[
                label.to_string(),
                format!("{:.4}", r.train_ce),
                format!("{:.4}", r.val_ce),
                format!("{:.2}", r.val_ppl()),
                format!("{:+.4}", r.val_ce - r.train_ce),
            ]),
            Err(e) => t.row(&[label.to_string(), format!("error: {e}"), "-".into(), "-".into(), "-".into()]),
        }
    }
    t.print();
    println!("(paper Table 2: TR matches or beats TC val PPL; EC shows a large train->val gap)");
}
