# SonicMoE reproduction — build/verify entry points.
#
#   make verify       tier-1 check: release build + full test suite
#                     (hermetic: runs on the native backend, no python)
#   make artifacts    AOT-export the HLO artifacts + goldens (python/jax;
#                     needed only for the PJRT backend and the
#                     cross-language integration goldens)
#   make golden       regenerate the native-backend parity goldens
#                     (rust/tests/golden/native, committed to the repo)
#   make test-python  run the python kernel/model test suite
#   make gateway-demo hermetic serving-gateway walkthrough (TCP + policies)
#   make bench-kernels blocked/fused kernel GFLOP/s + thread scaling
#   make bench-spec   speculative decode vs plain greedy (acceptance + tok/s)
#   make bench-residency tiered expert residency budget sweep (hit rate,
#                     prefetch latency, bitwise-identity asserted)
#   make bench-trace  trace-driven saturation sweep (shed-rate knee per
#                     batching policy over a committed workload trace,
#                     plus the front-tier replica sweep + failover drill)
#   make test-front   front-tier integration + replica-kill drills
#   make traces       regenerate the committed traces under bench/traces
#   make check-docs   doc-consistency: CLI flag coverage + missing-docs
#                     baseline (docs/OPERATIONS.md, scripts/check_docs.py)
#   make clean        remove build products (keeps artifacts/)

PYTHON ?= python3
CARGO ?= cargo
ARTIFACTS_DIR ?= $(abspath artifacts)
AOT_CONFIGS ?= small,medium

.PHONY: verify build test artifacts golden test-python clippy clean gateway-demo bench-kernels bench-spec bench-residency bench-trace test-front traces check-docs

verify: build test

build:
	$(CARGO) build --release

test:
	$(CARGO) test -q

# Hermetic gateway walkthrough: live TCP gateway + wire protocol +
# batching-policy comparison (no artifacts or network needed).
gateway-demo:
	$(CARGO) run --release --example gateway_demo

# Kernel throughput: blocked-vs-naive GEMM and fused-vs-gather grouped
# expert kernels (GFLOP/s + thread scaling + trajectory JSON record).
bench-kernels:
	$(CARGO) bench --bench kernel_throughput

# Speculative decoding: draft-and-verify vs plain greedy through the
# gateway (acceptance rate, tokens/verify-step, tokens/s + JSON record).
bench-spec:
	$(CARGO) bench --bench spec_decode

# Tiered expert residency: decode throughput + hit rate across a
# resident-bytes budget sweep; every budget must reproduce the dense
# token streams bitwise (the bench exits nonzero otherwise).
bench-residency:
	$(CARGO) bench --bench expert_residency

# Trace-driven saturation sweep: replay bench/traces/bursty_mixed.jsonl
# at increasing time compression per batching policy; records the
# shed-rate knee (highest offered load served with <= 5% shed), the
# front-tier 1-vs-2-replica knees, and the scripted failover drill.
bench-trace:
	$(CARGO) bench --bench trace_saturation

# Front-tier integration: relay fidelity, model routing, failover,
# shedding, fault plans and the replica-kill-mid-decode drill.
test-front:
	$(CARGO) test -q --test front_integration

# Regenerate the committed workload traces (python mirror of the rust
# synthesizer; `sonic-moe trace` produces the same streams).
traces:
	$(PYTHON) scripts/make_traces.py

# Doc consistency: every CLI flag documented in docs/OPERATIONS.md and
# no new undocumented public items in the serving modules.
check-docs:
	$(PYTHON) scripts/check_docs.py

# Python runs only here — the rust binary never calls back into python.
artifacts:
	cd python && $(PYTHON) -m compile.aot --out-dir $(ARTIFACTS_DIR) --configs $(AOT_CONFIGS)

golden:
	cd python && $(PYTHON) -m compile.native_golden

test-python:
	cd python && $(PYTHON) -m pytest tests -q

clippy:
	$(CARGO) clippy --all-targets -- -D warnings

clean:
	$(CARGO) clean
