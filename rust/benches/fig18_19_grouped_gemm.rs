//! Bench: regenerate Figures 18-19 via the GPU performance simulator and time
//! the evaluation hot path. See DESIGN.md per-experiment index.

use sonic_moe::bench::{figures, Bencher};

fn main() {
    for t in figures::fig18_19() {
        t.print();
    }
    let mut b = Bencher::new("simulator/fig18_19_grouped_gemm");
    b.iter(|| figures::fig18_19());
    println!("{}", b.report());
}
