//! Serving coordinator: the batched scoring core plus a synchronous
//! single-queue server over it.
//!
//! [`ScoreCore`] is the packing/execute engine shared by every serving
//! surface: it stages parameters once, discovers the eval artifact
//! shapes the manifest exports (`lm_eval` plus `lm_eval_b<rows>` batch
//! variants on builtin native configs), packs requests into the
//! smallest tile-compatible shape, and returns per-request CE when the
//! artifact carries the extended `ce_rows` output (batch mean
//! otherwise). The multi-threaded TCP gateway ([`crate::gateway`])
//! gives each worker thread its own `ScoreCore`; the in-process
//! [`Server`] below wraps one core behind the original submit/drain
//! API used by the `serve` CLI and the parity tests.
//!
//! Demonstrates the paper's "python never on the request path" property
//! for an inference-style workload; batching policy + queueing live
//! entirely in rust and are identical across backends.

use std::collections::VecDeque;
use std::time::Instant;

use anyhow::{bail, ensure, Result};

use crate::memory::residency::ResidencySpec;
use crate::runtime::backend::native::lm::{self, LmCfg, ParamStore};
use crate::runtime::{Runtime, Value};
use crate::util::dtype::{roundtrip_slice, Dtype};
use crate::util::tensor::Tensor;

/// One scoring request.
#[derive(Debug, Clone)]
pub struct Request {
    pub id: u64,
    pub tokens: Vec<i32>,
}

/// One scored response.
#[derive(Debug, Clone)]
pub struct Response {
    pub id: u64,
    /// Mean next-token cross entropy over the request's tokens
    /// (per-request exact when the eval artifact exports `ce_rows`).
    pub ce: f64,
    pub ppl: f64,
    /// Wall time from dequeue to completion (batch execution latency).
    pub latency_s: f64,
}

/// Result of scoring one packed batch.
#[derive(Debug, Clone)]
pub struct BatchScore {
    /// Per-request CE, in request order.
    pub ce: Vec<f64>,
    /// Batch-mean CE over the executed shape.
    pub mean: f64,
    /// Rows of the executed artifact shape (>= number of requests; the
    /// difference is padded rows — the serving analogue of tile waste).
    pub exec_rows: usize,
    /// True when `ce` came from the per-row `ce_rows` output rather
    /// than the batch mean.
    pub per_row: bool,
}

/// The packing/execute core of the scoring service: one runtime, the
/// parameters pre-staged as backend values (rebuilt only on checkpoint
/// load, never on the per-batch hot path), and the set of eval batch
/// shapes the manifest exports.
pub struct ScoreCore {
    rt: Runtime,
    param_vals: Vec<Value>,
    /// Canonical batch rows (the manifest model batch).
    pub rows: usize,
    pub seq: usize,
    /// Sorted rows of every eval artifact in the manifest.
    shapes: Vec<usize>,
    /// Numeric precision the GEMM weights are served at.
    dtype: Dtype,
    /// Native direct-eval state, used instead of the artifact executor
    /// when the weights should be *stored* at the serving precision
    /// (bf16 staging) or live file-backed behind a residency tier.
    direct: Option<DirectEval>,
}

/// The scoring path that bypasses the f32 artifact executor and runs
/// [`lm::eval_ce_rows`] straight off a [`ParamStore`]: staged bytes
/// land at the storage precision (bf16 halves them, where the
/// round-trip staging kept f32-sized buffers) and the expert weights
/// may spill behind an [`ExpertStore`](crate::memory::residency::ExpertStore).
/// Numerics are unchanged — the native `lm_eval` artifact calls the
/// same `eval_ce_rows`, and the pack-fused widening guarantee makes
/// bf16 storage bitwise equal to the pre-widened staging it replaces.
struct DirectEval {
    cfg: LmCfg,
    store: ParamStore,
}

/// Stage loaded parameters as backend values at a serving precision.
/// The artifact executor consumes f32 values, so bf16 here means the
/// GEMM weights are *round-tripped* through bf16 (quantize + widen)
/// before staging: the scoring surface serves exactly the numerics the
/// bf16 decode path computes, while its staged memory stays f32-sized
/// (the storage savings live on the decode path's [`ParamStore`]).
fn stage_params(rt: &Runtime, params: Vec<Tensor>, dtype: Dtype) -> Vec<Value> {
    params
        .into_iter()
        .zip(rt.manifest.params.iter())
        .map(|(t, spec)| match dtype {
            Dtype::Bf16 if ParamStore::is_gemm_weight(&spec.name) => {
                let data = roundtrip_slice(&t.data);
                Value::F32(Tensor::from_vec(&t.shape, data).expect("shape preserved"))
            }
            _ => Value::F32(t),
        })
        .collect()
}

impl ScoreCore {
    /// Open on the default backend (`SONIC_BACKEND`, native unless set).
    pub fn new(artifacts_dir: &str, config: &str) -> Result<ScoreCore> {
        Self::new_with_backend(artifacts_dir, config, "")
    }

    /// Open on a named backend ("" = default).
    pub fn new_with_backend(
        artifacts_dir: &str,
        config: &str,
        backend: &str,
    ) -> Result<ScoreCore> {
        Self::new_with_dtype(artifacts_dir, config, backend, Dtype::F32)
    }

    /// [`Self::new_with_backend`] with a serving precision. On the
    /// native backend bf16 weights are *stored* bf16 (see
    /// [`DirectEval`]); elsewhere they are round-tripped through bf16
    /// before f32 staging (see [`stage_params`]) — same numerics,
    /// different staged footprint.
    pub fn new_with_dtype(
        artifacts_dir: &str,
        config: &str,
        backend: &str,
        dtype: Dtype,
    ) -> Result<ScoreCore> {
        Self::new_inner(artifacts_dir, config, backend, dtype, None)
    }

    /// [`Self::new_with_dtype`] with tiered expert residency: expert
    /// weights spill to disk behind the spec's budget and are
    /// prefetched router-first during every eval forward. Requires the
    /// native backend. Scores are bitwise identical to the fully
    /// resident core at any budget.
    pub fn new_with_residency(
        artifacts_dir: &str,
        config: &str,
        backend: &str,
        dtype: Dtype,
        spec: &ResidencySpec,
    ) -> Result<ScoreCore> {
        Self::new_inner(artifacts_dir, config, backend, dtype, Some(spec))
    }

    fn new_inner(
        artifacts_dir: &str,
        config: &str,
        backend: &str,
        dtype: Dtype,
        residency: Option<&ResidencySpec>,
    ) -> Result<ScoreCore> {
        let rt = Runtime::open_with(
            artifacts_dir,
            config,
            crate::runtime::backend::by_name(backend)?,
        )?;
        if !rt.manifest.artifacts.contains_key("lm_eval") {
            bail!("lm_eval artifact missing — run `make artifacts`");
        }
        let native = rt.backend_name() == "native";
        if residency.is_some() && !native {
            bail!("expert residency requires the native backend (got {})", rt.backend_name());
        }
        let params = rt.load_initial_params()?;
        let (direct, param_vals) = if residency.is_some() || (native && dtype == Dtype::Bf16) {
            let m = &rt.manifest.model;
            let cfg = LmCfg {
                vocab: m.vocab,
                d: m.d,
                n_layers: m.n_layers,
                n_heads: m.n_heads,
                rows: m.batch,
                seq: m.seq_len,
                n: m.n,
                e: m.e,
                k: m.k,
                m_tile: m.m_tile,
                aux_coeff: m.aux_coeff,
                router: lm::parse_router_method(&m.router)?,
            };
            let named: Vec<(String, Tensor)> = rt
                .manifest
                .params
                .iter()
                .map(|p| p.name.clone())
                .zip(params)
                .collect();
            let store = match residency {
                Some(spec) => ParamStore::new_tiered(named, dtype, spec)?,
                None => ParamStore::new(named, dtype),
            };
            // the direct path never touches the artifact executor, so
            // nothing is staged as backend values
            (Some(DirectEval { cfg, store }), Vec::new())
        } else {
            (None, stage_params(&rt, params, dtype))
        };
        let (rows, seq) = (rt.manifest.model.batch, rt.manifest.model.seq_len);
        let mut shapes: Vec<usize> = rt
            .manifest
            .artifacts
            .iter()
            .filter(|(name, _)| {
                name.as_str() == "lm_eval" || name.starts_with("lm_eval_b")
            })
            .filter_map(|(_, spec)| {
                let tok = spec.inputs.last()?;
                if tok.shape.len() == 2 && tok.shape[1] == seq {
                    Some(tok.shape[0])
                } else {
                    None
                }
            })
            .collect();
        shapes.sort_unstable();
        shapes.dedup();
        ensure!(!shapes.is_empty(), "no eval artifact shapes in manifest");
        Ok(ScoreCore { rt, param_vals, rows, seq, shapes, dtype, direct })
    }

    /// Execution backend serving this config.
    pub fn backend_name(&self) -> &'static str {
        self.rt.backend_name()
    }

    /// Numeric precision the GEMM weights are served at.
    pub fn dtype(&self) -> Dtype {
        self.dtype
    }

    /// The tiered expert store, when this core runs under residency.
    pub fn residency(&self) -> Option<&crate::memory::residency::ExpertStore> {
        self.direct.as_ref().and_then(|d| d.store.residency())
    }

    /// Bytes of parameters staged on this core's serving path. The
    /// artifact path stages f32 values; the direct path stores at the
    /// configured precision (bf16 halves the GEMM weights), with
    /// tiered experts counted at their current residency.
    pub fn weight_bytes(&self) -> usize {
        match &self.direct {
            Some(d) => d.store.weight_bytes(),
            None => self
                .param_vals
                .iter()
                .map(|v| match v {
                    Value::F32(t) => t.data.len() * 4,
                    Value::I32 { data, .. } => data.len() * 4,
                })
                .sum(),
        }
    }

    /// Vocabulary size of the served model.
    pub fn vocab(&self) -> usize {
        self.rt.manifest.model.vocab
    }

    /// Sorted batch-row shapes the manifest exports for eval.
    pub fn batch_shapes(&self) -> &[usize] {
        &self.shapes
    }

    /// Largest batch the core can score in one execute when row counts
    /// are quantized to multiples of `m_tile` (falls back to the
    /// largest exported shape when no tile multiple exists).
    pub fn max_batch(&self, m_tile: usize) -> usize {
        let m = m_tile.max(1);
        self.shapes
            .iter()
            .rev()
            .copied()
            .find(|s| s % m == 0)
            .unwrap_or_else(|| *self.shapes.last().expect("non-empty shapes"))
    }

    /// Smallest exported shape that holds `b` requests and is a
    /// multiple of `m_tile` (tile-quantized row count — the serving
    /// analogue of grouped-GEMM tile rounding). Falls back to the
    /// smallest shape >= b, then to the largest shape.
    pub fn pick_shape(&self, b: usize, m_tile: usize) -> usize {
        let m = m_tile.max(1);
        for &s in &self.shapes {
            if s % m == 0 && s >= b {
                return s;
            }
        }
        self.shapes
            .iter()
            .copied()
            .find(|&s| s >= b)
            .unwrap_or_else(|| *self.shapes.last().expect("non-empty shapes"))
    }

    /// Replace parameters (e.g. from a trained checkpoint).
    pub fn load_checkpoint(&mut self, dir: &str) -> Result<()> {
        let (_, cfg, names, params) = super::checkpoint::load(dir)?;
        if cfg != self.rt.config_name {
            bail!("checkpoint config {cfg:?} != server config {:?}", self.rt.config_name);
        }
        match &mut self.direct {
            Some(d) => {
                ensure!(names.len() == params.len(), "checkpoint names/params mismatch");
                // re-quantize (and re-tier) under the core's layout
                d.store = d.store.rebuild(names.into_iter().zip(params).collect())?;
            }
            None => self.param_vals = stage_params(&self.rt, params, self.dtype),
        }
        Ok(())
    }

    /// Score a batch of requests in one execute. The batch is packed
    /// into the shape chosen by [`Self::pick_shape`] (rows are
    /// truncated/cycle-padded to the static sequence length; missing
    /// rows are zero-padding). `m_tile` quantizes the executed row
    /// count; pass [`Self::rows`] for the legacy full-shape behavior.
    pub fn score_batch(&mut self, reqs: &[&[i32]], m_tile: usize) -> Result<BatchScore> {
        ensure!(!reqs.is_empty(), "empty batch");
        let b = reqs.len();
        let shape = self.pick_shape(b, m_tile);
        ensure!(
            b <= shape,
            "batch of {b} exceeds the largest eval shape {shape} (cap batches at max_batch)"
        );
        let vocab = self.vocab() as i32;
        let mut tokens = vec![0i32; shape * self.seq];
        for (i, r) in reqs.iter().enumerate() {
            pack_row(&mut tokens[i * self.seq..(i + 1) * self.seq], r, vocab);
        }
        let (mean, rows_ce) = self.execute_eval(shape, tokens)?;
        let per_row = rows_ce.is_some();
        let ce = match rows_ce {
            Some(r) => r[..b].to_vec(),
            None => vec![mean; b],
        };
        Ok(BatchScore { ce, mean, exec_rows: shape, per_row })
    }

    /// Exact per-request scoring: replicate one request across all rows
    /// of the canonical batch shape so the batch-mean CE *is* the
    /// request's CE (identical to the per-row path under row-local
    /// routers like TC).
    pub fn score_exact(&mut self, tokens: &[i32]) -> Result<f64> {
        let vocab = self.vocab() as i32;
        let mut packed = vec![0i32; self.rows * self.seq];
        for i in 0..self.rows {
            pack_row(&mut packed[i * self.seq..(i + 1) * self.seq], tokens, vocab);
        }
        Ok(self.execute_eval(self.rows, packed)?.0)
    }

    /// Run the eval artifact of one batch shape on packed tokens. The
    /// cached parameter values are reused; only the token input is
    /// staged per call.
    fn execute_eval(&mut self, rows: usize, tokens: Vec<i32>) -> Result<(f64, Option<Vec<f64>>)> {
        if let Some(d) = &self.direct {
            // same numerics the `lm_eval` artifact runs (it calls this
            // very function over f32 `Params`), minus the staging
            let cfg = LmCfg { rows, ..d.cfg.clone() };
            let params = d.store.view(cfg.n_layers)?;
            let (mean, ce_rows) = lm::eval_ce_rows(&cfg, &params, &tokens);
            let rows_f64 = ce_rows.iter().map(|&x| x as f64).collect();
            return Ok((mean as f64, Some(rows_f64)));
        }
        let name = if rows == self.rows {
            "lm_eval".to_string()
        } else {
            format!("lm_eval_b{rows}")
        };
        self.param_vals.push(Value::i32(&[rows, self.seq], tokens)?);
        let out = Self::eval_inner(&mut self.rt, &name, &self.param_vals);
        self.param_vals.pop();
        out
    }

    fn eval_inner(
        rt: &mut Runtime,
        name: &str,
        vals: &[Value],
    ) -> Result<(f64, Option<Vec<f64>>)> {
        let art = rt.artifact(name)?;
        let outs = art.execute(vals)?;
        let mean = outs[0].scalar_f32()? as f64;
        let rows = if outs.len() > 1 {
            let t = outs[1].as_f32()?;
            Some(t.data.iter().map(|&x| x as f64).collect())
        } else {
            None
        };
        Ok((mean, rows))
    }
}

/// Pack one request into one row of the static (rows, seq) token
/// buffer: truncate/cycle-pad to the sequence length, clamp into the
/// vocabulary. The single definition keeps `score_batch` and
/// `score_exact` byte-identical per row — the invariant behind the
/// gateway's "per-row CE == score_exact" contract.
fn pack_row(row: &mut [i32], tokens: &[i32], vocab: i32) {
    for (j, slot) in row.iter_mut().enumerate() {
        let t = if tokens.is_empty() { 0 } else { tokens[j % tokens.len()] };
        *slot = t.rem_euclid(vocab);
    }
}

/// Batched scoring server over one config: a single FIFO queue drained
/// in fixed-shape microbatches (the synchronous predecessor of the
/// concurrent TCP gateway, kept for the CLI and as the accounting
/// reference).
pub struct Server {
    core: ScoreCore,
    queue: VecDeque<Request>,
    pub rows: usize,
    pub seq: usize,
    pub stats: ServeStats,
}

/// Aggregate service statistics.
#[derive(Debug, Clone, Copy, Default)]
pub struct ServeStats {
    pub requests: u64,
    pub batches: u64,
    pub padded_rows: u64,
    pub total_latency_s: f64,
    pub total_tokens: u64,
    pub busy_s: f64,
}

impl ServeStats {
    /// Mean request latency in seconds.
    pub fn mean_latency_s(&self) -> f64 {
        if self.requests == 0 { 0.0 } else { self.total_latency_s / self.requests as f64 }
    }

    /// Request tokens per second of execute busy time.
    pub fn tokens_per_s(&self) -> f64 {
        if self.busy_s == 0.0 { 0.0 } else { self.total_tokens as f64 / self.busy_s }
    }

    /// Fraction of executed rows that were padding (batch under-fill) —
    /// the serving analogue of grouped-GEMM tile waste.
    pub fn padding_frac(&self) -> f64 {
        let executed = self.padded_rows as f64 + self.requests as f64;
        if executed == 0.0 {
            return 0.0;
        }
        self.padded_rows as f64 / executed
    }
}

impl Server {
    /// Open on the default backend (`SONIC_BACKEND`, native unless set).
    pub fn new(artifacts_dir: &str, config: &str) -> Result<Server> {
        Self::new_with_backend(artifacts_dir, config, "")
    }

    /// Open on a named backend ("" = default).
    pub fn new_with_backend(artifacts_dir: &str, config: &str, backend: &str) -> Result<Server> {
        let core = ScoreCore::new_with_backend(artifacts_dir, config, backend)?;
        let (rows, seq) = (core.rows, core.seq);
        Ok(Server { core, queue: VecDeque::new(), rows, seq, stats: ServeStats::default() })
    }

    /// Execution backend serving this config.
    pub fn backend_name(&self) -> &'static str {
        self.core.backend_name()
    }

    /// Vocabulary size of the served model.
    pub fn vocab(&self) -> usize {
        self.core.vocab()
    }

    /// Replace parameters (e.g. from a trained checkpoint).
    pub fn load_checkpoint(&mut self, dir: &str) -> Result<()> {
        self.core.load_checkpoint(dir)
    }

    /// Enqueue a request (tokens are clamped to vocab, truncated/padded
    /// to the artifact's static sequence length).
    pub fn submit(&mut self, id: u64, tokens: Vec<i32>) {
        self.queue.push_back(Request { id, tokens });
    }

    /// Requests queued but not yet executed.
    pub fn pending(&self) -> usize {
        self.queue.len()
    }

    /// Serve one microbatch (up to `rows` requests). Returns responses
    /// in request order; empty when the queue is drained. Each response
    /// carries the request's own CE when the eval artifact exports the
    /// per-row contract (builtin native configs), the batch mean
    /// otherwise.
    pub fn serve_batch(&mut self) -> Result<Vec<Response>> {
        if self.queue.is_empty() {
            return Ok(Vec::new());
        }
        let t0 = Instant::now();
        let mut batch: Vec<Request> = Vec::with_capacity(self.rows);
        for _ in 0..self.rows {
            match self.queue.pop_front() {
                Some(r) => batch.push(r),
                None => break,
            }
        }
        let taken = batch.len();
        let toks: Vec<&[i32]> = batch.iter().map(|r| r.tokens.as_slice()).collect();
        // legacy accounting: always execute the canonical full shape
        let score = self.core.score_batch(&toks, self.rows)?;
        let dt = t0.elapsed().as_secs_f64();

        self.stats.padded_rows += (score.exec_rows - taken) as u64;
        self.stats.requests += taken as u64;
        self.stats.batches += 1;
        self.stats.total_latency_s += dt * taken as f64;
        self.stats.total_tokens += (taken * self.seq) as u64;
        self.stats.busy_s += dt;
        Ok(batch
            .into_iter()
            .zip(score.ce)
            .map(|(r, ce)| Response { id: r.id, ce, ppl: ce.exp(), latency_s: dt })
            .collect())
    }

    /// Exact per-request scoring: replicate one request across all batch
    /// rows so the batch-mean CE *is* the request's CE.
    pub fn score_exact(&mut self, tokens: &[i32]) -> Result<f64> {
        self.core.score_exact(tokens)
    }

    /// Drain the queue, returning all responses.
    pub fn drain(&mut self) -> Result<Vec<Response>> {
        let mut all = Vec::new();
        while !self.queue.is_empty() {
            all.extend(self.serve_batch()?);
        }
        Ok(all)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn core() -> ScoreCore {
        // built-in native config: no artifacts dir needed
        ScoreCore::new_with_backend("/nonexistent-artifacts", "small", "native").unwrap()
    }

    #[test]
    fn core_discovers_eval_shapes() {
        let c = core();
        // builtin small: batch 4 plus b1/b2/b8 variants
        assert_eq!(c.batch_shapes(), &[1, 2, 4, 8]);
        assert_eq!(c.max_batch(1), 8);
        assert_eq!(c.max_batch(4), 8);
        assert_eq!(c.max_batch(3), 8, "no multiple of 3 — falls back to largest");
        assert_eq!(c.pick_shape(1, 1), 1);
        assert_eq!(c.pick_shape(1, 2), 2);
        assert_eq!(c.pick_shape(3, 2), 4);
        assert_eq!(c.pick_shape(3, 4), 4);
        assert_eq!(c.pick_shape(5, 4), 8);
        assert_eq!(c.pick_shape(8, 4), 8);
    }

    /// The per-row scores of a mixed batch must equal `score_exact` of
    /// each request (<= 1e-6): the satellite guarantee the gateway
    /// relies on for exact per-request responses.
    #[test]
    fn score_batch_per_row_matches_score_exact() {
        let mut c = core();
        let seq = c.seq;
        let reqs: Vec<Vec<i32>> = vec![
            (0..5).map(|j| (j * 3 + 1) as i32).collect(),
            (0..seq).map(|j| (j * 7 + 2) as i32).collect(),
            (0..2 * seq).map(|j| (j * 11 + 3) as i32).collect(),
        ];
        let refs: Vec<&[i32]> = reqs.iter().map(|r| r.as_slice()).collect();
        let score = c.score_batch(&refs, 1).unwrap();
        assert!(score.per_row, "builtin config must export ce_rows");
        assert_eq!(score.ce.len(), 3);
        assert_eq!(score.exec_rows, 4, "3 requests -> shape 4 at m_tile=1");
        for (i, r) in reqs.iter().enumerate() {
            let exact = c.score_exact(r).unwrap();
            assert!(
                (score.ce[i] - exact).abs() <= 1e-6,
                "req {i}: batch per-row {} vs exact {exact}",
                score.ce[i]
            );
        }
        // rows genuinely differ, so the mean is not any single row
        assert!((score.ce[0] - score.ce[1]).abs() > 1e-9);
    }

    #[test]
    fn score_batch_tile_quantizes_rows() {
        let mut c = core();
        let one = vec![1, 2, 3];
        let reqs: Vec<&[i32]> = vec![one.as_slice()];
        // m_tile=2: a single request executes the 2-row shape
        let s = c.score_batch(&reqs, 2).unwrap();
        assert_eq!(s.exec_rows, 2);
        // m_tile=rows: the canonical full shape
        let s = c.score_batch(&reqs, c.rows).unwrap();
        assert_eq!(s.exec_rows, 4);
        // oversized batch errors instead of silently truncating
        let many: Vec<&[i32]> = (0..9).map(|_| one.as_slice()).collect();
        assert!(c.score_batch(&many, 1).is_err());
    }

    /// A bf16 scoring core serves round-tripped numerics: CE moves
    /// from f32 by at most the documented 1e-2 relative bound, and the
    /// per-row == score_exact contract still holds within the core.
    #[test]
    fn bf16_score_core_bounds_ce_drift() {
        let mut f = core();
        let mut b = ScoreCore::new_with_dtype(
            "/nonexistent-artifacts",
            "small",
            "native",
            Dtype::Bf16,
        )
        .unwrap();
        assert_eq!(f.dtype(), Dtype::F32);
        assert_eq!(b.dtype(), Dtype::Bf16);
        // satellite: the native bf16 core *stores* bf16 (direct path)
        // instead of round-tripping through f32-sized staging
        assert!(
            b.weight_bytes() < f.weight_bytes(),
            "bf16 staged bytes {} not below f32 staging {}",
            b.weight_bytes(),
            f.weight_bytes()
        );
        let toks: Vec<i32> = (0..f.seq).map(|j| ((j * 7 + 2) % 251) as i32).collect();
        let ce_f = f.score_exact(&toks).unwrap();
        let ce_b = b.score_exact(&toks).unwrap();
        assert!(ce_b.is_finite());
        let rel = ((ce_b - ce_f) / ce_f).abs();
        assert!(rel <= 1e-2, "bf16 CE {ce_b} vs f32 {ce_f}: relative drift {rel:e}");
        // within the bf16 core the per-row/exact contract is unchanged
        let reqs: Vec<&[i32]> = vec![&toks];
        let s = b.score_batch(&reqs, 1).unwrap();
        assert!((s.ce[0] - ce_b).abs() <= 1e-6, "bf16 per-row {} vs exact {ce_b}", s.ce[0]);
    }

    /// A residency-tiered scoring core with the expert budget capped
    /// to a single blob returns scores bitwise identical to the fully
    /// resident core (f32: artifact path; bf16: direct dense path),
    /// while actually spilling and evicting.
    #[test]
    fn tiered_score_core_is_bitwise_identical_under_cap() {
        for dtype in [Dtype::F32, Dtype::Bf16] {
            let mut dense = ScoreCore::new_with_dtype(
                "/nonexistent-artifacts",
                "small",
                "native",
                dtype,
            )
            .unwrap();
            let spec = ResidencySpec::new(1, None); // clamps up to one blob
            let mut tiered = ScoreCore::new_with_residency(
                "/nonexistent-artifacts",
                "small",
                "native",
                dtype,
                &spec,
            )
            .unwrap();
            assert!(tiered.residency().is_some());
            let seq = dense.seq;
            let reqs: Vec<Vec<i32>> = (0..3)
                .map(|i: usize| (0..seq).map(|j| ((i * 13 + j * 5 + 1) % 251) as i32).collect())
                .collect();
            let refs: Vec<&[i32]> = reqs.iter().map(|r| r.as_slice()).collect();
            let want = dense.score_batch(&refs, 1).unwrap();
            let got = tiered.score_batch(&refs, 1).unwrap();
            assert_eq!(got.ce, want.ce, "dtype {dtype:?}: tiered scores diverged");
            assert_eq!(got.mean, want.mean);
            let exact_w = dense.score_exact(&reqs[0]).unwrap();
            let exact_g = tiered.score_exact(&reqs[0]).unwrap();
            assert_eq!(exact_g, exact_w);
            let snap = spec.stats.snapshot();
            assert!(snap.total.evictions > 0, "one-blob budget must evict");
            assert!(snap.total.hits + snap.total.misses > 0);
            assert!(tiered.weight_bytes() < dense.weight_bytes());
        }
    }

    #[test]
    fn server_reports_per_request_ce() {
        let mut s = Server::new("/nonexistent-artifacts", "small").unwrap();
        let seq = s.seq;
        for id in 0..3u64 {
            let toks: Vec<i32> =
                (0..seq).map(|j| ((id as usize * 13 + j * 5 + 1) % 251) as i32).collect();
            s.submit(id, toks);
        }
        let rs = s.drain().unwrap();
        assert_eq!(rs.len(), 3);
        // per-request CE: not all equal (the old batch-mean behavior)
        assert!(
            (rs[0].ce - rs[1].ce).abs() > 1e-9 || (rs[1].ce - rs[2].ce).abs() > 1e-9,
            "responses still report a shared batch mean"
        );
        assert_eq!(s.stats.requests, 3);
        assert_eq!(s.stats.batches, 1);
        assert_eq!(s.stats.padded_rows, 1);
    }
}
