//! Synthetic pretraining corpus + batching (the FineWeb-Edu substitute,
//! DESIGN.md "Substitutions").
//!
//! The generator is a order-1 Markov chain over a Zipf-distributed
//! vocabulary with a small number of latent "topics": enough structure
//! that a language model's loss drops well below the unigram entropy
//! within a few hundred steps, while staying fully deterministic.

use crate::util::prng::Prng;

/// Corpus configuration.
#[derive(Debug, Clone, Copy)]
pub struct CorpusConfig {
    pub vocab: usize,
    pub topics: usize,
    /// Zipf exponent for the unigram distribution.
    pub zipf_s: f64,
    /// Probability of staying in the current topic per token.
    pub topic_stickiness: f64,
}

impl Default for CorpusConfig {
    fn default() -> Self {
        CorpusConfig { vocab: 256, topics: 8, zipf_s: 1.1, topic_stickiness: 0.98 }
    }
}

/// A deterministic synthetic token stream.
pub struct Corpus {
    cfg: CorpusConfig,
    rng: Prng,
    /// Per-topic unigram weights (vocab each).
    topic_weights: Vec<Vec<f64>>,
    topic: usize,
    prev: usize,
    /// Bigram coupling: each token biases a successor window.
    successor: Vec<usize>,
}

impl Corpus {
    pub fn new(cfg: CorpusConfig, seed: u64) -> Corpus {
        let mut rng = Prng::new(seed);
        let mut topic_weights = Vec::with_capacity(cfg.topics);
        for t in 0..cfg.topics {
            // each topic prefers a shifted slice of the vocab, Zipf-decayed
            let mut w = vec![0f64; cfg.vocab];
            let shift = t * cfg.vocab / cfg.topics;
            for (i, wi) in w.iter_mut().enumerate() {
                let r = ((i + cfg.vocab - shift) % cfg.vocab + 1) as f64;
                *wi = r.powf(-cfg.zipf_s);
            }
            topic_weights.push(w);
        }
        let successor = (0..cfg.vocab).map(|_| rng.below(cfg.vocab as u64) as usize).collect();
        Corpus { cfg, rng, topic_weights, topic: 0, prev: 0, successor }
    }

    /// Next token id.
    pub fn next_token(&mut self) -> i32 {
        if !self.rng.bernoulli(self.cfg.topic_stickiness) {
            self.topic = self.rng.below(self.cfg.topics as u64) as usize;
        }
        // 50%: bigram continuation (deterministic successor + noise),
        // else topic unigram draw — gives learnable local structure.
        let tok = if self.rng.bernoulli(0.5) {
            (self.successor[self.prev] + self.rng.below(4) as usize) % self.cfg.vocab
        } else {
            self.rng.categorical(&self.topic_weights[self.topic])
        };
        self.prev = tok;
        tok as i32
    }

    /// Fill a (batch, seq) token matrix, row-major.
    pub fn next_batch(&mut self, batch: usize, seq: usize) -> Vec<i32> {
        (0..batch * seq).map(|_| self.next_token()).collect()
    }
}

/// Batching iterator with a held-out validation stream (distinct seed).
pub struct Loader {
    pub train: Corpus,
    pub valid: Corpus,
    pub batch: usize,
    pub seq: usize,
}

impl Loader {
    pub fn new(cfg: CorpusConfig, batch: usize, seq: usize, seed: u64) -> Loader {
        Loader {
            train: Corpus::new(cfg, seed),
            valid: Corpus::new(cfg, seed ^ 0xDEAD_BEEF),
            batch,
            seq,
        }
    }

    pub fn train_batch(&mut self) -> Vec<i32> {
        self.train.next_batch(self.batch, self.seq)
    }

    pub fn valid_batch(&mut self) -> Vec<i32> {
        self.valid.next_batch(self.batch, self.seq)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_and_in_range() {
        let cfg = CorpusConfig::default();
        let mut a = Corpus::new(cfg, 1);
        let mut b = Corpus::new(cfg, 1);
        let xa = a.next_batch(2, 64);
        let xb = b.next_batch(2, 64);
        assert_eq!(xa, xb);
        assert!(xa.iter().all(|&t| t >= 0 && (t as usize) < cfg.vocab));
        let mut c = Corpus::new(cfg, 2);
        assert_ne!(xa, c.next_batch(2, 64));
    }

    #[test]
    fn zipf_head_is_heavy() {
        let cfg = CorpusConfig { topic_stickiness: 0.0, ..Default::default() };
        let mut c = Corpus::new(cfg, 3);
        let toks = c.next_batch(1, 20_000);
        let mut counts = vec![0usize; cfg.vocab];
        for &t in &toks {
            counts[t as usize] += 1;
        }
        let head: usize = counts[..8].iter().sum();
        let tail: usize = counts[cfg.vocab - 8..].iter().sum();
        assert!(head > tail, "head {head} tail {tail}");
    }

    #[test]
    fn bigram_structure_is_learnable() {
        // successor(t) within a window of 4 should be far more likely
        // than chance
        let cfg = CorpusConfig::default();
        let mut c = Corpus::new(cfg, 4);
        let toks = c.next_batch(1, 30_000);
        let succ = c.successor.clone();
        let mut hits = 0usize;
        for w in toks.windows(2) {
            let (a, b) = (w[0] as usize, w[1] as usize);
            let d = (b + cfg.vocab - succ[a]) % cfg.vocab;
            if d < 4 {
                hits += 1;
            }
        }
        let rate = hits as f64 / (toks.len() - 1) as f64;
        let chance = 4.0 / cfg.vocab as f64;
        assert!(rate > 5.0 * chance, "rate {rate:.3} vs chance {chance:.3}");
    }

    #[test]
    fn loader_streams_differ() {
        let mut l = Loader::new(CorpusConfig::default(), 2, 32, 0);
        assert_ne!(l.train_batch(), l.valid_batch());
    }
}
