//! Seeded deterministic sampling on decode logits: temperature /
//! top-k / top-p next to greedy.
//!
//! Each request owns a [`Sampler`] whose PRNG stream is derived from
//! the request id, so the same request (id, prompt, sampling knobs)
//! replays the same tokens on any gateway — determinism is part of the
//! serving contract, like everywhere else in this repo. Temperature 0
//! (the default) is exact greedy argmax with lowest-index tie-break,
//! bitwise identical to [`argmax`]; speculative decoding requires it
//! (acceptance is only exact against the greedy rule).

use crate::coordinator::decode::argmax;
use crate::util::prng::Prng;

/// Sampling knobs of one request. All-default means greedy.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SamplerCfg {
    /// Softmax temperature; `<= 0` selects exact greedy decoding.
    pub temperature: f32,
    /// Keep only the `top_k` highest logits (0 = no top-k cut).
    pub top_k: usize,
    /// Nucleus sampling: keep the smallest probability mass `>= top_p`
    /// (`<= 0` or `>= 1` = no nucleus cut).
    pub top_p: f32,
}

impl Default for SamplerCfg {
    fn default() -> Self {
        SamplerCfg { temperature: 0.0, top_k: 0, top_p: 0.0 }
    }
}

impl SamplerCfg {
    /// Greedy configurations never consult the PRNG, so greedy requests
    /// are exactly reproducible against `argmax`-based references.
    pub fn is_greedy(&self) -> bool {
        self.temperature <= 0.0
    }
}

/// Per-request sampler: knobs + a deterministic PRNG stream, plus
/// reusable candidate/probability scratch so a sampled stream stays
/// allocation-free after its first token (matching the decode loop's
/// arena discipline).
#[derive(Debug, Clone)]
pub struct Sampler {
    cfg: SamplerCfg,
    rng: Prng,
    idx: Vec<usize>,
    probs: Vec<f64>,
}

impl Sampler {
    /// Build the sampler for one request; the stream is a pure function
    /// of the request id (plus a domain constant so it never collides
    /// with the data-pipeline streams).
    pub fn new(cfg: SamplerCfg, request_id: u64) -> Sampler {
        Sampler {
            cfg,
            rng: Prng::new(0x5350_4543_u64 ^ request_id.wrapping_mul(0x9E37_79B9_7F4A_7C15)),
            idx: Vec::new(),
            probs: Vec::new(),
        }
    }

    /// The sampling configuration this sampler was built with.
    pub fn cfg(&self) -> &SamplerCfg {
        &self.cfg
    }

    /// Pick the next token from one row of logits.
    pub fn pick(&mut self, logits: &[f32]) -> i32 {
        if self.cfg.is_greedy() {
            return argmax(logits);
        }
        // order candidates by logit, descending; ties break on the
        // lower index so the ordering (and thus the draw) is total and
        // deterministic. With a top-k cut the top set is isolated by a
        // partial select first, so only k elements pay the sort.
        self.idx.clear();
        self.idx.extend(0..logits.len());
        let cmp = |a: &usize, b: &usize| {
            logits[*b]
                .partial_cmp(&logits[*a])
                .unwrap_or(std::cmp::Ordering::Equal)
                .then(a.cmp(b))
        };
        let keep = if self.cfg.top_k == 0 {
            self.idx.len()
        } else {
            self.cfg.top_k.min(self.idx.len()).max(1)
        };
        if keep < self.idx.len() {
            // the comparator is a total order, so the selected top-k
            // *set* is deterministic even though the partition's
            // internal arrangement is not — the sort below fixes it
            self.idx.select_nth_unstable_by(keep - 1, cmp);
            self.idx.truncate(keep);
        }
        self.idx.sort_by(cmp);
        // temperature softmax over the kept set (f64 accumulation,
        // max-subtracted for stability)
        let t = f64::from(self.cfg.temperature);
        let mx = f64::from(logits[self.idx[0]]);
        self.probs.clear();
        self.probs.extend(self.idx.iter().map(|&i| ((f64::from(logits[i]) - mx) / t).exp()));
        let total: f64 = self.probs.iter().sum();
        // nucleus cut: smallest prefix of the sorted set reaching top_p
        // of the mass (the prefix is sorted descending, so this is the
        // standard nucleus)
        let p = f64::from(self.cfg.top_p);
        if p > 0.0 && p < 1.0 {
            let mut cum = 0.0;
            let mut cut = self.probs.len();
            for (j, pr) in self.probs.iter().enumerate() {
                cum += pr / total;
                if cum >= p {
                    cut = j + 1;
                    break;
                }
            }
            self.probs.truncate(cut);
            self.idx.truncate(cut);
        }
        let total: f64 = self.probs.iter().sum();
        let mut x = self.rng.f64() * total;
        for (j, pr) in self.probs.iter().enumerate() {
            x -= pr;
            if x <= 0.0 {
                return self.idx[j] as i32;
            }
        }
        self.idx[self.idx.len() - 1] as i32
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn logits() -> Vec<f32> {
        vec![0.1, 2.5, -1.0, 2.5, 0.9, -3.0, 1.5, 0.0]
    }

    #[test]
    fn zero_temperature_is_exact_greedy() {
        let mut s = Sampler::new(SamplerCfg::default(), 7);
        for _ in 0..5 {
            assert_eq!(s.pick(&logits()), argmax(&logits()));
        }
        // greedy ties break low, matching argmax
        assert_eq!(s.pick(&[1.0, 3.0, 3.0]), 1);
    }

    #[test]
    fn same_request_id_replays_the_same_stream() {
        let cfg = SamplerCfg { temperature: 1.0, top_k: 0, top_p: 0.0 };
        let mut a = Sampler::new(cfg, 42);
        let mut b = Sampler::new(cfg, 42);
        let mut c = Sampler::new(cfg, 43);
        let xs: Vec<i32> = (0..64).map(|_| a.pick(&logits())).collect();
        let ys: Vec<i32> = (0..64).map(|_| b.pick(&logits())).collect();
        let zs: Vec<i32> = (0..64).map(|_| c.pick(&logits())).collect();
        assert_eq!(xs, ys, "the stream must be a function of the request id");
        assert_ne!(xs, zs, "different ids draw different streams");
    }

    #[test]
    fn top_k_restricts_the_support() {
        let cfg = SamplerCfg { temperature: 1.0, top_k: 2, top_p: 0.0 };
        let mut s = Sampler::new(cfg, 1);
        for _ in 0..200 {
            let t = s.pick(&logits());
            // the two largest logits sit at indices 1 and 3 (tied 2.5)
            assert!(t == 1 || t == 3, "top-2 sampling drew index {t}");
        }
    }

    #[test]
    fn top_p_keeps_the_nucleus() {
        // one dominant token: a tight nucleus collapses to greedy
        let dom = vec![0.0f32, 10.0, 0.1, -2.0];
        let cfg = SamplerCfg { temperature: 1.0, top_k: 0, top_p: 0.5 };
        let mut s = Sampler::new(cfg, 9);
        for _ in 0..100 {
            assert_eq!(s.pick(&dom), 1);
        }
        // a flat distribution with p ~ 1 keeps everything reachable
        let flat = vec![1.0f32; 4];
        let cfg = SamplerCfg { temperature: 1.0, top_k: 0, top_p: 0.999 };
        let mut s = Sampler::new(cfg, 9);
        let mut seen = [false; 4];
        for _ in 0..400 {
            seen[s.pick(&flat) as usize] = true;
        }
        assert!(seen.iter().all(|&b| b), "flat logits must reach every token: {seen:?}");
    }

    #[test]
    fn high_temperature_flattens_low_sharpens() {
        let lg = vec![0.0f32, 1.0];
        let count_ones = |temp: f32| {
            let mut s = Sampler::new(
                SamplerCfg { temperature: temp, top_k: 0, top_p: 0.0 },
                3,
            );
            (0..2000).filter(|_| s.pick(&lg) == 1).count()
        };
        let hot = count_ones(10.0);
        let cold = count_ones(0.1);
        assert!(cold > hot, "low temperature must concentrate on the max ({cold} vs {hot})");
        assert!(cold > 1990, "temperature 0.1 over a 1.0 gap is near-deterministic");
        assert!(hot > 800 && hot < 1200, "temperature 10 is near-uniform, got {hot}");
    }
}
