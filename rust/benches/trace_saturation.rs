//! Trace-driven saturation sweep: replay a committed bursty multi-tenant
//! trace against the in-process gateway at increasing time compression
//! and find each batching policy's shed knee.
//!
//! For every policy the trace is replayed at a ladder of speed
//! multipliers (offered load = trace rate × speed). As the offered load
//! crosses the gateway's capacity the admission queue fills and the
//! shed rate climbs; the *knee* is the highest offered rate the policy
//! still serves with ≤ 5% shed. The record reports the knee in req/s,
//! plus p99 latency and TTFT p99 at the knee and the shed rate at the
//! top of the ladder — the direction-aware metrics `bench_gate.py`
//! watches (`knee_rps` higher-is-better, `shed_rate` lower-is-better).
//!
//! A second sweep replays the same ladder through the front tier over
//! one and two gateway replicas (same per-replica capacity), so the
//! record attributes the knee per replica and shows how capacity
//! scales with the replica count. A scripted failover drill — a
//! believed-healthy replica dies mid-run and its replacement lives on
//! another address — contributes `failover_p99_ms` (lower is better)
//! and `front_success_rate` (higher is better) to the gate.
//!
//! A tracing-overhead probe runs the same closed-loop score workload
//! with the span flight recorder fully off and with every request
//! sampled, on a no-delay config so the instrumented native path
//! dominates; `obs_overhead_frac` (off/on throughput, 1.0 = free) is
//! gated at a tight 1.05 factor by `bench_gate.py`.
//!
//! Emits one JSON record (line starting with `{"bench":`) for the bench
//! trajectory. `SONIC_TRACE_BENCH_EVENTS` truncates the trace (CI smoke
//! uses a small value); `SONIC_TRACE_BENCH_SPEEDS` overrides the speed
//! ladder (comma-separated multipliers).

use std::collections::BTreeMap;
use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::time::{Duration, Instant};

use sonic_moe::front::{Front, FrontConfig, ReplicaSpec};
use sonic_moe::gateway::loadgen::{
    run_inprocess, run_trace, LoadgenConfig, TraceReport, TraceRunConfig,
};
use sonic_moe::gateway::trace::Trace;
use sonic_moe::gateway::{BatchPolicy, ClientMsg, Gateway, GatewayConfig, ServerMsg};
use sonic_moe::util::json::Json;

/// Committed trace replayed by this bench (also parsed by the
/// `trace_replay` integration test, so a malformed file fails fast).
const TRACE_PATH: &str =
    concat!(env!("CARGO_MANIFEST_DIR"), "/../bench/traces/bursty_mixed.jsonl");

/// Simulated model latency per batch: dominates native eval time so the
/// capacity (and therefore the knee) is stable across machines.
const WORKER_DELAY_MS: u64 = 40;

/// Shed-rate threshold that defines the knee.
const KNEE_SHED: f64 = 0.05;

/// Scores pushed through the failover drill (half before the replica
/// dies, half after).
const DRILL_SCORES: usize = 16;

fn gw_cfg(policy: BatchPolicy) -> GatewayConfig {
    GatewayConfig {
        artifacts_dir: "/nonexistent-artifacts-dir".to_string(),
        config: "small".to_string(),
        backend: "native".to_string(),
        addr: "127.0.0.1:0".to_string(),
        workers: 1,
        queue_cap: 4, // small: saturation sheds rather than queueing forever
        policy,
        m_tile: 4,
        worker_delay_ms: WORKER_DELAY_MS,
        gen_max_new: 8,
        draft_config: Some("small-draft".to_string()), // spec tenant needs a draft
        ..GatewayConfig::default()
    }
}

/// `report.to_json()` with the point renamed for the bench record: the
/// per-point label is the speed multiplier (`x1`, `x2`, …) so
/// `bench_gate.py` keys points by speed while the summary object keeps
/// the policy label.
fn point_json(report: &TraceReport, speed: f64) -> Json {
    match report.to_json() {
        Json::Obj(mut m) => {
            m.remove("policy");
            m.insert("name".to_string(), Json::Str(format!("x{speed}")));
            Json::Obj(m)
        }
        other => other,
    }
}

/// Knee of one ladder: the highest offered load still served with
/// ≤ `KNEE_SHED` shed (fallback: the lowest rung, so the metric is
/// always present).
fn knee_of(points: &[(f64, TraceReport)]) -> &(f64, TraceReport) {
    points
        .iter()
        .filter(|(_, r)| r.shed_rate <= KNEE_SHED)
        .max_by(|a, b| a.1.offered_rps.total_cmp(&b.1.offered_rps))
        .unwrap_or(&points[0])
}

/// Reserve a loopback port nothing listens on: the drill's replacement
/// replica binds it later, so the front's second replica address is
/// dead until then.
fn reserve_addr() -> String {
    let l = std::net::TcpListener::bind("127.0.0.1:0").expect("reserve port");
    let addr = l.local_addr().unwrap().to_string();
    drop(l);
    addr
}

/// Scripted failover drill: a front over one live gateway plus one
/// dead address. Mid-run the live gateway is shut down for real and a
/// replacement starts on the other address — the front's health belief
/// is stale, so the next score fails on transport and retries onto the
/// replacement. Returns `(failover_p99_ms, front_success_rate)`.
fn failover_drill() -> (f64, f64) {
    let mut cfg = gw_cfg(BatchPolicy::Immediate);
    cfg.worker_delay_ms = 5; // the drill measures failover, not capacity
    let gw0 = Gateway::start(cfg.clone()).expect("drill replica");
    let mut gw0 = Some(gw0);
    let spare = reserve_addr();
    let front = Front::start(FrontConfig {
        replicas: vec![
            ReplicaSpec {
                addr: gw0.as_ref().unwrap().local_addr().to_string(),
                model: String::new(),
            },
            ReplicaSpec { addr: spare.clone(), model: String::new() },
        ],
        // probe exactly once at startup: health beliefs only change
        // through relays, so the failover is scripted, never raced
        probe_interval_ms: 3_600_000,
        fail_threshold: 100,
        retry_base_ms: 1,
        ..FrontConfig::default()
    })
    .expect("drill front");
    let deadline = Instant::now() + Duration::from_secs(10);
    while front.stats_snapshot().probes < 2 {
        assert!(Instant::now() < deadline, "startup probes never completed");
        std::thread::sleep(Duration::from_millis(10));
    }

    let stream = TcpStream::connect(front.local_addr()).expect("connect front");
    stream.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    let mut writer = stream;
    let mut answered = 0usize;
    let mut replacement = None;
    for i in 0..DRILL_SCORES {
        if i == DRILL_SCORES / 2 {
            // the believed-healthy replica dies for real (joined, so it
            // is fully gone before the next score); its replacement
            // only exists on the so-far-dead address
            let dying = gw0.take().unwrap();
            dying.shutdown();
            dying.join();
            let mut cfg1 = cfg.clone();
            cfg1.addr = spare.clone();
            replacement = Some(Gateway::start(cfg1).expect("replacement replica"));
        }
        let tokens: Vec<i32> = (0..12).map(|j| ((i * 31 + j * 7 + 1) % 256) as i32).collect();
        let line = ClientMsg::Score { id: i as u64, tokens }.encode();
        writer.write_all(line.as_bytes()).unwrap();
        writer.write_all(b"\n").unwrap();
        writer.flush().unwrap();
        let mut reply = String::new();
        reader.read_line(&mut reply).expect("drill reply");
        if matches!(ServerMsg::parse(&reply), Ok(ServerMsg::Score { .. })) {
            answered += 1;
        }
    }
    let stats = front.stats_snapshot();
    let p99 = stats.failover_percentiles().map(|p| p.p99).unwrap_or(0.0);
    let success = answered as f64 / DRILL_SCORES as f64;
    println!(
        "failover drill: {answered}/{DRILL_SCORES} scores answered, {} failover(s), \
         failover p99 {:.1} ms",
        stats.failovers, p99
    );
    front.shutdown();
    front.join();
    if let Some(gw) = replacement {
        gw.shutdown();
        gw.join();
    }
    (p99, success)
}

/// Score requests pushed through each leg of the tracing-overhead
/// probe (`SONIC_OBS_BENCH_REQUESTS` overrides; CI smoke shrinks it).
const OBS_PROBE_REQUESTS: usize = 96;

/// Tracing-overhead probe: the same closed-loop score workload twice —
/// recorder fully off, then every request sampled — on a no-delay
/// config so the instrumented native path (not the simulated model
/// sleep) dominates the measurement. Returns off-over-on throughput:
/// 1.0 = tracing is free, 1.05 = 5% overhead (the gate's ceiling).
fn obs_overhead() -> f64 {
    let requests = std::env::var("SONIC_OBS_BENCH_REQUESTS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(OBS_PROBE_REQUESTS);
    let mut cfg = gw_cfg(BatchPolicy::Immediate);
    cfg.worker_delay_ms = 0;
    let lg = LoadgenConfig {
        requests,
        clients: 2,
        seq_hint: 48,
        seed: 17,
        ..LoadgenConfig::default()
    };
    let leg = |sampled: bool| -> f64 {
        sonic_moe::obs::set_enabled(sampled);
        sonic_moe::obs::set_sample_rate(1.0);
        run_inprocess(cfg.clone(), lg.clone()).expect("obs overhead leg").tokens_per_s
    };
    leg(false); // warmup: page in weights, settle the allocator
    let off = leg(false);
    let on = leg(true);
    sonic_moe::obs::set_enabled(true);
    let frac = if on > 0.0 { off / on } else { 1.0 };
    println!(
        "obs overhead probe: {requests} scores, {off:.0} tokens/s recorder-off vs \
         {on:.0} tokens/s fully sampled -> frac {frac:.3}\n"
    );
    frac
}

fn main() {
    let mut trace = Trace::load(std::path::Path::new(TRACE_PATH)).expect("committed trace");
    if let Ok(n) = std::env::var("SONIC_TRACE_BENCH_EVENTS") {
        let n: usize = n.parse().expect("SONIC_TRACE_BENCH_EVENTS must be an integer");
        if n > 0 && n < trace.events.len() {
            trace.events.truncate(n);
        }
    }
    let speeds: Vec<f64> = match std::env::var("SONIC_TRACE_BENCH_SPEEDS") {
        Ok(s) => s
            .split(',')
            .map(|t| t.trim().parse().expect("SONIC_TRACE_BENCH_SPEEDS entries must be numbers"))
            .collect(),
        Err(_) => vec![1.0, 2.0, 4.0],
    };
    let hold = Duration::from_millis(20);
    let policies = [
        ("immediate", BatchPolicy::Immediate),
        ("deadline", BatchPolicy::Deadline { max_wait: hold }),
        ("tile", BatchPolicy::TileRounded { m_tile: 4, max_wait: hold }),
    ];

    println!(
        "trace_saturation: {} events ({:.1} s span, base {:.1} req/s), speeds {:?}, \
         worker delay {WORKER_DELAY_MS}ms",
        trace.events.len(),
        trace.duration_ms() / 1e3,
        trace.offered_rps(),
        speeds
    );

    let mut policy_recs = Vec::new();
    for (pname, policy) in policies {
        let mut tbl = sonic_moe::bench::Table::new(
            &format!("policy {pname}: offered load ladder"),
            &["speed", "offered req/s", "ok", "shed", "shed %", "p99 ms", "ttft p99 ms"],
        );
        let mut points = Vec::new();
        for &speed in &speeds {
            let rc = TraceRunConfig { speed, ..TraceRunConfig::default() };
            let r = run_trace(gw_cfg(policy), &trace, rc).expect("trace replay");
            tbl.row(&[
                format!("x{speed}"),
                format!("{:.1}", r.offered_rps),
                r.ok.to_string(),
                r.shed.to_string(),
                format!("{:.1}", 100.0 * r.shed_rate),
                format!("{:.1}", r.p99_ms),
                format!("{:.1}", r.ttft_p99_ms),
            ]);
            points.push((speed, r));
        }
        tbl.print();

        let knee = knee_of(&points);
        let top = points.last().expect("at least one speed");
        println!(
            "policy {pname}: knee {:.1} req/s (shed {:.1}%), shed at x{} = {:.1}%\n",
            knee.1.offered_rps,
            100.0 * knee.1.shed_rate,
            top.0,
            100.0 * top.1.shed_rate
        );

        let mut m = BTreeMap::new();
        m.insert("policy".to_string(), Json::Str(pname.to_string()));
        m.insert("knee_rps".to_string(), Json::Num(knee.1.offered_rps));
        m.insert("knee_p99_ms".to_string(), Json::Num(knee.1.p99_ms));
        m.insert("knee_ttft_p99_ms".to_string(), Json::Num(knee.1.ttft_p99_ms));
        m.insert("shed_rate".to_string(), Json::Num(top.1.shed_rate));
        m.insert(
            "points".to_string(),
            Json::Arr(points.iter().map(|(s, r)| point_json(r, *s)).collect()),
        );
        policy_recs.push(Json::Obj(m));
    }

    // the same ladder through the front tier: one replica isolates the
    // relay overhead, two replicas show how the knee scales when the
    // front spreads load (each replica keeps the single-gateway config)
    let mut front_recs = Vec::new();
    let mut front_knees = Vec::new();
    for replicas in [1usize, 2] {
        let mut tbl = sonic_moe::bench::Table::new(
            &format!("front tier over {replicas} replica(s): offered load ladder"),
            &["speed", "offered req/s", "ok", "shed", "shed %", "p99 ms", "ttft p99 ms"],
        );
        let mut points = Vec::new();
        for &speed in &speeds {
            let rc =
                TraceRunConfig { speed, front_replicas: replicas, ..TraceRunConfig::default() };
            let r = run_trace(gw_cfg(BatchPolicy::Immediate), &trace, rc)
                .expect("front trace replay");
            tbl.row(&[
                format!("x{speed}"),
                format!("{:.1}", r.offered_rps),
                r.ok.to_string(),
                r.shed.to_string(),
                format!("{:.1}", 100.0 * r.shed_rate),
                format!("{:.1}", r.p99_ms),
                format!("{:.1}", r.ttft_p99_ms),
            ]);
            points.push((speed, r));
        }
        tbl.print();

        let knee = knee_of(&points);
        let top = points.last().expect("at least one speed");
        println!(
            "front x{replicas}: knee {:.1} req/s total = {:.1} req/s per replica \
             (shed at x{} = {:.1}%)\n",
            knee.1.offered_rps,
            knee.1.offered_rps / replicas as f64,
            top.0,
            100.0 * top.1.shed_rate
        );
        front_knees.push(knee.1.offered_rps);

        let mut m = BTreeMap::new();
        m.insert("name".to_string(), Json::Str(format!("front_x{replicas}")));
        m.insert("replicas".to_string(), Json::Num(replicas as f64));
        m.insert("knee_rps".to_string(), Json::Num(knee.1.offered_rps));
        m.insert(
            "knee_rps_per_replica".to_string(),
            Json::Num(knee.1.offered_rps / replicas as f64),
        );
        m.insert("knee_p99_ms".to_string(), Json::Num(knee.1.p99_ms));
        m.insert("shed_rate".to_string(), Json::Num(top.1.shed_rate));
        m.insert(
            "points".to_string(),
            Json::Arr(points.iter().map(|(s, r)| point_json(r, *s)).collect()),
        );
        front_recs.push(Json::Obj(m));
    }
    let scaling =
        if front_knees[0] > 0.0 { front_knees[1] / front_knees[0] } else { 0.0 };
    println!("front knee scaling 1 -> 2 replicas: {scaling:.2}x\n");

    let (failover_p99_ms, front_success_rate) = failover_drill();
    let obs_overhead_frac = obs_overhead();

    let mut front_obj = BTreeMap::new();
    front_obj.insert("sweeps".to_string(), Json::Arr(front_recs));
    front_obj.insert("knee_scaling_x".to_string(), Json::Num(scaling));
    front_obj.insert("failover_p99_ms".to_string(), Json::Num(failover_p99_ms));
    front_obj.insert("front_success_rate".to_string(), Json::Num(front_success_rate));

    let mut rec = BTreeMap::new();
    rec.insert("bench".to_string(), Json::Str("trace_saturation".to_string()));
    rec.insert("trace".to_string(), Json::Str(trace.name.clone()));
    rec.insert("events".to_string(), Json::Num(trace.events.len() as f64));
    rec.insert("base_rps".to_string(), Json::Num(trace.offered_rps()));
    rec.insert("worker_delay_ms".to_string(), Json::Num(WORKER_DELAY_MS as f64));
    rec.insert("policies".to_string(), Json::Arr(policy_recs));
    rec.insert("front".to_string(), Json::Obj(front_obj));
    rec.insert("obs_overhead_frac".to_string(), Json::Num(obs_overhead_frac));
    println!("{}", Json::Obj(rec));
}
