"""Backward Pallas kernels (dH, dW2, dX~, dW1, dX) vs the dense oracle.

The oracle is the closed-form Appendix-C backward, itself validated
against jax.grad in test_ref.py. The composition test exercises the full
5-kernel backward exactly as Figure 3 wires it.
"""

import jax.numpy as jnp
import numpy as np
import pytest

from compile.kernels import MoEConfig
from compile.kernels import aggregation, backward, grouped_gemm, metadata, ref

from .conftest import random_moe_inputs


CFGS = [
    MoEConfig(T=16, d=8, n=4, E=4, K=2, m_tile=4),
    MoEConfig(T=32, d=12, n=6, E=8, K=3, m_tile=8),
    MoEConfig(T=8, d=16, n=8, E=2, K=2, m_tile=16),
]


@pytest.fixture(params=CFGS, ids=str)
def case(request, rng):
    cfg = request.param
    x, w1, w2, pi, s = random_moe_inputs(rng, cfg)
    do = rng.normal(size=(cfg.T, cfg.d)).astype(np.float32)
    meta = metadata.build_metadata(cfg, jnp.asarray(pi), jnp.asarray(s))
    h_packed, a_packed = grouped_gemm.up_proj_swiglu(cfg, x, w1, meta)
    dx, dw1, dw2, ds = ref.moe_backward_dense(x, w1, w2, pi, s, do)
    return dict(
        cfg=cfg, x=x, w1=w1, w2=w2, pi=pi, s=s, do=do, meta=meta,
        h_packed=h_packed, a_packed=a_packed,
        want=dict(dx=dx, dw1=dw1, dw2=dw2, ds=ds),
    )


def test_dh_kernel_outputs(case):
    cfg, meta = case["cfg"], case["meta"]
    dh, ap, ds_slot = backward.down_proj_bwd_act(
        cfg, case["do"], case["w2"], case["h_packed"], meta
    )
    # Oracle per-(t,e) dH and A'
    h = jnp.einsum("td,edf->tef", case["x"], case["w1"])
    a = ref.swiglu(h)
    da_prime = jnp.einsum("td,end->ten", case["do"], case["w2"])
    gate = (case["pi"] * case["s"])[..., None]
    dh_dense = ref.dswiglu(gate * da_prime, h)
    ap_dense = gate * a

    slot_token = np.asarray(meta.slot_token)
    slot_valid = np.asarray(meta.slot_valid).astype(bool)
    off = np.asarray(meta.offsets)
    owner = np.searchsorted(off[1:], np.arange(cfg.cap_pad), side="right")
    dh, ap, ds_slot = np.asarray(dh), np.asarray(ap), np.asarray(ds_slot)
    ds_dense = np.asarray(case["want"]["ds"])
    for i in range(cfg.cap_pad):
        if slot_valid[i]:
            t, e = slot_token[i], owner[i]
            np.testing.assert_allclose(
                dh[i], np.asarray(dh_dense)[t, e], rtol=1e-4, atol=1e-5
            )
            np.testing.assert_allclose(
                ap[i], np.asarray(ap_dense)[t, e], rtol=1e-4, atol=1e-5
            )
            np.testing.assert_allclose(
                ds_slot[i], ds_dense[t, e], rtol=1e-4, atol=1e-5
            )
        else:
            assert np.abs(dh[i]).max() == 0.0
            assert np.abs(ap[i]).max() == 0.0
            assert ds_slot[i] == 0.0


def test_dw2_kernel(case):
    cfg, meta = case["cfg"], case["meta"]
    _, ap, _ = backward.down_proj_bwd_act(
        cfg, case["do"], case["w2"], case["h_packed"], meta
    )
    dw2 = backward.down_proj_bwd_weight(cfg, case["do"], ap, meta)
    np.testing.assert_allclose(
        np.asarray(dw2), np.asarray(case["want"]["dw2"]), rtol=1e-4, atol=1e-4
    )


def test_dw1_and_dx_kernels(case):
    cfg, meta = case["cfg"], case["meta"]
    dh, _, _ = backward.down_proj_bwd_act(
        cfg, case["do"], case["w2"], case["h_packed"], meta
    )
    dw1 = backward.up_proj_bwd_weight(cfg, case["x"], dh, meta)
    np.testing.assert_allclose(
        np.asarray(dw1), np.asarray(case["want"]["dw1"]), rtol=1e-4, atol=1e-4
    )
    dxt = backward.up_proj_bwd_act(cfg, dh, case["w1"], meta)
    dx = aggregation.grad_aggregate(cfg, dxt, meta)
    np.testing.assert_allclose(
        np.asarray(dx), np.asarray(case["want"]["dx"]), rtol=1e-4, atol=1e-4
    )


def test_ds_gather_back(case):
    """Gathering ds_slot through slot_of reproduces the dense dS."""
    cfg, meta = case["cfg"], case["meta"]
    _, _, ds_slot = backward.down_proj_bwd_act(
        cfg, case["do"], case["w2"], case["h_packed"], meta
    )
    padded = jnp.concatenate([ds_slot, jnp.zeros((1,), jnp.float32)])
    ds = padded[meta.slot_of]  # (T, E); sentinel -> 0
    np.testing.assert_allclose(
        np.asarray(ds), np.asarray(case["want"]["ds"]) * case["pi"],
        rtol=1e-4, atol=1e-5,
    )


def test_full_backward_composition(case):
    """All 5 backward kernels wired per Figure 3 reproduce jax.grad."""
    cfg, meta = case["cfg"], case["meta"]
    dh, ap, ds_slot = backward.down_proj_bwd_act(
        cfg, case["do"], case["w2"], case["h_packed"], meta
    )
    dw2 = backward.down_proj_bwd_weight(cfg, case["do"], ap, meta)
    dw1 = backward.up_proj_bwd_weight(cfg, case["x"], dh, meta)
    dxt = backward.up_proj_bwd_act(cfg, dh, case["w1"], meta)
    dx = aggregation.grad_aggregate(cfg, dxt, meta)

    import jax

    gx, g1, g2 = jax.grad(ref.moe_loss_for_autodiff, argnums=(0, 1, 2))(
        case["x"], case["w1"], case["w2"], case["pi"], case["s"], case["do"]
    )
    np.testing.assert_allclose(np.asarray(dx), np.asarray(gx), rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(dw1), np.asarray(g1), rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(dw2), np.asarray(g2), rtol=1e-4, atol=1e-4)
