//! Token rounding routing — Algorithm 4 with the Appendix G.2 rounding
//! subroutines (NR-f, SR-f, NR-s, Balance-f, UP, DOWN; Algorithm 6 for
//! Balance-f). Mirrors `python/compile/kernels/router.py`.

use crate::util::prng::Prng;

use super::tc::{sortable_bits, topk_row_into};
use super::Decision;

/// The `round_and_sparsify` subroutine choice.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RoundingRule {
    /// Nearest multiple of M_tile by expert frequency (paper default).
    NearestFreq,
    /// Stochastic rounding by expert frequency.
    StochasticFreq,
    /// Nearest by score mass between the two roundings (Eq. 13).
    NearestScore,
    /// Algorithm 6: accumulator-balanced rounding, preserves the total
    /// within M_tile/2.
    BalanceFreq,
    /// Always round up (pads EC tokens; model-TFLOPS lower bound).
    Up,
    /// Always round down (token dropping; model-TFLOPS upper bound).
    Down,
}

impl RoundingRule {
    /// All six rounding subroutines, in the paper's order.
    pub const ALL: [RoundingRule; 6] = [
        RoundingRule::NearestFreq,
        RoundingRule::StochasticFreq,
        RoundingRule::NearestScore,
        RoundingRule::BalanceFreq,
        RoundingRule::Up,
        RoundingRule::Down,
    ];

    /// Short rule name as used in the paper's tables.
    pub fn name(&self) -> &'static str {
        match self {
            RoundingRule::NearestFreq => "NR-f",
            RoundingRule::StochasticFreq => "SR-f",
            RoundingRule::NearestScore => "NR-s",
            RoundingRule::BalanceFreq => "Balance-f",
            RoundingRule::Up => "UP",
            RoundingRule::Down => "DOWN",
        }
    }
}

fn floor_ceil(f: usize, m: usize) -> (usize, usize) {
    (f / m * m, (f + m - 1) / m * m)
}

/// Round one count to a multiple of `m_tile` under `rule` — the scalar
/// core of `round_and_sparsify`, shared by the per-expert rounding
/// below and by the serving gateway's tile-aware batch sizing (the
/// batch-fill analogue of Algorithm 4). `rng` is consulted only by
/// [`RoundingRule::StochasticFreq`]. [`RoundingRule::BalanceFreq`] and
/// [`RoundingRule::NearestScore`] carry cross-expert state and fall
/// back to nearest-by-count here.
pub fn round_target(f: usize, m_tile: usize, rule: RoundingRule, rng: &mut Prng) -> usize {
    let m = m_tile.max(1);
    let (lo, hi) = floor_ceil(f, m);
    match rule {
        RoundingRule::Up => hi,
        RoundingRule::Down => lo,
        RoundingRule::StochasticFreq => {
            if lo == hi {
                lo
            } else {
                let p = (f - lo) as f64 / m as f64;
                if rng.bernoulli(p) { hi } else { lo }
            }
        }
        // NearestFreq semantics; Balance-f/NR-s need neighbours' state
        _ => {
            if hi - f < f - lo { hi } else { lo }
        }
    }
}

/// Token rounding over a (t, e) post-softmax score matrix.
///
/// `rng` is used only by the stochastic subroutines; pass any seeded
/// generator for deterministic replay.
pub fn token_rounding(
    scores: &[f32],
    t: usize,
    e: usize,
    k: usize,
    m_tile: usize,
    rule: RoundingRule,
    rng: &mut Prng,
) -> Decision {
    assert_eq!(scores.len(), t * e);
    // (1) TC top-K sorting
    let mut pi_tc = vec![false; t * e];
    let mut f = vec![0usize; e];
    let mut buf = Vec::with_capacity(k);
    for row in 0..t {
        let r = &scores[row * e..(row + 1) * e];
        topk_row_into(r, k, &mut buf);
        for &j in &buf {
            pi_tc[row * e + j] = true;
            f[j] += 1;
        }
    }

    // (2) rounding targets. All subroutines except NR-s depend only on
    // the frequencies; NR-s (Eq. 13) additionally needs per-column score
    // prefix sums, computed lazily from a full column sort.
    let mut keys: Vec<u64> = vec![0; t];
    let fill_keys = |keys: &mut [u64], j: usize| {
        // TC-preferred key: (sortable S' bits, !token) in one u64 so a
        // column ranking is a single integer sort/partition (the same
        // packing trick as the L1 bitonic kernel).
        for (tok, key) in keys.iter_mut().enumerate() {
            let s = scores[tok * e + j] - if pi_tc[tok * e + j] { 0.0 } else { 2.0 };
            *key = ((sortable_bits(s) as u64) << 32) | (!(tok as u32) as u64);
        }
    };
    let g = if rule == RoundingRule::NearestScore {
        let mut g = Vec::with_capacity(e);
        for j in 0..e {
            fill_keys(&mut keys, j);
            keys.sort_unstable_by(|a, b| b.cmp(a));
            let (lo, hi) = floor_ceil(f[j], m_tile);
            if lo == hi {
                g.push(lo);
                continue;
            }
            let sum_top = |n: usize| -> f64 {
                keys[..n.min(t)]
                    .iter()
                    .map(|key| {
                        let tok = !(*key as u32) as usize;
                        scores[tok * e + j] as f64
                    })
                    .sum()
            };
            let (s_lo, s_hi, s_f) = (sum_top(lo), sum_top(hi), sum_top(f[j]));
            let p = if s_hi > s_lo {
                ((s_f - s_lo) / (s_hi - s_lo)).clamp(0.0, 1.0)
            } else {
                0.0
            };
            g.push(if rng.bernoulli(p) { hi } else { lo });
        }
        g
    } else {
        round_targets_freq(rule, &f, m_tile, rng)
    };
    // cap: g_e must stay a reachable tile multiple
    let cap = t / m_tile * m_tile;
    let g: Vec<usize> = g.into_iter().map(|x| x.min(cap)).collect();

    // (4b) keep top g_e per expert. We only need the top-g_e *set*, not
    // full ranks: select_nth_unstable partitions each column in O(T)
    // instead of O(T log T) (§Perf: 5-8x on the routing hot path). The
    // packed key is a strict total order, so the selected set is
    // identical to the full-sort top-g (matches python exactly).
    let mut mask = vec![false; t * e];
    let mut sp = vec![0f32; t * e];
    for j in 0..e {
        let gj = g[j];
        if gj == 0 {
            continue;
        }
        fill_keys(&mut keys, j);
        if gj < t {
            // descending order: the top gj keys end up in keys[..gj]
            keys.select_nth_unstable_by(gj - 1, |a, b| b.cmp(a));
        }
        for key in &keys[..gj.min(t)] {
            let tok = !(*key as u32) as usize;
            mask[tok * e + j] = true;
            sp[tok * e + j] = scores[tok * e + j];
        }
    }
    Decision { t, e, mask, scores: sp, f, g }
}

fn round_targets_freq(
    rule: RoundingRule,
    f: &[usize],
    m: usize,
    rng: &mut Prng,
) -> Vec<usize> {
    match rule {
        RoundingRule::Up
        | RoundingRule::Down
        | RoundingRule::NearestFreq
        | RoundingRule::StochasticFreq => {
            f.iter().map(|&x| round_target(x, m, rule, rng)).collect()
        }
        RoundingRule::BalanceFreq => {
            // Algorithm 6: sequential accumulator z.
            let mut z: i64 = 0;
            f.iter()
                .map(|&x| {
                    let (lo, hi) = floor_ceil(x, m);
                    let r_up = hi as i64 - x as i64;
                    let r_dn = lo as i64 - x as i64;
                    if (r_up + z).abs() < (r_dn + z).abs() {
                        z += r_up;
                        hi
                    } else {
                        z += r_dn;
                        lo
                    }
                })
                .collect()
        }
        RoundingRule::NearestScore => unreachable!("handled in token_rounding"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::routing::synth_scores;
    use crate::util::propcheck::check;

    fn decide(seed: u64, t: usize, e: usize, k: usize, m: usize, rule: RoundingRule) -> Decision {
        let mut rng = Prng::new(seed);
        let scores = synth_scores(&mut rng, t, e, 0.7);
        token_rounding(&scores, t, e, k, m, rule, &mut rng)
    }

    #[test]
    fn prop_counts_are_tile_multiples_and_within_one_tile() {
        check("tr-invariants", 40, |g| {
            let e = *g.choice(&[4usize, 8, 16]);
            let k = g.usize_in(1, 3.min(e));
            let m = *g.choice(&[4usize, 8, 16]);
            let t = *g.choice(&[32usize, 64, 128]);
            let rule = *g.choice(&RoundingRule::ALL);
            let d = decide(g.seed, t, e, k, m, rule);
            for j in 0..e {
                assert_eq!(d.g[j] % m, 0, "{rule:?} e{j}");
                assert!(
                    (d.g[j] as i64 - d.f[j] as i64).unsigned_abs() < m as u64,
                    "deviation >= one tile: f={} g={}",
                    d.f[j],
                    d.g[j]
                );
            }
            // realized mask counts == targets
            for j in 0..e {
                let c = (0..t).filter(|&tok| d.mask[tok * e + j]).count();
                assert_eq!(c, d.g[j]);
            }
            // zero grouped-GEMM padding by construction
            assert_eq!(d.padding_rows(m), 0);
        });
    }

    #[test]
    fn prop_balance_total_within_half_tile() {
        check("balance-total", 30, |g| {
            let e = *g.choice(&[8usize, 16, 32]);
            let m = *g.choice(&[4usize, 8]);
            let t = 128;
            let k = 2;
            let d = decide(g.seed, t, e, k, m, RoundingRule::BalanceFreq);
            let total_f: i64 = d.f.iter().map(|&x| x as i64).sum();
            let total_g: i64 = d.g.iter().map(|&x| x as i64).sum();
            assert!(
                (total_g - total_f).abs() <= m as i64 / 2,
                "total drift {} > {}",
                (total_g - total_f).abs(),
                m / 2
            );
        });
    }

    #[test]
    fn prop_up_down_bracket() {
        check("up-down-bracket", 25, |g| {
            let e = 8;
            let k = 2;
            let m = 8;
            let t = 64;
            let up = decide(g.seed, t, e, k, m, RoundingRule::Up);
            let dn = decide(g.seed, t, e, k, m, RoundingRule::Down);
            for rule in [RoundingRule::NearestFreq, RoundingRule::BalanceFreq] {
                let d = decide(g.seed, t, e, k, m, rule);
                for j in 0..e {
                    assert!(dn.g[j] <= d.g[j] && d.g[j] <= up.g[j]);
                }
            }
        });
    }

    #[test]
    fn prop_tc_preference_at_boundary() {
        // Every kept token outscores every dropped TC token per expert;
        // padded EC tokens outscore every unrouted token.
        check("tc-preference", 25, |g| {
            let (t, e, k, m) = (64, 8, 2, 8);
            let mut rng = Prng::new(g.seed + 1000);
            let scores = synth_scores(&mut rng, t, e, 0.7);
            let tc = super::super::tc_topk(&scores, t, e, k);
            let d = token_rounding(&scores, t, e, k, m, RoundingRule::NearestFreq, &mut rng);
            for j in 0..e {
                let sc = |tok: usize| scores[tok * e + j];
                let kept: Vec<usize> = (0..t).filter(|&x| d.mask[x * e + j]).collect();
                let dropped: Vec<usize> = (0..t)
                    .filter(|&x| tc.mask[x * e + j] && !d.mask[x * e + j])
                    .collect();
                let padded: Vec<usize> = (0..t)
                    .filter(|&x| !tc.mask[x * e + j] && d.mask[x * e + j])
                    .collect();
                assert!(dropped.is_empty() || padded.is_empty());
                if let (Some(&kmin), Some(&dmax)) = (
                    kept.iter().min_by(|&&a, &&b| sc(a).partial_cmp(&sc(b)).unwrap()),
                    dropped.iter().max_by(|&&a, &&b| sc(a).partial_cmp(&sc(b)).unwrap()),
                ) {
                    assert!(sc(kmin) >= sc(dmax));
                }
                if !padded.is_empty() {
                    let unrouted: Vec<usize> = (0..t)
                        .filter(|&x| !tc.mask[x * e + j] && !d.mask[x * e + j])
                        .collect();
                    if !unrouted.is_empty() {
                        let pmin = padded.iter().map(|&x| sc(x)).fold(f32::MAX, f32::min);
                        let umax = unrouted.iter().map(|&x| sc(x)).fold(f32::MIN, f32::max);
                        assert!(pmin >= umax);
                    }
                }
            }
        });
    }

    #[test]
    fn round_target_scalar_rules() {
        let mut rng = Prng::new(0);
        assert_eq!(round_target(5, 8, RoundingRule::Up, &mut rng), 8);
        assert_eq!(round_target(5, 8, RoundingRule::Down, &mut rng), 0);
        assert_eq!(round_target(5, 8, RoundingRule::NearestFreq, &mut rng), 8);
        assert_eq!(round_target(3, 8, RoundingRule::NearestFreq, &mut rng), 0);
        assert_eq!(round_target(16, 8, RoundingRule::NearestFreq, &mut rng), 16);
        // degenerate tile never panics and is the identity
        assert_eq!(round_target(5, 1, RoundingRule::NearestFreq, &mut rng), 5);
        // stochastic stays on the bracketing multiples
        for _ in 0..50 {
            let g = round_target(5, 8, RoundingRule::StochasticFreq, &mut rng);
            assert!(g == 0 || g == 8);
        }
    }

    /// Edge cases surfaced by the serving gateway's slot quantization
    /// (decode batch fill runs through the same scalar subroutine).
    #[test]
    fn round_target_slot_quantization_edges() {
        let mut rng = Prng::new(1);
        for rule in RoundingRule::ALL {
            // target 0: an idle decode step executes nothing
            assert_eq!(round_target(0, 8, rule, &mut rng), 0, "{rule:?}");
            // tile 1: the identity (no padding ever)
            assert_eq!(round_target(5, 1, rule, &mut rng), 5, "{rule:?}");
            // tile 0 degenerates to 1 rather than dividing by zero
            assert_eq!(round_target(5, 0, rule, &mut rng), 5, "{rule:?}");
            // exact multiples are fixed points
            assert_eq!(round_target(16, 8, rule, &mut rng), 16, "{rule:?}");
        }
        // a target beyond the caller's capacity is produced here and
        // clamped by the caller (the gateway scheduler caps at its slot
        // count — see gateway::scheduler::quantize_rows)
        assert_eq!(round_target(5, 16, RoundingRule::Up, &mut rng), 16);
        assert_eq!(round_target(5, 16, RoundingRule::Down, &mut rng), 0);
    }

    #[test]
    fn down_never_exceeds_tc() {
        let d = decide(7, 64, 8, 2, 8, RoundingRule::Down);
        for j in 0..8 {
            assert!(d.g[j] <= d.f[j]);
        }
    }
}
