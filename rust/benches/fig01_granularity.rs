//! Bench: regenerate Figure 1 via the GPU performance simulator and time
//! the evaluation hot path. See DESIGN.md per-experiment index.

use sonic_moe::bench::{figures, Bencher};

fn main() {
    for t in figures::fig01() {
        t.print();
    }
    let mut b = Bencher::new("simulator/fig01_granularity");
    b.iter(|| figures::fig01());
    println!("{}", b.report());
}
