//! Gateway wire protocol: line-delimited JSON over TCP.
//!
//! One JSON object per `\n`-terminated line in each direction, parsed
//! and serialized through [`crate::util::json::Json`] (std-only — no
//! serde, no tokio). Client messages:
//!
//! ```text
//! {"type":"score","id":7,"tokens":[3,1,4,1,5]}   score a sequence
//! {"type":"stats"}                               service statistics
//! {"type":"reload","dir":"ckpt/"}                checkpoint hot-swap
//! {"type":"shutdown"}                            graceful drain + exit
//! ```
//!
//! Server messages mirror the request `type` (`score` responses carry
//! `ce`/`ppl`/`latency_ms`); failures are
//! `{"type":"error","code":...,"message":...}` with the request `id`
//! echoed when known. Error codes: `bad_request`, `queue_full`,
//! `shutting_down`, `exec_failed`.

use std::collections::BTreeMap;

use anyhow::{bail, Result};

use crate::util::json::Json;

/// A message from a client to the gateway.
#[derive(Debug, Clone, PartialEq)]
pub enum ClientMsg {
    Score { id: u64, tokens: Vec<i32> },
    Stats,
    Reload { dir: String },
    Shutdown,
}

impl ClientMsg {
    /// Parse one wire line.
    pub fn parse(line: &str) -> Result<ClientMsg> {
        let j = Json::parse(line.trim())?;
        let ty = j.get("type")?.as_str()?;
        Ok(match ty {
            "score" => {
                let id = j.get("id")?.as_f64()?;
                // ids ride through f64 (JSON numbers): above 2^53 - 1
                // they would be silently rounded and responses could
                // not be correlated, so reject them at the door
                if id < 0.0 || id.fract() != 0.0 || id >= 9_007_199_254_740_992.0 {
                    bail!("score id must be an integer in [0, 2^53)");
                }
                let tokens = j
                    .get("tokens")?
                    .as_arr()?
                    .iter()
                    .map(|v| {
                        let x = v.as_f64()?;
                        if x.fract() != 0.0 || x.abs() > i32::MAX as f64 {
                            bail!("token {x} is not an i32");
                        }
                        Ok(x as i32)
                    })
                    .collect::<Result<Vec<i32>>>()?;
                ClientMsg::Score { id: id as u64, tokens }
            }
            "stats" => ClientMsg::Stats,
            "reload" => ClientMsg::Reload { dir: j.get("dir")?.as_str()?.to_string() },
            "shutdown" => ClientMsg::Shutdown,
            t => bail!("unknown message type {t:?}"),
        })
    }

    /// Serialize to one wire line (no trailing newline).
    pub fn encode(&self) -> String {
        let mut m = BTreeMap::new();
        match self {
            ClientMsg::Score { id, tokens } => {
                m.insert("type".into(), Json::Str("score".into()));
                m.insert("id".into(), Json::Num(*id as f64));
                m.insert(
                    "tokens".into(),
                    Json::Arr(tokens.iter().map(|&t| Json::Num(t as f64)).collect()),
                );
            }
            ClientMsg::Stats => {
                m.insert("type".into(), Json::Str("stats".into()));
            }
            ClientMsg::Reload { dir } => {
                m.insert("type".into(), Json::Str("reload".into()));
                m.insert("dir".into(), Json::Str(dir.clone()));
            }
            ClientMsg::Shutdown => {
                m.insert("type".into(), Json::Str("shutdown".into()));
            }
        }
        Json::Obj(m).to_string()
    }
}

/// A message from the gateway to a client.
#[derive(Debug, Clone, PartialEq)]
pub enum ServerMsg {
    Score { id: u64, ce: f64, ppl: f64, latency_ms: f64 },
    /// Reply to `stats`: an open object of counters/gauges.
    Stats(Json),
    /// Acknowledgement of `reload`/`shutdown`.
    Ok { info: String },
    Error { id: Option<u64>, code: String, message: String },
}

impl ServerMsg {
    pub fn error(id: Option<u64>, code: &str, message: impl Into<String>) -> ServerMsg {
        ServerMsg::Error { id, code: code.to_string(), message: message.into() }
    }

    /// Serialize to one wire line (no trailing newline).
    pub fn encode(&self) -> String {
        let mut m = BTreeMap::new();
        match self {
            ServerMsg::Score { id, ce, ppl, latency_ms } => {
                m.insert("type".into(), Json::Str("score".into()));
                m.insert("id".into(), Json::Num(*id as f64));
                m.insert("ce".into(), Json::Num(*ce));
                m.insert("ppl".into(), Json::Num(*ppl));
                m.insert("latency_ms".into(), Json::Num(*latency_ms));
            }
            ServerMsg::Stats(j) => {
                let mut body = match j {
                    Json::Obj(b) => b.clone(),
                    other => {
                        let mut b = BTreeMap::new();
                        b.insert("value".into(), other.clone());
                        b
                    }
                };
                body.insert("type".into(), Json::Str("stats".into()));
                m = body;
            }
            ServerMsg::Ok { info } => {
                m.insert("type".into(), Json::Str("ok".into()));
                m.insert("info".into(), Json::Str(info.clone()));
            }
            ServerMsg::Error { id, code, message } => {
                m.insert("type".into(), Json::Str("error".into()));
                if let Some(id) = id {
                    m.insert("id".into(), Json::Num(*id as f64));
                }
                m.insert("code".into(), Json::Str(code.clone()));
                m.insert("message".into(), Json::Str(message.clone()));
            }
        }
        Json::Obj(m).to_string()
    }

    /// Parse one wire line (used by clients: loadgen, tests, demo).
    pub fn parse(line: &str) -> Result<ServerMsg> {
        let j = Json::parse(line.trim())?;
        let ty = j.get("type")?.as_str()?;
        Ok(match ty {
            "score" => ServerMsg::Score {
                id: j.get("id")?.as_f64()? as u64,
                ce: j.get("ce")?.as_f64()?,
                ppl: j.get("ppl")?.as_f64()?,
                latency_ms: j.get("latency_ms")?.as_f64()?,
            },
            "stats" => ServerMsg::Stats(j),
            "ok" => ServerMsg::Ok {
                info: j.opt("info").and_then(|v| v.as_str().ok()).unwrap_or("").to_string(),
            },
            "error" => ServerMsg::Error {
                id: j.opt("id").and_then(|v| v.as_f64().ok()).map(|x| x as u64),
                code: j.get("code")?.as_str()?.to_string(),
                message: j.get("message")?.as_str()?.to_string(),
            },
            t => bail!("unknown server message type {t:?}"),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_roundtrip() {
        let msgs = [
            ClientMsg::Score { id: 42, tokens: vec![-1, 0, 7, 255] },
            ClientMsg::Stats,
            ClientMsg::Reload { dir: "ckpt/step100".into() },
            ClientMsg::Shutdown,
        ];
        for m in msgs {
            let line = m.encode();
            assert!(!line.contains('\n'), "wire lines must be single-line");
            assert_eq!(ClientMsg::parse(&line).unwrap(), m);
        }
    }

    #[test]
    fn server_roundtrip() {
        let msgs = [
            ServerMsg::Score { id: 3, ce: 5.25, ppl: 190.5, latency_ms: 12.5 },
            ServerMsg::Ok { info: "drained".into() },
            ServerMsg::error(Some(9), "queue_full", "admission queue at capacity"),
            ServerMsg::error(None, "bad_request", "unparseable"),
        ];
        for m in msgs {
            let line = m.encode();
            assert!(!line.contains('\n'));
            assert_eq!(ServerMsg::parse(&line).unwrap(), m);
        }
    }

    #[test]
    fn stats_reply_keeps_fields() {
        let body = Json::parse(r#"{"requests": 12, "shed": 0}"#).unwrap();
        let line = ServerMsg::Stats(body).encode();
        let j = Json::parse(&line).unwrap();
        assert_eq!(j.get("type").unwrap().as_str().unwrap(), "stats");
        assert_eq!(j.get("requests").unwrap().as_usize().unwrap(), 12);
        match ServerMsg::parse(&line).unwrap() {
            ServerMsg::Stats(s) => {
                assert_eq!(s.get("shed").unwrap().as_usize().unwrap(), 0)
            }
            other => panic!("expected stats, got {other:?}"),
        }
    }

    #[test]
    fn parse_rejects_malformed() {
        assert!(ClientMsg::parse("not json").is_err());
        assert!(ClientMsg::parse(r#"{"type":"nope"}"#).is_err());
        assert!(ClientMsg::parse(r#"{"type":"score","id":-1,"tokens":[]}"#).is_err());
        // 2^53 + 1 would round through f64 to a different id — rejected
        assert!(
            ClientMsg::parse(r#"{"type":"score","id":9007199254740993,"tokens":[]}"#).is_err()
        );
        assert!(
            ClientMsg::parse(r#"{"type":"score","id":9007199254740991,"tokens":[]}"#).is_ok()
        );
        assert!(ClientMsg::parse(r#"{"type":"score","id":1,"tokens":[1.5]}"#).is_err());
        assert!(ClientMsg::parse(r#"{"type":"reload"}"#).is_err());
        assert!(ServerMsg::parse(r#"{"type":"score","id":1}"#).is_err());
    }
}
