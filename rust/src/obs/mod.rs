//! End-to-end request observability: span flight recorder, Chrome
//! trace export and structured logging.
//!
//! The subsystem is std-only and splits into:
//!
//! - [`span`]: the span taxonomy ([`SpanKind`]), the RAII
//!   [`SpanGuard`] recorder, and the wire format of trace ids
//!   (16-hex-digit strings in the optional `trace` field of
//!   `score`/`generate` lines, echoed on `score`/`done` replies);
//! - [`recorder`]: fixed-capacity per-thread ring buffers holding
//!   all-integer events, a global registry the collector snapshots
//!   without pausing recording, per-request sampling
//!   (`--trace-sample-rate`) and trace-id minting at admission;
//! - [`export`]: Chrome trace-event JSON rendering (`--trace-out`,
//!   the `trace_dump` control message) — one async track per sampled
//!   request, one nested track per recording thread;
//! - [`log`]: the leveled stderr logger (`SONIC_LOG`, `--log-json`).
//!
//! Everything here is behind the `obs` cargo feature (default on).
//! With the feature off the API stays present but recording and
//! minting compile to no-ops, so instrumented call sites carry no
//! `cfg` noise and numerics are bit-identical either way — which the
//! obs-on/off integration test asserts.

pub mod export;
pub mod log;
pub mod recorder;
pub mod span;

pub use recorder::{mint_trace, set_enabled, set_sample_rate, Snapshot};
pub use span::{parse_trace_hex, record_span, trace_hex, SpanGuard, SpanKind};
