//! PJRT runtime: load AOT-compiled HLO-text artifacts and execute them.
//!
//! Wraps the `xla` crate (`PjRtClient::cpu()` ->
//! `HloModuleProto::from_text_file` -> `compile` -> `execute`). One
//! compiled executable per artifact; the manifest (written by
//! `python/compile/aot.py`) is the signature contract.

mod manifest;

pub use manifest::{ArtifactSpec, ConfigManifest, Manifest, ParamSpec, TensorSpec};

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::util::tensor::Tensor;

/// A compiled artifact plus its signature.
pub struct Artifact {
    pub name: String,
    pub spec: ArtifactSpec,
    exe: xla::PjRtLoadedExecutable,
}

impl Artifact {
    /// Execute with positional literal inputs; returns the flattened
    /// output tuple (aot.py lowers with `return_tuple=True`).
    ///
    /// Inputs are staged through rust-owned `PjRtBuffer`s and run with
    /// `execute_b`: the crate's literal-taking `execute` leaks every
    /// input buffer per call in its C++ shim (`buffer.release()` without
    /// a matching free), which cost ~86 MB/step on the large config
    /// before this workaround (§Perf).
    pub fn execute(&self, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        if inputs.len() != self.spec.inputs.len() {
            bail!(
                "artifact {}: expected {} inputs, got {}",
                self.name,
                self.spec.inputs.len(),
                inputs.len()
            );
        }
        let client = self.exe.client();
        let in_bufs: Vec<xla::PjRtBuffer> = inputs
            .iter()
            .map(|l| client.buffer_from_host_literal(None, l))
            .collect::<std::result::Result<_, _>>()?;
        let bufs = self.exe.execute_b::<xla::PjRtBuffer>(&in_bufs)?;
        drop(in_bufs); // rust-owned: freed here, unlike the shim's path
        let lit = bufs[0][0].to_literal_sync()?;
        let outs = lit.to_tuple()?;
        if outs.len() != self.spec.outputs.len() {
            bail!(
                "artifact {}: manifest declares {} outputs, HLO returned {}",
                self.name,
                self.spec.outputs.len(),
                outs.len()
            );
        }
        Ok(outs)
    }

    /// Execute with f32 tensors (plus optional trailing i32 token input
    /// handled by the caller via raw literals).
    pub fn execute_tensors(&self, inputs: &[&Tensor]) -> Result<Vec<Tensor>> {
        let lits: Vec<xla::Literal> =
            inputs.iter().map(|t| t.to_literal()).collect::<Result<_>>()?;
        let outs = self.execute(&lits)?;
        outs.iter().map(Tensor::from_literal).collect()
    }
}

/// The runtime: a PJRT client plus lazily compiled artifacts for one
/// model config from the manifest.
pub struct Runtime {
    pub dir: PathBuf,
    pub config_name: String,
    pub manifest: ConfigManifest,
    client: xla::PjRtClient,
    compiled: HashMap<String, Artifact>,
}

impl Runtime {
    /// Open `artifacts/` (or another dir) for a named config.
    pub fn open(dir: &str, config_name: &str) -> Result<Runtime> {
        let dir = PathBuf::from(dir);
        let manifest_path = dir.join("manifest.json");
        let manifest = Manifest::load(
            manifest_path.to_str().ok_or_else(|| anyhow!("bad path"))?,
        )?;
        let cfg = manifest
            .configs
            .get(config_name)
            .with_context(|| {
                format!(
                    "config {config_name:?} not in manifest (have: {:?})",
                    manifest.configs.keys().collect::<Vec<_>>()
                )
            })?
            .clone();
        let client = xla::PjRtClient::cpu()?;
        log::info!(
            "PJRT client up: platform={} devices={}",
            client.platform_name(),
            client.device_count()
        );
        Ok(Runtime {
            dir,
            config_name: config_name.to_string(),
            manifest: cfg,
            client,
            compiled: HashMap::new(),
        })
    }

    /// Compile (once) and return an artifact by manifest name.
    pub fn artifact(&mut self, name: &str) -> Result<&Artifact> {
        if !self.compiled.contains_key(name) {
            let spec = self
                .manifest
                .artifacts
                .get(name)
                .with_context(|| format!("artifact {name:?} not in manifest"))?
                .clone();
            let path = self.dir.join(&spec.file);
            let t0 = std::time::Instant::now();
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().ok_or_else(|| anyhow!("bad path"))?,
            )?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self.client.compile(&comp)?;
            log::info!("compiled {name} in {:.2}s", t0.elapsed().as_secs_f64());
            self.compiled.insert(
                name.to_string(),
                Artifact { name: name.to_string(), spec, exe },
            );
        }
        Ok(&self.compiled[name])
    }

    /// Load the initial parameters written by aot.py, in manifest order.
    pub fn load_initial_params(&self) -> Result<Vec<Tensor>> {
        let path = self.dir.join(&self.manifest.params_file);
        let path = path.to_str().ok_or_else(|| anyhow!("bad path"))?;
        let bytes = std::fs::read(path).with_context(|| format!("reading {path}"))?;
        if bytes.len() != self.manifest.num_params * 4 {
            bail!(
                "{path}: {} bytes but manifest declares {} f32 params",
                bytes.len(),
                self.manifest.num_params
            );
        }
        let flat: Vec<f32> = bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();
        self.manifest
            .params
            .iter()
            .map(|p| {
                let sl = &flat[p.offset..p.offset + p.size];
                Tensor::from_vec(&p.shape, sl.to_vec())
            })
            .collect()
    }

    /// Resolve a path inside the artifact dir (goldens etc.).
    pub fn path(&self, rel: &str) -> PathBuf {
        self.dir.join(rel)
    }
}

/// True if the artifacts dir exists with a manifest (used by tests to
/// skip gracefully when `make artifacts` has not run).
pub fn artifacts_available(dir: &str) -> bool {
    Path::new(dir).join("manifest.json").exists()
}
