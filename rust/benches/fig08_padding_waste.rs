//! Bench: regenerate Figure 8 via the simulator/model and time it.

use sonic_moe::bench::{figures, Bencher};

fn main() {
    figures::fig08().print();
    let mut b = Bencher::new("simulator/fig08_padding_waste");
    b.iter(|| figures::fig08());
    println!("{}", b.report());
}
