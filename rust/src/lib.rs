//! # sonic-moe — SonicMoE reproduction (L3 coordinator)
//!
//! Rust coordinator of the three-layer stack reproducing *SonicMoE:
//! Accelerating MoE with IO and Tile-aware Optimizations* (Guo et al.):
//!
//! - [`runtime`] executes the manifest's artifact contracts through a
//!   pluggable execution backend ([`runtime::backend`]): the **native**
//!   pure-rust CPU backend (default — hermetic, no python/HLO anywhere
//!   on the path, built-in configs when `make artifacts` has not run)
//!   or the **PJRT** backend (cargo feature `pjrt`) that loads the
//!   AOT-compiled HLO artifacts (L2 JAX model + L1 Pallas kernels)
//!   through the PJRT C API;
//! - [`coordinator`] owns the training loop, parameter state, data
//!   pipeline and data-parallel workers;
//! - [`gateway`] is the concurrent tile-aware serving gateway: a TCP
//!   line-JSON protocol, bounded admission queue with shedding, a
//!   worker pool (one runtime per thread) and pluggable batch-formation
//!   policies including tile-rounded continuous batching;
//! - [`front`] is the replica-balanced front tier over N gateway
//!   replicas: health-watched peak-EWMA routing, idempotent score
//!   failover with jittered backoff, pinned generate streams with
//!   clean `replica_lost` semantics, and graceful shedding when every
//!   replica is down;
//! - [`obs`] is the observability layer: trace ids minted at
//!   admission, a per-thread span flight recorder, Chrome trace-event
//!   export (`chrome://tracing` / Perfetto) and structured logging —
//!   compile-out-able behind the default-on `obs` feature;
//! - [`spec`] is the speculative-decoding subsystem: a cheap draft
//!   model proposes k tokens, the target verifies them in one packed
//!   cached decode call with greedy acceptance that is token-for-token
//!   exact under the row-local tc router;
//! - [`routing`] re-implements every routing algorithm of the paper
//!   (token-choice, token rounding with all six rounding subroutines,
//!   expert choice, token drop) for the host-side dispatch, the
//!   simulator and property tests;
//! - [`simulator`] is the GPU performance model that regenerates the
//!   paper's throughput tables and figures (H100/B300 substitution — see
//!   DESIGN.md);
//! - [`memory`] is the activation-memory accounting model (Figure 10);
//! - [`optim`], [`data`], [`bench`], [`util`] are supporting substrates
//!   (AdamW, synthetic corpus, micro-bench harness, and the offline
//!   replacements for serde/clap/criterion/proptest).
//!
//! Python never runs at request time: `make artifacts` is the only
//! python entry point, and it is needed only for the PJRT backend and
//! the cross-language parity goldens — the native backend trains,
//! evaluates and serves entirely offline.

// The serving-stack modules documented in docs/ARCHITECTURE.md carry
// `missing_docs` under the opt-in `strict-docs` feature; CI counts the
// warnings against a committed baseline (scripts/check_docs.py) so new
// undocumented public items are caught without failing ordinary builds.
pub mod bench;
#[cfg_attr(feature = "strict-docs", warn(missing_docs))]
pub mod coordinator;
pub mod data;
#[cfg_attr(feature = "strict-docs", warn(missing_docs))]
pub mod front;
#[cfg_attr(feature = "strict-docs", warn(missing_docs))]
pub mod gateway;
#[cfg_attr(feature = "strict-docs", warn(missing_docs))]
pub mod memory;
#[cfg_attr(feature = "strict-docs", warn(missing_docs))]
pub mod obs;
pub mod optim;
#[cfg_attr(feature = "strict-docs", warn(missing_docs))]
pub mod routing;
pub mod runtime;
pub mod simulator;
#[cfg_attr(feature = "strict-docs", warn(missing_docs))]
pub mod spec;
pub mod util;

/// Crate-wide result type.
pub type Result<T> = anyhow::Result<T>;
