//! Gateway wire protocol: line-delimited JSON over TCP.
//!
//! One JSON object per `\n`-terminated line in each direction, parsed
//! and serialized through [`crate::util::json::Json`] (std-only — no
//! serde, no tokio). Client messages:
//!
//! ```text
//! {"type":"score","id":7,"tokens":[3,1,4,1,5]}         score a sequence
//! {"type":"generate","id":9,"tokens":[3,1],"max_new":8} autoregressive decode
//! {"type":"stats"}                                      service statistics
//! {"type":"metrics"}                                    Prometheus exposition poll
//! {"type":"trace_dump","path":"trace.json"}             flight-recorder export
//! {"type":"reload","dir":"ckpt/"}                       checkpoint hot-swap
//! {"type":"shutdown"}                                   graceful drain + exit
//! ```
//!
//! `generate` optionally carries speculative-decoding and sampling
//! options: `"spec":{"k":4,"draft":"small-draft"}` turns on
//! draft-and-verify with up to `k` drafted tokens per verify step
//! (`draft` pins the gateway's loaded draft config; omitted = accept
//! whichever draft is loaded), and `"temperature"`/`"top_k"`/`"top_p"`
//! select seeded sampling instead of greedy (`top_k`/`top_p` require
//! `temperature > 0`; all of them are mutually exclusive with `spec` —
//! speculative acceptance is exact only against greedy).
//! `done` frames of speculative requests add `spec_rounds` /
//! `spec_proposed` / `spec_accepted`.
//!
//! `metrics` is the one non-JSON reply: the gateway writes the stats
//! body in Prometheus text exposition format and closes the connection
//! (scrape semantics — one poll per connection).
//!
//! Server messages mirror the request `type` (`score` responses carry
//! `ce`/`ppl`/`latency_ms`). A `generate` request streams back one
//! incremental `{"type":"token","id":9,"token":17,"index":0}` frame per
//! generated token, terminated by a `done` frame carrying the full
//! generated sequence and per-request stats (`prompt_len`, `ttft_ms`,
//! `latency_ms`). Failures are
//! `{"type":"error","code":...,"message":...}` with the request `id`
//! echoed when known. Error codes: `bad_request`, `queue_full`,
//! `shutting_down`, `exec_failed`, and — emitted by the front tier —
//! `replica_lost` (the replica serving a pinned `generate` stream died
//! mid-decode; `last_index` carries the last contiguous token index so
//! the client can resume deterministically) and `no_healthy_replica`
//! (every replica for the requested model is unhealthy). Refusal
//! frames (`queue_full`, `no_healthy_replica`) carry a
//! `retry_after_ms` backoff hint; both extra fields are optional and
//! omitted everywhere else, keeping old clients wire-compatible.

use std::collections::BTreeMap;

use anyhow::{bail, Result};

use crate::util::json::Json;

/// Per-request generation options riding on a `generate` message:
/// speculative decoding (`spec_k > 0`, optionally pinning the draft
/// config by name) and seeded sampling (temperature 0 = greedy). The
/// default is plain greedy decode, wire-compatible with clients that
/// never send the optional fields.
#[derive(Debug, Clone, PartialEq)]
pub struct GenOpts {
    /// Draft tokens per verify step (0 = speculation off).
    pub spec_k: usize,
    /// Required draft config name ("" = accept the gateway's draft).
    pub draft: String,
    /// Sampling temperature (0 = greedy).
    pub temperature: f64,
    /// Top-k logit cut (0 = off).
    pub top_k: usize,
    /// Nucleus mass (0 or >= 1 = off).
    pub top_p: f64,
}

impl Default for GenOpts {
    fn default() -> Self {
        GenOpts { spec_k: 0, draft: String::new(), temperature: 0.0, top_k: 0, top_p: 0.0 }
    }
}

impl GenOpts {
    /// True when the request opts into speculative decoding.
    pub fn is_spec(&self) -> bool {
        self.spec_k > 0
    }

    /// True when the request selects seeded sampling over greedy.
    pub fn is_sampling(&self) -> bool {
        self.temperature > 0.0
    }
}

/// A message from a client to the gateway.
#[derive(Debug, Clone, PartialEq)]
pub enum ClientMsg {
    Score { id: u64, tokens: Vec<i32> },
    /// Autoregressive generation: `tokens` is the prompt, `max_new`
    /// caps the generated tokens (0 = the gateway's configured cap),
    /// `opts` selects speculation / sampling.
    Generate { id: u64, tokens: Vec<i32>, max_new: usize, opts: GenOpts },
    Stats,
    /// Prometheus text-exposition poll (the reply is not a JSON line;
    /// the gateway writes the exposition body and closes).
    Metrics,
    /// Dump the span flight recorder as Chrome trace-event JSON to
    /// `path` (or the server's `--trace-out` default when absent).
    TraceDump { path: Option<String> },
    Reload { dir: String },
    Shutdown,
}

/// Request-id validation shared by `score` and `generate`: ids ride
/// through f64 (JSON numbers), so above 2^53 - 1 they would be silently
/// rounded and responses could not be correlated — reject at the door.
fn parse_id(j: &Json) -> Result<u64> {
    let id = j.get("id")?.as_f64()?;
    if id < 0.0 || id.fract() != 0.0 || id >= 9_007_199_254_740_992.0 {
        bail!("request id must be an integer in [0, 2^53)");
    }
    Ok(id as u64)
}

/// Token-array validation shared by `score`/`generate` requests and
/// `done` frames.
fn parse_tokens(j: &Json, key: &str) -> Result<Vec<i32>> {
    j.get(key)?
        .as_arr()?
        .iter()
        .map(|v| {
            let x = v.as_f64()?;
            if x.fract() != 0.0 || x.abs() > i32::MAX as f64 {
                bail!("token {x} is not an i32");
            }
            Ok(x as i32)
        })
        .collect()
}

fn tokens_json(tokens: &[i32]) -> Json {
    Json::Arr(tokens.iter().map(|&t| Json::Num(t as f64)).collect())
}

/// Optional `trace` echo on `score`/`done` replies: a 16-hex-digit
/// string, absent (or unparseable — old peers) meaning untraced (0).
fn parse_trace_echo(j: &Json) -> u64 {
    j.opt("trace")
        .and_then(|v| v.as_str().ok())
        .and_then(crate::obs::parse_trace_hex)
        .unwrap_or(0)
}

impl ClientMsg {
    /// Parse one wire line.
    pub fn parse(line: &str) -> Result<ClientMsg> {
        let j = Json::parse(line.trim())?;
        let ty = j.get("type")?.as_str()?;
        Ok(match ty {
            "score" => ClientMsg::Score { id: parse_id(&j)?, tokens: parse_tokens(&j, "tokens")? },
            "generate" => {
                let max_new = match j.opt("max_new") {
                    Some(v) => v.as_usize()?,
                    None => 0,
                };
                let mut opts = GenOpts::default();
                if let Some(spec) = j.opt("spec") {
                    opts.spec_k = spec.get("k")?.as_usize()?;
                    if opts.spec_k == 0 {
                        bail!("spec.k must be >= 1 when a spec block is sent");
                    }
                    if let Some(d) = spec.opt("draft") {
                        opts.draft = d.as_str()?.to_string();
                    }
                }
                if let Some(v) = j.opt("temperature") {
                    opts.temperature = v.as_f64()?;
                    if opts.temperature < 0.0 || !opts.temperature.is_finite() {
                        bail!("temperature must be finite and >= 0");
                    }
                }
                if let Some(v) = j.opt("top_k") {
                    opts.top_k = v.as_usize()?;
                }
                if let Some(v) = j.opt("top_p") {
                    opts.top_p = v.as_f64()?;
                    if !(0.0..=1.0).contains(&opts.top_p) {
                        bail!("top_p must be in [0, 1]");
                    }
                }
                if opts.temperature == 0.0 && (opts.top_k != 0 || opts.top_p != 0.0) {
                    bail!("top_k/top_p require temperature > 0 (temperature 0 is greedy)");
                }
                if opts.is_spec() && opts.is_sampling() {
                    bail!("speculative decode is greedy-only: spec and sampling conflict");
                }
                ClientMsg::Generate {
                    id: parse_id(&j)?,
                    tokens: parse_tokens(&j, "tokens")?,
                    max_new,
                    opts,
                }
            }
            "stats" => ClientMsg::Stats,
            "metrics" => ClientMsg::Metrics,
            "trace_dump" => ClientMsg::TraceDump {
                path: match j.opt("path") {
                    Some(p) => Some(p.as_str()?.to_string()),
                    None => None,
                },
            },
            "reload" => ClientMsg::Reload { dir: j.get("dir")?.as_str()?.to_string() },
            "shutdown" => ClientMsg::Shutdown,
            t => bail!("unknown message type {t:?}"),
        })
    }

    /// Serialize to one wire line (no trailing newline).
    pub fn encode(&self) -> String {
        let mut m = BTreeMap::new();
        match self {
            ClientMsg::Score { id, tokens } => {
                m.insert("type".into(), Json::Str("score".into()));
                m.insert("id".into(), Json::Num(*id as f64));
                m.insert("tokens".into(), tokens_json(tokens));
            }
            ClientMsg::Generate { id, tokens, max_new, opts } => {
                m.insert("type".into(), Json::Str("generate".into()));
                m.insert("id".into(), Json::Num(*id as f64));
                m.insert("tokens".into(), tokens_json(tokens));
                m.insert("max_new".into(), Json::Num(*max_new as f64));
                if opts.is_spec() {
                    let mut spec = BTreeMap::new();
                    spec.insert("k".to_string(), Json::Num(opts.spec_k as f64));
                    if !opts.draft.is_empty() {
                        spec.insert("draft".to_string(), Json::Str(opts.draft.clone()));
                    }
                    m.insert("spec".into(), Json::Obj(spec));
                }
                if opts.temperature != 0.0 {
                    m.insert("temperature".into(), Json::Num(opts.temperature));
                }
                if opts.top_k != 0 {
                    m.insert("top_k".into(), Json::Num(opts.top_k as f64));
                }
                if opts.top_p != 0.0 {
                    m.insert("top_p".into(), Json::Num(opts.top_p));
                }
            }
            ClientMsg::Stats => {
                m.insert("type".into(), Json::Str("stats".into()));
            }
            ClientMsg::Metrics => {
                m.insert("type".into(), Json::Str("metrics".into()));
            }
            ClientMsg::TraceDump { path } => {
                m.insert("type".into(), Json::Str("trace_dump".into()));
                if let Some(p) = path {
                    m.insert("path".into(), Json::Str(p.clone()));
                }
            }
            ClientMsg::Reload { dir } => {
                m.insert("type".into(), Json::Str("reload".into()));
                m.insert("dir".into(), Json::Str(dir.clone()));
            }
            ClientMsg::Shutdown => {
                m.insert("type".into(), Json::Str("shutdown".into()));
            }
        }
        Json::Obj(m).to_string()
    }
}

/// A message from the gateway to a client.
#[derive(Debug, Clone, PartialEq)]
pub enum ServerMsg {
    /// Score reply. `trace` echoes the request's sampled trace id
    /// (0 = untraced, omitted on the wire).
    Score { id: u64, ce: f64, ppl: f64, latency_ms: f64, trace: u64 },
    /// One incremental generated token of a `generate` request.
    Token { id: u64, token: i32, index: usize },
    /// Terminal frame of a `generate` request: the full generated
    /// sequence plus per-request stats. Speculative requests carry the
    /// draft bookkeeping (`rounds` verify rounds that proposed at
    /// least one token, `proposed` drafted tokens, `accepted` of them
    /// confirmed); all three are 0 for plain decode and then omitted
    /// on the wire. `trace` echoes the request's sampled trace id
    /// (0 = untraced, omitted on the wire).
    Done {
        id: u64,
        tokens: Vec<i32>,
        prompt_len: usize,
        ttft_ms: f64,
        latency_ms: f64,
        rounds: u64,
        proposed: u64,
        accepted: u64,
        trace: u64,
    },
    /// Reply to `stats`: an open object of counters/gauges.
    Stats(Json),
    /// Acknowledgement of `reload`/`shutdown`.
    Ok { info: String },
    /// Failure/refusal frame. `retry_after_ms` rides on shedding
    /// refusals (`queue_full`, `no_healthy_replica`) as a backoff
    /// hint; `last_index` rides on `replica_lost` and is the last
    /// contiguous streamed token index (`None` = no token was ever
    /// streamed). Both are omitted from the wire when `None`.
    Error {
        id: Option<u64>,
        code: String,
        message: String,
        retry_after_ms: Option<u64>,
        last_index: Option<u64>,
    },
}

impl ServerMsg {
    /// Build an error reply (id echoed when known).
    pub fn error(id: Option<u64>, code: &str, message: impl Into<String>) -> ServerMsg {
        ServerMsg::Error {
            id,
            code: code.to_string(),
            message: message.into(),
            retry_after_ms: None,
            last_index: None,
        }
    }

    /// Build a shedding refusal carrying a backoff hint
    /// (`queue_full` / `no_healthy_replica`).
    pub fn refusal(
        id: Option<u64>,
        code: &str,
        message: impl Into<String>,
        retry_after_ms: u64,
    ) -> ServerMsg {
        ServerMsg::Error {
            id,
            code: code.to_string(),
            message: message.into(),
            retry_after_ms: Some(retry_after_ms),
            last_index: None,
        }
    }

    /// Build the front tier's `replica_lost` stream terminator:
    /// `last_index` is the last contiguous token index the client
    /// received (`None` = the stream died before its first token).
    pub fn replica_lost(id: u64, last_index: Option<u64>, message: impl Into<String>) -> ServerMsg {
        ServerMsg::Error {
            id: Some(id),
            code: "replica_lost".to_string(),
            message: message.into(),
            retry_after_ms: None,
            last_index,
        }
    }

    /// Serialize to one wire line (no trailing newline).
    pub fn encode(&self) -> String {
        let mut m = BTreeMap::new();
        match self {
            ServerMsg::Score { id, ce, ppl, latency_ms, trace } => {
                m.insert("type".into(), Json::Str("score".into()));
                m.insert("id".into(), Json::Num(*id as f64));
                m.insert("ce".into(), Json::Num(*ce));
                m.insert("ppl".into(), Json::Num(*ppl));
                m.insert("latency_ms".into(), Json::Num(*latency_ms));
                if *trace != 0 {
                    m.insert("trace".into(), Json::Str(crate::obs::trace_hex(*trace)));
                }
            }
            ServerMsg::Token { id, token, index } => {
                m.insert("type".into(), Json::Str("token".into()));
                m.insert("id".into(), Json::Num(*id as f64));
                m.insert("token".into(), Json::Num(*token as f64));
                m.insert("index".into(), Json::Num(*index as f64));
            }
            ServerMsg::Done {
                id,
                tokens,
                prompt_len,
                ttft_ms,
                latency_ms,
                rounds,
                proposed,
                accepted,
                trace,
            } => {
                m.insert("type".into(), Json::Str("done".into()));
                m.insert("id".into(), Json::Num(*id as f64));
                m.insert("tokens".into(), tokens_json(tokens));
                m.insert("prompt_len".into(), Json::Num(*prompt_len as f64));
                m.insert("ttft_ms".into(), Json::Num(*ttft_ms));
                m.insert("latency_ms".into(), Json::Num(*latency_ms));
                if *rounds > 0 {
                    m.insert("spec_rounds".into(), Json::Num(*rounds as f64));
                    m.insert("spec_proposed".into(), Json::Num(*proposed as f64));
                    m.insert("spec_accepted".into(), Json::Num(*accepted as f64));
                }
                if *trace != 0 {
                    m.insert("trace".into(), Json::Str(crate::obs::trace_hex(*trace)));
                }
            }
            ServerMsg::Stats(j) => {
                let mut body = match j {
                    Json::Obj(b) => b.clone(),
                    other => {
                        let mut b = BTreeMap::new();
                        b.insert("value".into(), other.clone());
                        b
                    }
                };
                body.insert("type".into(), Json::Str("stats".into()));
                m = body;
            }
            ServerMsg::Ok { info } => {
                m.insert("type".into(), Json::Str("ok".into()));
                m.insert("info".into(), Json::Str(info.clone()));
            }
            ServerMsg::Error { id, code, message, retry_after_ms, last_index } => {
                m.insert("type".into(), Json::Str("error".into()));
                if let Some(id) = id {
                    m.insert("id".into(), Json::Num(*id as f64));
                }
                m.insert("code".into(), Json::Str(code.clone()));
                m.insert("message".into(), Json::Str(message.clone()));
                if let Some(ms) = retry_after_ms {
                    m.insert("retry_after_ms".into(), Json::Num(*ms as f64));
                }
                if let Some(ix) = last_index {
                    m.insert("last_index".into(), Json::Num(*ix as f64));
                }
            }
        }
        Json::Obj(m).to_string()
    }

    /// Parse one wire line (used by clients: loadgen, tests, demo).
    pub fn parse(line: &str) -> Result<ServerMsg> {
        let j = Json::parse(line.trim())?;
        let ty = j.get("type")?.as_str()?;
        Ok(match ty {
            "score" => ServerMsg::Score {
                id: j.get("id")?.as_f64()? as u64,
                ce: j.get("ce")?.as_f64()?,
                ppl: j.get("ppl")?.as_f64()?,
                latency_ms: j.get("latency_ms")?.as_f64()?,
                trace: parse_trace_echo(&j),
            },
            "token" => ServerMsg::Token {
                id: j.get("id")?.as_f64()? as u64,
                token: j.get("token")?.as_f64()? as i32,
                index: j.get("index")?.as_usize()?,
            },
            "done" => {
                let opt_u64 =
                    |key: &str| j.opt(key).and_then(|v| v.as_f64().ok()).unwrap_or(0.0) as u64;
                ServerMsg::Done {
                    id: j.get("id")?.as_f64()? as u64,
                    tokens: parse_tokens(&j, "tokens")?,
                    prompt_len: j.get("prompt_len")?.as_usize()?,
                    ttft_ms: j.get("ttft_ms")?.as_f64()?,
                    latency_ms: j.get("latency_ms")?.as_f64()?,
                    rounds: opt_u64("spec_rounds"),
                    proposed: opt_u64("spec_proposed"),
                    accepted: opt_u64("spec_accepted"),
                    trace: parse_trace_echo(&j),
                }
            }
            "stats" => ServerMsg::Stats(j),
            "ok" => ServerMsg::Ok {
                info: j.opt("info").and_then(|v| v.as_str().ok()).unwrap_or("").to_string(),
            },
            "error" => ServerMsg::Error {
                id: j.opt("id").and_then(|v| v.as_f64().ok()).map(|x| x as u64),
                code: j.get("code")?.as_str()?.to_string(),
                message: j.get("message")?.as_str()?.to_string(),
                retry_after_ms: j
                    .opt("retry_after_ms")
                    .and_then(|v| v.as_f64().ok())
                    .map(|x| x as u64),
                last_index: j.opt("last_index").and_then(|v| v.as_f64().ok()).map(|x| x as u64),
            },
            t => bail!("unknown server message type {t:?}"),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_roundtrip() {
        let msgs = [
            ClientMsg::Score { id: 42, tokens: vec![-1, 0, 7, 255] },
            ClientMsg::Generate {
                id: 43,
                tokens: vec![3, 1, 4],
                max_new: 8,
                opts: GenOpts::default(),
            },
            ClientMsg::Generate {
                id: 44,
                tokens: vec![3, 1],
                max_new: 8,
                opts: GenOpts { spec_k: 4, draft: "small-draft".into(), ..GenOpts::default() },
            },
            ClientMsg::Generate {
                id: 45,
                tokens: vec![3],
                max_new: 4,
                opts: GenOpts {
                    temperature: 0.8,
                    top_k: 40,
                    top_p: 0.95,
                    ..GenOpts::default()
                },
            },
            ClientMsg::Stats,
            ClientMsg::Metrics,
            ClientMsg::TraceDump { path: None },
            ClientMsg::TraceDump { path: Some("target/trace.json".into()) },
            ClientMsg::Reload { dir: "ckpt/step100".into() },
            ClientMsg::Shutdown,
        ];
        for m in msgs {
            let line = m.encode();
            assert!(!line.contains('\n'), "wire lines must be single-line");
            assert_eq!(ClientMsg::parse(&line).unwrap(), m);
        }
    }

    #[test]
    fn generate_max_new_defaults_to_zero() {
        let m = ClientMsg::parse(r#"{"type":"generate","id":1,"tokens":[5]}"#).unwrap();
        assert_eq!(
            m,
            ClientMsg::Generate {
                id: 1,
                tokens: vec![5],
                max_new: 0,
                opts: GenOpts::default()
            }
        );
        assert!(ClientMsg::parse(r#"{"type":"generate","id":1}"#).is_err());
        assert!(ClientMsg::parse(r#"{"type":"generate","id":-2,"tokens":[]}"#).is_err());
    }

    #[test]
    fn generate_opts_validation() {
        // spec without k, k = 0, spec + sampling, bad temperature / top_p
        let base = r#""id":1,"tokens":[5]"#;
        for bad in [
            format!(r#"{{"type":"generate",{base},"spec":{{}}}}"#),
            format!(r#"{{"type":"generate",{base},"spec":{{"k":0}}}}"#),
            format!(r#"{{"type":"generate",{base},"spec":{{"k":2}},"temperature":0.7}}"#),
            format!(r#"{{"type":"generate",{base},"spec":{{"k":2}},"top_p":0.5,"temperature":0.7}}"#),
            format!(r#"{{"type":"generate",{base},"temperature":-1.0}}"#),
            format!(r#"{{"type":"generate",{base},"top_p":1.5}}"#),
            // top_k / top_p without a temperature would silently decode
            // greedily — refused instead
            format!(r#"{{"type":"generate",{base},"top_k":10}}"#),
            format!(r#"{{"type":"generate",{base},"top_p":0.9}}"#),
        ] {
            assert!(ClientMsg::parse(&bad).is_err(), "accepted {bad}");
        }
        // spec with a draft pin parses
        let m = ClientMsg::parse(
            r#"{"type":"generate","id":1,"tokens":[5],"spec":{"k":2,"draft":"small-draft"}}"#,
        )
        .unwrap();
        match m {
            ClientMsg::Generate { opts, .. } => {
                assert_eq!(opts.spec_k, 2);
                assert_eq!(opts.draft, "small-draft");
                assert!(opts.is_spec() && !opts.is_sampling());
            }
            other => panic!("expected generate, got {other:?}"),
        }
    }

    #[test]
    fn server_roundtrip() {
        let msgs = [
            ServerMsg::Score { id: 3, ce: 5.25, ppl: 190.5, latency_ms: 12.5, trace: 0 },
            ServerMsg::Score { id: 4, ce: 5.25, ppl: 190.5, latency_ms: 12.5, trace: 0xabc },
            ServerMsg::Token { id: 9, token: 17, index: 0 },
            ServerMsg::Done {
                id: 9,
                tokens: vec![17, 4, 200],
                prompt_len: 5,
                ttft_ms: 3.5,
                latency_ms: 20.25,
                rounds: 0,
                proposed: 0,
                accepted: 0,
                trace: 0,
            },
            ServerMsg::Done {
                id: 10,
                tokens: vec![17, 4],
                prompt_len: 5,
                ttft_ms: 3.5,
                latency_ms: 20.25,
                rounds: 3,
                proposed: 12,
                accepted: 7,
                trace: u64::MAX,
            },
            ServerMsg::Ok { info: "drained".into() },
            ServerMsg::error(Some(9), "queue_full", "admission queue at capacity"),
            ServerMsg::error(None, "bad_request", "unparseable"),
            ServerMsg::refusal(Some(11), "queue_full", "admission queue at capacity", 40),
            ServerMsg::refusal(Some(12), "no_healthy_replica", "all replicas down", 250),
            ServerMsg::replica_lost(13, Some(4), "replica died mid-stream"),
            ServerMsg::replica_lost(14, None, "replica died before first token"),
        ];
        for m in msgs {
            let line = m.encode();
            assert!(!line.contains('\n'));
            assert_eq!(ServerMsg::parse(&line).unwrap(), m);
        }
    }

    #[test]
    fn error_hint_fields_are_optional_on_the_wire() {
        // a plain error omits both optional fields entirely
        let line = ServerMsg::error(Some(1), "exec_failed", "boom").encode();
        assert!(!line.contains("retry_after_ms") && !line.contains("last_index"));
        // a pre-hint client payload (no optional fields) still parses
        let m =
            ServerMsg::parse(r#"{"type":"error","id":1,"code":"queue_full","message":"full"}"#)
                .unwrap();
        match m {
            ServerMsg::Error { retry_after_ms, last_index, .. } => {
                assert_eq!(retry_after_ms, None);
                assert_eq!(last_index, None);
            }
            other => panic!("expected error, got {other:?}"),
        }
        // replica_lost distinguishes "no token yet" from "index 0"
        let lost = ServerMsg::replica_lost(2, Some(0), "died").encode();
        assert!(lost.contains(r#""last_index":0"#));
        let never = ServerMsg::replica_lost(2, None, "died").encode();
        assert!(!never.contains("last_index"));
    }

    #[test]
    fn trace_echo_is_optional_on_the_wire() {
        // untraced replies omit the field entirely (old clients see no
        // new keys); traced replies carry it as a 16-hex-digit string
        let plain = ServerMsg::Score { id: 1, ce: 1.0, ppl: 2.0, latency_ms: 3.0, trace: 0 };
        assert!(!plain.encode().contains("trace"));
        let traced = ServerMsg::Score { id: 1, ce: 1.0, ppl: 2.0, latency_ms: 3.0, trace: 0x2a };
        assert!(traced.encode().contains(r#""trace":"000000000000002a""#));
        // a pre-trace peer payload (no field) parses as untraced, and a
        // garbage trace degrades to untraced instead of failing
        for line in [
            r#"{"type":"score","id":1,"ce":1,"ppl":2,"latency_ms":3}"#,
            r#"{"type":"score","id":1,"ce":1,"ppl":2,"latency_ms":3,"trace":"zz"}"#,
        ] {
            match ServerMsg::parse(line).unwrap() {
                ServerMsg::Score { trace, .. } => assert_eq!(trace, 0),
                other => panic!("expected score, got {other:?}"),
            }
        }
    }

    #[test]
    fn stats_reply_keeps_fields() {
        let body = Json::parse(r#"{"requests": 12, "shed": 0}"#).unwrap();
        let line = ServerMsg::Stats(body).encode();
        let j = Json::parse(&line).unwrap();
        assert_eq!(j.get("type").unwrap().as_str().unwrap(), "stats");
        assert_eq!(j.get("requests").unwrap().as_usize().unwrap(), 12);
        match ServerMsg::parse(&line).unwrap() {
            ServerMsg::Stats(s) => {
                assert_eq!(s.get("shed").unwrap().as_usize().unwrap(), 0)
            }
            other => panic!("expected stats, got {other:?}"),
        }
    }

    #[test]
    fn parse_rejects_malformed() {
        assert!(ClientMsg::parse("not json").is_err());
        assert!(ClientMsg::parse(r#"{"type":"nope"}"#).is_err());
        assert!(ClientMsg::parse(r#"{"type":"score","id":-1,"tokens":[]}"#).is_err());
        // 2^53 + 1 would round through f64 to a different id — rejected
        assert!(
            ClientMsg::parse(r#"{"type":"score","id":9007199254740993,"tokens":[]}"#).is_err()
        );
        assert!(
            ClientMsg::parse(r#"{"type":"score","id":9007199254740991,"tokens":[]}"#).is_ok()
        );
        assert!(ClientMsg::parse(r#"{"type":"score","id":1,"tokens":[1.5]}"#).is_err());
        assert!(ClientMsg::parse(r#"{"type":"reload"}"#).is_err());
        assert!(ServerMsg::parse(r#"{"type":"score","id":1}"#).is_err());
    }
}
