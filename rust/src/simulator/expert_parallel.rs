//! Expert-parallelism communication model (the paper's future-work
//! direction: "overlapping communication with computation in distributed
//! settings like expert parallelism", Section 7; DeepGEMM/DeepEP's
//! native regime, Appendix B).
//!
//! Under EP, experts are sharded across `ep` ranks; each microbatch pays
//! an all2all *dispatch* (route tokens to the rank holding their expert)
//! before up-proj and an all2all *combine* after down-proj, in both the
//! forward and backward passes. Tokens land contiguously per expert, so
//! EP pairs naturally with contiguous grouped GEMM (DeepGEMM) — but adds
//! communication that grows with K and suffers from expert imbalance
//! (the hottest rank gates the all2all).

use super::configs::MoeShape;
use super::hw::GpuSpec;
use super::methods::{kernel_graph, Method, Pass, Routing};

/// EP interconnect: per-rank all2all bandwidth and the fraction of the
/// transfer a fused/pipelined implementation hides behind compute.
#[derive(Debug, Clone, Copy)]
pub struct EpNet {
    pub bw_bps: f64,
    pub overlap: f64,
}

/// NVLink-class intra-node all2all (8-GPU EP group).
pub const NVLINK_EP: EpNet = EpNet { bw_bps: 300e9, overlap: 0.0 };
/// Same fabric with compute/communication overlap (DeepEP-style).
pub const NVLINK_EP_OVERLAPPED: EpNet = EpNet { bw_bps: 300e9, overlap: 0.6 };

/// Imbalance factor: the busiest rank's share over the ideal 1/ep.
/// 1.0 = perfectly balanced (EC routing); TC routing under mild skew
/// typically lands at 1.1–1.4.
pub fn imbalance_factor(counts: &[usize], ep: usize) -> f64 {
    assert!(!counts.is_empty() && ep > 0);
    let e = counts.len();
    let per = (e + ep - 1) / ep;
    let total: usize = counts.iter().sum();
    let max_rank: usize = (0..ep)
        .map(|r| counts[r * per..((r + 1) * per).min(e)].iter().sum())
        .max()
        .unwrap_or(0);
    if total == 0 {
        return 1.0;
    }
    max_rank as f64 * ep as f64 / total as f64
}

/// One EP step's timing decomposition (seconds).
#[derive(Debug, Clone, Copy)]
pub struct EpStep {
    pub compute_s: f64,
    pub dispatch_s: f64,
    pub combine_s: f64,
    pub total_s: f64,
}

/// Time one MoE layer pass under expert parallelism on `ep` ranks.
///
/// Per rank: compute runs on T*K/ep routed rows; dispatch moves each
/// routed token's d-vector once (2 bytes BF16), combine moves the
/// results back; the busiest rank (imbalance) gates both.
pub fn ep_layer_time(
    m: Method,
    s: &MoeShape,
    r: &Routing,
    pass: Pass,
    hw: &GpuSpec,
    net: &EpNet,
    ep: usize,
) -> EpStep {
    assert!(ep >= 1 && s.e % ep == 0, "E must divide into EP ranks");
    // per-rank shard: same T, E/ep experts, this rank's count slice;
    // the imbalance factor scales the critical (busiest) rank's work.
    let imb = imbalance_factor(&r.counts, ep);
    let per_rank_shape = MoeShape { e: s.e / ep, ..*s };
    let per = s.e / ep;
    let rank_routing = Routing::from_counts(r.counts[..per].to_vec(), r.m_tile);
    let ks = kernel_graph(m, &per_rank_shape, &rank_routing, pass);
    let compute = super::gemm::total_time_s(&ks, hw) * imb;

    // all2all volume per rank: every routed pair's d-vector, BF16, once
    // out (dispatch) and once back (combine); backward doubles (grads).
    let pairs_per_rank = (s.t * s.k) as f64 / ep as f64 * imb;
    let bytes = 2.0 * pairs_per_rank * s.d as f64;
    let factor = match pass {
        Pass::Forward => 1.0,
        Pass::Backward => 2.0,
    };
    let a2a = bytes * factor / net.bw_bps;
    let visible = a2a * (1.0 - net.overlap);
    EpStep {
        compute_s: compute,
        dispatch_s: visible / 2.0,
        combine_s: visible / 2.0,
        total_s: compute + visible,
    }
}

/// EP vs single-GPU speedup for one layer (strong scaling on T*K work).
pub fn ep_speedup(m: Method, s: &MoeShape, hw: &GpuSpec, net: &EpNet, ep: usize) -> f64 {
    let r = Routing::uniform(s, hw.tile.0);
    let single = {
        let ks = kernel_graph(m, s, &r, Pass::Forward);
        super::gemm::total_time_s(&ks, hw)
    };
    let step = ep_layer_time(m, s, &r, Pass::Forward, hw, net, ep);
    single / step.total_s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::simulator::hw::H100;

    fn s7b() -> MoeShape {
        MoeShape::new(24576, 1536, 256, 128, 8)
    }

    #[test]
    fn imbalance_bounds() {
        assert!((imbalance_factor(&[10, 10, 10, 10], 2) - 1.0).abs() < 1e-12);
        let f = imbalance_factor(&[40, 0, 0, 0], 2);
        assert!((f - 2.0).abs() < 1e-12); // one rank holds everything
        assert!(imbalance_factor(&[3, 1, 3, 1], 2) >= 1.0);
    }

    #[test]
    fn ep_scales_but_sublinearly_without_overlap() {
        let s = s7b();
        let sp8 = ep_speedup(Method::DeepGemmPlus, &s, &H100, &NVLINK_EP, 8);
        assert!(sp8 > 2.0, "ep8 speedup {sp8:.2}");
        assert!(sp8 < 8.0, "ep8 speedup {sp8:.2} should be sublinear");
    }

    #[test]
    fn overlap_recovers_throughput() {
        let s = s7b();
        let plain = ep_speedup(Method::DeepGemmPlus, &s, &H100, &NVLINK_EP, 8);
        let fused = ep_speedup(Method::DeepGemmPlus, &s, &H100, &NVLINK_EP_OVERLAPPED, 8);
        assert!(fused > plain, "{fused:.2} vs {plain:.2}");
    }

    #[test]
    fn backward_pays_double_a2a() {
        let s = s7b();
        let r = Routing::uniform(&s, 128);
        let f = ep_layer_time(Method::SonicMoE, &s, &r, Pass::Forward, &H100, &NVLINK_EP, 8);
        let b = ep_layer_time(Method::SonicMoE, &s, &r, Pass::Backward, &H100, &NVLINK_EP, 8);
        let f_comm = f.dispatch_s + f.combine_s;
        let b_comm = b.dispatch_s + b.combine_s;
        assert!((b_comm / f_comm - 2.0).abs() < 1e-9);
        assert!(b.total_s > f.total_s);
    }

    #[test]
    fn finer_granularity_more_comm_bound() {
        // iso-FLOPs: n*K constant; higher K = more routed pairs = more
        // all2all per FLOP -> comm share grows (the paper's motivation
        // for overlapping EP communication).
        let coarse = MoeShape::new(24576, 1536, 1024, 32, 2);
        let fine = MoeShape::new(24576, 1536, 256, 128, 8);
        let share = |s: &MoeShape| {
            let r = Routing::uniform(s, 128);
            let t = ep_layer_time(Method::SonicMoE, s, &r, Pass::Forward, &H100, &NVLINK_EP, 8);
            (t.dispatch_s + t.combine_s) / t.total_s
        };
        assert!(share(&fine) > share(&coarse));
    }
}
