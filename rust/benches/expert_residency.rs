//! Tiered expert-residency bench: decode throughput and hit rate
//! across a resident-bytes budget sweep.
//!
//! One `DecodeCore` generates greedy token streams with every expert
//! resident (the dense baseline), then the same streams run against
//! file-backed expert stores at shrinking budgets (100%, 50%, 25% of
//! the total expert bytes). The spill tier holds the same bits the
//! dense path reads, and the acquire guard pins a blob for the whole
//! GEMM, so every budget must produce **bitwise identical** tokens —
//! the bench asserts this per stream and fails the process otherwise
//! (the residency acceptance gate CI runs).
//!
//! What the sweep measures is the IO story: the router's top-k mask is
//! known before any expert GEMM runs, so the store prefetches the
//! routed experts while earlier layers compute. At 100% budget every
//! acquisition after warm-up hits; under a cap the hit rate tracks how
//! much of the working set the LRU keeps and `prefetch_p95_us` tracks
//! how well the loader hides the spill reads.
//!
//! Emits one JSON record (line starting with `{"bench":`) for the
//! bench trajectory: per-budget `residency_hit_rate`,
//! `prefetch_p95_us` and `decode_tokens_per_s` feed the gate.
//! `SONIC_RESIDENCY_BENCH_TOKENS` overrides the tokens per stream
//! (CI smoke uses a small value).

use std::collections::BTreeMap;
use std::time::Instant;

use sonic_moe::coordinator::decode::{argmax, DecodeCore};
use sonic_moe::memory::residency::ResidencySpec;
use sonic_moe::util::dtype::Dtype;
use sonic_moe::util::json::Json;

const NO_ARTIFACTS: &str = "/nonexistent-artifacts-dir";
/// Independent greedy streams per run (slots churn, so each stream
/// re-touches every layer's routed experts from a fresh prefix).
const STREAMS: usize = 6;

fn open_dense() -> DecodeCore {
    DecodeCore::new_with_dtype(NO_ARTIFACTS, "small", "native", 0, 0, Dtype::F32)
        .expect("open dense decode core")
}

fn open_tiered(budget: usize) -> (DecodeCore, ResidencySpec) {
    let spec = ResidencySpec::new(budget, None);
    let core =
        DecodeCore::new_with_residency(NO_ARTIFACTS, "small", "native", 0, 0, Dtype::F32, &spec)
            .expect("open tiered decode core");
    (core, spec)
}

/// Generate `n` greedy tokens from `prompt` in a fresh slot.
fn greedy_stream(core: &mut DecodeCore, prompt: &[i32], n: usize) -> Vec<i32> {
    let slot = core.alloc_slot().expect("free slot");
    let mut logits = core.prefill(slot, prompt).expect("prefill");
    let mut out = Vec::with_capacity(n);
    loop {
        let t = argmax(&logits);
        out.push(t);
        core.recycle_logits(logits);
        if out.len() == n {
            break;
        }
        logits = core.decode_step(&[(slot, t)]).expect("decode step");
    }
    core.free_slot(slot);
    out
}

/// Run every stream; returns (token streams, generated tokens/s).
fn run_streams(core: &mut DecodeCore, tokens: usize) -> (Vec<Vec<i32>>, f64) {
    let t0 = Instant::now();
    let mut streams = Vec::with_capacity(STREAMS);
    for s in 0..STREAMS {
        let prompt: Vec<i32> = (0..4).map(|i| ((s * 31 + i * 7) % 256) as i32).collect();
        streams.push(greedy_stream(core, &prompt, tokens));
    }
    let dt = t0.elapsed().as_secs_f64();
    let tok_s = if dt > 0.0 { (STREAMS * tokens) as f64 / dt } else { 0.0 };
    (streams, tok_s)
}

fn main() {
    let tokens: usize = std::env::var("SONIC_RESIDENCY_BENCH_TOKENS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(16)
        .clamp(2, 24); // prompt 4 + tokens must fit the 32-token slot
    println!("expert_residency: {STREAMS} greedy streams x {tokens} tokens, f32, builtin small\n");

    let mut dense = open_dense();
    let (want, dense_tok_s) = run_streams(&mut dense, tokens);
    let dense_weight = dense.weight_bytes();
    drop(dense);

    // total expert bytes = the spill tier's size at any budget
    let (probe, _spec) = open_tiered(usize::MAX);
    let total = probe.residency().expect("tiered core has a store").spilled_bytes();
    drop(probe);

    let mut tbl = sonic_moe::bench::Table::new(
        "tiered expert residency: budget sweep (dense-bitwise outputs asserted)",
        &["run", "budget B", "weight B", "tok/s", "hit rate", "evictions", "prefetch p95 us"],
    );
    tbl.row(&[
        "dense".to_string(),
        "-".to_string(),
        dense_weight.to_string(),
        format!("{dense_tok_s:.0}"),
        "-".to_string(),
        "-".to_string(),
        "-".to_string(),
    ]);

    let mut runs = Vec::new();
    let mut all_bitwise = true;
    for (name, budget) in [
        ("budget_100pct", total),
        ("budget_50pct", total / 2),
        ("budget_25pct", total / 4),
    ] {
        let (mut core, spec) = open_tiered(budget);
        let (got, tok_s) = run_streams(&mut core, tokens);
        let weight = core.weight_bytes();
        let bitwise = got == want;
        all_bitwise &= bitwise;
        if !bitwise {
            eprintln!("expert_residency: {name} diverged from the dense token streams");
        }
        let snap = spec.stats.snapshot();
        tbl.row(&[
            name.to_string(),
            budget.to_string(),
            weight.to_string(),
            format!("{tok_s:.0}"),
            format!("{:.3}", snap.hit_rate()),
            snap.total.evictions.to_string(),
            format!("{:.0}", snap.prefetch_p95_us),
        ]);
        let mut j = BTreeMap::new();
        j.insert("name".to_string(), Json::Str(name.to_string()));
        j.insert("resident_budget_bytes".to_string(), Json::Num(budget as f64));
        j.insert("weight_bytes".to_string(), Json::Num(weight as f64));
        j.insert("decode_tokens_per_s".to_string(), Json::Num(tok_s));
        j.insert("residency_hit_rate".to_string(), Json::Num(snap.hit_rate()));
        j.insert("prefetch_p95_us".to_string(), Json::Num(snap.prefetch_p95_us));
        j.insert("evictions".to_string(), Json::Num(snap.total.evictions as f64));
        j.insert("bitwise_identical".to_string(), Json::Bool(bitwise));
        runs.push(Json::Obj(j));
    }
    tbl.print();

    let mut rec = BTreeMap::new();
    rec.insert("bench".to_string(), Json::Str("expert_residency".to_string()));
    rec.insert("streams".to_string(), Json::Num(STREAMS as f64));
    rec.insert("tokens_per_stream".to_string(), Json::Num(tokens as f64));
    rec.insert("total_expert_bytes".to_string(), Json::Num(total as f64));
    rec.insert("dense_tokens_per_s".to_string(), Json::Num(dense_tok_s));
    rec.insert("runs".to_string(), Json::Arr(runs));
    rec.insert("all_bitwise_identical".to_string(), Json::Bool(all_bitwise));
    println!("{}", Json::Obj(rec));

    if !all_bitwise {
        eprintln!("expert_residency: a capped budget changed decode output");
        std::process::exit(1);
    }
}
