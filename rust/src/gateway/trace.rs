//! Trace-driven workload synthesis: deterministic, production-shaped
//! request schedules for the loadgen replayer and the saturation bench.
//!
//! A *trace* is a JSONL file: one header line naming the trace and its
//! seed, then one line per request event with an absolute arrival time
//! (milliseconds since trace start), a tenant label, a request mode
//! (`score` / `generate` / `spec`), a prompt length and, for decode
//! modes, an output budget and speculative draft depth:
//!
//! ```text
//! {"trace":"bursty_mixed","seed":42,"version":1}
//! {"at_ms":0.0,"tenant":"chat","mode":"generate","prompt_len":24,"max_new":8}
//! {"at_ms":13.7,"tenant":"batch","mode":"score","prompt_len":311}
//! {"at_ms":14.2,"tenant":"spec","mode":"spec","prompt_len":18,"max_new":8,"spec_k":3}
//! ```
//!
//! Traces are synthesized by [`TraceSpec::synthesize`] from three
//! deterministic seeded ingredients, so the committed files under
//! `bench/traces/` are reproducible evidence rather than captures:
//!
//! - **bursty arrivals** — a two-state Markov-modulated Poisson process
//!   (calm rate / burst rate, exponential dwell times) that produces
//!   the flash-crowd arrival clumping uniform open loops cannot;
//! - **heavy-tail lengths** — bounded-Pareto prompt lengths
//!   (`len = min * (1-u)^(-1/alpha)`, capped), matching the long-tail
//!   prompt mixes of deployed serving;
//! - **multi-tenant mixes** — weighted tenants, each pinning a request
//!   mode and its own length/output distribution.
//!
//! Replaying a trace ([`Trace::schedule`] feeding
//! [`crate::gateway::loadgen::run_trace`]) expands each event into the
//! concrete token ids deterministically from the trace seed, so the
//! same file + seed always issues byte-identical requests on the same
//! schedule — pinned by the trace-determinism tests.
//!
//! The inverse direction is **capture** ([`TraceCapture`]): a live
//! gateway started with `--capture-trace <path>` appends every arrival
//! (admitted or shed) back into the same JSONL format, so a
//! production-shaped workload can be re-played through
//! `loadgen --trace` later. Captured files validate under
//! [`Trace::from_jsonl`] by construction.

use std::collections::BTreeMap;
use std::io::Write;
use std::path::Path;
use std::sync::Mutex;
use std::time::Instant;

use anyhow::{bail, Context, Result};

use crate::util::json::Json;
use crate::util::prng::Prng;

/// Current trace-file format version (the header's `version` field).
pub const TRACE_VERSION: u64 = 1;

/// Splitmix-style stream separator: decorrelates the per-event token
/// streams drawn from one trace seed.
const EVENT_STREAM_SALT: u64 = 0x9E37_79B9_7F4A_7C15;

/// The request mode a trace event exercises.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum TraceMode {
    /// One-shot batch scoring (`score` message, one reply).
    Score,
    /// Plain greedy streaming decode (`generate` message).
    Generate,
    /// Speculative decode (`generate` with a `spec` block).
    Spec,
}

impl TraceMode {
    /// Wire/JSONL name of the mode.
    pub fn name(&self) -> &'static str {
        match self {
            TraceMode::Score => "score",
            TraceMode::Generate => "generate",
            TraceMode::Spec => "spec",
        }
    }

    /// Parse a JSONL mode name.
    pub fn parse(s: &str) -> Result<TraceMode> {
        Ok(match s {
            "score" => TraceMode::Score,
            "generate" => TraceMode::Generate,
            "spec" => TraceMode::Spec,
            other => bail!("unknown trace mode {other:?} (score|generate|spec)"),
        })
    }
}

/// One arrival in a trace: *when* a request of *what shape* arrives.
/// Token ids are not stored — they are derived from the trace seed at
/// schedule time, keeping trace files small and diffable.
#[derive(Debug, Clone, PartialEq)]
pub struct TraceEvent {
    /// Arrival time in milliseconds since trace start.
    pub at_ms: f64,
    /// Tenant label (aggregated in the replay report).
    pub tenant: String,
    /// Request mode.
    pub mode: TraceMode,
    /// Prompt length in tokens (>= 1).
    pub prompt_len: usize,
    /// Generated-token budget (decode modes; 0 = gateway default).
    pub max_new: usize,
    /// Draft depth for `spec` mode (>= 1 there, 0 otherwise).
    pub spec_k: usize,
}

/// A named, seeded request trace: the parsed form of one JSONL file.
#[derive(Debug, Clone, PartialEq)]
pub struct Trace {
    /// Trace name (header `trace` field).
    pub name: String,
    /// Seed that token synthesis derives from at schedule time.
    pub seed: u64,
    /// Arrival events, sorted by `at_ms`.
    pub events: Vec<TraceEvent>,
}

/// One concrete request ready to issue: a [`TraceEvent`] expanded with
/// its request id and synthesized prompt tokens.
#[derive(Debug, Clone, PartialEq)]
pub struct ScheduledReq {
    /// Arrival time in milliseconds since replay start.
    pub at_ms: f64,
    /// Wire request id (the event's index in the trace).
    pub id: u64,
    /// Tenant label.
    pub tenant: String,
    /// Request mode.
    pub mode: TraceMode,
    /// Synthesized prompt token ids.
    pub tokens: Vec<i32>,
    /// Generated-token budget (decode modes).
    pub max_new: usize,
    /// Draft depth (`spec` mode).
    pub spec_k: usize,
}

impl Trace {
    /// Trace length in milliseconds (time of the last arrival).
    pub fn duration_ms(&self) -> f64 {
        self.events.last().map_or(0.0, |e| e.at_ms)
    }

    /// Mean offered load over the trace in requests/second.
    pub fn offered_rps(&self) -> f64 {
        let d = self.duration_ms();
        if d <= 0.0 {
            return 0.0;
        }
        (self.events.len() as f64 - 1.0).max(1.0) / (d / 1000.0)
    }

    /// Expand every event into a concrete request. `seed_override`
    /// replaces the trace's own seed when nonzero (same file, fresh
    /// token streams). Prompt lengths are clamped to `seq_cap` so a
    /// trace synthesized for a large model still replays against a
    /// small one. Deterministic: same trace + same seed ⇒ identical
    /// schedule, byte for byte.
    pub fn schedule(&self, seed_override: u64, seq_cap: usize) -> Vec<ScheduledReq> {
        let seed = if seed_override != 0 { seed_override } else { self.seed };
        let cap = seq_cap.max(1);
        self.events
            .iter()
            .enumerate()
            .map(|(i, e)| {
                // One decorrelated stream per event: request i's tokens
                // never depend on how many tokens earlier events drew.
                let mut rng =
                    Prng::new(seed ^ (i as u64 + 1).wrapping_mul(EVENT_STREAM_SALT));
                let len = e.prompt_len.clamp(1, cap);
                let tokens =
                    (0..len).map(|_| rng.below(1 << 15) as i32).collect();
                ScheduledReq {
                    at_ms: e.at_ms,
                    id: i as u64,
                    tenant: e.tenant.clone(),
                    mode: e.mode,
                    tokens,
                    max_new: e.max_new,
                    spec_k: e.spec_k,
                }
            })
            .collect()
    }

    /// Serialize to JSONL (header line + one line per event).
    pub fn to_jsonl(&self) -> String {
        let mut out = String::new();
        out.push_str(&header_jsonl(&self.name, self.seed));
        out.push('\n');
        for e in &self.events {
            out.push_str(&event_jsonl(e));
            out.push('\n');
        }
        out
    }

    /// Parse the JSONL trace format. Validates the header, event
    /// shapes, and that arrivals are non-decreasing in time.
    pub fn from_jsonl(text: &str) -> Result<Trace> {
        let mut lines = text.lines().filter(|l| !l.trim().is_empty());
        let header = lines.next().context("empty trace file")?;
        let h = Json::parse(header).context("parsing trace header")?;
        let name = h.get("trace")?.as_str()?.to_string();
        let seed = h.get("seed")?.as_usize()? as u64;
        let version = h.get("version")?.as_usize()? as u64;
        if version != TRACE_VERSION {
            bail!("trace version {version} unsupported (expected {TRACE_VERSION})");
        }
        let mut events = Vec::new();
        let mut prev_ms = 0.0f64;
        for (n, line) in lines.enumerate() {
            let j = Json::parse(line)
                .with_context(|| format!("parsing trace event {}", n + 1))?;
            let at_ms = j.get("at_ms")?.as_f64()?;
            if !at_ms.is_finite() || at_ms < prev_ms {
                bail!("event {} arrives at {at_ms}ms, before {prev_ms}ms", n + 1);
            }
            prev_ms = at_ms;
            let mode = TraceMode::parse(j.get("mode")?.as_str()?)?;
            let prompt_len = j.get("prompt_len")?.as_usize()?;
            if prompt_len == 0 {
                bail!("event {} has an empty prompt", n + 1);
            }
            let opt = |key: &str| -> Result<usize> {
                match j.opt(key) {
                    Some(v) => v.as_usize(),
                    None => Ok(0),
                }
            };
            let (max_new, spec_k) = (opt("max_new")?, opt("spec_k")?);
            if mode == TraceMode::Spec && spec_k == 0 {
                bail!("event {} is spec mode but has no spec_k", n + 1);
            }
            if mode == TraceMode::Score && (max_new > 0 || spec_k > 0) {
                bail!("event {} is score mode but carries decode fields", n + 1);
            }
            events.push(TraceEvent {
                at_ms,
                tenant: j.get("tenant")?.as_str()?.to_string(),
                mode,
                prompt_len,
                max_new,
                spec_k,
            });
        }
        if events.is_empty() {
            bail!("trace {name:?} has no events");
        }
        Ok(Trace { name, seed, events })
    }

    /// Load a trace from a JSONL file on disk.
    pub fn load(path: &Path) -> Result<Trace> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading trace {}", path.display()))?;
        Self::from_jsonl(&text)
            .with_context(|| format!("parsing trace {}", path.display()))
    }
}

/// The canonical header line (no trailing newline) — shared by
/// [`Trace::to_jsonl`] and [`TraceCapture`] so the two writers can
/// never drift apart.
fn header_jsonl(name: &str, seed: u64) -> String {
    let mut h = BTreeMap::new();
    h.insert("trace".to_string(), Json::Str(name.to_string()));
    h.insert("seed".to_string(), Json::Num(seed as f64));
    h.insert("version".to_string(), Json::Num(TRACE_VERSION as f64));
    Json::Obj(h).to_string()
}

/// The canonical serialization of one event (no trailing newline).
/// `at_ms` is rounded to two decimals, decode fields are omitted when
/// zero — exactly the format [`Trace::from_jsonl`] validates.
fn event_jsonl(e: &TraceEvent) -> String {
    let mut m = BTreeMap::new();
    m.insert("at_ms".to_string(), Json::Num((e.at_ms * 100.0).round() / 100.0));
    m.insert("tenant".to_string(), Json::Str(e.tenant.clone()));
    m.insert("mode".to_string(), Json::Str(e.mode.name().to_string()));
    m.insert("prompt_len".to_string(), Json::Num(e.prompt_len as f64));
    if e.max_new > 0 {
        m.insert("max_new".to_string(), Json::Num(e.max_new as f64));
    }
    if e.spec_k > 0 {
        m.insert("spec_k".to_string(), Json::Num(e.spec_k as f64));
    }
    Json::Obj(m).to_string()
}

/// Records a live gateway's arrivals back into the JSONL trace format
/// (the `--capture-trace <path>` flag). Every `score`/`generate`
/// arrival — admitted *or* shed; a trace is an arrival process, not an
/// admission log — appends one event with `at_ms` measured from
/// capture start. Lines are flushed as they are written, so the file
/// is valid up to the last arrival even if the gateway dies. The
/// capture clamps `at_ms` non-decreasing and `prompt_len >= 1`, so the
/// output always round-trips through [`Trace::from_jsonl`].
pub struct TraceCapture {
    start: Instant,
    inner: Mutex<CaptureInner>,
}

struct CaptureInner {
    file: std::fs::File,
    last_ms: f64,
    events: u64,
}

/// Tenant label stamped on captured events: the wire protocol carries
/// no tenant field, so every live arrival aggregates under one label.
pub const CAPTURE_TENANT: &str = "live";

/// Header seed of captured traces. Token contents are never captured
/// (the wire tokens came from the *client*); replaying a captured
/// trace re-synthesizes tokens from this seed, or from `--seed`.
pub const CAPTURE_SEED: u64 = 1;

impl TraceCapture {
    /// Create (truncate) the capture file and write the header line.
    pub fn create(path: &Path, name: &str) -> Result<TraceCapture> {
        let mut file = std::fs::File::create(path)
            .with_context(|| format!("creating capture file {}", path.display()))?;
        writeln!(file, "{}", header_jsonl(name, CAPTURE_SEED))
            .with_context(|| format!("writing capture header to {}", path.display()))?;
        file.flush()?;
        Ok(TraceCapture {
            start: Instant::now(),
            inner: Mutex::new(CaptureInner { file, last_ms: 0.0, events: 0 }),
        })
    }

    /// Append one arrival. Write failures are logged, not fatal — a
    /// full disk must not take the serving path down with it.
    pub fn record(&self, mode: TraceMode, prompt_len: usize, max_new: usize, spec_k: usize) {
        let at_ms = self.start.elapsed().as_secs_f64() * 1000.0;
        let mut g = self.inner.lock().unwrap();
        let e = TraceEvent {
            // concurrent connection threads may race the clock read by
            // a hair; the format requires non-decreasing arrivals
            at_ms: ((at_ms * 100.0).round() / 100.0).max(g.last_ms),
            tenant: CAPTURE_TENANT.to_string(),
            mode,
            prompt_len: prompt_len.max(1),
            // score events must not carry decode fields
            max_new: if mode == TraceMode::Score { 0 } else { max_new },
            spec_k: if mode == TraceMode::Spec { spec_k.max(1) } else { 0 },
        };
        g.last_ms = e.at_ms;
        let line = event_jsonl(&e);
        let ok = writeln!(g.file, "{line}").is_ok() && g.file.flush().is_ok();
        if ok {
            g.events += 1;
        } else {
            log::warn!("trace capture: failed to append event (disk full?)");
        }
    }

    /// Events captured so far.
    pub fn events(&self) -> u64 {
        self.inner.lock().unwrap().events
    }
}

/// One tenant of a [`TraceSpec`]: a weighted request class pinning a
/// mode and its prompt/output length distribution.
#[derive(Debug, Clone, PartialEq)]
pub struct TenantSpec {
    /// Tenant label written into each event.
    pub name: String,
    /// Relative arrival weight among tenants.
    pub weight: f64,
    /// Request mode for this tenant's events.
    pub mode: TraceMode,
    /// Bounded-Pareto prompt length: minimum.
    pub prompt_min: usize,
    /// Bounded-Pareto tail exponent (smaller = heavier tail).
    pub prompt_alpha: f64,
    /// Bounded-Pareto prompt length: cap.
    pub prompt_cap: usize,
    /// Generated-token budget (decode modes).
    pub max_new: usize,
    /// Draft depth (`spec` mode).
    pub spec_k: usize,
}

/// Generator parameters for a synthetic trace: a two-state MMPP
/// arrival process over a weighted tenant mix.
#[derive(Debug, Clone, PartialEq)]
pub struct TraceSpec {
    /// Trace name (also the header name of the output).
    pub name: String,
    /// Seed for arrivals, tenant draws, lengths and (later) tokens.
    pub seed: u64,
    /// Number of arrival events to synthesize.
    pub events: usize,
    /// Poisson arrival rate in the calm state (req/s).
    pub calm_rps: f64,
    /// Poisson arrival rate in the burst state (req/s).
    pub burst_rps: f64,
    /// Mean dwell time in the calm state (ms, exponential).
    pub calm_ms: f64,
    /// Mean dwell time in the burst state (ms, exponential).
    pub burst_ms: f64,
    /// Tenant mix (must be non-empty, weights positive).
    pub tenants: Vec<TenantSpec>,
}

/// Exponential draw with mean `mean` (inverse-CDF; `1 - u` keeps the
/// argument of `ln` strictly positive since `u` is in `[0, 1)`).
fn exp_draw(rng: &mut Prng, mean: f64) -> f64 {
    -(1.0 - rng.f64()).ln() * mean
}

/// Bounded-Pareto draw: `min * (1-u)^(-1/alpha)` capped at `cap`.
fn pareto_len(rng: &mut Prng, min: usize, alpha: f64, cap: usize) -> usize {
    let u = rng.f64();
    let x = min as f64 * (1.0 - u).powf(-1.0 / alpha.max(0.05));
    (x as usize).clamp(min.max(1), cap.max(min.max(1)))
}

impl TraceSpec {
    /// Synthesize the trace: deterministic in `seed` and the spec.
    pub fn synthesize(&self) -> Result<Trace> {
        if self.tenants.is_empty() {
            bail!("trace spec {:?} has no tenants", self.name);
        }
        if self.events == 0 {
            bail!("trace spec {:?} asks for zero events", self.name);
        }
        let weights: Vec<f64> = self.tenants.iter().map(|t| t.weight).collect();
        if weights.iter().any(|&w| !(w > 0.0)) {
            bail!("trace spec {:?} has a non-positive tenant weight", self.name);
        }
        let mut rng = Prng::new(self.seed);
        let mut events = Vec::with_capacity(self.events);
        // Two-state MMPP: arrivals are Poisson at the current state's
        // rate; the state flips after an exponential dwell. A gap that
        // would cross the state boundary is discarded and redrawn at
        // the new rate — the memoryless property makes that exact.
        let mut burst = false;
        let mut t_ms = 0.0f64;
        let mut state_left_ms = exp_draw(&mut rng, self.calm_ms.max(1.0));
        while events.len() < self.events {
            let rate = if burst { self.burst_rps } else { self.calm_rps };
            let gap_ms = exp_draw(&mut rng, 1000.0 / rate.max(1e-6));
            if gap_ms >= state_left_ms {
                t_ms += state_left_ms;
                burst = !burst;
                let mean = if burst { self.burst_ms } else { self.calm_ms };
                state_left_ms = exp_draw(&mut rng, mean.max(1.0));
                continue;
            }
            state_left_ms -= gap_ms;
            t_ms += gap_ms;
            let tenant = &self.tenants[rng.categorical(&weights)];
            let prompt_len = pareto_len(
                &mut rng,
                tenant.prompt_min,
                tenant.prompt_alpha,
                tenant.prompt_cap,
            );
            events.push(TraceEvent {
                at_ms: (t_ms * 100.0).round() / 100.0,
                tenant: tenant.name.clone(),
                mode: tenant.mode,
                prompt_len,
                max_new: if tenant.mode == TraceMode::Score { 0 } else { tenant.max_new },
                spec_k: if tenant.mode == TraceMode::Spec { tenant.spec_k.max(1) } else { 0 },
            });
        }
        Ok(Trace { name: self.name.clone(), seed: self.seed, events })
    }

    /// Named builtin specs — the generators behind the committed
    /// traces under `bench/traces/` (regenerate with the `trace`
    /// subcommand or `scripts/make_traces.py`).
    pub fn builtin(name: &str) -> Result<TraceSpec> {
        let t = |name: &str,
                 weight: f64,
                 mode: TraceMode,
                 prompt_min: usize,
                 prompt_alpha: f64,
                 prompt_cap: usize,
                 max_new: usize,
                 spec_k: usize| TenantSpec {
            name: name.to_string(),
            weight,
            mode,
            prompt_min,
            prompt_alpha,
            prompt_cap,
            max_new,
            spec_k,
        };
        Ok(match name {
            // Steady low-rate score-only stream: the determinism
            // baseline (no shedding at replay speed 1).
            "steady_score" => TraceSpec {
                name: "steady_score".into(),
                seed: 11,
                events: 64,
                calm_rps: 12.0,
                burst_rps: 12.0,
                calm_ms: 1_000.0,
                burst_ms: 1_000.0,
                tenants: vec![t("score", 1.0, TraceMode::Score, 6, 2.5, 24, 0, 0)],
            },
            // Flash-crowd mixed tenants: chat decode + batch scoring
            // + a speculative tenant, calm/burst MMPP arrivals. The
            // saturation bench ramps this one.
            "bursty_mixed" => TraceSpec {
                name: "bursty_mixed".into(),
                seed: 42,
                events: 160,
                calm_rps: 18.0,
                burst_rps: 110.0,
                calm_ms: 1_400.0,
                burst_ms: 350.0,
                tenants: vec![
                    t("chat", 0.50, TraceMode::Generate, 8, 1.8, 28, 8, 0),
                    t("batch", 0.38, TraceMode::Score, 10, 1.3, 48, 0, 0),
                    t("spec", 0.12, TraceMode::Spec, 8, 2.0, 20, 8, 3),
                ],
            },
            // Heavy-tail score-only burst mix: alpha 1.1 puts real
            // mass at the prompt cap, stressing batch-fill policies.
            "heavy_tail_score" => TraceSpec {
                name: "heavy_tail_score".into(),
                seed: 7,
                events: 128,
                calm_rps: 25.0,
                burst_rps: 140.0,
                calm_ms: 1_000.0,
                burst_ms: 250.0,
                tenants: vec![
                    t("short", 0.7, TraceMode::Score, 4, 2.2, 16, 0, 0),
                    t("long", 0.3, TraceMode::Score, 12, 1.1, 64, 0, 0),
                ],
            },
            other => bail!(
                "unknown builtin trace {other:?} \
                 (steady_score|bursty_mixed|heavy_tail_score)"
            ),
        })
    }

    /// Names accepted by [`TraceSpec::builtin`].
    pub fn builtin_names() -> &'static [&'static str] {
        &["steady_score", "bursty_mixed", "heavy_tail_score"]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builtin_specs_synthesize() {
        for name in TraceSpec::builtin_names() {
            let spec = TraceSpec::builtin(name).unwrap();
            let trace = spec.synthesize().unwrap();
            assert_eq!(trace.name, *name);
            assert_eq!(trace.events.len(), spec.events);
            assert!(trace.duration_ms() > 0.0);
            assert!(trace.offered_rps() > 0.0);
            // arrivals sorted, prompts non-empty, mode fields coherent
            let mut prev = 0.0;
            for e in &trace.events {
                assert!(e.at_ms >= prev);
                prev = e.at_ms;
                assert!(e.prompt_len >= 1);
                match e.mode {
                    TraceMode::Score => assert_eq!((e.max_new, e.spec_k), (0, 0)),
                    TraceMode::Generate => assert_eq!(e.spec_k, 0),
                    TraceMode::Spec => assert!(e.spec_k >= 1),
                }
            }
        }
        assert!(TraceSpec::builtin("nope").is_err());
    }

    #[test]
    fn synthesis_is_deterministic() {
        let spec = TraceSpec::builtin("bursty_mixed").unwrap();
        assert_eq!(spec.synthesize().unwrap(), spec.synthesize().unwrap());
    }

    #[test]
    fn jsonl_roundtrip_is_exact() {
        let trace = TraceSpec::builtin("bursty_mixed").unwrap().synthesize().unwrap();
        let text = trace.to_jsonl();
        let back = Trace::from_jsonl(&text).unwrap();
        assert_eq!(back, trace);
        // serialization is canonical: a second roundtrip is a fixpoint
        assert_eq!(back.to_jsonl(), text);
    }

    #[test]
    fn schedule_is_deterministic_and_capped() {
        let trace = TraceSpec::builtin("heavy_tail_score").unwrap().synthesize().unwrap();
        let a = trace.schedule(0, 32);
        let b = trace.schedule(0, 32);
        assert_eq!(a, b, "same trace + seed must give an identical schedule");
        assert_eq!(a.len(), trace.events.len());
        for (i, r) in a.iter().enumerate() {
            assert_eq!(r.id, i as u64);
            assert!(!r.tokens.is_empty() && r.tokens.len() <= 32);
            assert!(r.tokens.iter().all(|&t| (0..1 << 15).contains(&t)));
        }
        // a seed override changes tokens but not the arrival schedule
        let c = trace.schedule(999, 32);
        assert_ne!(a, c);
        for (x, y) in a.iter().zip(&c) {
            assert_eq!(x.at_ms, y.at_ms);
            assert_eq!(x.tokens.len(), y.tokens.len());
        }
    }

    #[test]
    fn parser_rejects_malformed_traces() {
        let ok = "{\"trace\":\"t\",\"seed\":1,\"version\":1}\n\
                  {\"at_ms\":0.0,\"tenant\":\"a\",\"mode\":\"score\",\"prompt_len\":3}\n";
        assert!(Trace::from_jsonl(ok).is_ok());
        for bad in [
            // no events
            "{\"trace\":\"t\",\"seed\":1,\"version\":1}\n",
            // wrong version
            "{\"trace\":\"t\",\"seed\":1,\"version\":9}\n\
             {\"at_ms\":0.0,\"tenant\":\"a\",\"mode\":\"score\",\"prompt_len\":3}\n",
            // time goes backwards
            "{\"trace\":\"t\",\"seed\":1,\"version\":1}\n\
             {\"at_ms\":5.0,\"tenant\":\"a\",\"mode\":\"score\",\"prompt_len\":3}\n\
             {\"at_ms\":1.0,\"tenant\":\"a\",\"mode\":\"score\",\"prompt_len\":3}\n",
            // spec without spec_k
            "{\"trace\":\"t\",\"seed\":1,\"version\":1}\n\
             {\"at_ms\":0.0,\"tenant\":\"a\",\"mode\":\"spec\",\"prompt_len\":3,\"max_new\":4}\n",
            // score with decode fields
            "{\"trace\":\"t\",\"seed\":1,\"version\":1}\n\
             {\"at_ms\":0.0,\"tenant\":\"a\",\"mode\":\"score\",\"prompt_len\":3,\"max_new\":4}\n",
            // empty prompt
            "{\"trace\":\"t\",\"seed\":1,\"version\":1}\n\
             {\"at_ms\":0.0,\"tenant\":\"a\",\"mode\":\"score\",\"prompt_len\":0}\n",
        ] {
            assert!(Trace::from_jsonl(bad).is_err(), "accepted: {bad}");
        }
    }

    #[test]
    fn capture_round_trips_through_the_parser() {
        let path = std::env::temp_dir()
            .join(format!("sonic_capture_unit_{}.jsonl", std::process::id()));
        let cap = TraceCapture::create(&path, "captured").unwrap();
        cap.record(TraceMode::Score, 5, 7, 0); // decode fields dropped for score
        cap.record(TraceMode::Generate, 3, 8, 0);
        cap.record(TraceMode::Spec, 2, 8, 0); // spec_k clamped to >= 1
        cap.record(TraceMode::Score, 0, 0, 0); // empty prompt clamped to 1
        assert_eq!(cap.events(), 4);
        drop(cap);
        let trace = Trace::load(&path).unwrap();
        std::fs::remove_file(&path).unwrap();
        assert_eq!(trace.name, "captured");
        assert_eq!(trace.seed, CAPTURE_SEED);
        assert_eq!(trace.events.len(), 4);
        assert_eq!((trace.events[0].max_new, trace.events[0].spec_k), (0, 0));
        assert_eq!(trace.events[1].max_new, 8);
        assert_eq!(trace.events[2].spec_k, 1);
        assert_eq!(trace.events[3].prompt_len, 1);
        assert!(trace.events.iter().all(|e| e.tenant == CAPTURE_TENANT));
        // captured output is canonical: serialize → parse is a fixpoint
        assert_eq!(Trace::from_jsonl(&trace.to_jsonl()).unwrap(), trace);
        // and it schedules deterministically
        assert_eq!(trace.schedule(0, 16), trace.schedule(0, 16));
    }

    #[test]
    fn heavy_tail_reaches_the_cap() {
        let trace =
            TraceSpec::builtin("heavy_tail_score").unwrap().synthesize().unwrap();
        let max = trace.events.iter().map(|e| e.prompt_len).max().unwrap();
        let min = trace.events.iter().map(|e| e.prompt_len).min().unwrap();
        // alpha 1.1 over 128 draws reaches the cap; the short tenant
        // keeps the minimum small — both ends of the tail are present
        assert_eq!(max, 64, "heavy tail should hit the prompt cap");
        assert!(min <= 8, "short prompts should survive the mix");
    }
}
