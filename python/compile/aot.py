"""AOT exporter: lower L2 functions (containing the L1 Pallas kernels) to
HLO **text** artifacts for the rust PJRT runtime.

Why text: jax >= 0.5 serializes HloModuleProto with 64-bit instruction
ids, which xla_extension 0.5.1 (the version the `xla` crate binds)
rejects; the HLO text parser reassigns ids and round-trips cleanly. See
/opt/xla-example/README.md.

Outputs (under --out-dir, default ../artifacts):

- ``<artifact>_<config>.hlo.txt``  one per exported function/config
- ``params_<config>.bin``          flat little-endian f32 initial params
- ``golden/<name>.*.bin``          input/output tensors for rust
                                   integration tests
- ``manifest.json``                the complete contract with rust: model
                                   configs, parameter layout (name, shape,
                                   offset), artifact signatures, goldens

Python runs only here (``make artifacts``); the rust binary never calls
back into python.
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model as model_lib
from . import moe_layer
from .kernels import MoEConfig


# ---------------------------------------------------------------------------
# Named model configs
# ---------------------------------------------------------------------------

CONFIGS = {
    # CI-fast config for rust integration tests.
    "small": model_lib.ModelConfig(
        vocab=256, d=64, n_layers=2, n_heads=4, seq_len=32, batch=4,
        n=32, E=8, K=2, m_tile=16,
    ),
    # The end-to-end training example (examples/train_moe_lm.rs). Scaled
    # to a 1-core CPU box; see DESIGN.md "Substitutions".
    "medium": model_lib.ModelConfig(
        vocab=1024, d=128, n_layers=4, n_heads=4, seq_len=64, batch=4,
        n=64, E=16, K=2, m_tile=32,
    ),
    # ~22M-parameter fine-grained MoE for the headline end-to-end run
    # (EXPERIMENTS.md §End-to-end): E=32 experts, K=4, G=d/n=2.
    "large": model_lib.ModelConfig(
        vocab=4096, d=256, n_layers=6, n_heads=8, seq_len=128, batch=4,
        n=128, E=32, K=4, m_tile=64,
    ),
    # Table 5 granularity family: iso-FLOPs (n*K const) and iso-params
    # (n*E const), increasingly fine-grained from g1 -> g3.
    "gran1": model_lib.ModelConfig(
        vocab=256, d=64, n_layers=2, n_heads=4, seq_len=32, batch=4,
        n=64, E=4, K=1, m_tile=8,
    ),
    "gran2": model_lib.ModelConfig(
        vocab=256, d=64, n_layers=2, n_heads=4, seq_len=32, batch=4,
        n=32, E=8, K=2, m_tile=8,
    ),
    "gran3": model_lib.ModelConfig(
        vocab=256, d=64, n_layers=2, n_heads=4, seq_len=32, batch=4,
        n=16, E=16, K=4, m_tile=8,
    ),
}


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(arr_shape, dtype) -> dict:
    return {"shape": list(arr_shape), "dtype": str(np.dtype(dtype).name)}


def _write_bin(path: str, arr: np.ndarray) -> None:
    arr = np.ascontiguousarray(arr)
    arr.tofile(path)


# ---------------------------------------------------------------------------
# Artifact builders
# ---------------------------------------------------------------------------


# Router variants exported per config. The "small" config gets every
# routing method so the Table 2/5/6/7/8 quality benches can train each;
# bigger configs ship only the two headline routers. Tags with _m*/_b*
# vary the rounding tile / microbatch for the Table 7/8 ablations.
ROUTER_VARIANTS = {
    "small": [
        ("tc", "tc", {}),
        ("tr", "tr-nr-f", {}),
        ("trbal", "tr-balance-f", {}),
        ("trup", "tr-up", {}),
        ("trdown", "tr-down", {}),
        ("ec", "ec", {}),
        ("tr_m8", "tr-nr-f", {"m_tile": 8}),
        ("tr_m32", "tr-nr-f", {"m_tile": 32}),
        ("tr_b2", "tr-nr-f", {"batch": 2}),
        ("tr_b8", "tr-nr-f", {"batch": 8}),
    ],
    "medium": [("tc", "tc", {}), ("tr", "tr-nr-f", {})],
    "large": [("tc", "tc", {}), ("tr", "tr-nr-f", {})],
    "gran1": [("tc", "tc", {})],
    "gran2": [("tc", "tc", {})],
    "gran3": [("tc", "tc", {})],
}


def export_lm(cfg_name: str, cfg, out_dir: str, manifest_cfg: dict) -> None:
    """Export grad-step (per router variant), eval artifact and params."""
    names = list(model_lib.param_specs(cfg).keys())
    specs = model_lib.param_specs(cfg)
    params = model_lib.init_params(cfg, seed=0)

    # flat initial parameter file + layout
    offset = 0
    layout = []
    with open(os.path.join(out_dir, f"params_{cfg_name}.bin"), "wb") as f:
        for n in names:
            a = np.asarray(params[n], np.float32)
            f.write(a.tobytes())
            layout.append(
                {"name": n, "shape": list(a.shape), "offset": offset, "size": a.size}
            )
            offset += a.size
    manifest_cfg["params"] = layout
    manifest_cfg["params_file"] = f"params_{cfg_name}.bin"
    manifest_cfg["num_params"] = offset
    manifest_cfg["model"] = dataclasses.asdict(cfg)
    manifest_cfg["num_active_params"] = model_lib.num_active_params(cfg)
    manifest_cfg.setdefault("artifacts", {})

    tok_spec = jax.ShapeDtypeStruct((cfg.batch, cfg.seq_len), jnp.int32)
    p_specs = [
        jax.ShapeDtypeStruct(specs[n], jnp.float32) for n in names
    ]

    for tag, router, overrides in ROUTER_VARIANTS[cfg_name]:
        rcfg = dataclasses.replace(cfg, router=router, **overrides)
        # batch overrides change the token input shape for this variant
        r_tok_spec = jax.ShapeDtypeStruct((rcfg.batch, rcfg.seq_len), jnp.int32)
        f, _ = model_lib.grad_step_fn(rcfg)
        t0 = time.time()
        lowered = jax.jit(f).lower(*p_specs, r_tok_spec)
        text = to_hlo_text(lowered)
        fname = f"lm_grad_step_{tag}_{cfg_name}.hlo.txt"
        with open(os.path.join(out_dir, fname), "w") as fh:
            fh.write(text)
        print(f"  lowered {fname}: {len(text)/1e6:.1f} MB in {time.time()-t0:.1f}s")
        manifest_cfg["artifacts"][f"lm_grad_step_{tag}"] = {
            "file": fname,
            "inputs": [{"name": n, **_spec(specs[n], "float32")} for n in names]
            + [{"name": "tokens", **_spec((rcfg.batch, rcfg.seq_len), "int32")}],
            "outputs": [
                {"name": "loss", **_spec((), "float32")},
                {"name": "ce", **_spec((), "float32")},
            ]
            + [{"name": f"d_{n}", **_spec(specs[n], "float32")} for n in names],
        }

    fe, _ = model_lib.eval_loss_fn(cfg)
    lowered = jax.jit(fe).lower(*p_specs, tok_spec)
    fname = f"lm_eval_{cfg_name}.hlo.txt"
    with open(os.path.join(out_dir, fname), "w") as fh:
        fh.write(to_hlo_text(lowered))
    manifest_cfg["artifacts"]["lm_eval"] = {
        "file": fname,
        "inputs": [{"name": n, **_spec(specs[n], "float32")} for n in names]
        + [{"name": "tokens", **_spec((cfg.batch, cfg.seq_len), "int32")}],
        "outputs": [{"name": "ce", **_spec((), "float32")}],
    }

    # golden for rust integration tests: run the jitted grad step once
    if cfg_name == "small":
        rng = np.random.default_rng(7)
        tokens = rng.integers(0, cfg.vocab, size=(cfg.batch, cfg.seq_len)).astype(
            np.int32
        )
        f, _ = model_lib.grad_step_fn(cfg)
        out = jax.jit(f)(*[params[n] for n in names], jnp.asarray(tokens))
        gold_dir = os.path.join(out_dir, "golden")
        os.makedirs(gold_dir, exist_ok=True)
        _write_bin(os.path.join(gold_dir, "lm_tokens.bin"), tokens)
        manifest_cfg["golden_lm"] = {
            "tokens_file": "golden/lm_tokens.bin",
            "loss": float(out[0]),
            "ce": float(out[1]),
            "grad_l1": {
                n: float(jnp.abs(g).sum()) for n, g in zip(names, out[2:])
            },
        }


def export_moe_layer(cfg_name: str, cfg, out_dir: str, manifest_cfg: dict) -> None:
    """Standalone single-MoE-layer artifacts (quickstart + microbench).

    Signature: (x, wr, w1, w2) -> (o, aux). One variant per router.
    """
    mcfg: MoEConfig = cfg.moe_cfg
    x_spec = jax.ShapeDtypeStruct((mcfg.T, mcfg.d), jnp.float32)
    wr_spec = jax.ShapeDtypeStruct((mcfg.d, mcfg.E), jnp.float32)
    w1_spec = jax.ShapeDtypeStruct((mcfg.E, mcfg.d, 2 * mcfg.n), jnp.float32)
    w2_spec = jax.ShapeDtypeStruct((mcfg.E, mcfg.n, mcfg.d), jnp.float32)

    rng = np.random.default_rng(11)
    x = rng.normal(size=(mcfg.T, mcfg.d)).astype(np.float32) * 0.5
    wr = rng.normal(size=(mcfg.d, mcfg.E)).astype(np.float32) * 0.1
    w1 = rng.normal(size=(mcfg.E, mcfg.d, 2 * mcfg.n)).astype(np.float32) * (
        mcfg.d**-0.5
    )
    w2 = rng.normal(size=(mcfg.E, mcfg.n, mcfg.d)).astype(np.float32) * (
        mcfg.n**-0.5
    )
    gold_dir = os.path.join(out_dir, "golden")
    os.makedirs(gold_dir, exist_ok=True)
    for arr, nm in ((x, "x"), (wr, "wr"), (w1, "w1"), (w2, "w2")):
        _write_bin(os.path.join(gold_dir, f"moe_{nm}_{cfg_name}.bin"), arr)

    for router in ("tc", "tr-nr-f"):
        tag = "tc" if router == "tc" else "tr"

        def fn(x, wr, w1, w2, _router=router):
            o, aux = moe_layer.sonic_moe_block(mcfg, x, wr, w1, w2, method=_router)
            return (o, aux)

        lowered = jax.jit(fn).lower(x_spec, wr_spec, w1_spec, w2_spec)
        fname = f"moe_layer_fwd_{tag}_{cfg_name}.hlo.txt"
        with open(os.path.join(out_dir, fname), "w") as fh:
            fh.write(to_hlo_text(lowered))
        o, aux = jax.jit(fn)(x, wr, w1, w2)
        _write_bin(os.path.join(gold_dir, f"moe_o_{tag}_{cfg_name}.bin"), np.asarray(o))
        manifest_cfg["artifacts"][f"moe_layer_fwd_{tag}"] = {
            "file": fname,
            "inputs": [
                {"name": "x", **_spec((mcfg.T, mcfg.d), "float32")},
                {"name": "wr", **_spec((mcfg.d, mcfg.E), "float32")},
                {"name": "w1", **_spec((mcfg.E, mcfg.d, 2 * mcfg.n), "float32")},
                {"name": "w2", **_spec((mcfg.E, mcfg.n, mcfg.d), "float32")},
            ],
            "outputs": [
                {"name": "o", **_spec((mcfg.T, mcfg.d), "float32")},
                {"name": "aux", **_spec((), "float32")},
            ],
            "golden": {
                "inputs": [
                    f"golden/moe_x_{cfg_name}.bin",
                    f"golden/moe_wr_{cfg_name}.bin",
                    f"golden/moe_w1_{cfg_name}.bin",
                    f"golden/moe_w2_{cfg_name}.bin",
                ],
                "output_o": f"golden/moe_o_{tag}_{cfg_name}.bin",
                "output_aux": float(aux),
            },
        }


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default=os.path.join("..", "artifacts"))
    ap.add_argument(
        "--configs", default="small,medium", help="comma-separated config names"
    )
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    manifest = {"version": 1, "configs": {}}
    for cfg_name in args.configs.split(","):
        cfg = CONFIGS[cfg_name]
        print(f"[aot] config {cfg_name}: {model_lib.num_params(cfg):,} params")
        mc: dict = {"artifacts": {}}
        export_lm(cfg_name, cfg, args.out_dir, mc)
        export_moe_layer(cfg_name, cfg, args.out_dir, mc)
        manifest["configs"][cfg_name] = mc

    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"[aot] wrote manifest with {len(manifest['configs'])} config(s)")


if __name__ == "__main__":
    main()
