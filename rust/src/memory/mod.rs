//! Activation-memory accounting model (Section 3.2, Figures 1 & 10).
//!
//! For each MoE kernel design we count the activation bytes that must be
//! *cached for the backward pass* in one MoE layer, in BF16 (2 bytes)
//! as in the paper's benchmarks, plus fp32 routing metadata. Peak
//! transient usage (temporaries live only inside the layer) is reported
//! separately, matching how Figure 10 measures "peak activation memory
//! per layer".
//!
//! The formulas follow Appendix B/C.1 and Section 3.2:
//!
//! - SonicMoE caches X (Td) and H (2TKn) -> `2*(Td + 2TKn)` bytes: the
//!   minimum without GEMM recomputation, independent of granularity.
//! - ScatterMoE additionally caches Y (TKd) for its dS = <dO, Y> path
//!   and the top-K score/index metadata.
//! - MoMoE additionally caches the gathered X_e (TKd) on top of Y.
//! - MegaBlocks materializes the gathered+padded X_e and the
//!   block-sparse layout, plus Y.
//! - Megatron (GroupedMLP, memory-efficient patch) matches SonicMoE's
//!   computational path but materializes gathered X_e for its separate
//!   gather kernel.
//! - DeepGEMM(++/pt) caches X, gathered X_e, H (minimum possible built
//!   on an external grouped GEMM, per the Figure 10 caption).

pub mod residency;

use crate::simulator::configs::MoeShape;

/// bf16 bytes per element.
pub const BF16: u64 = 2;
/// f32 bytes per element.
pub const F32: u64 = 4;

/// One method's activation accounting.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Method {
    SonicMoE,
    ScatterMoE,
    MoMoE,
    MegaBlocks,
    Megatron,
    DeepGemmPlus,
}

impl Method {
    /// Every accounted method, in the paper's figure order.
    pub const ALL: [Method; 6] = [
        Method::SonicMoE,
        Method::ScatterMoE,
        Method::MoMoE,
        Method::MegaBlocks,
        Method::Megatron,
        Method::DeepGemmPlus,
    ];

    /// Method name as printed in the paper's figures.
    pub fn name(&self) -> &'static str {
        match self {
            Method::SonicMoE => "SonicMoE",
            Method::ScatterMoE => "ScatterMoE",
            Method::MoMoE => "MoMoE",
            Method::MegaBlocks => "MegaBlocks",
            Method::Megatron => "Megatron",
            Method::DeepGemmPlus => "DeepGEMM++",
        }
    }

    /// MegaBlocks' block-sparse path does not support very small expert
    /// intermediate sizes (Figure 10 note: "MegaBlocks does not support
    /// small n").
    pub fn supports(&self, shape: &MoeShape) -> bool {
        match self {
            Method::MegaBlocks => shape.n >= 128,
            _ => true,
        }
    }
}

/// Routing metadata bytes common to all methods (indices + scores for
/// T*K routed pairs, int32/fp32).
fn routing_metadata_bytes(s: &MoeShape) -> u64 {
    let tk = (s.t * s.k) as u64;
    2 * 4 * tk // (index, score) per routed pair
}

/// Activation bytes cached for backward, per layer.
pub fn cached_activation_bytes(m: Method, s: &MoeShape) -> u64 {
    let t = s.t as u64;
    let d = s.d as u64;
    let n = s.n as u64;
    let k = s.k as u64;
    let x = BF16 * t * d;
    let h = BF16 * t * k * 2 * n;
    let y = BF16 * t * k * d;
    let xe = BF16 * t * k * d; // gathered/scattered X_e copies
    let a = BF16 * t * k * n;
    let meta = routing_metadata_bytes(s);
    match m {
        Method::SonicMoE => x + h + meta,
        // ScatterMoE caches X, H, A and Y (dS = <dO, Y>).
        Method::ScatterMoE => x + h + a + y + meta,
        // MoMoE additionally keeps the gathered X_e from its fused fwd.
        Method::MoMoE => x + h + a + y + xe + meta,
        // MegaBlocks: gathered+padded X_e, H, A, Y + block-sparse topology.
        Method::MegaBlocks => {
            let pad = BF16 * (s.e as u64) * 64 * d; // pad to 64-row blocks
            x + xe + pad + h + a + y + meta
        }
        // Megatron GroupedMLP (memory-efficient patch): SonicMoE path but
        // with materialized gathered inputs for its separate gather.
        Method::Megatron => x + xe + h + meta,
        // DeepGEMM++: X, gathered X_e, H (minimum for an external
        // contiguous grouped GEMM; Figure 10 caption).
        Method::DeepGemmPlus => x + xe + h + meta,
    }
}

/// Peak per-layer usage during backward: cached bytes + the largest set
/// of simultaneously-live temporaries. SonicMoE's recycled Y/dX~ buffer
/// (footnote 6) is charged once since it is reused across layers.
pub fn peak_activation_bytes(m: Method, s: &MoeShape) -> u64 {
    let t = s.t as u64;
    let d = s.d as u64;
    let n = s.n as u64;
    let k = s.k as u64;
    let y_like = BF16 * t * k * d;
    let dh = BF16 * t * k * 2 * n;
    let cached = cached_activation_bytes(m, s);
    match m {
        // dH kernel epilogue writes dH + A' while the recycled Y-sized
        // buffer holds dX~: peak = cache + dH + A' + dX~/L (amortized;
        // we charge the full buffer to be conservative).
        Method::SonicMoE => cached + dh + BF16 * t * k * n + y_like,
        // ScatterMoE / MoMoE also materialize dY and gathered dO.
        Method::ScatterMoE => cached + dh + 2 * y_like,
        Method::MoMoE => cached + dh + 2 * y_like,
        Method::MegaBlocks => cached + dh + 2 * y_like,
        Method::Megatron => cached + dh + y_like,
        Method::DeepGemmPlus => cached + dh + y_like,
    }
}

/// GiB helper for table printing.
pub fn gib(bytes: u64) -> f64 {
    bytes as f64 / (1u64 << 30) as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::simulator::configs::MoeShape;

    fn shape(t: usize, d: usize, n: usize, e: usize, k: usize) -> MoeShape {
        MoeShape { t, d, n, e, k }
    }

    #[test]
    fn sonic_matches_paper_formula() {
        let s = shape(24576, 1536, 256, 128, 8);
        let got = cached_activation_bytes(Method::SonicMoE, &s);
        let want = 2 * (s.t * s.d + 2 * s.t * s.k * s.n) as u64;
        assert_eq!(got - routing_metadata_bytes(&s), want);
    }

    #[test]
    fn sonic_constant_in_granularity_scatter_linear() {
        // iso-FLOPs sweep: n*K constant (7B config family of Table 9a)
        let sweep = [(256usize, 8usize, 128usize), (512, 4, 64), (1024, 2, 32)];
        let sonic: Vec<u64> = sweep
            .iter()
            .map(|&(n, k, e)| cached_activation_bytes(Method::SonicMoE, &shape(24576, 1536, n, e, k)))
            .collect();
        let scatter: Vec<u64> = sweep
            .iter()
            .map(|&(n, k, e)| cached_activation_bytes(Method::ScatterMoE, &shape(24576, 1536, n, e, k)))
            .collect();
        // constant up to the (tiny) K-dependent routing metadata
        let ratio = *sonic.iter().max().unwrap() as f64 / *sonic.iter().min().unwrap() as f64;
        assert!(ratio < 1.02, "sonic cache varies {ratio:.4}x across granularity");
        // ScatterMoE grows with K (granularity) via the Y/A caches
        assert!(scatter[0] > scatter[2]);
    }

    #[test]
    fn paper_45_percent_saving_on_7b() {
        // Figure 10 reports a 45% saving vs ScatterMoE for 7B n=256. Our
        // accounting counts only the MoE-layer tensors (the paper's
        // measured per-layer peak includes allocator slack and transient
        // buffers that dilute the ratio), so the isolated saving is
        // larger; see EXPERIMENTS.md. Assert direction + a sane band.
        let s = shape(24576, 1536, 256, 128, 8);
        let sonic = cached_activation_bytes(Method::SonicMoE, &s) as f64;
        let scatter = cached_activation_bytes(Method::ScatterMoE, &s) as f64;
        let saving = 1.0 - sonic / scatter;
        assert!(saving > 0.40 && saving < 0.80, "saving = {saving:.2}");
        // on the *peak* metric (closer to what Figure 10 measures) the
        // gap is tighter
        let sp = peak_activation_bytes(Method::SonicMoE, &s) as f64;
        let cp = peak_activation_bytes(Method::ScatterMoE, &s) as f64;
        let peak_saving = 1.0 - sp / cp;
        assert!(peak_saving > 0.3 && peak_saving < 0.7, "peak saving {peak_saving:.2}");
    }

    #[test]
    fn ordering_matches_figure_10() {
        let s = shape(32768, 4096, 512, 256, 16);
        let b: Vec<u64> = Method::ALL
            .iter()
            .map(|&m| cached_activation_bytes(m, &s))
            .collect();
        // SonicMoE < Megatron/DeepGEMM++ < ScatterMoE < MoMoE < MegaBlocks
        assert!(b[0] < b[4] && b[4] <= b[5]);
        assert!(b[5] < b[1] && b[1] < b[2] && b[2] < b[3]);
    }

    #[test]
    fn megablocks_unsupported_for_small_n() {
        assert!(!Method::MegaBlocks.supports(&shape(1024, 768, 64, 8, 2)));
        assert!(Method::MegaBlocks.supports(&shape(1024, 768, 256, 8, 2)));
    }

    #[test]
    fn peak_exceeds_cached() {
        let s = shape(24576, 1536, 256, 128, 8);
        for m in Method::ALL {
            assert!(peak_activation_bytes(m, &s) > cached_activation_bytes(m, &s));
        }
    }
}
