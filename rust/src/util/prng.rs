//! Deterministic PRNG: xoshiro256++ (the `rand` crate is unavailable
//! offline). Used by the data pipeline, routing workload generators and
//! the property-test runner — never for anything cryptographic.

/// xoshiro256++ by Blackman & Vigna (public domain reference algorithm).
#[derive(Debug, Clone)]
pub struct Prng {
    s: [u64; 4],
}

impl Prng {
    /// Seed via SplitMix64 so any u64 (including 0) gives a good state.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Prng { s: [next(), next(), next(), next()] }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[0]
            .wrapping_add(self.s[3])
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [0, 1).
    #[inline]
    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform integer in [0, n) without modulo bias (Lemire's method).
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0);
        loop {
            let x = self.next_u64();
            let m = (x as u128).wrapping_mul(n as u128);
            let lo = m as u64;
            if lo >= n || lo >= (n.wrapping_neg() % n) {
                return (m >> 64) as u64;
            }
        }
    }

    /// Uniform in [lo, hi] inclusive.
    pub fn range(&mut self, lo: i64, hi: i64) -> i64 {
        assert!(lo <= hi);
        lo + self.below((hi - lo + 1) as u64) as i64
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = (1.0 - self.f64()).max(1e-300);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Bernoulli(p).
    pub fn bernoulli(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Sample an index from unnormalized weights.
    pub fn categorical(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        let mut x = self.f64() * total;
        for (i, w) in weights.iter().enumerate() {
            x -= w;
            if x <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Prng::new(42);
        let mut b = Prng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        assert_ne!(Prng::new(1).next_u64(), Prng::new(2).next_u64());
    }

    #[test]
    fn uniform_mean() {
        let mut p = Prng::new(0);
        let n = 20_000;
        let mean: f64 = (0..n).map(|_| p.f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn below_bounds_and_coverage() {
        let mut p = Prng::new(3);
        let mut seen = [false; 7];
        for _ in 0..1000 {
            let v = p.below(7) as usize;
            assert!(v < 7);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_moments() {
        let mut p = Prng::new(9);
        let n = 20_000;
        let xs: Vec<f64> = (0..n).map(|_| p.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.03, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn categorical_weighted() {
        let mut p = Prng::new(4);
        let w = [1.0, 0.0, 3.0];
        let mut counts = [0usize; 3];
        for _ in 0..4000 {
            counts[p.categorical(&w)] += 1;
        }
        assert_eq!(counts[1], 0);
        assert!(counts[2] > 2 * counts[0]);
    }

    #[test]
    fn shuffle_permutes() {
        let mut p = Prng::new(5);
        let mut v: Vec<u32> = (0..50).collect();
        p.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
        assert_ne!(v, (0..50).collect::<Vec<_>>());
    }
}
