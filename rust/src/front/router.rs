//! Route choice over the replica set: model filter, health filter,
//! lowest peak-EWMA score wins.
//!
//! Pure selection logic (no sockets) so the preference ladder is unit
//! testable: a request prefers an untried `Healthy` replica, then an
//! untried `Degraded` one, then falls back to already-tried replicas
//! in the same order (a single-replica front can still retry on a
//! fresh connection). `Dead` replicas are never chosen — the breaker
//! owns bringing them back.

use std::sync::Arc;

use super::replica::{Replica, ReplicaState};

/// Model compatibility: an untagged request matches any replica, an
/// untagged replica serves any model, otherwise the tags must agree.
pub fn model_matches(request: &str, replica: &str) -> bool {
    request.is_empty() || replica.is_empty() || request == replica
}

/// Pick the replica to route to: lowest
/// [`route_score`](Replica::route_score) among eligible candidates,
/// ties broken by the lower index for determinism. `tried` lists
/// replica indices already attempted for this request — they are
/// deprioritized, not excluded, so retries prefer a different replica
/// but a lone survivor still gets a second chance. Returns `None` only
/// when every model-matching replica is `Dead` (or none matches).
pub fn choose(replicas: &[Arc<Replica>], model: &str, tried: &[usize]) -> Option<usize> {
    let pick = |allow_degraded: bool, allow_tried: bool| {
        replicas
            .iter()
            .enumerate()
            .filter(|(_, r)| model_matches(model, &r.spec.model))
            .filter(|(_, r)| match r.state() {
                ReplicaState::Healthy => true,
                ReplicaState::Degraded => allow_degraded,
                ReplicaState::Dead => false,
            })
            .filter(|(i, _)| allow_tried || !tried.contains(i))
            .min_by(|(ia, a), (ib, b)| {
                a.route_score().partial_cmp(&b.route_score()).unwrap().then(ia.cmp(ib))
            })
            .map(|(i, _)| i)
    };
    // preference ladder: (healthy, untried) -> (degraded, untried)
    // -> (healthy, tried) -> (degraded, tried)
    for (allow_degraded, allow_tried) in [(false, false), (true, false), (false, true), (true, true)]
    {
        if let Some(i) = pick(allow_degraded, allow_tried) {
            return Some(i);
        }
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::front::replica::ReplicaSpec;

    fn pool(specs: &[&str]) -> Vec<Arc<Replica>> {
        specs
            .iter()
            .enumerate()
            .map(|(i, s)| Arc::new(Replica::new(ReplicaSpec::parse(s).unwrap(), i, 2)))
            .collect()
    }

    #[test]
    fn model_matching_rules() {
        assert!(model_matches("", ""));
        assert!(model_matches("", "m"));
        assert!(model_matches("m", ""));
        assert!(model_matches("m", "m"));
        assert!(!model_matches("m", "other"));
    }

    #[test]
    fn lowest_score_wins_and_ties_break_low_index() {
        let rs = pool(&["h:1", "h:2", "h:3"]);
        // no samples yet: all score 0, lowest index wins
        assert_eq!(choose(&rs, "", &[]), Some(0));
        rs[0].report_success(30.0);
        rs[1].report_success(10.0);
        rs[2].report_success(40.0);
        assert_eq!(choose(&rs, "", &[]), Some(1));
        // concurrency shifts the score: 10 * (2+1) = 30 ties replica 0,
        // which wins the tie on index
        rs[1].in_flight.store(2, std::sync::atomic::Ordering::Relaxed);
        assert_eq!(choose(&rs, "", &[]), Some(0));
    }

    #[test]
    fn model_filter_and_dead_exclusion() {
        let rs = pool(&["h:1=a", "h:2=b", "h:3"]);
        assert_eq!(choose(&rs, "b", &[]), Some(1), "tag match");
        rs[1].force_kill();
        assert_eq!(choose(&rs, "b", &[]), Some(2), "untagged replica serves any model");
        rs[2].force_kill();
        assert_eq!(choose(&rs, "b", &[]), None, "every b-capable replica dead");
        assert_eq!(choose(&rs, "a", &[]), Some(0), "other models unaffected");
    }

    #[test]
    fn tried_is_a_preference_not_an_exclusion() {
        let rs = pool(&["h:1", "h:2"]);
        rs[0].report_success(1.0);
        rs[1].report_success(50.0);
        // retry prefers the other (slower) replica over the tried one
        assert_eq!(choose(&rs, "", &[0]), Some(1));
        // with everything tried, the best replica is chosen again
        assert_eq!(choose(&rs, "", &[0, 1]), Some(0));
        // a lone survivor is retried rather than refused
        rs[1].force_kill();
        assert_eq!(choose(&rs, "", &[0]), Some(0));
    }

    #[test]
    fn degraded_is_last_resort_before_shedding() {
        let rs = pool(&["h:1", "h:2"]);
        rs[0].report_success(1.0);
        rs[1].report_failure(3);
        assert_eq!(choose(&rs, "", &[]), Some(0), "healthy beats degraded regardless of score");
        assert_eq!(choose(&rs, "", &[0]), Some(1), "degraded beats re-trying");
        rs[0].force_kill();
        assert_eq!(choose(&rs, "", &[]), Some(1), "degraded beats shedding");
    }
}
