//! PJRT execution backend (cargo feature `pjrt`): load AOT-compiled
//! HLO-text artifacts through the `xla` crate and execute them.
//!
//! This is the original accelerator path (`PjRtClient::cpu()` ->
//! `HloModuleProto::from_text_file` -> `compile` -> `execute`), now an
//! implementation of [`Backend`]. It is the **only** module in the crate
//! that touches `xla::` types; everything above speaks [`Value`].

use std::path::Path;

use anyhow::{anyhow, bail, Result};

use crate::runtime::backend::{Backend, Executable, Value};
use crate::runtime::manifest::{ArtifactSpec, ConfigManifest};
use crate::util::tensor::Tensor;

/// The PJRT backend: one client, executables compiled per artifact.
pub struct PjrtBackend {
    client: xla::PjRtClient,
}

impl PjrtBackend {
    pub fn new() -> Result<PjrtBackend> {
        let client = xla::PjRtClient::cpu()?;
        log::info!(
            "PJRT client up: platform={} devices={}",
            client.platform_name(),
            client.device_count()
        );
        Ok(PjrtBackend { client })
    }
}

fn to_literal(v: &Value) -> Result<xla::Literal> {
    let dims: Vec<i64> = v.shape().iter().map(|&d| d as i64).collect();
    Ok(match v {
        Value::F32(t) => xla::Literal::vec1(&t.data).reshape(&dims)?,
        Value::I32 { data, .. } => xla::Literal::vec1(data).reshape(&dims)?,
    })
}

/// All artifact outputs are f32 arrays (the manifest contract), so the
/// readback side only needs the f32 arm.
fn from_literal(lit: &xla::Literal) -> Result<Value> {
    let shape = lit.array_shape()?;
    let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
    let data = lit.to_vec::<f32>()?;
    Ok(Value::F32(Tensor::from_vec(&dims, data)?))
}

struct PjrtExec {
    name: String,
    n_outputs: usize,
    exe: xla::PjRtLoadedExecutable,
}

impl Executable for PjrtExec {
    /// Inputs are staged through rust-owned `PjRtBuffer`s and run with
    /// `execute_b`: the crate's literal-taking `execute` leaks every
    /// input buffer per call in its C++ shim (`buffer.release()` without
    /// a matching free), which cost ~86 MB/step on the large config
    /// before this workaround (§Perf).
    fn execute(&self, inputs: &[Value]) -> Result<Vec<Value>> {
        let lits: Vec<xla::Literal> = inputs.iter().map(to_literal).collect::<Result<_>>()?;
        let client = self.exe.client();
        let in_bufs: Vec<xla::PjRtBuffer> = lits
            .iter()
            .map(|l| client.buffer_from_host_literal(None, l))
            .collect::<std::result::Result<_, _>>()?;
        let bufs = self.exe.execute_b::<xla::PjRtBuffer>(&in_bufs)?;
        drop(in_bufs); // rust-owned: freed here, unlike the shim's path
        let lit = bufs[0][0].to_literal_sync()?;
        let outs = lit.to_tuple()?;
        if outs.len() != self.n_outputs {
            bail!(
                "artifact {}: manifest declares {} outputs, HLO returned {}",
                self.name,
                self.n_outputs,
                outs.len()
            );
        }
        outs.iter().map(from_literal).collect()
    }
}

impl Backend for PjrtBackend {
    fn name(&self) -> &'static str {
        "pjrt"
    }

    fn compile(
        &self,
        dir: &Path,
        name: &str,
        spec: &ArtifactSpec,
        _manifest: &ConfigManifest,
    ) -> Result<Box<dyn Executable>> {
        if spec.file.is_empty() {
            bail!(
                "artifact {name:?} has no HLO file (built-in native config?) — \
                 run `make artifacts` to export HLO for the PJRT backend"
            );
        }
        let path = dir.join(&spec.file);
        let t0 = std::time::Instant::now();
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("bad path"))?,
        )?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp)?;
        log::info!("compiled {name} in {:.2}s", t0.elapsed().as_secs_f64());
        Ok(Box::new(PjrtExec {
            name: name.to_string(),
            n_outputs: spec.outputs.len(),
            exe,
        }))
    }
}
