//! Open- and closed-loop load generator driving an in-process gateway
//! over real TCP loopback connections.
//!
//! Closed loop (`rate == 0`): each client keeps exactly one request in
//! flight — throughput is latency-bound. Open loop (`rate > 0`):
//! clients send at a fixed aggregate rate regardless of completions —
//! the regime where batch-formation policy decides how much padding
//! the executed shapes carry, which is the serving analogue of the
//! paper's tile-waste experiments. Generation mode (`gen_tokens > 0`):
//! closed-loop `generate` requests whose streamed `token`/`done`
//! frames measure time-to-first-token and the continuous batcher's
//! per-step decode padding.
//!
//! Trace replay ([`run_trace`]): issues a [`Trace`]'s events on their
//! recorded arrival schedule (optionally time-compressed by a `speed`
//! factor), one connection per request, mixing `score` / `generate` /
//! speculative tenants — the production-shaped counterpart to the
//! uniform loops above, and the engine behind the saturation bench and
//! the trace-determinism tests.
//!
//! Front-tier mode (`front_replicas > 0` on either config): instead of
//! one direct gateway, the run starts N identical gateway replicas
//! behind an in-process [`crate::front::Front`] and points every
//! client at the front, so routing, failover and shedding behaviour
//! can be measured with the same reports. Gateway-side counters are
//! merged across the replicas (sums for counters and rates, weighted
//! means for padding fractions).
//!
//! Closed-loop and generation clients honor the `retry_after_ms`
//! backoff hint riding on shedding refusals (`queue_full`,
//! `no_healthy_replica`): the request is retried after a jittered
//! sleep of the hinted backoff, a bounded number of times, before it
//! counts as shed/failed. The open-loop and trace clients never back
//! off — fixed offered load is their point.

use std::collections::BTreeMap;
use std::collections::HashMap;
use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpStream};
use std::sync::{Arc, Mutex};
use std::thread;
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use crate::front::{Front, FrontConfig, ReplicaSpec};
use crate::util::json::Json;
use crate::util::prng::Prng;
use crate::util::stats::percentile;

use super::protocol::{ClientMsg, GenOpts, ServerMsg};
use super::trace::{ScheduledReq, Trace, TraceMode};
use super::{Gateway, GatewayConfig};

/// Load shape.
#[derive(Debug, Clone)]
pub struct LoadgenConfig {
    /// Total score requests across all clients.
    pub requests: usize,
    /// Concurrent client connections.
    pub clients: usize,
    /// Aggregate offered load in requests/s; 0 = closed loop.
    pub rate: f64,
    /// Synthetic token sequences are drawn around this length
    /// (0 = the served model's sequence length).
    pub seq_hint: usize,
    pub seed: u64,
    /// Generation mode: when > 0, every request is a closed-loop
    /// `generate` for this many new tokens (streams consumed frame by
    /// frame) instead of a `score`.
    pub gen_tokens: usize,
    /// Speculative decoding in generation mode: draft tokens per verify
    /// step (0 = plain decode; requires the gateway to carry a draft).
    pub spec_k: usize,
    /// Front-tier mode: run this many identical gateway replicas behind
    /// an in-process front and drive the front (0 = one direct gateway).
    pub front_replicas: usize,
}

impl Default for LoadgenConfig {
    fn default() -> Self {
        LoadgenConfig {
            requests: 64,
            clients: 3,
            rate: 0.0,
            seq_hint: 32,
            seed: 0,
            gen_tokens: 0,
            spec_k: 0,
            front_replicas: 0,
        }
    }
}

/// One loadgen run: client-side latency percentiles plus the gateway's
/// own accounting (padding, throughput, shed) pulled via `stats`.
#[derive(Debug, Clone)]
pub struct LoadgenReport {
    pub policy: String,
    pub mode: String,
    pub offered_rps: f64,
    pub sent: usize,
    pub ok: usize,
    pub shed: usize,
    pub failed: usize,
    pub wall_s: f64,
    pub achieved_rps: f64,
    pub p50_ms: f64,
    pub p95_ms: f64,
    pub p99_ms: f64,
    pub padding_frac: f64,
    pub tokens_per_s: f64,
    pub batches: u64,
    /// Generation-mode extras (0 in score mode): client-side
    /// time-to-first-token percentiles, generated-token throughput and
    /// the scheduler's per-step decode padding.
    pub ttft_p50_ms: f64,
    pub ttft_p99_ms: f64,
    pub gen_tokens: u64,
    pub decode_padding_frac: f64,
    pub decode_tokens_per_s: f64,
    /// Speculation extras (0 with spec off): the requested k, the
    /// gateway's aggregate acceptance rate and emitted-tokens-per-
    /// verify-round, and client-side per-request tokens-per-step
    /// percentiles (generated tokens / verify rounds per stream).
    pub spec_k: usize,
    pub accept_rate: f64,
    pub accepted_per_step: f64,
    pub tokens_per_step_p50: f64,
    pub tokens_per_step_p99: f64,
}

impl LoadgenReport {
    /// One-line JSON record (the bench trajectory datapoint).
    pub fn to_json(&self) -> Json {
        let mut m = BTreeMap::new();
        m.insert("policy".to_string(), Json::Str(self.policy.clone()));
        m.insert("mode".to_string(), Json::Str(self.mode.clone()));
        let mut num = |k: &str, v: f64| {
            m.insert(k.to_string(), Json::Num(v));
        };
        num("offered_rps", self.offered_rps);
        num("sent", self.sent as f64);
        num("ok", self.ok as f64);
        num("shed", self.shed as f64);
        num("failed", self.failed as f64);
        num("wall_s", self.wall_s);
        num("achieved_rps", self.achieved_rps);
        num("p50_ms", self.p50_ms);
        num("p95_ms", self.p95_ms);
        num("p99_ms", self.p99_ms);
        num("padding_frac", self.padding_frac);
        num("tokens_per_s", self.tokens_per_s);
        num("batches", self.batches as f64);
        num("ttft_p50_ms", self.ttft_p50_ms);
        num("ttft_p99_ms", self.ttft_p99_ms);
        num("gen_tokens", self.gen_tokens as f64);
        num("decode_padding_frac", self.decode_padding_frac);
        num("decode_tokens_per_s", self.decode_tokens_per_s);
        num("spec_k", self.spec_k as f64);
        num("accept_rate", self.accept_rate);
        num("accepted_per_step", self.accepted_per_step);
        num("tokens_per_step_p50", self.tokens_per_step_p50);
        num("tokens_per_step_p99", self.tokens_per_step_p99);
        Json::Obj(m)
    }
}

#[derive(Default)]
struct ClientResult {
    lat_ms: Vec<f64>,
    /// Time to first `token` frame per generate request.
    ttft_ms: Vec<f64>,
    /// Generated tokens received across all streams.
    tokens: u64,
    /// Per-request tokens per verify round (speculative streams only).
    tokens_per_step: Vec<f64>,
    /// Aggregate draft bookkeeping from `done` frames.
    proposed: u64,
    accepted: u64,
    shed: usize,
    failed: usize,
    sent: usize,
}

/// The serving stack under load: one direct gateway, or N gateway
/// replicas behind an in-process front tier.
struct Stack {
    gws: Vec<Gateway>,
    front: Option<Front>,
    /// Address the clients dial (front when present, else the gateway).
    addr: SocketAddr,
}

impl Stack {
    /// Start `front_replicas.max(1)` gateways on ephemeral loopback
    /// ports, plus a front over them when `front_replicas > 0`.
    fn start(gw_cfg: GatewayConfig, front_replicas: usize) -> Result<Stack> {
        let mut gws = Vec::with_capacity(front_replicas.max(1));
        for i in 0..front_replicas.max(1) {
            let mut cfg = gw_cfg.clone();
            if i > 0 {
                // replicas would clobber each other's capture file
                cfg.capture_trace = None;
            }
            let gw = Gateway::start(cfg)?;
            gws.push(gw);
        }
        let front = if front_replicas > 0 {
            let cfg = FrontConfig {
                replicas: gws
                    .iter()
                    .map(|g| ReplicaSpec { addr: g.local_addr().to_string(), model: String::new() })
                    .collect(),
                // loadgen runs are short: converge health fast
                probe_interval_ms: 50,
                ..FrontConfig::default()
            };
            match Front::start(cfg) {
                Ok(f) => Some(f),
                Err(e) => {
                    for g in gws {
                        g.shutdown();
                        g.join();
                    }
                    return Err(e);
                }
            }
        } else {
            None
        };
        let addr = match &front {
            Some(f) => f.local_addr(),
            None => gws[0].local_addr(),
        };
        Ok(Stack { gws, front, addr })
    }

    /// Model sequence length (identical across replicas).
    fn seq(&self) -> usize {
        self.gws[0].seq()
    }

    /// Graceful control-plane teardown: pull and merge every replica's
    /// `stats`, then wire-shutdown the front (when present) and every
    /// replica, and join them all. Used on the success path.
    fn stats_and_shutdown(self) -> Result<Json> {
        let control = (|| -> Result<Json> {
            let mut per = Vec::new();
            for g in &self.gws {
                match control_request(g.local_addr(), &ClientMsg::Stats)? {
                    ServerMsg::Stats(j) => per.push(j),
                    other => bail!("expected stats reply, got {other:?}"),
                }
            }
            if self.front.is_some() {
                match control_request(self.addr, &ClientMsg::Shutdown)? {
                    ServerMsg::Ok { .. } => {}
                    other => bail!("expected ok to front shutdown, got {other:?}"),
                }
            }
            for g in &self.gws {
                match control_request(g.local_addr(), &ClientMsg::Shutdown)? {
                    ServerMsg::Ok { .. } => {}
                    other => bail!("expected ok to shutdown, got {other:?}"),
                }
            }
            Ok(merge_stats(per))
        })();
        match control {
            Ok(stats) => {
                if let Some(f) = self.front {
                    f.join();
                }
                for g in self.gws {
                    g.join();
                }
                Ok(stats)
            }
            Err(e) => {
                self.drain();
                Err(e)
            }
        }
    }

    /// Unconditional teardown (error paths): never leak the stack.
    fn drain(self) {
        if let Some(f) = self.front {
            f.shutdown();
            f.join();
        }
        for g in self.gws {
            g.shutdown();
            g.join();
        }
    }
}

/// Merge per-replica gateway stats into one report-shaped object:
/// counters and rates sum, padding fractions average weighted by the
/// batch/step counts that produced them. A single replica passes
/// through untouched.
fn merge_stats(mut per: Vec<Json>) -> Json {
    if per.len() == 1 {
        return per.pop().unwrap();
    }
    let getf = |j: &Json, k: &str| j.get(k).and_then(|v| v.as_f64()).unwrap_or(0.0);
    let sum = |k: &str, per: &[Json]| per.iter().map(|j| getf(j, k)).sum::<f64>();
    let wmean = |k: &str, w: &str, per: &[Json]| {
        let tot: f64 = per.iter().map(|j| getf(j, w)).sum();
        if tot > 0.0 {
            per.iter().map(|j| getf(j, k) * getf(j, w)).sum::<f64>() / tot
        } else {
            per.iter().map(|j| getf(j, k)).sum::<f64>() / per.len().max(1) as f64
        }
    };
    let mut m = BTreeMap::new();
    let mut num = |k: &str, v: f64| {
        m.insert(k.to_string(), Json::Num(v));
    };
    for k in [
        "requests",
        "responses",
        "batches",
        "shed",
        "failed",
        "total_tokens",
        "tokens_per_s",
        "gen_requests",
        "gen_done",
        "gen_tokens",
        "decode_steps",
        "decode_tokens_per_s",
        "spec_rounds",
        "spec_proposed",
        "spec_accepted",
    ] {
        num(k, sum(k, &per));
    }
    num("padding_frac", wmean("padding_frac", "batches", &per));
    num("decode_padding_frac", wmean("decode_padding_frac", "decode_steps", &per));
    num("accepted_per_step", wmean("accepted_per_step", "spec_rounds", &per));
    Json::Obj(m)
}

/// End-of-run Chrome-trace dump: the in-process stack shares the one
/// global flight recorder, so a direct snapshot sees every span the
/// run produced without a `trace_dump` round-trip.
fn dump_trace(path: Option<&str>) -> Result<()> {
    if let Some(path) = path {
        let snap = crate::obs::recorder::snapshot();
        let n = crate::obs::export::write_chrome_trace(path, &snap)?;
        log::info!("loadgen: wrote {n} trace events to {path}");
    }
    Ok(())
}

/// Start a gateway on an ephemeral loopback port (or, in front-tier
/// mode, N replicas behind a front), drive it with the configured
/// load, query `stats`, shut it down cleanly and return the merged
/// report.
pub fn run_inprocess(gw_cfg: GatewayConfig, lg: LoadgenConfig) -> Result<LoadgenReport> {
    let policy_name = gw_cfg.policy.name().to_string();
    let trace_out = gw_cfg.trace_out.clone();
    let stack = Stack::start(gw_cfg, lg.front_replicas)?;
    let addr = stack.addr;
    let resolved_seq_hint = if lg.seq_hint == 0 { stack.seq() } else { lg.seq_hint };

    let t0 = Instant::now();
    let mut handles = Vec::new();
    let per = lg.requests / lg.clients.max(1);
    let extra = lg.requests - per * lg.clients.max(1);
    let per_client_rate = if lg.rate > 0.0 { lg.rate / lg.clients.max(1) as f64 } else { 0.0 };
    let mut next_id = 0u64;
    for c in 0..lg.clients.max(1) {
        let n = per + usize::from(c < extra);
        if n == 0 {
            continue;
        }
        let ids: Vec<u64> = (next_id..next_id + n as u64).collect();
        next_id += n as u64;
        let seed = lg.seed.wrapping_add(c as u64).wrapping_mul(0x9E37_79B9);
        let seq_hint = resolved_seq_hint;
        let gen_tokens = lg.gen_tokens;
        let spec_k = lg.spec_k;
        handles.push(thread::spawn(move || {
            client_thread(addr, ids, seq_hint, seed, per_client_rate, gen_tokens, spec_k)
        }));
    }
    let mut all = ClientResult::default();
    let mut client_err: Option<anyhow::Error> = None;
    for h in handles {
        match h.join() {
            Ok(Ok(r)) => {
                all.lat_ms.extend(r.lat_ms);
                all.ttft_ms.extend(r.ttft_ms);
                all.tokens += r.tokens;
                all.tokens_per_step.extend(r.tokens_per_step);
                all.proposed += r.proposed;
                all.accepted += r.accepted;
                all.shed += r.shed;
                all.failed += r.failed;
                all.sent += r.sent;
            }
            Ok(Err(e)) => client_err = Some(e.context("loadgen client failed")),
            Err(_) => client_err = Some(anyhow::anyhow!("loadgen client panicked")),
        }
    }
    if let Some(e) = client_err {
        // never leak the stack: drain it before surfacing the error
        stack.drain();
        return Err(e);
    }
    let wall_s = t0.elapsed().as_secs_f64();

    // control plane: per-replica stats snapshots merged, then graceful
    // shutdown of the front and every replica
    let stats = stack.stats_and_shutdown()?;
    dump_trace(trace_out.as_deref())?;

    let mut lat = all.lat_ms.clone();
    lat.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let pct = |p: f64| if lat.is_empty() { 0.0 } else { percentile(&lat, p) };
    let mut ttft = all.ttft_ms.clone();
    ttft.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let tpct = |p: f64| if ttft.is_empty() { 0.0 } else { percentile(&ttft, p) };
    let mut tps = all.tokens_per_step.clone();
    tps.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let tps_pct = |p: f64| if tps.is_empty() { 0.0 } else { percentile(&tps, p) };
    let getf = |k: &str| stats.get(k).and_then(|v| v.as_f64()).unwrap_or(0.0);
    let mode = if lg.gen_tokens > 0 {
        "generate".to_string()
    } else if lg.rate > 0.0 {
        "open".to_string()
    } else {
        "closed".to_string()
    };
    Ok(LoadgenReport {
        policy: policy_name,
        mode,
        offered_rps: lg.rate,
        sent: all.sent,
        ok: all.lat_ms.len(),
        shed: all.shed,
        failed: all.failed,
        wall_s,
        achieved_rps: if wall_s > 0.0 { all.lat_ms.len() as f64 / wall_s } else { 0.0 },
        p50_ms: pct(50.0),
        p95_ms: pct(95.0),
        p99_ms: pct(99.0),
        padding_frac: getf("padding_frac"),
        tokens_per_s: getf("tokens_per_s"),
        batches: getf("batches") as u64,
        ttft_p50_ms: tpct(50.0),
        ttft_p99_ms: tpct(99.0),
        gen_tokens: all.tokens,
        decode_padding_frac: getf("decode_padding_frac"),
        decode_tokens_per_s: getf("decode_tokens_per_s"),
        spec_k: lg.spec_k,
        accept_rate: if all.proposed == 0 {
            0.0
        } else {
            all.accepted as f64 / all.proposed as f64
        },
        accepted_per_step: getf("accepted_per_step"),
        tokens_per_step_p50: tps_pct(50.0),
        tokens_per_step_p99: tps_pct(99.0),
    })
}

/// One request/reply exchange on a fresh control connection.
pub fn control_request(addr: SocketAddr, msg: &ClientMsg) -> Result<ServerMsg> {
    let mut stream = TcpStream::connect(addr).context("connecting to gateway")?;
    stream.set_nodelay(true).ok();
    stream
        .set_read_timeout(Some(Duration::from_secs(30)))
        .context("setting control timeout")?;
    let mut reader = BufReader::new(stream.try_clone().context("cloning control stream")?);
    stream.write_all(msg.encode().as_bytes())?;
    stream.write_all(b"\n")?;
    stream.flush()?;
    let mut line = String::new();
    let n = reader.read_line(&mut line)?;
    if n == 0 {
        bail!("gateway closed the control connection");
    }
    ServerMsg::parse(&line)
}

/// Total attempts per logical request in the closed-loop clients when
/// shedding refusals carry a `retry_after_ms` hint.
const SHED_ATTEMPTS: usize = 3;

/// Shedding refusals worth retrying when they carry a backoff hint.
fn is_shed_code(code: &str) -> bool {
    code == "queue_full" || code == "no_healthy_replica"
}

/// Honor a refusal's `retry_after_ms` hint: sleep 50–100% of the hint
/// (jittered so retried clients do not re-arrive in lockstep).
fn backoff_sleep(hint_ms: u64, rng: &mut Prng) {
    let ms = (hint_ms as f64 * (0.5 + 0.5 * rng.f64())) as u64;
    thread::sleep(Duration::from_millis(ms.clamp(1, 2000)));
}

fn synth_tokens(rng: &mut Prng, seq_hint: usize) -> Vec<i32> {
    let lo = (seq_hint / 2).max(1) as i64;
    let hi = (seq_hint * 2).max(2) as i64;
    let len = rng.range(lo, hi) as usize;
    (0..len).map(|_| rng.below(1 << 15) as i32).collect()
}

fn client_thread(
    addr: SocketAddr,
    ids: Vec<u64>,
    seq_hint: usize,
    seed: u64,
    rate: f64,
    gen_tokens: usize,
    spec_k: usize,
) -> Result<ClientResult> {
    if gen_tokens > 0 {
        generate_client(addr, ids, seq_hint, seed, gen_tokens, spec_k)
    } else if rate > 0.0 {
        open_loop_client(addr, ids, seq_hint, seed, rate)
    } else {
        closed_loop_client(addr, ids, seq_hint, seed)
    }
}

/// Closed-loop generation: one `generate` in flight per client, the
/// stream consumed frame by frame (`token`* then `done`). Measures
/// time-to-first-token and full-stream latency per request.
fn generate_client(
    addr: SocketAddr,
    ids: Vec<u64>,
    seq_hint: usize,
    seed: u64,
    gen_tokens: usize,
    spec_k: usize,
) -> Result<ClientResult> {
    let mut stream = TcpStream::connect(addr).context("loadgen connect")?;
    stream.set_nodelay(true).ok();
    stream.set_read_timeout(Some(Duration::from_secs(60)))?;
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut rng = Prng::new(seed);
    let mut out = ClientResult::default();
    for id in ids {
        let tokens = synth_tokens(&mut rng, seq_hint);
        let opts = super::protocol::GenOpts { spec_k, ..Default::default() };
        let line = ClientMsg::Generate { id, tokens, max_new: gen_tokens, opts }.encode();
        out.sent += 1;
        let mut attempt = 0usize;
        'attempts: loop {
            let t0 = Instant::now();
            stream.write_all(line.as_bytes())?;
            stream.write_all(b"\n")?;
            stream.flush()?;
            let mut first_seen = false;
            loop {
                let mut resp = String::new();
                let n = reader.read_line(&mut resp)?;
                if n == 0 {
                    bail!("gateway closed the connection mid-stream");
                }
                match ServerMsg::parse(&resp)? {
                    ServerMsg::Token { id: rid, .. } => {
                        if rid != id {
                            bail!("token frame for {rid}, expected {id}");
                        }
                        if !first_seen {
                            first_seen = true;
                            out.ttft_ms.push(t0.elapsed().as_secs_f64() * 1e3);
                        }
                        out.tokens += 1;
                    }
                    ServerMsg::Done { id: rid, rounds, proposed, accepted, .. } => {
                        if rid != id {
                            bail!("done frame for {rid}, expected {id}");
                        }
                        out.lat_ms.push(t0.elapsed().as_secs_f64() * 1e3);
                        out.proposed += proposed;
                        out.accepted += accepted;
                        if rounds > 0 {
                            // every counted verify round emits its accepted
                            // prefix plus the target's bonus token, so
                            // (accepted + rounds) / rounds is exactly the
                            // gateway's accepted_per_step for this stream
                            // (prefill and plain fallback steps excluded)
                            out.tokens_per_step.push((accepted + rounds) as f64 / rounds as f64);
                        }
                        break 'attempts;
                    }
                    ServerMsg::Error { code, retry_after_ms: Some(hint), .. }
                        if is_shed_code(&code) && attempt + 1 < SHED_ATTEMPTS =>
                    {
                        attempt += 1;
                        backoff_sleep(hint, &mut rng);
                        continue 'attempts;
                    }
                    ServerMsg::Error { code, .. } if code == "queue_full" => {
                        out.shed += 1;
                        break 'attempts;
                    }
                    ServerMsg::Error { .. } => {
                        out.failed += 1;
                        break 'attempts;
                    }
                    other => bail!("unexpected reply {other:?}"),
                }
            }
        }
    }
    Ok(out)
}

/// One request in flight at a time; the next send waits for the reply.
fn closed_loop_client(
    addr: SocketAddr,
    ids: Vec<u64>,
    seq_hint: usize,
    seed: u64,
) -> Result<ClientResult> {
    let mut stream = TcpStream::connect(addr).context("loadgen connect")?;
    stream.set_nodelay(true).ok();
    stream.set_read_timeout(Some(Duration::from_secs(60)))?;
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut rng = Prng::new(seed);
    let mut out = ClientResult::default();
    for id in ids {
        let tokens = synth_tokens(&mut rng, seq_hint);
        let line = ClientMsg::Score { id, tokens }.encode();
        out.sent += 1;
        let mut attempt = 0usize;
        loop {
            let t0 = Instant::now();
            stream.write_all(line.as_bytes())?;
            stream.write_all(b"\n")?;
            stream.flush()?;
            let mut resp = String::new();
            let n = reader.read_line(&mut resp)?;
            if n == 0 {
                bail!("gateway closed the connection mid-run");
            }
            match ServerMsg::parse(&resp)? {
                ServerMsg::Score { .. } => {
                    out.lat_ms.push(t0.elapsed().as_secs_f64() * 1e3);
                    break;
                }
                ServerMsg::Error { code, retry_after_ms: Some(hint), .. }
                    if is_shed_code(&code) && attempt + 1 < SHED_ATTEMPTS =>
                {
                    attempt += 1;
                    backoff_sleep(hint, &mut rng);
                }
                ServerMsg::Error { code, .. } if code == "queue_full" => {
                    out.shed += 1;
                    break;
                }
                ServerMsg::Error { .. } => {
                    out.failed += 1;
                    break;
                }
                other => bail!("unexpected reply {other:?}"),
            }
        }
    }
    Ok(out)
}

/// Paced sends regardless of completions; a reader thread matches
/// responses back to send timestamps by request id.
fn open_loop_client(
    addr: SocketAddr,
    ids: Vec<u64>,
    seq_hint: usize,
    seed: u64,
    rate: f64,
) -> Result<ClientResult> {
    let mut stream = TcpStream::connect(addr).context("loadgen connect")?;
    stream.set_nodelay(true).ok();
    stream.set_read_timeout(Some(Duration::from_secs(60)))?;
    let reader_stream = stream.try_clone()?;
    let sent_at: Arc<Mutex<HashMap<u64, Instant>>> = Arc::new(Mutex::new(HashMap::new()));
    let expected = ids.len();
    let sent_at_r = Arc::clone(&sent_at);
    let reader = thread::spawn(move || -> Result<ClientResult> {
        let mut out = ClientResult::default();
        let mut reader = BufReader::new(reader_stream);
        let mut got = 0usize;
        while got < expected {
            let mut line = String::new();
            let n = reader.read_line(&mut line)?;
            if n == 0 {
                bail!("gateway closed the connection with {got}/{expected} replies");
            }
            got += 1;
            match ServerMsg::parse(&line)? {
                ServerMsg::Score { id, .. } => {
                    let t0 = sent_at_r.lock().unwrap().remove(&id);
                    if let Some(t0) = t0 {
                        out.lat_ms.push(t0.elapsed().as_secs_f64() * 1e3);
                    }
                }
                ServerMsg::Error { code, .. } if code == "queue_full" => out.shed += 1,
                ServerMsg::Error { .. } => out.failed += 1,
                other => bail!("unexpected reply {other:?}"),
            }
        }
        Ok(out)
    });

    let interval = Duration::from_secs_f64(1.0 / rate);
    let mut rng = Prng::new(seed);
    let mut sent = 0usize;
    let start = Instant::now();
    for (i, id) in ids.iter().enumerate() {
        // absolute schedule so pacing error does not accumulate
        let due = start + interval.mul_f64(i as f64);
        let now = Instant::now();
        if due > now {
            thread::sleep(due - now);
        }
        let tokens = synth_tokens(&mut rng, seq_hint);
        let line = ClientMsg::Score { id: *id, tokens }.encode();
        sent_at.lock().unwrap().insert(*id, Instant::now());
        stream.write_all(line.as_bytes())?;
        stream.write_all(b"\n")?;
        stream.flush()?;
        sent += 1;
    }
    let mut out = match reader.join() {
        Ok(r) => r?,
        Err(_) => bail!("loadgen reader panicked"),
    };
    out.sent = sent;
    Ok(out)
}

// ---------------------------------------------------------------------------
// Trace replay
// ---------------------------------------------------------------------------

/// Replay knobs: how fast to play a trace back and which token seed to
/// expand it with.
#[derive(Debug, Clone, Copy)]
pub struct TraceRunConfig {
    /// Time-compression factor: 2.0 replays the trace at twice its
    /// recorded rate (arrival times divided by `speed`). Values <= 0
    /// replay in real time.
    pub speed: f64,
    /// Token-synthesis seed override (0 = the trace's own seed).
    pub seed: u64,
    /// Front-tier mode: replay against this many identical gateway
    /// replicas behind an in-process front (0 = one direct gateway).
    pub front_replicas: usize,
}

impl Default for TraceRunConfig {
    fn default() -> Self {
        TraceRunConfig { speed: 1.0, seed: 0, front_replicas: 0 }
    }
}

/// Per-class accounting (one per tenant and one per request mode).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ClassCounts {
    /// Requests issued.
    pub sent: usize,
    /// Requests answered successfully.
    pub ok: usize,
    /// Requests shed (`queue_full`).
    pub shed: usize,
    /// Requests failed (any other error, or a broken stream).
    pub failed: usize,
    /// Generated tokens streamed back.
    pub gen_tokens: u64,
}

impl ClassCounts {
    fn json(&self) -> Json {
        let mut m = BTreeMap::new();
        m.insert("sent".to_string(), Json::Num(self.sent as f64));
        m.insert("ok".to_string(), Json::Num(self.ok as f64));
        m.insert("shed".to_string(), Json::Num(self.shed as f64));
        m.insert("failed".to_string(), Json::Num(self.failed as f64));
        m.insert("gen_tokens".to_string(), Json::Num(self.gen_tokens as f64));
        Json::Obj(m)
    }
}

/// One trace replay: client-observed latency/TTFT percentiles, shed
/// accounting overall and per tenant/mode, plus the gateway's own
/// padding/throughput counters pulled via `stats`.
#[derive(Debug, Clone)]
pub struct TraceReport {
    pub trace: String,
    pub policy: String,
    pub speed: f64,
    /// Offered load after time compression (trace rate × speed).
    pub offered_rps: f64,
    pub sent: usize,
    pub ok: usize,
    pub shed: usize,
    pub failed: usize,
    /// shed / sent — the saturation-sweep headline.
    pub shed_rate: f64,
    pub wall_s: f64,
    pub achieved_rps: f64,
    pub p50_ms: f64,
    pub p95_ms: f64,
    pub p99_ms: f64,
    pub ttft_p50_ms: f64,
    pub ttft_p99_ms: f64,
    pub gen_tokens: u64,
    pub padding_frac: f64,
    pub decode_padding_frac: f64,
    pub tokens_per_s: f64,
    pub decode_tokens_per_s: f64,
    /// Per-tenant accounting, keyed by the trace's tenant labels.
    pub tenants: BTreeMap<String, ClassCounts>,
    /// Per-mode accounting (`score` / `generate` / `spec`).
    pub modes: BTreeMap<String, ClassCounts>,
}

impl TraceReport {
    /// One-line JSON record (the saturation-bench datapoint).
    pub fn to_json(&self) -> Json {
        let mut m = BTreeMap::new();
        m.insert("trace".to_string(), Json::Str(self.trace.clone()));
        m.insert("policy".to_string(), Json::Str(self.policy.clone()));
        let mut num = |k: &str, v: f64| {
            m.insert(k.to_string(), Json::Num(v));
        };
        num("speed", self.speed);
        num("offered_rps", self.offered_rps);
        num("sent", self.sent as f64);
        num("ok", self.ok as f64);
        num("shed", self.shed as f64);
        num("failed", self.failed as f64);
        num("shed_rate", self.shed_rate);
        num("wall_s", self.wall_s);
        num("achieved_rps", self.achieved_rps);
        num("p50_ms", self.p50_ms);
        num("p95_ms", self.p95_ms);
        num("p99_ms", self.p99_ms);
        num("ttft_p50_ms", self.ttft_p50_ms);
        num("ttft_p99_ms", self.ttft_p99_ms);
        num("gen_tokens", self.gen_tokens as f64);
        num("padding_frac", self.padding_frac);
        num("decode_padding_frac", self.decode_padding_frac);
        num("tokens_per_s", self.tokens_per_s);
        num("decode_tokens_per_s", self.decode_tokens_per_s);
        let nest = |classes: &BTreeMap<String, ClassCounts>| {
            Json::Obj(classes.iter().map(|(k, v)| (k.clone(), v.json())).collect())
        };
        m.insert("tenants".to_string(), nest(&self.tenants));
        m.insert("modes".to_string(), nest(&self.modes));
        Json::Obj(m)
    }
}

/// What one replayed request observed.
struct ReqOutcome {
    tenant: String,
    mode: TraceMode,
    ok: bool,
    shed: bool,
    lat_ms: f64,
    /// Negative = no token frame seen.
    ttft_ms: f64,
    gen_tokens: u64,
}

/// Start a gateway (or, in front-tier mode, replicas behind a front),
/// replay `trace` against it on its arrival schedule (time-compressed
/// by `rc.speed`), pull `stats`, shut down and return the merged
/// report. One connection and one thread per request — the
/// replay is open-loop by construction, so a saturated gateway sheds
/// rather than slowing the arrival process down.
pub fn run_trace(
    gw_cfg: GatewayConfig,
    trace: &Trace,
    rc: TraceRunConfig,
) -> Result<TraceReport> {
    let policy_name = gw_cfg.policy.name().to_string();
    let speed = if rc.speed > 0.0 { rc.speed } else { 1.0 };
    let trace_out = gw_cfg.trace_out.clone();
    let stack = Stack::start(gw_cfg, rc.front_replicas)?;
    let addr = stack.addr;
    let schedule = trace.schedule(rc.seed, stack.seq());

    let t0 = Instant::now();
    let mut handles = Vec::new();
    for req in schedule {
        // absolute schedule so pacing error does not accumulate
        let due = t0 + Duration::from_secs_f64(req.at_ms / 1000.0 / speed);
        let now = Instant::now();
        if due > now {
            thread::sleep(due - now);
        }
        handles.push(thread::spawn(move || replay_one(addr, req)));
    }

    let mut outcomes = Vec::new();
    let mut client_err: Option<anyhow::Error> = None;
    for h in handles {
        match h.join() {
            Ok(o) => outcomes.push(o),
            Err(_) => client_err = Some(anyhow::anyhow!("trace replay client panicked")),
        }
    }
    if let Some(e) = client_err {
        stack.drain();
        return Err(e);
    }
    let wall_s = t0.elapsed().as_secs_f64();

    let stats = stack.stats_and_shutdown()?;
    dump_trace(trace_out.as_deref())?;

    let mut tenants: BTreeMap<String, ClassCounts> = BTreeMap::new();
    let mut modes: BTreeMap<String, ClassCounts> = BTreeMap::new();
    let mut lat = Vec::new();
    let mut ttft = Vec::new();
    let (mut ok, mut shed, mut failed, mut gen_tokens) = (0usize, 0usize, 0usize, 0u64);
    for o in &outcomes {
        let mut bump = |c: &mut ClassCounts| {
            c.sent += 1;
            c.ok += usize::from(o.ok);
            c.shed += usize::from(o.shed);
            c.failed += usize::from(!o.ok && !o.shed);
            c.gen_tokens += o.gen_tokens;
        };
        bump(tenants.entry(o.tenant.clone()).or_default());
        bump(modes.entry(o.mode.name().to_string()).or_default());
        ok += usize::from(o.ok);
        shed += usize::from(o.shed);
        failed += usize::from(!o.ok && !o.shed);
        gen_tokens += o.gen_tokens;
        if o.ok {
            lat.push(o.lat_ms);
        }
        if o.ttft_ms >= 0.0 {
            ttft.push(o.ttft_ms);
        }
    }
    lat.sort_by(|a, b| a.partial_cmp(b).unwrap());
    ttft.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let pct = |xs: &[f64], p: f64| if xs.is_empty() { 0.0 } else { percentile(xs, p) };
    let getf = |k: &str| stats.get(k).and_then(|v| v.as_f64()).unwrap_or(0.0);
    let sent = outcomes.len();
    Ok(TraceReport {
        trace: trace.name.clone(),
        policy: policy_name,
        speed,
        offered_rps: trace.offered_rps() * speed,
        sent,
        ok,
        shed,
        failed,
        shed_rate: if sent > 0 { shed as f64 / sent as f64 } else { 0.0 },
        wall_s,
        achieved_rps: if wall_s > 0.0 { ok as f64 / wall_s } else { 0.0 },
        p50_ms: pct(&lat, 50.0),
        p95_ms: pct(&lat, 95.0),
        p99_ms: pct(&lat, 99.0),
        ttft_p50_ms: pct(&ttft, 50.0),
        ttft_p99_ms: pct(&ttft, 99.0),
        gen_tokens,
        padding_frac: getf("padding_frac"),
        decode_padding_frac: getf("decode_padding_frac"),
        tokens_per_s: getf("tokens_per_s"),
        decode_tokens_per_s: getf("decode_tokens_per_s"),
        tenants,
        modes,
    })
}

/// Issue one scheduled request on its own connection and classify the
/// outcome. Transport errors are outcomes (`failed`), not panics — a
/// saturated or draining gateway must not abort the whole replay.
fn replay_one(addr: SocketAddr, req: ScheduledReq) -> ReqOutcome {
    let mut out = ReqOutcome {
        tenant: req.tenant.clone(),
        mode: req.mode,
        ok: false,
        shed: false,
        lat_ms: 0.0,
        ttft_ms: -1.0,
        gen_tokens: 0,
    };
    let t0 = Instant::now();
    let inner = (|| -> Result<()> {
        let mut stream = TcpStream::connect(addr).context("trace replay connect")?;
        stream.set_nodelay(true).ok();
        stream.set_read_timeout(Some(Duration::from_secs(60)))?;
        let mut reader = BufReader::new(stream.try_clone()?);
        let line = match req.mode {
            TraceMode::Score => ClientMsg::Score { id: req.id, tokens: req.tokens }.encode(),
            TraceMode::Generate | TraceMode::Spec => {
                let opts = GenOpts { spec_k: req.spec_k, ..Default::default() };
                ClientMsg::Generate {
                    id: req.id,
                    tokens: req.tokens,
                    max_new: req.max_new,
                    opts,
                }
                .encode()
            }
        };
        stream.write_all(line.as_bytes())?;
        stream.write_all(b"\n")?;
        stream.flush()?;
        let mut next_index = 0usize;
        loop {
            let mut resp = String::new();
            let n = reader.read_line(&mut resp)?;
            if n == 0 {
                bail!("gateway closed the connection mid-request");
            }
            match ServerMsg::parse(&resp)? {
                ServerMsg::Score { id, .. } if id == req.id => {
                    out.ok = true;
                    out.lat_ms = t0.elapsed().as_secs_f64() * 1e3;
                    return Ok(());
                }
                ServerMsg::Token { id, index, .. } if id == req.id => {
                    // a gap or repeat here is token loss/duplication —
                    // surfaced as a failed request in the report
                    if index != next_index {
                        bail!("token index {index}, expected {next_index}");
                    }
                    next_index += 1;
                    if out.ttft_ms < 0.0 {
                        out.ttft_ms = t0.elapsed().as_secs_f64() * 1e3;
                    }
                    out.gen_tokens += 1;
                }
                ServerMsg::Done { id, tokens, .. } if id == req.id => {
                    if tokens.len() != next_index {
                        bail!("done carries {} tokens, streamed {next_index}", tokens.len());
                    }
                    out.ok = true;
                    out.lat_ms = t0.elapsed().as_secs_f64() * 1e3;
                    return Ok(());
                }
                ServerMsg::Error { code, .. } => {
                    if code == "queue_full" {
                        out.shed = true;
                    }
                    return Ok(());
                }
                other => bail!("unexpected reply {other:?}"),
            }
        }
    })();
    if inner.is_err() {
        out.ok = false;
        out.shed = false;
    }
    out
}
