"""Token-rounding router (Algorithm 4 + Appendix G.2 subroutines)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.kernels import router

from .conftest import random_routing


def _softmax_scores(rng, t, e):
    s, _ = random_routing(rng, t, e, 1)
    return jnp.asarray(s)


ALL_SUBS = list(router.SUBROUTINES)


@pytest.mark.parametrize("sub", ALL_SUBS)
@pytest.mark.parametrize("t,e,k,m", [(64, 8, 2, 8), (128, 16, 4, 16), (32, 4, 1, 8)])
def test_tr_invariants(rng, sub, t, e, k, m):
    scores = _softmax_scores(rng, t, e)
    key = jax.random.PRNGKey(0)
    dec = router.token_rounding(scores, k, m, subroutine=sub, key=key)
    pi = np.asarray(dec.pi)
    f = np.asarray(dec.f)
    g = np.asarray(dec.g)

    # counts realize the targets and targets are tile multiples
    np.testing.assert_array_equal(pi.sum(axis=0).astype(int), g)
    assert np.all(g % m == 0)
    # deviation from TC bounded by one tile (Section 5.2 guarantee)
    assert np.all(np.abs(g - f) < m)
    # sparsified scores live exactly on the mask
    s = np.asarray(dec.scores)
    assert np.all((s > 0) == (pi > 0))


@pytest.mark.parametrize("t,e,k,m", [(64, 8, 2, 8), (128, 16, 4, 16)])
def test_tr_tc_preference(rng, t, e, k, m):
    """Discard/pad only touches the boundary: every kept token for expert e
    scores >= every dropped TC token; every padded EC token scores <= every
    TC token kept (within the same expert)."""
    scores = _softmax_scores(rng, t, e)
    dec_tc = router.tc_topk(scores, k)
    dec = router.token_rounding(scores, k, m, subroutine="nr-f")
    s = np.asarray(scores)
    pi_tc = np.asarray(dec_tc.pi) > 0
    pi_tr = np.asarray(dec.pi) > 0
    for ee in range(e):
        dropped = pi_tc[:, ee] & ~pi_tr[:, ee]
        kept_tc = pi_tc[:, ee] & pi_tr[:, ee]
        padded = ~pi_tc[:, ee] & pi_tr[:, ee]
        # only one of dropping / padding can happen per expert
        assert not (dropped.any() and padded.any())
        if dropped.any() and kept_tc.any():
            assert s[kept_tc, ee].min() >= s[dropped, ee].max()
        if padded.any():
            not_selected = ~pi_tc[:, ee] & ~pi_tr[:, ee]
            if not_selected.any():
                assert s[padded, ee].min() >= s[not_selected, ee].max()


def test_tr_preserves_total_in_expectation(rng):
    """NR-f: total routed tokens stays within E*m/2 of T*K."""
    t, e, k, m = 256, 16, 4, 16
    scores = _softmax_scores(rng, t, e)
    dec = router.token_rounding(scores, k, m, subroutine="nr-f")
    assert abs(int(np.asarray(dec.g).sum()) - t * k) <= e * m // 2


def test_balance_f_accumulator_bound(rng):
    """Algorithm 6 guarantee: |sum(g) - sum(f)| <= m/2."""
    t, e, k, m = 256, 32, 4, 16
    scores = _softmax_scores(rng, t, e)
    dec = router.token_rounding(scores, k, m, subroutine="balance-f")
    total_dev = abs(int(np.asarray(dec.g).sum()) - int(np.asarray(dec.f).sum()))
    assert total_dev <= m // 2
    assert np.all(np.abs(np.asarray(dec.g) - np.asarray(dec.f)) <= m)


def test_up_down_bracket_everything(rng):
    t, e, k, m = 64, 8, 2, 8
    scores = _softmax_scores(rng, t, e)
    g_up = np.asarray(router.token_rounding(scores, k, m, subroutine="up").g)
    g_dn = np.asarray(router.token_rounding(scores, k, m, subroutine="down").g)
    for sub in ("nr-f", "balance-f"):
        g = np.asarray(router.token_rounding(scores, k, m, subroutine=sub).g)
        assert np.all(g_dn <= g) and np.all(g <= g_up)
    f = np.asarray(router.tc_topk(scores, k).f)
    assert np.all(g_dn <= f) and np.all(f <= g_up)


def test_token_drop_equals_down(rng):
    t, e, k, m = 64, 8, 2, 8
    scores = _softmax_scores(rng, t, e)
    a = router.token_drop(scores, k, m)
    b = router.token_rounding(scores, k, m, subroutine="down")
    np.testing.assert_array_equal(np.asarray(a.pi), np.asarray(b.pi))


def test_expert_choice_capacity(rng):
    t, e, k = 64, 8, 2
    scores = _softmax_scores(rng, t, e)
    dec = router.expert_choice(scores, k)
    np.testing.assert_array_equal(np.asarray(dec.f), (t * k) // e)


def test_tc_topk_matches_ref(rng):
    from compile.kernels import ref

    scores = _softmax_scores(rng, 32, 8)
    dec = router.tc_topk(scores, 3)
    pi_ref, s_ref = ref.tc_topk_dense(scores, 3)
    np.testing.assert_array_equal(np.asarray(dec.pi), np.asarray(pi_ref))
    np.testing.assert_allclose(np.asarray(dec.scores), np.asarray(s_ref))


def test_renormalize_decision(rng):
    scores = _softmax_scores(rng, 32, 8)
    dec = router.token_rounding(scores, 2, 8)
    dec_r = router.renormalize_decision(dec)
    sums = np.asarray(dec_r.scores.sum(axis=1))
    routed = np.asarray(dec.pi).sum(axis=1) > 0
    np.testing.assert_allclose(sums[routed], 1.0, rtol=1e-5)


def test_sr_f_is_bernoulli_between_floor_ceil(rng):
    t, e, k, m = 64, 8, 2, 8
    scores = _softmax_scores(rng, t, e)
    f = np.asarray(router.tc_topk(scores, k).f)
    lo = (f // m) * m
    hi = ((f + m - 1) // m) * m
    seen_lo = np.zeros(e, bool)
    seen_hi = np.zeros(e, bool)
    for seed in range(20):
        g = np.asarray(
            router.token_rounding(
                scores, k, m, subroutine="sr-f", key=jax.random.PRNGKey(seed)
            ).g
        )
        assert np.all((g == lo) | (g == hi))
        seen_lo |= g == lo
        seen_hi |= g == hi
    # fractional experts should see both outcomes across seeds
    frac = (f % m != 0) & (hi <= (t // m) * m)
    assert (seen_lo | seen_hi)[frac].all()
