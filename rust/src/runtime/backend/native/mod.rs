//! Native pure-rust CPU execution backend.
//!
//! Executes the manifest's artifact contracts (`lm_eval`,
//! `lm_grad_step_<router>`, `moe_layer_fwd_<router>`) directly on the
//! host by porting the reference numerics of
//! `python/compile/kernels/ref.py` / `python/compile/model.py` onto the
//! `util::tensor`, `routing` and `optim` substrates. No python, HLO
//! files or external runtime anywhere — the whole train/eval/serve path
//! is hermetic and works offline.
//!
//! When no `make artifacts` output exists, the backend synthesizes the
//! built-in model configs (mirroring `python/compile/aot.py::CONFIGS`)
//! and deterministic initial parameters, so `sonic-moe train/eval/serve`
//! run out of the box.

pub mod kernels;
pub mod linalg;
pub mod lm;

use std::collections::BTreeMap;
use std::collections::HashMap;
use std::path::Path;

use anyhow::{anyhow, bail, Result};

use crate::runtime::backend::{Backend, Executable, Value};
use crate::runtime::manifest::{ArtifactSpec, ConfigManifest, ModelInfo, ParamSpec, TensorSpec};
use crate::util::prng::Prng;
use crate::util::tensor::Tensor;

use lm::{LmCfg, Params, RouterKind};

/// The native backend (stateless; all state lives in the executables).
#[derive(Debug, Default)]
pub struct NativeBackend;

impl NativeBackend {
    pub fn new() -> NativeBackend {
        NativeBackend
    }
}

impl Backend for NativeBackend {
    fn name(&self) -> &'static str {
        "native"
    }

    fn compile(
        &self,
        _dir: &Path,
        name: &str,
        spec: &ArtifactSpec,
        manifest: &ConfigManifest,
    ) -> Result<Box<dyn Executable>> {
        // `lm_eval` plus batch-shape variants (`lm_eval_b<rows>`) — the
        // serving gateway picks the smallest tile-multiple shape that
        // fits a batch, so under-filled batches pad fewer rows
        if name == "lm_eval" || name.starts_with("lm_eval_b") {
            let router = lm::parse_router_method(&manifest.model.router)?;
            let cfg = lm_cfg(&manifest.model, spec, router, None)?;
            return Ok(Box::new(LmExec::new(spec.clone(), cfg, false)?));
        }
        // `lm_decode_step` plus batch-shape variants: next-token logits
        // for a packed batch of variable-length rows (the generation
        // path's stateless contract; the gateway's continuous batcher
        // runs the incremental KV-cache equivalent)
        if name == "lm_decode_step" || name.starts_with("lm_decode_step_b") {
            let router = lm::parse_router_method(&manifest.model.router)?;
            // signature is (params..., tokens, lengths): the token
            // shape sits second to last
            let n = spec.inputs.len();
            if n < 2 {
                bail!("decode artifact needs (tokens, lengths) inputs");
            }
            let cfg = lm_cfg_from_tok(&manifest.model, &spec.inputs[n - 2], router, None)?;
            return Ok(Box::new(DecodeExec::new(spec.clone(), cfg)?));
        }
        if let Some(tag) = name.strip_prefix("lm_grad_step_") {
            let (router, m_override) = lm::parse_router_tag(tag)?;
            let cfg = lm_cfg(&manifest.model, spec, router, m_override)?;
            return Ok(Box::new(LmExec::new(spec.clone(), cfg, true)?));
        }
        if let Some(tag) = name.strip_prefix("moe_layer_fwd_") {
            let (router, m_override) = lm::parse_router_tag(tag)?;
            return Ok(Box::new(MoeExec::new(spec.clone(), &manifest.model, router, m_override)?));
        }
        bail!("artifact {name:?} is not implemented by the native backend")
    }

    fn builtin_manifest(&self, config_name: &str) -> Option<ConfigManifest> {
        builtin_manifest(config_name)
    }
}

/// Build an [`LmCfg`] from the manifest model plus the artifact's token
/// signature (variant artifacts may override batch / m_tile).
fn lm_cfg(
    m: &ModelInfo,
    spec: &ArtifactSpec,
    router: RouterKind,
    m_tile_override: Option<usize>,
) -> Result<LmCfg> {
    let tok = spec
        .inputs
        .last()
        .ok_or_else(|| anyhow!("artifact has no inputs"))?;
    lm_cfg_from_tok(m, tok, router, m_tile_override)
}

/// [`lm_cfg`] from an explicit token spec (decode artifacts carry the
/// token shape second to last, before the `lengths` input).
fn lm_cfg_from_tok(
    m: &ModelInfo,
    tok: &TensorSpec,
    router: RouterKind,
    m_tile_override: Option<usize>,
) -> Result<LmCfg> {
    if tok.dtype != "int32" || tok.shape.len() != 2 {
        bail!("token artifact input must be int32 (rows, seq), got {tok:?}");
    }
    if m.d % m.n_heads != 0 {
        bail!("d={} not divisible by n_heads={}", m.d, m.n_heads);
    }
    Ok(LmCfg {
        vocab: m.vocab,
        d: m.d,
        n_layers: m.n_layers,
        n_heads: m.n_heads,
        rows: tok.shape[0],
        seq: tok.shape[1],
        n: m.n,
        e: m.e,
        k: m.k,
        m_tile: m_tile_override.unwrap_or(m.m_tile),
        aux_coeff: m.aux_coeff,
        router,
    })
}

/// Positional-input resolver shared by the LM executables.
struct InputMap {
    by_name: HashMap<String, usize>,
}

impl InputMap {
    fn new(spec: &ArtifactSpec) -> InputMap {
        let by_name = spec
            .inputs
            .iter()
            .enumerate()
            .map(|(i, ts)| (ts.name.clone(), i))
            .collect();
        InputMap { by_name }
    }

    fn tensor<'a>(&self, values: &'a [Value], name: &str) -> Result<&'a Tensor> {
        let &i = self
            .by_name
            .get(name)
            .ok_or_else(|| anyhow!("artifact input {name:?} missing from signature"))?;
        values
            .get(i)
            .ok_or_else(|| anyhow!("input {name:?} (position {i}) not provided"))?
            .as_f32()
    }
}

fn scalar(x: f32) -> Value {
    Value::F32(Tensor { shape: Vec::new(), data: vec![x] })
}

/// `lm_eval` / `lm_grad_step_*` executable.
struct LmExec {
    spec: ArtifactSpec,
    cfg: LmCfg,
    grad: bool,
    inputs: InputMap,
}

impl LmExec {
    fn new(spec: ArtifactSpec, cfg: LmCfg, grad: bool) -> Result<LmExec> {
        let inputs = InputMap::new(&spec);
        Ok(LmExec { spec, cfg, grad, inputs })
    }
}

impl Executable for LmExec {
    fn execute(&self, values: &[Value]) -> Result<Vec<Value>> {
        let params = Params::collect(self.cfg.n_layers, |name| self.inputs.tensor(values, name))?;
        let (_, tokens) = values
            .last()
            .ok_or_else(|| anyhow!("no inputs"))?
            .as_i32()?;
        if !self.grad {
            let (ce, ce_rows) = lm::eval_ce_rows(&self.cfg, &params, tokens);
            let mut out = vec![scalar(ce)];
            // extended contract: a second `ce_rows` output when the
            // manifest declares it (builtin configs do; AOT manifests
            // may still carry the original scalar-only signature)
            if self.spec.outputs.len() > 1 {
                out.push(Value::F32(Tensor::from_vec(&[self.cfg.rows], ce_rows)?));
            }
            return Ok(out);
        }
        let (loss, ce, mut grads) = lm::grad_step(&self.cfg, &params, tokens);
        let mut out = Vec::with_capacity(self.spec.outputs.len());
        out.push(scalar(loss));
        out.push(scalar(ce));
        for ospec in &self.spec.outputs[2..] {
            let pname = ospec
                .name
                .strip_prefix("d_")
                .ok_or_else(|| anyhow!("unexpected grad output name {:?}", ospec.name))?;
            let data = grads.take(pname)?;
            out.push(Value::F32(Tensor::from_vec(&ospec.shape, data)?));
        }
        Ok(out)
    }
}

/// `lm_decode_step` executable: (params..., tokens, lengths) ->
/// next-token logits (rows, vocab).
struct DecodeExec {
    spec: ArtifactSpec,
    cfg: LmCfg,
    inputs: InputMap,
}

impl DecodeExec {
    fn new(spec: ArtifactSpec, cfg: LmCfg) -> Result<DecodeExec> {
        let inputs = InputMap::new(&spec);
        Ok(DecodeExec { spec, cfg, inputs })
    }
}

impl Executable for DecodeExec {
    fn execute(&self, values: &[Value]) -> Result<Vec<Value>> {
        let params = Params::collect(self.cfg.n_layers, |name| self.inputs.tensor(values, name))?;
        let n = values.len();
        if n < 2 {
            bail!("decode artifact expects (tokens, lengths) after the parameters");
        }
        let (_, tokens) = values[n - 2].as_i32()?;
        let (_, lengths) = values[n - 1].as_i32()?;
        let lens: Vec<usize> =
            lengths.iter().map(|&x| (x.max(1) as usize).min(self.cfg.seq)).collect();
        let logits = lm::decode_logits(&self.cfg, &params, tokens, &lens)?;
        let shape = &self.spec.outputs[0].shape;
        Ok(vec![Value::F32(Tensor::from_vec(shape, logits)?)])
    }
}

/// `moe_layer_fwd_*` executable: (x, wr, w1, w2) -> (o, aux).
struct MoeExec {
    cfg: LmCfg,
}

impl MoeExec {
    fn new(
        spec: ArtifactSpec,
        m: &ModelInfo,
        router: RouterKind,
        m_tile_override: Option<usize>,
    ) -> Result<MoeExec> {
        if spec.inputs.len() != 4 {
            bail!("moe_layer_fwd expects 4 inputs (x, wr, w1, w2)");
        }
        let t = spec.inputs[0].shape[0];
        let cfg = LmCfg {
            vocab: m.vocab,
            d: m.d,
            n_layers: 1,
            n_heads: m.n_heads,
            rows: t,
            seq: 1,
            n: m.n,
            e: m.e,
            k: m.k,
            m_tile: m_tile_override.unwrap_or(m.m_tile),
            aux_coeff: m.aux_coeff,
            router,
        };
        Ok(MoeExec { cfg })
    }
}

impl Executable for MoeExec {
    fn execute(&self, values: &[Value]) -> Result<Vec<Value>> {
        let x = values[0].as_f32()?;
        let wr = values[1].as_f32()?;
        let w1 = values[2].as_f32()?;
        let w2 = values[3].as_f32()?;
        let (o, aux) = lm::moe_layer_forward(&self.cfg, x, wr, w1, w2, self.cfg.router);
        Ok(vec![
            Value::F32(Tensor::from_vec(&x.shape, o)?),
            scalar(aux),
        ])
    }
}

// ---------------------------------------------------------------------------
// Built-in configs (mirrors python/compile/aot.py) + native param init
// ---------------------------------------------------------------------------

/// Names of the built-in configs, in display order (the single source
/// of truth is [`builtin_cfg`]; every name here must resolve there).
pub const BUILTIN_CONFIGS: [&str; 7] =
    ["small", "small-draft", "medium", "large", "gran1", "gran2", "gran3"];

struct BuiltinCfg {
    vocab: usize,
    d: usize,
    n_layers: usize,
    n_heads: usize,
    seq_len: usize,
    batch: usize,
    n: usize,
    e: usize,
    k: usize,
    m_tile: usize,
}

fn builtin_cfg(name: &str) -> Option<BuiltinCfg> {
    let c = |vocab, d, n_layers, n_heads, seq_len, batch, n, e, k, m_tile| BuiltinCfg {
        vocab, d, n_layers, n_heads, seq_len, batch, n, e, k, m_tile,
    };
    Some(match name {
        "small" => c(256, 64, 2, 4, 32, 4, 32, 8, 2, 16),
        // speculative-decode draft for `small`: half the layers, same
        // vocab/d/seq family. Because `init_params` draws parameters in
        // declaration order from one seeded stream (and norm vectors
        // consume no randomness), this config's embed + layer0 are
        // bitwise identical to `small`'s — a self-speculative truncated
        // draft whose proposals share the target's embedding geometry.
        "small-draft" => c(256, 64, 1, 4, 32, 4, 32, 8, 2, 16),
        "medium" => c(1024, 128, 4, 4, 64, 4, 64, 16, 2, 32),
        "large" => c(4096, 256, 6, 8, 128, 4, 128, 32, 4, 64),
        "gran1" => c(256, 64, 2, 4, 32, 4, 64, 4, 1, 8),
        "gran2" => c(256, 64, 2, 4, 32, 4, 32, 8, 2, 8),
        "gran3" => c(256, 64, 2, 4, 32, 4, 16, 16, 4, 8),
        _ => return None,
    })
}

/// Router-variant artifact tags per config (tag, batch override),
/// mirroring `aot.py::ROUTER_VARIANTS`.
fn router_variants(name: &str) -> Vec<(&'static str, Option<usize>)> {
    match name {
        "small" => vec![
            ("tc", None),
            ("tr", None),
            ("trbal", None),
            ("trup", None),
            ("trdown", None),
            ("ec", None),
            ("tr_m8", None),
            ("tr_m32", None),
            ("tr_b2", Some(2)),
            ("tr_b8", Some(8)),
        ],
        "medium" | "large" => vec![("tc", None), ("tr", None)],
        _ => vec![("tc", None)],
    }
}

/// Ordered (name, shape) parameter layout — the same contract as
/// `python/compile/model.py::param_specs`.
fn param_specs(c: &BuiltinCfg) -> Vec<(String, Vec<usize>)> {
    let mut specs = vec![("embed".to_string(), vec![c.vocab, c.d])];
    for i in 0..c.n_layers {
        let p = |s: &str| format!("layer{i}.{s}");
        specs.push((p("attn_norm"), vec![c.d]));
        specs.push((p("wq"), vec![c.d, c.d]));
        specs.push((p("wk"), vec![c.d, c.d]));
        specs.push((p("wv"), vec![c.d, c.d]));
        specs.push((p("wo"), vec![c.d, c.d]));
        specs.push((p("moe_norm"), vec![c.d]));
        specs.push((p("wr"), vec![c.d, c.e]));
        specs.push((p("w1"), vec![c.e, c.d, 2 * c.n]));
        specs.push((p("w2"), vec![c.e, c.n, c.d]));
    }
    specs.push(("final_norm".to_string(), vec![c.d]));
    specs
}

fn fspec(name: &str, shape: &[usize]) -> TensorSpec {
    TensorSpec { name: name.to_string(), shape: shape.to_vec(), dtype: "float32".into() }
}

fn ispec(name: &str, shape: &[usize]) -> TensorSpec {
    TensorSpec { name: name.to_string(), shape: shape.to_vec(), dtype: "int32".into() }
}

/// Synthesize the manifest of a built-in config (no files involved:
/// `params_file` is empty, signalling native parameter initialization).
pub fn builtin_manifest(name: &str) -> Option<ConfigManifest> {
    let c = builtin_cfg(name)?;
    let specs = param_specs(&c);
    let mut params = Vec::with_capacity(specs.len());
    let mut offset = 0usize;
    for (pname, shape) in &specs {
        let size: usize = shape.iter().product();
        params.push(ParamSpec { name: pname.clone(), shape: shape.clone(), offset, size });
        offset += size;
    }
    let num_params = offset;
    let per_expert = c.d * 2 * c.n + c.n * c.d;
    let num_active_params = num_params - c.n_layers * (c.e - c.k) * per_expert;

    let param_inputs: Vec<TensorSpec> =
        specs.iter().map(|(n, s)| fspec(n, s)).collect();
    let grad_outputs: Vec<TensorSpec> = [fspec("loss", &[]), fspec("ce", &[])]
        .into_iter()
        .chain(specs.iter().map(|(n, s)| fspec(&format!("d_{n}"), s)))
        .collect();

    let mut artifacts = BTreeMap::new();
    for (tag, batch_override) in router_variants(name) {
        let rows = batch_override.unwrap_or(c.batch);
        let mut inputs = param_inputs.clone();
        inputs.push(ispec("tokens", &[rows, c.seq_len]));
        artifacts.insert(
            format!("lm_grad_step_{tag}"),
            ArtifactSpec {
                file: String::new(),
                inputs,
                outputs: grad_outputs.clone(),
                golden: None,
            },
        );
    }
    // eval artifacts: the canonical batch shape plus power-of-two batch
    // variants (`lm_eval_b<rows>`) so the serving gateway can execute a
    // tile-rounded batch without padding all the way to the full shape.
    // All of them carry the extended [ce, ce_rows] output contract.
    // Decode artifacts (`lm_decode_step[_b<rows>]`) mirror the same
    // batch shapes: (params..., tokens, lengths) -> next-token logits,
    // the stateless contract behind the continuous-batching generation
    // path (its KV-cache fast path is numerically identical under TC).
    let mut eval_rows: Vec<usize> = vec![1, 2, c.batch, 2 * c.batch];
    eval_rows.sort_unstable();
    eval_rows.dedup();
    for rows in eval_rows {
        let mut eval_inputs = param_inputs.clone();
        eval_inputs.push(ispec("tokens", &[rows, c.seq_len]));
        let ename = if rows == c.batch {
            "lm_eval".to_string()
        } else {
            format!("lm_eval_b{rows}")
        };
        artifacts.insert(
            ename,
            ArtifactSpec {
                file: String::new(),
                inputs: eval_inputs,
                outputs: vec![fspec("ce", &[]), fspec("ce_rows", &[rows])],
                golden: None,
            },
        );
        let mut dec_inputs = param_inputs.clone();
        dec_inputs.push(ispec("tokens", &[rows, c.seq_len]));
        dec_inputs.push(ispec("lengths", &[rows]));
        let dname = if rows == c.batch {
            "lm_decode_step".to_string()
        } else {
            format!("lm_decode_step_b{rows}")
        };
        artifacts.insert(
            dname,
            ArtifactSpec {
                file: String::new(),
                inputs: dec_inputs,
                outputs: vec![fspec("logits", &[rows, c.vocab])],
                golden: None,
            },
        );
    }
    let t = c.batch * c.seq_len;
    for tag in ["tc", "tr"] {
        artifacts.insert(
            format!("moe_layer_fwd_{tag}"),
            ArtifactSpec {
                file: String::new(),
                inputs: vec![
                    fspec("x", &[t, c.d]),
                    fspec("wr", &[c.d, c.e]),
                    fspec("w1", &[c.e, c.d, 2 * c.n]),
                    fspec("w2", &[c.e, c.n, c.d]),
                ],
                outputs: vec![fspec("o", &[t, c.d]), fspec("aux", &[])],
                golden: None,
            },
        );
    }

    Some(ConfigManifest {
        model: ModelInfo {
            vocab: c.vocab,
            d: c.d,
            n_layers: c.n_layers,
            n_heads: c.n_heads,
            seq_len: c.seq_len,
            batch: c.batch,
            n: c.n,
            e: c.e,
            k: c.k,
            m_tile: c.m_tile,
            router: "tc".to_string(),
            aux_coeff: 0.01,
        },
        params,
        params_file: String::new(),
        num_params,
        num_active_params,
        artifacts,
        golden_lm: None,
    })
}

/// Deterministic native parameter init for a (builtin) manifest: the
/// same distribution family as `model.py::init_params` — norms at 1,
/// embed/router at N(0, 0.02), projections at N(0, fan_in^-1/2) — drawn
/// from the repo PRNG (bitwise-stable across runs and platforms).
pub fn init_params(manifest: &ConfigManifest) -> Result<Vec<Tensor>> {
    let mut rng = Prng::new(0x5041_5241_4d53_0001);
    manifest
        .params
        .iter()
        .map(|p| {
            let numel: usize = p.shape.iter().product();
            let data: Vec<f32> = if p.name.ends_with("norm") {
                vec![1.0; numel]
            } else if p.name == "embed" || p.name.ends_with("wr") {
                (0..numel).map(|_| rng.normal() as f32 * 0.02).collect()
            } else {
                let fan_in = if p.shape.len() >= 2 { p.shape[p.shape.len() - 2] } else { p.shape[0] };
                let scale = (fan_in as f32).powf(-0.5);
                (0..numel).map(|_| rng.normal() as f32 * scale).collect()
            };
            Tensor::from_vec(&p.shape, data)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builtin_manifest_layout_is_consistent() {
        for name in BUILTIN_CONFIGS {
            let m = builtin_manifest(name).unwrap();
            let total: usize = m.params.iter().map(|p| p.size).sum();
            assert_eq!(total, m.num_params, "{name}");
            assert!(m.num_active_params < m.num_params, "{name}");
            assert!(m.artifacts.contains_key("lm_eval"), "{name}");
            assert!(m.artifacts.contains_key("lm_grad_step_tc"), "{name}");
            assert!(m.artifacts.contains_key("moe_layer_fwd_tc"), "{name}");
            // eval carries the extended [ce, ce_rows] contract and
            // batch-shape variants for the gateway's tile-aware packing
            let ev = &m.artifacts["lm_eval"];
            assert_eq!(ev.outputs.len(), 2, "{name}");
            assert_eq!(ev.outputs[1].shape, vec![m.model.batch], "{name}");
            for (tag, rows) in [("lm_eval_b1", 1usize), ("lm_eval_b2", 2)] {
                let v = m.artifacts.get(tag).unwrap_or_else(|| panic!("{name}/{tag}"));
                assert_eq!(v.inputs.last().unwrap().shape[0], rows, "{name}/{tag}");
                assert_eq!(v.outputs[1].shape, vec![rows], "{name}/{tag}");
            }
            // decode artifacts mirror the eval batch shapes, with a
            // trailing per-row lengths input and a logits output
            let dv = &m.artifacts["lm_decode_step"];
            assert_eq!(dv.inputs.len(), 2 + m.params.len(), "{name}");
            assert_eq!(dv.inputs.last().unwrap().shape, vec![m.model.batch], "{name}");
            assert_eq!(
                dv.outputs[0].shape,
                vec![m.model.batch, m.model.vocab],
                "{name}"
            );
            for (tag, rows) in [("lm_decode_step_b1", 1usize), ("lm_decode_step_b2", 2)] {
                let v = m.artifacts.get(tag).unwrap_or_else(|| panic!("{name}/{tag}"));
                assert_eq!(v.inputs[v.inputs.len() - 2].shape[0], rows, "{name}/{tag}");
                assert_eq!(v.outputs[0].shape, vec![rows, m.model.vocab], "{name}/{tag}");
            }
            // offsets are contiguous
            let mut off = 0;
            for p in &m.params {
                assert_eq!(p.offset, off, "{name}/{}", p.name);
                off += p.size;
            }
            // grad artifact declares 2 + n_params outputs
            let g = &m.artifacts["lm_grad_step_tc"];
            assert_eq!(g.outputs.len(), 2 + m.params.len());
            assert_eq!(g.inputs.len(), 1 + m.params.len());
        }
        assert!(builtin_manifest("nope").is_none());
    }

    #[test]
    fn small_has_all_router_variants() {
        let m = builtin_manifest("small").unwrap();
        for tag in ["tc", "tr", "trbal", "trup", "trdown", "ec", "tr_m8", "tr_m32", "tr_b2", "tr_b8"] {
            assert!(m.artifacts.contains_key(&format!("lm_grad_step_{tag}")), "{tag}");
        }
        // batch-variant artifacts change the token input shape
        let b2 = &m.artifacts["lm_grad_step_tr_b2"];
        assert_eq!(b2.inputs.last().unwrap().shape, vec![2, 32]);
    }

    #[test]
    fn init_params_deterministic_and_scaled() {
        let m = builtin_manifest("small").unwrap();
        let a = init_params(&m).unwrap();
        let b = init_params(&m).unwrap();
        assert_eq!(a.len(), m.params.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x, y);
        }
        // norms are ones
        let norm_idx = m.params.iter().position(|p| p.name.ends_with("norm")).unwrap();
        assert!(a[norm_idx].data.iter().all(|&v| v == 1.0));
        // embed has the 0.02 scale
        let embed = &a[0];
        let var: f64 = embed.data.iter().map(|&v| (v as f64) * (v as f64)).sum::<f64>()
            / embed.data.len() as f64;
        assert!((var.sqrt() - 0.02).abs() < 0.005, "embed std {}", var.sqrt());
    }

    /// The `small-draft` config is `small` truncated to its first
    /// layer: the shared parameter prefix (embed + layer0) is bitwise
    /// identical, which is what makes it a meaningful speculative
    /// draft rather than an unrelated random model.
    #[test]
    fn small_draft_shares_small_param_prefix() {
        let target = builtin_manifest("small").unwrap();
        let draft = builtin_manifest("small-draft").unwrap();
        assert_eq!(draft.model.vocab, target.model.vocab);
        assert_eq!(draft.model.d, target.model.d);
        assert_eq!(draft.model.seq_len, target.model.seq_len);
        assert_eq!(draft.model.n_layers, 1);
        let tp = init_params(&target).unwrap();
        let dp = init_params(&draft).unwrap();
        assert!(dp.len() < tp.len());
        for (spec, value) in draft.params.iter().zip(&dp) {
            let (tspec, tvalue) = target
                .params
                .iter()
                .zip(&tp)
                .find(|(p, _)| p.name == spec.name)
                .unwrap_or_else(|| panic!("{} missing from small", spec.name));
            assert_eq!(tspec.shape, spec.shape, "{}", spec.name);
            assert_eq!(tvalue, value, "{} diverged from the target's copy", spec.name);
        }
    }

    #[test]
    fn native_executes_builtin_grad_step() {
        let m = builtin_manifest("gran2").unwrap();
        let be = NativeBackend::new();
        let spec = m.artifacts["lm_grad_step_tc"].clone();
        let exe = be
            .compile(Path::new("unused"), "lm_grad_step_tc", &spec, &m)
            .unwrap();
        let params = init_params(&m).unwrap();
        let mut vals: Vec<Value> = params.into_iter().map(Value::F32).collect();
        let tok_shape = spec.inputs.last().unwrap().shape.clone();
        let nt: usize = tok_shape.iter().product();
        let tokens: Vec<i32> = (0..nt).map(|i| (i * 13 % m.model.vocab) as i32).collect();
        vals.push(Value::i32(&tok_shape, tokens).unwrap());
        let outs = exe.execute(&vals).unwrap();
        assert_eq!(outs.len(), spec.outputs.len());
        let loss = outs[0].scalar_f32().unwrap();
        let ce = outs[1].scalar_f32().unwrap();
        assert!(loss.is_finite() && ce.is_finite() && loss >= ce);
        // untrained CE should be near ln(vocab)
        let lnv = (m.model.vocab as f32).ln();
        assert!((ce - lnv).abs() < 1.5, "ce {ce} vs ln V {lnv}");
        // grads have the declared shapes and are finite
        for (o, ospec) in outs[2..].iter().zip(&spec.outputs[2..]) {
            let t = o.as_f32().unwrap();
            assert_eq!(t.shape, ospec.shape, "{}", ospec.name);
            assert!(t.data.iter().all(|x| x.is_finite()), "{}", ospec.name);
        }
    }

    #[test]
    fn native_decode_step_executes() {
        let m = builtin_manifest("gran2").unwrap();
        let be = NativeBackend::new();
        let spec = m.artifacts["lm_decode_step_b2"].clone();
        let exe = be
            .compile(Path::new("unused"), "lm_decode_step_b2", &spec, &m)
            .unwrap();
        let params = init_params(&m).unwrap();
        let mut vals: Vec<Value> = params.into_iter().map(Value::F32).collect();
        let tok_shape = spec.inputs[spec.inputs.len() - 2].shape.clone();
        let (rows, seq) = (tok_shape[0], tok_shape[1]);
        let tokens: Vec<i32> = (0..rows * seq).map(|i| (i * 11 % m.model.vocab) as i32).collect();
        vals.push(Value::i32(&tok_shape, tokens).unwrap());
        vals.push(Value::i32(&[rows], vec![3, seq as i32]).unwrap());
        let outs = exe.execute(&vals).unwrap();
        assert_eq!(outs.len(), 1);
        let t = outs[0].as_f32().unwrap();
        assert_eq!(t.shape, vec![rows, m.model.vocab]);
        assert!(t.data.iter().all(|x| x.is_finite()));
        // the two rows read different prefixes -> different logits
        let v = m.model.vocab;
        assert!(t.data[..v]
            .iter()
            .zip(&t.data[v..])
            .any(|(a, b)| (a - b).abs() > 1e-9));
    }

    #[test]
    fn native_eval_and_moe_layer_execute() {
        let m = builtin_manifest("gran2").unwrap();
        let be = NativeBackend::new();
        let params = init_params(&m).unwrap();

        let spec = m.artifacts["lm_eval"].clone();
        let exe = be.compile(Path::new("unused"), "lm_eval", &spec, &m).unwrap();
        let mut vals: Vec<Value> = params.iter().cloned().map(Value::F32).collect();
        let tok_shape = spec.inputs.last().unwrap().shape.clone();
        let nt: usize = tok_shape.iter().product();
        vals.push(Value::i32(&tok_shape, (0..nt).map(|i| (i % 7) as i32).collect()).unwrap());
        let outs = exe.execute(&vals).unwrap();
        let ce = outs[0].scalar_f32().unwrap();
        assert!(ce.is_finite() && ce > 0.0);
        // second output: per-row CE whose mean is the batch CE
        let rows_t = outs[1].as_f32().unwrap();
        assert_eq!(rows_t.shape, vec![tok_shape[0]]);
        let mean: f32 = rows_t.data.iter().sum::<f32>() / rows_t.data.len() as f32;
        assert!((mean - ce).abs() < 1e-5, "row mean {mean} vs batch ce {ce}");

        let spec = m.artifacts["moe_layer_fwd_tr"].clone();
        let exe = be.compile(Path::new("unused"), "moe_layer_fwd_tr", &spec, &m).unwrap();
        let mut rng = Prng::new(3);
        let vals: Vec<Value> = spec
            .inputs
            .iter()
            .map(|ts| {
                let n: usize = ts.shape.iter().product();
                let data: Vec<f32> = (0..n).map(|_| rng.normal() as f32 * 0.2).collect();
                Value::F32(Tensor::from_vec(&ts.shape, data).unwrap())
            })
            .collect();
        let outs = exe.execute(&vals).unwrap();
        assert_eq!(outs[0].shape(), spec.outputs[0].shape.as_slice());
        assert!(outs[1].scalar_f32().unwrap().is_finite());
    }
}
