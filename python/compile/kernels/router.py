"""MoE routing methods: TC top-K, token rounding (Algorithm 4), EC, drop.

Token rounding (TR) is the paper's tile-aware router. Given post-softmax
scores ``S`` in (0, 1):

1. **TC sorting** — plain top-K token choice gives mask ``pi_tc`` and
   per-expert frequencies ``f_e``.
2. **Rounding** — a ``round_and_sparsify`` subroutine picks a target
   ``g_e ∈ {⌊f_e⌋_M, ⌈f_e⌉_M}`` per expert (Appendix G.2 subroutines:
   NR-f, SR-f, NR-s, Balance-f, UP, DOWN).
3. **TC-preferred score matrix** — ``S' = S`` on TC-selected entries and
   ``S - 2`` elsewhere, so *every* TC token outranks *every* non-TC (EC
   candidate) token of the same expert.
4. **Expert-wise ranking** — expert ``e`` keeps its top ``g_e`` tokens by
   ``S'``: if ``g_e < f_e`` the lowest-score TC tokens are dropped, if
   ``g_e > f_e`` the best non-TC tokens are padded in (EC-style).

Guarantee: each expert's deviation from TC top-K is < one tile, and every
``g_e`` is a multiple of ``M_tile`` — zero grouped-GEMM padding waste.

Everything is static-shape jax (masks of shape (T, E)), so the router can
live inside the AOT-compiled train step. Rounding decisions are
non-differentiable (wrapped in stop_gradient); gradients flow to the
router weights only through the *scores* of routed tokens (dS), exactly
as in the paper's formulation.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

SUBROUTINES = ("nr-f", "sr-f", "nr-s", "balance-f", "up", "down")


class RoutingDecision(NamedTuple):
    pi: jnp.ndarray  # (T, E) binary mask
    scores: jnp.ndarray  # (T, E) sparsified scores (raw, not renormalized)
    f: jnp.ndarray  # (E,) TC frequencies (before rounding)
    g: jnp.ndarray  # (E,) final per-expert token counts


def topk_indices(scores: jnp.ndarray, k: int) -> jnp.ndarray:
    """Row-wise argtop-K, descending, ties to the lower index.

    Implemented with a stable argsort instead of ``jax.lax.top_k``: the
    TopK HLO instruction jax emits carries a ``largest`` attribute the
    pinned XLA 0.5.1 text parser rejects, while ``sort`` round-trips.
    Same tie-break semantics as lax.top_k (and as SonicMoE's stable
    bitonic kernel, Appendix D). Indices are integers: stop_gradient
    keeps autodiff out of the sort.
    """
    order = jnp.argsort(-jax.lax.stop_gradient(scores), axis=-1, stable=True)
    return order[..., :k]


def tc_topk(scores: jnp.ndarray, k: int) -> RoutingDecision:
    """Vanilla token-choice top-K routing."""
    idx = topk_indices(scores, k)
    t = scores.shape[0]
    pi = jnp.zeros_like(scores).at[jnp.arange(t)[:, None], idx].set(1.0)
    f = jnp.sum(pi, axis=0).astype(jnp.int32)
    return RoutingDecision(pi=pi, scores=scores * pi, f=f, g=f)


def _floor_ceil(f: jnp.ndarray, m: int):
    lo = (f // m) * m
    hi = ((f + m - 1) // m) * m
    return lo, hi


def _round_subroutine(
    name: str,
    f: jnp.ndarray,
    m: int,
    scores: jnp.ndarray | None = None,
    pi_tc: jnp.ndarray | None = None,
    rank: jnp.ndarray | None = None,
    key: jax.Array | None = None,
) -> jnp.ndarray:
    """round_and_sparsify: per-expert binary choice between ⌊f⌋_M and ⌈f⌉_M."""
    lo, hi = _floor_ceil(f, m)
    if name == "up":
        return hi
    if name == "down":
        return lo
    if name == "nr-f":
        # pad EC tokens iff ceil is strictly closer (ties round down)
        return jnp.where(hi - f < f - lo, hi, lo)
    if name == "sr-f":
        assert key is not None, "sr-f needs a PRNG key"
        p = (f - lo).astype(jnp.float32) / float(m)
        up = jax.random.bernoulli(key, p)
        return jnp.where(up, hi, lo)
    if name == "nr-s":
        # Eq. 13: Bernoulli on the score mass between the two roundings.
        assert key is not None and scores is not None and rank is not None
        in_lo = (rank < lo[None, :]).astype(jnp.float32)
        in_hi = (rank < hi[None, :]).astype(jnp.float32)
        in_f = (rank < f[None, :]).astype(jnp.float32)
        s_lo = jnp.sum(scores * in_lo, axis=0)
        s_hi = jnp.sum(scores * in_hi, axis=0)
        s_f = jnp.sum(scores * in_f, axis=0)
        p = jnp.where(s_hi > s_lo, (s_f - s_lo) / jnp.maximum(s_hi - s_lo, 1e-9), 0.0)
        up = jax.random.bernoulli(key, jnp.clip(p, 0.0, 1.0))
        return jnp.where(up, hi, lo)
    if name == "balance-f":
        # Algorithm 6: greedy accumulator keeps the *total* count within
        # M/2 of sum(f) while each expert stays within M/2 of f_e.
        def step(z, fe):
            lo_e = (fe // m) * m
            hi_e = ((fe + m - 1) // m) * m
            r_up = hi_e - fe
            r_dn = lo_e - fe
            up = jnp.abs(r_up + z) < jnp.abs(r_dn + z)
            g = jnp.where(up, hi_e, lo_e)
            z = z + jnp.where(up, r_up, r_dn)
            return z, g

        _, g = jax.lax.scan(step, jnp.int32(0), f)
        return g
    raise ValueError(f"unknown rounding subroutine {name!r}")


def token_rounding(
    scores: jnp.ndarray,  # (T, E) post-softmax scores in (0, 1)
    k: int,
    m_tile: int,
    subroutine: str = "nr-f",
    key: jax.Array | None = None,
) -> RoutingDecision:
    """Algorithm 4: tile-aware token rounding routing."""
    t, e = scores.shape
    # (1) TC top-K sorting
    topk_idx = topk_indices(scores, k)
    pi_tc = jnp.zeros_like(scores).at[jnp.arange(t)[:, None], topk_idx].set(1.0)
    f = jnp.sum(pi_tc, axis=0).astype(jnp.int32)

    # (3) TC-preferred S': every TC entry outranks every non-TC entry
    # (scores are in (0,1); subtracting 2 keeps non-TC ordering intact).
    s_pref = jnp.where(pi_tc > 0, scores, scores - 2.0)

    # (4a) expert-wise rank of each token (0 = best) under S'. Ranks are
    # integers (non-differentiable); stop_gradient keeps autodiff from
    # tracing the sort (its JVP is unnecessary and broken in some jax
    # builds — decisions must not carry gradients regardless).
    s_pref_ng = jax.lax.stop_gradient(s_pref)
    order = jnp.argsort(-s_pref_ng, axis=0)
    rank = jnp.argsort(order, axis=0).astype(jnp.int32)  # (T, E)

    # (2) rounding targets, capped so g_e stays a reachable tile multiple
    g = _round_subroutine(
        subroutine, f, m_tile, scores=scores, pi_tc=pi_tc, rank=rank, key=key
    )
    g = jnp.minimum(g, (t // m_tile) * m_tile).astype(jnp.int32)
    g = jax.lax.stop_gradient(g)

    # (4b) keep the top g_e tokens per expert
    pi = (rank < g[None, :]).astype(scores.dtype)
    return RoutingDecision(pi=pi, scores=scores * pi, f=f, g=g)


def token_drop(scores: jnp.ndarray, k: int, m_tile: int) -> RoutingDecision:
    """"TC (token drop)" baseline == TR with the DOWN subroutine."""
    return token_rounding(scores, k, m_tile, subroutine="down")


def expert_choice(scores: jnp.ndarray, k: int) -> RoutingDecision:
    """EC routing (Zhou et al. 2022): each expert takes its top C = T*K/E
    tokens by column score. Breaks causality (used as a baseline only)."""
    t, e = scores.shape
    cap = max(1, (t * k) // e)
    order = jnp.argsort(-jax.lax.stop_gradient(scores), axis=0)
    rank = jnp.argsort(order, axis=0)
    pi = (rank < cap).astype(scores.dtype)
    f = jnp.sum(pi, axis=0).astype(jnp.int32)
    return RoutingDecision(pi=pi, scores=scores * pi, f=f, g=f)


def renormalize_decision(dec: RoutingDecision, eps: float = 1e-9) -> RoutingDecision:
    """Softmax renormalization over each token's selected experts (the
    paper uses this for TR; a token may have != K experts after rounding)."""
    denom = jnp.sum(dec.scores, axis=-1, keepdims=True)
    return dec._replace(scores=dec.scores / jnp.maximum(denom, eps))
