//! Small statistics helpers shared by the bench harness and metrics.

/// Summary statistics of a sample.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub median: f64,
    pub p90: f64,
    pub max: f64,
}

impl Summary {
    pub fn of(xs: &[f64]) -> Summary {
        assert!(!xs.is_empty());
        let n = xs.len();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>()
            / (n.max(2) - 1) as f64;
        let mut s = xs.to_vec();
        s.sort_by(|a, b| a.partial_cmp(b).unwrap());
        Summary {
            n,
            mean,
            std: var.sqrt(),
            min: s[0],
            median: percentile(&s, 50.0),
            p90: percentile(&s, 90.0),
            max: s[n - 1],
        }
    }
}

/// Percentile of a pre-sorted sample (linear interpolation).
pub fn percentile(sorted: &[f64], p: f64) -> f64 {
    assert!(!sorted.is_empty());
    let rank = p / 100.0 * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        let w = rank - lo as f64;
        sorted[lo] * (1.0 - w) + sorted[hi] * w
    }
}

/// Latency percentile summary (p50/p95/p99) of a sample stream.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Percentiles {
    pub n: u64,
    pub p50: f64,
    pub p95: f64,
    pub p99: f64,
    pub max: f64,
}

impl Percentiles {
    pub fn zero() -> Percentiles {
        Percentiles { n: 0, p50: 0.0, p95: 0.0, p99: 0.0, max: 0.0 }
    }
}

/// Bounded-memory quantile sketch: classic reservoir sampling
/// (Algorithm R) over a deterministic PRNG, so gateway stats and the
/// bench harness can report p50/p95/p99 of millions of request
/// latencies in O(cap) memory. With fewer than `cap` observations the
/// reservoir holds the full sample and quantiles are exact.
#[derive(Debug, Clone)]
pub struct Reservoir {
    cap: usize,
    seen: u64,
    samples: Vec<f64>,
    max: f64,
    rng: crate::util::prng::Prng,
}

impl Reservoir {
    pub fn new(cap: usize) -> Reservoir {
        assert!(cap > 0);
        Reservoir {
            cap,
            seen: 0,
            samples: Vec::with_capacity(cap.min(1024)),
            max: 0.0,
            rng: crate::util::prng::Prng::new(0x5245_5345_5256_4f49),
        }
    }

    pub fn add(&mut self, x: f64) {
        self.seen += 1;
        if self.seen == 1 || x > self.max {
            self.max = x;
        }
        if self.samples.len() < self.cap {
            self.samples.push(x);
        } else {
            // Algorithm R: keep slot j with probability cap/seen
            let j = self.rng.below(self.seen) as usize;
            if j < self.cap {
                self.samples[j] = x;
            }
        }
    }

    /// Observations seen (not the retained sample size).
    pub fn count(&self) -> u64 {
        self.seen
    }

    /// True when no observation has been recorded (an empty window has
    /// no percentiles — callers should omit them rather than report 0).
    pub fn is_empty(&self) -> bool {
        self.seen == 0
    }

    /// Quantile estimate over the retained sample (exact while
    /// `count() <= cap`). Returns 0.0 on an empty reservoir.
    pub fn quantile(&self, p: f64) -> f64 {
        if self.samples.is_empty() {
            return 0.0;
        }
        let mut s = self.samples.clone();
        s.sort_by(|a, b| a.partial_cmp(b).unwrap());
        percentile(&s, p * 100.0)
    }

    pub fn percentiles(&self) -> Percentiles {
        if self.samples.is_empty() {
            return Percentiles::zero();
        }
        let mut s = self.samples.clone();
        s.sort_by(|a, b| a.partial_cmp(b).unwrap());
        Percentiles {
            n: self.seen,
            p50: percentile(&s, 50.0),
            p95: percentile(&s, 95.0),
            p99: percentile(&s, 99.0),
            max: self.max,
        }
    }
}

/// Exponential moving average, used by the trainer's loss smoothing.
#[derive(Debug, Clone)]
pub struct Ema {
    alpha: f64,
    value: Option<f64>,
}

impl Ema {
    pub fn new(alpha: f64) -> Self {
        assert!(alpha > 0.0 && alpha <= 1.0);
        Ema { alpha, value: None }
    }

    pub fn update(&mut self, x: f64) -> f64 {
        let v = match self.value {
            None => x,
            Some(v) => v * (1.0 - self.alpha) + x * self.alpha,
        };
        self.value = Some(v);
        v
    }

    pub fn get(&self) -> Option<f64> {
        self.value
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basics() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.n, 5);
        assert_eq!(s.mean, 3.0);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert_eq!(s.median, 3.0);
        assert!((s.std - (2.5f64).sqrt()).abs() < 1e-12);
    }

    #[test]
    fn percentile_interpolates() {
        let s = [0.0, 10.0];
        assert_eq!(percentile(&s, 0.0), 0.0);
        assert_eq!(percentile(&s, 50.0), 5.0);
        assert_eq!(percentile(&s, 100.0), 10.0);
    }

    #[test]
    fn reservoir_exact_against_sorted_oracle() {
        // below cap the reservoir holds the full sample: p50/p95/p99
        // must equal the sorted-slice percentile exactly
        let mut r = Reservoir::new(2048);
        let mut xs: Vec<f64> = (0..1000).map(|i| i as f64).collect();
        // deterministic shuffle so insertion order is adversarial
        let mut rng = crate::util::prng::Prng::new(7);
        rng.shuffle(&mut xs);
        for &x in &xs {
            r.add(x);
        }
        let mut sorted = xs.clone();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let p = r.percentiles();
        assert_eq!(p.n, 1000);
        assert_eq!(p.p50, percentile(&sorted, 50.0));
        assert_eq!(p.p95, percentile(&sorted, 95.0));
        assert_eq!(p.p99, percentile(&sorted, 99.0));
        assert_eq!(p.max, 999.0);
        assert_eq!(r.quantile(0.5), percentile(&sorted, 50.0));
    }

    #[test]
    fn reservoir_subsamples_within_range() {
        // above cap the estimate is approximate but must stay in-range
        // and track the distribution roughly (uniform 0..10_000)
        let mut r = Reservoir::new(256);
        for i in 0..10_000 {
            r.add(i as f64);
        }
        assert_eq!(r.count(), 10_000);
        let p = r.percentiles();
        assert_eq!(p.max, 9999.0);
        assert!(p.p50 > 2500.0 && p.p50 < 7500.0, "p50 {}", p.p50);
        assert!(p.p95 > p.p50 && p.p99 >= p.p95);
        assert!(p.p99 <= 9999.0);
    }

    #[test]
    fn reservoir_empty_and_single() {
        let mut r = Reservoir::new(8);
        assert!(r.is_empty());
        assert_eq!(r.percentiles(), Percentiles::zero());
        assert_eq!(r.quantile(0.99), 0.0);
        r.add(5.0);
        assert!(!r.is_empty());
        let p = r.percentiles();
        assert_eq!((p.p50, p.p95, p.p99, p.max), (5.0, 5.0, 5.0, 5.0));
    }

    #[test]
    fn ema_converges() {
        let mut e = Ema::new(0.5);
        assert_eq!(e.update(10.0), 10.0);
        let v = e.update(0.0);
        assert_eq!(v, 5.0);
        for _ in 0..50 {
            e.update(2.0);
        }
        assert!((e.get().unwrap() - 2.0).abs() < 1e-6);
    }
}
