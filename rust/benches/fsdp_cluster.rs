//! Bench: regenerate Section 6.2 cluster claim via the simulator/model and time it.

use sonic_moe::bench::{figures, Bencher};

fn main() {
    figures::cluster_claim().print();
    let mut b = Bencher::new("simulator/fsdp_cluster");
    b.iter(|| figures::cluster_claim());
    println!("{}", b.report());
}
