//! Integration: the backend-generic runtime executing the AOT artifacts
//! against the goldens emitted by `python/compile/aot.py`. Skips (with
//! a notice) when `make artifacts` has not been run — on the default
//! native backend these are cross-language parity checks (rust numerics
//! vs the jax export); on PJRT they validate the HLO path.
//!
//! Hermetic native-backend coverage (no artifacts needed) lives in
//! `native_backend_parity.rs` and `integration_trainer.rs`.

use sonic_moe::runtime::{artifacts_available, Runtime, Value};
use sonic_moe::util::tensor::{read_i32_bin, Tensor};

const DIR: &str = "artifacts";

fn runtime() -> Option<Runtime> {
    if !artifacts_available(DIR) {
        eprintln!("skipping: no artifacts (run `make artifacts`)");
        return None;
    }
    Some(Runtime::open(DIR, "small").expect("open runtime"))
}

fn read_golden(rt: &Runtime, rel: &str, shape: &[usize]) -> Tensor {
    Tensor::read_f32_bin(rt.path(rel).to_str().unwrap(), shape).expect("golden read")
}

#[test]
fn moe_layer_forward_matches_python_golden() {
    let Some(mut rt) = runtime() else { return };
    for tag in ["tc", "tr"] {
        let name = format!("moe_layer_fwd_{tag}");
        let spec = rt.manifest.artifacts[&name].clone();
        let g = spec.golden.as_ref().expect("golden block");
        let in_files: Vec<String> = g
            .get("inputs")
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .map(|f| f.as_str().unwrap().to_string())
            .collect();
        let inputs: Vec<Tensor> = in_files
            .iter()
            .zip(&spec.inputs)
            .map(|(f, ts)| read_golden(&rt, f, &ts.shape))
            .collect();
        let want_o = read_golden(
            &rt,
            g.get("output_o").unwrap().as_str().unwrap(),
            &spec.outputs[0].shape,
        );
        let want_aux = g.get("output_aux").unwrap().as_f64().unwrap();

        let art = rt.artifact(&name).expect("compile artifact");
        let refs: Vec<&Tensor> = inputs.iter().collect();
        let outs = art.execute_tensors(&refs).expect("execute");
        assert_eq!(outs.len(), 2, "{name}");
        let got_o = &outs[0];
        assert_eq!(got_o.shape, want_o.shape);
        let diff = got_o.max_abs_diff(&want_o);
        assert!(diff < 1e-4, "{name}: max |Δo| = {diff}");
        let got_aux = outs[1].data[0] as f64;
        assert!((got_aux - want_aux).abs() < 1e-4, "{name}: aux {got_aux} vs {want_aux}");
    }
}

#[test]
fn lm_grad_step_matches_python_golden() {
    let Some(mut rt) = runtime() else { return };
    let m = rt.manifest.clone();
    let gold = m.golden_lm.as_ref().expect("golden_lm");
    let tok_file = gold.get("tokens_file").unwrap().as_str().unwrap();
    let shape = [m.model.batch, m.model.seq_len];
    let (_, tokens) =
        read_i32_bin(rt.path(tok_file).to_str().unwrap(), &shape).expect("tokens");

    let params = rt.load_initial_params().expect("params");
    let mut vals: Vec<Value> = params.into_iter().map(Value::F32).collect();
    vals.push(Value::i32(&shape, tokens).unwrap());

    let art = rt.artifact("lm_grad_step_tc").expect("compile");
    let outs = art.execute(&vals).expect("execute");
    let loss = outs[0].scalar_f32().unwrap() as f64;
    let ce = outs[1].scalar_f32().unwrap() as f64;
    let want_loss = gold.get("loss").unwrap().as_f64().unwrap();
    let want_ce = gold.get("ce").unwrap().as_f64().unwrap();
    assert!((loss - want_loss).abs() < 5e-4, "loss {loss} vs {want_loss}");
    assert!((ce - want_ce).abs() < 5e-4, "ce {ce} vs {want_ce}");

    // per-parameter gradient L1 norms match python
    let grad_l1 = gold.get("grad_l1").unwrap().as_obj().unwrap();
    for (i, p) in m.params.iter().enumerate() {
        let g = outs[2 + i].as_f32().unwrap();
        let want = grad_l1[&p.name].as_f64().unwrap();
        let got = g.l1();
        let tol = 1e-3 * want.abs().max(1.0);
        assert!(
            (got - want).abs() < tol,
            "grad_l1[{}] = {got} vs {want}",
            p.name
        );
    }
}

#[test]
fn eval_artifact_consistent_with_grad_step_ce() {
    let Some(mut rt) = runtime() else { return };
    let m = rt.manifest.clone();
    let shape = [m.model.batch, m.model.seq_len];
    // deterministic but different tokens than the golden
    let tokens: Vec<i32> =
        (0..shape[0] * shape[1]).map(|i| (i * 37 % m.model.vocab) as i32).collect();
    let params = rt.load_initial_params().unwrap();
    let mut vals: Vec<Value> = params.into_iter().map(Value::F32).collect();
    vals.push(Value::i32(&shape, tokens).unwrap());

    let ce_eval = {
        let art = rt.artifact("lm_eval").unwrap();
        art.execute(&vals).unwrap()[0].scalar_f32().unwrap()
    };
    let ce_grad = {
        let art = rt.artifact("lm_grad_step_tc").unwrap();
        art.execute(&vals).unwrap()[1].scalar_f32().unwrap()
    };
    assert!((ce_eval - ce_grad).abs() < 1e-5, "{ce_eval} vs {ce_grad}");
}

#[test]
fn initial_params_match_manifest_layout() {
    let Some(rt) = runtime() else { return };
    let params = rt.load_initial_params().unwrap();
    assert_eq!(params.len(), rt.manifest.params.len());
    let total: usize = params.iter().map(|p| p.numel()).sum();
    assert_eq!(total, rt.manifest.num_params);
    for (t, spec) in params.iter().zip(&rt.manifest.params) {
        assert_eq!(t.shape, spec.shape, "{}", spec.name);
        assert!(t.data.iter().all(|x| x.is_finite()), "{}", spec.name);
    }
}
