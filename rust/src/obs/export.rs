//! Chrome trace-event JSON rendering of a recorder [`Snapshot`].
//!
//! The output opens directly in `chrome://tracing` or Perfetto:
//!
//! - every registered recording thread becomes one **thread track**
//!   (an `"M"` thread-name metadata event), and every thread-scoped
//!   event (`trace_id == 0`) a complete `"X"` interval on it —
//!   batch/decode loops with their kernel, fault-wait and prefetch
//!   children nested inside;
//! - every sampled request becomes one **async track** keyed by its
//!   16-hex-digit trace id: each request-scoped event renders as a
//!   `"b"`/`"e"` pair under `cat: "request"`, so the queue-wait →
//!   batch → exec ladder of one request reads top to bottom regardless
//!   of which threads executed it.
//!
//! Timestamps are microseconds (the trace-event spec's unit) with
//! nanosecond precision kept in the fraction. `scripts/check_trace.py`
//! validates the schema and span-tree well-formedness in CI.

use std::fmt::Write as _;

use super::recorder::{Event, Snapshot};
use super::span::{trace_hex, SpanKind};

/// Render the kind-specific `detail` payload as Chrome `args` JSON
/// (without braces), or `None` when the kind carries no payload.
fn detail_args(kind: SpanKind, detail: u64) -> Option<String> {
    let hi = detail >> 32;
    let lo = detail & 0xffff_ffff;
    match kind {
        SpanKind::Request | SpanKind::QueueWait | SpanKind::GenQueueWait => None,
        SpanKind::BatchForm => Some(format!("\"rows\":{detail}")),
        SpanKind::BatchExec => Some(format!("\"rows\":{detail}")),
        SpanKind::Prefill => Some(format!("\"prompt_tokens\":{detail}")),
        SpanKind::DecodeStep => Some(format!("\"live_rows\":{hi},\"padding_rows\":{lo}")),
        SpanKind::Drain => Some(format!("\"sequences\":{detail}")),
        SpanKind::SpecPropose => Some(format!("\"proposed\":{detail}")),
        SpanKind::SpecVerify => Some(format!("\"proposed\":{hi},\"accepted\":{lo}")),
        SpanKind::SpecRollback => Some(format!("\"rejected\":{detail}")),
        SpanKind::Gemm | SpanKind::FusedExpert => Some(format!("\"flops\":{detail}")),
        SpanKind::FaultWait | SpanKind::Prefetch => {
            Some(format!("\"layer\":{hi},\"expert\":{lo}"))
        }
        SpanKind::RouteDecide => Some(format!("\"replica\":{detail}")),
        SpanKind::RetryWait => Some(format!("\"attempt\":{detail}")),
        SpanKind::Failover => Some(format!("\"attempts\":{detail}")),
    }
}

/// Microsecond timestamp with the nanosecond fraction kept.
fn us(ns: u64) -> String {
    format!("{:.3}", ns as f64 / 1000.0)
}

fn push_event(out: &mut String, body: &str) {
    if !out.ends_with('[') {
        out.push(',');
    }
    out.push_str(body);
}

/// Render a snapshot as a complete Chrome trace-event JSON document.
pub fn chrome_trace(snap: &Snapshot) -> String {
    let mut out = String::with_capacity(128 + snap.events.len() * 160);
    out.push_str("{\"displayTimeUnit\":\"ms\",\"traceEvents\":[");
    for (tid, name) in &snap.threads {
        // thread-name metadata; the name is user-controlled, escape it
        let escaped = crate::util::json::Json::Str(name.clone()).to_string();
        push_event(
            &mut out,
            &format!(
                "{{\"ph\":\"M\",\"pid\":1,\"tid\":{tid},\"name\":\"thread_name\",\
                 \"args\":{{\"name\":{escaped}}}}}"
            ),
        );
    }
    for e in &snap.events {
        render_event(&mut out, e);
    }
    out.push_str("]}");
    out
}

fn render_event(out: &mut String, e: &Event) {
    let name = e.kind.name();
    let args = detail_args(e.kind, e.detail);
    if e.trace_id == 0 {
        // thread track: one complete interval
        let mut body = format!(
            "{{\"ph\":\"X\",\"pid\":1,\"tid\":{},\"ts\":{},\"dur\":{},\"name\":\"{}\"",
            e.thread,
            us(e.t_start_ns),
            us(e.t_end_ns.saturating_sub(e.t_start_ns)),
            name
        );
        if let Some(a) = args {
            let _ = write!(body, ",\"args\":{{{a}}}");
        }
        body.push('}');
        push_event(out, &body);
    } else {
        // request track: an async begin/end pair keyed by the trace id
        let id = trace_hex(e.trace_id);
        let mut begin = format!(
            "{{\"ph\":\"b\",\"cat\":\"request\",\"id\":\"{}\",\"pid\":1,\"tid\":{},\
             \"ts\":{},\"name\":\"{}\"",
            id,
            e.thread,
            us(e.t_start_ns),
            name
        );
        match args {
            Some(a) => {
                let _ = write!(begin, ",\"args\":{{\"trace\":\"{id}\",{a}}}}}");
            }
            None => {
                let _ = write!(begin, ",\"args\":{{\"trace\":\"{id}\"}}}}");
            }
        }
        push_event(out, &begin);
        push_event(
            out,
            &format!(
                "{{\"ph\":\"e\",\"cat\":\"request\",\"id\":\"{}\",\"pid\":1,\"tid\":{},\
                 \"ts\":{},\"name\":\"{}\"}}",
                id,
                e.thread,
                us(e.t_end_ns),
                name
            ),
        );
    }
}

/// Render and write a snapshot to `path`. Returns the number of
/// recorder events exported.
pub fn write_chrome_trace(path: &str, snap: &Snapshot) -> anyhow::Result<usize> {
    let body = chrome_trace(snap);
    std::fs::write(path, body)
        .map_err(|e| anyhow::anyhow!("writing trace to {path}: {e}"))?;
    Ok(snap.events.len())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ev(trace: u64, kind: SpanKind, t0: u64, t1: u64, thread: u32, detail: u64) -> Event {
        Event { trace_id: trace, kind, t_start_ns: t0, t_end_ns: t1, thread, detail }
    }

    fn sample_snapshot() -> Snapshot {
        Snapshot {
            threads: vec![(1, "score-worker-0".into()), (2, "decode \"sched\"".into())],
            events: vec![
                ev(0, SpanKind::BatchForm, 1_000, 5_000, 1, 3),
                ev(0, SpanKind::Gemm, 2_000, 4_000, 1, 99_000),
                ev(0xabc, SpanKind::QueueWait, 500, 5_000, 1, 0),
                ev(0xabc, SpanKind::BatchExec, 5_000, 9_000, 1, 4),
                ev(0, SpanKind::DecodeStep, 1_000, 2_000, 2, (3 << 32) | 1),
            ],
            dropped: 0,
        }
    }

    #[test]
    fn export_parses_as_json_with_expected_phases() {
        let body = chrome_trace(&sample_snapshot());
        let j = crate::util::json::Json::parse(&body).expect("export must be valid JSON");
        let evs = j.get("traceEvents").unwrap().as_arr().unwrap().clone();
        let phase = |e: &crate::util::json::Json| {
            e.get("ph").unwrap().as_str().unwrap().to_string()
        };
        let phases: Vec<String> = evs.iter().map(phase).collect();
        assert_eq!(phases.iter().filter(|p| *p == "M").count(), 2, "one M per thread");
        assert_eq!(phases.iter().filter(|p| *p == "X").count(), 3, "thread-track spans");
        assert_eq!(phases.iter().filter(|p| *p == "b").count(), 2, "async begins");
        assert_eq!(phases.iter().filter(|p| *p == "e").count(), 2, "async ends");
        // the async pair carries the zero-padded trace id
        assert!(body.contains("\"id\":\"0000000000000abc\""));
        // detail payloads unpack
        assert!(body.contains("\"live_rows\":3,\"padding_rows\":1"));
        assert!(body.contains("\"flops\":99000"));
    }

    #[test]
    fn thread_names_are_escaped() {
        let body = chrome_trace(&sample_snapshot());
        assert!(body.contains("decode \\\"sched\\\""), "quotes in thread names escape");
        crate::util::json::Json::parse(&body).unwrap();
    }

    #[test]
    fn timestamps_are_microseconds_with_ns_precision() {
        let snap = Snapshot {
            threads: vec![(1, "t".into())],
            events: vec![ev(0, SpanKind::Gemm, 1_234, 5_678, 1, 1)],
            dropped: 0,
        };
        let body = chrome_trace(&snap);
        assert!(body.contains("\"ts\":1.234"), "{body}");
        assert!(body.contains("\"dur\":4.444"), "{body}");
    }

    #[test]
    fn empty_snapshot_renders_empty_document() {
        let body = chrome_trace(&Snapshot::default());
        let j = crate::util::json::Json::parse(&body).unwrap();
        assert!(j.get("traceEvents").unwrap().as_arr().unwrap().is_empty());
    }
}
