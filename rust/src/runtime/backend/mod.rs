//! Execution-backend abstraction.
//!
//! The runtime above this layer speaks one contract: open a config,
//! compile an artifact by manifest name, execute positional tensors per
//! the manifest's [`ArtifactSpec`] signature. Everything below is a
//! [`Backend`]:
//!
//! - [`native`] — pure-rust CPU execution of the LM/MoE artifact
//!   contracts (the reference numerics of `python/compile/kernels/ref.py`
//!   ported onto `util::tensor` + `routing`). Hermetic: needs no python,
//!   no HLO files, no external runtime; can synthesize built-in configs
//!   when no `make artifacts` output exists.
//! - [`pjrt`] *(cargo feature `pjrt`, non-default)* — the original
//!   AOT-HLO path through the `xla` PJRT binding.
//!
//! Select with `SONIC_BACKEND=native|pjrt` (default `native`).

pub mod native;
#[cfg(feature = "pjrt")]
pub mod pjrt;

use std::path::Path;

use anyhow::{bail, Result};

use crate::runtime::manifest::{ArtifactSpec, ConfigManifest, TensorSpec};
use crate::util::tensor::Tensor;

/// A positional argument/result: what flows across the backend boundary.
///
/// The artifact signatures only ever use f32 arrays (params,
/// activations, scalars) and i32 arrays (token ids), so a two-armed enum
/// covers the whole contract.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    F32(Tensor),
    I32 { shape: Vec<usize>, data: Vec<i32> },
}

impl Value {
    /// Build an i32 value (token inputs), validating the element count.
    pub fn i32(shape: &[usize], data: Vec<i32>) -> Result<Value> {
        let want: usize = shape.iter().product();
        if data.len() != want {
            bail!("shape {shape:?} wants {want} elems, got {}", data.len());
        }
        Ok(Value::I32 { shape: shape.to_vec(), data })
    }

    pub fn shape(&self) -> &[usize] {
        match self {
            Value::F32(t) => &t.shape,
            Value::I32 { shape, .. } => shape,
        }
    }

    pub fn dtype(&self) -> &'static str {
        match self {
            Value::F32(_) => "float32",
            Value::I32 { .. } => "int32",
        }
    }

    pub fn as_f32(&self) -> Result<&Tensor> {
        match self {
            Value::F32(t) => Ok(t),
            Value::I32 { .. } => bail!("expected f32 value, got i32"),
        }
    }

    pub fn into_f32(self) -> Result<Tensor> {
        match self {
            Value::F32(t) => Ok(t),
            Value::I32 { .. } => bail!("expected f32 value, got i32"),
        }
    }

    pub fn as_i32(&self) -> Result<(&[usize], &[i32])> {
        match self {
            Value::I32 { shape, data } => Ok((shape, data)),
            Value::F32(_) => bail!("expected i32 value, got f32"),
        }
    }

    /// Scalar f32 readout (loss/ce/aux outputs).
    pub fn scalar_f32(&self) -> Result<f32> {
        let t = self.as_f32()?;
        if t.numel() != 1 {
            bail!("expected scalar, got shape {:?}", t.shape);
        }
        Ok(t.data[0])
    }

    /// Does this value match a manifest tensor spec?
    pub fn matches(&self, spec: &TensorSpec) -> bool {
        self.shape() == spec.shape.as_slice() && self.dtype() == spec.dtype
    }
}

impl From<Tensor> for Value {
    fn from(t: Tensor) -> Value {
        Value::F32(t)
    }
}

/// A compiled artifact, ready to execute.
///
/// Not `Send`: device-backed executables (PJRT) may hold thread-affine
/// handles; the coordinator owns one runtime per thread.
pub trait Executable {
    /// Execute with positional inputs; returns the flattened output
    /// tuple in the order declared by the artifact spec.
    fn execute(&self, inputs: &[Value]) -> Result<Vec<Value>>;
}

/// An execution backend: compiles manifest artifacts into executables.
pub trait Backend {
    fn name(&self) -> &'static str;

    /// Compile one artifact. `dir` is the artifact directory (file-based
    /// backends read HLO from it; the native backend ignores it).
    fn compile(
        &self,
        dir: &Path,
        name: &str,
        spec: &ArtifactSpec,
        manifest: &ConfigManifest,
    ) -> Result<Box<dyn Executable>>;

    /// Synthesize a built-in config manifest when no artifacts directory
    /// exists. File-based backends cannot (they need compiled HLO).
    fn builtin_manifest(&self, config_name: &str) -> Option<ConfigManifest> {
        let _ = config_name;
        None
    }
}

/// Resolve a backend by name; `""` falls back to [`default_backend`]
/// (the `SONIC_BACKEND` env var, native unless set).
pub fn by_name(name: &str) -> Result<Box<dyn Backend>> {
    match name {
        "" => default_backend(),
        "native" => Ok(Box::new(native::NativeBackend::new())),
        #[cfg(feature = "pjrt")]
        "pjrt" => Ok(Box::new(pjrt::PjrtBackend::new()?)),
        #[cfg(not(feature = "pjrt"))]
        "pjrt" => bail!(
            "backend \"pjrt\" requested but this binary was built without the \
             `pjrt` cargo feature (rebuild with `--features pjrt`)"
        ),
        other => bail!("unknown backend {other:?} (native|pjrt)"),
    }
}

/// The backend selected by `SONIC_BACKEND` (default: native).
pub fn default_backend() -> Result<Box<dyn Backend>> {
    match std::env::var("SONIC_BACKEND") {
        Err(_) => Ok(Box::new(native::NativeBackend::new())),
        Ok(name) if name.is_empty() || name == "native" => {
            Ok(Box::new(native::NativeBackend::new()))
        }
        Ok(name) => by_name(&name),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn value_accessors() {
        let v = Value::F32(Tensor::from_vec(&[2, 2], vec![1.0, 2.0, 3.0, 4.0]).unwrap());
        assert_eq!(v.shape(), &[2, 2]);
        assert_eq!(v.dtype(), "float32");
        assert!(v.as_f32().is_ok());
        assert!(v.as_i32().is_err());
        assert!(v.scalar_f32().is_err());

        let s = Value::F32(Tensor::from_vec(&[], vec![7.5]).unwrap());
        assert_eq!(s.scalar_f32().unwrap(), 7.5);

        let i = Value::i32(&[2, 3], vec![0, 1, 2, 3, 4, 5]).unwrap();
        assert_eq!(i.dtype(), "int32");
        assert_eq!(i.as_i32().unwrap().1.len(), 6);
        assert!(Value::i32(&[2], vec![1]).is_err());
    }

    #[test]
    fn value_matches_spec() {
        let spec = TensorSpec { name: "tokens".into(), shape: vec![2, 3], dtype: "int32".into() };
        let good = Value::i32(&[2, 3], vec![0; 6]).unwrap();
        let bad_shape = Value::i32(&[3, 2], vec![0; 6]).unwrap();
        let bad_dtype = Value::F32(Tensor::zeros(&[2, 3]));
        assert!(good.matches(&spec));
        assert!(!bad_shape.matches(&spec));
        assert!(!bad_dtype.matches(&spec));
    }

    #[test]
    fn default_backend_is_native() {
        // do not mutate the env in tests (parallel test runner); just
        // check the default resolution path
        if std::env::var("SONIC_BACKEND").is_err() {
            assert_eq!(default_backend().unwrap().name(), "native");
        }
    }
}
