//! Hermetic tiered-residency integration tests: a real TCP gateway
//! serving with a `resident_bytes` cap below the total expert bytes,
//! so every core spills its expert weights to disk and faults/
//! prefetches them back under LRU eviction.
//!
//! The load-bearing guarantees:
//!
//! - **bitwise identity**: score CE and greedy generate streams from
//!   the capped gateway equal the fully-resident gateway's exactly
//!   (the spill tier holds the same bits, and the acquire guard pins a
//!   blob for the whole GEMM);
//! - **observability**: the `stats` reply carries a `residency` block
//!   and the Prometheus `metrics` scrape carries nonzero
//!   `sonic_residency_hits_total` / `sonic_residency_evictions_total`
//!   series, plus the live/capacity KV gauges;
//! - **hygiene**: spill files live under the configured `spill_dir`
//!   and are deleted when the gateway drains.
//!
//! `SONIC_TEST_DTYPE=bf16` reruns the suite at bf16 storage precision
//! (the spill tier then holds u16 words; identity still binds because
//! the capped and dense gateways share one precision).

use std::io::{BufRead, BufReader, Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::time::Duration;

use sonic_moe::coordinator::decode::DecodeCore;
use sonic_moe::gateway::{BatchPolicy, ClientMsg, Gateway, GatewayConfig, ServerMsg, SlotPolicy};
use sonic_moe::memory::residency::ResidencySpec;
use sonic_moe::util::dtype::Dtype;
use sonic_moe::util::json::Json;

const NO_ARTIFACTS: &str = "/nonexistent-artifacts-dir";
const MAX_NEW: usize = 6;

/// Storage precision under test: `SONIC_TEST_DTYPE` (default f32).
fn test_dtype() -> Dtype {
    match std::env::var("SONIC_TEST_DTYPE") {
        Ok(s) => Dtype::parse(&s).expect("SONIC_TEST_DTYPE must be f32 or bf16"),
        Err(_) => Dtype::F32,
    }
}

fn base_cfg(resident_bytes: usize, spill_dir: Option<String>) -> GatewayConfig {
    GatewayConfig {
        artifacts_dir: NO_ARTIFACTS.to_string(),
        config: "small".to_string(),
        backend: "native".to_string(),
        addr: "127.0.0.1:0".to_string(),
        workers: 1,
        queue_cap: 16,
        policy: BatchPolicy::Immediate,
        m_tile: 2,
        decode_slots: 4,
        gen_max_new: 8,
        slot_policy: SlotPolicy::TileQuantized,
        dtype: test_dtype(),
        resident_bytes,
        spill_dir,
        ..GatewayConfig::default()
    }
}

/// (total expert bytes, one blob's bytes) per store at the test dtype.
fn expert_sizes() -> (usize, usize) {
    let spec = ResidencySpec::new(usize::MAX, None);
    let probe =
        DecodeCore::new_with_residency(NO_ARTIFACTS, "small", "native", 1, 0, test_dtype(), &spec)
            .expect("open tiered probe core");
    let store = probe.residency().expect("tiered core has a store");
    (store.spilled_bytes(), store.blob_bytes())
}

struct Client {
    stream: TcpStream,
    reader: BufReader<TcpStream>,
}

impl Client {
    fn connect(addr: SocketAddr) -> Client {
        let stream = TcpStream::connect(addr).expect("connect to gateway");
        stream.set_nodelay(true).unwrap();
        stream.set_read_timeout(Some(Duration::from_secs(60))).unwrap();
        let reader = BufReader::new(stream.try_clone().unwrap());
        Client { stream, reader }
    }

    fn send(&mut self, msg: &ClientMsg) {
        self.stream.write_all(msg.encode().as_bytes()).unwrap();
        self.stream.write_all(b"\n").unwrap();
        self.stream.flush().unwrap();
    }

    fn recv(&mut self) -> ServerMsg {
        let mut line = String::new();
        let n = self.reader.read_line(&mut line).expect("read reply");
        assert!(n > 0, "gateway closed the connection unexpectedly");
        ServerMsg::parse(&line).expect("parse reply")
    }

    /// Prometheus scrape: the gateway writes the exposition body and
    /// closes the connection, so read to EOF.
    fn metrics(mut self) -> String {
        self.send(&ClientMsg::Metrics);
        let mut body = String::new();
        self.reader.read_to_string(&mut body).expect("read metrics body");
        body
    }
}

/// Score three fixed requests and run one greedy generate stream;
/// returns (per-request CE, generated tokens) for identity checks.
fn score_and_generate(addr: SocketAddr) -> (Vec<f64>, Vec<i32>) {
    let mut cl = Client::connect(addr);
    let mut ces = Vec::new();
    for i in 0..3u64 {
        let len = 7 + (i as usize) * 11;
        let tokens: Vec<i32> = (0..len).map(|j| ((i as usize * 31 + j * 7 + 1) % 256) as i32).collect();
        cl.send(&ClientMsg::Score { id: i, tokens });
        match cl.recv() {
            ServerMsg::Score { id, ce, .. } => {
                assert_eq!(id, i);
                ces.push(ce);
            }
            other => panic!("expected score, got {other:?}"),
        }
    }
    let prompt: Vec<i32> = (0..6).map(|j| ((j * 17 + 3) % 256) as i32).collect();
    cl.send(&ClientMsg::Generate { id: 99, tokens: prompt, max_new: MAX_NEW, opts: Default::default() });
    let mut streamed = Vec::new();
    loop {
        match cl.recv() {
            ServerMsg::Token { id, token, index } => {
                assert_eq!(id, 99);
                assert_eq!(index, streamed.len());
                streamed.push(token);
            }
            ServerMsg::Done { id, tokens, .. } => {
                assert_eq!(id, 99);
                assert_eq!(tokens, streamed, "done frame disagrees with streamed tokens");
                return (ces, streamed);
            }
            other => panic!("unexpected frame {other:?}"),
        }
    }
}

fn stats_body(addr: SocketAddr) -> Json {
    let mut cl = Client::connect(addr);
    cl.send(&ClientMsg::Stats);
    match cl.recv() {
        ServerMsg::Stats(j) => j,
        other => panic!("expected stats reply, got {other:?}"),
    }
}

fn shutdown(addr: SocketAddr) {
    let mut cl = Client::connect(addr);
    cl.send(&ClientMsg::Shutdown);
    match cl.recv() {
        ServerMsg::Ok { .. } => {}
        other => panic!("expected ok to shutdown, got {other:?}"),
    }
}

/// A gateway capped below the total expert bytes serves scores and
/// greedy streams **bitwise identical** to the fully-resident gateway,
/// while the stats/metrics surfaces report the spill traffic.
#[test]
fn capped_gateway_is_bitwise_identical_and_observable() {
    // reference: everything resident
    let dense = Gateway::start(base_cfg(0, None)).expect("start dense gateway");
    let (want_ces, want_tokens) = score_and_generate(dense.local_addr());
    shutdown(dense.local_addr());
    dense.join();

    // cap one blob below the total: eviction is structural (17th
    // distinct acquisition cannot fit), and with 15 of 16 blobs
    // resident the steady state still hits
    let (total, blob) = expert_sizes();
    assert!(total > blob, "small config has multiple expert blobs");
    let spill_dir = std::env::temp_dir().join(format!("sonic-residency-it-{}", std::process::id()));
    std::fs::create_dir_all(&spill_dir).expect("create spill dir");
    let cfg = base_cfg(total - blob, Some(spill_dir.to_string_lossy().into_owned()));
    let gw = Gateway::start(cfg).expect("start capped gateway");
    let addr = gw.local_addr();

    let (ces, tokens) = score_and_generate(addr);
    assert_eq!(tokens, want_tokens, "capped generate stream diverged from dense");
    for (i, (a, b)) in ces.iter().zip(&want_ces).enumerate() {
        assert!(a == b, "request {i}: capped ce {a} != dense ce {b} (must be bitwise)");
    }

    // give the decode worker's post-retire gauge publish a beat
    std::thread::sleep(Duration::from_millis(300));

    let st = stats_body(addr);
    let r = st.get("residency").expect("capped gateway stats carry a residency block");
    let num = |k: &str| r.get(k).unwrap().as_f64().unwrap();
    assert!(num("hits") >= 1.0, "steady state at 15/16 resident must hit");
    assert!(num("misses") >= 1.0, "the cold pass must miss");
    assert!(num("evictions") >= 1.0, "a capped budget must evict");
    let rate = num("hit_rate");
    assert!(rate > 0.0 && rate < 1.0, "hit rate {rate} should be interior");
    assert!(num("spilled_bytes") > 0.0, "spill tier holds the expert bytes");
    assert!(r.get("per_layer").is_ok(), "residency block carries per-layer counters");
    let kv_cap = st.get("kv_cache_capacity_bytes").unwrap().as_f64().unwrap();
    assert!(kv_cap > 0.0, "KV capacity gauge published");
    let kv_live = st.get("kv_cache_bytes").unwrap().as_f64().unwrap();
    assert_eq!(kv_live, 0.0, "all streams retired: live KV gauge is back to zero");

    let body = Client::connect(addr).metrics();
    for needle in [
        "# TYPE sonic_residency_hits_total counter",
        "sonic_residency_hits_total{layer=\"0\"}",
        "sonic_residency_hits_total{layer=\"1\"}",
        "sonic_residency_misses_total{layer=\"0\"}",
        "sonic_residency_evictions_total{layer=",
        "sonic_residency_hit_rate",
        "sonic_residency_spilled_bytes",
        "sonic_residency_prefetch_us{quantile=\"0.95\"}",
        "sonic_gateway_kv_cache_capacity_bytes",
    ] {
        assert!(body.contains(needle), "metrics body missing {needle:?}:\n{body}");
    }
    // the exposition renders the same counters the JSON asserted
    // nonzero above, so the series are nonzero too; spot-check that
    // hits did not render as the all-zero series
    let zero_hits = body
        .lines()
        .filter(|l| l.starts_with("sonic_residency_hits_total{"))
        .all(|l| l.ends_with(" 0"));
    assert!(!zero_hits, "metrics hits series is all zero:\n{body}");

    shutdown(addr);
    gw.join();
    // spill files are per-store temporaries: the drain deletes them
    let leftovers: Vec<_> = std::fs::read_dir(&spill_dir)
        .expect("spill dir survives the drain")
        .filter_map(|e| e.ok().map(|e| e.path()))
        .collect();
    assert!(leftovers.is_empty(), "spill files leaked: {leftovers:?}");
    let _ = std::fs::remove_dir(&spill_dir);
}

/// Without a cap nothing is tiered: no residency block in `stats`, no
/// `sonic_residency_*` series in `metrics`.
#[test]
fn dense_gateway_reports_no_residency() {
    let gw = Gateway::start(base_cfg(0, None)).expect("start gateway");
    let addr = gw.local_addr();
    let mut cl = Client::connect(addr);
    cl.send(&ClientMsg::Score { id: 1, tokens: vec![1, 2, 3, 4] });
    match cl.recv() {
        ServerMsg::Score { id, .. } => assert_eq!(id, 1),
        other => panic!("expected score, got {other:?}"),
    }
    let st = stats_body(addr);
    assert!(st.get("residency").is_err(), "dense gateway must not report a residency block");
    let body = Client::connect(addr).metrics();
    assert!(!body.contains("sonic_residency_"), "dense metrics carry residency series:\n{body}");
    assert!(body.contains("sonic_gateway_kv_cache_capacity_bytes"));
    shutdown(addr);
    gw.join();
}
