//! Storage dtypes for the native backend's IO-bound operands.
//!
//! SonicMoE's CPU analogue of low-precision HBM streaming: weights and
//! KV rows can be *stored* as bf16 (the upper 16 bits of an f32, with
//! round-to-nearest-even narrowing) while every accumulation stays
//! f32. Halving the bytes of the streamed operand halves the memory
//! traffic of the bandwidth-bound GEMM path; the widen back to f32 is
//! fused into the GEMM panel packs (see
//! [`kernels`](crate::runtime::backend::native::kernels)) so no
//! separate convert pass or f32 copy of the weights ever exists.
//!
//! The f32 path is untouched by construction: [`WView::F32`] feeds the
//! kernels the exact accessor closures they compiled before this
//! module existed, so f32 results stay bitwise identical.

use std::fmt;

use anyhow::{bail, Result};

/// Storage precision of model parameters / KV rows. Compute is always
/// f32; this only selects how the streamed operand is *held*.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Dtype {
    /// Full f32 storage — the bitwise-reference path.
    #[default]
    F32,
    /// bf16 storage (u16 bit patterns), widened to f32 on read.
    Bf16,
}

impl Dtype {
    pub fn parse(s: &str) -> Result<Dtype> {
        match s {
            "" | "f32" | "float32" => Ok(Dtype::F32),
            "bf16" | "bfloat16" => Ok(Dtype::Bf16),
            other => bail!("unknown dtype {other:?} (expected f32 or bf16)"),
        }
    }

    pub fn as_str(&self) -> &'static str {
        match self {
            Dtype::F32 => "f32",
            Dtype::Bf16 => "bf16",
        }
    }

    /// Bytes per stored element.
    pub fn elem_bytes(&self) -> usize {
        match self {
            Dtype::F32 => 4,
            Dtype::Bf16 => 2,
        }
    }
}

impl fmt::Display for Dtype {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.as_str())
    }
}

/// Narrow an f32 to bf16 with round-to-nearest-even.
///
/// Pure bit arithmetic: adding `0x7FFF + lsb` to the f32 bits rounds
/// the mantissa at bit 16 with ties going to the even result, then the
/// top 16 bits are kept. Subnormals round the same way (they are just
/// small mantissas), infinities pass through exactly (their low 16
/// bits are zero so no carry fires), and NaNs are forced quiet so the
/// carry can never round a NaN payload up into an infinity.
#[inline]
pub fn narrow(x: f32) -> u16 {
    let bits = x.to_bits();
    if x.is_nan() {
        // keep sign + a quiet payload; never round
        return ((bits >> 16) as u16) | 0x0040;
    }
    (bits.wrapping_add(0x7FFF + ((bits >> 16) & 1)) >> 16) as u16
}

/// Widen a bf16 bit pattern back to f32 (exact: bf16 values are a
/// subset of f32).
#[inline]
pub fn widen(b: u16) -> f32 {
    f32::from_bits((b as u32) << 16)
}

/// Quantize a slice to bf16 storage.
pub fn narrow_slice(xs: &[f32]) -> Vec<u16> {
    xs.iter().map(|&x| narrow(x)).collect()
}

/// The value each element of `xs` takes after a bf16 round trip (the
/// numerics a bf16-stored operand actually computes with).
pub fn roundtrip_slice(xs: &[f32]) -> Vec<f32> {
    xs.iter().map(|&x| widen(narrow(x))).collect()
}

/// A borrowed weight operand in either storage precision.
///
/// Call sites match once and hand the kernel an arm-specific accessor:
/// the f32 arm is byte-for-byte the closure the kernels always used
/// (bitwise-identical results), the bf16 arm widens inside the pack —
/// streaming half the bytes with no intermediate f32 buffer.
#[derive(Debug, Clone, Copy)]
pub enum WView<'a> {
    F32(&'a [f32]),
    Bf16(&'a [u16]),
}

impl<'a> WView<'a> {
    pub fn len(&self) -> usize {
        match self {
            WView::F32(w) => w.len(),
            WView::Bf16(w) => w.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn dtype(&self) -> Dtype {
        match self {
            WView::F32(_) => Dtype::F32,
            WView::Bf16(_) => Dtype::Bf16,
        }
    }

    /// Bytes this operand streams when read end to end once.
    pub fn bytes(&self) -> usize {
        self.len() * self.dtype().elem_bytes()
    }

    /// Sub-view of one expert / layer segment.
    pub fn slice(&self, range: std::ops::Range<usize>) -> WView<'a> {
        match self {
            WView::F32(w) => WView::F32(&w[range]),
            WView::Bf16(w) => WView::Bf16(&w[range]),
        }
    }

    /// Element at `i`, widened when stored bf16. Fine for the O(d)
    /// per-row reads of norms/embeddings; the GEMM hot paths match on
    /// the variant once instead.
    #[inline]
    pub fn at(&self, i: usize) -> f32 {
        match self {
            WView::F32(w) => w[i],
            WView::Bf16(w) => widen(w[i]),
        }
    }

    /// The underlying f32 slice. Panics on bf16 storage: the training
    /// path keeps full-precision masters, so a bf16 weight reaching it
    /// is a wiring bug, not a numeric choice.
    pub fn f32(&self) -> &'a [f32] {
        match self {
            WView::F32(w) => w,
            WView::Bf16(_) => {
                panic!("bf16 weights are inference-only (training requires f32 masters)")
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::propcheck;
    use crate::util::Prng;

    /// Reference narrowing via f64 arithmetic: pick the representable
    /// bf16 neighbor nearest to x, ties to the even mantissa.
    fn narrow_reference(x: f32) -> u16 {
        if x.is_nan() {
            return ((x.to_bits() >> 16) as u16) | 0x0040;
        }
        let bits = x.to_bits();
        let lo = (bits >> 16) as u16; // truncate toward zero-mantissa
        let hi = lo.wrapping_add(1);
        let tail = bits & 0xFFFF;
        if !widen(lo).is_finite() || tail == 0 {
            return lo;
        }
        // distance of x from the two candidates, in units of the
        // dropped 16 bits (exact integer comparison)
        match tail.cmp(&0x8000) {
            std::cmp::Ordering::Less => lo,
            std::cmp::Ordering::Greater => hi,
            std::cmp::Ordering::Equal => {
                if lo & 1 == 0 {
                    lo
                } else {
                    hi
                }
            }
        }
    }

    #[test]
    fn exact_values_roundtrip_bitwise() {
        for x in [
            0.0f32,
            -0.0,
            1.0,
            -1.0,
            0.5,
            2.0,
            -3.0,
            256.0,
            1.5e-39, // subnormal territory after narrowing
            f32::MIN_POSITIVE,
        ] {
            let rt = widen(narrow(x));
            // every value with a 7-bit-or-less mantissa is exact
            if x.to_bits() & 0xFFFF == 0 {
                assert_eq!(rt.to_bits(), x.to_bits(), "exact bf16 value {x} changed");
            }
        }
        assert_eq!(widen(narrow(1.0)), 1.0);
        assert_eq!(widen(narrow(-2.5)), -2.5);
    }

    #[test]
    fn ties_round_to_even() {
        // 1 + 2^-8 sits exactly between bf16 neighbors 1.0 (mantissa
        // even) and 1 + 2^-7: RNE keeps 1.0
        let tie_down = f32::from_bits(0x3F80_8000);
        assert_eq!(widen(narrow(tie_down)), 1.0);
        // (1 + 2^-7) + 2^-8 ties between odd-mantissa 1+2^-7 and even
        // 1+2^-6: RNE rounds up to the even one
        let tie_up = f32::from_bits(0x3F81_8000);
        assert_eq!(widen(narrow(tie_up)), f32::from_bits(0x3F82_0000));
        // anything past the midpoint rounds up regardless of parity
        let above = f32::from_bits(0x3F80_8001);
        assert_eq!(widen(narrow(above)), f32::from_bits(0x3F81_0000));
    }

    #[test]
    fn inf_and_nan_pass_through() {
        assert_eq!(widen(narrow(f32::INFINITY)), f32::INFINITY);
        assert_eq!(widen(narrow(f32::NEG_INFINITY)), f32::NEG_INFINITY);
        // a huge finite f32 past bf16::MAX rounds up to infinity — the
        // standard saturating-to-inf RNE behavior
        assert_eq!(widen(narrow(f32::MAX)), f32::INFINITY);
        assert!(widen(narrow(f32::NAN)).is_nan());
        // a signalling-ish payload must stay NaN, never become inf
        let snan = f32::from_bits(0x7F80_0001);
        assert!(widen(narrow(snan)).is_nan());
        let neg_nan = f32::from_bits(0xFF80_0001);
        assert!(widen(narrow(neg_nan)).is_nan());
        assert_eq!(narrow(neg_nan) & 0x8000, 0x8000, "NaN sign preserved");
    }

    #[test]
    fn subnormals_narrow_like_reference() {
        for i in 0..64u32 {
            // f32 subnormals and tiny normals around the bf16 subnormal
            // boundary
            let x = f32::from_bits(i * 0x0000_2001 + 1);
            assert_eq!(narrow(x), narrow_reference(x), "subnormal {x:e} ({:#x})", x.to_bits());
        }
    }

    #[test]
    fn narrowing_matches_reference_on_random_bits() {
        let mut rng = Prng::new(0xD7);
        for _ in 0..20_000 {
            let bits = (rng.next_u64() as u32) ^ ((rng.next_u64() as u32) << 1);
            let x = f32::from_bits(bits);
            assert_eq!(
                narrow(x),
                narrow_reference(x),
                "bits {bits:#010x} value {x:e}: RNE narrow disagrees with reference"
            );
        }
    }

    /// Property: the bf16 round trip of a finite normal value has
    /// relative error at most 2^-8 (half the bf16 mantissa ulp).
    #[test]
    fn roundtrip_relative_error_bound() {
        propcheck::check("bf16 roundtrip relative error", 2000, |g| {
            // log-uniform magnitudes across the normal range
            let exp = g.usize_in(0, 200) as i32 - 100;
            let mant = 1.0 + g.f64_in(0.0, 1.0);
            let sign = *g.choice(&[1.0f64, -1.0]);
            let x = (sign * mant * 2f64.powi(exp)) as f32;
            if !x.is_finite() || x == 0.0 || x.abs() < 1e-37 {
                return; // stay clear of subnormal ulps
            }
            let rt = widen(narrow(x));
            let rel = ((rt as f64 - x as f64) / (x as f64)).abs();
            assert!(
                rel <= 1.0 / 256.0,
                "x={x:e}: roundtrip {rt:e} relative error {rel:e} > 2^-8"
            );
        });
    }

    #[test]
    fn dtype_parse_and_bytes() {
        assert_eq!(Dtype::parse("").unwrap(), Dtype::F32);
        assert_eq!(Dtype::parse("f32").unwrap(), Dtype::F32);
        assert_eq!(Dtype::parse("bf16").unwrap(), Dtype::Bf16);
        assert!(Dtype::parse("fp8").is_err());
        assert_eq!(Dtype::F32.elem_bytes(), 4);
        assert_eq!(Dtype::Bf16.elem_bytes(), 2);
        assert_eq!(Dtype::Bf16.to_string(), "bf16");
    }

    #[test]
    fn wview_accessors() {
        let w = vec![1.0f32, -2.0, 0.5, 3.25];
        let q = narrow_slice(&w);
        let vf = WView::F32(&w);
        let vb = WView::Bf16(&q);
        assert_eq!(vf.len(), 4);
        assert_eq!(vb.len(), 4);
        assert_eq!(vf.bytes(), 16);
        assert_eq!(vb.bytes(), 8, "bf16 view streams half the bytes");
        for i in 0..4 {
            assert_eq!(vf.at(i), w[i]);
            assert_eq!(vb.at(i), w[i], "exact bf16 values widen back exactly");
        }
        assert_eq!(vf.slice(1..3).len(), 2);
        assert_eq!(vb.slice(1..3).at(0), -2.0);
        assert_eq!(vf.f32(), &w[..]);
        assert_eq!(roundtrip_slice(&w), w);
    }

    #[test]
    #[should_panic(expected = "inference-only")]
    fn bf16_view_refuses_f32_slice() {
        let q = narrow_slice(&[1.0, 2.0]);
        let _ = WView::Bf16(&q).f32();
    }
}
