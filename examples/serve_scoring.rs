//! Serving example: batched LM scoring service over the execution
//! backend (native pure-rust CPU by default; PJRT with the `pjrt`
//! feature).
//!
//! Loads the small config (optionally a trained checkpoint), submits a
//! stream of synthetic requests, serves them in fixed-shape batches,
//! and reports latency/throughput — the inference-side "python never on
//! the request path" demonstration. Runs hermetically: without a
//! `make artifacts` export the built-in native config is used.
//!
//!     cargo run --release --example serve_scoring -- --requests 64

use anyhow::Result;
use sonic_moe::bench::Table;
use sonic_moe::coordinator::serve::Server;
use sonic_moe::data::{Corpus, CorpusConfig};
use sonic_moe::util::cli::Cli;

fn main() -> Result<()> {
    let cli = Cli::new("serve_scoring", "batched LM scoring service")
        .opt("artifacts", "artifacts", "artifacts dir")
        .opt("config", "small", "config name")
        .opt("requests", "64", "number of requests")
        .opt("checkpoint", "", "trained checkpoint dir (optional)");
    let a = cli.parse()?;
    let mut server = Server::new(a.get("artifacts"), a.get("config"))?;
    if !a.get("checkpoint").is_empty() {
        server.load_checkpoint(a.get("checkpoint"))?;
        println!("loaded checkpoint from {}", a.get("checkpoint"));
    }
    let n = a.get_usize("requests")?;
    println!(
        "server up: backend={} config={} batch={} seq={}",
        server.backend_name(),
        a.get("config"),
        server.rows,
        server.seq
    );

    // synthetic request stream: in-distribution (corpus) and random junk
    let mut corpus = Corpus::new(CorpusConfig::default(), 42);
    for id in 0..n as u64 {
        let toks = if id % 4 == 3 {
            // out-of-distribution: uniform random tokens
            (0..server.seq).map(|j| ((id as usize * 131 + j * 7) % 256) as i32).collect()
        } else {
            corpus.next_batch(1, server.seq)
        };
        server.submit(id, toks);
    }
    let responses = server.drain()?;
    assert_eq!(responses.len(), n);

    let s = server.stats;
    let mut t = Table::new("scoring service report", &["metric", "value"]);
    t.row(&["requests served".into(), s.requests.to_string()]);
    t.row(&["batches executed".into(), s.batches.to_string()]);
    t.row(&["batch padding".into(), format!("{:.1}%", 100.0 * s.padding_frac())]);
    t.row(&["mean request latency".into(), format!("{:.1} ms", s.mean_latency_s() * 1e3)]);
    t.row(&["throughput".into(), format!("{:.0} tokens/s", s.tokens_per_s())]);
    t.print();

    // exact scoring demo: corpus text should score lower CE than junk
    let good = corpus.next_batch(1, server.seq);
    let junk: Vec<i32> = (0..server.seq).map(|j| ((j * 97 + 13) % 251) as i32).collect();
    let ce_good = server.score_exact(&good)?;
    let ce_junk = server.score_exact(&junk)?;
    println!("exact scores: corpus CE {ce_good:.3} vs junk CE {ce_junk:.3}");
    println!("serve_scoring OK");
    Ok(())
}
