//! Per-thread scratch arena: zero-alloc buffer reuse for the native
//! kernel hot paths.
//!
//! Every activation-sized temporary in the forward/backward/decode
//! paths is checked out with [`take`] / [`take_idx`] and returned with
//! [`put`] / [`put_idx`] when its lifetime ends. The pool is
//! thread-local, so each gateway worker, trainer rank, or decode core
//! reuses one arena across requests with no locking — and after a
//! warmup call every `take` is served from the pool instead of the
//! allocator ([`Stats::allocs`] stops growing; the zero-alloc tests
//! assert exactly that).
//!
//! Buffers are matched best-fit by capacity, so a steady-state workload
//! settles on one buffer per live temporary. `take` always returns a
//! zero-filled buffer of the requested length (`resize` within the
//! pooled capacity allocates nothing). The pool is bounded; overflow
//! buffers are simply dropped.

use std::cell::RefCell;

/// Max pooled buffers per kind (a runaway caller degrades to plain
/// allocation instead of hoarding memory).
const POOL_CAP: usize = 256;

/// Cumulative arena counters for the calling thread.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct Stats {
    /// `take`/`take_idx` calls that missed the pool and hit the
    /// allocator. Flat across calls == zero per-call heap allocation.
    pub allocs: u64,
    /// Total `take`/`take_idx` calls.
    pub takes: u64,
    /// f32 elements currently parked in the pool.
    pub pooled_f32: usize,
    /// usize elements currently parked in the pool.
    pub pooled_idx: usize,
}

#[derive(Default)]
struct Pool {
    f32s: Vec<Vec<f32>>,
    idxs: Vec<Vec<usize>>,
    allocs: u64,
    takes: u64,
}

thread_local! {
    static POOL: RefCell<Pool> = RefCell::new(Pool::default());
}

/// Best-fit checkout: the smallest pooled buffer with capacity >= `len`.
fn best_fit<T>(pool: &mut Vec<Vec<T>>, len: usize) -> Option<Vec<T>> {
    let mut best: Option<(usize, usize)> = None; // (index, capacity)
    for (i, b) in pool.iter().enumerate() {
        let cap = b.capacity();
        if cap < len {
            continue;
        }
        match best {
            Some((_, c)) if c <= cap => {}
            _ => best = Some((i, cap)),
        }
    }
    best.map(|(i, _)| pool.swap_remove(i))
}

/// Check out a zero-filled `Vec<f32>` of length `len`.
pub fn take(len: usize) -> Vec<f32> {
    POOL.with(|p| {
        let mut p = p.borrow_mut();
        p.takes += 1;
        match best_fit(&mut p.f32s, len) {
            Some(mut v) => {
                v.clear();
                v.resize(len, 0.0);
                v
            }
            None => {
                p.allocs += 1;
                vec![0.0; len]
            }
        }
    })
}

/// Return a buffer to the calling thread's pool.
pub fn put(v: Vec<f32>) {
    if v.capacity() == 0 {
        return;
    }
    POOL.with(|p| {
        let mut p = p.borrow_mut();
        if p.f32s.len() < POOL_CAP {
            p.f32s.push(v);
        }
    });
}

/// Check out an empty `Vec<usize>` with capacity for at least `cap`
/// elements (index lists are built by pushing, so length starts 0).
pub fn take_idx(cap: usize) -> Vec<usize> {
    POOL.with(|p| {
        let mut p = p.borrow_mut();
        p.takes += 1;
        match best_fit(&mut p.idxs, cap) {
            Some(mut v) => {
                v.clear();
                v
            }
            None => {
                p.allocs += 1;
                Vec::with_capacity(cap)
            }
        }
    })
}

/// Return an index buffer to the calling thread's pool.
pub fn put_idx(v: Vec<usize>) {
    if v.capacity() == 0 {
        return;
    }
    POOL.with(|p| {
        let mut p = p.borrow_mut();
        if p.idxs.len() < POOL_CAP {
            p.idxs.push(v);
        }
    });
}

/// Arena counters for the calling thread.
pub fn stats() -> Stats {
    POOL.with(|p| {
        let p = p.borrow();
        Stats {
            allocs: p.allocs,
            takes: p.takes,
            pooled_f32: p.f32s.iter().map(|b| b.capacity()).sum(),
            pooled_idx: p.idxs.iter().map(|b| b.capacity()).sum(),
        }
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn take_returns_zeroed_and_reuses() {
        let mut v = take(16);
        assert_eq!(v.len(), 16);
        assert!(v.iter().all(|&x| x == 0.0));
        v[3] = 7.0;
        let cap = v.capacity();
        put(v);
        let before = stats().allocs;
        // same-size take must come back zeroed from the pool, alloc-free
        let v2 = take(16);
        assert_eq!(v2.capacity(), cap);
        assert!(v2.iter().all(|&x| x == 0.0));
        assert_eq!(stats().allocs, before);
        put(v2);
    }

    #[test]
    fn best_fit_prefers_smallest_sufficient() {
        put(Vec::with_capacity(64));
        put(Vec::with_capacity(8));
        let v = take(8);
        assert!(v.capacity() < 64, "took the oversized buffer");
        put(v);
        let v = take(64);
        assert!(v.capacity() >= 64);
        put(v);
    }

    #[test]
    fn idx_pool_reuses_capacity() {
        let mut v = take_idx(10);
        v.extend(0..10);
        put_idx(v);
        let before = stats().allocs;
        let v2 = take_idx(10);
        assert!(v2.is_empty());
        assert!(v2.capacity() >= 10);
        assert_eq!(stats().allocs, before);
        put_idx(v2);
    }

    #[test]
    fn steady_state_is_alloc_free() {
        // warmup: populate the pool with this loop's working set
        for _ in 0..2 {
            let a = take(100);
            let b = take(50);
            let c = take_idx(20);
            put(a);
            put(b);
            put_idx(c);
        }
        let before = stats().allocs;
        for _ in 0..10 {
            let a = take(100);
            let b = take(50);
            let c = take_idx(20);
            put(a);
            put(b);
            put_idx(c);
        }
        assert_eq!(stats().allocs, before, "steady-state takes hit the allocator");
    }
}
