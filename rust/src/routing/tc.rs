//! Token-choice top-K routing (the baseline router).

use super::Decision;

/// fp32 -> sortable u32 key: unsigned order == float order. The same
/// sign-flip trick as the L1 bitonic kernel (Appendix D / topk.py).
#[inline]
pub(crate) fn sortable_bits(x: f32) -> u32 {
    let u = x.to_bits();
    if u >> 31 == 1 {
        !u
    } else {
        u ^ 0x8000_0000
    }
}

/// Top-K indices of one row, descending by score, ties to lower index —
/// same order as `jax.lax.top_k` and the paper's stable bitonic kernel.
/// Allocation-free in the hot path via the caller-provided buffer.
pub fn topk_row_into(scores: &[f32], k: usize, out: &mut Vec<usize>) {
    debug_assert!(k <= scores.len());
    out.clear();
    // maintain an insertion-sorted top-K of packed keys:
    // (sortable_bits << 32) | !index  — descending key, ascending index.
    let mut best = [0u64; 16];
    let kk = k.min(16);
    let mut len = 0usize;
    for (j, &v) in scores.iter().enumerate() {
        let key = ((sortable_bits(v) as u64) << 32) | (!(j as u32) as u64);
        if len < kk {
            let mut i = len;
            while i > 0 && best[i - 1] < key {
                best[i] = best[i - 1];
                i -= 1;
            }
            best[i] = key;
            len += 1;
        } else if key > best[kk - 1] {
            let mut i = kk - 1;
            while i > 0 && best[i - 1] < key {
                best[i] = best[i - 1];
                i -= 1;
            }
            best[i] = key;
        }
    }
    for b in best.iter().take(len) {
        out.push(!(*b as u32) as usize);
    }
    // k > 16 is outside the paper's supported range (Appendix D); fall
    // back to a full sort for completeness.
    if k > 16 {
        let mut keys: Vec<u64> = scores
            .iter()
            .enumerate()
            .map(|(j, &v)| ((sortable_bits(v) as u64) << 32) | (!(j as u32) as u64))
            .collect();
        keys.sort_unstable_by(|a, b| b.cmp(a));
        out.clear();
        out.extend(keys[..k].iter().map(|&b| !(b as u32) as usize));
    }
}

/// Convenience wrapper returning a fresh Vec.
pub fn topk_row(scores: &[f32], k: usize) -> Vec<usize> {
    let mut out = Vec::with_capacity(k);
    topk_row_into(scores, k, &mut out);
    out
}

/// Token-choice top-K over a (t, e) score matrix (row-major).
pub fn tc_topk(scores: &[f32], t: usize, e: usize, k: usize) -> Decision {
    assert_eq!(scores.len(), t * e);
    assert!(k <= e);
    let mut mask = vec![false; t * e];
    let mut sp = vec![0f32; t * e];
    let mut f = vec![0usize; e];
    let mut buf = Vec::with_capacity(k);
    for row in 0..t {
        let r = &scores[row * e..(row + 1) * e];
        topk_row_into(r, k, &mut buf);
        for &j in &buf {
            mask[row * e + j] = true;
            sp[row * e + j] = r[j];
            f[j] += 1;
        }
    }
    Decision { t, e, mask, scores: sp, f: f.clone(), g: f }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn topk_row_orders_descending() {
        let s = [0.1, 0.5, 0.3, 0.9];
        assert_eq!(topk_row(&s, 2), vec![3, 1]);
        assert_eq!(topk_row(&s, 4), vec![3, 1, 2, 0]);
    }

    #[test]
    fn topk_row_tie_breaks_to_lower_index() {
        let s = [0.5, 0.5, 0.5];
        assert_eq!(topk_row(&s, 2), vec![0, 1]);
    }

    #[test]
    fn tc_counts_sum_to_tk() {
        let t = 16;
        let e = 4;
        let k = 2;
        let mut rng = crate::util::prng::Prng::new(0);
        let scores = super::super::synth_scores(&mut rng, t, e, 0.0);
        let d = tc_topk(&scores, t, e, k);
        assert_eq!(d.f.iter().sum::<usize>(), t * k);
        assert_eq!(d.mask.iter().filter(|&&m| m).count(), t * k);
        // every row has exactly k selections
        for row in 0..t {
            let c = d.mask[row * e..(row + 1) * e].iter().filter(|&&m| m).count();
            assert_eq!(c, k);
        }
    }
}
