//! Tables 7 & 8 (scaled-down): token-rounding sensitivity to the
//! microbatch size T (Table 7) and the rounding tile M_tile (Table 8).
//! The quality knob is the ratio mean-tokens-per-expert / M_tile.

use sonic_moe::bench::Table;
use sonic_moe::coordinator::quality::{bench_steps, train_and_eval};
use sonic_moe::runtime::artifacts_available;

fn main() {
    if !artifacts_available("artifacts") {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    let steps = bench_steps();
    // small config: T = batch*32 tokens, E = 8, K = 2 -> mean T_e = T/4.
    let mut t7 = Table::new(
        &format!("Table 7 (scaled down): vary microbatch T, M_tile=16, {steps} steps"),
        &["variant", "T", "mean T_e / M_tile", "train CE", "val CE"],
    );
    for (label, router, t_tokens) in [
        ("batch 2", "tr_b2", 64usize),
        ("batch 4 (base)", "tr", 128),
        ("batch 8", "tr_b8", 256),
    ] {
        let ratio = (t_tokens * 2 / 8) as f64 / 16.0;
        match train_and_eval("small", router, steps, 3e-3, 0) {
            Ok(r) => t7.row(&[
                label.to_string(),
                t_tokens.to_string(),
                format!("{ratio:.1}"),
                format!("{:.4}", r.train_ce),
                format!("{:.4}", r.val_ce),
            ]),
            Err(e) => t7.row(&[label.to_string(), t_tokens.to_string(), format!("{ratio:.1}"), format!("error: {e}"), "-".into()]),
        }
    }
    t7.print();

    let mut t8 = Table::new(
        &format!("Table 8 (scaled down): vary rounding tile M_tile, T=128, {steps} steps"),
        &["M_tile", "mean T_e / M_tile", "train CE", "val CE"],
    );
    for (label, router, m) in [("8", "tr_m8", 8usize), ("16 (base)", "tr", 16), ("32", "tr_m32", 32)] {
        let ratio = 32.0 / m as f64;
        match train_and_eval("small", router, steps, 3e-3, 0) {
            Ok(r) => t8.row(&[
                label.to_string(),
                format!("{ratio:.1}"),
                format!("{:.4}", r.train_ce),
                format!("{:.4}", r.val_ce),
            ]),
            Err(e) => t8.row(&[label.to_string(), format!("{ratio:.1}"), format!("error: {e}"), "-".into()]),
        }
    }
    t8.print();
    println!("(paper Tables 7/8: TR robust while mean T_e / M_tile >= 2)");
}
