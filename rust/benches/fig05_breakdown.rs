//! Bench: regenerate Figure 5 via the GPU performance simulator and time
//! the evaluation hot path. See DESIGN.md per-experiment index.

use sonic_moe::bench::{figures, Bencher};

fn main() {
    for t in figures::fig05() {
        t.print();
    }
    let mut b = Bencher::new("simulator/fig05_breakdown");
    b.iter(|| figures::fig05());
    println!("{}", b.report());
}
