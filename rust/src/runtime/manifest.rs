//! Typed view of `artifacts/manifest.json` (the python<->rust contract).

use std::collections::BTreeMap;

use anyhow::{Context, Result};

use crate::util::json::Json;

/// Shape+dtype of one artifact input/output.
#[derive(Debug, Clone, PartialEq)]
pub struct TensorSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: String,
}

/// One HLO artifact entry.
#[derive(Debug, Clone)]
pub struct ArtifactSpec {
    pub file: String,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
    /// Optional golden block (quickstart/integration tests).
    pub golden: Option<Json>,
}

/// One named parameter in the flat params file.
#[derive(Debug, Clone)]
pub struct ParamSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub offset: usize,
    pub size: usize,
}

/// Model hyperparameters as exported (subset we need in rust).
#[derive(Debug, Clone)]
pub struct ModelInfo {
    pub vocab: usize,
    pub d: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub seq_len: usize,
    pub batch: usize,
    pub n: usize,
    pub e: usize,
    pub k: usize,
    pub m_tile: usize,
    /// Default router method string ("tc", "tr-nr-f", ...).
    pub router: String,
    /// Auxiliary load-balance loss coefficient.
    pub aux_coeff: f32,
}

/// Everything for one config ("small", "medium", ...).
#[derive(Debug, Clone)]
pub struct ConfigManifest {
    pub model: ModelInfo,
    pub params: Vec<ParamSpec>,
    pub params_file: String,
    pub num_params: usize,
    pub num_active_params: usize,
    pub artifacts: BTreeMap<String, ArtifactSpec>,
    pub golden_lm: Option<Json>,
}

/// The whole manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub configs: BTreeMap<String, ConfigManifest>,
}

fn tensor_specs(j: &Json) -> Result<Vec<TensorSpec>> {
    j.as_arr()?
        .iter()
        .map(|t| {
            Ok(TensorSpec {
                name: t.get("name")?.as_str()?.to_string(),
                shape: t.get("shape")?.as_usize_vec()?,
                dtype: t.get("dtype")?.as_str()?.to_string(),
            })
        })
        .collect()
}

impl Manifest {
    pub fn load(path: &str) -> Result<Manifest> {
        let j = Json::parse_file(path)?;
        Self::from_json(&j).with_context(|| format!("interpreting {path}"))
    }

    pub fn from_json(j: &Json) -> Result<Manifest> {
        let mut configs = BTreeMap::new();
        for (name, cj) in j.get("configs")?.as_obj()? {
            let m = cj.get("model")?;
            let model = ModelInfo {
                vocab: m.get("vocab")?.as_usize()?,
                d: m.get("d")?.as_usize()?,
                n_layers: m.get("n_layers")?.as_usize()?,
                n_heads: m.get("n_heads")?.as_usize()?,
                seq_len: m.get("seq_len")?.as_usize()?,
                batch: m.get("batch")?.as_usize()?,
                n: m.get("n")?.as_usize()?,
                e: m.get("E")?.as_usize()?,
                k: m.get("K")?.as_usize()?,
                m_tile: m.get("m_tile")?.as_usize()?,
                router: m
                    .opt("router")
                    .and_then(|r| r.as_str().ok())
                    .unwrap_or("tc")
                    .to_string(),
                aux_coeff: m.opt("aux_coeff").and_then(|a| a.as_f64().ok()).unwrap_or(0.01)
                    as f32,
            };
            let params = cj
                .get("params")?
                .as_arr()?
                .iter()
                .map(|p| {
                    Ok(ParamSpec {
                        name: p.get("name")?.as_str()?.to_string(),
                        shape: p.get("shape")?.as_usize_vec()?,
                        offset: p.get("offset")?.as_usize()?,
                        size: p.get("size")?.as_usize()?,
                    })
                })
                .collect::<Result<Vec<_>>>()?;
            let mut artifacts = BTreeMap::new();
            for (an, aj) in cj.get("artifacts")?.as_obj()? {
                artifacts.insert(
                    an.clone(),
                    ArtifactSpec {
                        file: aj.get("file")?.as_str()?.to_string(),
                        inputs: tensor_specs(aj.get("inputs")?)?,
                        outputs: tensor_specs(aj.get("outputs")?)?,
                        golden: aj.opt("golden").cloned(),
                    },
                );
            }
            configs.insert(
                name.clone(),
                ConfigManifest {
                    model,
                    params,
                    params_file: cj.get("params_file")?.as_str()?.to_string(),
                    num_params: cj.get("num_params")?.as_usize()?,
                    num_active_params: cj.get("num_active_params")?.as_usize()?,
                    artifacts,
                    golden_lm: cj.opt("golden_lm").cloned(),
                },
            );
        }
        Ok(Manifest { configs })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "version": 1,
      "configs": {
        "tiny": {
          "model": {"vocab": 64, "d": 16, "n_layers": 2, "n_heads": 2,
                    "seq_len": 16, "batch": 2, "n": 8, "E": 4, "K": 2,
                    "m_tile": 8, "router": "tc", "aux_coeff": 0.01},
          "params": [{"name": "embed", "shape": [64, 16], "offset": 0, "size": 1024}],
          "params_file": "params_tiny.bin",
          "num_params": 1024,
          "num_active_params": 900,
          "artifacts": {
            "lm_eval": {
              "file": "lm_eval_tiny.hlo.txt",
              "inputs": [{"name": "embed", "shape": [64, 16], "dtype": "float32"}],
              "outputs": [{"name": "ce", "shape": [], "dtype": "float32"}]
            }
          }
        }
      }
    }"#;

    #[test]
    fn parses_sample() {
        let j = Json::parse(SAMPLE).unwrap();
        let m = Manifest::from_json(&j).unwrap();
        let cfg = &m.configs["tiny"];
        assert_eq!(cfg.model.vocab, 64);
        assert_eq!(cfg.model.e, 4);
        assert_eq!(cfg.model.n_heads, 2);
        assert_eq!(cfg.model.router, "tc");
        assert!((cfg.model.aux_coeff - 0.01).abs() < 1e-9);
        assert_eq!(cfg.params[0].size, 1024);
        let a = &cfg.artifacts["lm_eval"];
        assert_eq!(a.inputs[0].shape, vec![64, 16]);
        assert_eq!(a.outputs[0].shape, Vec::<usize>::new());
        assert!(cfg.golden_lm.is_none());
    }

    #[test]
    fn real_manifest_if_present() {
        if crate::runtime::artifacts_available("artifacts") {
            let m = Manifest::load("artifacts/manifest.json").unwrap();
            let cfg = &m.configs["small"];
            assert!(cfg.num_params > 0);
            assert!(cfg.artifacts.contains_key("lm_grad_step_tc"));
            assert!(cfg.artifacts.contains_key("moe_layer_fwd_tr"));
        }
    }
}
