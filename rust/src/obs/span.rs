//! Span taxonomy and the RAII [`SpanGuard`] recorder.
//!
//! A span is one timed interval of work attributed to either a request
//! (trace id != 0 — rendered as an async per-request track in the
//! Chrome export) or a thread (trace id == 0 — rendered as a nested
//! interval on that thread's track). The guard samples the monotonic
//! clock at construction and records on drop; an unarmed guard (trace
//! id 0 on a request-scoped span, or tracing disabled) never touches
//! the clock or the recorder, so the disabled cost is two branch
//! instructions.

use super::recorder::{self, now_ns};

/// What a recorded interval measured. The wire/Chrome name of each
/// kind is [`SpanKind::name`]; the `detail` payload packing per kind
/// is documented on the variant.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
#[repr(u8)]
pub enum SpanKind {
    /// Whole request: admission to terminal reply. `detail` = 0.
    Request,
    /// Score request sitting in the admission queue. `detail` = 0.
    QueueWait,
    /// Generate request sitting in the generation queue. `detail` = 0.
    GenQueueWait,
    /// Worker holding its microbatch open. `detail` = batch rows.
    BatchForm,
    /// Scoring a formed microbatch. `detail` = executed rows.
    BatchExec,
    /// Prompt prefill of one admitted sequence. `detail` = prompt tokens.
    Prefill,
    /// One continuous-batching decode step.
    /// `detail` = live_rows << 32 | padding_rows.
    DecodeStep,
    /// Scheduler draining in-flight sequences (reload / shutdown).
    /// `detail` = sequences drained.
    Drain,
    /// Draft-model proposal of one speculative round. `detail` =
    /// proposed tokens.
    SpecPropose,
    /// Verify + accept of one speculative round.
    /// `detail` = proposed << 32 | accepted.
    SpecVerify,
    /// KV rollback of rejected draft tokens. `detail` = rejected tokens.
    SpecRollback,
    /// One blocked GEMM call (recorded above a FLOP floor). `detail` =
    /// FLOPs.
    Gemm,
    /// One fused gather-GEMM-scatter expert forward. `detail` = FLOPs.
    FusedExpert,
    /// Residency acquire blocked on a non-resident expert.
    /// `detail` = layer << 32 | expert.
    FaultWait,
    /// Loader-thread prefetch of one expert blob.
    /// `detail` = layer << 32 | expert.
    Prefetch,
    /// Front-tier replica choice for one request. `detail` = chosen
    /// replica index.
    RouteDecide,
    /// Front-tier backoff sleep between relay attempts. `detail` =
    /// attempt number.
    RetryWait,
    /// Front-tier retry on a different replica after a transport
    /// failure. `detail` = attempts used.
    Failover,
}

impl SpanKind {
    /// Stable span name used in the Chrome export and docs.
    pub fn name(self) -> &'static str {
        match self {
            SpanKind::Request => "request",
            SpanKind::QueueWait => "queue_wait",
            SpanKind::GenQueueWait => "gen_queue_wait",
            SpanKind::BatchForm => "batch_form",
            SpanKind::BatchExec => "batch_exec",
            SpanKind::Prefill => "prefill",
            SpanKind::DecodeStep => "decode_step",
            SpanKind::Drain => "drain",
            SpanKind::SpecPropose => "spec_propose",
            SpanKind::SpecVerify => "spec_verify",
            SpanKind::SpecRollback => "spec_rollback",
            SpanKind::Gemm => "gemm",
            SpanKind::FusedExpert => "fused_expert",
            SpanKind::FaultWait => "fault_wait",
            SpanKind::Prefetch => "prefetch",
            SpanKind::RouteDecide => "route_decide",
            SpanKind::RetryWait => "retry_wait",
            SpanKind::Failover => "failover",
        }
    }
}

/// RAII span recorder: samples the monotonic clock at construction,
/// records the interval into the flight recorder on drop. Guards are
/// cheap to construct when unarmed and allocation-free always.
#[derive(Debug)]
pub struct SpanGuard {
    trace: u64,
    kind: SpanKind,
    detail: u64,
    t_start_ns: u64,
    armed: bool,
}

impl SpanGuard {
    /// Request-scoped span: armed only for sampled requests
    /// (`trace != 0`) while tracing is enabled. Rendered on the
    /// request's async track.
    pub fn request(trace: u64, kind: SpanKind) -> SpanGuard {
        let armed = trace != 0 && recorder::enabled();
        SpanGuard {
            trace,
            kind,
            detail: 0,
            t_start_ns: if armed { now_ns() } else { 0 },
            armed,
        }
    }

    /// Thread-scoped span (no request context — kernels, batch loops,
    /// loader threads): armed while tracing is enabled, rendered as a
    /// nested interval on the recording thread's track.
    pub fn thread(kind: SpanKind) -> SpanGuard {
        let armed = recorder::enabled();
        SpanGuard {
            trace: 0,
            kind,
            detail: 0,
            t_start_ns: if armed { now_ns() } else { 0 },
            armed,
        }
    }

    /// Attach the kind-specific `detail` payload (see [`SpanKind`]).
    pub fn detail(&mut self, detail: u64) {
        self.detail = detail;
    }

    /// Disarm: drop without recording (e.g. a batch that turned out
    /// empty on queue close).
    pub fn cancel(mut self) {
        self.armed = false;
    }
}

impl Drop for SpanGuard {
    fn drop(&mut self) {
        if self.armed {
            recorder::record(self.trace, self.kind, self.t_start_ns, now_ns(), self.detail);
        }
    }
}

/// Record a span whose endpoints were measured by the caller (e.g.
/// queue wait reconstructed from an admission `Instant` at pop time).
/// No-op while tracing is disabled; request-scoped semantics — pass
/// `trace = 0` for a thread-scoped interval.
pub fn record_span(trace: u64, kind: SpanKind, t_start_ns: u64, t_end_ns: u64, detail: u64) {
    recorder::record(trace, kind, t_start_ns, t_end_ns, detail);
}

/// Format a trace id the way the wire protocol carries it (16 hex
/// digits, zero-padded).
pub fn trace_hex(trace: u64) -> String {
    format!("{trace:016x}")
}

/// Parse a wire `trace` field. Accepts 1–16 hex digits; anything else
/// (empty, overlong, non-hex) is `None` and the request proceeds
/// untraced rather than refused.
pub fn parse_trace_hex(s: &str) -> Option<u64> {
    if s.is_empty() || s.len() > 16 {
        return None;
    }
    u64::from_str_radix(s, 16).ok().filter(|&t| t != 0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn trace_hex_roundtrip() {
        for t in [1u64, 0xdead_beef, u64::MAX] {
            assert_eq!(parse_trace_hex(&trace_hex(t)), Some(t));
        }
        assert_eq!(trace_hex(0x2a), "000000000000002a");
    }

    #[test]
    fn parse_trace_rejects_garbage() {
        assert_eq!(parse_trace_hex(""), None);
        assert_eq!(parse_trace_hex("zz"), None);
        assert_eq!(parse_trace_hex("00000000000000000"), None, "17 digits");
        assert_eq!(parse_trace_hex("0"), None, "zero means untraced");
        assert_eq!(parse_trace_hex("a3"), Some(0xa3));
    }

    #[test]
    fn kind_names_are_unique() {
        let kinds = [
            SpanKind::Request,
            SpanKind::QueueWait,
            SpanKind::GenQueueWait,
            SpanKind::BatchForm,
            SpanKind::BatchExec,
            SpanKind::Prefill,
            SpanKind::DecodeStep,
            SpanKind::Drain,
            SpanKind::SpecPropose,
            SpanKind::SpecVerify,
            SpanKind::SpecRollback,
            SpanKind::Gemm,
            SpanKind::FusedExpert,
            SpanKind::FaultWait,
            SpanKind::Prefetch,
            SpanKind::RouteDecide,
            SpanKind::RetryWait,
            SpanKind::Failover,
        ];
        let mut names: Vec<&str> = kinds.iter().map(|k| k.name()).collect();
        names.sort_unstable();
        names.dedup();
        assert_eq!(names.len(), kinds.len());
    }
}
