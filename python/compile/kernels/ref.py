"""Pure-jnp correctness oracle for the SonicMoE kernels.

This module implements the MoE layer in the dense one-hot formulation of
Algorithm 1 (every expert sees every token, masked), which is O(T*E)
memory but trivially correct. All Pallas kernels are tested against it,
and the backward formulas of Appendix C are cross-checked against
``jax.grad`` of this forward.

Nothing here is ever part of an AOT artifact; it exists only for pytest.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def silu(x: jnp.ndarray) -> jnp.ndarray:
    return x * jax.nn.sigmoid(x)


def swiglu(h: jnp.ndarray) -> jnp.ndarray:
    """SwiGLU over the last dim: h = [gate | up] -> silu(gate) * up.

    Matches the kernel convention: the first ``n`` columns of the up-proj
    output are the gate, the last ``n`` the linear (`up`) half.
    """
    n = h.shape[-1] // 2
    gate, up = h[..., :n], h[..., n:]
    return silu(gate) * up


def dswiglu(da: jnp.ndarray, h: jnp.ndarray) -> jnp.ndarray:
    """Backward of SwiGLU: given dA and the *pre*-activation H, return dH.

    This is the paper's ``dAct_func`` (Algorithm 3): it recomputes the
    forward activation from H on the fly, so A never needs to be cached.
    """
    n = h.shape[-1] // 2
    gate, up = h[..., :n], h[..., n:]
    sig = jax.nn.sigmoid(gate)
    dsilu = sig * (1.0 + gate * (1.0 - sig))  # d/dg silu(g)
    dgate = da * up * dsilu
    dup = da * sig * gate
    return jnp.concatenate([dgate, dup], axis=-1)


def moe_forward_dense(
    x: jnp.ndarray,  # (T, d)
    w1: jnp.ndarray,  # (E, d, 2n)
    w2: jnp.ndarray,  # (E, n, d)
    pi: jnp.ndarray,  # (T, E) binary mask
    s: jnp.ndarray,  # (T, E) routing scores (already sparsified/masked)
) -> jnp.ndarray:
    """Algorithm 1: O_t = sum_e pi_te * S_te * SwiGLU(x_t W1_e) W2_e."""
    h = jnp.einsum("td,edf->tef", x, w1)  # (T, E, 2n)
    a = swiglu(h)  # (T, E, n)
    y = jnp.einsum("ten,end->ted", a, w2)  # (T, E, d)
    gate = (pi * s)[..., None]  # (T, E, 1)
    return jnp.sum(gate * y, axis=1)


def moe_forward_intermediates(x, w1, w2, pi, s):
    """Forward with all named intermediates, for kernel-level checks."""
    h = jnp.einsum("td,edf->tef", x, w1)
    a = swiglu(h)
    y = jnp.einsum("ten,end->ted", a, w2)
    gate = (pi * s)[..., None]
    o = jnp.sum(gate * y, axis=1)
    return {"h": h, "a": a, "y": y, "o": o}


def moe_backward_dense(x, w1, w2, pi, s, do):
    """Closed-form backward per Appendix C, dense formulation.

    Returns (dx, dw1, dw2, ds). ``ds`` is dense (T, E) with nonzeros only
    where ``pi`` is set — the gradient w.r.t. the *used* scores. Note that
    SonicMoE computes dS as <dA'_t, A_t> (Eq. 10); we intentionally write
    that form here so tests can also diff against jax.grad of the forward.
    """
    h = jnp.einsum("td,edf->tef", x, w1)  # (T, E, 2n)
    a = swiglu(h)  # (T, E, n)

    # dY_e = Broadcast(s_e) dO  (Eq. 8);   dA'_e = dO W2_e^T
    da_prime = jnp.einsum("td,end->ten", do, w2)  # (T, E, n)
    ds = jnp.einsum("ten,ten->te", da_prime, a) * pi  # Eq. 10
    da = (pi * s)[..., None] * da_prime  # Eq. 9
    dh = dswiglu(da, h)  # Eq. 11, (T, E, 2n)

    # dW2_e = (Broadcast(s_e) A_e)^T dO_e  (Eq. 12)
    a_prime = (pi * s)[..., None] * a
    dw2 = jnp.einsum("ten,td->end", a_prime, do)

    dw1 = jnp.einsum("td,tef->edf", x, dh)
    dx = jnp.einsum("tef,edf->td", dh, w1)
    return dx, dw1, dw2, ds


def moe_loss_for_autodiff(x, w1, w2, pi, s, do):
    """<O, dO> whose grads equal the VJP with cotangent dO — used to get
    an independent oracle via jax.grad."""
    o = moe_forward_dense(x, w1, w2, pi, s)
    return jnp.sum(o * do)


def tc_topk_dense(scores: jnp.ndarray, k: int):
    """Token-choice top-K as (pi, sparsified scores), jax.lax.top_k oracle.

    ``scores`` are post-softmax router scores (T, E). Returned scores are
    masked to the selected experts (no renormalization here; that is a
    model-level choice tested separately).
    """
    _, idx = jax.lax.top_k(scores, k)
    pi = jnp.zeros_like(scores).at[jnp.arange(scores.shape[0])[:, None], idx].set(1.0)
    return pi, scores * pi


def renormalize(pi: jnp.ndarray, s: jnp.ndarray, eps: float = 1e-9) -> jnp.ndarray:
    """Per-token softmax renormalization over the selected experts."""
    sel = s * pi
    denom = jnp.sum(sel, axis=-1, keepdims=True)
    return sel / jnp.maximum(denom, eps)


def expert_frequencies(pi: jnp.ndarray) -> jnp.ndarray:
    """f_e: number of tokens routed to each expert (Algorithm 4 step 2)."""
    return jnp.sum(pi, axis=0).astype(jnp.int32)


def padded_frequencies(f: jnp.ndarray, m_tile: int) -> jnp.ndarray:
    """ceil(f_e / m_tile) * m_tile — grouped-GEMM padded group sizes."""
    return ((f + m_tile - 1) // m_tile) * m_tile


def padding_waste_flops(f: jnp.ndarray, d: int, n: int, m_tile: int) -> jnp.ndarray:
    """Wasted fwd+bwd FLOPs from tile quantization (Figure 8).

    Each padded row still runs the full (6+12) n*d FLOPs of an activated
    token through up/down projection forward and backward.
    """
    pad = padded_frequencies(f, m_tile) - f
    return jnp.sum(pad) * 18 * n * d
