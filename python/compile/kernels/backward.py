"""Backward kernels: *dH*, *dW2*, *dX~*, *dW1* (Algorithms 3 and 5).

The centerpiece is the **dH kernel** with the paper's heavy epilogue
fusion (Section 4.1.2): a single varlen-M grouped GEMM that

1. gathers ``dO`` rows fused with the load (no materialized ``dO_e``),
2. computes ``dA' = dO_e W2_e^T`` on the MXU,
3. in the epilogue recomputes ``A = SwiGLU(H)`` from the cached ``H``,
   producing simultaneously

   - ``dH = dSwiGLU(s * dA', H)``      (activation gradient),
   - ``dS = <dA', A>`` per row          (router score gradient, Eq. 10),
   - ``A' = s * A``                     (the dW2 input, Eq. 12).

This is what lets SonicMoE cache only ``(X, H, pi, S)``: neither ``Y`` nor
``dY`` nor gathered copies of ``X``/``dO`` ever exist in HBM, so the
activation footprint is ``2Td + 4TKn`` — constant in granularity.

The weight-gradient kernels are varlen-K grouped GEMMs: the reduction runs
over the token dimension, accumulated across the M-tiles of each expert's
region (output block revisited per tile, zero-initialised on the first
grid step). ``dW1`` re-gathers ``X`` fused with its load — the fusion
that ScatterMoE/MoMoE only do in the forward pass (Table 1 row 1).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .config import MoEConfig
from .metadata import RoutingMeta


def _pad_rows(x: jnp.ndarray) -> jnp.ndarray:
    return jnp.concatenate([x, jnp.zeros((1,) + x.shape[1:], x.dtype)], axis=0)


def down_proj_bwd_act(
    cfg: MoEConfig,
    do: jnp.ndarray,  # (T, d) upstream gradient of O
    w2: jnp.ndarray,  # (E, n, d)
    h_packed: jnp.ndarray,  # (cap_pad, 2n) cached pre-activation
    meta: RoutingMeta,
    interpret: bool = True,
):
    """dH kernel. Returns ``(dh_packed, a_prime_packed, ds_slot)``.

    ``ds_slot`` is the per-slot score gradient; the layer gathers it back
    to (T, E) via ``slot_of`` (a cheap O(TK) index op, Algorithm 3 stores
    dS directly because its scatter targets are disjoint).
    """
    m, n, d, E = cfg.m_tile, cfg.n, cfg.d, cfg.E
    dop = _pad_rows(do.astype(jnp.float32))  # (T+1, d)

    def kernel(
        tile_e_ref,
        slot_tok_ref,
        slot_score_ref,
        slot_valid_ref,
        do_ref,
        w2_ref,
        h_ref,
        dh_ref,
        ap_ref,
        ds_ref,
    ):
        e = jnp.minimum(tile_e_ref[0], E - 1)
        toks = slot_tok_ref[...]  # (m,)
        do_rows = do_ref[toks]  # fused gather of dO: (m, d)
        w = w2_ref[e]  # (n, d)
        # mainloop: dA' = dO_e W2_e^T
        da_prime = jnp.dot(do_rows, w.T, preferred_element_type=jnp.float32)

        # --- heavy fused epilogue (Section 4.1.2) ---
        s = slot_score_ref[...][:, None]  # (m, 1)
        valid = slot_valid_ref[...][:, None]
        h = h_ref[...]  # (m, 2n) cached
        gate, up = h[:, :n], h[:, n:]
        sig = jax.nn.sigmoid(gate)
        a = gate * sig * up  # recomputed A (dAct_func computes fwd+bwd together)
        da = s * da_prime  # Eq. 9
        dsilu = sig * (1.0 + gate * (1.0 - sig))
        dgate = da * up * dsilu
        dup = da * gate * sig
        dh = jnp.concatenate([dgate, dup], axis=1) * valid
        dh_ref[...] = dh
        ap_ref[...] = s * a * valid  # A' for dW2 (Eq. 12)
        ds_ref[...] = jnp.sum(da_prime * a, axis=1) * valid[:, 0]  # Eq. 10

    return pl.pallas_call(
        kernel,
        grid=(cfg.max_tiles,),
        in_specs=[
            pl.BlockSpec((1,), lambda i: (i,)),
            pl.BlockSpec((m,), lambda i: (i,)),
            pl.BlockSpec((m,), lambda i: (i,)),
            pl.BlockSpec((m,), lambda i: (i,)),
            pl.BlockSpec((cfg.T + 1, d), lambda i: (0, 0)),
            pl.BlockSpec((E, n, d), lambda i: (0, 0, 0)),
            pl.BlockSpec((m, 2 * n), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((m, 2 * n), lambda i: (i, 0)),
            pl.BlockSpec((m, n), lambda i: (i, 0)),
            pl.BlockSpec((m,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((cfg.cap_pad, 2 * n), jnp.float32),
            jax.ShapeDtypeStruct((cfg.cap_pad, n), jnp.float32),
            jax.ShapeDtypeStruct((cfg.cap_pad,), jnp.float32),
        ],
        interpret=interpret,
    )(
        meta.tile_expert,
        meta.slot_token,
        meta.slot_score,
        meta.slot_valid,
        dop,
        w2.astype(jnp.float32),
        h_packed.astype(jnp.float32),
    )


def _segment_sum_by_expert(partials: jnp.ndarray, tile_expert: jnp.ndarray, E: int):
    """Reduce per-tile partial weight gradients into per-expert blocks.

    (max_tiles, a, b) -> (E, a, b) via a one-hot einsum. Tiles owned by
    the sentinel expert E (unused tail) are dropped. On a real TPU this
    is the varlen-K accumulation the grouped GEMM performs across the
    tiles of one expert; expressing it as partials + segment-sum keeps
    the interpret-mode lowering free of a grid-carried accumulator
    (§Perf: ~1.9x on the AOT train step)."""
    onehot = (tile_expert[:, None] == jnp.arange(E)[None, :]).astype(jnp.float32)
    return jnp.einsum("te,tab->eab", onehot, partials)


def down_proj_bwd_weight(
    cfg: MoEConfig,
    do: jnp.ndarray,  # (T, d)
    a_prime_packed: jnp.ndarray,  # (cap_pad, n)
    meta: RoutingMeta,
    interpret: bool = True,
) -> jnp.ndarray:
    """dW2 kernel: varlen-K grouped GEMM, dW2_e = A'_e^T dO_e (gathered).

    The reduction dimension is the token dim; each M-tile of an expert's
    region contributes a rank-m partial, reduced per expert by
    `_segment_sum_by_expert`. Gather of dO is fused with the load.
    """
    m, n, d, E = cfg.m_tile, cfg.n, cfg.d, cfg.E
    dop = _pad_rows(do.astype(jnp.float32))

    def kernel(slot_tok_ref, do_ref, ap_ref, dw_ref):
        toks = slot_tok_ref[...]
        do_rows = do_ref[toks]  # (m, d), zero rows for pads
        ap = ap_ref[...]  # (m, n), zero rows for pads
        dw_ref[0] = jnp.dot(ap.T, do_rows, preferred_element_type=jnp.float32)

    partials = pl.pallas_call(
        kernel,
        grid=(cfg.max_tiles,),
        in_specs=[
            pl.BlockSpec((m,), lambda i: (i,)),
            pl.BlockSpec((cfg.T + 1, d), lambda i: (0, 0)),
            pl.BlockSpec((m, n), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1, n, d), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((cfg.max_tiles, n, d), jnp.float32),
        interpret=interpret,
    )(meta.slot_token, dop, a_prime_packed.astype(jnp.float32))
    return _segment_sum_by_expert(partials, meta.tile_expert, E)


def up_proj_bwd_act(
    cfg: MoEConfig,
    dh_packed: jnp.ndarray,  # (cap_pad, 2n)
    w1: jnp.ndarray,  # (E, d, 2n)
    meta: RoutingMeta,
    interpret: bool = True,
) -> jnp.ndarray:
    """dX~ kernel: varlen-M grouped GEMM, dX~ = dH W1^T, packed layout.

    Contiguous in and out — SonicMoE stores dX~ via (modelled) async TMA
    and defers the per-token reduction to the dX aggregation kernel
    instead of fusing a scatter here (Figure 16).
    """
    m, n, d, E = cfg.m_tile, cfg.n, cfg.d, cfg.E

    def kernel(tile_e_ref, dh_ref, w1_ref, dx_ref):
        e = jnp.minimum(tile_e_ref[0], E - 1)
        dh = dh_ref[...]  # (m, 2n)
        w = w1_ref[e]  # (d, 2n)
        dx_ref[...] = jnp.dot(dh, w.T, preferred_element_type=jnp.float32)

    return pl.pallas_call(
        kernel,
        grid=(cfg.max_tiles,),
        in_specs=[
            pl.BlockSpec((1,), lambda i: (i,)),
            pl.BlockSpec((m, 2 * n), lambda i: (i, 0)),
            pl.BlockSpec((E, d, 2 * n), lambda i: (0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((m, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((cfg.cap_pad, d), jnp.float32),
        interpret=interpret,
    )(meta.tile_expert, dh_packed.astype(jnp.float32), w1.astype(jnp.float32))


def up_proj_bwd_weight(
    cfg: MoEConfig,
    x: jnp.ndarray,  # (T, d)
    dh_packed: jnp.ndarray,  # (cap_pad, 2n)
    meta: RoutingMeta,
    interpret: bool = True,
) -> jnp.ndarray:
    """dW1 kernel: varlen-K grouped GEMM, dW1_e = X_e^T dH_e.

    The ``X`` gather is fused with the load (Table 1: SonicMoE is the only
    design fusing the *backward* gathers; ScatterMoE/MoMoE launch a
    separate gather kernel here, costing an extra 2TKd of HBM traffic).
    """
    m, n, d, E = cfg.m_tile, cfg.n, cfg.d, cfg.E
    xp = _pad_rows(x.astype(jnp.float32))

    def kernel(slot_tok_ref, x_ref, dh_ref, dw_ref):
        toks = slot_tok_ref[...]
        x_rows = x_ref[toks]  # fused gather on the K (reduction) dim
        dh = dh_ref[...]  # (m, 2n)
        dw_ref[0] = jnp.dot(x_rows.T, dh, preferred_element_type=jnp.float32)

    partials = pl.pallas_call(
        kernel,
        grid=(cfg.max_tiles,),
        in_specs=[
            pl.BlockSpec((m,), lambda i: (i,)),
            pl.BlockSpec((cfg.T + 1, d), lambda i: (0, 0)),
            pl.BlockSpec((m, 2 * n), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1, d, 2 * n), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((cfg.max_tiles, d, 2 * n), jnp.float32),
        interpret=interpret,
    )(meta.slot_token, xp, dh_packed.astype(jnp.float32))
    return _segment_sum_by_expert(partials, meta.tile_expert, E)
