//! Incremental KV cache for autoregressive decode.
//!
//! Slot-oriented (vLLM-style): the cache owns `slots` independent
//! sequence slots, each holding per-layer K/V rows up to `max_seq`
//! positions. The continuous-batching scheduler allocates a slot per
//! in-flight sequence, the native decode step appends one K/V row per
//! layer per generated token, and finished sequences release their slot
//! for immediate reuse by a newly admitted request — sequences grow
//! in-flight without ever recomputing their prefix.
//!
//! The cache is a plain data substrate: it never runs math itself, the
//! native backend's `lm::decode_step_cached` reads and writes it. Write
//! protocol per generated token: `push` one K/V row per layer (the rows
//! become visible to `kv_pending` immediately, so the new position can
//! attend to itself), then `advance` the slot once after the last layer.

use anyhow::{ensure, Result};

use crate::util::dtype::{narrow, Dtype};

/// K/V buffers in the configured storage precision. bf16 rows are
/// narrowed on write ([`KvCache::push`]) and widened on read inside
/// the decode attention loop — the resident cache and the streamed
/// attention bytes both halve.
#[derive(Debug, Clone)]
enum KvStore {
    F32 { k: Vec<Vec<f32>>, v: Vec<Vec<f32>> },
    Bf16 { k: Vec<Vec<u16>>, v: Vec<Vec<u16>> },
}

/// Borrowed K/V prefix of one (layer, slot) in its storage precision.
pub enum KvView<'a> {
    F32 { k: &'a [f32], v: &'a [f32] },
    Bf16 { k: &'a [u16], v: &'a [u16] },
}

/// Per-slot, per-layer K/V row storage for incremental decode.
#[derive(Debug, Clone)]
pub struct KvCache {
    n_layers: usize,
    d: usize,
    slots: usize,
    max_seq: usize,
    /// (layer, slot) -> row-major (max_seq, d) buffer, index
    /// `layer * slots + slot`.
    store: KvStore,
    /// Committed positions per slot.
    lens: Vec<usize>,
    /// Slot allocation state.
    live: Vec<bool>,
    free: Vec<usize>,
}

impl KvCache {
    pub fn new(n_layers: usize, d: usize, slots: usize, max_seq: usize) -> KvCache {
        Self::new_with_dtype(n_layers, d, slots, max_seq, Dtype::F32)
    }

    pub fn new_with_dtype(
        n_layers: usize,
        d: usize,
        slots: usize,
        max_seq: usize,
        dtype: Dtype,
    ) -> KvCache {
        assert!(n_layers > 0 && d > 0 && slots > 0 && max_seq > 0);
        let bufs = n_layers * slots;
        let store = match dtype {
            Dtype::F32 => KvStore::F32 {
                k: (0..bufs).map(|_| vec![0f32; max_seq * d]).collect(),
                v: (0..bufs).map(|_| vec![0f32; max_seq * d]).collect(),
            },
            Dtype::Bf16 => KvStore::Bf16 {
                k: (0..bufs).map(|_| vec![0u16; max_seq * d]).collect(),
                v: (0..bufs).map(|_| vec![0u16; max_seq * d]).collect(),
            },
        };
        KvCache {
            n_layers,
            d,
            slots,
            max_seq,
            store,
            lens: vec![0; slots],
            live: vec![false; slots],
            // pop from the back: slot 0 is handed out first
            free: (0..slots).rev().collect(),
        }
    }

    /// Storage precision of the K/V rows.
    pub fn dtype(&self) -> Dtype {
        match self.store {
            KvStore::F32 { .. } => Dtype::F32,
            KvStore::Bf16 { .. } => Dtype::Bf16,
        }
    }

    pub fn slots(&self) -> usize {
        self.slots
    }

    pub fn max_seq(&self) -> usize {
        self.max_seq
    }

    pub fn live_count(&self) -> usize {
        self.slots - self.free.len()
    }

    pub fn free_count(&self) -> usize {
        self.free.len()
    }

    /// Committed sequence length of a slot.
    pub fn len(&self, slot: usize) -> usize {
        self.lens[slot]
    }

    /// Resident bytes of the K/V buffers (capacity accounting).
    pub fn bytes(&self) -> usize {
        2 * self.n_layers * self.slots * self.max_seq * self.d * self.dtype().elem_bytes()
    }

    /// Bytes committed by live sequences (K and V across all layers).
    /// Unlike [`KvCache::bytes`] — a constant capacity figure — this
    /// moves as slots fill, roll back, and release, so it is the number
    /// a metrics gauge should publish on every allocation change rather
    /// than only at poll time.
    pub fn live_bytes(&self) -> usize {
        let row = 2 * self.n_layers * self.d * self.dtype().elem_bytes();
        self.lens.iter().zip(&self.live).filter(|&(_, &l)| l).map(|(&n, _)| n * row).sum()
    }

    /// Claim a free slot (length 0), or `None` when every slot is live.
    pub fn alloc(&mut self) -> Option<usize> {
        let slot = self.free.pop()?;
        self.lens[slot] = 0;
        self.live[slot] = true;
        Some(slot)
    }

    /// Return a slot to the free pool (its prefix is discarded).
    pub fn release(&mut self, slot: usize) {
        assert!(self.live[slot], "releasing a slot that is not live");
        self.live[slot] = false;
        self.lens[slot] = 0;
        self.free.push(slot);
    }

    /// Discard every slot's prefix (parameters changed: all cached K/V
    /// rows are stale). Live slots stay allocated but restart at length
    /// 0 — callers apply reloads only between sequences.
    pub fn reset(&mut self) {
        for l in self.lens.iter_mut() {
            *l = 0;
        }
    }

    /// Write one K/V row at the pending (uncommitted) position of a
    /// slot. Each layer pushes once per token; `advance` commits. Under
    /// bf16 storage the row is narrowed (round-to-nearest-even) as it
    /// is written — the only conversion the row ever sees.
    pub fn push(&mut self, layer: usize, slot: usize, k_row: &[f32], v_row: &[f32]) -> Result<()> {
        ensure!(layer < self.n_layers, "layer {layer} out of range");
        ensure!(slot < self.slots && self.live[slot], "slot {slot} is not live");
        ensure!(k_row.len() == self.d && v_row.len() == self.d, "K/V row must be d wide");
        let pos = self.lens[slot];
        ensure!(pos < self.max_seq, "slot {slot} at capacity {}", self.max_seq);
        let off = pos * self.d;
        let idx = layer * self.slots + slot;
        match &mut self.store {
            KvStore::F32 { k, v } => {
                k[idx][off..off + self.d].copy_from_slice(k_row);
                v[idx][off..off + self.d].copy_from_slice(v_row);
            }
            KvStore::Bf16 { k, v } => {
                for (dst, &src) in k[idx][off..off + self.d].iter_mut().zip(k_row) {
                    *dst = narrow(src);
                }
                for (dst, &src) in v[idx][off..off + self.d].iter_mut().zip(v_row) {
                    *dst = narrow(src);
                }
            }
        }
        Ok(())
    }

    /// K/V prefix of a slot *including* the pending position written by
    /// [`KvCache::push`] — what the new token's attention reads. f32
    /// storage only; the dtype-generic path is [`KvCache::kv_pending_view`].
    pub fn kv_pending(&self, layer: usize, slot: usize) -> (&[f32], &[f32]) {
        match self.kv_pending_view(layer, slot) {
            KvView::F32 { k, v } => (k, v),
            KvView::Bf16 { .. } => {
                panic!("kv_pending on a bf16 cache (use kv_pending_view)")
            }
        }
    }

    /// Dtype-aware [`KvCache::kv_pending`]: the prefix in its storage
    /// precision (the bf16 attention loop widens element-by-element).
    pub fn kv_pending_view(&self, layer: usize, slot: usize) -> KvView<'_> {
        let n = (self.lens[slot] + 1).min(self.max_seq) * self.d;
        let idx = layer * self.slots + slot;
        match &self.store {
            KvStore::F32 { k, v } => KvView::F32 { k: &k[idx][..n], v: &v[idx][..n] },
            KvStore::Bf16 { k, v } => KvView::Bf16 { k: &k[idx][..n], v: &v[idx][..n] },
        }
    }

    /// Roll a slot back to `len` committed positions (speculative
    /// decode rejection: the target refused some drafted suffix, so the
    /// rows written past the accepted prefix are abandoned). The K/V
    /// row at a position depends only on that position's token and the
    /// prefix before it, so a later `push` at the truncated position
    /// overwrites the stale row and the cache is indistinguishable from
    /// one that never held the rejected suffix (the truncate-then-append
    /// equality the unit tests pin down).
    pub fn truncate(&mut self, slot: usize, len: usize) -> Result<()> {
        ensure!(slot < self.slots && self.live[slot], "slot {slot} is not live");
        ensure!(
            len <= self.lens[slot],
            "truncate to {len} cannot extend slot {slot} (len {})",
            self.lens[slot]
        );
        self.lens[slot] = len;
        Ok(())
    }

    /// Commit the pending position (call once per token, after every
    /// layer has pushed its row).
    pub fn advance(&mut self, slot: usize) {
        assert!(self.live[slot], "advancing a slot that is not live");
        assert!(self.lens[slot] < self.max_seq, "advancing past capacity");
        self.lens[slot] += 1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn alloc_release_reuse() {
        let mut c = KvCache::new(2, 4, 2, 8);
        assert_eq!(c.free_count(), 2);
        let a = c.alloc().unwrap();
        let b = c.alloc().unwrap();
        assert_ne!(a, b);
        assert!(c.alloc().is_none(), "only 2 slots");
        assert_eq!(c.live_count(), 2);
        c.release(a);
        let a2 = c.alloc().unwrap();
        assert_eq!(a2, a, "released slot is reused");
        assert_eq!(c.len(a2), 0, "reused slot starts empty");
    }

    #[test]
    fn push_advance_and_read_back() {
        let d = 3;
        let mut c = KvCache::new(2, d, 1, 4);
        let s = c.alloc().unwrap();
        for t in 0..4 {
            for layer in 0..2 {
                let k: Vec<f32> = (0..d).map(|j| (t * 10 + layer * 100 + j) as f32).collect();
                let v: Vec<f32> = k.iter().map(|x| -x).collect();
                c.push(layer, s, &k, &v).unwrap();
                let (kc, vc) = c.kv_pending(layer, s);
                assert_eq!(kc.len(), (t + 1) * d, "pending prefix includes the new row");
                assert_eq!(&kc[t * d..(t + 1) * d], k.as_slice());
                assert_eq!(&vc[t * d..(t + 1) * d], v.as_slice());
            }
            c.advance(s);
            assert_eq!(c.len(s), t + 1);
        }
        // earlier rows survived the appends
        let (kc, _) = c.kv_pending(0, s);
        assert_eq!(kc[0], 0.0);
        assert_eq!(kc[d], 10.0);
        // at capacity: further pushes refuse
        assert!(c.push(0, s, &[0.0; 3], &[0.0; 3]).is_err());
    }

    #[test]
    fn capacity_and_validation() {
        let mut c = KvCache::new(1, 2, 1, 2);
        let s = c.alloc().unwrap();
        assert!(c.push(5, s, &[0.0; 2], &[0.0; 2]).is_err(), "bad layer");
        assert!(c.push(0, s, &[0.0; 3], &[0.0; 2]).is_err(), "bad width");
        c.push(0, s, &[1.0, 2.0], &[3.0, 4.0]).unwrap();
        c.advance(s);
        c.push(0, s, &[1.0, 2.0], &[3.0, 4.0]).unwrap();
        c.advance(s);
        assert!(c.push(0, s, &[1.0, 2.0], &[3.0, 4.0]).is_err(), "full slot");
        assert!(c.bytes() > 0);
    }

    /// Rolling back rejected positions and appending different rows
    /// leaves the cache bitwise identical to one that only ever held
    /// the accepted stream — the guarantee speculative rejection
    /// rollback rests on.
    #[test]
    fn truncate_then_append_equals_fresh_stream() {
        let d = 3;
        let push_tok = |c: &mut KvCache, s: usize, tag: f32| {
            for layer in 0..2 {
                let k: Vec<f32> = (0..d).map(|j| tag + layer as f32 * 100.0 + j as f32).collect();
                let v: Vec<f32> = k.iter().map(|x| -x).collect();
                c.push(layer, s, &k, &v).unwrap();
            }
            c.advance(s);
        };
        // stream A: accept 2, speculate 2 (rejected), roll back, then
        // append the corrected continuation
        let mut a = KvCache::new(2, d, 1, 8);
        let sa = a.alloc().unwrap();
        for tag in [1.0, 2.0, 777.0, 888.0] {
            push_tok(&mut a, sa, tag);
        }
        a.truncate(sa, 2).unwrap();
        assert_eq!(a.len(sa), 2);
        for tag in [3.0, 4.0] {
            push_tok(&mut a, sa, tag);
        }
        // stream B: the accepted stream, no detour
        let mut b = KvCache::new(2, d, 1, 8);
        let sb = b.alloc().unwrap();
        for tag in [1.0, 2.0, 3.0, 4.0] {
            push_tok(&mut b, sb, tag);
        }
        assert_eq!(a.len(sa), b.len(sb));
        for layer in 0..2 {
            let (ka, va) = a.kv_pending(layer, sa);
            let (kb, vb) = b.kv_pending(layer, sb);
            assert_eq!(ka, kb, "layer {layer} K prefix diverged after rollback");
            assert_eq!(va, vb, "layer {layer} V prefix diverged after rollback");
        }
    }

    /// A mid-stream disconnect releases a slot whose length was rolled
    /// back; the next sequence reuses it from zero.
    #[test]
    fn truncate_validation_and_disconnect_reuse() {
        let mut c = KvCache::new(1, 2, 2, 4);
        let s = c.alloc().unwrap();
        for _ in 0..3 {
            c.push(0, s, &[1.0, 2.0], &[3.0, 4.0]).unwrap();
            c.advance(s);
        }
        assert!(c.truncate(s, 4).is_err(), "truncate cannot extend");
        c.truncate(s, 1).unwrap();
        assert_eq!(c.len(s), 1);
        // idempotent at the same length, and a free slot is rejected
        c.truncate(s, 1).unwrap();
        let other = c.alloc().unwrap();
        c.release(other);
        assert!(c.truncate(other, 0).is_err(), "truncating a freed slot");
        // mid-stream disconnect: release while rolled back, then reuse
        c.release(s);
        let s2 = c.alloc().unwrap();
        assert_eq!(s2, s, "released slot is reused");
        assert_eq!(c.len(s2), 0, "reused slot starts empty");
        c.push(0, s2, &[9.0, 9.0], &[9.0, 9.0]).unwrap();
        let (k, _) = c.kv_pending(0, s2);
        assert_eq!(&k[..2], &[9.0, 9.0], "fresh rows overwrite the stale prefix");
    }

    /// bf16 storage: halved resident bytes, rows narrowed on write
    /// (exact bf16 values round-trip bitwise), rollback semantics
    /// unchanged.
    #[test]
    fn bf16_cache_halves_bytes_and_roundtrips_rows() {
        use crate::util::dtype::widen;
        let d = 4;
        let f = KvCache::new(2, d, 3, 8);
        let mut c = KvCache::new_with_dtype(2, d, 3, 8, Dtype::Bf16);
        assert_eq!(f.dtype(), Dtype::F32);
        assert_eq!(c.dtype(), Dtype::Bf16);
        assert_eq!(c.bytes() * 2, f.bytes(), "bf16 cache is half the bytes");

        let s = c.alloc().unwrap();
        // exactly-representable values survive the round trip bitwise
        let k_row = [1.0f32, -0.5, 2.0, 0.25];
        let v_row = [0.5f32, -1.0, 4.0, -0.125];
        c.push(0, s, &k_row, &v_row).unwrap();
        match c.kv_pending_view(0, s) {
            KvView::Bf16 { k, v } => {
                for j in 0..d {
                    assert_eq!(widen(k[j]), k_row[j]);
                    assert_eq!(widen(v[j]), v_row[j]);
                }
            }
            KvView::F32 { .. } => panic!("bf16 cache returned f32 view"),
        }
        c.advance(s);
        // a non-representable value lands on its RNE neighbor
        let fine = [1.0f32 + 1.0 / 512.0, 0.0, 0.0, 0.0];
        c.push(0, s, &fine, &fine).unwrap();
        match c.kv_pending_view(0, s) {
            KvView::Bf16 { k, .. } => {
                let got = widen(k[d]);
                assert!(got == 1.0 || got == 1.0 + 1.0 / 128.0);
                assert_ne!(got, fine[0]);
            }
            KvView::F32 { .. } => unreachable!(),
        }
        c.advance(s);
        // truncate-then-append stays bitwise (narrowing is deterministic)
        c.truncate(s, 1).unwrap();
        c.push(0, s, &fine, &fine).unwrap();
        c.advance(s);
        match c.kv_pending_view(0, s) {
            KvView::Bf16 { k, .. } => assert_eq!(widen(k[d]), widen(narrow(fine[0]))),
            KvView::F32 { .. } => unreachable!(),
        }
    }

    #[test]
    #[should_panic(expected = "kv_pending on a bf16 cache")]
    fn f32_accessor_refuses_bf16_cache() {
        let mut c = KvCache::new_with_dtype(1, 2, 1, 2, Dtype::Bf16);
        let s = c.alloc().unwrap();
        c.push(0, s, &[1.0, 2.0], &[3.0, 4.0]).unwrap();
        let _ = c.kv_pending(0, s);
    }

    /// Speculative rejection rollback on the bf16 arm: the same
    /// truncate-then-append-equals-fresh-stream guarantee as f32, but
    /// checked on the narrowed u16 rows (narrowing is deterministic, so
    /// the detour leaves no trace even in reduced precision).
    #[test]
    fn bf16_truncate_then_append_equals_fresh_stream() {
        let d = 3;
        let push_tok = |c: &mut KvCache, s: usize, tag: f32| {
            for layer in 0..2 {
                // deliberately not bf16-representable: exercises RNE on both arms
                let k: Vec<f32> =
                    (0..d).map(|j| tag + layer as f32 * 100.0 + j as f32 + 1.0 / 512.0).collect();
                let v: Vec<f32> = k.iter().map(|x| -x).collect();
                c.push(layer, s, &k, &v).unwrap();
            }
            c.advance(s);
        };
        let mut a = KvCache::new_with_dtype(2, d, 1, 8, Dtype::Bf16);
        let sa = a.alloc().unwrap();
        for tag in [1.0, 2.0, 777.0, 888.0] {
            push_tok(&mut a, sa, tag);
        }
        a.truncate(sa, 2).unwrap();
        for tag in [3.0, 4.0] {
            push_tok(&mut a, sa, tag);
        }
        let mut b = KvCache::new_with_dtype(2, d, 1, 8, Dtype::Bf16);
        let sb = b.alloc().unwrap();
        for tag in [1.0, 2.0, 3.0, 4.0] {
            push_tok(&mut b, sb, tag);
        }
        for layer in 0..2 {
            match (a.kv_pending_view(layer, sa), b.kv_pending_view(layer, sb)) {
                (KvView::Bf16 { k: ka, v: va }, KvView::Bf16 { k: kb, v: vb }) => {
                    assert_eq!(ka, kb, "layer {layer} bf16 K prefix diverged after rollback");
                    assert_eq!(va, vb, "layer {layer} bf16 V prefix diverged after rollback");
                }
                _ => panic!("bf16 cache returned f32 view"),
            }
        }
    }

    /// `live_bytes` tracks committed rows of live slots only — it rises
    /// on advance, falls on truncate and release, and ignores capacity.
    #[test]
    fn live_bytes_follows_alloc_advance_truncate_release() {
        let (n_layers, d) = (2, 4);
        let mut c = KvCache::new(n_layers, d, 2, 8);
        let row = 2 * n_layers * d * 4; // K+V, all layers, f32
        assert_eq!(c.live_bytes(), 0);
        let s = c.alloc().unwrap();
        assert_eq!(c.live_bytes(), 0, "allocation alone commits nothing");
        for t in 0..3 {
            for layer in 0..n_layers {
                c.push(layer, s, &[0.0; 4], &[0.0; 4]).unwrap();
            }
            c.advance(s);
            assert_eq!(c.live_bytes(), (t + 1) * row);
        }
        c.truncate(s, 1).unwrap();
        assert_eq!(c.live_bytes(), row, "rollback returns committed bytes");
        c.release(s);
        assert_eq!(c.live_bytes(), 0, "released slots do not count");
        assert!(c.bytes() > 0, "capacity accounting is unaffected");
        // bf16 commits half the bytes per row
        let mut h = KvCache::new_with_dtype(n_layers, d, 2, 8, Dtype::Bf16);
        let hs = h.alloc().unwrap();
        for layer in 0..n_layers {
            h.push(layer, hs, &[0.0; 4], &[0.0; 4]).unwrap();
        }
        h.advance(hs);
        assert_eq!(h.live_bytes() * 2, row);
    }

    #[test]
    fn reset_clears_lengths_but_keeps_allocation() {
        let mut c = KvCache::new(1, 2, 2, 4);
        let s = c.alloc().unwrap();
        c.push(0, s, &[1.0, 2.0], &[3.0, 4.0]).unwrap();
        c.advance(s);
        assert_eq!(c.len(s), 1);
        c.reset();
        assert_eq!(c.len(s), 0);
        assert_eq!(c.live_count(), 1, "reset does not free slots");
    }
}
