//! Regenerate every table and figure of the paper's evaluation section
//! as text (the simulator substitutes for the H100/B300 testbed — see
//! DESIGN.md "Substitutions" and EXPERIMENTS.md for paper-vs-measured).
//!
//!     cargo run --release --example paper_figures [-- --only fig13]

use anyhow::Result;
use sonic_moe::bench::figures as f;
use sonic_moe::bench::Table;
use sonic_moe::util::cli::Cli;

fn main() -> Result<()> {
    let cli = Cli::new("paper_figures", "regenerate all paper tables/figures")
        .opt("only", "", "comma-separated subset (e.g. fig11,fig13)");
    let a = cli.parse()?;
    let only: Vec<&str> = a.get("only").split(',').filter(|s| !s.is_empty()).collect();
    let want = |name: &str| only.is_empty() || only.contains(&name);

    let sections: Vec<(&str, Vec<Table>)> = vec![
        ("fig01", f::fig01()),
        ("fig05", f::fig05()),
        ("fig08", vec![f::fig08()]),
        ("fig10", vec![f::fig10()]),
        ("fig11", f::fig11()),
        ("fig12", f::fig12()),
        ("fig13", f::fig13()),
        ("fig14", vec![f::fig14()]),
        ("fig18_19", f::fig18_19()),
        ("fig20", f::fig20()),
        ("fig21", vec![f::fig21()]),
        ("fig22", f::fig22()),
        ("cluster", vec![f::cluster_claim()]),
    ];
    for (name, tables) in sections {
        if want(name) {
            for t in tables {
                t.print();
            }
        }
    }
    Ok(())
}
