"""L2 SonicMoE layer: custom_vjp wiring the 8 L1 kernels per Figure 3.

``moe_compute`` is the router-agnostic MoE computation (Section 3.1). Its
custom VJP implements the paper's memory-efficient backward:

- forward launches the **A**, **Y**, **O** kernels and saves *only*
  ``(X, H_packed, routing metadata)`` — never ``Y``, ``A`` or gathered
  copies (the 2Td + 4TKn activation footprint of Section 3.2);
- backward launches **dH**, **dW2**, **dX~**, **dW1**, **dX** and gathers
  ``dS`` from the dH kernel's fused epilogue.

``sonic_moe_block`` adds the router (TC top-K or token rounding), score
renormalization and the auxiliary load-balancing loss — the full drop-in
MoE block used by the L2 transformer (model.py).
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp

from .kernels import MoEConfig
from .kernels import aggregation, backward, grouped_gemm, metadata, router


# ---------------------------------------------------------------------------
# moe_compute: the 8-kernel computation with a memory-efficient custom VJP
# ---------------------------------------------------------------------------


@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def moe_compute(cfg: MoEConfig, x, w1, w2, pi, s):
    """O = sum_e pi_te * s_te * SwiGLU(x W1_e) W2_e via the L1 kernels.

    Differentiable in ``x``, ``w1``, ``w2`` and ``s``; the routing mask
    ``pi`` is a constant of the computation (zero cotangent).
    """
    o, _ = _moe_compute_fwd(cfg, x, w1, w2, pi, s)
    return o


def _moe_compute_fwd(cfg: MoEConfig, x, w1, w2, pi, s):
    meta = metadata.build_metadata(cfg, pi, s)
    h_packed, a_packed = grouped_gemm.up_proj_swiglu(cfg, x, w1, meta)
    y_packed = grouped_gemm.down_proj(cfg, a_packed, w2, meta)
    o = aggregation.expert_aggregate(cfg, y_packed, meta)
    # Residuals — the *entire* activation cache of the layer (Figure 3 red
    # boxes): X, H, and routing metadata. A, Y, gathered X/dO are never
    # saved; A is recomputed from H inside the dH kernel's epilogue.
    residuals = (x, w1, w2, h_packed, meta)
    return o, residuals


def _moe_compute_bwd(cfg: MoEConfig, residuals, do):
    x, w1, w2, h_packed, meta = residuals
    dh, a_prime, ds_slot = backward.down_proj_bwd_act(cfg, do, w2, h_packed, meta)
    dw2 = backward.down_proj_bwd_weight(cfg, do, a_prime, meta)
    dw1 = backward.up_proj_bwd_weight(cfg, x, dh, meta)
    dxt = backward.up_proj_bwd_act(cfg, dh, w1, meta)
    dx = aggregation.grad_aggregate(cfg, dxt, meta)
    # dS: gather the per-slot epilogue output back to (T, E); the sentinel
    # slot (== cap_pad) reads the appended zero.
    padded = jnp.concatenate([ds_slot, jnp.zeros((1,), ds_slot.dtype)])
    ds = padded[meta.slot_of]
    dpi = jnp.zeros_like(ds)  # mask is non-differentiable
    return dx, dw1, dw2, dpi, ds


moe_compute.defvjp(_moe_compute_fwd, _moe_compute_bwd)


def residual_bytes(cfg: MoEConfig, dtype_bytes: int = 4) -> dict:
    """Static accounting of what _moe_compute_fwd saves (tested against
    the paper's 2Td + 4TKn formula up to routing metadata)."""
    tensor = dtype_bytes * (cfg.T * cfg.d + cfg.cap_pad * 2 * cfg.n)
    meta_b = 4 * (
        2 * cfg.E + 1  # f, p, offsets
        + 3 * cfg.cap_pad  # slot_token/score/valid
        + cfg.max_tiles
        + cfg.T * cfg.E  # slot_of
        + 1
    )
    return {"tensors": tensor, "metadata": meta_b, "total": tensor + meta_b}


# ---------------------------------------------------------------------------
# Full MoE block: router + compute + aux loss
# ---------------------------------------------------------------------------

ROUTERS = ("tc", "tr-nr-f", "tr-sr-f", "tr-nr-s", "tr-balance-f", "tr-up",
           "tr-down", "ec", "drop")


def route(
    cfg: MoEConfig,
    scores: jnp.ndarray,
    method: str,
    key: jax.Array | None = None,
) -> router.RoutingDecision:
    """Dispatch to a routing method by name (see ROUTERS)."""
    if method == "tc":
        return router.tc_topk(scores, cfg.K)
    if method.startswith("tr-"):
        return router.token_rounding(
            scores, cfg.K, cfg.m_tile, subroutine=method[3:], key=key
        )
    if method == "ec":
        return router.expert_choice(scores, cfg.K)
    if method == "drop":
        return router.token_drop(scores, cfg.K, cfg.m_tile)
    raise ValueError(f"unknown routing method {method!r}")


def load_balance_loss(pi: jnp.ndarray, scores: jnp.ndarray, k: int) -> jnp.ndarray:
    """Shazeer-style auxiliary loss: E * sum_e frac_tokens_e * frac_score_e.

    Equals 1 under perfect balance; the paper trains with coefficient 0.01
    and no router z-loss (Appendix I).
    """
    t, e = scores.shape
    frac_tokens = jnp.mean(jax.lax.stop_gradient(pi), axis=0) / k
    frac_scores = jnp.mean(scores, axis=0)
    return e * jnp.sum(frac_tokens * frac_scores)


def sonic_moe_block(
    cfg: MoEConfig,
    x: jnp.ndarray,  # (T, d)
    wr: jnp.ndarray,  # (d, E) router weights
    w1: jnp.ndarray,  # (E, d, 2n)
    w2: jnp.ndarray,  # (E, n, d)
    method: str = "tc",
    key: jax.Array | None = None,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Full MoE block: router GEMM -> routing -> 3 fwd kernels.

    Returns ``(output, aux_loss)``. Gradients flow to ``wr`` through the
    renormalized scores of the routed tokens (the dS path) and the aux
    loss; the discrete mask is stop-gradient, as in standard MoE training.
    """
    logits = x @ wr
    scores = jax.nn.softmax(logits, axis=-1)
    dec = route(cfg, scores, method, key)
    pi = jax.lax.stop_gradient(dec.pi)
    dec_r = router.renormalize_decision(dec._replace(pi=pi, scores=scores * pi))
    o = moe_compute(cfg, x, w1, w2, pi, dec_r.scores)
    aux = load_balance_loss(pi, scores, cfg.K)
    return o, aux
