//! Decode coordinator: the autoregressive generation engine behind the
//! gateway's continuous batcher.
//!
//! [`DecodeCore`] owns one model's parameters plus an incremental
//! [`KvCache`](crate::runtime::kvcache::KvCache) and exposes the two
//! operations the scheduler composes: `prefill` (feed a prompt into a
//! fresh slot, returning the logits that sample the first generated
//! token) and `decode_step` (advance every live slot by one token in a
//! single packed step). Slots are allocated per in-flight sequence and
//! released on completion, so the cache is reused vLLM-style without
//! ever recomputing a prefix.
//!
//! The core drives the native backend's cached decode path directly —
//! the `lm_decode_step` manifest artifact is the equivalent stateless
//! contract (full-prefix recompute), kept for AOT export and parity
//! tests. Under row-local routers (TC) the two are numerically
//! identical token for token.

use anyhow::{bail, ensure, Result};

use crate::memory::residency::ResidencySpec;
use crate::runtime::backend::native::kernels::scratch;
use crate::runtime::backend::native::lm::{self, LmCfg, ParamStore, RouterKind};
use crate::runtime::kvcache::KvCache;
use crate::runtime::{backend, Runtime};
use crate::util::dtype::Dtype;
use crate::util::tensor::Tensor;

/// Greedy next-token choice: argmax with lowest-index tie-break (the
/// deterministic sampling rule the parity tests rely on).
pub fn argmax(logits: &[f32]) -> i32 {
    let mut best = 0usize;
    let mut bv = f32::NEG_INFINITY;
    for (i, &x) in logits.iter().enumerate() {
        if x > bv {
            bv = x;
            best = i;
        }
    }
    best as i32
}

/// The packed decode engine: parameters + KV cache + slot allocation.
pub struct DecodeCore {
    cfg: LmCfg,
    store: ParamStore,
    cache: KvCache,
    /// Vocabulary size (logits width).
    pub vocab: usize,
    /// Per-slot KV capacity: prompt + generated tokens per sequence.
    pub max_seq: usize,
    config_name: String,
}

impl DecodeCore {
    /// Open on a named backend ("" = default). The cached decode path
    /// runs native numerics, so only the native backend is accepted.
    /// `slots` = 0 defaults to twice the model batch (the largest
    /// exported decode shape); `max_seq` = 0 defaults to the model's
    /// sequence length.
    pub fn new_with_backend(
        artifacts_dir: &str,
        config: &str,
        backend_name: &str,
        slots: usize,
        max_seq: usize,
    ) -> Result<DecodeCore> {
        Self::new_with_dtype(artifacts_dir, config, backend_name, slots, max_seq, Dtype::F32)
    }

    /// [`Self::new_with_backend`] with a storage precision: under
    /// [`Dtype::Bf16`] the GEMM-streamed weights and the KV cache are
    /// stored as bf16 and widened on read (accumulation stays f32),
    /// halving resident and streamed bytes on the bandwidth-bound path.
    pub fn new_with_dtype(
        artifacts_dir: &str,
        config: &str,
        backend_name: &str,
        slots: usize,
        max_seq: usize,
        dtype: Dtype,
    ) -> Result<DecodeCore> {
        Self::new_inner(artifacts_dir, config, backend_name, slots, max_seq, dtype, None)
    }

    /// [`Self::new_with_dtype`] with tiered expert residency: the
    /// expert weights are spilled to disk behind an
    /// [`ExpertStore`](crate::memory::residency::ExpertStore) with the
    /// spec's resident-bytes budget, prefetched router-first during
    /// every forward. Outputs are bitwise identical to the fully
    /// resident core at any budget.
    pub fn new_with_residency(
        artifacts_dir: &str,
        config: &str,
        backend_name: &str,
        slots: usize,
        max_seq: usize,
        dtype: Dtype,
        spec: &ResidencySpec,
    ) -> Result<DecodeCore> {
        Self::new_inner(artifacts_dir, config, backend_name, slots, max_seq, dtype, Some(spec))
    }

    #[allow(clippy::too_many_arguments)]
    fn new_inner(
        artifacts_dir: &str,
        config: &str,
        backend_name: &str,
        slots: usize,
        max_seq: usize,
        dtype: Dtype,
        residency: Option<&ResidencySpec>,
    ) -> Result<DecodeCore> {
        let be = backend::by_name(backend_name)?;
        if be.name() != "native" {
            bail!("the decode path requires the native backend (got {})", be.name());
        }
        let rt = Runtime::open_with(artifacts_dir, config, be)?;
        let m = &rt.manifest.model;
        let router = lm::parse_router_method(&m.router)?;
        // continuous batching relies on rows being independent of batch
        // composition; batch-global routers (TR, EC) couple rows
        // through the routing decision and break token-for-token parity
        if router != RouterKind::Tc {
            bail!(
                "the decode path requires the row-local tc router; config {config:?} \
                 routes with {:?} (batch-global routers break decode parity)",
                m.router
            );
        }
        let cfg = LmCfg {
            vocab: m.vocab,
            d: m.d,
            n_layers: m.n_layers,
            n_heads: m.n_heads,
            rows: 1,
            seq: 1,
            n: m.n,
            e: m.e,
            k: m.k,
            m_tile: m.m_tile,
            aux_coeff: m.aux_coeff,
            router,
        };
        let slots = if slots == 0 { 2 * m.batch } else { slots };
        let max_seq = if max_seq == 0 { m.seq_len } else { max_seq };
        let names: Vec<String> = rt.manifest.params.iter().map(|p| p.name.clone()).collect();
        let params = rt.load_initial_params()?;
        ensure!(names.len() == params.len(), "manifest/params length mismatch");
        let cache = KvCache::new_with_dtype(cfg.n_layers, cfg.d, slots, max_seq, dtype);
        let named: Vec<(String, Tensor)> = names.into_iter().zip(params).collect();
        let store = match residency {
            Some(spec) => ParamStore::new_tiered(named, dtype, spec)?,
            None => ParamStore::new(named, dtype),
        };
        Ok(DecodeCore {
            vocab: cfg.vocab,
            max_seq,
            cfg,
            store,
            cache,
            config_name: config.to_string(),
        })
    }

    /// The tiered expert store, when this core runs under residency.
    pub fn residency(&self) -> Option<&crate::memory::residency::ExpertStore> {
        self.store.residency()
    }

    /// Storage precision of the weights and KV cache.
    pub fn dtype(&self) -> Dtype {
        self.store.dtype()
    }

    /// Resident parameter bytes in the configured storage precision.
    pub fn weight_bytes(&self) -> usize {
        self.store.weight_bytes()
    }

    /// Total sequence slots (live + free).
    pub fn slots(&self) -> usize {
        self.cache.slots()
    }

    /// KV slots currently free for new sequences.
    pub fn free_slots(&self) -> usize {
        self.cache.free_count()
    }

    /// KV slots currently holding a live sequence.
    pub fn live_slots(&self) -> usize {
        self.cache.live_count()
    }

    /// Committed tokens (prompt + generated) held by a slot.
    pub fn slot_len(&self, slot: usize) -> usize {
        self.cache.len(slot)
    }

    /// Resident KV bytes (capacity accounting for stats).
    pub fn kv_bytes(&self) -> usize {
        self.cache.bytes()
    }

    /// KV bytes committed by live sequences right now (the moving
    /// gauge; [`DecodeCore::kv_bytes`] is the constant capacity).
    pub fn live_kv_bytes(&self) -> usize {
        self.cache.live_bytes()
    }

    /// Claim a slot for a new sequence.
    pub fn alloc_slot(&mut self) -> Option<usize> {
        self.cache.alloc()
    }

    /// Release a finished sequence's slot for reuse.
    pub fn free_slot(&mut self, slot: usize) {
        self.cache.release(slot);
    }

    /// Roll a slot back to `len` committed tokens (speculative-decode
    /// rejection: the K/V rows past the accepted prefix are abandoned
    /// and overwritten by the next append).
    pub fn truncate(&mut self, slot: usize, len: usize) -> Result<()> {
        self.cache.truncate(slot, len)
    }

    /// Feed a prompt into a fresh slot one position at a time (the
    /// cached equivalent of a prefill pass) and return the logits after
    /// the last prompt token — greedy-sampling them yields the first
    /// generated token.
    pub fn prefill(&mut self, slot: usize, prompt: &[i32]) -> Result<Vec<f32>> {
        ensure!(!prompt.is_empty(), "empty prompt");
        ensure!(self.cache.len(slot) == 0, "prefill requires a fresh slot");
        ensure!(
            prompt.len() <= self.max_seq,
            "prompt of {} exceeds the {} slot capacity",
            prompt.len(),
            self.max_seq
        );
        let params = self.store.view(self.cfg.n_layers)?;
        let mut logits = Vec::new();
        for &t in prompt {
            let next = lm::decode_step_cached(&self.cfg, &params, &mut self.cache, &[(slot, t)])?;
            // recycle the previous position's logits so the prefill
            // loop runs on one pooled buffer
            let prev = std::mem::replace(&mut logits, next);
            scratch::put(prev);
        }
        Ok(logits)
    }

    /// Advance every `(slot, token)` row by one position in a single
    /// packed step; returns next-token logits in row order
    /// (`rows.len() * vocab`).
    pub fn decode_step(&mut self, rows: &[(usize, i32)]) -> Result<Vec<f32>> {
        self.decode_step_padded(rows, rows.len())
    }

    /// [`Self::decode_step`] inside an executed shape of `exec_rows`
    /// >= rows.len(): the `exec_rows - live` padding rows *really run*
    /// (same per-position compute on a dummy token, result discarded),
    /// mirroring the fixed executed shapes of an accelerator decode
    /// artifact — so slot-quantization policies differ in measured
    /// work, not just counters.
    pub fn decode_step_padded(
        &mut self,
        rows: &[(usize, i32)],
        exec_rows: usize,
    ) -> Result<Vec<f32>> {
        ensure!(!rows.is_empty(), "empty decode step");
        let params = self.store.view(self.cfg.n_layers)?;
        for _ in rows.len()..exec_rows {
            std::hint::black_box(lm::decode_pad_row(&self.cfg, &params));
        }
        lm::decode_step_cached(&self.cfg, &params, &mut self.cache, rows)
    }

    /// Hand a consumed logits buffer back to this worker's scratch
    /// arena. [`Self::prefill`] / [`Self::decode_step`] check their
    /// result out of the per-thread pool, so a caller that recycles it
    /// (the gateway's decode scheduler does, every step) keeps the
    /// whole generation loop allocation-free after warmup.
    pub fn recycle_logits(&self, logits: Vec<f32>) {
        scratch::put(logits);
    }

    /// Replace parameters from a trained checkpoint. Every cached K/V
    /// row is stale under the new parameters, so the cache is reset —
    /// callers apply reloads only when no sequence is in flight.
    pub fn load_checkpoint(&mut self, dir: &str) -> Result<()> {
        let (_, cfg_name, names, params) = super::checkpoint::load(dir)?;
        if cfg_name != self.config_name {
            bail!("checkpoint config {cfg_name:?} != decode config {:?}", self.config_name);
        }
        ensure!(names.len() == params.len(), "checkpoint names/params mismatch");
        // re-quantize (and re-tier) under the core's configured layout
        self.store = self.store.rebuild(names.into_iter().zip(params).collect())?;
        self.cache.reset();
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const NO_ARTIFACTS: &str = "/nonexistent-artifacts-dir";

    fn core(slots: usize) -> DecodeCore {
        DecodeCore::new_with_backend(NO_ARTIFACTS, "small", "native", slots, 0).unwrap()
    }

    fn greedy_generate(core: &mut DecodeCore, prompt: &[i32], n: usize) -> Vec<i32> {
        let slot = core.alloc_slot().expect("free slot");
        let mut logits = core.prefill(slot, prompt).unwrap();
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            let t = argmax(&logits);
            out.push(t);
            if out.len() == n {
                break;
            }
            logits = core.decode_step(&[(slot, t)]).unwrap();
        }
        core.free_slot(slot);
        out
    }

    #[test]
    fn argmax_ties_break_low() {
        assert_eq!(argmax(&[0.0, 2.0, 2.0, 1.0]), 1);
        assert_eq!(argmax(&[5.0]), 0);
        assert_eq!(argmax(&[f32::NEG_INFINITY, -1.0]), 1);
    }

    #[test]
    fn defaults_and_slot_lifecycle() {
        let mut c = core(0);
        // builtin small: batch 4 -> 8 slots, seq 32
        assert_eq!(c.slots(), 8);
        assert_eq!(c.max_seq, 32);
        assert_eq!(c.vocab, 256);
        assert!(c.kv_bytes() > 0);
        let s = c.alloc_slot().unwrap();
        assert_eq!(c.live_slots(), 1);
        let logits = c.prefill(s, &[1, 2, 3]).unwrap();
        assert_eq!(logits.len(), c.vocab);
        assert_eq!(c.slot_len(s), 3);
        // a padded step returns the same logits as an unpadded one —
        // padding rows are dummy compute, never state
        let unpadded = c.decode_step(&[(s, 7)]).unwrap();
        let mut c2 = core(0);
        let s2 = c2.alloc_slot().unwrap();
        c2.prefill(s2, &[1, 2, 3]).unwrap();
        let padded = c2.decode_step_padded(&[(s2, 7)], 4).unwrap();
        assert_eq!(unpadded, padded, "padding rows must not change live-row logits");
        // a second prefill into a used slot is refused
        assert!(c.prefill(s, &[1]).is_err());
        c.free_slot(s);
        assert_eq!(c.live_slots(), 0);
    }

    /// One worker's `DecodeCore` reuses its thread's scratch arena
    /// across requests: a second sequence through the same core
    /// performs zero arena allocations (the first request warmed the
    /// pool).
    #[test]
    fn decode_core_reuses_arena_across_requests() {
        let mut c = core(2);
        let run_request = |c: &mut DecodeCore| {
            let s = c.alloc_slot().unwrap();
            let l = c.prefill(s, &[1, 2, 3]).unwrap();
            c.recycle_logits(l);
            for t in 0..3 {
                let l = c.decode_step(&[(s, t)]).unwrap();
                c.recycle_logits(l);
            }
            c.free_slot(s);
        };
        run_request(&mut c); // warmup request
        let before = scratch::stats().allocs;
        run_request(&mut c);
        run_request(&mut c);
        assert_eq!(
            scratch::stats().allocs,
            before,
            "decode core re-allocated its activation set on a later request"
        );
    }

    /// Decoding a speculated-then-rejected suffix, truncating, and
    /// re-decoding the accepted continuation yields logits bitwise
    /// identical to a core that never took the detour — the numeric
    /// form of the KV rollback guarantee.
    #[test]
    fn truncate_then_append_matches_fresh_decode() {
        let prompt: Vec<i32> = (0..5).map(|j| (j * 13 + 2) % 256).collect();
        let mut a = core(1);
        let sa = a.alloc_slot().unwrap();
        a.prefill(sa, &prompt).unwrap();
        // speculate two tokens the verifier will "reject"
        a.decode_step(&[(sa, 250), (sa, 251)]).unwrap();
        assert_eq!(a.slot_len(sa), prompt.len() + 2);
        a.truncate(sa, prompt.len()).unwrap();
        assert_eq!(a.slot_len(sa), prompt.len());
        let after_rollback = a.decode_step(&[(sa, 9)]).unwrap();

        let mut b = core(1);
        let sb = b.alloc_slot().unwrap();
        b.prefill(sb, &prompt).unwrap();
        let fresh = b.decode_step(&[(sb, 9)]).unwrap();
        assert_eq!(after_rollback, fresh, "rollback left stale state behind");
    }

    #[test]
    fn non_native_backend_is_rejected() {
        assert!(DecodeCore::new_with_backend(NO_ARTIFACTS, "small", "pjrt", 0, 0).is_err());
    }

    /// A bf16 core halves both resident footprints, reports its dtype,
    /// and still generates: greedy tokens stay in-vocab and the stream
    /// is deterministic run-to-run.
    #[test]
    fn bf16_core_halves_footprint_and_generates() {
        let mut f = core(2);
        let mut b =
            DecodeCore::new_with_dtype(NO_ARTIFACTS, "small", "native", 2, 0, Dtype::Bf16)
                .unwrap();
        assert_eq!(f.dtype(), Dtype::F32);
        assert_eq!(b.dtype(), Dtype::Bf16);
        assert_eq!(b.kv_bytes() * 2, f.kv_bytes(), "bf16 KV cache is half the bytes");
        assert!(
            b.weight_bytes() < f.weight_bytes(),
            "bf16 weights ({}) not smaller than f32 ({})",
            b.weight_bytes(),
            f.weight_bytes()
        );
        let prompt: Vec<i32> = (0..5).map(|j| (j * 13 + 2) % 256).collect();
        let toks = greedy_generate(&mut b, &prompt, 5);
        assert!(toks.iter().all(|&t| (0..256).contains(&t)));
        let mut b2 =
            DecodeCore::new_with_dtype(NO_ARTIFACTS, "small", "native", 2, 0, Dtype::Bf16)
                .unwrap();
        assert_eq!(greedy_generate(&mut b2, &prompt, 5), toks, "bf16 decode not deterministic");
        // f32 core still generates the same prompt (smoke: shared path)
        assert_eq!(greedy_generate(&mut f, &prompt, 5).len(), 5);
    }

    /// A residency-tiered core with the expert budget capped to one
    /// blob generates greedy tokens bitwise identical to the fully
    /// resident core, at both storage precisions, while actually
    /// spilling (nonzero evictions under cap).
    #[test]
    fn tiered_core_generates_identical_tokens_under_cap() {
        use crate::memory::residency::ResidencySpec;
        let prompt: Vec<i32> = (0..6).map(|j| (j * 13 + 2) % 256).collect();
        for dtype in [Dtype::F32, Dtype::Bf16] {
            let mut dense =
                DecodeCore::new_with_dtype(NO_ARTIFACTS, "small", "native", 2, 0, dtype)
                    .unwrap();
            let want = greedy_generate(&mut dense, &prompt, 8);

            let spec = ResidencySpec::new(1, None); // clamps up to one blob
            let mut tiered = DecodeCore::new_with_residency(
                NO_ARTIFACTS, "small", "native", 2, 0, dtype, &spec,
            )
            .unwrap();
            let store = tiered.residency().expect("core should be tiered");
            assert_eq!(store.spilled_bytes(), 2 * 8 * store.blob_bytes());
            assert_eq!(greedy_generate(&mut tiered, &prompt, 8), want, "dtype {dtype:?}");
            let snap = spec.stats.snapshot();
            assert!(snap.total.evictions > 0, "one-blob budget must evict");
            assert!(snap.total.hits + snap.total.misses > 0);
            assert!(
                tiered.weight_bytes() < dense.weight_bytes(),
                "tiered resident bytes should undercut the dense store"
            );
        }
    }

    /// Generating the same prompt in isolation and alongside another
    /// sequence yields identical greedy tokens: the row-independence
    /// guarantee continuous batching rests on.
    #[test]
    fn greedy_tokens_independent_of_batch_composition() {
        let prompt_a: Vec<i32> = (0..6).map(|j| (j * 17 + 3) % 256).collect();
        let prompt_b: Vec<i32> = (0..4).map(|j| (j * 29 + 7) % 256).collect();

        let mut solo = core(2);
        let ref_a = greedy_generate(&mut solo, &prompt_a, 5);
        let ref_b = greedy_generate(&mut solo, &prompt_b, 5);
        assert_eq!(ref_a.len(), 5);

        // interleaved: both sequences live in one cache, stepped jointly
        let mut joint = core(2);
        let sa = joint.alloc_slot().unwrap();
        let sb = joint.alloc_slot().unwrap();
        let la = joint.prefill(sa, &prompt_a).unwrap();
        let lb = joint.prefill(sb, &prompt_b).unwrap();
        let mut got_a = vec![argmax(&la)];
        let mut got_b = vec![argmax(&lb)];
        for _ in 0..4 {
            let rows = vec![(sa, *got_a.last().unwrap()), (sb, *got_b.last().unwrap())];
            let l = joint.decode_step(&rows).unwrap();
            got_a.push(argmax(&l[..joint.vocab]));
            got_b.push(argmax(&l[joint.vocab..]));
        }
        assert_eq!(got_a, ref_a, "sequence A diverged under continuous batching");
        assert_eq!(got_b, ref_b, "sequence B diverged under continuous batching");
    }
}
