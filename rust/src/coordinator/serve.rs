//! Serving coordinator: a batched scoring service over the LM.
//!
//! The vLLM-router-shaped L3 feature: clients submit token sequences,
//! the coordinator packs them into fixed-shape microbatches (the
//! artifact's static (batch, seq) signature), executes the `lm_eval`
//! forward through the execution backend (native CPU by default, PJRT
//! behind the `pjrt` feature), and returns cross-entropy scores
//! (losses/perplexities). `serve_batch` amortizes one execute across up
//! to `rows` requests and reports the batch CE per request;
//! `score_exact` replicates one request across all rows so the batch
//! mean *is* that request's CE.
//!
//! Demonstrates the paper's "python never on the request path" property
//! for an inference-style workload; batching policy + queueing live
//! entirely in rust and are identical across backends.

use std::collections::VecDeque;
use std::time::Instant;

use anyhow::{bail, Result};

use crate::runtime::{Runtime, Value};

/// One scoring request.
#[derive(Debug, Clone)]
pub struct Request {
    pub id: u64,
    pub tokens: Vec<i32>,
}

/// One scored response.
#[derive(Debug, Clone)]
pub struct Response {
    pub id: u64,
    /// Mean next-token cross entropy over the request's tokens.
    pub ce: f64,
    pub ppl: f64,
    /// Wall time from dequeue to completion (batch execution latency).
    pub latency_s: f64,
}

/// Batched scoring server over one config.
pub struct Server {
    rt: Runtime,
    /// Parameters pre-staged as backend values (rebuilt only on
    /// checkpoint load, never on the per-batch hot path). The token
    /// input is pushed/popped around each execute.
    param_vals: Vec<Value>,
    queue: VecDeque<Request>,
    pub rows: usize,
    pub seq: usize,
    pub stats: ServeStats,
}

/// Aggregate service statistics.
#[derive(Debug, Clone, Copy, Default)]
pub struct ServeStats {
    pub requests: u64,
    pub batches: u64,
    pub padded_rows: u64,
    pub total_latency_s: f64,
    pub total_tokens: u64,
    pub busy_s: f64,
}

impl ServeStats {
    pub fn mean_latency_s(&self) -> f64 {
        if self.requests == 0 { 0.0 } else { self.total_latency_s / self.requests as f64 }
    }

    pub fn tokens_per_s(&self) -> f64 {
        if self.busy_s == 0.0 { 0.0 } else { self.total_tokens as f64 / self.busy_s }
    }

    /// Fraction of executed rows that were padding (batch under-fill) —
    /// the serving analogue of grouped-GEMM tile waste.
    pub fn padding_frac(&self) -> f64 {
        let executed = self.padded_rows as f64 + self.requests as f64;
        if executed == 0.0 {
            return 0.0;
        }
        self.padded_rows as f64 / executed
    }
}

impl Server {
    /// Open on the default backend (`SONIC_BACKEND`, native unless set).
    pub fn new(artifacts_dir: &str, config: &str) -> Result<Server> {
        Self::new_with_backend(artifacts_dir, config, "")
    }

    /// Open on a named backend ("" = default).
    pub fn new_with_backend(artifacts_dir: &str, config: &str, backend: &str) -> Result<Server> {
        let rt = Runtime::open_with(
            artifacts_dir,
            config,
            crate::runtime::backend::by_name(backend)?,
        )?;
        if !rt.manifest.artifacts.contains_key("lm_eval") {
            bail!("lm_eval artifact missing — run `make artifacts`");
        }
        let param_vals = rt.load_initial_params()?.into_iter().map(Value::F32).collect();
        let (rows, seq) = (rt.manifest.model.batch, rt.manifest.model.seq_len);
        Ok(Server {
            rt,
            param_vals,
            queue: VecDeque::new(),
            rows,
            seq,
            stats: ServeStats::default(),
        })
    }

    /// Execution backend serving this config.
    pub fn backend_name(&self) -> &'static str {
        self.rt.backend_name()
    }

    /// Vocabulary size of the served model.
    pub fn vocab(&self) -> usize {
        self.rt.manifest.model.vocab
    }

    /// Replace parameters (e.g. from a trained checkpoint).
    pub fn load_checkpoint(&mut self, dir: &str) -> Result<()> {
        let (_, cfg, _, params) = super::checkpoint::load(dir)?;
        if cfg != self.rt.config_name {
            bail!("checkpoint config {cfg:?} != server config {:?}", self.rt.config_name);
        }
        self.param_vals = params.into_iter().map(Value::F32).collect();
        Ok(())
    }

    /// Enqueue a request (tokens are clamped to vocab, truncated/padded
    /// to the artifact's static sequence length).
    pub fn submit(&mut self, id: u64, tokens: Vec<i32>) {
        self.queue.push_back(Request { id, tokens });
    }

    pub fn pending(&self) -> usize {
        self.queue.len()
    }

    /// Serve one microbatch (up to `rows` requests). Returns responses
    /// in request order; empty when the queue is drained.
    pub fn serve_batch(&mut self) -> Result<Vec<Response>> {
        if self.queue.is_empty() {
            return Ok(Vec::new());
        }
        let t0 = Instant::now();
        let vocab = self.rt.manifest.model.vocab as i32;
        let mut batch: Vec<Request> = Vec::with_capacity(self.rows);
        for _ in 0..self.rows {
            match self.queue.pop_front() {
                Some(r) => batch.push(r),
                None => break,
            }
        }
        let taken = batch.len();
        // pack rows: truncate/cycle-pad to the static seq length
        let mut tokens = vec![0i32; self.rows * self.seq];
        for (i, r) in batch.iter().enumerate() {
            for j in 0..self.seq {
                let t = if r.tokens.is_empty() { 0 } else { r.tokens[j % r.tokens.len()] };
                tokens[i * self.seq + j] = t.rem_euclid(vocab);
            }
        }
        self.stats.padded_rows += (self.rows - taken) as u64;

        // one execute for the whole batch; the artifact returns the
        // batch-mean CE, reported per request (exact per-request scores
        // via `score_exact`).
        let ce = self.execute_eval(tokens)?;
        let dt = t0.elapsed().as_secs_f64();

        self.stats.requests += taken as u64;
        self.stats.batches += 1;
        self.stats.total_latency_s += dt * taken as f64;
        self.stats.total_tokens += (taken * self.seq) as u64;
        self.stats.busy_s += dt;
        Ok(batch
            .into_iter()
            .map(|r| Response { id: r.id, ce, ppl: ce.exp(), latency_s: dt })
            .collect())
    }

    /// Exact per-request scoring: replicate one request across all batch
    /// rows so the batch-mean CE *is* the request's CE.
    pub fn score_exact(&mut self, tokens: &[i32]) -> Result<f64> {
        let vocab = self.rt.manifest.model.vocab as i32;
        let mut packed = vec![0i32; self.rows * self.seq];
        for i in 0..self.rows {
            for j in 0..self.seq {
                let t = if tokens.is_empty() { 0 } else { tokens[j % tokens.len()] };
                packed[i * self.seq + j] = t.rem_euclid(vocab);
            }
        }
        self.execute_eval(packed)
    }

    /// Run the `lm_eval` artifact on one packed (rows, seq) token batch.
    /// The cached parameter values are reused; only the token input is
    /// staged per call.
    fn execute_eval(&mut self, tokens: Vec<i32>) -> Result<f64> {
        self.param_vals.push(Value::i32(&[self.rows, self.seq], tokens)?);
        let out = Self::eval_inner(&mut self.rt, &self.param_vals);
        self.param_vals.pop();
        out
    }

    fn eval_inner(rt: &mut Runtime, vals: &[Value]) -> Result<f64> {
        let art = rt.artifact("lm_eval")?;
        let outs = art.execute(vals)?;
        Ok(outs[0].scalar_f32()? as f64)
    }

    /// Drain the queue, returning all responses.
    pub fn drain(&mut self) -> Result<Vec<Response>> {
        let mut all = Vec::new();
        while !self.queue.is_empty() {
            all.extend(self.serve_batch()?);
        }
        Ok(all)
    }
}
