//! Native-backend LM numerics: the `python/compile/model.py` +
//! `python/compile/kernels/ref.py` computation ported to rust.
//!
//! Implements the three artifact contracts of the manifest —
//! `lm_eval`, `lm_grad_step_<router>` and `moe_layer_fwd_<router>` —
//! as plain f32 CPU code over [`crate::util::tensor::Tensor`], with the
//! routing decisions delegated to [`crate::routing`] (the same
//! algorithms the python exporter compiles into the HLO).
//!
//! The backward pass follows the paper's Appendix C formulation exactly
//! as written in `ref.py::moe_backward_dense` (dS = <dA', A>, dAct
//! recomputing A from the cached pre-activation H), composed with
//! standard backprop for the attention/RMSNorm/tied-head pieces.
//!
//! All matmul-shaped compute runs on [`super::kernels`] — the blocked,
//! multithreaded, fused kernel layer. Forward-path results are bitwise
//! identical to the naive reference loops in [`super::linalg`] for any
//! thread count (the expert backward's `dxn` reduction is bitwise only
//! at a fixed thread count); the MoE block uses the fused
//! gather-GEMM-scatter expert kernels over CSR routing, and every
//! activation-sized temporary is recycled through the per-thread
//! scratch arena (forward, backward and the cached decode step
//! allocate nothing after warmup).

// index-heavy numeric kernels: explicit loops mirror the math
#![allow(clippy::needless_range_loop)]

use anyhow::{anyhow, bail, ensure, Result};

use super::kernels::{self, scratch};
use super::linalg::{axpy, axpy_wb, dot, dot_wb, sigmoid, softmax_inplace, softmax_rows};
use crate::memory::residency::{ExpertBlob, ExpertStore, ResidencySpec};
use crate::routing::{self, Decision, RoundingRule};
use crate::runtime::kvcache::{KvCache, KvView};
use crate::util::dtype::{narrow_slice, Dtype, WView};
use crate::util::prng::Prng;
use crate::util::tensor::Tensor;

const RMS_EPS: f32 = 1e-6;
const RENORM_EPS: f32 = 1e-9;

/// Routing method of one artifact (parsed from its name tag).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RouterKind {
    Tc,
    Tr(RoundingRule),
    Ec,
}

/// Parse an artifact router tag (`tc`, `tr`, `trbal`, `trup`, `trdown`,
/// `ec`, `tr_m<N>`, `tr_b<N>`) into a routing method and an optional
/// m_tile override.
pub fn parse_router_tag(tag: &str) -> Result<(RouterKind, Option<usize>)> {
    if let Some(m) = tag.strip_prefix("tr_m") {
        let m: usize = m.parse().map_err(|_| anyhow!("bad router tag {tag:?}"))?;
        return Ok((RouterKind::Tr(RoundingRule::NearestFreq), Some(m)));
    }
    if let Some(b) = tag.strip_prefix("tr_b") {
        // batch override: the token shape already comes from the
        // artifact signature, so only the method matters here
        let _: usize = b.parse().map_err(|_| anyhow!("bad router tag {tag:?}"))?;
        return Ok((RouterKind::Tr(RoundingRule::NearestFreq), None));
    }
    Ok(match tag {
        "tc" => (RouterKind::Tc, None),
        "tr" => (RouterKind::Tr(RoundingRule::NearestFreq), None),
        "trbal" => (RouterKind::Tr(RoundingRule::BalanceFreq), None),
        "trup" => (RouterKind::Tr(RoundingRule::Up), None),
        "trdown" => (RouterKind::Tr(RoundingRule::Down), None),
        "ec" => (RouterKind::Ec, None),
        t => bail!("unknown router tag {t:?}"),
    })
}

/// Parse a python-side router method string ("tc", "tr-nr-f", ...) as
/// stored in `ModelInfo::router`.
pub fn parse_router_method(method: &str) -> Result<RouterKind> {
    Ok(match method {
        "tc" => RouterKind::Tc,
        "ec" => RouterKind::Ec,
        "tr-nr-f" => RouterKind::Tr(RoundingRule::NearestFreq),
        "tr-sr-f" => RouterKind::Tr(RoundingRule::StochasticFreq),
        "tr-nr-s" => RouterKind::Tr(RoundingRule::NearestScore),
        "tr-balance-f" => RouterKind::Tr(RoundingRule::BalanceFreq),
        "tr-up" => RouterKind::Tr(RoundingRule::Up),
        "tr-down" | "drop" => RouterKind::Tr(RoundingRule::Down),
        m => bail!("unknown router method {m:?}"),
    })
}

/// Static configuration of one LM executable.
#[derive(Debug, Clone)]
pub struct LmCfg {
    pub vocab: usize,
    pub d: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    /// Token rows of this artifact's signature (batch may be a variant
    /// override, e.g. `tr_b2`).
    pub rows: usize,
    pub seq: usize,
    pub n: usize,
    pub e: usize,
    pub k: usize,
    pub m_tile: usize,
    pub aux_coeff: f32,
    pub router: RouterKind,
}

impl LmCfg {
    pub fn head_dim(&self) -> usize {
        self.d / self.n_heads
    }

    /// Tokens per microbatch (the MoE T dimension).
    pub fn t(&self) -> usize {
        self.rows * self.seq
    }
}

/// Where one layer's expert weights live.
///
/// `Dense` is the resident path every existing caller stays on:
/// contiguous `[E, d, 2n]` / `[E, n, d]` views in either storage
/// precision. `Tiered` is a residency handle — per-expert blobs are
/// faulted in from the spill file on demand, prefetched as soon as
/// the router decides, and handed to the fused kernel behind
/// eviction-fencing guards. Both arms run the same per-expert GEMM
/// body, so results are bitwise identical for identical weight bits.
pub enum ExpertWeights<'a> {
    Dense { w1: WView<'a>, w2: WView<'a> },
    Tiered { store: &'a ExpertStore, layer: usize },
}

impl<'a> ExpertWeights<'a> {
    /// The dense f32 masters, for the training path. Panics on bf16
    /// or tiered storage: training keeps full-precision resident
    /// weights (mirrors [`WView::f32`]).
    pub fn dense_f32(&self) -> (&'a [f32], &'a [f32]) {
        match self {
            ExpertWeights::Dense { w1, w2 } => (w1.f32(), w2.f32()),
            ExpertWeights::Tiered { .. } => {
                panic!("tiered expert weights are inference-only (training needs f32 masters)")
            }
        }
    }
}

/// A residency guard adapting one acquired expert blob to the fused
/// kernel's [`kernels::ExpertViews`] seam: the held `Arc` fences the
/// blob against eviction for exactly that expert's two GEMMs.
struct ResidentExpert {
    blob: std::sync::Arc<ExpertBlob>,
}

impl kernels::ExpertViews for ResidentExpert {
    fn w1(&self) -> WView<'_> {
        self.blob.w1()
    }

    fn w2(&self) -> WView<'_> {
        self.blob.w2()
    }
}

/// Borrowed per-layer parameters. Projection / router / expert weights
/// are [`WView`]s so they can live in either storage precision; norms
/// stay f32 slices (they are O(d) and numerically load-bearing).
pub struct LayerParams<'a> {
    pub attn_norm: &'a [f32],
    pub wq: WView<'a>,
    pub wk: WView<'a>,
    pub wv: WView<'a>,
    pub wo: WView<'a>,
    pub moe_norm: &'a [f32],
    pub wr: WView<'a>,
    pub experts: ExpertWeights<'a>,
}

/// Borrowed model parameters, resolved by manifest name. The embedding
/// stays f32: it doubles as the tied logits head (read row-wise per
/// vocab entry, not streamed through a GEMM) and dominates CE
/// sensitivity.
pub struct Params<'a> {
    pub embed: &'a [f32],
    pub layers: Vec<LayerParams<'a>>,
    pub final_norm: &'a [f32],
}

impl<'a> Params<'a> {
    /// Collect parameters through a name-resolving closure (the
    /// executable maps manifest input names to positional values). All
    /// views are f32 — this is the bitwise-reference path every
    /// existing caller stays on.
    pub fn collect(
        n_layers: usize,
        mut get: impl FnMut(&str) -> Result<&'a Tensor>,
    ) -> Result<Params<'a>> {
        let embed = &get("embed")?.data;
        let mut layers = Vec::with_capacity(n_layers);
        for i in 0..n_layers {
            let p = |s: &str| format!("layer{i}.{s}");
            layers.push(LayerParams {
                attn_norm: &get(&p("attn_norm"))?.data,
                wq: WView::F32(&get(&p("wq"))?.data),
                wk: WView::F32(&get(&p("wk"))?.data),
                wv: WView::F32(&get(&p("wv"))?.data),
                wo: WView::F32(&get(&p("wo"))?.data),
                moe_norm: &get(&p("moe_norm"))?.data,
                wr: WView::F32(&get(&p("wr"))?.data),
                experts: ExpertWeights::Dense {
                    w1: WView::F32(&get(&p("w1"))?.data),
                    w2: WView::F32(&get(&p("w2"))?.data),
                },
            });
        }
        let final_norm = &get("final_norm")?.data;
        Ok(Params { embed, layers, final_norm })
    }
}

/// One stored parameter: full-precision master or bf16 storage.
pub enum StoredParam {
    F32(Tensor),
    Bf16 { shape: Vec<usize>, data: Vec<u16> },
}

impl StoredParam {
    fn view(&self) -> WView<'_> {
        match self {
            StoredParam::F32(t) => WView::F32(&t.data),
            StoredParam::Bf16 { data, .. } => WView::Bf16(data),
        }
    }

    fn f32(&self) -> Result<&[f32]> {
        match self {
            StoredParam::F32(t) => Ok(&t.data),
            StoredParam::Bf16 { .. } => bail!("parameter stored bf16 where f32 is required"),
        }
    }

    pub fn shape(&self) -> &[usize] {
        match self {
            StoredParam::F32(t) => &t.shape,
            StoredParam::Bf16 { shape, .. } => shape,
        }
    }

    /// Bytes this parameter occupies in storage.
    pub fn bytes(&self) -> usize {
        match self {
            StoredParam::F32(t) => t.data.len() * 4,
            StoredParam::Bf16 { data, .. } => data.len() * 2,
        }
    }
}

/// Owned model parameters in a chosen storage precision — the decode
/// path's resident weight set. Under [`Dtype::Bf16`] the GEMM-streamed
/// weights (`wq`/`wk`/`wv`/`wo`/`wr`/`w1`/`w2`) are quantized once at
/// construction and the f32 masters are dropped: resident weight bytes
/// halve and every matmul streams u16 panels. Norms and the embedding
/// keep f32 (O(d) reads / tied logits head).
pub struct ParamStore {
    dtype: Dtype,
    entries: Vec<(String, StoredParam)>,
    /// When set, the expert weights (`*.w1`/`*.w2`) live file-backed
    /// behind this store instead of in `entries`; everything else is
    /// the pinned always-resident set.
    tiered: Option<ExpertStore>,
    /// The spec the tiered store was opened with, kept so checkpoint
    /// reloads can rebuild the same tiering (same budget, spill dir
    /// and stats sink).
    tier_spec: Option<ResidencySpec>,
}

impl ParamStore {
    /// True for parameters that are streamed through GEMMs and thus
    /// quantized under bf16 storage. Shared with the batch-scoring
    /// path, which round-trips the same set through bf16 so both
    /// surfaces serve identical numerics at a given dtype.
    pub fn is_gemm_weight(name: &str) -> bool {
        name.starts_with("layer") && !name.ends_with("norm")
    }

    pub fn new(named: Vec<(String, Tensor)>, dtype: Dtype) -> ParamStore {
        let entries = named
            .into_iter()
            .map(|(name, t)| {
                let stored = match dtype {
                    Dtype::F32 => StoredParam::F32(t),
                    Dtype::Bf16 if Self::is_gemm_weight(&name) => StoredParam::Bf16 {
                        shape: t.shape.clone(),
                        data: narrow_slice(&t.data),
                    },
                    Dtype::Bf16 => StoredParam::F32(t),
                };
                (name, stored)
            })
            .collect();
        ParamStore { dtype, entries, tiered: None, tier_spec: None }
    }

    /// Like [`ParamStore::new`], but the expert weights (`*.w1` /
    /// `*.w2`) are spilled to disk behind an [`ExpertStore`] instead
    /// of staying resident. The remaining parameters — norms, the
    /// embedding, attention and router weights — are the pinned
    /// always-resident set, stored exactly as `new` stores them (same
    /// bf16 quantization rule), so tiered and dense stores serve
    /// bitwise-identical numerics at a given dtype.
    pub fn new_tiered(
        named: Vec<(String, Tensor)>,
        dtype: Dtype,
        spec: &ResidencySpec,
    ) -> Result<ParamStore> {
        let mut rest = Vec::new();
        let mut w1s: Vec<(usize, Tensor)> = Vec::new();
        let mut w2s: Vec<(usize, Tensor)> = Vec::new();
        let layer_of = |name: &str, suffix: &str| -> Option<usize> {
            name.strip_prefix("layer")?.strip_suffix(suffix)?.parse().ok()
        };
        for (name, t) in named {
            if let Some(l) = layer_of(&name, ".w1") {
                w1s.push((l, t));
            } else if let Some(l) = layer_of(&name, ".w2") {
                w2s.push((l, t));
            } else {
                rest.push((name, t));
            }
        }
        w1s.sort_by_key(|(l, _)| *l);
        w2s.sort_by_key(|(l, _)| *l);
        ensure!(
            !w1s.is_empty() && w1s.len() == w2s.len(),
            "tiered store needs matching w1/w2 per layer (got {} w1, {} w2)",
            w1s.len(),
            w2s.len()
        );
        for (i, ((l1, _), (l2, _))) in w1s.iter().zip(&w2s).enumerate() {
            ensure!(*l1 == i && *l2 == i, "expert layers must be contiguous from 0");
        }
        let layers: Vec<(&Tensor, &Tensor)> =
            w1s.iter().zip(&w2s).map(|((_, a), (_, b))| (a, b)).collect();
        let store = ExpertStore::new(&layers, dtype, spec)?;
        let pinned = ParamStore::new(rest, dtype);
        Ok(ParamStore {
            dtype,
            entries: pinned.entries,
            tiered: Some(store),
            tier_spec: Some(spec.clone()),
        })
    }

    /// Rebuild this store's layout (dtype + tiering) over a fresh
    /// parameter set — the checkpoint-reload path.
    pub fn rebuild(&self, named: Vec<(String, Tensor)>) -> Result<ParamStore> {
        match &self.tier_spec {
            Some(spec) => ParamStore::new_tiered(named, self.dtype, spec),
            None => Ok(ParamStore::new(named, self.dtype)),
        }
    }

    pub fn dtype(&self) -> Dtype {
        self.dtype
    }

    /// The tiered expert store, when this store is residency-managed.
    pub fn residency(&self) -> Option<&ExpertStore> {
        self.tiered.as_ref()
    }

    /// Total resident parameter bytes in this storage precision. For a
    /// tiered store this is the pinned set plus the expert bytes
    /// resident *right now* — a point-in-time gauge, not a constant.
    pub fn weight_bytes(&self) -> usize {
        let pinned: usize = self.entries.iter().map(|(_, p)| p.bytes()).sum();
        pinned + self.tiered.as_ref().map_or(0, |s| s.resident_bytes())
    }

    fn get(&self, name: &str) -> Result<&StoredParam> {
        self.entries
            .iter()
            .find(|(n, _)| n == name)
            .map(|(_, p)| p)
            .ok_or_else(|| anyhow!("missing parameter {name:?}"))
    }

    /// Borrow the full parameter set for the forward/decode kernels.
    pub fn view(&self, n_layers: usize) -> Result<Params<'_>> {
        let embed = self.get("embed")?.f32()?;
        let mut layers = Vec::with_capacity(n_layers);
        for i in 0..n_layers {
            let p = |s: &str| format!("layer{i}.{s}");
            layers.push(LayerParams {
                attn_norm: self.get(&p("attn_norm"))?.f32()?,
                wq: self.get(&p("wq"))?.view(),
                wk: self.get(&p("wk"))?.view(),
                wv: self.get(&p("wv"))?.view(),
                wo: self.get(&p("wo"))?.view(),
                moe_norm: self.get(&p("moe_norm"))?.f32()?,
                wr: self.get(&p("wr"))?.view(),
                experts: match &self.tiered {
                    Some(store) => ExpertWeights::Tiered { store, layer: i },
                    None => ExpertWeights::Dense {
                        w1: self.get(&p("w1"))?.view(),
                        w2: self.get(&p("w2"))?.view(),
                    },
                },
            });
        }
        let final_norm = self.get("final_norm")?.f32()?;
        Ok(Params { embed, layers, final_norm })
    }
}

/// Owned per-layer gradients (same shapes as the parameters).
pub struct LayerGrads {
    pub attn_norm: Vec<f32>,
    pub wq: Vec<f32>,
    pub wk: Vec<f32>,
    pub wv: Vec<f32>,
    pub wo: Vec<f32>,
    pub moe_norm: Vec<f32>,
    pub wr: Vec<f32>,
    pub w1: Vec<f32>,
    pub w2: Vec<f32>,
}

/// Owned model gradients.
pub struct Grads {
    pub embed: Vec<f32>,
    pub layers: Vec<LayerGrads>,
    pub final_norm: Vec<f32>,
}

impl Grads {
    fn zeros(cfg: &LmCfg) -> Grads {
        let (d, n, e) = (cfg.d, cfg.n, cfg.e);
        Grads {
            embed: vec![0.0; cfg.vocab * d],
            layers: (0..cfg.n_layers)
                .map(|_| LayerGrads {
                    attn_norm: vec![0.0; d],
                    wq: vec![0.0; d * d],
                    wk: vec![0.0; d * d],
                    wv: vec![0.0; d * d],
                    wo: vec![0.0; d * d],
                    moe_norm: vec![0.0; d],
                    wr: vec![0.0; d * e],
                    w1: vec![0.0; e * d * 2 * n],
                    w2: vec![0.0; e * n * d],
                })
                .collect(),
            final_norm: vec![0.0; d],
        }
    }

    /// Move a gradient out by parameter name (used once per name when
    /// assembling the positional output tuple).
    pub fn take(&mut self, name: &str) -> Result<Vec<f32>> {
        if name == "embed" {
            return Ok(std::mem::take(&mut self.embed));
        }
        if name == "final_norm" {
            return Ok(std::mem::take(&mut self.final_norm));
        }
        let rest = name
            .strip_prefix("layer")
            .ok_or_else(|| anyhow!("unknown parameter {name:?}"))?;
        let (idx, field) = rest
            .split_once('.')
            .ok_or_else(|| anyhow!("unknown parameter {name:?}"))?;
        let i: usize = idx.parse().map_err(|_| anyhow!("unknown parameter {name:?}"))?;
        let l = self
            .layers
            .get_mut(i)
            .ok_or_else(|| anyhow!("layer index out of range in {name:?}"))?;
        Ok(std::mem::take(match field {
            "attn_norm" => &mut l.attn_norm,
            "wq" => &mut l.wq,
            "wk" => &mut l.wk,
            "wv" => &mut l.wv,
            "wo" => &mut l.wo,
            "moe_norm" => &mut l.moe_norm,
            "wr" => &mut l.wr,
            "w1" => &mut l.w1,
            "w2" => &mut l.w2,
            _ => bail!("unknown parameter {name:?}"),
        }))
    }
}

// ---------------------------------------------------------------------------
// RMSNorm
// ---------------------------------------------------------------------------

fn rmsnorm(x: &[f32], scale: &[f32], rows: usize, d: usize) -> Vec<f32> {
    let mut y = scratch::take(rows * d);
    for r in 0..rows {
        let xr = &x[r * d..(r + 1) * d];
        let mean_sq = dot(xr, xr) / d as f32;
        let inv = 1.0 / (mean_sq + RMS_EPS).sqrt();
        let yr = &mut y[r * d..(r + 1) * d];
        for j in 0..d {
            yr[j] = xr[j] * inv * scale[j];
        }
    }
    y
}

/// Backward of rmsnorm: returns dx; accumulates dscale.
fn rmsnorm_bwd(
    x: &[f32],
    scale: &[f32],
    dy: &[f32],
    rows: usize,
    d: usize,
    dscale: &mut [f32],
) -> Vec<f32> {
    let mut dx = scratch::take(rows * d);
    for r in 0..rows {
        let xr = &x[r * d..(r + 1) * d];
        let dyr = &dy[r * d..(r + 1) * d];
        let mean_sq = dot(xr, xr) / d as f32;
        let inv = 1.0 / (mean_sq + RMS_EPS).sqrt();
        // proj = sum_i dy_i * scale_i * x_i
        let mut proj = 0f32;
        for j in 0..d {
            proj += dyr[j] * scale[j] * xr[j];
            dscale[j] += dyr[j] * xr[j] * inv;
        }
        let c = inv * inv * inv / d as f32 * proj;
        let dxr = &mut dx[r * d..(r + 1) * d];
        for j in 0..d {
            dxr[j] = dyr[j] * scale[j] * inv - xr[j] * c;
        }
    }
    dx
}

// ---------------------------------------------------------------------------
// MoE block (router GEMM -> routing -> grouped SwiGLU expert compute)
// ---------------------------------------------------------------------------

/// Forward cache of one MoE block (everything the backward needs; like
/// the paper's residual set, A/Y are never stored — A is recomputed
/// from the packed H). Routing is CSR over experts; every buffer is
/// checked out of the per-thread scratch arena and returned by
/// [`MoeCache::recycle`].
pub struct MoeCache {
    /// (T, E) softmax router scores.
    scores: Vec<f32>,
    /// Final routing decision (mask + counts).
    dec: Decision,
    /// (T, E) renormalized masked scores (the gate).
    r: Vec<f32>,
    /// (T) pre-clamp renormalization denominators.
    denom_raw: Vec<f32>,
    /// CSR offsets: expert j owns routed pairs rows_off[j]..rows_off[j+1].
    rows_off: Vec<usize>,
    /// Routed token indices, ascending within each expert.
    rows_flat: Vec<usize>,
    /// Gate weights per routed pair (CSR-aligned copy of `r`).
    gates: Vec<f32>,
    /// Packed pre-activation H, CSR-aligned (pairs, 2n).
    h: Vec<f32>,
    /// (E) fraction of token slots per expert (mean pi / K).
    frac_tokens: Vec<f32>,
    /// Auxiliary load-balance loss value.
    pub aux: f32,
}

impl MoeCache {
    /// Return every arena-owned buffer to the calling thread's pool.
    pub fn recycle(self) {
        scratch::put(self.scores);
        scratch::put(self.r);
        scratch::put(self.denom_raw);
        scratch::put_idx(self.rows_off);
        scratch::put_idx(self.rows_flat);
        scratch::put(self.gates);
        scratch::put(self.h);
        scratch::put(self.frac_tokens);
    }
}

fn route(kind: RouterKind, scores: &[f32], t: usize, e: usize, k: usize, m_tile: usize) -> Decision {
    match kind {
        RouterKind::Tc => routing::tc_topk(scores, t, e, k),
        RouterKind::Tr(rule) => {
            // stochastic subroutines draw from a fixed-seed stream so the
            // executable stays deterministic, mirroring the AOT export
            let mut rng = Prng::new(0);
            routing::token_rounding(scores, t, e, k, m_tile, rule, &mut rng)
        }
        RouterKind::Ec => routing::expert_choice(scores, t, e, k),
    }
}

/// MoE block forward: returns (o, cache). The router weight comes in
/// as a [`WView`]; the expert weights as an [`ExpertWeights`] —
/// resident contiguous views (bf16-stored experts stream half the
/// bytes through the fused GEMM packs; f32 views take the exact
/// pre-dtype code path) or a tiered residency handle whose blobs are
/// prefetched the moment the router decides and faulted in per expert
/// otherwise.
pub fn moe_forward(
    cfg: &LmCfg,
    xn: &[f32],                  // (T, d)
    wr: WView<'_>,               // (d, E)
    experts: &ExpertWeights<'_>, // (E, d, 2n) + (E, n, d)
    kind: RouterKind,
) -> (Vec<f32>, MoeCache) {
    let (t, d, n, e, k) = (cfg.t(), cfg.d, cfg.n, cfg.e, cfg.k);
    let mut scores = kernels::matmul_wview(xn, wr, t, d, e);
    softmax_rows(&mut scores, t, e);
    let dec = route(kind, &scores, t, e, k, cfg.m_tile);

    // tiered experts: the router has decided, the GEMMs are still a
    // renorm + aux + CSR build away — submit this layer's expert set
    // to the background loader now so the spill reads overlap that
    // work (and the earlier experts' GEMMs once the kernel starts)
    if let ExpertWeights::Tiered { store, layer } = experts {
        store.prefetch_from_mask(*layer, &dec.mask, t);
    }

    // per-token softmax renormalization over the selected experts
    let mut r = scratch::take(t * e);
    let mut denom_raw = scratch::take(t);
    for tok in 0..t {
        let mut sum = 0f32;
        for j in 0..e {
            if dec.mask[tok * e + j] {
                sum += scores[tok * e + j];
            }
        }
        denom_raw[tok] = sum;
        let denom = sum.max(RENORM_EPS);
        for j in 0..e {
            if dec.mask[tok * e + j] {
                r[tok * e + j] = scores[tok * e + j] / denom;
            }
        }
    }

    // aux load-balance loss: E * sum_e frac_tokens_e * frac_scores_e,
    // with the per-expert row lists built CSR in the same mask scan
    let mut frac_tokens = scratch::take(e);
    let mut rows_off = scratch::take_idx(e + 1);
    let mut rows_flat = scratch::take_idx(t * k);
    rows_off.push(0);
    let mut aux = 0f64;
    for j in 0..e {
        for tok in 0..t {
            if dec.mask[tok * e + j] {
                rows_flat.push(tok);
            }
        }
        let f_j = rows_flat.len() - rows_off[j];
        rows_off.push(rows_flat.len());
        frac_tokens[j] = f_j as f32 / (t * k) as f32;
        let mean_score: f64 =
            (0..t).map(|tok| scores[tok * e + j] as f64).sum::<f64>() / t as f64;
        aux += frac_tokens[j] as f64 * mean_score;
    }
    let aux = (aux * e as f64) as f32;
    let pairs = rows_flat.len();

    // CSR-aligned gate weights (the scatter epilogue's row scales)
    let mut gates = scratch::take(pairs);
    for j in 0..e {
        for (p, &tok) in rows_flat[rows_off[j]..rows_off[j + 1]].iter().enumerate() {
            gates[rows_off[j] + p] = r[tok * e + j];
        }
    }

    // grouped expert compute O_t += r_te * SwiGLU(x_t W1_e) W2_e as one
    // fused gather-GEMM-scatter pass: no xg copy, no y materialization
    let mut o = scratch::take(t * d);
    let mut h = scratch::take(pairs * 2 * n);
    match experts {
        ExpertWeights::Dense { w1, w2 } => kernels::fused_expert_forward(
            d, n, e, xn, *w1, *w2, &rows_off, &rows_flat, &gates, &mut h, &mut o,
        ),
        ExpertWeights::Tiered { store, layer } => kernels::fused_expert_forward_with(
            d,
            n,
            e,
            xn,
            |j| ResidentExpert {
                blob: store
                    .acquire(*layer, j)
                    .expect("expert residency: spill read failed mid-forward"),
            },
            &rows_off,
            &rows_flat,
            &gates,
            &mut h,
            &mut o,
        ),
    }
    (o, MoeCache { scores, dec, r, denom_raw, rows_off, rows_flat, gates, h, frac_tokens, aux })
}

/// SwiGLU over packed H = [gate | up]: A = silu(gate) * up (reference
/// form; the production path fuses this into the expert GEMM packs).
#[cfg(test)]
fn swiglu(h: &[f32], rows: usize, n: usize) -> Vec<f32> {
    let mut a = vec![0f32; rows * n];
    for i in 0..rows {
        let hr = &h[i * 2 * n..(i + 1) * 2 * n];
        let ar = &mut a[i * n..(i + 1) * n];
        for j in 0..n {
            let g = hr[j];
            let u = hr[n + j];
            ar[j] = g * sigmoid(g) * u;
        }
    }
    a
}

/// MoE block backward.
///
/// `d_o` is the output cotangent, `g_aux` the cotangent of the aux loss
/// (the trainer's aux coefficient). Returns dxn and accumulates dwr,
/// dw1, dw2.
#[allow(clippy::too_many_arguments)]
pub fn moe_backward(
    cfg: &LmCfg,
    cache: &MoeCache,
    xn: &[f32],
    wr: &[f32],
    w1: &[f32],
    w2: &[f32],
    d_o: &[f32],
    g_aux: f32,
    dwr: &mut [f32],
    dw1: &mut [f32],
    dw2: &mut [f32],
) -> Vec<f32> {
    let (t, d, n, e) = (cfg.t(), cfg.d, cfg.n, cfg.e);
    let mut dscores = scratch::take(t * e);

    // aux path: d aux / d scores_te = E * frac_tokens_e / T (pi is
    // stop-gradient)
    for j in 0..e {
        let c = g_aux * e as f32 * cache.frac_tokens[j] / t as f32;
        if c != 0.0 {
            for tok in 0..t {
                dscores[tok * e + j] += c;
            }
        }
    }

    // expert compute path (Appendix C) as one fused pass: the dO
    // gather, the gate-scaled activation and the dX~ scatter all live
    // inside the GEMM packs/epilogues (Eqs. 8-12); dr_pairs holds dS
    // per routed pair, scattered into the dense (t, e) dr below
    let mut dr = scratch::take(t * e);
    let mut dxn = scratch::take(t * d);
    let pairs = cache.rows_flat.len();
    let mut dr_pairs = scratch::take(pairs);
    kernels::fused_expert_backward(
        d,
        n,
        e,
        xn,
        d_o,
        w1,
        w2,
        &cache.rows_off,
        &cache.rows_flat,
        &cache.gates,
        &cache.h,
        &mut dr_pairs,
        dw1,
        dw2,
        &mut dxn,
    );
    for j in 0..e {
        for (i, &tok) in
            cache.rows_flat[cache.rows_off[j]..cache.rows_off[j + 1]].iter().enumerate()
        {
            dr[tok * e + j] = dr_pairs[cache.rows_off[j] + i];
        }
    }
    scratch::put(dr_pairs);

    // renormalization backward: r_j = sel_j / max(sum(sel), eps)
    for tok in 0..t {
        let mut dot_t = 0f32;
        for j in 0..e {
            dot_t += dr[tok * e + j] * cache.r[tok * e + j];
        }
        let clamped = cache.denom_raw[tok] < RENORM_EPS;
        let denom = cache.denom_raw[tok].max(RENORM_EPS);
        for j in 0..e {
            if cache.dec.mask[tok * e + j] {
                let quot = if clamped { 0.0 } else { dot_t };
                dscores[tok * e + j] += (dr[tok * e + j] - quot) / denom;
            }
        }
    }

    // softmax backward on the router scores
    let mut dlogits = scratch::take(t * e);
    for tok in 0..t {
        let s = &cache.scores[tok * e..(tok + 1) * e];
        let ds = &dscores[tok * e..(tok + 1) * e];
        let dp = dot(ds, s);
        let dl = &mut dlogits[tok * e..(tok + 1) * e];
        for j in 0..e {
            dl[j] = s[j] * (ds[j] - dp);
        }
    }
    kernels::add_matmul_tn(dwr, xn, &dlogits, t, d, e);
    let dxn_router = kernels::matmul_nt(&dlogits, wr, t, e, d);
    for (a, b) in dxn.iter_mut().zip(&dxn_router) {
        *a += b;
    }
    scratch::put(dxn_router);
    scratch::put(dlogits);
    scratch::put(dscores);
    scratch::put(dr);
    dxn
}

// ---------------------------------------------------------------------------
// Full LM forward
// ---------------------------------------------------------------------------

struct LayerCache {
    x_in: Vec<f32>,
    xn1: Vec<f32>,
    q: Vec<f32>,
    k: Vec<f32>,
    v: Vec<f32>,
    /// (B, H, S, S) attention probabilities (strict upper triangle 0).
    att: Vec<f32>,
    /// (T, d) attention output before the wo projection.
    att_concat: Vec<f32>,
    x_mid: Vec<f32>,
    xn2: Vec<f32>,
    moe: MoeCache,
}

struct ForwardCache {
    layers: Vec<LayerCache>,
    /// Input of the final RMSNorm.
    x_final: Vec<f32>,
    /// Output of the final RMSNorm (head input).
    xf: Vec<f32>,
    aux_total: f32,
}

impl ForwardCache {
    /// Return every arena-owned activation to the thread pool (called
    /// once the consumer — CE head or backward — is done with it).
    fn recycle(self) {
        for lc in self.layers {
            scratch::put(lc.x_in);
            scratch::put(lc.xn1);
            scratch::put(lc.q);
            scratch::put(lc.k);
            scratch::put(lc.v);
            scratch::put(lc.att);
            scratch::put(lc.att_concat);
            scratch::put(lc.x_mid);
            scratch::put(lc.xn2);
            lc.moe.recycle();
        }
        scratch::put(self.x_final);
        scratch::put(self.xf);
    }
}

fn clamp_token(tok: i32, vocab: usize) -> usize {
    (tok.max(0) as usize).min(vocab - 1)
}

fn forward(cfg: &LmCfg, p: &Params, tokens: &[i32]) -> ForwardCache {
    let (t, d) = (cfg.t(), cfg.d);
    let (b, s, nh, hd) = (cfg.rows, cfg.seq, cfg.n_heads, cfg.head_dim());
    let sqrt_hd = (hd as f32).sqrt();

    // embedding lookup
    let mut x = scratch::take(t * d);
    for (pidx, &tok) in tokens.iter().enumerate() {
        let v = clamp_token(tok, cfg.vocab);
        x[pidx * d..(pidx + 1) * d].copy_from_slice(&p.embed[v * d..(v + 1) * d]);
    }

    let mut layers = Vec::with_capacity(cfg.n_layers);
    let mut aux_total = 0f32;
    for lp in &p.layers {
        let x_in = x;
        let xn1 = rmsnorm(&x_in, lp.attn_norm, t, d);
        let q = kernels::matmul_wview(&xn1, lp.wq, t, d, d);
        let k = kernels::matmul_wview(&xn1, lp.wk, t, d, d);
        let v = kernels::matmul_wview(&xn1, lp.wv, t, d, d);

        // causal multi-head attention
        let mut att = scratch::take(b * nh * s * s);
        let mut att_concat = scratch::take(t * d);
        for bi in 0..b {
            for h in 0..nh {
                for si in 0..s {
                    let pq = bi * s + si;
                    let qrow = &q[pq * d + h * hd..pq * d + (h + 1) * hd];
                    let row_off = ((bi * nh + h) * s + si) * s;
                    for sj in 0..=si {
                        let pk = bi * s + sj;
                        let krow = &k[pk * d + h * hd..pk * d + (h + 1) * hd];
                        att[row_off + sj] = dot(qrow, krow) / sqrt_hd;
                    }
                    softmax_inplace(&mut att[row_off..row_off + si + 1]);
                    let orow = &mut att_concat[pq * d + h * hd..pq * d + (h + 1) * hd];
                    for sj in 0..=si {
                        let pv = bi * s + sj;
                        let vrow = &v[pv * d + h * hd..pv * d + (h + 1) * hd];
                        axpy(att[row_off + sj], vrow, orow);
                    }
                }
            }
        }
        let att_proj = kernels::matmul_wview(&att_concat, lp.wo, t, d, d);
        let mut x_mid = scratch::take(t * d);
        x_mid.copy_from_slice(&x_in);
        for (a, bb) in x_mid.iter_mut().zip(&att_proj) {
            *a += bb;
        }
        scratch::put(att_proj);

        let xn2 = rmsnorm(&x_mid, lp.moe_norm, t, d);
        let (o, moe) = moe_forward(cfg, &xn2, lp.wr, &lp.experts, cfg.router);
        aux_total += moe.aux;
        let mut x_out = scratch::take(t * d);
        x_out.copy_from_slice(&x_mid);
        for (a, bb) in x_out.iter_mut().zip(&o) {
            *a += bb;
        }
        scratch::put(o);
        layers.push(LayerCache { x_in, xn1, q, k, v, att, att_concat, x_mid, xn2, moe });
        x = x_out;
    }

    let xf = rmsnorm(&x, p.final_norm, t, d);
    ForwardCache { layers, x_final: x, xf, aux_total }
}

/// Next-token cross entropy through the tied head; optionally produces
/// the head gradients (dxf and the head's contribution to dembed).
/// Returns the batch-mean CE plus each row's own mean CE (the serving
/// gateway reports the per-row values so every request gets its true
/// score rather than the batch mean).
fn ce_head(
    cfg: &LmCfg,
    embed: &[f32],
    xf: &[f32],
    tokens: &[i32],
    grad: Option<(&mut Vec<f32>, &mut [f32])>, // (dxf, dembed)
) -> (f32, Vec<f32>) {
    let (bsz, s, d, vocab) = (cfg.rows, cfg.seq, cfg.d, cfg.vocab);
    let n_pos = bsz * (s - 1);
    let inv_n = 1.0 / n_pos as f32;
    let mut ce_sum = 0f64;
    let mut row_ce = vec![0f32; bsz];
    let mut grad = grad;
    let mut logits = scratch::take(vocab);
    for bi in 0..bsz {
        let mut row_sum = 0f64;
        for si in 0..s - 1 {
            let pidx = bi * s + si;
            let xrow = &xf[pidx * d..(pidx + 1) * d];
            for (v, l) in logits.iter_mut().enumerate() {
                *l = dot(xrow, &embed[v * d..(v + 1) * d]);
            }
            let target = clamp_token(tokens[bi * s + si + 1], vocab);
            let mx = logits.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            let lse = logits.iter().map(|l| (l - mx).exp()).sum::<f32>().ln();
            row_sum -= (logits[target] - mx - lse) as f64;
            if let Some((dxf, dembed)) = grad.as_mut() {
                let dxrow = &mut dxf[pidx * d..(pidx + 1) * d];
                for (v, l) in logits.iter().enumerate() {
                    let p_v = (l - mx - lse).exp();
                    let g = (p_v - if v == target { 1.0 } else { 0.0 }) * inv_n;
                    axpy(g, &embed[v * d..(v + 1) * d], dxrow);
                    axpy(g, xrow, &mut dembed[v * d..(v + 1) * d]);
                }
            }
        }
        row_ce[bi] = (row_sum / (s - 1) as f64) as f32;
        ce_sum += row_sum;
    }
    scratch::put(logits);
    ((ce_sum / n_pos as f64) as f32, row_ce)
}

/// Validation CE (the `lm_eval` contract).
pub fn eval_ce(cfg: &LmCfg, p: &Params, tokens: &[i32]) -> f32 {
    eval_ce_rows(cfg, p, tokens).0
}

/// Validation CE plus each row's own mean CE (the extended `lm_eval`
/// contract with a `ce_rows` output). Under the TC router every row's
/// score depends only on that row's tokens, so `ce_rows[i]` equals the
/// CE of scoring row `i` on its own (batch-global routers — EC, TR —
/// couple rows through the routing decision).
pub fn eval_ce_rows(cfg: &LmCfg, p: &Params, tokens: &[i32]) -> (f32, Vec<f32>) {
    let fc = forward(cfg, p, tokens);
    let out = ce_head(cfg, p.embed, &fc.xf, tokens, None);
    fc.recycle();
    out
}

/// One MoE-layer forward (the `moe_layer_fwd_<tag>` contract):
/// x -> (o, aux).
pub fn moe_layer_forward(
    cfg: &LmCfg,
    x: &Tensor,
    wr: &Tensor,
    w1: &Tensor,
    w2: &Tensor,
    kind: RouterKind,
) -> (Vec<f32>, f32) {
    let experts = ExpertWeights::Dense {
        w1: WView::F32(&w1.data),
        w2: WView::F32(&w2.data),
    };
    let (o, cache) = moe_forward(cfg, &x.data, WView::F32(&wr.data), &experts, kind);
    let aux = cache.aux;
    cache.recycle();
    (o, aux)
}

/// The `lm_grad_step_<tag>` contract: (loss, ce, grads).
pub fn grad_step(cfg: &LmCfg, p: &Params, tokens: &[i32]) -> (f32, f32, Grads) {
    let (t, d) = (cfg.t(), cfg.d);
    let (b, s, nh, hd) = (cfg.rows, cfg.seq, cfg.n_heads, cfg.head_dim());
    let inv_sqrt_hd = 1.0 / (hd as f32).sqrt();
    let fc = forward(cfg, p, tokens);
    let mut g = Grads::zeros(cfg);

    // head: CE + dlogits -> (dxf, dembed)
    let mut dxf = scratch::take(t * d);
    let (ce, _) = ce_head(cfg, p.embed, &fc.xf, tokens, Some((&mut dxf, &mut g.embed)));
    let loss = ce + cfg.aux_coeff * fc.aux_total;

    // final rmsnorm
    let mut dx = rmsnorm_bwd(&fc.x_final, p.final_norm, &dxf, t, d, &mut g.final_norm);
    scratch::put(dxf);

    for (li, lc) in fc.layers.iter().enumerate().rev() {
        let lp = &p.layers[li];
        let lg = &mut g.layers[li];

        // x_out = x_mid + o: dx flows to both the residual and the MoE
        let (w1, w2) = lp.experts.dense_f32();
        let dxn2 = moe_backward(
            cfg,
            &lc.moe,
            &lc.xn2,
            lp.wr.f32(),
            w1,
            w2,
            &dx,
            cfg.aux_coeff,
            &mut lg.wr,
            &mut lg.w1,
            &mut lg.w2,
        );
        let dmid_norm = rmsnorm_bwd(&lc.x_mid, lp.moe_norm, &dxn2, t, d, &mut lg.moe_norm);
        scratch::put(dxn2);
        let mut dx_mid = dx;
        for (a, bb) in dx_mid.iter_mut().zip(&dmid_norm) {
            *a += bb;
        }
        scratch::put(dmid_norm);

        // x_mid = x_in + att_concat @ wo
        kernels::add_matmul_tn(&mut lg.wo, &lc.att_concat, &dx_mid, t, d, d);
        let datt_concat = kernels::matmul_nt(&dx_mid, lp.wo.f32(), t, d, d);

        // attention backward
        let mut dq = scratch::take(t * d);
        let mut dk = scratch::take(t * d);
        let mut dv = scratch::take(t * d);
        let mut datt_row = scratch::take(s);
        for bi in 0..b {
            for h in 0..nh {
                for si in 0..s {
                    let pq = bi * s + si;
                    let doh = &datt_concat[pq * d + h * hd..pq * d + (h + 1) * hd];
                    let row_off = ((bi * nh + h) * s + si) * s;
                    let att_row = &lc.att[row_off..row_off + si + 1];
                    // dV and d(att)
                    for sj in 0..=si {
                        let pv = bi * s + sj;
                        let vrow = &lc.v[pv * d + h * hd..pv * d + (h + 1) * hd];
                        datt_row[sj] = dot(doh, vrow);
                        axpy(att_row[sj], doh, &mut dv[pv * d + h * hd..pv * d + (h + 1) * hd]);
                    }
                    // softmax backward
                    let dp = dot(&datt_row[..si + 1], att_row);
                    let qrow = &lc.q[pq * d + h * hd..pq * d + (h + 1) * hd];
                    // split-borrow dq row vs reading q
                    for sj in 0..=si {
                        let dpre = att_row[sj] * (datt_row[sj] - dp) * inv_sqrt_hd;
                        if dpre == 0.0 {
                            continue;
                        }
                        let pk = bi * s + sj;
                        let krow = &lc.k[pk * d + h * hd..pk * d + (h + 1) * hd];
                        axpy(dpre, krow, &mut dq[pq * d + h * hd..pq * d + (h + 1) * hd]);
                        axpy(dpre, qrow, &mut dk[pk * d + h * hd..pk * d + (h + 1) * hd]);
                    }
                }
            }
        }

        // projections
        kernels::add_matmul_tn(&mut lg.wq, &lc.xn1, &dq, t, d, d);
        kernels::add_matmul_tn(&mut lg.wk, &lc.xn1, &dk, t, d, d);
        kernels::add_matmul_tn(&mut lg.wv, &lc.xn1, &dv, t, d, d);
        let mut dxn1 = kernels::matmul_nt(&dq, lp.wq.f32(), t, d, d);
        let dxn1_k = kernels::matmul_nt(&dk, lp.wk.f32(), t, d, d);
        let dxn1_v = kernels::matmul_nt(&dv, lp.wv.f32(), t, d, d);
        for i in 0..t * d {
            dxn1[i] += dxn1_k[i] + dxn1_v[i];
        }
        scratch::put(dxn1_k);
        scratch::put(dxn1_v);
        scratch::put(dq);
        scratch::put(dk);
        scratch::put(dv);
        scratch::put(datt_row);
        scratch::put(datt_concat);
        let din_norm = rmsnorm_bwd(&lc.x_in, lp.attn_norm, &dxn1, t, d, &mut lg.attn_norm);
        scratch::put(dxn1);
        // x_in feeds the residual (dx_mid) and the attn norm
        let mut dx_in = dx_mid;
        for (a, bb) in dx_in.iter_mut().zip(&din_norm) {
            *a += bb;
        }
        scratch::put(din_norm);
        dx = dx_in;
    }

    // embedding lookup backward
    for (pidx, &tok) in tokens.iter().enumerate() {
        let v = clamp_token(tok, cfg.vocab);
        axpy(1.0, &dx[pidx * d..(pidx + 1) * d], &mut g.embed[v * d..(v + 1) * d]);
    }
    scratch::put(dx);
    fc.recycle();

    (loss, ce, g)
}

// ---------------------------------------------------------------------------
// Autoregressive decode: the stateless `lm_decode_step` artifact plus
// the incremental KV-cache fast path the serving scheduler runs on
// ---------------------------------------------------------------------------

/// Next-token logits for a packed batch of variable-length rows (the
/// `lm_decode_step` artifact contract): row `i`'s logits are read at
/// position `lengths[i] - 1`. Trailing padding never influences the
/// result — causal attention masks it out of every earlier position,
/// and under row-local routers (TC) one row's MoE path never depends on
/// the others, so any batch composition yields the same per-row logits.
pub fn decode_logits(
    cfg: &LmCfg,
    p: &Params,
    tokens: &[i32],
    lengths: &[usize],
) -> Result<Vec<f32>> {
    let (b, s, d, vocab) = (cfg.rows, cfg.seq, cfg.d, cfg.vocab);
    ensure!(tokens.len() == b * s, "decode expects {b}x{s} tokens, got {}", tokens.len());
    ensure!(lengths.len() == b, "decode expects {b} lengths, got {}", lengths.len());
    let fc = forward(cfg, p, tokens);
    let mut logits = vec![0f32; b * vocab];
    for bi in 0..b {
        let len = lengths[bi].clamp(1, s);
        let pidx = bi * s + (len - 1);
        let xrow = &fc.xf[pidx * d..(pidx + 1) * d];
        let lrow = &mut logits[bi * vocab..(bi + 1) * vocab];
        for (v, l) in lrow.iter_mut().enumerate() {
            *l = dot(xrow, &p.embed[v * d..(v + 1) * d]);
        }
    }
    fc.recycle();
    Ok(logits)
}

/// One incremental decode step over live cache slots: append one token
/// per `(slot, token)` row, run the forward for just that position
/// against the cached K/V, and return next-token logits
/// (`rows.len() * vocab`, row order preserved). The returned buffer is
/// checked out of the per-thread scratch arena — callers on a steady
/// decode loop should hand it back with
/// [`scratch::put`](super::kernels::scratch::put) once consumed so the
/// step stays allocation-free.
///
/// Position-for-position this goes through the same kernels in the
/// same accumulation order as the full [`forward`] (per-row RMSNorm,
/// per-pair attention dots, in-order expert accumulation), and a row's
/// hidden state never reads the other rows of the step batch — so under
/// row-local routers (TC) the cached path is numerically identical to
/// [`decode_logits`] on the full prefix, whatever batch compositions
/// the scheduler produced along the way. Batch-global routers (TR, EC)
/// couple rows through the routing decision and lose that guarantee.
pub fn decode_step_cached(
    cfg: &LmCfg,
    p: &Params,
    cache: &mut KvCache,
    rows: &[(usize, i32)],
) -> Result<Vec<f32>> {
    let (d, nh, hd, vocab) = (cfg.d, cfg.n_heads, cfg.head_dim(), cfg.vocab);
    let sqrt_hd = (hd as f32).sqrt();
    ensure!(p.layers.len() == cfg.n_layers, "params/cfg layer mismatch");
    // per-token MoE shape: routing one row is exactly the full
    // forward's per-token decision under TC
    let step_cfg = LmCfg { rows: 1, seq: 1, ..cfg.clone() };
    let mut logits = scratch::take(rows.len() * vocab);
    for (ri, &(slot, tok)) in rows.iter().enumerate() {
        ensure!(cache.len(slot) < cache.max_seq(), "kv slot {slot} at capacity");
        let v0 = clamp_token(tok, cfg.vocab);
        let mut x = scratch::take(d);
        x.copy_from_slice(&p.embed[v0 * d..(v0 + 1) * d]);
        for (li, lp) in p.layers.iter().enumerate() {
            let xn1 = rmsnorm(&x, lp.attn_norm, 1, d);
            let q = kernels::matmul_wview(&xn1, lp.wq, 1, d, d);
            let k = kernels::matmul_wview(&xn1, lp.wk, 1, d, d);
            let v = kernels::matmul_wview(&xn1, lp.wv, 1, d, d);
            scratch::put(xn1);
            cache.push(li, slot, &k, &v)?;
            scratch::put(k);
            scratch::put(v);
            let n_pos = cache.len(slot) + 1; // committed prefix + this token
            // sized to slot capacity so the pooled buffer fits every
            // step of the sequence (a per-step n_pos take would grow
            // past the pool each step and re-allocate)
            let mut att = scratch::take(cache.max_seq());
            let mut att_concat = scratch::take(d);
            // the f32 arm is the pre-dtype loop verbatim (bitwise
            // contract); the bf16 arm widens each K/V element as it is
            // read, same accumulation order, half the streamed bytes
            match cache.kv_pending_view(li, slot) {
                KvView::F32 { k: kc, v: vc } => {
                    for h in 0..nh {
                        let qrow = &q[h * hd..(h + 1) * hd];
                        for sj in 0..n_pos {
                            let krow = &kc[sj * d + h * hd..sj * d + (h + 1) * hd];
                            att[sj] = dot(qrow, krow) / sqrt_hd;
                        }
                        softmax_inplace(&mut att[..n_pos]);
                        let orow = &mut att_concat[h * hd..(h + 1) * hd];
                        for sj in 0..n_pos {
                            let vrow = &vc[sj * d + h * hd..sj * d + (h + 1) * hd];
                            axpy(att[sj], vrow, orow);
                        }
                    }
                }
                KvView::Bf16 { k: kc, v: vc } => {
                    for h in 0..nh {
                        let qrow = &q[h * hd..(h + 1) * hd];
                        for sj in 0..n_pos {
                            let krow = &kc[sj * d + h * hd..sj * d + (h + 1) * hd];
                            att[sj] = dot_wb(qrow, krow) / sqrt_hd;
                        }
                        softmax_inplace(&mut att[..n_pos]);
                        let orow = &mut att_concat[h * hd..(h + 1) * hd];
                        for sj in 0..n_pos {
                            let vrow = &vc[sj * d + h * hd..sj * d + (h + 1) * hd];
                            axpy_wb(att[sj], vrow, orow);
                        }
                    }
                }
            }
            scratch::put(q);
            scratch::put(att);
            let att_proj = kernels::matmul_wview(&att_concat, lp.wo, 1, d, d);
            scratch::put(att_concat);
            let mut x_mid = x;
            for (a, bb) in x_mid.iter_mut().zip(&att_proj) {
                *a += bb;
            }
            scratch::put(att_proj);
            let xn2 = rmsnorm(&x_mid, lp.moe_norm, 1, d);
            let (o, moe) = moe_forward(&step_cfg, &xn2, lp.wr, &lp.experts, cfg.router);
            moe.recycle();
            scratch::put(xn2);
            let mut x_out = x_mid;
            for (a, bb) in x_out.iter_mut().zip(&o) {
                *a += bb;
            }
            scratch::put(o);
            x = x_out;
        }
        cache.advance(slot);
        let xf = rmsnorm(&x, p.final_norm, 1, d);
        scratch::put(x);
        let lrow = &mut logits[ri * vocab..(ri + 1) * vocab];
        for (vi, l) in lrow.iter_mut().enumerate() {
            *l = dot(&xf, &p.embed[vi * d..(vi + 1) * d]);
        }
        scratch::put(xf);
    }
    Ok(logits)
}

/// The compute of one padded decode row: the same per-position work as
/// a live row (projections, single-position attention, routed MoE,
/// logits head) on a dummy token, result discarded by the caller. The
/// scheduler executes `exec_rows - live` of these per step, so
/// tile-quantized vs full-shape slot scheduling differ in *real* work
/// — mirroring the fixed executed shapes of an accelerator decode
/// artifact — not just in bookkeeping. Returns a data-dependent scalar
/// so the work cannot be elided.
pub fn decode_pad_row(cfg: &LmCfg, p: &Params) -> f32 {
    let d = cfg.d;
    let step_cfg = LmCfg { rows: 1, seq: 1, ..cfg.clone() };
    let mut x = scratch::take(d);
    x.copy_from_slice(&p.embed[..d]);
    for lp in &p.layers {
        let xn1 = rmsnorm(&x, lp.attn_norm, 1, d);
        let q = kernels::matmul_wview(&xn1, lp.wq, 1, d, d);
        let k = kernels::matmul_wview(&xn1, lp.wk, 1, d, d);
        let v = kernels::matmul_wview(&xn1, lp.wv, 1, d, d);
        scratch::put(xn1);
        scratch::put(q);
        scratch::put(k);
        // single-position causal attention: the softmax of one score is
        // 1, so the head output is v itself (q/k still computed — a
        // padded row pays the projection cost either way)
        let att_proj = kernels::matmul_wview(&v, lp.wo, 1, d, d);
        scratch::put(v);
        let mut x_mid = x;
        for (a, bb) in x_mid.iter_mut().zip(&att_proj) {
            *a += bb;
        }
        scratch::put(att_proj);
        let xn2 = rmsnorm(&x_mid, lp.moe_norm, 1, d);
        let (o, moe) = moe_forward(&step_cfg, &xn2, lp.wr, &lp.experts, cfg.router);
        moe.recycle();
        scratch::put(xn2);
        let mut x_out = x_mid;
        for (a, bb) in x_out.iter_mut().zip(&o) {
            *a += bb;
        }
        scratch::put(o);
        x = x_out;
    }
    let xf = rmsnorm(&x, p.final_norm, 1, d);
    scratch::put(x);
    let mut acc = 0f32;
    for vi in 0..cfg.vocab {
        acc += dot(&xf, &p.embed[vi * d..(vi + 1) * d]);
    }
    scratch::put(xf);
    acc
}

// ---------------------------------------------------------------------------
// Tests: self-contained numeric checks (finite differences, dense-MoE
// cross-check, eval/grad consistency)
// ---------------------------------------------------------------------------

#[cfg(test)]
mod tests {
    use super::super::linalg::matmul;
    use super::*;

    fn tiny_cfg() -> LmCfg {
        LmCfg {
            vocab: 32,
            d: 8,
            n_layers: 2,
            n_heads: 2,
            rows: 2,
            seq: 6,
            n: 4,
            e: 4,
            k: 2,
            m_tile: 2,
            aux_coeff: 0.01,
            router: RouterKind::Tc,
        }
    }

    fn rand_tensor(rng: &mut Prng, shape: &[usize], scale: f32) -> Tensor {
        let n: usize = shape.iter().product();
        let data: Vec<f32> = (0..n).map(|_| rng.normal() as f32 * scale).collect();
        Tensor::from_vec(shape, data).unwrap()
    }

    /// Build a full random parameter set for `cfg` (owned tensors in
    /// manifest order).
    fn rand_params(cfg: &LmCfg, seed: u64) -> Vec<(String, Tensor)> {
        let mut rng = Prng::new(seed);
        let (d, n, e, v) = (cfg.d, cfg.n, cfg.e, cfg.vocab);
        let mut out: Vec<(String, Tensor)> = Vec::new();
        out.push(("embed".into(), rand_tensor(&mut rng, &[v, d], 0.05)));
        for i in 0..cfg.n_layers {
            let p = |s: &str| format!("layer{i}.{s}");
            out.push((p("attn_norm"), Tensor::from_vec(&[d], vec![1.0; d]).unwrap()));
            out.push((p("wq"), rand_tensor(&mut rng, &[d, d], (d as f32).powf(-0.5))));
            out.push((p("wk"), rand_tensor(&mut rng, &[d, d], (d as f32).powf(-0.5))));
            out.push((p("wv"), rand_tensor(&mut rng, &[d, d], (d as f32).powf(-0.5))));
            out.push((p("wo"), rand_tensor(&mut rng, &[d, d], (d as f32).powf(-0.5))));
            out.push((p("moe_norm"), Tensor::from_vec(&[d], vec![1.0; d]).unwrap()));
            out.push((p("wr"), rand_tensor(&mut rng, &[d, e], 0.1)));
            out.push((p("w1"), rand_tensor(&mut rng, &[e, d, 2 * n], (d as f32).powf(-0.5))));
            out.push((p("w2"), rand_tensor(&mut rng, &[e, n, d], (n as f32).powf(-0.5))));
        }
        out.push(("final_norm".into(), Tensor::from_vec(&[d], vec![1.0; d]).unwrap()));
        out
    }

    fn params_view<'a>(store: &'a [(String, Tensor)], n_layers: usize) -> Params<'a> {
        Params::collect(n_layers, |name| {
            store
                .iter()
                .find(|(n, _)| n == name)
                .map(|(_, t)| t)
                .ok_or_else(|| anyhow!("missing {name}"))
        })
        .unwrap()
    }

    fn tiny_tokens(cfg: &LmCfg) -> Vec<i32> {
        (0..cfg.t()).map(|i| ((i * 7 + 3) % cfg.vocab) as i32).collect()
    }

    #[test]
    fn eval_matches_grad_step_ce() {
        let cfg = tiny_cfg();
        let store = rand_params(&cfg, 1);
        let p = params_view(&store, cfg.n_layers);
        let toks = tiny_tokens(&cfg);
        let ce_eval = eval_ce(&cfg, &p, &toks);
        let (loss, ce_grad, _) = grad_step(&cfg, &p, &toks);
        assert!((ce_eval - ce_grad).abs() < 1e-5, "{ce_eval} vs {ce_grad}");
        assert!(loss > ce_grad, "loss should include the aux term");
        assert!(ce_eval.is_finite() && ce_eval > 0.0);
    }

    /// Per-row CE of a mixed batch equals the CE of replicating that
    /// row across the whole batch (`score_exact` semantics) under the
    /// TC router, and the batch mean is the mean of the rows.
    #[test]
    fn per_row_ce_matches_replicated_exact() {
        let cfg = tiny_cfg();
        let store = rand_params(&cfg, 11);
        let p = params_view(&store, cfg.n_layers);
        let (s, b) = (cfg.seq, cfg.rows);
        // two genuinely different rows
        let rows: Vec<Vec<i32>> = (0..b)
            .map(|bi| (0..s).map(|j| ((bi * 17 + j * 5 + 1) % cfg.vocab) as i32).collect())
            .collect();
        let mixed: Vec<i32> = rows.iter().flatten().copied().collect();
        let (ce_batch, ce_rows) = eval_ce_rows(&cfg, &p, &mixed);
        assert_eq!(ce_rows.len(), b);
        let mean: f64 =
            ce_rows.iter().map(|&x| x as f64).sum::<f64>() / b as f64;
        assert!((mean - ce_batch as f64).abs() < 1e-6, "{mean} vs {ce_batch}");
        for (bi, row) in rows.iter().enumerate() {
            let replicated: Vec<i32> =
                (0..b).flat_map(|_| row.iter().copied()).collect();
            let exact = eval_ce(&cfg, &p, &replicated);
            assert!(
                (ce_rows[bi] - exact).abs() < 1e-6,
                "row {bi}: per-row {} vs replicated-exact {exact}",
                ce_rows[bi]
            );
        }
        // the rows really do differ
        assert!((ce_rows[0] - ce_rows[1]).abs() > 1e-9);
    }

    #[test]
    fn deterministic_across_runs() {
        let cfg = tiny_cfg();
        let store = rand_params(&cfg, 2);
        let p = params_view(&store, cfg.n_layers);
        let toks = tiny_tokens(&cfg);
        let (l1, c1, g1) = grad_step(&cfg, &p, &toks);
        let (l2, c2, g2) = grad_step(&cfg, &p, &toks);
        assert_eq!(l1, l2);
        assert_eq!(c1, c2);
        assert_eq!(g1.embed, g2.embed);
        assert_eq!(g1.layers[0].w1, g2.layers[0].w1);
    }

    /// Central-difference gradient check of selected parameters through
    /// the full model (loss includes the aux term; the routing mask is
    /// piecewise constant, so small perturbations stay differentiable).
    #[test]
    fn finite_difference_gradcheck() {
        let cfg = tiny_cfg();
        let store = rand_params(&cfg, 3);
        let toks = tiny_tokens(&cfg);
        let (_, _, mut grads) = {
            let p = params_view(&store, cfg.n_layers);
            grad_step(&cfg, &p, &toks)
        };

        let mut checked = 0;
        let mut failures: Vec<String> = Vec::new();
        for name in ["layer0.wq", "layer0.w1", "layer1.w2", "layer0.wr", "final_norm", "embed"] {
            let g = grads.take(name).unwrap();
            // check the element with the largest gradient magnitude
            let (idx, &gmax) = g
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.abs().partial_cmp(&b.1.abs()).unwrap())
                .unwrap();
            if gmax.abs() < 1e-2 {
                continue; // too small for f32 finite differences
            }
            let h = 1e-3f32;
            let loss_at = |delta: f32| -> f64 {
                let mut store2 = store.clone();
                let slot = store2.iter_mut().find(|(n, _)| n == name).unwrap();
                slot.1.data[idx] += delta;
                let p = params_view(&store2, cfg.n_layers);
                let (loss, _, _) = grad_step(&cfg, &p, &toks);
                loss as f64
            };
            let num = (loss_at(h) - loss_at(-h)) / (2.0 * h as f64);
            let rel = (num - gmax as f64).abs() / gmax.abs().max(1e-3) as f64;
            checked += 1;
            if rel > 0.25 {
                failures.push(format!(
                    "{name}[{idx}]: analytic {gmax:.5} vs numeric {num:.5} (rel {rel:.3})"
                ));
            }
        }
        assert!(checked >= 3, "only {checked} parameters had checkable gradients");
        // a discrete routing-mask flip under perturbation can break one
        // probe; a systematic backward bug breaks them all
        assert!(failures.len() <= 1, "gradcheck failures: {failures:?}");
    }

    /// Grouped expert compute == dense one-hot formulation (ref.py
    /// Algorithm 1) on the same routing decision.
    #[test]
    fn grouped_moe_matches_dense_reference() {
        let cfg = tiny_cfg();
        let (t, d, n, e) = (cfg.t(), cfg.d, cfg.n, cfg.e);
        let mut rng = Prng::new(9);
        let x = rand_tensor(&mut rng, &[t, d], 0.5);
        let wr = rand_tensor(&mut rng, &[d, e], 0.1);
        let w1 = rand_tensor(&mut rng, &[e, d, 2 * n], 0.3);
        let w2 = rand_tensor(&mut rng, &[e, n, d], 0.3);
        let experts = ExpertWeights::Dense {
            w1: WView::F32(&w1.data),
            w2: WView::F32(&w2.data),
        };
        let (o, cache) = moe_forward(&cfg, &x.data, WView::F32(&wr.data), &experts, RouterKind::Tc);

        // dense: O_t = sum_e r_te * SwiGLU(x_t W1_e) W2_e
        for tok in 0..t {
            for c in 0..d {
                let mut want = 0f32;
                for j in 0..e {
                    let gate = cache.r[tok * e + j];
                    if gate == 0.0 {
                        continue;
                    }
                    let w1_e = &w1.data[j * d * 2 * n..(j + 1) * d * 2 * n];
                    let w2_e = &w2.data[j * n * d..(j + 1) * n * d];
                    let h = matmul(&x.data[tok * d..(tok + 1) * d], w1_e, 1, d, 2 * n);
                    let a = swiglu(&h, 1, n);
                    let mut y_c = 0f32;
                    for jj in 0..n {
                        y_c += a[jj] * w2_e[jj * d + c];
                    }
                    want += gate * y_c;
                }
                let got = o[tok * d + c];
                assert!(
                    (got - want).abs() < 1e-4,
                    "o[{tok},{c}] = {got} vs dense {want}"
                );
            }
        }
        // every token routed to exactly K experts under TC
        for tok in 0..t {
            let cnt = (0..e).filter(|&j| cache.dec.mask[tok * e + j]).count();
            assert_eq!(cnt, cfg.k);
            // renormalized gates sum to 1
            let sum: f32 = (0..e).map(|j| cache.r[tok * e + j]).sum();
            assert!((sum - 1.0).abs() < 1e-5);
        }
    }

    #[test]
    fn router_tag_parsing() {
        assert_eq!(parse_router_tag("tc").unwrap(), (RouterKind::Tc, None));
        assert_eq!(
            parse_router_tag("tr").unwrap(),
            (RouterKind::Tr(RoundingRule::NearestFreq), None)
        );
        assert_eq!(
            parse_router_tag("tr_m8").unwrap(),
            (RouterKind::Tr(RoundingRule::NearestFreq), Some(8))
        );
        assert_eq!(
            parse_router_tag("tr_b2").unwrap(),
            (RouterKind::Tr(RoundingRule::NearestFreq), None)
        );
        assert_eq!(parse_router_tag("trdown").unwrap().0, RouterKind::Tr(RoundingRule::Down));
        assert!(parse_router_tag("bogus").is_err());
        assert_eq!(parse_router_method("tr-nr-f").unwrap(), RouterKind::Tr(RoundingRule::NearestFreq));
        assert_eq!(parse_router_method("tc").unwrap(), RouterKind::Tc);
    }

    /// Stateless decode is padding-invariant: the logits at
    /// `lengths[i] - 1` do not change when the tokens past the length
    /// change (causal masking + row-local TC routing).
    #[test]
    fn decode_logits_ignore_trailing_padding() {
        let cfg = tiny_cfg();
        let store = rand_params(&cfg, 17);
        let p = params_view(&store, cfg.n_layers);
        let (b, s) = (cfg.rows, cfg.seq);
        let lens = [3usize, 5];
        let mut toks = vec![0i32; b * s];
        for bi in 0..b {
            for j in 0..lens[bi] {
                toks[bi * s + j] = ((bi * 11 + j * 3 + 1) % cfg.vocab) as i32;
            }
        }
        let base = decode_logits(&cfg, &p, &toks, &lens).unwrap();
        assert_eq!(base.len(), b * cfg.vocab);
        assert!(base.iter().all(|x| x.is_finite()));
        // scribble over the padding region
        let mut toks2 = toks.clone();
        for bi in 0..b {
            for j in lens[bi]..s {
                toks2[bi * s + j] = ((bi * 7 + j * 13 + 5) % cfg.vocab) as i32;
            }
        }
        let scribbled = decode_logits(&cfg, &p, &toks2, &lens).unwrap();
        assert_eq!(base, scribbled, "trailing padding leaked into decode logits");
        // wrong shapes are refused
        assert!(decode_logits(&cfg, &p, &toks[..b * s - 1], &lens).is_err());
        assert!(decode_logits(&cfg, &p, &toks, &lens[..1]).is_err());
    }

    /// The incremental KV-cache path reproduces the stateless
    /// full-prefix decode exactly under the TC router, with two
    /// sequences of different lengths grown in one cache.
    #[test]
    fn cached_decode_matches_stateless_logits() {
        let cfg = tiny_cfg();
        let store = rand_params(&cfg, 21);
        let p = params_view(&store, cfg.n_layers);
        let lens = [5usize, 4];
        let seqs: Vec<Vec<i32>> = (0..cfg.rows)
            .map(|r| {
                (0..lens[r]).map(|j| ((r * 13 + j * 5 + 2) % cfg.vocab) as i32).collect()
            })
            .collect();
        let mut cache = KvCache::new(cfg.n_layers, cfg.d, cfg.rows, cfg.seq);
        let s0 = cache.alloc().unwrap();
        let s1 = cache.alloc().unwrap();
        // row 1 joins two steps late: batch composition changes
        // mid-flight, exactly the continuous-batching regime
        let mut last0 = Vec::new();
        let mut last1 = Vec::new();
        for t in 0..lens[0] {
            let mut rows = vec![(s0, seqs[0][t])];
            let joined = t >= lens[0] - lens[1];
            if joined {
                rows.push((s1, seqs[1][t - (lens[0] - lens[1])]));
            }
            let out = decode_step_cached(&cfg, &p, &mut cache, &rows).unwrap();
            last0 = out[..cfg.vocab].to_vec();
            if joined {
                last1 = out[cfg.vocab..].to_vec();
            }
        }
        assert_eq!(cache.len(s0), lens[0]);
        assert_eq!(cache.len(s1), lens[1]);
        // stateless reference over the full prefixes
        let mut toks = vec![0i32; cfg.t()];
        for (r, seq) in seqs.iter().enumerate() {
            for (j, &tk) in seq.iter().enumerate() {
                toks[r * cfg.seq + j] = tk;
            }
        }
        let reference = decode_logits(&cfg, &p, &toks, &lens).unwrap();
        assert_eq!(last0, reference[..cfg.vocab].to_vec(), "row 0 cached != stateless");
        assert_eq!(last1, reference[cfg.vocab..].to_vec(), "row 1 cached != stateless");
    }

    /// A bf16 [`ParamStore`] halves the resident bytes of every
    /// GEMM-streamed weight (norms/embed stay f32) and its eval CE
    /// drifts from the f32 reference by at most 1e-2 relative — the
    /// documented golden-drift bound for bf16 storage.
    #[test]
    fn bf16_store_halves_weight_bytes_and_bounds_ce_drift() {
        let cfg = tiny_cfg();
        let store = rand_params(&cfg, 41);
        let toks = tiny_tokens(&cfg);
        let ce_f32 = {
            let p = params_view(&store, cfg.n_layers);
            eval_ce(&cfg, &p, &toks)
        };

        let f32_store = ParamStore::new(store.clone(), Dtype::F32);
        let bf16_store = ParamStore::new(store.clone(), Dtype::Bf16);
        assert_eq!(f32_store.dtype(), Dtype::F32);
        assert_eq!(bf16_store.dtype(), Dtype::Bf16);

        // byte accounting: GEMM weights halve, norms + embed stay f32
        let (d, n, e, v) = (cfg.d, cfg.n, cfg.e, cfg.vocab);
        let gemm_per_layer = 4 * d * d + d * e + e * d * 2 * n + e * n * d;
        let f32_only = v * d + cfg.n_layers * 2 * d + d;
        let want_f32 = 4 * (f32_only + cfg.n_layers * gemm_per_layer);
        let want_bf16 = 4 * f32_only + 2 * cfg.n_layers * gemm_per_layer;
        assert_eq!(f32_store.weight_bytes(), want_f32);
        assert_eq!(bf16_store.weight_bytes(), want_bf16);

        // the f32 store reproduces the reference bitwise
        let p = f32_store.view(cfg.n_layers).unwrap();
        assert_eq!(eval_ce(&cfg, &p, &toks), ce_f32);

        // bf16 CE drift stays inside the documented bound
        let p = bf16_store.view(cfg.n_layers).unwrap();
        let ce_bf16 = eval_ce(&cfg, &p, &toks);
        let rel = ((ce_bf16 - ce_f32) / ce_f32).abs();
        assert!(
            rel <= 1e-2,
            "bf16 eval CE {ce_bf16} vs f32 {ce_f32}: relative drift {rel:e} > 1e-2"
        );
    }

    /// Cached decode on a bf16 store is bitwise equal to cached decode
    /// on f32 params pre-roundtripped through bf16 — the pack-fused
    /// widening changes where the widen happens, never the math.
    #[test]
    fn bf16_cached_decode_matches_roundtripped_reference() {
        use crate::util::dtype::roundtrip_slice;
        let cfg = tiny_cfg();
        let store = rand_params(&cfg, 43);
        let bf16_store = ParamStore::new(store.clone(), Dtype::Bf16);
        // reference: the same quantization applied up front, f32 path
        let rt_store: Vec<(String, Tensor)> = store
            .iter()
            .map(|(name, t)| {
                let t = if ParamStore::is_gemm_weight(name) {
                    Tensor::from_vec(&t.shape, roundtrip_slice(&t.data)).unwrap()
                } else {
                    t.clone()
                };
                (name.clone(), t)
            })
            .collect();

        let p_bf16 = bf16_store.view(cfg.n_layers).unwrap();
        let p_rt = params_view(&rt_store, cfg.n_layers);
        let mut cache_a = KvCache::new(cfg.n_layers, cfg.d, 1, cfg.seq);
        let mut cache_b = KvCache::new(cfg.n_layers, cfg.d, 1, cfg.seq);
        let sa = cache_a.alloc().unwrap();
        let sb = cache_b.alloc().unwrap();
        for tok in [3i32, 11, 7, 2] {
            let la = decode_step_cached(&cfg, &p_bf16, &mut cache_a, &[(sa, tok)]).unwrap();
            let lb = decode_step_cached(&cfg, &p_rt, &mut cache_b, &[(sb, tok)]).unwrap();
            assert_eq!(la, lb, "bf16 decode differs from pre-widened f32 decode");
            scratch::put(la);
            scratch::put(lb);
        }
    }

    /// A tiered store whose budget clamps to a single expert blob
    /// serves bitwise-identical eval CE to the dense store at both
    /// storage precisions — eviction pressure never changes the math,
    /// it only changes where the bytes are read from.
    #[test]
    fn tiered_store_matches_dense_bitwise_under_eviction() {
        use crate::memory::residency::ResidencySpec;
        let cfg = tiny_cfg();
        let store = rand_params(&cfg, 53);
        let toks = tiny_tokens(&cfg);
        for dtype in [Dtype::F32, Dtype::Bf16] {
            let dense = ParamStore::new(store.clone(), dtype);
            let ce_dense = {
                let p = dense.view(cfg.n_layers).unwrap();
                eval_ce(&cfg, &p, &toks)
            };
            let spec = ResidencySpec::new(1, None); // clamps up to one blob
            let tiered = ParamStore::new_tiered(store.clone(), dtype, &spec).unwrap();
            let p = tiered.view(cfg.n_layers).unwrap();
            for _ in 0..2 {
                assert_eq!(eval_ce(&cfg, &p, &toks), ce_dense, "dtype {dtype:?}");
            }
            let snap = spec.stats.snapshot();
            assert!(snap.total.evictions > 0, "one-blob budget must evict");
            assert!(snap.total.hits + snap.total.misses > 0, "no residency traffic recorded");
            // resident gauge: pinned set + at most a handful of blobs
            assert!(tiered.weight_bytes() < dense.weight_bytes());
        }
    }

    /// Checkpoint reload on a tiered store rebuilds the same tiering —
    /// same effective budget, same stats sink — over fresh weights.
    #[test]
    fn tiered_rebuild_preserves_tiering_and_stats_sink() {
        use crate::memory::residency::ResidencySpec;
        let cfg = tiny_cfg();
        let spec = ResidencySpec::new(1 << 20, None);
        let t1 = ParamStore::new_tiered(rand_params(&cfg, 59), Dtype::F32, &spec).unwrap();
        let budget = t1.residency().unwrap().budget_bytes();
        let t2 = t1.rebuild(rand_params(&cfg, 61)).unwrap();
        let store2 = t2.residency().expect("rebuild dropped the tiering");
        assert_eq!(store2.budget_bytes(), budget);
        let toks = tiny_tokens(&cfg);
        let p = t2.view(cfg.n_layers).unwrap();
        let ce = eval_ce(&cfg, &p, &toks);
        assert!(ce.is_finite() && ce > 0.0);
        // the rebuilt store reports into the original spec's sink
        let snap = spec.stats.snapshot();
        assert!(snap.total.hits + snap.total.misses > 0);
    }

    /// Cached decode over a bf16 KV cache: deterministic (bit-identical
    /// across runs), finite, and within a loose drift bound of the f32
    /// cache — each K/V element carries one bf16 rounding (rel 2^-8).
    #[test]
    fn bf16_kv_cache_decode_is_deterministic_and_bounded() {
        let cfg = tiny_cfg();
        let store = rand_params(&cfg, 47);
        let p = params_view(&store, cfg.n_layers);
        let toks = [3i32, 11, 7, 2, 5];
        let run = |dtype: Dtype| {
            let mut cache =
                KvCache::new_with_dtype(cfg.n_layers, cfg.d, 1, cfg.seq, dtype);
            let s = cache.alloc().unwrap();
            let mut rows = Vec::new();
            for &tok in &toks {
                let l = decode_step_cached(&cfg, &p, &mut cache, &[(s, tok)]).unwrap();
                rows.push(l.to_vec());
                scratch::put(l);
            }
            rows
        };
        let f = run(Dtype::F32);
        let b1 = run(Dtype::Bf16);
        let b2 = run(Dtype::Bf16);
        assert_eq!(b1, b2, "bf16 KV decode is not deterministic");
        for (step, (lf, lb)) in f.iter().zip(&b1).enumerate() {
            let scale = lf.iter().fold(0f32, |m, x| m.max(x.abs()));
            for (a, b) in lf.iter().zip(lb) {
                assert!(b.is_finite());
                assert!(
                    (a - b).abs() <= 0.05 * scale + 1e-3,
                    "step {step}: bf16-KV logit {b} drifted from f32 {a} (scale {scale})"
                );
            }
        }
    }

    /// After one warmup call, the MoE forward + backward hot path
    /// performs zero heap allocation for activations: every scratch
    /// take is served from the per-thread arena pool.
    #[test]
    fn moe_hot_path_zero_alloc_after_warmup() {
        let cfg = tiny_cfg();
        let (t, d, n, e) = (cfg.t(), cfg.d, cfg.n, cfg.e);
        let mut rng = Prng::new(31);
        let x = rand_tensor(&mut rng, &[t, d], 0.5);
        let wr = rand_tensor(&mut rng, &[d, e], 0.1);
        let w1 = rand_tensor(&mut rng, &[e, d, 2 * n], 0.3);
        let w2 = rand_tensor(&mut rng, &[e, n, d], 0.3);
        let d_o = vec![0.1f32; t * d];
        let mut dwr = vec![0f32; d * e];
        let mut dw1 = vec![0f32; e * d * 2 * n];
        let mut dw2 = vec![0f32; e * n * d];
        let mut run = || {
            let experts = ExpertWeights::Dense {
                w1: WView::F32(&w1.data),
                w2: WView::F32(&w2.data),
            };
            let (o, cache) = moe_forward(&cfg, &x.data, WView::F32(&wr.data), &experts, RouterKind::Tc);
            let dxn = moe_backward(
                &cfg, &cache, &x.data, &wr.data, &w1.data, &w2.data, &d_o, 0.01, &mut dwr,
                &mut dw1, &mut dw2,
            );
            scratch::put(dxn);
            scratch::put(o);
            cache.recycle();
        };
        for _ in 0..2 {
            run(); // warmup populates the pool
        }
        let before = scratch::stats().allocs;
        for _ in 0..5 {
            run();
        }
        let after = scratch::stats().allocs;
        assert_eq!(after, before, "moe fwd/bwd allocated after warmup");
    }

    /// The cached decode step is allocation-free after warmup when the
    /// caller recycles the logits buffer (the serving scheduler does).
    #[test]
    fn decode_step_zero_alloc_after_warmup() {
        let cfg = tiny_cfg();
        let store = rand_params(&cfg, 23);
        let p = params_view(&store, cfg.n_layers);
        let mut cache = KvCache::new(cfg.n_layers, cfg.d, 1, cfg.seq);
        let slot = cache.alloc().unwrap();
        // warmup: two steps (the first grows every pool buffer)
        for tok in 0..2 {
            let l = decode_step_cached(&cfg, &p, &mut cache, &[(slot, tok)]).unwrap();
            scratch::put(l);
        }
        let before = scratch::stats().allocs;
        for tok in 2..5 {
            let l = decode_step_cached(&cfg, &p, &mut cache, &[(slot, tok)]).unwrap();
            scratch::put(l);
        }
        assert_eq!(scratch::stats().allocs, before, "decode step allocated after warmup");
    }

    #[test]
    fn tr_grad_step_runs_and_is_finite() {
        let mut cfg = tiny_cfg();
        cfg.router = RouterKind::Tr(RoundingRule::NearestFreq);
        let store = rand_params(&cfg, 5);
        let p = params_view(&store, cfg.n_layers);
        let toks = tiny_tokens(&cfg);
        let (loss, ce, g) = grad_step(&cfg, &p, &toks);
        assert!(loss.is_finite() && ce.is_finite());
        assert!(g.embed.iter().all(|x| x.is_finite()));
        assert!(g.layers[1].wr.iter().all(|x| x.is_finite()));
    }
}
