//! Small statistics helpers shared by the bench harness and metrics.

/// Summary statistics of a sample.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub median: f64,
    pub p90: f64,
    pub max: f64,
}

impl Summary {
    pub fn of(xs: &[f64]) -> Summary {
        assert!(!xs.is_empty());
        let n = xs.len();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>()
            / (n.max(2) - 1) as f64;
        let mut s = xs.to_vec();
        s.sort_by(|a, b| a.partial_cmp(b).unwrap());
        Summary {
            n,
            mean,
            std: var.sqrt(),
            min: s[0],
            median: percentile(&s, 50.0),
            p90: percentile(&s, 90.0),
            max: s[n - 1],
        }
    }
}

/// Percentile of a pre-sorted sample (linear interpolation).
pub fn percentile(sorted: &[f64], p: f64) -> f64 {
    assert!(!sorted.is_empty());
    let rank = p / 100.0 * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        let w = rank - lo as f64;
        sorted[lo] * (1.0 - w) + sorted[hi] * w
    }
}

/// Exponential moving average, used by the trainer's loss smoothing.
#[derive(Debug, Clone)]
pub struct Ema {
    alpha: f64,
    value: Option<f64>,
}

impl Ema {
    pub fn new(alpha: f64) -> Self {
        assert!(alpha > 0.0 && alpha <= 1.0);
        Ema { alpha, value: None }
    }

    pub fn update(&mut self, x: f64) -> f64 {
        let v = match self.value {
            None => x,
            Some(v) => v * (1.0 - self.alpha) + x * self.alpha,
        };
        self.value = Some(v);
        v
    }

    pub fn get(&self) -> Option<f64> {
        self.value
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basics() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.n, 5);
        assert_eq!(s.mean, 3.0);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert_eq!(s.median, 3.0);
        assert!((s.std - (2.5f64).sqrt()).abs() < 1e-12);
    }

    #[test]
    fn percentile_interpolates() {
        let s = [0.0, 10.0];
        assert_eq!(percentile(&s, 0.0), 0.0);
        assert_eq!(percentile(&s, 50.0), 5.0);
        assert_eq!(percentile(&s, 100.0), 10.0);
    }

    #[test]
    fn ema_converges() {
        let mut e = Ema::new(0.5);
        assert_eq!(e.update(10.0), 10.0);
        let v = e.update(0.0);
        assert_eq!(v, 5.0);
        for _ in 0..50 {
            e.update(2.0);
        }
        assert!((e.get().unwrap() - 2.0).abs() < 1e-6);
    }
}
