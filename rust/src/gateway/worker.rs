//! Gateway worker pool: each worker owns a full scoring core (runtime,
//! staged parameters, eval artifacts) and loops
//! form-batch → execute → respond until the admission queue closes and
//! drains. Cores are constructed *inside* the worker thread because the
//! backend [`Executable`](crate::runtime::Executable) contract is
//! deliberately not `Send` (device-backed executables may hold
//! thread-affine handles).
//!
//! Because the native kernels' scratch arena is per-thread, pinning one
//! core per worker thread also pins one arena per worker: the first
//! scored batch warms the pool and every later batch on that worker
//! executes its full activation set out of recycled buffers instead of
//! re-allocating it per request.

use std::sync::Arc;
use std::time::Instant;

use crate::coordinator::serve::ScoreCore;
use crate::memory::residency::ResidencySpec;
use crate::obs::{self, SpanKind};
use crate::util::dtype::Dtype;

use super::batcher::form_batch;
use super::protocol::ServerMsg;
use super::{send_line, Shared};

/// Per-worker construction parameters (the gateway config minus the
/// shared state).
pub struct WorkerCfg {
    pub artifacts_dir: String,
    pub config: String,
    pub backend: String,
    pub checkpoint: Option<String>,
    pub index: usize,
    /// Serving precision (bf16 round-trips the GEMM weights so scores
    /// match the bf16 decode numerics).
    pub dtype: Dtype,
    /// Tiered expert residency (each worker builds its own spill-backed
    /// store from the cloned spec; the stats sink is shared).
    pub residency: Option<ResidencySpec>,
    /// Chaos-drill fault injection: abandon the worker loop after this
    /// many completed batches, as if the thread died (0 = off). Set by
    /// [`FaultPlan::kill_worker_after_batches`](super::FaultPlan) on
    /// worker 0 only.
    pub kill_after_batches: usize,
}

/// Worker thread body.
pub fn run(cfg: WorkerCfg, shared: Arc<Shared>) {
    let open = || match &cfg.residency {
        Some(spec) => ScoreCore::new_with_residency(
            &cfg.artifacts_dir,
            &cfg.config,
            &cfg.backend,
            cfg.dtype,
            spec,
        ),
        None => ScoreCore::new_with_dtype(&cfg.artifacts_dir, &cfg.config, &cfg.backend, cfg.dtype),
    };
    let mut core = match open() {
        Ok(c) => c,
        Err(e) => {
            // the gateway validated this config before spawning, so
            // this is an environment race
            log::error!("gateway worker {} failed to open core: {e:#}", cfg.index);
            abandon(&shared);
            return;
        }
    };
    if let Some(dir) = &cfg.checkpoint {
        if let Err(e) = core.load_checkpoint(dir) {
            log::error!("gateway worker {} failed checkpoint load: {e:#}", cfg.index);
            abandon(&shared);
            return;
        }
    }
    let seq = core.seq;
    let mut local_gen = 0u64;
    let mut batches_done = 0usize;
    loop {
        // scripted kill (chaos drill): die between batches the way a
        // panicked worker would — without replying to anything still
        // queued. The surviving pool must absorb the backlog.
        if cfg.kill_after_batches > 0 && batches_done >= cfg.kill_after_batches {
            log::warn!(
                "gateway worker {}: injected kill after {batches_done} batches",
                cfg.index
            );
            shared.stats.lock().unwrap().injected_worker_kills += 1;
            abandon(&shared);
            return;
        }
        // apply a pending checkpoint hot-swap between batches
        let pending = {
            let r = shared.reload.lock().unwrap();
            if r.gen != local_gen { Some((r.gen, r.dir.clone())) } else { None }
        };
        if let Some((gen, dir)) = pending {
            match core.load_checkpoint(&dir) {
                Ok(()) => {
                    shared.stats.lock().unwrap().reloads += 1;
                    log::info!("gateway worker {}: reloaded {dir}", cfg.index);
                }
                Err(e) => log::warn!("gateway worker {}: reload failed: {e:#}", cfg.index),
            }
            local_gen = gen;
        }

        // the form interval doubles as the thread-track batch_form span
        // (it includes any idle wait for the first arrival — that *is*
        // the time this worker held its batch open)
        let form_t0 = obs::recorder::now_ns();
        let batch = form_batch(&shared.queue, shared.rows_max, &shared.policy);
        if batch.is_empty() {
            break; // queue closed and drained
        }
        let form_end = obs::recorder::now_ns();
        if obs::recorder::enabled() {
            obs::record_span(0, SpanKind::BatchForm, form_t0, form_end, batch.len() as u64);
        }
        batches_done += 1;
        let t0 = Instant::now();
        // the simulated-latency sleep stands in for model time, so it
        // belongs inside the exec span
        let exec_t0 = obs::recorder::now_ns();
        if !shared.worker_delay.is_zero() {
            // simulated model latency (bench/test hook)
            std::thread::sleep(shared.worker_delay);
        }
        let toks: Vec<&[i32]> = batch.iter().map(|r| r.tokens.as_slice()).collect();
        match core.score_batch(&toks, shared.m_tile) {
            Ok(score) => {
                let dt = t0.elapsed().as_secs_f64();
                if obs::recorder::enabled() {
                    obs::record_span(
                        0,
                        SpanKind::BatchExec,
                        exec_t0,
                        obs::recorder::now_ns(),
                        score.exec_rows as u64,
                    );
                }
                shared
                    .stats
                    .lock()
                    .unwrap()
                    .record_batch(batch.len(), score.exec_rows, seq, dt);
                for (req, &ce) in batch.iter().zip(score.ce.iter()) {
                    let latency_ms = req.enqueued.elapsed().as_secs_f64() * 1e3;
                    let wait = t0.saturating_duration_since(req.enqueued);
                    // count before writing: a client that has read its
                    // reply must find it reflected in `stats`
                    {
                        let mut st = shared.stats.lock().unwrap();
                        st.record_response(latency_ms);
                        st.record_queue_wait(wait.as_secs_f64() * 1e3);
                        st.record_exemplar("score", req.id, req.trace, latency_ms);
                    }
                    if req.trace != 0 && obs::recorder::enabled() {
                        // reconstruct the request's async ladder from
                        // its admission instant: queue_wait until this
                        // worker started forming (clamped for arrivals
                        // mid-formation), batch_form to batch close,
                        // batch_exec to the reply
                        let end_ns = obs::recorder::now_ns();
                        let enq_ns = form_end.saturating_sub(wait.as_nanos() as u64);
                        let form_start = form_t0.max(enq_ns);
                        obs::record_span(req.trace, SpanKind::QueueWait, enq_ns, form_start, 0);
                        obs::record_span(
                            req.trace,
                            SpanKind::BatchForm,
                            form_start,
                            form_end,
                            batch.len() as u64,
                        );
                        obs::record_span(
                            req.trace,
                            SpanKind::BatchExec,
                            exec_t0,
                            end_ns,
                            score.exec_rows as u64,
                        );
                        obs::record_span(req.trace, SpanKind::Request, enq_ns, end_ns, 0);
                    }
                    send_line(
                        &req.sink,
                        &ServerMsg::Score {
                            id: req.id,
                            ce,
                            ppl: ce.exp(),
                            latency_ms,
                            trace: req.trace,
                        }
                        .encode(),
                    );
                }
            }
            Err(e) => {
                let msg = format!("{e:#}");
                log::warn!("gateway worker {}: batch failed: {msg}", cfg.index);
                shared.stats.lock().unwrap().failed += batch.len() as u64;
                for req in &batch {
                    send_line(
                        &req.sink,
                        &ServerMsg::error(Some(req.id), "exec_failed", msg.clone()).encode(),
                    );
                }
            }
        }
    }
    log::debug!("gateway worker {} drained", cfg.index);
}

/// Terminal worker startup failure: step out of the pool and let the
/// healthy workers absorb the load. Only when *no* worker is left does
/// this thread stay behind to drain the queue with `exec_failed`
/// errors, so clients are never left hanging on an unservable gateway.
fn abandon(shared: &Shared) {
    let left =
        shared.alive_workers.fetch_sub(1, std::sync::atomic::Ordering::SeqCst) - 1;
    if left > 0 {
        return;
    }
    log::error!("gateway has no healthy workers — failing queued requests");
    while let Some(req) = shared.queue.pop_blocking() {
        shared.stats.lock().unwrap().failed += 1;
        send_line(
            &req.sink,
            &ServerMsg::error(Some(req.id), "exec_failed", "no healthy workers").encode(),
        );
    }
}
