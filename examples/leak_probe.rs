//! Diagnostic/regression probe for the per-execute input-buffer leak in
//! the xla crate's C++ shim (worked around in the PJRT backend by
//! staging inputs through rust-owned PjRtBuffers + execute_b).
//!
//! PJRT-only (`required-features = ["pjrt"]` in Cargo.toml):
//!
//!     make artifacts && cargo run --release --features pjrt --example leak_probe
//!
//! Prints RSS across 2000 executions; flat memory = workaround holds.

use sonic_moe::runtime::backend::pjrt::PjrtBackend;
use sonic_moe::runtime::{artifacts_available, Runtime, Value};
use sonic_moe::util::tensor::Tensor;

fn rss_mb() -> f64 {
    let s = std::fs::read_to_string("/proc/self/status").unwrap();
    let line = s.lines().find(|l| l.starts_with("VmRSS")).unwrap();
    line.split_whitespace().nth(1).unwrap().parse::<f64>().unwrap() / 1024.0
}

fn main() {
    if !artifacts_available("artifacts") {
        eprintln!("run `make artifacts` first");
        return;
    }
    let backend = PjrtBackend::new().expect("pjrt client");
    let mut rt = Runtime::open_with("artifacts", "small", Box::new(backend)).unwrap();
    let spec = rt.manifest.artifacts["moe_layer_fwd_tc"].clone();
    let vals: Vec<Value> = spec
        .inputs
        .iter()
        .map(|ts| Value::F32(Tensor::zeros(&ts.shape)))
        .collect();
    let art = rt.artifact("moe_layer_fwd_tc").unwrap();
    let start = rss_mb();
    println!("start {start:.1} MB");
    for i in 0..2000u32 {
        let outs = art.execute(&vals).unwrap();
        drop(outs);
        if i % 500 == 0 {
            println!("iter {i}: {:.1} MB", rss_mb());
        }
    }
    let end = rss_mb();
    println!("end {end:.1} MB (grew {:.1} MB over 2000 executes)", end - start);
    assert!(end - start < 50.0, "leak regression: grew {:.1} MB", end - start);
    println!("leak_probe OK");
}
