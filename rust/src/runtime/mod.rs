//! Backend-generic artifact runtime.
//!
//! [`Runtime`] owns one model config from the manifest (written by
//! `python/compile/aot.py`, or synthesized natively for the built-in
//! configs) and compiles/executes its artifacts through a pluggable
//! [`Backend`]:
//!
//! - **native** (default): pure-rust CPU execution, hermetic — no
//!   python, HLO or external runtime anywhere on the path;
//! - **pjrt** (cargo feature `pjrt`): the AOT-HLO path through the
//!   `xla` PJRT binding.
//!
//! The manifest is the signature contract either way: positional
//! [`Value`] inputs/outputs per [`ArtifactSpec`].

pub mod backend;
pub mod kvcache;
mod manifest;

pub use backend::{default_backend, Backend, Executable, Value};
pub use manifest::{ArtifactSpec, ConfigManifest, Manifest, ModelInfo, ParamSpec, TensorSpec};

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::util::tensor::Tensor;

/// A compiled artifact plus its signature.
pub struct Artifact {
    pub name: String,
    pub spec: ArtifactSpec,
    exe: Box<dyn Executable>,
}

impl Artifact {
    /// Execute with positional inputs; returns the output tuple in
    /// manifest order. Inputs are validated against the signature.
    pub fn execute(&self, inputs: &[Value]) -> Result<Vec<Value>> {
        if inputs.len() != self.spec.inputs.len() {
            bail!(
                "artifact {}: expected {} inputs, got {}",
                self.name,
                self.spec.inputs.len(),
                inputs.len()
            );
        }
        for (v, ts) in inputs.iter().zip(&self.spec.inputs) {
            if !v.matches(ts) {
                bail!(
                    "artifact {}: input {:?} expects {} {:?}, got {} {:?}",
                    self.name,
                    ts.name,
                    ts.dtype,
                    ts.shape,
                    v.dtype(),
                    v.shape()
                );
            }
        }
        let outs = self.exe.execute(inputs)?;
        if outs.len() != self.spec.outputs.len() {
            bail!(
                "artifact {}: manifest declares {} outputs, backend returned {}",
                self.name,
                self.spec.outputs.len(),
                outs.len()
            );
        }
        Ok(outs)
    }

    /// Execute with f32 tensors only (single-dtype artifacts such as
    /// `moe_layer_fwd_*`).
    pub fn execute_tensors(&self, inputs: &[&Tensor]) -> Result<Vec<Tensor>> {
        let vals: Vec<Value> = inputs.iter().map(|&t| Value::F32(t.clone())).collect();
        self.execute(&vals)?.into_iter().map(Value::into_f32).collect()
    }
}

/// The runtime: an execution backend plus lazily compiled artifacts for
/// one model config from the manifest.
pub struct Runtime {
    pub dir: PathBuf,
    pub config_name: String,
    pub manifest: ConfigManifest,
    backend: Box<dyn Backend>,
    compiled: HashMap<String, Artifact>,
}

impl Runtime {
    /// Open `artifacts/` (or another dir) for a named config on the
    /// default backend (`SONIC_BACKEND`, native unless set).
    pub fn open(dir: &str, config_name: &str) -> Result<Runtime> {
        Self::open_with(dir, config_name, default_backend()?)
    }

    /// Open on an explicit backend.
    pub fn open_with(
        dir: &str,
        config_name: &str,
        backend: Box<dyn Backend>,
    ) -> Result<Runtime> {
        let dir = resolve_dir(dir);
        let manifest_path = dir.join("manifest.json");
        let cfg = if manifest_path.exists() {
            let manifest = Manifest::load(
                manifest_path.to_str().ok_or_else(|| anyhow!("bad path"))?,
            )?;
            manifest
                .configs
                .get(config_name)
                .with_context(|| {
                    format!(
                        "config {config_name:?} not in manifest (have: {:?})",
                        manifest.configs.keys().collect::<Vec<_>>()
                    )
                })?
                .clone()
        } else if let Some(cfg) = backend.builtin_manifest(config_name) {
            log::info!(
                "no manifest at {} — using built-in {config_name:?} config on the {} backend",
                manifest_path.display(),
                backend.name()
            );
            cfg
        } else {
            bail!(
                "no manifest at {} and the {} backend has no built-in config \
                 {config_name:?} — run `make artifacts`",
                manifest_path.display(),
                backend.name()
            );
        };
        log::info!("runtime up: backend={} config={}", backend.name(), config_name);
        Ok(Runtime {
            dir,
            config_name: config_name.to_string(),
            manifest: cfg,
            backend,
            compiled: HashMap::new(),
        })
    }

    /// Name of the execution backend in use.
    pub fn backend_name(&self) -> &'static str {
        self.backend.name()
    }

    /// Compile (once) and return an artifact by manifest name.
    pub fn artifact(&mut self, name: &str) -> Result<&Artifact> {
        if !self.compiled.contains_key(name) {
            let spec = self
                .manifest
                .artifacts
                .get(name)
                .with_context(|| format!("artifact {name:?} not in manifest"))?
                .clone();
            let exe = self
                .backend
                .compile(&self.dir, name, &spec, &self.manifest)
                .with_context(|| format!("compiling {name} on {}", self.backend.name()))?;
            self.compiled.insert(
                name.to_string(),
                Artifact { name: name.to_string(), spec, exe },
            );
        }
        Ok(&self.compiled[name])
    }

    /// Load the initial parameters: from the flat file written by
    /// aot.py, or — for built-in native configs (empty `params_file`) —
    /// deterministically initialized in rust.
    pub fn load_initial_params(&self) -> Result<Vec<Tensor>> {
        if self.manifest.params_file.is_empty() {
            return backend::native::init_params(&self.manifest);
        }
        let path = self.dir.join(&self.manifest.params_file);
        let path = path.to_str().ok_or_else(|| anyhow!("bad path"))?;
        let bytes = std::fs::read(path).with_context(|| format!("reading {path}"))?;
        if bytes.len() != self.manifest.num_params * 4 {
            bail!(
                "{path}: {} bytes but manifest declares {} f32 params",
                bytes.len(),
                self.manifest.num_params
            );
        }
        let flat: Vec<f32> = bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();
        self.manifest
            .params
            .iter()
            .map(|p| {
                let sl = &flat[p.offset..p.offset + p.size];
                Tensor::from_vec(&p.shape, sl.to_vec())
            })
            .collect()
    }

    /// Resolve a path inside the artifact dir (goldens etc.).
    pub fn path(&self, rel: &str) -> PathBuf {
        self.dir.join(rel)
    }
}

/// Resolve an artifacts dir robustly: as given if it exists, otherwise
/// (for relative paths) next to the crate — `cargo test` runs from the
/// crate dir (`rust/`) while `make artifacts` writes to the repo root.
pub fn resolve_artifacts_dir(dir: &str) -> PathBuf {
    resolve_dir(dir)
}

fn resolve_dir(dir: &str) -> PathBuf {
    let p = PathBuf::from(dir);
    if p.exists() || p.is_absolute() {
        return p;
    }
    let sibling = Path::new(env!("CARGO_MANIFEST_DIR")).join("..").join(dir);
    if sibling.exists() {
        // never silent: a deployed binary far from the build tree should
        // not pick this up unnoticed
        log::info!(
            "artifacts dir {dir:?} not found in the working directory; using {}",
            sibling.display()
        );
        return sibling;
    }
    p
}

/// True if a *real* artifacts dir exists with a manifest (used by tests
/// that need the python-exported goldens; the native backend itself
/// also works without one via the built-in configs).
pub fn artifacts_available(dir: &str) -> bool {
    resolve_dir(dir).join("manifest.json").exists()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builtin_config_opens_without_artifacts() {
        let dir = std::env::temp_dir().join("sonic_no_artifacts_here");
        let dir = dir.to_str().unwrap();
        let rt = Runtime::open_with(
            dir,
            "gran2",
            Box::new(backend::native::NativeBackend::new()),
        )
        .unwrap();
        assert_eq!(rt.backend_name(), "native");
        assert_eq!(rt.manifest.model.e, 8);
        assert!(rt.manifest.artifacts.contains_key("lm_eval"));
        let params = rt.load_initial_params().unwrap();
        let total: usize = params.iter().map(|p| p.numel()).sum();
        assert_eq!(total, rt.manifest.num_params);
    }

    #[test]
    fn unknown_builtin_config_errors() {
        let dir = std::env::temp_dir().join("sonic_no_artifacts_here");
        let err = Runtime::open_with(
            dir.to_str().unwrap(),
            "not-a-config",
            Box::new(backend::native::NativeBackend::new()),
        );
        assert!(err.is_err());
    }

    #[test]
    fn artifact_input_validation() {
        let dir = std::env::temp_dir().join("sonic_no_artifacts_here");
        let mut rt = Runtime::open_with(
            dir.to_str().unwrap(),
            "gran2",
            Box::new(backend::native::NativeBackend::new()),
        )
        .unwrap();
        let params = rt.load_initial_params().unwrap();
        let art = rt.artifact("lm_eval").unwrap();
        // wrong arity
        assert!(art.execute(&[]).is_err());
        // wrong dtype in the token slot
        let mut vals: Vec<Value> = params.into_iter().map(Value::F32).collect();
        let tok_spec = art.spec.inputs.last().unwrap().clone();
        vals.push(Value::F32(Tensor::zeros(&tok_spec.shape)));
        assert!(art.execute(&vals).is_err());
    }
}
