"""Golden generator for the rust native backend's parity tests.

Computes the LM eval/grad-step and single-MoE-layer outputs for a tiny
fixed-seed config using the **pure-jnp reference numerics** —
``kernels/ref.py`` (dense Algorithm 1 + Appendix C) composed with
``kernels/router.py`` routing and the model-level pieces of
``model.py`` — and writes them, plus the exact inputs, to
``rust/tests/golden/native/`` in the standard manifest layout.

The rust test ``native_backend_parity.rs`` then opens that directory as
an artifacts dir on the native backend and asserts CE / loss / gradient
parity. ``moe_compute`` (the Pallas kernel path) is tested against
``ref.py`` by the python suite, so agreement with ``ref.py`` means
agreement with the paper's computation.

Run from ``python/``:

    python -m compile.native_golden

Deterministic: re-running reproduces byte-identical tensors (same seeds,
same jax version caveats aside — goldens are committed, not rebuilt in
CI).
"""

from __future__ import annotations

import dataclasses
import json
import os

import jax
import jax.numpy as jnp
import numpy as np

from . import model as model_lib
from .kernels import ref, router

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "rust", "tests", "golden", "native")

CFG = model_lib.ModelConfig(
    vocab=64, d=32, n_layers=2, n_heads=2, seq_len=16, batch=2,
    n=16, E=4, K=2, m_tile=8, router="tc", aux_coeff=0.01,
)


# ---------------------------------------------------------------------------
# Pure-jnp model forward: model.py with the MoE block expressed through
# ref.py (dense formulation) + router.py — no Pallas anywhere.
# ---------------------------------------------------------------------------


def moe_block_ref(cfg: model_lib.ModelConfig, x, wr, w1, w2, method: str):
    """sonic_moe_block semantics on ref.moe_forward_dense."""
    logits = x @ wr
    scores = jax.nn.softmax(logits, axis=-1)
    if method == "tc":
        dec = router.tc_topk(scores, cfg.K)
    elif method == "tr":
        dec = router.token_rounding(scores, cfg.K, cfg.m_tile, subroutine="nr-f")
    else:
        raise ValueError(method)
    pi = jax.lax.stop_gradient(dec.pi)
    sel = scores * pi
    denom = jnp.sum(sel, axis=-1, keepdims=True)
    r = sel / jnp.maximum(denom, 1e-9)
    o = ref.moe_forward_dense(x, w1, w2, pi, r)
    t, e = scores.shape
    frac_tokens = jax.lax.stop_gradient(jnp.mean(pi, axis=0) / cfg.K)
    frac_scores = jnp.mean(scores, axis=0)
    aux = e * jnp.sum(frac_tokens * frac_scores)
    return o, aux, scores, pi


def forward_ref(cfg, params, tokens, method):
    b, s = tokens.shape
    x = params["embed"][tokens]
    aux_total = jnp.float32(0.0)
    for i in range(cfg.n_layers):
        p = f"layer{i}."
        x = x + model_lib.attention(
            cfg, model_lib.rmsnorm(x, params[p + "attn_norm"]), params, p
        )
        resid = x
        xn = model_lib.rmsnorm(x, params[p + "moe_norm"]).reshape(b * s, cfg.d)
        o, aux, _, _ = moe_block_ref(
            cfg, xn, params[p + "wr"], params[p + "w1"], params[p + "w2"], method
        )
        aux_total = aux_total + aux
        x = resid + o.reshape(b, s, cfg.d)
    x = model_lib.rmsnorm(x, params["final_norm"])
    logits = x @ params["embed"].T
    return logits, aux_total


def loss_ref(cfg, params, tokens, method):
    logits, aux = forward_ref(cfg, params, tokens, method)
    logp = jax.nn.log_softmax(logits[:, :-1].astype(jnp.float32), axis=-1)
    targets = tokens[:, 1:]
    ce = -jnp.take_along_axis(logp, targets[..., None], axis=-1).mean()
    return ce + cfg.aux_coeff * aux, ce


def grad_step_ref(cfg, params, tokens, method):
    names = list(model_lib.param_specs(cfg).keys())

    def f(flat):
        p = dict(zip(names, flat))
        loss, ce = loss_ref(cfg, p, tokens, method)
        return loss, ce

    flat = [params[n] for n in names]
    (loss, ce), grads = jax.value_and_grad(f, has_aux=True)(flat)
    return float(loss), float(ce), {n: g for n, g in zip(names, grads)}


# ---------------------------------------------------------------------------
# Margin checks: the goldens must not sit on a routing tie, or float
# noise between backends could flip a (token, expert) pair.
# ---------------------------------------------------------------------------


def check_routing_margins(cfg, params, tokens, method, min_margin=1e-4):
    """Worst routing decision margin along the forward pass: the TC
    top-K gap (k-th vs k+1-th score per token) and, for TR, the rank
    boundary gap (g_e-th vs g_e+1-th TC-preferred score per expert)."""
    b, s = tokens.shape
    x = params["embed"][tokens]
    worst = np.inf
    for i in range(cfg.n_layers):
        p = f"layer{i}."
        x = x + model_lib.attention(
            cfg, model_lib.rmsnorm(x, params[p + "attn_norm"]), params, p
        )
        xn = model_lib.rmsnorm(x, params[p + "moe_norm"]).reshape(b * s, cfg.d)
        scores = jax.nn.softmax(xn @ params[p + "wr"], axis=-1)
        srt = np.sort(np.asarray(scores), axis=-1)[:, ::-1]
        worst = min(worst, float(np.min(srt[:, cfg.K - 1] - srt[:, cfg.K])))
        if method == "tr":
            dec = router.token_rounding(scores, cfg.K, cfg.m_tile, subroutine="nr-f")
            pi_tc = np.asarray(router.tc_topk(scores, cfg.K).pi)
            s_pref = np.where(pi_tc > 0, np.asarray(scores), np.asarray(scores) - 2.0)
            g = np.asarray(dec.g)
            for j in range(cfg.E):
                col = np.sort(s_pref[:, j])[::-1]
                if 0 < g[j] < col.shape[0]:
                    worst = min(worst, float(col[g[j] - 1] - col[g[j]]))
        o, _, _, _ = moe_block_ref(
            cfg, xn, params[p + "wr"], params[p + "w1"], params[p + "w2"], method
        )
        x = x + o.reshape(b, s, cfg.d)
    assert worst > min_margin, f"routing margin too small for a stable golden: {worst}"
    return worst


def _write_bin(path, arr):
    np.ascontiguousarray(arr).tofile(path)


def _spec(name, shape, dtype="float32"):
    return {"name": name, "shape": list(shape), "dtype": dtype}


def main():
    os.makedirs(OUT_DIR, exist_ok=True)
    gold_dir = os.path.join(OUT_DIR, "golden")
    os.makedirs(gold_dir, exist_ok=True)

    cfg = CFG
    specs = model_lib.param_specs(cfg)
    names = list(specs.keys())
    params = model_lib.init_params(cfg, seed=0)

    # flat params file + layout
    offset = 0
    layout = []
    with open(os.path.join(OUT_DIR, "params_golden.bin"), "wb") as f:
        for n in names:
            a = np.asarray(params[n], np.float32)
            f.write(a.tobytes())
            layout.append(
                {"name": n, "shape": list(a.shape), "offset": offset, "size": int(a.size)}
            )
            offset += int(a.size)

    # tokens: seed 25 maximizes the routing decision margins for this
    # init (scanned over seeds 0..39), keeping the golden far from any
    # top-K / rank-boundary tie that float noise could flip
    rng = np.random.default_rng(25)
    tokens = rng.integers(0, cfg.vocab, size=(cfg.batch, cfg.seq_len)).astype(np.int32)
    _write_bin(os.path.join(gold_dir, "lm_tokens.bin"), tokens)
    jt = jnp.asarray(tokens)

    for method in ("tc", "tr"):
        margin = check_routing_margins(cfg, params, jt, method)
        print(f"[native_golden] worst {method} routing margin: {margin:.2e}")

    # LM goldens (TC and TR grad steps + eval CE)
    loss_tc, ce_tc, grads_tc = grad_step_ref(cfg, params, jt, "tc")
    loss_tr, ce_tr, grads_tr = grad_step_ref(cfg, params, jt, "tr")
    _, eval_ce = loss_ref(cfg, params, jt, "tc")
    golden_lm = {
        "tokens_file": "golden/lm_tokens.bin",
        "loss": loss_tc,
        "ce": ce_tc,
        "eval_ce": float(eval_ce),
        "grad_l1": {n: float(jnp.abs(g).sum()) for n, g in grads_tc.items()},
        "tr": {
            "loss": loss_tr,
            "ce": ce_tr,
            "grad_l1": {n: float(jnp.abs(g).sum()) for n, g in grads_tr.items()},
        },
    }
    print(f"[native_golden] tc: loss {loss_tc:.5f} ce {ce_tc:.5f}")
    print(f"[native_golden] tr: loss {loss_tr:.5f} ce {ce_tr:.5f}")

    # single-MoE-layer goldens
    mcfg = cfg.moe_cfg
    rng = np.random.default_rng(11)
    x = rng.normal(size=(mcfg.T, mcfg.d)).astype(np.float32) * 0.5
    wr = rng.normal(size=(mcfg.d, mcfg.E)).astype(np.float32) * 0.1
    w1 = rng.normal(size=(mcfg.E, mcfg.d, 2 * mcfg.n)).astype(np.float32) * (mcfg.d**-0.5)
    w2 = rng.normal(size=(mcfg.E, mcfg.n, mcfg.d)).astype(np.float32) * (mcfg.n**-0.5)
    for arr, nm in ((x, "x"), (wr, "wr"), (w1, "w1"), (w2, "w2")):
        _write_bin(os.path.join(gold_dir, f"moe_{nm}.bin"), arr)

    moe_artifacts = {}
    for tag in ("tc", "tr"):
        o, aux, _, _ = moe_block_ref(
            cfg, jnp.asarray(x), jnp.asarray(wr), jnp.asarray(w1), jnp.asarray(w2), tag
        )
        _write_bin(os.path.join(gold_dir, f"moe_o_{tag}.bin"), np.asarray(o))
        moe_artifacts[f"moe_layer_fwd_{tag}"] = {
            "file": "",
            "inputs": [
                _spec("x", (mcfg.T, mcfg.d)),
                _spec("wr", (mcfg.d, mcfg.E)),
                _spec("w1", (mcfg.E, mcfg.d, 2 * mcfg.n)),
                _spec("w2", (mcfg.E, mcfg.n, mcfg.d)),
            ],
            "outputs": [_spec("o", (mcfg.T, mcfg.d)), _spec("aux", ())],
            "golden": {
                "inputs": [
                    "golden/moe_x.bin",
                    "golden/moe_wr.bin",
                    "golden/moe_w1.bin",
                    "golden/moe_w2.bin",
                ],
                "output_o": f"golden/moe_o_{tag}.bin",
                "output_aux": float(aux),
            },
        }
        print(f"[native_golden] moe_layer {tag}: aux {float(aux):.5f}")

    # manifest
    param_inputs = [_spec(n, specs[n]) for n in names]
    grad_outputs = [_spec("loss", ()), _spec("ce", ())] + [
        _spec(f"d_{n}", specs[n]) for n in names
    ]
    artifacts = {
        "lm_eval": {
            "file": "",
            "inputs": param_inputs + [_spec("tokens", (cfg.batch, cfg.seq_len), "int32")],
            "outputs": [_spec("ce", ())],
        },
    }
    for tag in ("tc", "tr"):
        artifacts[f"lm_grad_step_{tag}"] = {
            "file": "",
            "inputs": param_inputs + [_spec("tokens", (cfg.batch, cfg.seq_len), "int32")],
            "outputs": grad_outputs,
        }
    artifacts.update(moe_artifacts)

    manifest = {
        "version": 1,
        "configs": {
            "golden": {
                "model": dataclasses.asdict(cfg),
                "params": layout,
                "params_file": "params_golden.bin",
                "num_params": offset,
                "num_active_params": model_lib.num_active_params(cfg),
                "artifacts": artifacts,
                "golden_lm": golden_lm,
            }
        },
    }
    with open(os.path.join(OUT_DIR, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"[native_golden] wrote {OUT_DIR} ({offset} params)")


if __name__ == "__main__":
    main()
