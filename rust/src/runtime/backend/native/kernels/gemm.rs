//! Cache-blocked, register-tiled GEMM core with panel packing and a
//! scoped-thread parallel driver.
//!
//! One generic kernel ([`gemm_buf`]) serves every layout the LM needs:
//! the operands are addressed through `get_a(i, l)` / `get_b(j, l)`
//! accessor closures (`i` = output row, `j` = output column, `l` =
//! reduction index), so transposition, row gathering (the fused
//! gather-GEMM of the expert kernels) and on-the-fly activation or
//! gate scaling all compile into the pack loops — the packed panels
//! are what the microkernel sees, and the microkernel is closure-free.
//!
//! ## Bitwise contract
//!
//! Every output element is produced by a **single accumulator folded in
//! ascending reduction order** — the exact chain the naive reference
//! kernels in [`super::super::linalg`] execute. Blocking only reorders
//! *which elements* are computed when, never the adds inside one
//! element, and the parallel driver shards output rows so each element
//! is still produced by exactly one thread with that same chain. The
//! result: everything that goes through this driver — the blocked
//! GEMMs and the fused expert *forward* — is bitwise identical to the
//! naive reference for **any** thread count, which is what keeps the
//! committed jax goldens, the decode cached-vs-stateless equality and
//! the padding-invariance tests true on the fast path. (The expert
//! *backward* additionally reduces per-thread `dxn` partials outside
//! this driver; see [`super::expert`] for its weaker — fixed thread
//! count — guarantee.)
//!
//! ## Blocking scheme
//!
//! B (the shared weight operand) is packed once per call into
//! panel-major `NR`-wide strips; A is packed per `MR`-row block and
//! reused across all B panels, cutting B traffic by `MR`x. The
//! reduction dimension is not split (every k this model produces keeps
//! the packed panels cache-resident), so the single-chain contract
//! above comes for free. Row counts below one register tile fall back
//! to a packed-row naive loop with the same chain — the m=1 decode
//! GEMMs take that path and skip the panel pack entirely.

// index-heavy numeric kernels: explicit loops mirror the math
#![allow(clippy::needless_range_loop)]

use std::cell::RefCell;

/// Register-tile rows (independent accumulator chains per column).
pub const MR: usize = 4;
/// Register-tile columns (vectorized lanes of one packed B strip).
pub const NR: usize = 16;

/// FLOP floor below which a GEMM call records no span: decode's m=1
/// micro-GEMMs fire thousands of times per step, and a span each would
/// wrap the flight recorder ring with noise long before anything
/// interesting is retained.
const SPAN_MIN_FLOPS: u64 = 100_000;

/// Where a GEMM's product goes.
pub(crate) enum Out<'a> {
    /// `c[i*stride + j] = prod[i][j]` (C logically zero on entry).
    Assign { c: &'a mut [f32], stride: usize },
    /// `c[i*stride + j] += prod[i][j]`, continuing each element's
    /// chain from the existing value (the gradient-accumulate layout).
    Accum { c: &'a mut [f32], stride: usize },
    /// `c[idx[i]*stride + j] += scale_i * prod[i][j]` — the fused
    /// scatter epilogue. `idx` must be strictly ascending (per-expert
    /// row lists are built that way), which is what lets the parallel
    /// driver split `c` at row boundaries. `scales: None` means 1.0.
    ScatterAdd {
        c: &'a mut [f32],
        idx: &'a [usize],
        scales: Option<&'a [f32]>,
        stride: usize,
    },
}

/// Reusable pack/work buffers (resized up, never shrunk, so a warmed
/// buffer set serves every later call alloc-free).
#[derive(Default)]
pub(crate) struct GemmBufs {
    /// Packed A block: k x MR.
    pub ap: Vec<f32>,
    /// Packed B panels: ceil(n/NR) strips of k x NR.
    pub bp: Vec<f32>,
    /// One unpacked A row (the small-m naive path).
    pub arow: Vec<f32>,
    /// One product row (the small-m naive path).
    pub orow: Vec<f32>,
}

thread_local! {
    static TLS_BUFS: RefCell<GemmBufs> = RefCell::new(GemmBufs::default());
}

/// Grow a buffer to at least `len` elements (contents unspecified —
/// packing overwrites every element the kernel later reads).
#[inline]
fn ensure_len(v: &mut Vec<f32>, len: usize) {
    if v.len() < len {
        v.resize(len, 0.0);
    }
}

/// Run `f` with the calling thread's persistent buffer set.
pub(crate) fn with_tls_bufs<R>(f: impl FnOnce(&mut GemmBufs) -> R) -> R {
    TLS_BUFS.with(|b| f(&mut b.borrow_mut()))
}

// ---------------------------------------------------------------------------
// Packing
// ---------------------------------------------------------------------------

/// Pack one MR-row block of A: `ap[l*MR + mm] = A[i0+mm, l]`, rows past
/// `mr_n` zero-padded (they feed discarded accumulator lanes).
#[inline]
fn pack_a_block<GA: Fn(usize, usize) -> f32>(
    ap: &mut [f32],
    get_a: &GA,
    i0: usize,
    mr_n: usize,
    k: usize,
) {
    for l in 0..k {
        let dst = &mut ap[l * MR..l * MR + MR];
        for (mm, d) in dst.iter_mut().enumerate() {
            *d = if mm < mr_n { get_a(i0 + mm, l) } else { 0.0 };
        }
    }
}

/// Pack all of B panel-major: strip `p` holds columns `p*NR..` as
/// `bp[p*k*NR + l*NR + nn]`, tail columns zero-padded.
fn pack_b_all<GB: Fn(usize, usize) -> f32>(bp: &mut [f32], get_b: &GB, n: usize, k: usize) {
    let panels = n.div_ceil(NR);
    for p in 0..panels {
        let j0 = p * NR;
        let nr_n = NR.min(n - j0);
        let panel = &mut bp[p * k * NR..(p + 1) * k * NR];
        for l in 0..k {
            let dst = &mut panel[l * NR..l * NR + NR];
            for (nn, d) in dst.iter_mut().enumerate() {
                *d = if nn < nr_n { get_b(j0 + nn, l) } else { 0.0 };
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Microkernel
// ---------------------------------------------------------------------------

/// MR x NR register tile: `acc[mm][nn] += ap[l][mm] * bp[l][nn]` for l
/// ascending. One accumulator per element, no reassociation — the
/// bitwise contract lives here.
#[inline]
fn microkernel(acc: &mut [[f32; NR]; MR], ap: &[f32], bp: &[f32], k: usize) {
    for l in 0..k {
        let av: &[f32] = &ap[l * MR..l * MR + MR];
        let bv: &[f32] = &bp[l * NR..l * NR + NR];
        for (mm, acc_row) in acc.iter_mut().enumerate() {
            let a = av[mm];
            for (nn, c) in acc_row.iter_mut().enumerate() {
                *c += a * bv[nn];
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Row-range driver (one thread's share)
// ---------------------------------------------------------------------------

/// A thread's mutable view of the output: dense views cover GEMM rows
/// `i0..i1` (the slice starts at row `i0`); scatter views cover base
/// rows `[base_lo, ..)` of the scatter target.
enum RangeOut<'a> {
    Dense { c: &'a mut [f32], stride: usize, accum: bool },
    Scatter {
        c: &'a mut [f32],
        base_lo: usize,
        idx: &'a [usize],
        scales: Option<&'a [f32]>,
        stride: usize,
    },
}

/// Blocked kernel over output rows `i0..i1` with pre-packed B.
#[allow(clippy::too_many_arguments)]
fn gebp_rows<GA: Fn(usize, usize) -> f32>(
    get_a: &GA,
    bp: &[f32],
    ap: &mut [f32],
    i0: usize,
    i1: usize,
    k: usize,
    n: usize,
    out: &mut RangeOut,
) {
    let panels = n.div_ceil(NR);
    let mut i = i0;
    while i < i1 {
        let mr_n = MR.min(i1 - i);
        pack_a_block(ap, get_a, i, mr_n, k);
        for p in 0..panels {
            let j0 = p * NR;
            let nr_n = NR.min(n - j0);
            let mut acc = [[0f32; NR]; MR];
            if let RangeOut::Dense { c, stride, accum: true } = out {
                for (mm, acc_row) in acc.iter_mut().enumerate().take(mr_n) {
                    let crow = &c[(i - i0 + mm) * *stride + j0..];
                    acc_row[..nr_n].copy_from_slice(&crow[..nr_n]);
                }
            }
            microkernel(&mut acc, ap, &bp[p * k * NR..(p + 1) * k * NR], k);
            match out {
                RangeOut::Dense { c, stride, .. } => {
                    for (mm, acc_row) in acc.iter().enumerate().take(mr_n) {
                        let crow = &mut c[(i - i0 + mm) * *stride + j0..];
                        crow[..nr_n].copy_from_slice(&acc_row[..nr_n]);
                    }
                }
                RangeOut::Scatter { c, base_lo, idx, scales, stride } => {
                    for (mm, acc_row) in acc.iter().enumerate().take(mr_n) {
                        let row = i + mm;
                        let s = scales.map_or(1.0, |sc| sc[row]);
                        let crow = &mut c[(idx[row] - *base_lo) * *stride + j0..];
                        for (nn, cv) in crow.iter_mut().enumerate().take(nr_n) {
                            *cv += s * acc_row[nn];
                        }
                    }
                }
            }
        }
        i += MR;
    }
}

// ---------------------------------------------------------------------------
// Entry points
// ---------------------------------------------------------------------------

/// The generic blocked GEMM: `threads` > 1 shards output rows across
/// scoped threads (bitwise identical to `threads == 1`). Callers pick
/// `threads` with [`super::plan_threads`].
#[allow(clippy::too_many_arguments)]
pub(crate) fn gemm_buf<GA, GB>(
    m: usize,
    n: usize,
    k: usize,
    get_a: GA,
    get_b: GB,
    out: Out,
    bufs: &mut GemmBufs,
    threads: usize,
) where
    GA: Fn(usize, usize) -> f32 + Sync,
    GB: Fn(usize, usize) -> f32 + Sync,
{
    // thread-track span, recorded on every return path below; the
    // guard never allocates, so the arena stays warm-steady-state
    let flops = 2 * (m as u64) * (n as u64) * (k as u64);
    let mut span = crate::obs::SpanGuard::thread(crate::obs::SpanKind::Gemm);
    if flops >= SPAN_MIN_FLOPS {
        span.detail(flops);
    } else {
        span.cancel();
    }
    if m == 0 || n == 0 {
        return;
    }
    if k == 0 {
        // empty reduction: assign zeroes, leave accumulate targets alone
        if let Out::Assign { c, stride } = out {
            for i in 0..m {
                for v in &mut c[i * stride..i * stride + n] {
                    *v = 0.0;
                }
            }
        }
        return;
    }
    if m < MR {
        gemm_small(m, n, k, &get_a, &get_b, out, bufs);
        return;
    }
    ensure_len(&mut bufs.bp, n.div_ceil(NR) * k * NR);
    pack_b_all(&mut bufs.bp, &get_b, n, k);
    let bp: &[f32] = &bufs.bp;

    let blocks = m.div_ceil(MR);
    let threads = threads.clamp(1, blocks);
    if threads == 1 {
        ensure_len(&mut bufs.ap, k * MR);
        let mut range = full_range_out(out);
        gebp_rows(&get_a, bp, &mut bufs.ap, 0, m, k, n, &mut range);
        return;
    }

    // shard rows in MR-aligned contiguous chunks; each thread owns a
    // disjoint output region, so no cross-thread reduction exists and
    // the result is bitwise independent of the thread count
    let mut aps: Vec<Vec<f32>> = (0..threads).map(|_| super::scratch::take(k * MR)).collect();
    let shards = split_out(out, m, blocks, threads);
    std::thread::scope(|s| {
        for ((i0, i1, mut range), ap) in shards.into_iter().zip(aps.iter_mut()) {
            let get_a = &get_a;
            s.spawn(move || gebp_rows(get_a, bp, ap, i0, i1, k, n, &mut range));
        }
    });
    for ap in aps {
        super::scratch::put(ap);
    }
}

/// Packed-row naive path for m below one register tile (the m=1 decode
/// GEMMs): each A row is materialized once into `arow` — so gather and
/// activation accessors are still evaluated once per element — then the
/// product row accumulates in axpy order (l outer, j inner: B streams
/// row-major). Per element that is the same ascending-l
/// single-accumulator chain as the blocked path.
fn gemm_small<GA, GB>(
    m: usize,
    n: usize,
    k: usize,
    get_a: &GA,
    get_b: &GB,
    out: Out,
    bufs: &mut GemmBufs,
) where
    GA: Fn(usize, usize) -> f32,
    GB: Fn(usize, usize) -> f32,
{
    ensure_len(&mut bufs.arow, k);
    ensure_len(&mut bufs.orow, n);
    let arow = &mut bufs.arow[..k];
    let orow = &mut bufs.orow[..n];
    let mut out = out;
    for i in 0..m {
        for (l, a) in arow.iter_mut().enumerate() {
            *a = get_a(i, l);
        }
        // seed each element's chain: existing C for Accum, zero else
        match &out {
            Out::Accum { c, stride } => {
                orow.copy_from_slice(&c[i * stride..i * stride + n]);
            }
            _ => orow.fill(0.0),
        }
        for (l, &a) in arow.iter().enumerate() {
            for (j, o) in orow.iter_mut().enumerate() {
                *o += a * get_b(j, l);
            }
        }
        match &mut out {
            Out::Assign { c, stride } | Out::Accum { c, stride } => {
                c[i * *stride..i * *stride + n].copy_from_slice(orow);
            }
            Out::ScatterAdd { c, idx, scales, stride } => {
                let s = scales.map_or(1.0, |sc| sc[i]);
                let crow = &mut c[idx[i] * *stride..idx[i] * *stride + n];
                for (cv, &o) in crow.iter_mut().zip(orow.iter()) {
                    *cv += s * o;
                }
            }
        }
    }
}

/// The whole output as one range (the single-thread path).
fn full_range_out(out: Out) -> RangeOut {
    match out {
        Out::Assign { c, stride } => RangeOut::Dense { c, stride, accum: false },
        Out::Accum { c, stride } => RangeOut::Dense { c, stride, accum: true },
        Out::ScatterAdd { c, idx, scales, stride } => {
            RangeOut::Scatter { c, base_lo: 0, idx, scales, stride }
        }
    }
}

/// Split the output into up to `threads` disjoint row-range views.
fn split_out(out: Out, m: usize, blocks: usize, threads: usize) -> Vec<(usize, usize, RangeOut)> {
    // MR-aligned contiguous row ranges with near-equal block counts
    let mut bounds = Vec::with_capacity(threads + 1);
    for t in 0..=threads {
        bounds.push(((blocks * t / threads) * MR).min(m));
    }
    let mut shards: Vec<(usize, usize, RangeOut)> = Vec::with_capacity(threads);
    match out {
        Out::Assign { c, stride } => split_dense(c, stride, false, &bounds, &mut shards),
        Out::Accum { c, stride } => split_dense(c, stride, true, &bounds, &mut shards),
        Out::ScatterAdd { c, idx, scales, stride } => {
            // thread t's scatter targets live in base rows
            // [idx[i0], idx[i1]): strictly ascending idx keeps the
            // chunks disjoint and contiguous
            let total_rows = c.len() / stride;
            let mut rest = c;
            let mut lo = 0usize;
            for t in 0..bounds.len() - 1 {
                let (i0, i1) = (bounds[t], bounds[t + 1]);
                if i0 >= i1 {
                    continue;
                }
                let hi = if i1 < m { idx[i1] } else { total_rows };
                let (chunk, r) = rest.split_at_mut((hi - lo) * stride);
                rest = r;
                shards.push((
                    i0,
                    i1,
                    RangeOut::Scatter { c: chunk, base_lo: lo, idx, scales, stride },
                ));
                lo = hi;
            }
        }
    }
    shards
}

/// Dense row-range split at the same bounds.
fn split_dense<'a>(
    c: &'a mut [f32],
    stride: usize,
    accum: bool,
    bounds: &[usize],
    shards: &mut Vec<(usize, usize, RangeOut<'a>)>,
) {
    let mut rest = c;
    let mut off = 0usize;
    for t in 0..bounds.len() - 1 {
        let (i0, i1) = (bounds[t], bounds[t + 1]);
        if i0 >= i1 {
            continue;
        }
        debug_assert_eq!(off, i0);
        let (chunk, r) = rest.split_at_mut((i1 - i0) * stride);
        rest = r;
        off = i1;
        shards.push((i0, i1, RangeOut::Dense { c: chunk, stride, accum }));
    }
}
