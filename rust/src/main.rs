//! `sonic-moe` CLI — the L3 leader entrypoint.
//!
//! Subcommands:
//!   train       run the training loop on a config
//!   eval        validation loss of a checkpoint (or initial params)
//!   serve       batched scoring service over the LM
//!   gateway     concurrent TCP scoring gateway (line-JSON protocol)
//!   front       replica-balanced front tier over N gateway replicas
//!   generate    autoregressive decode through the gateway
//!   loadgen     drive an in-process gateway (open/closed loop or trace replay)
//!   trace       synthesize a named workload trace to JSONL
//!   simulate    GPU performance model for one MoE shape
//!   memory      activation-memory report (Figure 10 style)
//!   routing     routing statistics / token-rounding demo on synth scores
//!   info        manifest + artifact inventory
//!
//! All model subcommands run on the execution backend selected by
//! `--backend` / `SONIC_BACKEND` (native pure-rust CPU by default; PJRT
//! when built with `--features pjrt`). With no artifacts directory the
//! native backend uses the built-in configs, so `sonic-moe train` works
//! out of the box.

use anyhow::{bail, Result};

use sonic_moe::coordinator::serve::Server;
use sonic_moe::coordinator::{Trainer, TrainerConfig};
use sonic_moe::front::{Front, FrontConfig, FrontFaultPlan, ReplicaSpec};
use sonic_moe::gateway::loadgen::{self, LoadgenConfig, TraceRunConfig};
use sonic_moe::gateway::trace::{Trace, TraceSpec};
use sonic_moe::gateway::{
    BatchPolicy, ClientMsg, FaultPlan, Gateway, GatewayConfig, ServerMsg, SlotPolicy,
};
use sonic_moe::data::{Corpus, CorpusConfig};
use sonic_moe::memory;
use sonic_moe::routing::{self, RoundingRule};
use sonic_moe::simulator::{self, configs::MoeShape, Method, Pass};
use sonic_moe::util::cli::Cli;
use sonic_moe::util::dtype::Dtype;
use sonic_moe::util::prng::Prng;

fn main() {
    // structured logger: level from SONIC_LOG (or RUST_LOG), plain
    // lines until a subcommand parses --log-json
    sonic_moe::obs::log::init();
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn run() -> Result<()> {
    let mut argv: Vec<String> = std::env::args().skip(1).collect();
    let sub = if argv.is_empty() { "help".to_string() } else { argv.remove(0) };
    match sub.as_str() {
        "train" => cmd_train(argv),
        "eval" => cmd_eval(argv),
        "serve" => cmd_serve(argv),
        "gateway" => cmd_gateway(argv),
        "front" => cmd_front(argv),
        "loadgen" => cmd_loadgen(argv),
        "trace" => cmd_trace(argv),
        "generate" => cmd_generate(argv),
        "simulate" => cmd_simulate(argv),
        "memory" => cmd_memory(argv),
        "routing" => cmd_routing(argv),
        "info" => cmd_info(argv),
        _ => {
            println!(
                "sonic-moe — SonicMoE reproduction CLI\n\n\
                 subcommands:\n\
                 \x20 train     train the MoE LM end to end\n\
                 \x20 eval      validation loss of a checkpoint\n\
                 \x20 serve     batched LM scoring service\n\
                 \x20 gateway   concurrent TCP scoring gateway (line-JSON protocol)\n\
                 \x20 front     replica-balanced front tier over N gateway replicas\n\
                 \x20 generate  autoregressive decode through the gateway (streamed tokens)\n\
                 \x20 loadgen   drive an in-process gateway with open/closed-loop or trace load\n\
                 \x20 trace     synthesize a named workload trace to JSONL\n\
                 \x20 simulate  GPU performance model for one MoE shape\n\
                 \x20 memory    activation-memory report\n\
                 \x20 routing   token-rounding statistics on synthetic scores\n\
                 \x20 info      manifest inventory\n\n\
                 run `sonic-moe <subcommand> --help` for options"
            );
            Ok(())
        }
    }
}

/// Shared `--threads` option: 0 defers to `SONIC_NATIVE_THREADS` /
/// `available_parallelism`, anything else pins the kernel thread count.
fn threads_cli(cli: Cli) -> Cli {
    cli.opt("threads", "0", "native kernel threads (0 = SONIC_NATIVE_THREADS or all cores)")
}

fn apply_threads(a: &sonic_moe::util::cli::Args) -> Result<()> {
    let n = a.get_usize("threads")?;
    if n > 0 {
        sonic_moe::runtime::backend::native::kernels::set_threads(n);
    }
    Ok(())
}

fn cmd_train(argv: Vec<String>) -> Result<()> {
    let cli = threads_cli(Cli::new("sonic-moe train", "train the MoE LM end to end"))
        .opt("artifacts", "artifacts", "artifacts directory")
        .opt("config", "small", "AOT config name (small|medium)")
        .opt("router", "tc", "routing method artifact (tc|tr)")
        .opt("steps", "100", "training steps")
        .opt("warmup", "10", "LR warmup steps")
        .opt("lr", "6e-4", "peak learning rate")
        .opt("weight-decay", "0.01", "AdamW weight decay")
        .opt("clip", "1.0", "gradient clipping norm")
        .opt("workers", "1", "data-parallel ranks")
        .opt("seed", "0", "data seed")
        .opt("log-every", "10", "console log interval")
        .opt("eval-every", "0", "validation interval (0 = off)")
        .opt("csv", "", "CSV metrics path (empty = off)")
        .opt("checkpoint", "", "checkpoint dir (empty = off)")
        .opt("backend", "", "execution backend (native|pjrt; default native)");
    let a = cli.parse_from(argv)?;
    apply_threads(&a)?;
    let cfg = TrainerConfig {
        artifacts_dir: a.get("artifacts").to_string(),
        config_name: a.get("config").to_string(),
        router: a.get("router").to_string(),
        steps: a.get_u64("steps")?,
        warmup: a.get_u64("warmup")?,
        lr: a.get_f64("lr")? as f32,
        weight_decay: a.get_f64("weight-decay")? as f32,
        clip: a.get_f64("clip")? as f32,
        workers: a.get_usize("workers")?,
        seed: a.get_u64("seed")?,
        log_every: a.get_u64("log-every")?,
        eval_every: a.get_u64("eval-every")?,
        csv_path: non_empty(a.get("csv")),
        checkpoint_dir: non_empty(a.get("checkpoint")),
        backend: a.get("backend").to_string(),
    };
    let mut t = Trainer::new(cfg)?;
    let ema = t.run()?;
    println!("final smoothed CE: {ema:.4}");
    Ok(())
}

fn cmd_eval(argv: Vec<String>) -> Result<()> {
    let cli = threads_cli(Cli::new("sonic-moe eval", "validation CE of a checkpoint"))
        .opt("artifacts", "artifacts", "artifacts directory")
        .opt("config", "small", "AOT config name")
        .opt("checkpoint", "", "checkpoint dir (empty = initial params)")
        .opt("batches", "8", "validation microbatches")
        .opt("backend", "", "execution backend (native|pjrt; default native)");
    let a = cli.parse_from(argv)?;
    apply_threads(&a)?;
    let mut t = Trainer::new(TrainerConfig {
        artifacts_dir: a.get("artifacts").to_string(),
        config_name: a.get("config").to_string(),
        steps: 0,
        backend: a.get("backend").to_string(),
        ..Default::default()
    })?;
    if let Some(dir) = non_empty(a.get("checkpoint")) {
        let step = t.restore(&dir)?;
        println!("restored checkpoint at step {step}");
    }
    let ce = t.evaluate(a.get_usize("batches")?)?;
    println!("val_ce {ce:.4}  (ppl {:.2})", ce.exp());
    Ok(())
}

fn cmd_serve(argv: Vec<String>) -> Result<()> {
    let cli = threads_cli(Cli::new("sonic-moe serve", "batched LM scoring service"))
        .opt("artifacts", "artifacts", "artifacts directory")
        .opt("config", "small", "config name")
        .opt("checkpoint", "", "trained checkpoint dir (empty = initial params)")
        .opt("rows", "32", "synthetic scoring requests to serve")
        .opt("seed", "42", "request stream seed")
        .opt("backend", "", "execution backend (native|pjrt; default native)");
    let a = cli.parse_from(argv)?;
    apply_threads(&a)?;
    let mut server =
        Server::new_with_backend(a.get("artifacts"), a.get("config"), a.get("backend"))?;
    if let Some(dir) = non_empty(a.get("checkpoint")) {
        server.load_checkpoint(&dir)?;
        println!("loaded checkpoint from {dir}");
    }
    println!(
        "server up: backend={} config={} batch={} seq={}",
        server.backend_name(),
        a.get("config"),
        server.rows,
        server.seq
    );

    // synthetic request stream: mostly in-distribution corpus tokens,
    // every 4th request out-of-distribution junk
    let n = a.get_usize("rows")?;
    let seed = a.get_u64("seed")?;
    let vocab = server.vocab();
    let mut corpus = Corpus::new(CorpusConfig { vocab, ..Default::default() }, seed);
    let seq = server.seq;
    for id in 0..n as u64 {
        let toks: Vec<i32> = if id % 4 == 3 {
            (0..seq).map(|j| ((id as usize * 131 + j * 7) % vocab) as i32).collect()
        } else {
            corpus.next_batch(1, seq)
        };
        server.submit(id, toks);
    }
    let responses = server.drain()?;

    let mut tbl = sonic_moe::bench::Table::new(
        "scoring responses (first 8)",
        &["request", "ce", "ppl", "latency ms"],
    );
    for r in responses.iter().take(8) {
        tbl.row(&[
            r.id.to_string(),
            format!("{:.4}", r.ce),
            format!("{:.2}", r.ppl),
            format!("{:.2}", r.latency_s * 1e3),
        ]);
    }
    tbl.print();

    let s = server.stats;
    let mut t = sonic_moe::bench::Table::new("service report", &["metric", "value"]);
    t.row(&["requests served".into(), s.requests.to_string()]);
    t.row(&["batches executed".into(), s.batches.to_string()]);
    t.row(&["batch padding".into(), format!("{:.1}%", 100.0 * s.padding_frac())]);
    t.row(&["mean request latency".into(), format!("{:.1} ms", s.mean_latency_s() * 1e3)]);
    t.row(&["throughput".into(), format!("{:.0} tokens/s", s.tokens_per_s())]);
    t.print();
    Ok(())
}

/// Shared observability options (used by `gateway`, `loadgen` and
/// `front`).
fn obs_cli(cli: Cli) -> Cli {
    cli.opt("trace-sample-rate", "1", "fraction of requests minted a trace id (0 = tracing off)")
        .opt("trace-out", "", "default Chrome-trace path for trace_dump requests (empty = none)")
        .opt("log-json", "0", "emit one JSON object per log line instead of plain text (1 = on)")
}

/// Apply the parsed observability options (process-global).
fn apply_obs(a: &sonic_moe::util::cli::Args) -> Result<()> {
    sonic_moe::obs::set_sample_rate(a.get_f64("trace-sample-rate")?);
    sonic_moe::obs::log::set_json(a.get_u64("log-json")? != 0);
    Ok(())
}

/// Shared gateway options (used by `gateway` and `loadgen`).
fn gateway_cli(cli: Cli) -> Cli {
    obs_cli(threads_cli(cli))
        .opt("artifacts", "artifacts", "artifacts directory")
        .opt("config", "small", "config name")
        .opt("checkpoint", "", "trained checkpoint dir (empty = initial params)")
        .opt("workers", "2", "worker threads (one runtime each)")
        .opt("queue-cap", "64", "admission queue capacity (full = shed)")
        .opt("policy", "tile", "batching policy (immediate|deadline|tile)")
        .opt("max-wait-ms", "20", "batch hold deadline for deadline/tile policies")
        .opt("m-tile", "0", "row tile for executed batch shapes (0 = model batch)")
        .opt("worker-delay-ms", "0", "simulated extra model latency per batch")
        .opt("decode-slots", "0", "KV slots for generation (0 = largest exported batch)")
        .opt("gen-max-new", "16", "cap on generated tokens per generate request")
        .opt("slot-policy", "tile", "decode slot quantization (tile|full)")
        .opt("draft", "", "draft config for speculative decoding (empty = spec off)")
        .opt("draft-checkpoint", "", "trained draft checkpoint dir (empty = initial params)")
        .opt("spec-k-cap", "8", "cap on drafted tokens per verify step")
        .opt("dtype", "f32", "weight/KV storage precision (f32|bf16)")
        .opt("resident-bytes", "0", "expert-weight RAM budget per core (0 = no tiering)")
        .opt("spill-dir", "", "directory for expert spill files (empty = OS temp dir)")
        .opt("capture-trace", "", "record live arrivals into a JSONL workload trace (empty = off)")
        .opt("fault-kill-worker-after", "0", "chaos: kill worker 0 after N batches (0 = off)")
        .opt("fault-fail-decode-after", "0", "chaos: fail one decode step after N steps (0 = off)")
        .opt("backend", "", "execution backend (native|pjrt; default native)")
}

fn gateway_config(a: &sonic_moe::util::cli::Args, addr: &str) -> Result<GatewayConfig> {
    apply_threads(a)?;
    apply_obs(a)?;
    let m_tile = a.get_usize("m-tile")?;
    let max_wait = std::time::Duration::from_millis(a.get_u64("max-wait-ms")?);
    // a tile of 0 is resolved by the gateway (model batch) once it
    // knows the config
    let policy = BatchPolicy::parse(a.get("policy"), m_tile, max_wait)?;
    Ok(GatewayConfig {
        artifacts_dir: a.get("artifacts").to_string(),
        config: a.get("config").to_string(),
        backend: a.get("backend").to_string(),
        addr: addr.to_string(),
        workers: a.get_usize("workers")?,
        queue_cap: a.get_usize("queue-cap")?,
        policy,
        m_tile,
        checkpoint: non_empty(a.get("checkpoint")),
        worker_delay_ms: a.get_u64("worker-delay-ms")?,
        decode_slots: a.get_usize("decode-slots")?,
        gen_max_new: a.get_usize("gen-max-new")?,
        slot_policy: SlotPolicy::parse(a.get("slot-policy"))?,
        draft_config: non_empty(a.get("draft")),
        draft_checkpoint: non_empty(a.get("draft-checkpoint")),
        spec_k_cap: a.get_usize("spec-k-cap")?,
        dtype: Dtype::parse(a.get("dtype"))?,
        resident_bytes: a.get_usize("resident-bytes")?,
        spill_dir: non_empty(a.get("spill-dir")),
        capture_trace: non_empty(a.get("capture-trace")),
        trace_out: non_empty(a.get("trace-out")),
        fault: FaultPlan {
            kill_worker_after_batches: a.get_usize("fault-kill-worker-after")?,
            fail_decode_after_steps: a.get_usize("fault-fail-decode-after")?,
        },
    })
}

fn cmd_gateway(argv: Vec<String>) -> Result<()> {
    let cli = gateway_cli(Cli::new(
        "sonic-moe gateway",
        "concurrent TCP scoring gateway (line-delimited JSON protocol)",
    ))
    .opt("addr", "127.0.0.1:7433", "bind address (port 0 = ephemeral)");
    let a = cli.parse_from(argv)?;
    let cfg = gateway_config(&a, a.get("addr"))?;
    let policy = cfg.policy;
    let gw = Gateway::start(cfg)?;
    println!(
        "gateway listening on {} (config={} policy={}) — send {{\"type\":\"shutdown\"}} to stop",
        gw.local_addr(),
        a.get("config"),
        policy.name()
    );
    let stats = gw.join(); // blocks until a client sends shutdown
    let mut t = sonic_moe::bench::Table::new("gateway final stats", &["metric", "value"]);
    t.row(&["requests admitted".into(), stats.requests.to_string()]);
    t.row(&["responses".into(), stats.responses.to_string()]);
    t.row(&["batches".into(), stats.batches.to_string()]);
    t.row(&["shed (queue full)".into(), stats.shed.to_string()]);
    t.row(&["padding".into(), format!("{:.1}%", 100.0 * stats.padding_frac())]);
    let pcts = match stats.latency_percentiles() {
        Some(p) => format!("{:.1} / {:.1} / {:.1} ms", p.p50, p.p95, p.p99),
        None => "n/a (no responses)".to_string(),
    };
    t.row(&["p50 / p95 / p99".into(), pcts]);
    t.row(&["throughput".into(), format!("{:.0} tokens/s", stats.tokens_per_s())]);
    t.row(&["generate done".into(), stats.gen_done.to_string()]);
    t.row(&["generated tokens".into(), stats.gen_tokens.to_string()]);
    t.row(&["decode steps".into(), stats.decode_steps.to_string()]);
    t.row(&[
        "decode padding".into(),
        format!("{:.1}%", 100.0 * stats.decode_padding_frac()),
    ]);
    if stats.spec_rounds > 0 {
        t.row(&[
            "speculation".into(),
            format!(
                "{} rounds, accept {:.0}%, {:.2} tok/step",
                stats.spec_rounds,
                100.0 * stats.acceptance_rate(),
                stats.accepted_per_step()
            ),
        ]);
    }
    t.print();
    Ok(())
}

fn cmd_front(argv: Vec<String>) -> Result<()> {
    let cli = obs_cli(Cli::new(
        "sonic-moe front",
        "replica-balanced front tier over N gateway replicas",
    ))
    .opt("addr", "127.0.0.1:7434", "bind address (port 0 = ephemeral)")
    .multi("replica", "gateway replica as host:port[=model] (repeat per replica)")
    .opt("probe-interval-ms", "200", "health-probe period per replica")
    .opt("probe-timeout-ms", "1000", "probe/connect timeout (slower counts as failed)")
    .opt("fail-threshold", "3", "consecutive failures that trip a replica's breaker")
    .opt("retry-attempts", "3", "total score relay attempts per request (1 = no retry)")
    .opt("retry-base-ms", "10", "base of the jittered exponential retry backoff")
    .opt("request-deadline-ms", "10000", "per-request deadline / stream inactivity bound")
    .opt("pool-cap", "4", "idle replica connections pooled per replica")
    .opt("fault-kill-replica-after", "0", "chaos: kill replica 0 after N healthy probes (0 = off)")
    .opt("fault-stall-replica-after", "0", "chaos: stall one probe of replica 0 after N probes (0 = off)");
    let a = cli.parse_from(argv)?;
    apply_obs(&a)?;
    let replicas = a
        .get_all("replica")
        .iter()
        .map(|s| ReplicaSpec::parse(s))
        .collect::<Result<Vec<_>>>()?;
    let cfg = FrontConfig {
        addr: a.get("addr").to_string(),
        replicas,
        probe_interval_ms: a.get_u64("probe-interval-ms")?,
        probe_timeout_ms: a.get_u64("probe-timeout-ms")?,
        fail_threshold: a.get_u64("fail-threshold")? as u32,
        retry_attempts: a.get_usize("retry-attempts")?,
        retry_base_ms: a.get_u64("retry-base-ms")?,
        request_deadline_ms: a.get_u64("request-deadline-ms")?,
        pool_cap: a.get_usize("pool-cap")?,
        fault: FrontFaultPlan {
            kill_replica_after_probes: a.get_usize("fault-kill-replica-after")?,
            stall_replica_after_probes: a.get_usize("fault-stall-replica-after")?,
        },
        trace_out: non_empty(a.get("trace-out")),
    };
    let n = cfg.replicas.len();
    let front = Front::start(cfg)?;
    println!(
        "front listening on {} fronting {n} replica(s) — send {{\"type\":\"shutdown\"}} to stop",
        front.local_addr()
    );
    let stats = front.join(); // blocks until a client sends shutdown
    let mut t = sonic_moe::bench::Table::new("front final stats", &["metric", "value"]);
    t.row(&["score relayed ok".into(), stats.relayed_ok.to_string()]);
    t.row(&["generate streams done".into(), stats.gen_done.to_string()]);
    t.row(&["retries / failovers".into(), format!("{} / {}", stats.retries, stats.failovers)]);
    let fo = match stats.failover_percentiles() {
        Some(p) => format!("{:.1} / {:.1} ms", p.p50, p.p99),
        None => "n/a (no failovers)".to_string(),
    };
    t.row(&["failover p50 / p99".into(), fo]);
    t.row(&["shed (no healthy replica)".into(), stats.shed_no_healthy.to_string()]);
    t.row(&["relay attempts exhausted".into(), stats.exhausted.to_string()]);
    t.row(&["streams lost to replicas".into(), stats.replica_lost_streams.to_string()]);
    t.row(&[
        "breaker trips / recoveries".into(),
        format!("{} / {}", stats.breaker_trips, stats.breaker_recoveries),
    ]);
    t.row(&[
        "probes (failed)".into(),
        format!("{} ({})", stats.probes, stats.probe_failures),
    ]);
    t.print();
    Ok(())
}

fn cmd_loadgen(argv: Vec<String>) -> Result<()> {
    let cli = gateway_cli(Cli::new(
        "sonic-moe loadgen",
        "drive an in-process gateway with open/closed-loop or trace load",
    ))
    .opt("requests", "64", "total score requests")
    .opt("clients", "3", "concurrent client connections")
    .opt("rate", "0", "aggregate offered requests/s (0 = closed loop)")
    .opt("seq-hint", "0", "synthetic sequence length center (0 = model seq)")
    .opt("gen-tokens", "0", "generate this many tokens per request instead of scoring")
    .opt("spec-k", "0", "speculative decode with this many drafted tokens (needs --draft)")
    .opt("trace", "", "replay a JSONL workload trace instead of synthetic load")
    .opt("trace-speed", "1", "time-compression factor for trace replay (2 = twice the rps)")
    .opt("seed", "0", "request stream seed (trace mode: 0 = the trace's own seed)")
    .opt("front", "0", "drive N gateway replicas behind an in-process front tier (0 = direct)");
    let a = cli.parse_from(argv)?;
    if a.get_usize("spec-k")? > 0 && a.get("draft").is_empty() {
        bail!("--spec-k needs a draft model: pass --draft (e.g. --draft small-draft)");
    }
    let cfg = gateway_config(&a, "127.0.0.1:0")?;
    if !a.get("trace").is_empty() {
        let trace = Trace::load(std::path::Path::new(a.get("trace")))?;
        let speed = a.get_f64("trace-speed")?;
        if !speed.is_finite() || speed <= 0.0 {
            bail!("--trace-speed must be > 0");
        }
        let rc = TraceRunConfig {
            speed,
            seed: a.get_u64("seed")?,
            front_replicas: a.get_usize("front")?,
        };
        let report = loadgen::run_trace(cfg, &trace, rc)?;
        let mut t = sonic_moe::bench::Table::new("trace replay report", &["metric", "value"]);
        t.row(&["trace / policy".into(), format!("{} / {}", report.trace, report.policy)]);
        t.row(&[
            "offered / achieved".into(),
            format!("{:.1} / {:.1} req/s", report.offered_rps, report.achieved_rps),
        ]);
        t.row(&[
            "sent / ok / shed / failed".into(),
            format!(
                "{} / {} / {} / {}",
                report.sent, report.ok, report.shed, report.failed
            ),
        ]);
        t.row(&["shed rate".into(), format!("{:.1}%", 100.0 * report.shed_rate)]);
        t.row(&[
            "latency p50 / p95 / p99".into(),
            format!("{:.1} / {:.1} / {:.1} ms", report.p50_ms, report.p95_ms, report.p99_ms),
        ]);
        if report.gen_tokens > 0 {
            t.row(&[
                "ttft p50 / p99".into(),
                format!("{:.1} / {:.1} ms", report.ttft_p50_ms, report.ttft_p99_ms),
            ]);
            t.row(&["generated tokens".into(), report.gen_tokens.to_string()]);
        }
        t.row(&["throughput".into(), format!("{:.0} tokens/s", report.tokens_per_s)]);
        t.print();
        println!("{}", report.to_json());
        return Ok(());
    }
    let lg = LoadgenConfig {
        requests: a.get_usize("requests")?,
        clients: a.get_usize("clients")?,
        rate: a.get_f64("rate")?,
        // 0 resolves to the served model's seq inside run_inprocess
        seq_hint: a.get_usize("seq-hint")?,
        seed: a.get_u64("seed")?,
        gen_tokens: a.get_usize("gen-tokens")?,
        spec_k: a.get_usize("spec-k")?,
        front_replicas: a.get_usize("front")?,
    };
    let report = loadgen::run_inprocess(cfg, lg)?;
    let mut t = sonic_moe::bench::Table::new("loadgen report", &["metric", "value"]);
    t.row(&["policy / mode".into(), format!("{} / {}", report.policy, report.mode)]);
    t.row(&["sent / ok / shed".into(), format!("{} / {} / {}", report.sent, report.ok, report.shed)]);
    t.row(&["achieved".into(), format!("{:.1} req/s", report.achieved_rps)]);
    t.row(&[
        "latency p50 / p95 / p99".into(),
        format!("{:.1} / {:.1} / {:.1} ms", report.p50_ms, report.p95_ms, report.p99_ms),
    ]);
    t.row(&["padding".into(), format!("{:.1}%", 100.0 * report.padding_frac)]);
    t.row(&["throughput".into(), format!("{:.0} tokens/s", report.tokens_per_s)]);
    if report.mode == "generate" {
        t.row(&[
            "ttft p50 / p99".into(),
            format!("{:.1} / {:.1} ms", report.ttft_p50_ms, report.ttft_p99_ms),
        ]);
        t.row(&["generated tokens".into(), report.gen_tokens.to_string()]);
        t.row(&[
            "decode padding".into(),
            format!("{:.1}%", 100.0 * report.decode_padding_frac),
        ]);
        t.row(&[
            "decode throughput".into(),
            format!("{:.0} tokens/s", report.decode_tokens_per_s),
        ]);
        if report.spec_k > 0 {
            t.row(&[
                format!("speculation (k={})", report.spec_k),
                format!(
                    "accept {:.0}%, {:.2} tok/step (p50 {:.2}, p99 {:.2})",
                    100.0 * report.accept_rate,
                    report.accepted_per_step,
                    report.tokens_per_step_p50,
                    report.tokens_per_step_p99
                ),
            ]);
        }
    }
    t.print();
    println!("{}", report.to_json());
    Ok(())
}

fn cmd_trace(argv: Vec<String>) -> Result<()> {
    let cli = Cli::new("sonic-moe trace", "synthesize a named workload trace to JSONL")
        .opt("name", "bursty_mixed", "builtin spec (steady_score|bursty_mixed|heavy_tail_score)")
        .opt("events", "0", "override the spec's event count (0 = spec default)")
        .opt("seed", "0", "override the spec's seed (0 = spec default)")
        .opt("out", "", "output path (empty = stdout)");
    let a = cli.parse_from(argv)?;
    let mut spec = TraceSpec::builtin(a.get("name"))?;
    if a.get_usize("events")? > 0 {
        spec.events = a.get_usize("events")?;
    }
    if a.get_u64("seed")? > 0 {
        spec.seed = a.get_u64("seed")?;
    }
    let trace = spec.synthesize()?;
    let jsonl = trace.to_jsonl();
    if a.get("out").is_empty() {
        print!("{jsonl}");
    } else {
        std::fs::write(a.get("out"), &jsonl)?;
        eprintln!(
            "wrote {} events ({:.1} s span, {:.1} req/s offered) to {}",
            trace.events.len(),
            trace.duration_ms() / 1e3,
            trace.offered_rps(),
            a.get("out")
        );
    }
    Ok(())
}

fn cmd_generate(argv: Vec<String>) -> Result<()> {
    let cli = gateway_cli(Cli::new(
        "sonic-moe generate",
        "autoregressive decode through the gateway (streamed token frames)",
    ))
    .opt("addr", "", "address of a running gateway (empty = in-process)")
    .opt("prompt", "", "comma-separated prompt token ids (empty = synthetic)")
    .opt("prompt-len", "8", "synthetic prompt length")
    .opt("max-new", "16", "tokens to generate per request")
    .opt("requests", "2", "concurrent generate requests")
    .opt("spec-k", "0", "speculative decode with this many drafted tokens (needs --draft)")
    .opt("temperature", "0", "sampling temperature (0 = greedy)")
    .opt("top-k", "0", "sample from the top-k logits (0 = off)")
    .opt("top-p", "0", "nucleus sampling mass (0 = off)")
    .opt("seed", "0", "synthetic prompt seed");
    let a = cli.parse_from(argv)?;
    let requests = a.get_usize("requests")?.max(1);
    let max_new = a.get_usize("max-new")?.max(1);
    let opts = sonic_moe::gateway::protocol::GenOpts {
        spec_k: a.get_usize("spec-k")?,
        draft: String::new(),
        temperature: a.get_f64("temperature")?,
        top_k: a.get_usize("top-k")?,
        top_p: a.get_f64("top-p")?,
    };
    if opts.is_spec() && opts.is_sampling() {
        bail!("--spec-k needs greedy decoding; drop --temperature");
    }
    if opts.temperature == 0.0 && (opts.top_k != 0 || opts.top_p != 0.0) {
        bail!("--top-k/--top-p need --temperature > 0 (temperature 0 is greedy)");
    }
    if opts.is_spec() && a.get("draft").is_empty() && a.get("addr").is_empty() {
        bail!("--spec-k needs a draft model: pass --draft (e.g. --draft small-draft)");
    }

    // in-process by default (hermetic); --addr targets a live gateway
    let gw = if a.get("addr").is_empty() {
        let mut cfg = gateway_config(&a, "127.0.0.1:0")?;
        // the local gateway should honor the requested budget and k
        cfg.gen_max_new = cfg.gen_max_new.max(max_new);
        cfg.spec_k_cap = cfg.spec_k_cap.max(opts.spec_k);
        Some(Gateway::start(cfg)?)
    } else {
        None
    };
    let addr: std::net::SocketAddr = match &gw {
        Some(g) => g.local_addr(),
        None => a.get("addr").parse().map_err(|e| anyhow::anyhow!("bad --addr: {e}"))?,
    };

    // prompts: explicit csv applies to every request; otherwise synthetic
    let explicit: Option<Vec<i32>> = if a.get("prompt").is_empty() {
        None
    } else {
        Some(
            a.get("prompt")
                .split(',')
                .map(|s| s.trim().parse::<i32>().map_err(|e| anyhow::anyhow!("bad token: {e}")))
                .collect::<Result<Vec<i32>>>()?,
        )
    };
    let mut rng = Prng::new(a.get_u64("seed")?);
    let prompt_len = a.get_usize("prompt-len")?.max(1);

    use std::io::{BufRead, BufReader, Write};
    let mut stream = std::net::TcpStream::connect(addr)?;
    stream.set_nodelay(true).ok();
    stream.set_read_timeout(Some(std::time::Duration::from_secs(120)))?;
    let mut reader = BufReader::new(stream.try_clone()?);
    for id in 0..requests as u64 {
        let prompt = match &explicit {
            Some(p) => p.clone(),
            None => (0..prompt_len).map(|_| rng.below(1 << 15) as i32).collect(),
        };
        println!("request {id}: prompt {prompt:?} -> up to {max_new} tokens");
        let line =
            ClientMsg::Generate { id, tokens: prompt, max_new, opts: opts.clone() }.encode();
        stream.write_all(line.as_bytes())?;
        stream.write_all(b"\n")?;
        stream.flush()?;
    }
    // frames interleave across requests on this one connection —
    // that interleaving *is* continuous batching made visible
    let mut done = 0usize;
    while done < requests {
        let mut line = String::new();
        let n = reader.read_line(&mut line)?;
        if n == 0 {
            bail!("gateway closed the connection with {done}/{requests} streams finished");
        }
        match ServerMsg::parse(&line)? {
            ServerMsg::Token { id, token, index } => {
                println!("  id {id} token[{index}] = {token}");
            }
            ServerMsg::Done {
                id,
                tokens,
                prompt_len,
                ttft_ms,
                latency_ms,
                rounds,
                proposed,
                accepted,
                ..
            } => {
                done += 1;
                println!(
                    "request {id} done: {} tokens (prompt {prompt_len}) in {latency_ms:.1} ms \
                     (ttft {ttft_ms:.1} ms): {tokens:?}",
                    tokens.len()
                );
                if rounds > 0 {
                    let rate = if proposed == 0 {
                        0.0
                    } else {
                        100.0 * accepted as f64 / proposed as f64
                    };
                    // each counted round emits accepted-prefix + 1 bonus
                    println!(
                        "  speculation: {rounds} verify rounds, {accepted}/{proposed} drafts \
                         accepted ({rate:.0}%), {:.2} tokens/step",
                        (accepted + rounds) as f64 / rounds as f64
                    );
                }
            }
            ServerMsg::Error { id, code, message, .. } => {
                done += 1;
                println!("request {id:?} failed: {code}: {message}");
            }
            other => bail!("unexpected frame {other:?}"),
        }
    }
    if let Some(gw) = gw {
        match loadgen::control_request(addr, &ClientMsg::Shutdown)? {
            ServerMsg::Ok { .. } => {}
            other => bail!("unexpected shutdown reply {other:?}"),
        }
        let stats = gw.join();
        println!(
            "gateway drained: {} streams, {} generated tokens, decode padding {:.1}%",
            stats.gen_done,
            stats.gen_tokens,
            100.0 * stats.decode_padding_frac()
        );
    }
    Ok(())
}

fn cmd_simulate(argv: Vec<String>) -> Result<()> {
    let cli = Cli::new("sonic-moe simulate", "GPU perf model for one MoE shape")
        .opt("t", "24576", "tokens per microbatch")
        .opt("d", "1536", "embedding dim")
        .opt("n", "256", "expert intermediate dim")
        .opt("e", "128", "total experts")
        .opt("k", "8", "activated experts")
        .opt("gpu", "h100", "h100|b300");
    let a = cli.parse_from(argv)?;
    let s = MoeShape::new(
        a.get_usize("t")?,
        a.get_usize("d")?,
        a.get_usize("n")?,
        a.get_usize("e")?,
        a.get_usize("k")?,
    );
    let hw = match a.get("gpu") {
        "h100" => simulator::H100,
        "b300" => simulator::B300,
        g => bail!("unknown gpu {g:?}"),
    };
    println!(
        "shape T={} d={} n={} E={} K={}  G={:.2}  rho={:.3}  on {}",
        s.t, s.d, s.n, s.e, s.k, s.granularity(), s.activation_ratio(), hw.name
    );
    let mut tbl = sonic_moe::bench::Table::new(
        "fwd / bwd model TFLOPS",
        &["method", "fwd TF/s", "bwd TF/s", "fwd ms", "bwd ms"],
    );
    for m in Method::MAIN {
        let f = simulator::evaluate_uniform(m, &s, Pass::Forward, &hw);
        let b = simulator::evaluate_uniform(m, &s, Pass::Backward, &hw);
        tbl.row(&[
            m.name().to_string(),
            format!("{:.0}", f.model_tflops),
            format!("{:.0}", b.model_tflops),
            format!("{:.2}", f.time_s * 1e3),
            format!("{:.2}", b.time_s * 1e3),
        ]);
    }
    tbl.print();
    Ok(())
}

fn cmd_memory(argv: Vec<String>) -> Result<()> {
    let cli = Cli::new("sonic-moe memory", "activation memory per layer")
        .opt("t", "24576", "tokens")
        .opt("d", "1536", "embedding dim")
        .opt("n", "256", "expert intermediate dim")
        .opt("e", "128", "total experts")
        .opt("k", "8", "activated experts");
    let a = cli.parse_from(argv)?;
    let s = MoeShape::new(
        a.get_usize("t")?,
        a.get_usize("d")?,
        a.get_usize("n")?,
        a.get_usize("e")?,
        a.get_usize("k")?,
    );
    let mut tbl = sonic_moe::bench::Table::new(
        "activation memory per MoE layer",
        &["method", "cached GiB", "peak GiB"],
    );
    for m in memory::Method::ALL {
        if !m.supports(&s) {
            tbl.row(&[m.name().to_string(), "n/a".into(), "n/a".into()]);
            continue;
        }
        tbl.row(&[
            m.name().to_string(),
            format!("{:.3}", memory::gib(memory::cached_activation_bytes(m, &s))),
            format!("{:.3}", memory::gib(memory::peak_activation_bytes(m, &s))),
        ]);
    }
    tbl.print();
    Ok(())
}

fn cmd_routing(argv: Vec<String>) -> Result<()> {
    let cli = Cli::new("sonic-moe routing", "token-rounding statistics")
        .opt("t", "16384", "tokens")
        .opt("e", "128", "experts")
        .opt("k", "8", "top-K")
        .opt("m-tile", "128", "GEMM tile size")
        .opt("skew", "0.5", "expert popularity skew")
        .opt("seed", "0", "rng seed");
    let a = cli.parse_from(argv)?;
    let (t, e, k) = (a.get_usize("t")?, a.get_usize("e")?, a.get_usize("k")?);
    let m_tile = a.get_usize("m-tile")?;
    let mut rng = Prng::new(a.get_u64("seed")?);
    let scores = routing::synth_scores(&mut rng, t, e, a.get_f64("skew")?);
    let tc = routing::tc_topk(&scores, t, e, k);
    let mut tbl = sonic_moe::bench::Table::new(
        "routing methods on one microbatch",
        &["method", "routed pairs", "padding rows", "waste %"],
    );
    let waste = |g: &routing::Decision| {
        100.0 * g.padding_rows(m_tile) as f64
            / (g.routed_pairs() + g.padding_rows(m_tile)) as f64
    };
    tbl.row(&[
        "TC top-K".into(),
        tc.routed_pairs().to_string(),
        tc.padding_rows(m_tile).to_string(),
        format!("{:.2}", waste(&tc)),
    ]);
    for rule in RoundingRule::ALL {
        let d = routing::token_rounding(&scores, t, e, k, m_tile, rule, &mut rng);
        tbl.row(&[
            format!("TR ({})", rule.name()),
            d.routed_pairs().to_string(),
            d.padding_rows(m_tile).to_string(),
            format!("{:.2}", waste(&d)),
        ]);
    }
    tbl.print();
    Ok(())
}

fn cmd_info(argv: Vec<String>) -> Result<()> {
    let cli = Cli::new("sonic-moe info", "manifest inventory")
        .opt("artifacts", "artifacts", "artifacts directory");
    let a = cli.parse_from(argv)?;
    let dir = a.get("artifacts");
    let print_cfg = |name: &str, cfg: &sonic_moe::runtime::ConfigManifest| {
        println!(
            "config {name}: vocab={} d={} layers={} E={} K={} n={}  ({} params, {} active)",
            cfg.model.vocab, cfg.model.d, cfg.model.n_layers, cfg.model.e, cfg.model.k,
            cfg.model.n, cfg.num_params, cfg.num_active_params
        );
        for (an, aspec) in &cfg.artifacts {
            let file = if aspec.file.is_empty() { "<native>" } else { &aspec.file };
            println!(
                "  artifact {an}: {file} ({} in, {} out)",
                aspec.inputs.len(),
                aspec.outputs.len()
            );
        }
    };
    if !sonic_moe::runtime::artifacts_available(dir) {
        println!(
            "no manifest in {dir:?} — built-in native configs (run `make artifacts` \
             for the AOT export):"
        );
        for name in sonic_moe::runtime::backend::native::BUILTIN_CONFIGS {
            let cfg = sonic_moe::runtime::backend::native::builtin_manifest(name)
                .expect("BUILTIN_CONFIGS entry must resolve in builtin_cfg");
            print_cfg(name, &cfg);
        }
        return Ok(());
    }
    let path = sonic_moe::runtime::resolve_artifacts_dir(dir).join("manifest.json");
    let m = sonic_moe::runtime::Manifest::load(path.to_str().expect("utf-8 path"))?;
    for (name, cfg) in &m.configs {
        print_cfg(name, cfg);
    }
    Ok(())
}

fn non_empty(s: &str) -> Option<String> {
    if s.is_empty() { None } else { Some(s.to_string()) }
}
