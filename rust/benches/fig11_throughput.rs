//! Bench: regenerate Figure 11 via the GPU performance simulator and time
//! the evaluation hot path. See DESIGN.md per-experiment index.

use sonic_moe::bench::{figures, Bencher};

fn main() {
    for t in figures::fig11() {
        t.print();
    }
    let mut b = Bencher::new("simulator/fig11_throughput");
    b.iter(|| figures::fig11());
    println!("{}", b.report());
}
