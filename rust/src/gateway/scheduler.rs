//! Continuous-batching decode scheduler: the gateway's generation
//! worker.
//!
//! One thread owns a [`SpecCore`] (target parameters + incremental KV
//! cache, plus an optional draft model for speculative decoding) and
//! loops admit → draft → step → emit:
//!
//! - **admit**: pop `generate` requests from the gen queue into free KV
//!   slots mid-flight (vLLM-style slot reuse — new sequences join while
//!   others are mid-generation), prefill their prompt (speculative
//!   sequences also prefill a paired draft slot), and stream the first
//!   `token` frame;
//! - **draft**: each speculative sequence proposes up to its `k` tokens
//!   on the cheap draft model;
//! - **step**: advance every live sequence in one packed decode step on
//!   the target — one row per plain sequence, `k + 1` verify rows per
//!   speculative sequence. The *executed* row count is the combined
//!   live-row count quantized to a tile multiple via [`round_target`]
//!   (Algorithm 4's round-up applied to decode batch fill), so
//!   speculative verify shapes and plain decode fill the same
//!   tile-quantized shapes and per-step padding stays the minimal
//!   `exec_rows - live`;
//! - **emit**: plain sequences sample one token per step (greedy or the
//!   request's seeded temperature/top-k/top-p [`Sampler`]); speculative
//!   sequences emit their accepted prefix plus the target's bonus token
//!   and roll both caches back past the rejected suffix. When a
//!   sequence reaches its budget (or its KV slot fills), write the
//!   terminal `done` frame — with per-request acceptance stats — and
//!   release its slot(s).
//!
//! Shutdown semantics: the gen queue closes, in-flight sequences run to
//! completion (their budget is capped, so the drain is bounded), then
//! the worker exits.

use std::sync::Arc;
use std::time::Instant;

use crate::coordinator::sampling::{Sampler, SamplerCfg};
use crate::memory::residency::ResidencySpec;
use crate::obs::{self, SpanKind};
use crate::routing::{round_target, RoundingRule};
use crate::spec::{SpecCore, SpecSeq};
use crate::util::dtype::Dtype;
use crate::util::prng::Prng;

use super::protocol::ServerMsg;
use super::{send_line, GenReq, Shared};

/// How the scheduler sizes the executed decode shape each step.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SlotPolicy {
    /// Always execute at least the full slot count (the naive baseline:
    /// maximum per-step padding, the comparator in the decode bench).
    Full,
    /// Quantize the live-row count up to the next tile multiple (the
    /// serving analogue of the paper's token rounding).
    TileQuantized,
}

impl SlotPolicy {
    /// Parse a `--slot-policy` CLI value (`tile` | `full`).
    pub fn parse(name: &str) -> anyhow::Result<SlotPolicy> {
        Ok(match name {
            "full" => SlotPolicy::Full,
            "tile" | "tile-quantized" => SlotPolicy::TileQuantized,
            p => anyhow::bail!("unknown slot policy {p:?} (tile|full)"),
        })
    }

    /// Policy name as reported on `stats` and bench records.
    pub fn name(&self) -> &'static str {
        match self {
            SlotPolicy::Full => "full",
            SlotPolicy::TileQuantized => "tile",
        }
    }
}

/// Executed decode rows for `live` rows: the smallest tile multiple
/// holding every live row, capped at the slot capacity (speculative
/// verify rows can exceed the slot count; the executed shape then
/// tracks the live count exactly). Shared with the decode bench and
/// the round-target edge-case tests (live 0, tile 1, rounding past
/// capacity).
pub fn quantize_rows(live: usize, m_tile: usize, cap: usize) -> usize {
    if live == 0 {
        return 0;
    }
    // Up is deterministic; the rng is never consulted
    let mut rng = Prng::new(0);
    round_target(live, m_tile, RoundingRule::Up, &mut rng).clamp(live, cap.max(live))
}

/// Per-worker construction parameters (the gateway config minus the
/// shared state).
pub struct DecodeWorkerCfg {
    pub artifacts_dir: String,
    pub config: String,
    pub backend: String,
    pub checkpoint: Option<String>,
    /// Draft config for speculative decoding (None = spec refused).
    pub draft_config: Option<String>,
    pub draft_checkpoint: Option<String>,
    /// KV slots (max concurrent sequences).
    pub slots: usize,
    /// Cap on per-request generated tokens (bounds the drain).
    pub max_new_cap: usize,
    /// Cap on per-request drafted tokens per verify step.
    pub spec_k_cap: usize,
    /// Row tile quantizing executed decode shapes.
    pub m_tile: usize,
    pub policy: SlotPolicy,
    /// Storage precision for weights and KV cache (target + draft).
    pub dtype: Dtype,
    /// Tiered expert residency for the target core (the draft stays
    /// dense; it is small and on the latency-critical propose loop).
    pub residency: Option<ResidencySpec>,
    /// Chaos-drill fault injection: after this many successful decode
    /// steps, fail one step as if the backend errored (0 = off; fires
    /// once). From [`FaultPlan::fail_decode_after_steps`](super::FaultPlan).
    pub fail_after_steps: usize,
}

/// One in-flight sequence: a KV slot plus the way back to its client.
struct ActiveSeq {
    id: u64,
    slot: usize,
    sink: super::Sink,
    enqueued: Instant,
    /// Sampled trace id (0 = untraced); echoed on the `done` frame.
    trace: u64,
    ttft_ms: f64,
    prompt_len: usize,
    generated: Vec<i32>,
    max_new: usize,
    /// Next input token (the previously generated one).
    last: i32,
    /// Per-request sampler (greedy unless the request set temperature).
    sampler: Sampler,
    /// Speculative state (draft slot + proposal bookkeeping); `None`
    /// for plain sequences.
    spec: Option<SpecSeq>,
}

impl ActiveSeq {
    fn remaining(&self) -> usize {
        self.max_new.saturating_sub(self.generated.len())
    }
}

/// Decode worker thread body.
pub fn run(cfg: DecodeWorkerCfg, shared: Arc<Shared>) {
    let open = || match &cfg.residency {
        Some(spec) => SpecCore::new_with_residency(
            &cfg.artifacts_dir,
            &cfg.config,
            cfg.draft_config.as_deref(),
            &cfg.backend,
            cfg.slots,
            0,
            cfg.dtype,
            spec,
        ),
        None => SpecCore::new_with_dtype(
            &cfg.artifacts_dir,
            &cfg.config,
            cfg.draft_config.as_deref(),
            &cfg.backend,
            cfg.slots,
            0,
            cfg.dtype,
        ),
    };
    let mut core = match open() {
        Ok(c) => c,
        Err(e) => {
            log::error!("gateway decode worker failed to open core: {e:#}");
            drain_with_errors(&shared, &format!("decode path unavailable: {e:#}"));
            return;
        }
    };
    // publish weight bytes and KV capacity once the cores are open
    // (they only change on construction); the *live* KV gauge moves
    // with every slot transition, see publish_kv below
    {
        let (w, kv_capacity) = core.resident_bytes();
        shared.weight_bytes.store(w, std::sync::atomic::Ordering::Relaxed);
        shared.kv_capacity_bytes.store(kv_capacity, std::sync::atomic::Ordering::Relaxed);
    }
    publish_kv(&core, &shared);
    if let Some(dir) = &cfg.checkpoint {
        if let Err(e) = core.load_checkpoint(dir) {
            log::error!("gateway decode worker failed checkpoint load: {e:#}");
            drain_with_errors(&shared, "decode checkpoint load failed");
            return;
        }
    }
    if let Some(dir) = &cfg.draft_checkpoint {
        if let Err(e) = core.load_draft_checkpoint(dir) {
            log::error!("gateway decode worker failed draft checkpoint load: {e:#}");
            drain_with_errors(&shared, "draft checkpoint load failed");
            return;
        }
    }
    let mut active: Vec<ActiveSeq> = Vec::new();
    let mut local_gen = 0u64;
    let mut steps_done = 0usize;
    let mut fault_fired = false;
    // a reload-paused drain in progress: (start ns, sequences at start)
    let mut drain_since: Option<(u64, usize)> = None;
    loop {
        if active.is_empty() {
            // a reload-paused drain just finished: close its span
            if let Some((t0_ns, n)) = drain_since.take() {
                obs::record_span(0, SpanKind::Drain, t0_ns, obs::recorder::now_ns(), n as u64);
            }
            // idle: a pending checkpoint swap applies against the empty
            // KV cache — once before blocking (a swap that was waiting
            // on the in-flight drain) and again after waking (a swap
            // acknowledged while blocked), so no sequence admitted
            // after the ack ever runs on stale parameters
            apply_pending_reload(&mut core, &shared, &mut local_gen);
            // block for work; `None` means closed + drained (exit)
            match shared.gen_queue.pop_blocking() {
                Some(req) => {
                    apply_pending_reload(&mut core, &shared, &mut local_gen);
                    admit(&mut core, &shared, &mut active, req, &cfg);
                }
                None => break,
            }
        }
        // a reload that arrives mid-flight pauses admissions instead:
        // in-flight sequences drain (their budget is capped, so this is
        // bounded), then the idle branch above applies the swap — a
        // parameter swap must never corrupt a live prefix, but
        // sustained traffic must not defer it forever either
        let reload_pending = shared.reload.lock().unwrap().gen != local_gen;
        if obs::recorder::enabled() && reload_pending && !active.is_empty() && drain_since.is_none()
        {
            drain_since = Some((obs::recorder::now_ns(), active.len()));
        }
        // fill remaining slots from the backlog without blocking
        while !reload_pending && active.len() < core.target().slots() {
            match shared.gen_queue.try_pop() {
                Some(req) => admit(&mut core, &shared, &mut active, req, &cfg),
                None => break,
            }
        }
        // retire sequences whose budget (or KV slot) is exhausted
        // before stepping — a 1-token request finishes at prefill
        retire_finished(&mut core, &shared, &mut active);
        if active.is_empty() {
            continue;
        }

        // scripted step failure (chaos drill): take the same fail_all
        // path a real backend error would, exactly once. Streams end
        // with `exec_failed` after a contiguous prefix; the worker
        // keeps serving whatever arrives next.
        if cfg.fail_after_steps > 0 && steps_done >= cfg.fail_after_steps && !fault_fired {
            fault_fired = true;
            log::warn!("gateway decode worker: injected step failure (chaos drill)");
            shared.stats.lock().unwrap().injected_decode_faults += 1;
            fail_all(&mut core, &shared, &mut active, "injected step failure (chaos drill)");
            continue;
        }

        // the step clock starts before drafting: draft proposals are
        // part of what a speculative token costs, so decode_busy_s —
        // and the decode_tokens_per_s the bench gate watches — must
        // include them, not just the target's verify pass
        let t0 = Instant::now();
        // draft phase: speculative sequences propose on the cheap model
        // (a failure degrades that sequence to a plain step — the
        // target path never depends on the draft)
        for seq in active.iter_mut() {
            let remaining = seq.remaining();
            if let Some(st) = seq.spec.as_mut() {
                let mut span = obs::SpanGuard::request(seq.trace, SpanKind::SpecPropose);
                if let Err(e) = core.draft_propose(st, remaining) {
                    log::warn!("gateway decode worker: draft failed ({e:#}); plain step");
                    st.pending.clear();
                }
                span.detail(st.pending.len() as u64);
            }
        }

        // pack the step: one row per plain sequence, 1 + k_eff verify
        // rows per speculative sequence, all in one executed shape
        let mut rows: Vec<(usize, i32)> = Vec::new();
        let mut spans: Vec<(usize, usize)> = Vec::with_capacity(active.len());
        for seq in &active {
            let start = rows.len();
            match &seq.spec {
                Some(st) => rows.extend(core.verify_rows(seq.slot, st)),
                None => rows.push((seq.slot, seq.last)),
            }
            spans.push((start, rows.len()));
        }
        let live = rows.len();
        let exec_rows = match cfg.policy {
            SlotPolicy::Full => core.target().slots().max(live),
            SlotPolicy::TileQuantized => {
                let slots = core.target().slots();
                // plain decode never exceeds the slot count, and the
                // naive-baseline cap keeps quantization <= Full there;
                // speculative verify rows can push live past it, and
                // those shapes round up to the next tile multiple
                // uncapped (Algorithm 4 has no baseline to honor)
                let cap = if live > slots { usize::MAX } else { slots };
                quantize_rows(live, cfg.m_tile, cap)
            }
        };
        // the padding rows really execute (dummy compute, discarded):
        // the slot policies differ in measured work, not bookkeeping
        let mut step_span = obs::SpanGuard::thread(SpanKind::DecodeStep);
        step_span.detail(((live as u64) << 32) | (exec_rows - live) as u64);
        let step_result = core.target_mut().decode_step_padded(&rows, exec_rows);
        drop(step_span);
        match step_result {
            Ok(logits) => {
                steps_done += 1;
                let dt = t0.elapsed().as_secs_f64();
                let vocab = core.target().vocab;
                let mut emitted_total = 0usize;
                let mut spec_records: Vec<(usize, usize, usize)> = Vec::new();
                let mut fatal: Option<anyhow::Error> = None;
                for (seq, &(s0, s1)) in active.iter_mut().zip(&spans) {
                    let span = &logits[s0 * vocab..s1 * vocab];
                    let remaining = seq.remaining();
                    let emitted: Vec<i32> = match seq.spec.as_mut() {
                        Some(st) => {
                            let mut vspan =
                                obs::SpanGuard::request(seq.trace, SpanKind::SpecVerify);
                            match core.accept(seq.slot, st, span, remaining) {
                                Ok(out) => {
                                    vspan.detail(
                                        ((out.proposed as u64) << 32) | out.accepted as u64,
                                    );
                                    if out.proposed > 0 {
                                        spec_records.push((
                                            out.proposed,
                                            out.accepted,
                                            out.emitted.len(),
                                        ));
                                    }
                                    out.emitted
                                }
                                Err(e) => {
                                    vspan.cancel();
                                    fatal = Some(e);
                                    break;
                                }
                            }
                        }
                        None => vec![seq.sampler.pick(span)],
                    };
                    for &t in &emitted {
                        seq.generated.push(t);
                        send_line(
                            &seq.sink,
                            &ServerMsg::Token {
                                id: seq.id,
                                token: t,
                                index: seq.generated.len() - 1,
                            }
                            .encode(),
                        );
                    }
                    seq.last = *emitted.last().expect("a step emits at least one token");
                    emitted_total += emitted.len();
                }
                // steady-state decode is allocation-free: the logits
                // buffer goes back to this worker's scratch arena
                core.target().recycle_logits(logits);
                {
                    let mut st = shared.stats.lock().unwrap();
                    st.record_decode_step(live, exec_rows, emitted_total, dt);
                    for (proposed, accepted, emitted) in spec_records {
                        st.record_spec_round(proposed, accepted, emitted);
                    }
                }
                if let Some(e) = fatal {
                    fail_all(&mut core, &shared, &mut active, &format!("{e:#}"));
                } else {
                    retire_finished(&mut core, &shared, &mut active);
                }
            }
            Err(e) => {
                let msg = format!("{e:#}");
                log::warn!("gateway decode worker: step failed: {msg}");
                fail_all(&mut core, &shared, &mut active, &msg);
            }
        }
    }
    log::debug!("gateway decode worker drained");
}

/// Republish the live KV-bytes gauge. Called on every slot transition
/// (admit, step, rollback, retire, failure) rather than at stats-poll
/// time, so a `metrics` scrape between polls reads the current
/// committed bytes instead of a stale snapshot.
fn publish_kv(core: &SpecCore, shared: &Shared) {
    shared.kv_bytes.store(core.live_kv_bytes(), std::sync::atomic::Ordering::Relaxed);
}

/// Apply a pending checkpoint hot-swap (call only with no sequence in
/// flight: the swap resets the KV cache).
fn apply_pending_reload(core: &mut SpecCore, shared: &Shared, local_gen: &mut u64) {
    let pending = {
        let r = shared.reload.lock().unwrap();
        if r.gen != *local_gen { Some((r.gen, r.dir.clone())) } else { None }
    };
    if let Some((gen, dir)) = pending {
        match core.load_checkpoint(&dir) {
            Ok(()) => {
                shared.stats.lock().unwrap().reloads += 1;
                log::info!("gateway decode worker: reloaded {dir}");
            }
            Err(e) => log::warn!("gateway decode worker: reload failed: {e:#}"),
        }
        *local_gen = gen;
    }
}

/// Admit one request: validate its options, clamp its budget, truncate
/// the prompt to leave room for generation, prefill a fresh slot (and
/// a paired draft slot for speculative requests), and stream the first
/// token.
fn admit(
    core: &mut SpecCore,
    shared: &Shared,
    active: &mut Vec<ActiveSeq>,
    req: GenReq,
    cfg: &DecodeWorkerCfg,
) {
    // option validation before any slot is claimed
    if req.opts.is_spec() {
        let refuse = |msg: String| {
            shared.stats.lock().unwrap().gen_failed += 1;
            send_line(&req.sink, &ServerMsg::error(Some(req.id), "bad_request", msg).encode());
        };
        match core.draft_name() {
            None => {
                return refuse(
                    "speculation unavailable: no draft model loaded \
                     (start the gateway with --draft)"
                        .to_string(),
                );
            }
            Some(loaded) => {
                if !req.opts.draft.is_empty() && req.opts.draft != loaded {
                    return refuse(format!(
                        "requested draft {:?} but the gateway serves {loaded:?}",
                        req.opts.draft
                    ));
                }
            }
        }
        if req.opts.is_sampling() {
            return refuse(
                "speculative decode is greedy-only (acceptance is exact against argmax)"
                    .to_string(),
            );
        }
    }
    let max_new = if req.max_new == 0 {
        cfg.max_new_cap
    } else {
        req.max_new.min(cfg.max_new_cap)
    };
    // tokens flow through raw: the native decode path clamps them with
    // the same `clamp_token` rule as the stateless `lm_decode_step`
    // artifact, so gateway streams and the artifact stay token-for-token
    // identical even for out-of-range prompt ids
    let mut prompt = req.prompt;
    if prompt.is_empty() {
        prompt.push(0);
    }
    // leave the generation budget inside the KV slot
    let keep = core.target().max_seq.saturating_sub(max_new).max(1);
    prompt.truncate(keep);
    let slot = match core.target_mut().alloc_slot() {
        Some(s) => s,
        None => {
            // admission is gated on free slots; reaching here means a
            // bookkeeping bug, fail the request rather than wedge
            shared.stats.lock().unwrap().gen_failed += 1;
            send_line(
                &req.sink,
                &ServerMsg::error(Some(req.id), "exec_failed", "no free decode slots").encode(),
            );
            return;
        }
    };
    // gen_queue_wait ends where prefill begins: admission is the
    // moment this worker picked the request up
    let t0 = Instant::now();
    let prefill_t0 = obs::recorder::now_ns();
    if req.trace != 0 && obs::recorder::enabled() {
        let wait_ns = t0.saturating_duration_since(req.enqueued).as_nanos() as u64;
        obs::record_span(
            req.trace,
            SpanKind::GenQueueWait,
            prefill_t0.saturating_sub(wait_ns),
            prefill_t0,
            0,
        );
    }
    match core.target_mut().prefill(slot, &prompt) {
        Ok(logits) => {
            let mut sampler = Sampler::new(
                SamplerCfg {
                    temperature: req.opts.temperature as f32,
                    top_k: req.opts.top_k,
                    top_p: req.opts.top_p as f32,
                },
                req.id,
            );
            let first = sampler.pick(&logits);
            core.target().recycle_logits(logits);
            // pair a draft slot and replay the prompt into the draft
            // cache; on any failure fall back to plain decode rather
            // than failing the request (the draft is an accelerator,
            // never a dependency)
            let spec = if req.opts.is_spec() {
                let k = req.opts.spec_k.min(cfg.spec_k_cap.max(1));
                match core.alloc_draft_slot() {
                    Some(ds) => match core.prefill_draft(ds, &prompt) {
                        Ok(()) => Some(SpecSeq::new(ds, k, &prompt, first)),
                        Err(e) => {
                            log::warn!("draft prefill failed ({e:#}); serving plain decode");
                            core.release_draft(ds);
                            None
                        }
                    },
                    None => {
                        log::warn!("no free draft slot; serving plain decode");
                        None
                    }
                }
            } else {
                None
            };
            if obs::recorder::enabled() {
                // thread-track prefill (kernel spans nest inside) plus
                // the request's async copy when sampled
                let end_ns = obs::recorder::now_ns();
                obs::record_span(0, SpanKind::Prefill, prefill_t0, end_ns, prompt.len() as u64);
                if req.trace != 0 {
                    obs::record_span(
                        req.trace,
                        SpanKind::Prefill,
                        prefill_t0,
                        end_ns,
                        prompt.len() as u64,
                    );
                }
            }
            let ttft_ms = req.enqueued.elapsed().as_secs_f64() * 1e3;
            shared
                .stats
                .lock()
                .unwrap()
                .record_prefill(prompt.len(), t0.elapsed().as_secs_f64(), ttft_ms);
            send_line(
                &req.sink,
                &ServerMsg::Token { id: req.id, token: first, index: 0 }.encode(),
            );
            active.push(ActiveSeq {
                id: req.id,
                slot,
                sink: req.sink,
                enqueued: req.enqueued,
                trace: req.trace,
                ttft_ms,
                prompt_len: prompt.len(),
                generated: vec![first],
                max_new,
                last: first,
                sampler,
                spec,
            });
        }
        Err(e) => {
            core.target_mut().free_slot(slot);
            shared.stats.lock().unwrap().gen_failed += 1;
            send_line(
                &req.sink,
                &ServerMsg::error(Some(req.id), "exec_failed", format!("{e:#}")).encode(),
            );
        }
    }
    publish_kv(core, shared);
}

/// Retire every sequence that hit its budget or filled its KV slot:
/// write the `done` frame (with per-request acceptance stats for
/// speculative sequences) and release the slot(s) for reuse.
fn retire_finished(core: &mut SpecCore, shared: &Shared, active: &mut Vec<ActiveSeq>) {
    let mut i = 0;
    while i < active.len() {
        let done = active[i].generated.len() >= active[i].max_new
            || core.target().slot_len(active[i].slot) >= core.target().max_seq;
        if !done {
            i += 1;
            continue;
        }
        let seq = active.swap_remove(i);
        let latency_ms = seq.enqueued.elapsed().as_secs_f64() * 1e3;
        {
            let mut st = shared.stats.lock().unwrap();
            st.record_gen_done();
            st.record_exemplar("generate", seq.id, seq.trace, latency_ms);
        }
        if seq.trace != 0 && obs::recorder::enabled() {
            let end_ns = obs::recorder::now_ns();
            let enq_ns = end_ns
                .saturating_sub(seq.enqueued.elapsed().as_nanos() as u64);
            obs::record_span(seq.trace, SpanKind::Request, enq_ns, end_ns, 0);
        }
        let (rounds, proposed, accepted) = seq
            .spec
            .as_ref()
            .map(|st| (st.rounds, st.proposed, st.accepted))
            .unwrap_or((0, 0, 0));
        send_line(
            &seq.sink,
            &ServerMsg::Done {
                id: seq.id,
                tokens: seq.generated,
                prompt_len: seq.prompt_len,
                ttft_ms: seq.ttft_ms,
                latency_ms,
                rounds,
                proposed,
                accepted,
                trace: seq.trace,
            }
            .encode(),
        );
        if let Some(st) = &seq.spec {
            core.release_draft(st.draft_slot);
        }
        core.target_mut().free_slot(seq.slot);
    }
    publish_kv(core, shared);
}

/// Fail every in-flight sequence (a decode step or acceptance pass
/// errored): stream the error frame and release all slots.
fn fail_all(core: &mut SpecCore, shared: &Shared, active: &mut Vec<ActiveSeq>, msg: &str) {
    let mut st = shared.stats.lock().unwrap();
    st.gen_failed += active.len() as u64;
    drop(st);
    for seq in active.drain(..) {
        send_line(
            &seq.sink,
            &ServerMsg::error(Some(seq.id), "exec_failed", msg.to_string()).encode(),
        );
        if let Some(spec) = &seq.spec {
            core.release_draft(spec.draft_slot);
        }
        core.target_mut().free_slot(seq.slot);
    }
    publish_kv(core, shared);
}

/// Terminal decode-worker failure: fail queued generate requests so no
/// client is left hanging (the scoring pool is unaffected).
fn drain_with_errors(shared: &Shared, msg: &str) {
    while let Some(req) = shared.gen_queue.pop_blocking() {
        shared.stats.lock().unwrap().gen_failed += 1;
        send_line(&req.sink, &ServerMsg::error(Some(req.id), "exec_failed", msg).encode());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The slot-quantization edge cases the continuous batcher hits:
    /// no live rows, a tile that exceeds capacity, and tile 1.
    #[test]
    fn quantize_rows_edges() {
        // no live rows: nothing executes
        assert_eq!(quantize_rows(0, 4, 8), 0);
        // round up to the containing tile multiple
        assert_eq!(quantize_rows(1, 4, 8), 4);
        assert_eq!(quantize_rows(3, 4, 8), 4);
        assert_eq!(quantize_rows(5, 4, 8), 8);
        assert_eq!(quantize_rows(8, 4, 8), 8);
        // rounding target past capacity is capped
        assert_eq!(quantize_rows(3, 16, 8), 8);
        assert_eq!(quantize_rows(1, 16, 8), 8);
        // tile 1: the identity (no padding ever)
        assert_eq!(quantize_rows(1, 1, 8), 1);
        assert_eq!(quantize_rows(7, 1, 8), 7);
        // degenerate tile 0 behaves like 1 (round_target clamps)
        assert_eq!(quantize_rows(3, 0, 8), 3);
        // capacity smaller than live never shrinks the live set:
        // speculative verify rows routinely exceed the slot count
        assert_eq!(quantize_rows(5, 4, 3), 5);
        assert_eq!(quantize_rows(9, 4, 8), 9);
        // quantized never exceeds the full-shape baseline
        for live in 1..=8 {
            assert!(quantize_rows(live, 4, 8) <= 8);
            assert!(quantize_rows(live, 4, 8) >= live);
        }
    }

    #[test]
    fn slot_policy_parsing() {
        assert_eq!(SlotPolicy::parse("tile").unwrap(), SlotPolicy::TileQuantized);
        assert_eq!(SlotPolicy::parse("tile-quantized").unwrap(), SlotPolicy::TileQuantized);
        assert_eq!(SlotPolicy::parse("full").unwrap(), SlotPolicy::Full);
        assert_eq!(SlotPolicy::parse("full").unwrap().name(), "full");
        assert_eq!(SlotPolicy::parse("tile").unwrap().name(), "tile");
        assert!(SlotPolicy::parse("bogus").is_err());
    }
}
