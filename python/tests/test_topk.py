"""Bitonic top-K kernel vs jax.lax.top_k."""

import jax.numpy as jnp
import numpy as np
import pytest

from compile.kernels import topk


@pytest.mark.parametrize("t,e,k", [(16, 8, 2), (32, 16, 4), (8, 64, 8), (128, 32, 1)])
def test_matches_lax_topk(rng, t, e, k):
    scores = rng.normal(size=(t, e)).astype(np.float32)
    v, i = topk.topk_kernel(jnp.asarray(scores), k, block_t=16)
    vr, ir = topk.topk_reference(jnp.asarray(scores), k)
    np.testing.assert_allclose(np.asarray(v), np.asarray(vr), rtol=0, atol=0)
    np.testing.assert_array_equal(np.asarray(i), np.asarray(ir))


def test_non_power_of_two_experts(rng):
    scores = rng.normal(size=(16, 6)).astype(np.float32)
    v, i = topk.topk_kernel(jnp.asarray(scores), 2, block_t=8)
    vr, ir = topk.topk_reference(jnp.asarray(scores), 2)
    np.testing.assert_array_equal(np.asarray(i), np.asarray(ir))
    np.testing.assert_allclose(np.asarray(v), np.asarray(vr))


def test_negative_and_positive_scores():
    scores = jnp.asarray(
        [[-1.0, -2.0, 3.0, 0.0], [0.5, -0.5, -0.25, 0.25], [-1e-30, 1e-30, 0.0, -0.0]],
        jnp.float32,
    )
    v, i = topk.topk_kernel(scores, 2, block_t=1)
    vr, ir = topk.topk_reference(scores, 2)
    np.testing.assert_allclose(np.asarray(v), np.asarray(vr))
    # index agreement except where values tie (0.0 vs -0.0 compare equal)
    ties = np.asarray(v) == np.asarray(vr)
    assert ties.all()


def test_stability_no_duplicate_indices(rng):
    """Packed indices guarantee no ties: all K indices distinct per row."""
    scores = np.zeros((8, 16), np.float32)  # all-equal scores: worst case
    _, i = topk.topk_kernel(jnp.asarray(scores), 8, block_t=8)
    i = np.asarray(i)
    for row in i:
        assert len(set(row.tolist())) == 8


def test_sortable_key_monotonicity(rng):
    xs = np.sort(rng.normal(size=(257,)).astype(np.float32) * 100)
    keys = topk._sortable_keys(jnp.asarray(xs)[None, :], 0)[0]
    keys = np.asarray(keys)
    assert np.all(keys[1:] >= keys[:-1])
