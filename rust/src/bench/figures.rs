//! Regeneration of every table and figure in the paper's evaluation
//! (the per-experiment index in DESIGN.md maps each to its source).
//! Each function returns printable [`Table`]s; the bench targets and
//! `examples/paper_figures.rs` both call these.

use super::Table;
use crate::memory;
use crate::routing::{self, RoundingRule};
use crate::simulator::breakdown::{breakdown, total_ms};
use crate::simulator::cluster;
use crate::simulator::configs::{
    MoeShape, NamedShape, FIG13_SWEEPS, FIG13_T, FIG1_SWEEP, OPEN_SOURCE, TABLE_4, TABLE_9A,
    TABLE_9B,
};
use crate::simulator::topk::TopKImpl;
use crate::simulator::{evaluate, evaluate_uniform, GpuSpec, Method, Pass, Routing, B300, H100};
use crate::util::prng::Prng;

/// Sampled-routing evaluation (what every figure feeds the methods; the
/// cuBLAS bound keeps uniform routing by definition).
fn eval_sampled(m: Method, s: &MoeShape, pass: Pass, hw: &GpuSpec, seed: u64) -> f64 {
    if m == Method::CublasBmm {
        return evaluate_uniform(m, s, pass, hw).model_tflops;
    }
    let mut rng = Prng::new(seed);
    let r = Routing::sampled(s, hw.tile.0, &mut rng, 0.3);
    evaluate(m, s, &r, pass, hw).model_tflops
}

/// Figure 1: activation memory + fwd TFLOPS vs cuBLAS bound across the
/// 30B granularity/sparsity sweep, H100 and B300.
pub fn fig01() -> Vec<Table> {
    let mut mem = Table::new(
        "Figure 1 (left): per-layer activation memory vs granularity, 30B sweep",
        &["config", "G=d/n", "SonicMoE MiB", "ScatterMoE MiB", "MoMoE MiB"],
    );
    for c in FIG1_SWEEP {
        let s = c.shape;
        let mib = |m| memory::cached_activation_bytes(m, &s) as f64 / (1 << 20) as f64;
        mem.row(&[
            c.label.to_string(),
            format!("{:.1}", s.granularity()),
            format!("{:.0}", mib(memory::Method::SonicMoE)),
            format!("{:.0}", mib(memory::Method::ScatterMoE)),
            format!("{:.0}", mib(memory::Method::MoMoE)),
        ]);
    }
    let mut out = vec![mem];
    for hw in [&H100, &B300] {
        let mut t = Table::new(
            &format!("Figure 1 ({}): forward TFLOPS vs cuBLAS upper bound", hw.name),
            &["config", "SonicMoE TF/s", "cuBLAS bound TF/s", "fraction"],
        );
        for (i, c) in FIG1_SWEEP.iter().enumerate() {
            let sonic = eval_sampled(Method::SonicMoE, &c.shape, Pass::Forward, hw, i as u64);
            let bound = eval_sampled(Method::CublasBmm, &c.shape, Pass::Forward, hw, i as u64);
            t.row(&[
                c.label.to_string(),
                format!("{sonic:.0}"),
                format!("{bound:.0}"),
                format!("{:.2}", sonic / bound),
            ]);
        }
        out.push(t);
    }
    out
}

/// Figure 5: runtime breakdown of 7B training per kernel category.
pub fn fig05() -> Vec<Table> {
    let mut out = Vec::new();
    for (hw, shape) in [
        (&H100, MoeShape::new(24576, 1536, 256, 128, 8)),
        (&B300, MoeShape::new(32768, 2048, 1024, 64, 8)), // OLMoE-sized
    ] {
        let mut t = Table::new(
            &format!("Figure 5 ({}): fwd+bwd runtime breakdown (ms)", hw.name),
            &["method", "total ms", "grouped GEMM", "gather/scatter", "act", "aggregation", "dS", "router"],
        );
        for m in Method::MAIN {
            let b = breakdown(m, &shape, hw);
            let get = |name: &str| {
                b.iter()
                    .find(|(c, _)| c.name() == name)
                    .map(|(_, v)| format!("{:.2}", v.ms))
                    .unwrap_or_else(|| "-".into())
            };
            t.row(&[
                m.name().to_string(),
                format!("{:.2}", total_ms(m, &shape, hw)),
                get("grouped GEMM"),
                get("gather/scatter"),
                get("SwiGLU/dSwiGLU"),
                get("expert aggregation"),
                get("dS compute"),
                get("router related"),
            ]);
        }
        out.push(t);
    }
    out
}

/// Figure 8: wasted FLOPs from tile padding vs E (T=16k, d=4k, n=1k, K=4).
pub fn fig08() -> Table {
    let (t, d, n, k, m) = (16384, 4096, 1024, 4, 128);
    let mut tbl = Table::new(
        "Figure 8: padding waste, fwd+bwd (T=16k d=4k n=1k K=4, m_tile=128)",
        &["E", "pad rows", "wasted TFLOP", "% of model FLOPs"],
    );
    for e in [32usize, 64, 128, 256] {
        let mut rng = Prng::new(e as u64);
        let scores = routing::synth_scores(&mut rng, t, e, 0.5);
        let dec = routing::tc_topk(&scores, t, e, k);
        let waste = dec.padding_waste_flops(m, d, n);
        let model = 18u64 * (t * k) as u64 * (n * d) as u64;
        tbl.row(&[
            e.to_string(),
            dec.padding_rows(m).to_string(),
            format!("{:.2}", waste as f64 / 1e12),
            format!("{:.2}", 100.0 * waste as f64 / model as f64),
        ]);
    }
    tbl
}

/// Figure 10: peak activation memory per layer, Table 9a configs.
pub fn fig10() -> Table {
    let mut t = Table::new(
        "Figure 10: activation memory per MoE layer (GiB), H100 configs",
        &["config", "SonicMoE", "ScatterMoE", "MoMoE", "MegaBlocks", "Megatron", "DeepGEMM++"],
    );
    for c in TABLE_9A {
        let mut row = vec![c.label.to_string()];
        for m in memory::Method::ALL {
            if m.supports(&c.shape) {
                row.push(format!(
                    "{:.3}",
                    memory::gib(memory::cached_activation_bytes(m, &c.shape))
                ));
            } else {
                row.push("n/a".into());
            }
        }
        t.row(&row);
    }
    t
}

fn throughput_table(title: &str, configs: &[NamedShape], hw: &GpuSpec) -> Vec<Table> {
    let mut out = Vec::new();
    for pass in [Pass::Forward, Pass::Backward] {
        let pname = if pass == Pass::Forward { "forward" } else { "backward" };
        let mut t = Table::new(
            &format!("{title} — {pname} model TFLOPS"),
            &["config", "SonicMoE", "ScatterMoE", "MoMoE", "MegaBlocks", "Megatron", "DG++", "DG-pt"],
        );
        for (i, c) in configs.iter().enumerate() {
            let mut row = vec![c.label.to_string()];
            for m in Method::MAIN {
                row.push(format!("{:.0}", eval_sampled(m, &c.shape, pass, hw, i as u64)));
            }
            t.row(&row);
        }
        out.push(t);
    }
    out
}

/// Figure 11a/11b: fwd/bwd TFLOPS across Table 9 configs.
pub fn fig11() -> Vec<Table> {
    let mut out = throughput_table("Figure 11a (H100)", &TABLE_9A, &H100);
    out.extend(throughput_table("Figure 11b (B300)", &TABLE_9B, &B300));
    out
}

/// Figure 12a/12b + Table 4: open-source MoE configs.
pub fn fig12() -> Vec<Table> {
    let mut t4 = Table::new(
        "Table 4: MoE scaling trends (release date, K/E, d/n)",
        &["model", "date", "activation ratio", "granularity"],
    );
    for (name, date, rho, g_inv) in TABLE_4 {
        t4.row(&[
            name.to_string(),
            date.to_string(),
            format!("{:.2}%", rho * 100.0),
            format!("{:.2}", 1.0 / g_inv),
        ]);
    }
    let mut out = vec![t4];
    out.extend(throughput_table("Figure 12a (H100, open-source configs)", &OPEN_SOURCE, &H100));
    out.extend(throughput_table("Figure 12b (B300, open-source configs)", &OPEN_SOURCE, &B300));
    out
}

/// TR-vs-TC evaluation on a shape: returns (tc fwd, tr fwd, tc bwd, tr bwd).
fn tr_vs_tc(s: &MoeShape, m_tile: usize, seed: u64) -> (f64, f64, f64, f64) {
    let mut rng = Prng::new(seed);
    let scores = routing::synth_scores(&mut rng, s.t, s.e, 0.5);
    let tc = routing::tc_topk(&scores, s.t, s.e, s.k);
    let tr = routing::token_rounding(
        &scores, s.t, s.e, s.k, m_tile, RoundingRule::NearestFreq, &mut rng,
    );
    // model FLOPs follow the *realized* token counts (footnote 12)
    let eval_counts = |g: &[usize], pass: Pass| {
        let r = Routing::from_counts(g.to_vec(), m_tile);
        let e = evaluate(Method::SonicMoE, s, &r, pass, &H100);
        let factor = if pass == Pass::Forward { 6 } else { 12 };
        let model_flops = factor as u64 * r.rows() as u64 * (s.n * s.d) as u64;
        model_flops as f64 / e.time_s / 1e12
    };
    (
        eval_counts(&tc.g, Pass::Forward),
        eval_counts(&tr.g, Pass::Forward),
        eval_counts(&tc.g, Pass::Backward),
        eval_counts(&tr.g, Pass::Backward),
    )
}

/// Figure 13: TR vs TC TFLOPS across the four sparsity sweeps.
pub fn fig13() -> Vec<Table> {
    let mut out = Vec::new();
    for sw in &FIG13_SWEEPS {
        let mut t = Table::new(
            &format!("Figure 13: TR vs TC, {} (T=16384, m_tile=128)", sw.label),
            &["E", "K/E", "TC fwd TF/s", "TR fwd TF/s", "TC bwd TF/s", "TR bwd TF/s", "e2e gain %"],
        );
        for &e in &sw.e_values {
            let s = MoeShape::new(FIG13_T, sw.d, sw.n, e, sw.k);
            let (tcf, trf, tcb, trb) = tr_vs_tc(&s, 128, e as u64);
            let e2e = (1.0 / tcf + 2.0 / tcb) / (1.0 / trf + 2.0 / trb);
            t.row(&[
                e.to_string(),
                format!("1/{}", e / sw.k),
                format!("{tcf:.0}"),
                format!("{trf:.0}"),
                format!("{tcb:.0}"),
                format!("{trb:.0}"),
                format!("{:+.1}", (e2e - 1.0) * 100.0),
            ]);
        }
        out.push(t);
    }
    out
}

/// Figure 14: TR vs TC on the open-source configs.
pub fn fig14() -> Table {
    let mut t = Table::new(
        "Figure 14: SonicMoE with TR vs TC router, open-source configs (H100)",
        &["config", "K/E", "TC fwd", "TR fwd", "gain %", "TC bwd", "TR bwd", "gain %"],
    );
    for (i, c) in OPEN_SOURCE.iter().enumerate() {
        let (tcf, trf, tcb, trb) = tr_vs_tc(&c.shape, 128, 100 + i as u64);
        t.row(&[
            c.label.to_string(),
            format!("{}/{}", c.shape.k, c.shape.e),
            format!("{tcf:.0}"),
            format!("{trf:.0}"),
            format!("{:+.1}", (trf / tcf - 1.0) * 100.0),
            format!("{tcb:.0}"),
            format!("{trb:.0}"),
            format!("{:+.1}", (trb / tcb - 1.0) * 100.0),
        ]);
    }
    t
}

/// Figures 18/19: grouped GEMM with contiguous vs gathered inputs.
pub fn fig18_19() -> Vec<Table> {
    let mut out = Vec::new();
    for hw in [&H100, &B300] {
        let mut t = Table::new(
            &format!("Figure 18/19 ({}): up-proj grouped GEMM TFLOPS", hw.name),
            &["config", "SonicMoE", "SonicMoE+gather", "DG++ (sep. gather)", "cuBLAS bound"],
        );
        let configs = if hw.name == "H100" { &TABLE_9A } else { &TABLE_9B };
        for c in configs.iter().step_by(3) {
            // contiguous = uniform tile-aligned counts (no gather read)
            let sonic = evaluate_uniform(Method::SonicMoE, &c.shape, Pass::Forward, hw);
            let sg = eval_sampled(Method::SonicMoE, &c.shape, Pass::Forward, hw, 1);
            let dg = eval_sampled(Method::DeepGemmPlus, &c.shape, Pass::Forward, hw, 1);
            let cb = evaluate_uniform(Method::CublasBmm, &c.shape, Pass::Forward, hw);
            t.row(&[
                c.label.to_string(),
                format!("{:.0}", sonic.model_tflops),
                format!("{sg:.0}"),
                format!("{dg:.0}"),
                format!("{:.0}", cb.model_tflops),
            ]);
        }
        out.push(t);
    }
    out
}

/// Figure 20: expert-aggregation kernel bandwidth.
pub fn fig20() -> Vec<Table> {
    let mut out = Vec::new();
    for hw in [&H100, &B300] {
        let mut t = Table::new(
            &format!("Figure 20 ({}): aggregation kernel bandwidth (TB/s)", hw.name),
            &["config", "SonicMoE gth+sum", "ScatterMoE bmm", "MoMoE sum", "triton bound"],
        );
        let configs = if hw.name == "H100" { &TABLE_9A } else { &TABLE_9B };
        for c in configs.iter().step_by(3) {
            let s = &c.shape;
            let bytes = 2.0 * (s.t * s.k * s.d) as f64 + 2.0 * (s.t * s.d) as f64;
            // kernel time at each implementation's efficiency
            let time = |eff: f64, gathered: bool| {
                let pen = if gathered { 0.85 } else { 1.0 };
                hw.stream_s(bytes / pen) / eff + hw.launch_s
            };
            let row = |eff: f64, gathered: bool| {
                format!("{:.2}", bytes / time(eff, gathered) / 1e12)
            };
            t.row(&[
                c.label.to_string(),
                row(1.0, true),
                row(0.40, false),
                row(0.95, false),
                row(1.0, false),
            ]);
        }
        out.push(t);
    }
    out
}

/// Figure 21 (+16/17): aggregation strategy ablation on SonicMoE.
pub fn fig21() -> Table {
    let mut t = Table::new(
        "Figure 21: gemm+gather-sum vs gemm-with-scatter+sum (H100, fwd down-proj + aggregation)",
        &["config", "gth w. sum TF/s", "sct + sum TF/s", "speedup %"],
    );
    for c in TABLE_9A.iter().step_by(3) {
        let s = &c.shape;
        let r = Routing::uniform(s, H100.tile.0);
        // SonicMoE default (left strategy)
        let left = evaluate(Method::SonicMoE, s, &r, Pass::Forward, &H100);
        // middle strategy modelled via MoMoE's scatter-fused store with
        // SonicMoE's other features: approximate by adding the st.global
        // penalty to the down-proj store and dropping the gather penalty
        // from aggregation. We reuse the MoMoE graph but with SonicMoE's
        // epilogue fusion and overlap disabled only on the scatter store.
        let middle_time = {
            use crate::simulator::gemm::{Class, Kernel};
            let ks = crate::simulator::kernel_graph(Method::SonicMoE, s, &r, Pass::Forward);
            let mut total = 0.0;
            for k in &ks {
                let mut k2 = k.clone();
                if k.name == "down-proj Y" {
                    if let Class::GroupedGemm { scatter_store, overlap, .. } = &mut k2.class {
                        *scatter_store = true;
                        *overlap = false; // st.global blocks the next MMA tile
                    }
                }
                if k.name == "aggregate O" {
                    if let Class::MemBound { gathered_read, .. } = &mut k2.class {
                        *gathered_read = 0.0; // already scattered contiguous
                    }
                }
                total += Kernel::time_s(&k2, &H100);
            }
            total
        };
        let left_tf = left.model_tflops;
        let mid_tf = s.flops_fwd() as f64 / middle_time / 1e12;
        t.row(&[
            c.label.to_string(),
            format!("{left_tf:.0}"),
            format!("{mid_tf:.0}"),
            format!("{:+.1}", (left_tf / mid_tf - 1.0) * 100.0),
        ]);
    }
    t
}

/// Figure 22: top-K kernel bandwidth.
pub fn fig22() -> Vec<Table> {
    let mut out = Vec::new();
    for hw in [&H100, &B300] {
        for (dtype, bytes) in [("BF16", 2.0), ("FP32", 4.0)] {
            let mut t = Table::new(
                &format!("Figure 22 ({}, {dtype}): top-K kernel bandwidth (GB/s)", hw.name),
                &["config", "SonicMoE", "torch", "triton", "tilelang", "RTop-K"],
            );
            let configs = if hw.name == "H100" { &TABLE_9A } else { &TABLE_9B };
            for c in configs.iter().step_by(3) {
                let s = &c.shape;
                let mut row = vec![c.label.to_string()];
                for imp in TopKImpl::ALL {
                    if imp == TopKImpl::RTopK && dtype == "BF16" {
                        row.push("n/a".into()); // RTop-K is FP32-only
                        continue;
                    }
                    row.push(format!(
                        "{:.0}",
                        imp.bandwidth_gbps(s.t, s.e, s.k, bytes, hw)
                    ));
                }
                t.row(&row);
            }
            out.push(t);
        }
    }
    out
}

/// Section 6.2's FSDP cluster claim.
pub fn cluster_claim() -> Table {
    let model = cluster::moe_7b(24576);
    let mut t = Table::new(
        "Section 6.2: 7B MoE FSDP-2 training throughput (tokens/day)",
        &["method", "GPUs", "tokens/day (B)", "paper"],
    );
    for (m, gpus, paper) in [
        (Method::SonicMoE, 64, "213B"),
        (Method::ScatterMoE, 96, "225B"),
        (Method::ScatterMoE, 64, "~150B (42% slower e2e)"),
    ] {
        let tpd = cluster::tokens_per_day(&model, m, gpus, &H100);
        t.row(&[
            m.name().to_string(),
            gpus.to_string(),
            format!("{:.0}", tpd / 1e9),
            paper.to_string(),
        ]);
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_figures_render() {
        // smoke: every generator produces non-empty tables
        let mut count = 0;
        for t in fig01() {
            count += 1;
            assert!(t.to_string().len() > 50);
        }
        for t in [fig08(), fig10(), fig14(), fig21(), cluster_claim()] {
            count += 1;
            assert!(t.to_string().len() > 50);
        }
        for ts in [fig05(), fig11(), fig12(), fig13(), fig18_19(), fig20(), fig22()] {
            for t in ts {
                count += 1;
                assert!(t.to_string().len() > 50);
            }
        }
        assert!(count >= 20, "{count} tables");
    }

    #[test]
    fn fig13_tr_gain_grows_with_sparsity() {
        // the paper's headline TR trend: larger E (sparser) => larger gain
        let sw = &FIG13_SWEEPS[0];
        let gains: Vec<f64> = sw
            .e_values
            .iter()
            .map(|&e| {
                let s = MoeShape::new(FIG13_T, sw.d, sw.n, e, sw.k);
                let (tcf, trf, _, _) = tr_vs_tc(&s, 128, e as u64);
                trf / tcf
            })
            .collect();
        assert!(
            gains.last().unwrap() > gains.first().unwrap(),
            "TR gain should grow with E: {gains:?}"
        );
        assert!(gains.iter().all(|&g| g >= 0.98), "{gains:?}");
    }

    #[test]
    fn fig01_sonic_below_bound() {
        for t in fig01().into_iter().skip(1) {
            let s = t.to_string();
            // fraction column must stay <= 1.00
            for line in s.lines().skip(3) {
                if let Some(frac) = line.split_whitespace().last() {
                    if let Ok(f) = frac.parse::<f64>() {
                        assert!(f <= 1.0 + 1e-9, "{line}");
                    }
                }
            }
        }
    }
}
