//! Bench: regenerate Figure 12 + Table 4 via the GPU performance simulator and time
//! the evaluation hot path. See DESIGN.md per-experiment index.

use sonic_moe::bench::{figures, Bencher};

fn main() {
    for t in figures::fig12() {
        t.print();
    }
    let mut b = Bencher::new("simulator/fig12_opensource");
    b.iter(|| figures::fig12());
    println!("{}", b.report());
}
