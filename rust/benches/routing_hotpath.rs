//! §Perf (L3): routing hot-path micro-benchmarks — tokens/s for TC
//! top-K, token rounding and EC at paper-scale microbatches, plus the
//! packed-layout metadata build. Target: >= 10^7 tokens/s (DESIGN.md).

use sonic_moe::bench::{black_box, Bencher};
use sonic_moe::routing::{
    build_metadata, expert_choice, synth_scores, tc_topk, token_rounding, RoundingRule,
};
use sonic_moe::util::prng::Prng;

fn main() {
    let cases = [(16384usize, 64usize, 8usize), (16384, 128, 8), (32768, 256, 16)];
    for (t, e, k) in cases {
        let mut rng = Prng::new(0);
        let scores = synth_scores(&mut rng, t, e, 0.5);

        let mut b = Bencher::new(&format!("routing/tc_topk T={t} E={e} K={k}"));
        let s = b.iter(|| black_box(tc_topk(&scores, t, e, k)));
        println!("{}  ({:.1} Mtok/s)", b.report(), t as f64 / s.median / 1e6);

        let mut b = Bencher::new(&format!("routing/token_rounding T={t} E={e} K={k}"));
        let s = b.iter(|| {
            black_box(token_rounding(
                &scores,
                t,
                e,
                k,
                128,
                RoundingRule::NearestFreq,
                &mut rng,
            ))
        });
        println!("{}  ({:.1} Mtok/s)", b.report(), t as f64 / s.median / 1e6);

        let mut b = Bencher::new(&format!("routing/expert_choice T={t} E={e} K={k}"));
        let s = b.iter(|| black_box(expert_choice(&scores, t, e, k)));
        println!("{}  ({:.1} Mtok/s)", b.report(), t as f64 / s.median / 1e6);

        let dec = tc_topk(&scores, t, e, k);
        let mut b = Bencher::new(&format!("routing/build_metadata T={t} E={e} K={k}"));
        let s = b.iter(|| black_box(build_metadata(&dec, 128)));
        println!("{}  ({:.1} Mtok/s)", b.report(), t as f64 / s.median / 1e6);
    }
}
