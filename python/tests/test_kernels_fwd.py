"""Forward Pallas kernels (A, Y, O) vs the dense jnp oracle."""

import jax.numpy as jnp
import numpy as np
import pytest

from compile.kernels import MoEConfig
from compile.kernels import aggregation, grouped_gemm, metadata, ref

from .conftest import random_moe_inputs


CFGS = [
    MoEConfig(T=16, d=8, n=4, E=4, K=2, m_tile=4),
    MoEConfig(T=32, d=12, n=6, E=8, K=3, m_tile=8),
    MoEConfig(T=8, d=16, n=8, E=2, K=2, m_tile=16),  # heavy padding
    MoEConfig(T=64, d=8, n=4, E=4, K=1, m_tile=4),
]


@pytest.fixture(params=CFGS, ids=str)
def case(request, rng):
    cfg = request.param
    x, w1, w2, pi, s = random_moe_inputs(rng, cfg)
    meta = metadata.build_metadata(cfg, jnp.asarray(pi), jnp.asarray(s))
    oracle = ref.moe_forward_intermediates(x, w1, w2, pi, s)
    return cfg, x, w1, w2, pi, s, meta, oracle


def _packed_vs_dense(cfg, meta, packed, dense_te, atol=1e-5):
    """Compare a packed (cap_pad, f) tensor against the dense (T, E, f)
    oracle, slot by slot; padding slots must be exactly zero."""
    slot_token = np.asarray(meta.slot_token)
    slot_valid = np.asarray(meta.slot_valid).astype(bool)
    off = np.asarray(meta.offsets)
    packed = np.asarray(packed)
    owner = np.searchsorted(off[1:], np.arange(cfg.cap_pad), side="right")
    for i in range(cfg.cap_pad):
        if slot_valid[i]:
            t, e = slot_token[i], owner[i]
            np.testing.assert_allclose(
                packed[i], np.asarray(dense_te)[t, e], rtol=1e-4, atol=atol
            )
        else:
            assert np.abs(packed[i]).max() == 0.0, f"pad slot {i} nonzero"


def test_up_proj_swiglu(case):
    cfg, x, w1, w2, pi, s, meta, oracle = case
    h_packed, a_packed = grouped_gemm.up_proj_swiglu(cfg, x, w1, meta)
    _packed_vs_dense(cfg, meta, h_packed, oracle["h"])
    _packed_vs_dense(cfg, meta, a_packed, oracle["a"])


def test_down_proj(case):
    cfg, x, w1, w2, pi, s, meta, oracle = case
    _, a_packed = grouped_gemm.up_proj_swiglu(cfg, x, w1, meta)
    y_packed = grouped_gemm.down_proj(cfg, a_packed, w2, meta)
    _packed_vs_dense(cfg, meta, y_packed, oracle["y"])


def test_full_forward_composition(case):
    cfg, x, w1, w2, pi, s, meta, oracle = case
    _, a_packed = grouped_gemm.up_proj_swiglu(cfg, x, w1, meta)
    y_packed = grouped_gemm.down_proj(cfg, a_packed, w2, meta)
    o = aggregation.expert_aggregate(cfg, y_packed, meta)
    np.testing.assert_allclose(
        np.asarray(o), np.asarray(oracle["o"]), rtol=1e-4, atol=1e-5
    )


def test_forward_is_router_agnostic(rng):
    """Any (pi, s) — here an unbalanced, partially-empty routing — must
    produce the dense result (Section 3.1: router-independent kernels)."""
    cfg = MoEConfig(T=16, d=8, n=4, E=4, K=2, m_tile=4)
    x, w1, w2, _, _ = random_moe_inputs(rng, cfg)
    pi = np.zeros((cfg.T, cfg.E), np.float32)
    pi[:13, 0] = 1  # very unbalanced; expert 3 empty
    pi[5:9, 1] = 1
    pi[0, 2] = 1
    s = (rng.random((cfg.T, cfg.E)).astype(np.float32) + 0.05) * pi
    meta = metadata.build_metadata(cfg, jnp.asarray(pi), jnp.asarray(s))
    _, a_packed = grouped_gemm.up_proj_swiglu(cfg, x, w1, meta)
    y_packed = grouped_gemm.down_proj(cfg, a_packed, w2, meta)
    o = aggregation.expert_aggregate(cfg, y_packed, meta)
    want = ref.moe_forward_dense(x, w1, w2, pi, s)
    np.testing.assert_allclose(np.asarray(o), np.asarray(want), rtol=1e-4, atol=1e-5)
