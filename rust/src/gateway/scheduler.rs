//! Continuous-batching decode scheduler: the gateway's generation
//! worker.
//!
//! One thread owns a [`DecodeCore`] (parameters + incremental KV cache)
//! and loops admit → step → emit:
//!
//! - **admit**: pop `generate` requests from the gen queue into free KV
//!   slots mid-flight (vLLM-style slot reuse — new sequences join while
//!   others are mid-generation), prefill their prompt, and stream the
//!   first `token` frame;
//! - **step**: advance every live sequence by one token in one packed
//!   decode step. The *executed* row count is the live-slot count
//!   quantized to a tile multiple via [`round_target`] (Algorithm 4's
//!   round-up applied to decode batch fill), so per-step padding is the
//!   minimal `exec_rows - live` instead of the full-shape
//!   `slots - live` a naive scheduler pays;
//! - **emit**: stream one incremental `token` frame per sequence per
//!   step; when a sequence reaches its budget (or its KV slot fills),
//!   write the terminal `done` frame, release the slot, and admit
//!   whoever is waiting.
//!
//! Shutdown semantics: the gen queue closes, in-flight sequences run to
//! completion (their budget is capped, so the drain is bounded), then
//! the worker exits.

use std::sync::Arc;
use std::time::Instant;

use crate::coordinator::decode::{argmax, DecodeCore};
use crate::routing::{round_target, RoundingRule};
use crate::util::prng::Prng;

use super::protocol::ServerMsg;
use super::{send_line, GenReq, Shared};

/// How the scheduler sizes the executed decode shape each step.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SlotPolicy {
    /// Always execute the full slot count (the naive baseline: maximum
    /// per-step padding, the comparator in the decode bench).
    Full,
    /// Quantize the live-slot count up to the next tile multiple (the
    /// serving analogue of the paper's token rounding).
    TileQuantized,
}

impl SlotPolicy {
    pub fn parse(name: &str) -> anyhow::Result<SlotPolicy> {
        Ok(match name {
            "full" => SlotPolicy::Full,
            "tile" | "tile-quantized" => SlotPolicy::TileQuantized,
            p => anyhow::bail!("unknown slot policy {p:?} (tile|full)"),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            SlotPolicy::Full => "full",
            SlotPolicy::TileQuantized => "tile",
        }
    }
}

/// Executed decode rows for `live` sequences: the smallest tile
/// multiple holding every live row, capped at the slot capacity.
/// Shared with the decode bench and the round-target edge-case tests
/// (live 0, tile 1, rounding past capacity).
pub fn quantize_rows(live: usize, m_tile: usize, cap: usize) -> usize {
    if live == 0 {
        return 0;
    }
    // Up is deterministic; the rng is never consulted
    let mut rng = Prng::new(0);
    round_target(live, m_tile, RoundingRule::Up, &mut rng).clamp(live, cap.max(live))
}

/// Per-worker construction parameters (the gateway config minus the
/// shared state).
pub struct DecodeWorkerCfg {
    pub artifacts_dir: String,
    pub config: String,
    pub backend: String,
    pub checkpoint: Option<String>,
    /// KV slots (max concurrent sequences).
    pub slots: usize,
    /// Cap on per-request generated tokens (bounds the drain).
    pub max_new_cap: usize,
    /// Row tile quantizing executed decode shapes.
    pub m_tile: usize,
    pub policy: SlotPolicy,
}

/// One in-flight sequence: a KV slot plus the way back to its client.
struct ActiveSeq {
    id: u64,
    slot: usize,
    sink: super::Sink,
    enqueued: Instant,
    ttft_ms: f64,
    prompt_len: usize,
    generated: Vec<i32>,
    max_new: usize,
    /// Next input token (the previously generated one).
    last: i32,
}

/// Decode worker thread body.
pub fn run(cfg: DecodeWorkerCfg, shared: Arc<Shared>) {
    let mut core = match DecodeCore::new_with_backend(
        &cfg.artifacts_dir,
        &cfg.config,
        &cfg.backend,
        cfg.slots,
        0,
    ) {
        Ok(c) => c,
        Err(e) => {
            log::error!("gateway decode worker failed to open core: {e:#}");
            drain_with_errors(&shared, &format!("decode path unavailable: {e:#}"));
            return;
        }
    };
    if let Some(dir) = &cfg.checkpoint {
        if let Err(e) = core.load_checkpoint(dir) {
            log::error!("gateway decode worker failed checkpoint load: {e:#}");
            drain_with_errors(&shared, "decode checkpoint load failed");
            return;
        }
    }
    let mut active: Vec<ActiveSeq> = Vec::new();
    let mut local_gen = 0u64;
    loop {
        if active.is_empty() {
            // idle: a pending checkpoint swap applies against the empty
            // KV cache — once before blocking (a swap that was waiting
            // on the in-flight drain) and again after waking (a swap
            // acknowledged while blocked), so no sequence admitted
            // after the ack ever runs on stale parameters
            apply_pending_reload(&mut core, &shared, &mut local_gen);
            // block for work; `None` means closed + drained (exit)
            match shared.gen_queue.pop_blocking() {
                Some(req) => {
                    apply_pending_reload(&mut core, &shared, &mut local_gen);
                    admit(&mut core, &shared, &mut active, req, cfg.max_new_cap);
                }
                None => break,
            }
        }
        // a reload that arrives mid-flight pauses admissions instead:
        // in-flight sequences drain (their budget is capped, so this is
        // bounded), then the idle branch above applies the swap — a
        // parameter swap must never corrupt a live prefix, but
        // sustained traffic must not defer it forever either
        let reload_pending = shared.reload.lock().unwrap().gen != local_gen;
        // fill remaining slots from the backlog without blocking
        while !reload_pending && active.len() < core.slots() {
            match shared.gen_queue.try_pop() {
                Some(req) => admit(&mut core, &shared, &mut active, req, cfg.max_new_cap),
                None => break,
            }
        }
        // retire sequences whose budget (or KV slot) is exhausted
        // before stepping — a 1-token request finishes at prefill
        retire_finished(&mut core, &shared, &mut active);
        if active.is_empty() {
            continue;
        }

        let live = active.len();
        let exec_rows = match cfg.policy {
            SlotPolicy::Full => core.slots(),
            SlotPolicy::TileQuantized => quantize_rows(live, cfg.m_tile, core.slots()),
        };
        let t0 = Instant::now();
        let rows: Vec<(usize, i32)> = active.iter().map(|s| (s.slot, s.last)).collect();
        // the padding rows really execute (dummy compute, discarded):
        // the slot policies differ in measured work, not bookkeeping
        match core.decode_step_padded(&rows, exec_rows) {
            Ok(logits) => {
                let dt = t0.elapsed().as_secs_f64();
                shared.stats.lock().unwrap().record_decode_step(live, exec_rows, dt);
                let vocab = core.vocab;
                for (i, seq) in active.iter_mut().enumerate() {
                    let next = argmax(&logits[i * vocab..(i + 1) * vocab]);
                    seq.generated.push(next);
                    seq.last = next;
                    send_line(
                        &seq.sink,
                        &ServerMsg::Token {
                            id: seq.id,
                            token: next,
                            index: seq.generated.len() - 1,
                        }
                        .encode(),
                    );
                }
                // steady-state decode is allocation-free: the logits
                // buffer goes back to this worker's scratch arena
                core.recycle_logits(logits);
                retire_finished(&mut core, &shared, &mut active);
            }
            Err(e) => {
                let msg = format!("{e:#}");
                log::warn!("gateway decode worker: step failed: {msg}");
                let mut st = shared.stats.lock().unwrap();
                st.gen_failed += active.len() as u64;
                drop(st);
                for seq in active.drain(..) {
                    send_line(
                        &seq.sink,
                        &ServerMsg::error(Some(seq.id), "exec_failed", msg.clone()).encode(),
                    );
                    core.free_slot(seq.slot);
                }
            }
        }
    }
    log::debug!("gateway decode worker drained");
}

/// Apply a pending checkpoint hot-swap (call only with no sequence in
/// flight: the swap resets the KV cache).
fn apply_pending_reload(core: &mut DecodeCore, shared: &Shared, local_gen: &mut u64) {
    let pending = {
        let r = shared.reload.lock().unwrap();
        if r.gen != *local_gen { Some((r.gen, r.dir.clone())) } else { None }
    };
    if let Some((gen, dir)) = pending {
        match core.load_checkpoint(&dir) {
            Ok(()) => {
                shared.stats.lock().unwrap().reloads += 1;
                log::info!("gateway decode worker: reloaded {dir}");
            }
            Err(e) => log::warn!("gateway decode worker: reload failed: {e:#}"),
        }
        *local_gen = gen;
    }
}

/// Admit one request: clamp its budget, truncate the prompt to leave
/// room for generation, prefill a fresh slot, and stream the first
/// token.
fn admit(
    core: &mut DecodeCore,
    shared: &Shared,
    active: &mut Vec<ActiveSeq>,
    req: GenReq,
    max_new_cap: usize,
) {
    let max_new = if req.max_new == 0 {
        max_new_cap
    } else {
        req.max_new.min(max_new_cap)
    };
    // tokens flow through raw: the native decode path clamps them with
    // the same `clamp_token` rule as the stateless `lm_decode_step`
    // artifact, so gateway streams and the artifact stay token-for-token
    // identical even for out-of-range prompt ids
    let mut prompt = req.prompt;
    if prompt.is_empty() {
        prompt.push(0);
    }
    // leave the generation budget inside the KV slot
    let keep = core.max_seq.saturating_sub(max_new).max(1);
    prompt.truncate(keep);
    let slot = match core.alloc_slot() {
        Some(s) => s,
        None => {
            // admission is gated on free slots; reaching here means a
            // bookkeeping bug, fail the request rather than wedge
            shared.stats.lock().unwrap().gen_failed += 1;
            send_line(
                &req.sink,
                &ServerMsg::error(Some(req.id), "exec_failed", "no free decode slots").encode(),
            );
            return;
        }
    };
    let t0 = Instant::now();
    match core.prefill(slot, &prompt) {
        Ok(logits) => {
            let first = argmax(&logits);
            core.recycle_logits(logits);
            let ttft_ms = req.enqueued.elapsed().as_secs_f64() * 1e3;
            shared
                .stats
                .lock()
                .unwrap()
                .record_prefill(prompt.len(), t0.elapsed().as_secs_f64(), ttft_ms);
            send_line(
                &req.sink,
                &ServerMsg::Token { id: req.id, token: first, index: 0 }.encode(),
            );
            active.push(ActiveSeq {
                id: req.id,
                slot,
                sink: req.sink,
                enqueued: req.enqueued,
                ttft_ms,
                prompt_len: prompt.len(),
                generated: vec![first],
                max_new,
                last: first,
            });
        }
        Err(e) => {
            core.free_slot(slot);
            shared.stats.lock().unwrap().gen_failed += 1;
            send_line(
                &req.sink,
                &ServerMsg::error(Some(req.id), "exec_failed", format!("{e:#}")).encode(),
            );
        }
    }
}

/// Retire every sequence that hit its budget or filled its KV slot:
/// write the `done` frame and release the slot for reuse.
fn retire_finished(core: &mut DecodeCore, shared: &Shared, active: &mut Vec<ActiveSeq>) {
    let mut i = 0;
    while i < active.len() {
        let done = active[i].generated.len() >= active[i].max_new
            || core.slot_len(active[i].slot) >= core.max_seq;
        if !done {
            i += 1;
            continue;
        }
        let seq = active.swap_remove(i);
        shared.stats.lock().unwrap().record_gen_done();
        send_line(
            &seq.sink,
            &ServerMsg::Done {
                id: seq.id,
                tokens: seq.generated,
                prompt_len: seq.prompt_len,
                ttft_ms: seq.ttft_ms,
                latency_ms: seq.enqueued.elapsed().as_secs_f64() * 1e3,
            }
            .encode(),
        );
        core.free_slot(seq.slot);
    }
}

/// Terminal decode-worker failure: fail queued generate requests so no
/// client is left hanging (the scoring pool is unaffected).
fn drain_with_errors(shared: &Shared, msg: &str) {
    while let Some(req) = shared.gen_queue.pop_blocking() {
        shared.stats.lock().unwrap().gen_failed += 1;
        send_line(&req.sink, &ServerMsg::error(Some(req.id), "exec_failed", msg).encode());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The slot-quantization edge cases the continuous batcher hits:
    /// no live rows, a tile that exceeds capacity, and tile 1.
    #[test]
    fn quantize_rows_edges() {
        // no live rows: nothing executes
        assert_eq!(quantize_rows(0, 4, 8), 0);
        // round up to the containing tile multiple
        assert_eq!(quantize_rows(1, 4, 8), 4);
        assert_eq!(quantize_rows(3, 4, 8), 4);
        assert_eq!(quantize_rows(5, 4, 8), 8);
        assert_eq!(quantize_rows(8, 4, 8), 8);
        // rounding target past capacity is capped
        assert_eq!(quantize_rows(3, 16, 8), 8);
        assert_eq!(quantize_rows(1, 16, 8), 8);
        // tile 1: the identity (no padding ever)
        assert_eq!(quantize_rows(1, 1, 8), 1);
        assert_eq!(quantize_rows(7, 1, 8), 7);
        // degenerate tile 0 behaves like 1 (round_target clamps)
        assert_eq!(quantize_rows(3, 0, 8), 3);
        // capacity smaller than live never shrinks the live set
        assert_eq!(quantize_rows(5, 4, 3), 5);
        // quantized never exceeds the full-shape baseline
        for live in 1..=8 {
            assert!(quantize_rows(live, 4, 8) <= 8);
            assert!(quantize_rows(live, 4, 8) >= live);
        }
    }

    #[test]
    fn slot_policy_parsing() {
        assert_eq!(SlotPolicy::parse("tile").unwrap(), SlotPolicy::TileQuantized);
        assert_eq!(SlotPolicy::parse("tile-quantized").unwrap(), SlotPolicy::TileQuantized);
        assert_eq!(SlotPolicy::parse("full").unwrap(), SlotPolicy::Full);
        assert_eq!(SlotPolicy::parse("full").unwrap().name(), "full");
        assert_eq!(SlotPolicy::parse("tile").unwrap().name(), "tile");
        assert!(SlotPolicy::parse("bogus").is_err());
    }
}
