//! Dense f32 linear algebra primitives plus the **naive reference
//! GEMMs** for the native backend.
//!
//! The production matmul path is [`super::kernels`] (cache-blocked,
//! packed, multithreaded); the `matmul` / `matmul_nt` /
//! `add_matmul_tn` here are the single-loop reference implementations
//! the property tests and the `kernel_throughput` bench compare
//! against. They accumulate each output element with a single
//! ascending-order chain, and the blocked kernels preserve that chain
//! exactly — so "reference" means *bitwise* reference, not just
//! approximately equal. The inner loops are branch-free on dense
//! operands (a value-sparsity test in the hot loop defeats
//! vectorization; sparsity is exploited only where routing masks make
//! it structural, e.g. the causal-attention backward).
//!
//! `axpy` / `dot` / softmax / sigmoid remain the production
//! elementwise primitives for both paths.

// index-heavy numeric kernels: explicit loops mirror the math
#![allow(clippy::needless_range_loop)]

use crate::util::dtype::widen;

/// y += alpha * x (fused accumulate row).
#[inline]
pub fn axpy(alpha: f32, x: &[f32], y: &mut [f32]) {
    debug_assert_eq!(x.len(), y.len());
    for (yi, xi) in y.iter_mut().zip(x) {
        *yi += alpha * xi;
    }
}

/// y += alpha * widen(x): `axpy` with a bf16 source row, widened on
/// read. The bf16 decode attention path streams half the V bytes.
#[inline]
pub fn axpy_wb(alpha: f32, x: &[u16], y: &mut [f32]) {
    debug_assert_eq!(x.len(), y.len());
    for (yi, &xi) in y.iter_mut().zip(x) {
        *yi += alpha * widen(xi);
    }
}

#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(x, y)| x * y).sum()
}

/// `dot` with a bf16 second operand, widened on read; the accumulator
/// stays f32 with the same ascending summation order as `dot`.
#[inline]
pub fn dot_wb(a: &[f32], b: &[u16]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(x, &y)| x * widen(y)).sum()
}

/// C = A @ B with A (m,k), B (k,n), all row-major (naive reference).
pub fn matmul(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    let mut out = vec![0f32; m * n];
    for i in 0..m {
        let arow = &a[i * k..(i + 1) * k];
        let orow = &mut out[i * n..(i + 1) * n];
        for (l, &v) in arow.iter().enumerate() {
            axpy(v, &b[l * n..(l + 1) * n], orow);
        }
    }
    out
}

/// C += A^T @ B with A (t,m), B (t,n): the weight-gradient layout
/// (naive reference).
pub fn add_matmul_tn(out: &mut [f32], a: &[f32], b: &[f32], t: usize, m: usize, n: usize) {
    debug_assert_eq!(a.len(), t * m);
    debug_assert_eq!(b.len(), t * n);
    debug_assert_eq!(out.len(), m * n);
    for r in 0..t {
        let arow = &a[r * m..(r + 1) * m];
        let brow = &b[r * n..(r + 1) * n];
        for (i, &v) in arow.iter().enumerate() {
            axpy(v, brow, &mut out[i * n..(i + 1) * n]);
        }
    }
}

/// C = A @ B^T with A (m,k), B (n,k): the activation-gradient layout
/// (naive reference; both operands row-contiguous over k).
pub fn matmul_nt(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), n * k);
    let mut out = vec![0f32; m * n];
    for i in 0..m {
        let arow = &a[i * k..(i + 1) * k];
        for j in 0..n {
            out[i * n + j] = dot(arow, &b[j * k..(j + 1) * k]);
        }
    }
    out
}

/// In-place row softmax over an (rows, cols) matrix.
pub fn softmax_rows(x: &mut [f32], rows: usize, cols: usize) {
    debug_assert_eq!(x.len(), rows * cols);
    for r in 0..rows {
        let row = &mut x[r * cols..(r + 1) * cols];
        softmax_inplace(row);
    }
}

/// In-place softmax of one row (max-subtracted, like jax.nn.softmax).
pub fn softmax_inplace(row: &mut [f32]) {
    let mx = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let mut sum = 0f32;
    for v in row.iter_mut() {
        *v = (*v - mx).exp();
        sum += *v;
    }
    let inv = 1.0 / sum;
    for v in row.iter_mut() {
        *v *= inv;
    }
}

#[inline]
pub fn sigmoid(x: f32) -> f32 {
    1.0 / (1.0 + (-x).exp())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matmul_small() {
        // A (2,3) @ B (3,2)
        let a = [1.0, 2.0, 3.0, 4.0, 5.0, 6.0];
        let b = [7.0, 8.0, 9.0, 10.0, 11.0, 12.0];
        let c = matmul(&a, &b, 2, 3, 2);
        assert_eq!(c, vec![58.0, 64.0, 139.0, 154.0]);
    }

    #[test]
    fn transposed_layouts_agree() {
        // random-ish small matrices; cross-check the three layouts
        let m = 3;
        let k = 4;
        let n = 2;
        let a: Vec<f32> = (0..m * k).map(|i| (i as f32 * 0.7).sin()).collect();
        let b: Vec<f32> = (0..k * n).map(|i| (i as f32 * 0.3).cos()).collect();
        let c = matmul(&a, &b, m, k, n);

        // A @ B == (A^T)^T @ B via add_matmul_tn with A^T stored (k,m)
        let mut at = vec![0f32; k * m];
        for i in 0..m {
            for l in 0..k {
                at[l * m + i] = a[i * k + l];
            }
        }
        let mut c2 = vec![0f32; m * n];
        add_matmul_tn(&mut c2, &at, &b, k, m, n);
        for (x, y) in c.iter().zip(&c2) {
            assert!((x - y).abs() < 1e-5);
        }

        // A @ B == A @ (B^T)^T via matmul_nt with B^T stored (n,k)
        let mut bt = vec![0f32; n * k];
        for l in 0..k {
            for j in 0..n {
                bt[j * k + l] = b[l * n + j];
            }
        }
        let c3 = matmul_nt(&a, &bt, m, k, n);
        for (x, y) in c.iter().zip(&c3) {
            assert!((x - y).abs() < 1e-5);
        }
    }

    #[test]
    fn softmax_rows_normalize() {
        let mut x = vec![1.0, 2.0, 3.0, -1.0, 0.0, 1.0];
        softmax_rows(&mut x, 2, 3);
        for r in 0..2 {
            let s: f32 = x[r * 3..(r + 1) * 3].iter().sum();
            assert!((s - 1.0).abs() < 1e-6);
        }
        assert!(x[2] > x[1] && x[1] > x[0]);
    }

    #[test]
    fn bf16_dot_axpy_match_f32_on_roundtripped_operands() {
        use crate::util::dtype::{narrow_slice, roundtrip_slice};
        let a: Vec<f32> = (0..17).map(|i| (i as f32 * 0.37).sin()).collect();
        let b: Vec<f32> = (0..17).map(|i| (i as f32 * 0.91).cos()).collect();
        let bq = narrow_slice(&b);
        let br = roundtrip_slice(&b);
        // dot_wb is bitwise the f32 dot against the widened operand
        assert_eq!(dot_wb(&a, &bq), dot(&a, &br));
        let mut y1 = a.clone();
        let mut y2 = a.clone();
        axpy_wb(0.7, &bq, &mut y1);
        axpy(0.7, &br, &mut y2);
        assert_eq!(y1, y2);
    }

    #[test]
    fn sigmoid_bounds() {
        assert!((sigmoid(0.0) - 0.5).abs() < 1e-7);
        assert!(sigmoid(20.0) > 0.999_999);
        assert!(sigmoid(-20.0) < 1e-6);
    }
}
