//! Micro-benchmark harness + table printing (criterion replacement; the
//! crate is unavailable offline — see DESIGN.md "Substitutions").
//!
//! Usage inside a `[[bench]] harness = false` target:
//!
//! ```ignore
//! let mut b = bench::Bencher::new("routing/tc_topk");
//! b.iter(|| tc_topk(&scores, t, e, k));
//! println!("{}", b.report());
//! ```

pub mod figures;

use std::time::{Duration, Instant};

use crate::util::stats::Summary;

/// Prevent the optimizer from discarding a value (std::hint::black_box).
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// Timing configuration.
#[derive(Debug, Clone, Copy)]
pub struct BenchConfig {
    pub warmup: Duration,
    pub measure: Duration,
    pub min_samples: usize,
    pub max_samples: usize,
}

impl Default for BenchConfig {
    fn default() -> Self {
        BenchConfig {
            warmup: Duration::from_millis(100),
            measure: Duration::from_millis(400),
            min_samples: 10,
            max_samples: 10_000,
        }
    }
}

/// One benchmark: collects per-iteration wall times.
pub struct Bencher {
    pub name: String,
    pub cfg: BenchConfig,
    samples_s: Vec<f64>,
}

impl Bencher {
    pub fn new(name: &str) -> Bencher {
        Bencher { name: name.to_string(), cfg: BenchConfig::default(), samples_s: Vec::new() }
    }

    pub fn with_config(name: &str, cfg: BenchConfig) -> Bencher {
        Bencher { name: name.to_string(), cfg, samples_s: Vec::new() }
    }

    /// Run `f` repeatedly: warmup phase, then sample until the measure
    /// budget or max_samples is reached.
    pub fn iter<T, F: FnMut() -> T>(&mut self, mut f: F) -> Summary {
        let warm_until = Instant::now() + self.cfg.warmup;
        while Instant::now() < warm_until {
            black_box(f());
        }
        self.samples_s.clear();
        let measure_until = Instant::now() + self.cfg.measure;
        loop {
            let t0 = Instant::now();
            black_box(f());
            self.samples_s.push(t0.elapsed().as_secs_f64());
            let done_budget =
                Instant::now() >= measure_until && self.samples_s.len() >= self.cfg.min_samples;
            if done_budget || self.samples_s.len() >= self.cfg.max_samples {
                break;
            }
        }
        Summary::of(&self.samples_s)
    }

    pub fn summary(&self) -> Summary {
        Summary::of(&self.samples_s)
    }

    /// criterion-style one-line report.
    pub fn report(&self) -> String {
        let s = self.summary();
        format!(
            "{:<44} time: [{} {} {}]  ({} samples)",
            self.name,
            fmt_time(s.min),
            fmt_time(s.median),
            fmt_time(s.max),
            s.n
        )
    }
}

/// Human duration formatting.
pub fn fmt_time(s: f64) -> String {
    if s < 1e-6 {
        format!("{:.1}ns", s * 1e9)
    } else if s < 1e-3 {
        format!("{:.2}µs", s * 1e6)
    } else if s < 1.0 {
        format!("{:.2}ms", s * 1e3)
    } else {
        format!("{:.2}s", s)
    }
}

// ---------------------------------------------------------------------------
// Paper-style table printer
// ---------------------------------------------------------------------------

/// Fixed-width table with a title, printed to stdout — every bench emits
/// the corresponding paper table/figure through this.
pub struct Table {
    title: String,
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Table {
        Table {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len(), "column count mismatch");
        self.rows.push(cells.to_vec());
    }

    pub fn to_string(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for r in &self.rows {
            for (i, c) in r.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = format!("\n== {} ==\n", self.title);
        let line = |cells: &[String], w: &[usize]| -> String {
            cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:<w$}", c, w = w[i] + 2))
                .collect::<String>()
        };
        out.push_str(&line(&self.headers, &widths));
        out.push('\n');
        out.push_str(&"-".repeat(widths.iter().map(|w| w + 2).sum()));
        out.push('\n');
        for r in &self.rows {
            out.push_str(&line(r, &widths));
            out.push('\n');
        }
        out
    }

    pub fn print(&self) {
        println!("{}", self.to_string());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bencher_collects_samples() {
        let mut b = Bencher::with_config(
            "noop",
            BenchConfig {
                warmup: Duration::from_millis(1),
                measure: Duration::from_millis(10),
                min_samples: 5,
                max_samples: 100,
            },
        );
        let s = b.iter(|| 1 + 1);
        assert!(s.n >= 5);
        assert!(s.min >= 0.0 && s.median >= s.min);
        assert!(b.report().contains("noop"));
    }

    #[test]
    fn fmt_time_ranges() {
        assert!(fmt_time(5e-9).ends_with("ns"));
        assert!(fmt_time(5e-6).ends_with("µs"));
        assert!(fmt_time(5e-3).ends_with("ms"));
        assert!(fmt_time(5.0).ends_with('s'));
    }

    #[test]
    fn table_formats() {
        let mut t = Table::new("demo", &["a", "bb"]);
        t.row(&["1".into(), "2".into()]);
        let s = t.to_string();
        assert!(s.contains("demo") && s.contains("bb"));
        assert_eq!(s.lines().count(), 5);
    }

    #[test]
    #[should_panic(expected = "column count mismatch")]
    fn table_checks_columns() {
        let mut t = Table::new("demo", &["a"]);
        t.row(&["1".into(), "2".into()]);
    }
}
