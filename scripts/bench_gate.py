#!/usr/bin/env python3
"""Perf-trajectory gate for the serving + kernel benches (stdlib only).

Reads the stdout of one or more bench runs (``serve_gateway``,
``decode_continuous``, ``kernel_throughput``), extracts each run's
one-line JSON record (the line starting with ``{"bench":``), assembles
a per-PR trajectory record ``BENCH_pr<N>.json``, and compares the
watched metrics against the most recent record committed under
``bench/records/``. A metric that regresses by more than 20% (plus a
small absolute noise floor) fails the gate.

Watched metrics, each with a direction:

- ``padding_frac`` / ``decode_padding_frac`` — tile-waste fractions,
  lower is better (floor: +0.02 absolute);
- ``p99_ms`` / ``ttft_p99_ms`` — tail latencies, lower is better
  (floor: +1.0 ms, CI runners are noisy at millisecond scale);
- ``gflops`` — kernel throughput, **higher** is better: the gate fires
  on a >20% *drop* (floor: -0.5 GFLOP/s);
- ``weight_gb_s`` — effective weight-operand bandwidth of a kernel
  (streamed weight bytes over median time), **higher** is better
  (floor: -0.5 GB/s); each row gates against its own dtype's record,
  so a bf16 row is never compared against an f32 row;
- ``tokens_per_s`` — serving throughput, **higher** is better (floor:
  -50 tokens/s, small CI workloads are timer-noisy);
- ``decode_tokens_per_s`` — generation throughput, **higher** is better
  (floor: -200 tokens/s, the decode workloads are small and timer-noisy);
- ``accepted_per_step`` — speculative amortization (tokens emitted per
  verify round), **higher** is better (floor: -0.1 tokens/step; the
  workloads are deterministic, so this mostly guards against acceptance
  logic regressions);
- ``residency_hit_rate`` — tiered expert-store hit rate (acquisitions
  served from RAM over all acquisitions), **higher** is better (floor:
  -0.02 absolute; the budget sweep is deterministic, so this guards the
  prefetch/eviction logic, and each budget point gates against its own
  row);
- ``prefetch_p95_us`` — expert prefetch submit-to-resident latency
  tail, lower is better (floor: +200 us, CI disks are noisy at
  microsecond scale);
- ``shed_rate`` — fraction of trace-replay requests shed at the top of
  the saturation ladder (``trace_saturation``), lower is better
  (floor: +0.05 absolute; shedding under overload is by design, the
  gate guards against a policy suddenly shedding *more* at the same
  offered load);
- ``knee_rps`` — the highest offered load a batching policy serves
  with <= 5% shed in the saturation sweep, **higher** is better
  (floor: -5 req/s; the knee moving down means serving capacity
  regressed);
- ``failover_p99_ms`` — p99 of the front tier's failover latency in
  the scripted replica-death drill (``trace_saturation``), lower is
  better (floor: +25 ms, the drill's one transport failure rides on
  CI-noisy connect/retry timing);
- ``front_success_rate`` — fraction of drill requests answered through
  the front across the replica death, **higher** is better (floor:
  -0.02 absolute; this should be 1.0 — anything lost during failover
  is a retry-path regression);
- ``obs_overhead_frac`` — tracing overhead of the span flight recorder
  (``trace_saturation``: throughput with tracing off over throughput
  with every request sampled; 1.0 = free), lower is better and gated
  at a tight per-metric factor of 1.05 instead of the default 1.2 —
  instrumentation that costs more than ~5% throughput defeats an
  always-on flight recorder (floor: +0.02 absolute for timer noise).

With no committed record (the trajectory's first datapoint) the gate
passes and prints the record to commit. To extend the trajectory, copy
the uploaded ``BENCH_pr<N>.json`` artifact into ``bench/records/`` when
merging.

Besides the pass/fail verdict the gate prints a per-metric delta table
(old, new, delta, limit, verdict) — written to ``GITHUB_STEP_SUMMARY``
as a markdown table when that file is set (CI step summaries), plain
text on stdout otherwise.
"""

import argparse
import glob
import json
import os
import re
import sys

# metric -> (unit, absolute noise floor, direction[, regression factor])
# the optional 4th element overrides REGRESSION_FACTOR for metrics
# gated tighter than the default 20%
WATCHED = {
    "padding_frac": ("frac", 0.02, "lower"),
    "decode_padding_frac": ("frac", 0.02, "lower"),
    "p99_ms": ("ms", 1.0, "lower"),
    "ttft_p99_ms": ("ms", 1.0, "lower"),
    "gflops": ("gflops", 0.5, "higher"),
    "weight_gb_s": ("GB/s", 0.5, "higher"),
    "tokens_per_s": ("tokens/s", 50.0, "higher"),
    "decode_tokens_per_s": ("tokens/s", 200.0, "higher"),
    "accepted_per_step": ("tokens/step", 0.1, "higher"),
    "residency_hit_rate": ("frac", 0.02, "higher"),
    "prefetch_p95_us": ("us", 200.0, "lower"),
    "shed_rate": ("frac", 0.05, "lower"),
    "knee_rps": ("req/s", 5.0, "higher"),
    "failover_p99_ms": ("ms", 25.0, "lower"),
    "front_success_rate": ("frac", 0.02, "higher"),
    "obs_overhead_frac": ("frac", 0.02, "lower", 1.05),
}
REGRESSION_FACTOR = 1.2


def extract_record(path):
    """The bench's one-line JSON record from its captured stdout."""
    with open(path, "r", encoding="utf-8") as f:
        for line in f:
            line = line.strip()
            if line.startswith('{"bench":'):
                return json.loads(line)
    raise SystemExit(f"bench_gate: no JSON record line in {path}")


def label_for(node, index):
    """Stable path label for a list element: prefer policy/shape names."""
    if isinstance(node, dict):
        for key in ("slot_policy", "policy", "name", "bench"):
            if isinstance(node.get(key), str):
                return node[key]
    return str(index)


def collect_metrics(node, path, out):
    """Flatten watched numeric leaves into {'a/b/metric': value}."""
    if isinstance(node, dict):
        for key, value in node.items():
            if key in WATCHED and isinstance(value, (int, float)):
                out["/".join(path + [key])] = float(value)
            else:
                collect_metrics(value, path + [key], out)
    elif isinstance(node, list):
        for i, value in enumerate(node):
            collect_metrics(value, path + [label_for(value, i)], out)


def latest_record(records_dir):
    """(path, parsed) of the highest-numbered committed record."""
    best = None
    for path in glob.glob(os.path.join(records_dir, "BENCH_pr*.json")):
        m = re.search(r"BENCH_pr(\d+)\.json$", os.path.basename(path))
        if not m:
            continue
        n = int(m.group(1))
        if best is None or n > best[0]:
            best = (n, path)
    if best is None:
        return None, None
    with open(best[1], "r", encoding="utf-8") as f:
        return best[1], json.load(f)


def compare(old, new):
    """Regression list: watched metrics worse than factor + floor, in
    each metric's own direction (latency/waste up, throughput down).
    Metrics absent from the committed record (new bench rows) are
    reported back so the gate can announce them instead of silently
    passing them. Also returns the full per-metric delta table."""
    old_metrics, new_metrics = {}, {}
    collect_metrics(old.get("benches", {}), [], old_metrics)
    collect_metrics(new.get("benches", {}), [], new_metrics)
    regressions = []
    skipped = []
    rows = []  # (key, unit, old, new, delta_pct, limit, verdict)
    for key, new_val in sorted(new_metrics.items()):
        if key not in old_metrics:
            skipped.append(key)
            continue
        old_val = old_metrics[key]
        metric = key.rsplit("/", 1)[-1]
        spec = WATCHED[metric]
        unit, floor, direction = spec[0], spec[1], spec[2]
        factor = spec[3] if len(spec) > 3 else REGRESSION_FACTOR
        if direction == "lower":
            limit = old_val * factor + floor
            failed = new_val > limit
            rule = f"old * {factor} + {floor}"
        else:
            limit = old_val / factor - floor
            failed = new_val < limit
            rule = f"old / {factor} - {floor}"
        delta_pct = (new_val - old_val) / old_val * 100.0 if old_val else float("inf")
        rows.append((key, unit, old_val, new_val, delta_pct, limit, "FAIL" if failed else "ok"))
        if failed:
            regressions.append(
                f"  {key}: {old_val:.4g} -> {new_val:.4g} "
                f"(limit {limit:.4g} = {rule})"
            )
    return rows, regressions, skipped


def emit_delta_table(rows):
    """Per-metric delta table: markdown into GITHUB_STEP_SUMMARY when
    CI provides one, plain text on stdout otherwise."""
    if not rows:
        return
    summary_path = os.environ.get("GITHUB_STEP_SUMMARY")
    if summary_path:
        lines = [
            "### bench_gate: per-metric deltas",
            "",
            "| metric | old | new | delta | limit | verdict |",
            "| --- | ---: | ---: | ---: | ---: | :---: |",
        ]
        for key, unit, old_val, new_val, delta_pct, limit, verdict in rows:
            lines.append(
                f"| `{key}` ({unit}) | {old_val:.4g} | {new_val:.4g} "
                f"| {delta_pct:+.1f}% | {limit:.4g} | {verdict} |"
            )
        with open(summary_path, "a", encoding="utf-8") as f:
            f.write("\n".join(lines) + "\n")
        print(f"bench_gate: delta table appended to step summary ({len(rows)} metrics)")
    else:
        width = max(len(r[0]) for r in rows)
        print("bench_gate: per-metric deltas:")
        for key, unit, old_val, new_val, delta_pct, limit, verdict in rows:
            print(
                f"  {key:<{width}}  {old_val:>10.4g} -> {new_val:>10.4g}  "
                f"{delta_pct:+7.1f}%  limit {limit:.4g} [{unit}]  {verdict}"
            )


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--inputs", nargs="+", required=True, help="bench stdout captures")
    ap.add_argument("--records", default="bench/records", help="committed trajectory dir")
    ap.add_argument("--pr", type=int, default=0, help="PR number for the record name")
    ap.add_argument("--out", required=True, help="where to write the new record")
    args = ap.parse_args()

    benches = {}
    for path in args.inputs:
        rec = extract_record(path)
        name = rec.get("bench", os.path.basename(path))
        benches[name] = rec
    record = {"pr": args.pr, "benches": benches}
    with open(args.out, "w", encoding="utf-8") as f:
        json.dump(record, f, indent=2, sort_keys=True)
        f.write("\n")
    print(f"bench_gate: wrote {args.out} ({', '.join(sorted(benches))})")

    prev_path, prev = latest_record(args.records)
    if prev is None:
        print(
            f"bench_gate: no committed record under {args.records}/ — first trajectory "
            f"datapoint, gate passes; commit {os.path.basename(args.out)} there to arm it"
        )
        return 0
    rows, regressions, skipped = compare(prev, record)
    print(f"bench_gate: compared {len(rows)} watched metrics against {prev_path}")
    emit_delta_table(rows)
    for key in skipped:
        print(f"bench_gate: {key}: no baseline record — metric skipped")
    if skipped:
        print(
            f"bench_gate: {len(skipped)} metric(s) arm once "
            f"{os.path.basename(args.out)} is committed to {args.records}/"
        )
    if regressions:
        print("bench_gate: REGRESSIONS (>20% worse than the committed record):")
        print("\n".join(regressions))
        return 1
    print("bench_gate: no regressions")
    return 0


if __name__ == "__main__":
    sys.exit(main())
