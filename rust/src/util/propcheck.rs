//! Micro property-testing runner (proptest is unavailable offline).
//!
//! A property is a closure over a [`Gen`] (seeded PRNG with sampling
//! helpers). The runner executes it for N seeds; on failure it reports
//! the seed so the case can be replayed deterministically — a light
//! substitute for shrinking.

use super::prng::Prng;

/// Sampling context handed to properties.
pub struct Gen {
    pub rng: Prng,
    pub seed: u64,
}

impl Gen {
    /// Uniform usize in [lo, hi].
    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        self.rng.range(lo as i64, hi as i64) as usize
    }

    /// Pick one of the given choices.
    pub fn choice<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.rng.below(xs.len() as u64) as usize]
    }

    /// Uniform f64 in [lo, hi).
    pub fn f64_in(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.rng.f64() * (hi - lo)
    }

    /// Vector of standard normals.
    pub fn normals(&mut self, n: usize) -> Vec<f64> {
        (0..n).map(|_| self.rng.normal()).collect()
    }
}

/// Run `prop` for `cases` deterministic seeds; panic with the seed on the
/// first failure (properties signal failure by panicking, e.g. assert!).
pub fn check<F: Fn(&mut Gen)>(name: &str, cases: u64, prop: F) {
    for seed in 0..cases {
        let mut g = Gen { rng: Prng::new(0xC0FFEE ^ seed.wrapping_mul(0x9E3779B9)), seed };
        let res = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| prop(&mut g)));
        if let Err(e) = res {
            let msg = e
                .downcast_ref::<String>()
                .map(|s| s.as_str())
                .or_else(|| e.downcast_ref::<&str>().copied())
                .unwrap_or("<non-string panic>");
            panic!("property {name:?} failed at seed {seed}: {msg}");
        }
    }
}

/// Replay a single seed (for debugging a reported failure).
pub fn replay<F: FnMut(&mut Gen)>(seed: u64, mut prop: F) {
    let mut g = Gen { rng: Prng::new(0xC0FFEE ^ seed.wrapping_mul(0x9E3779B9)), seed };
    prop(&mut g);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0u64;
        check("trivial", 25, |g| {
            let x = g.usize_in(1, 10);
            assert!(x >= 1 && x <= 10);
        });
        // count via replay of a couple of seeds is deterministic
        replay(3, |g| {
            count += 1;
            let _ = g.f64_in(-1.0, 1.0);
        });
        assert_eq!(count, 1);
    }

    #[test]
    #[should_panic(expected = "failed at seed")]
    fn failing_property_reports_seed() {
        check("always-false", 5, |_g| {
            assert!(false, "intentional");
        });
    }

    #[test]
    fn choice_and_ranges() {
        check("gen-helpers", 20, |g| {
            let c = *g.choice(&[2usize, 4, 8]);
            assert!([2, 4, 8].contains(&c));
            let f = g.f64_in(3.0, 4.0);
            assert!((3.0..4.0).contains(&f));
            assert_eq!(g.normals(5).len(), 5);
        });
    }
}
