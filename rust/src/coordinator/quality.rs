//! Routing-quality experiments (Tables 2, 5, 6, 7, 8): train the AOT LM
//! with a routing method, then evaluate with TC top-K — the paper's
//! protocol ("use TR for training and during evaluation switch to token
//! choice", Section 6.3.1).

use anyhow::Result;

use crate::coordinator::{Trainer, TrainerConfig};

/// Outcome of one quality run.
#[derive(Debug, Clone)]
pub struct QualityRun {
    pub config: String,
    pub router: String,
    pub steps: u64,
    pub train_ce: f64,
    pub val_ce: f64,
}

impl QualityRun {
    /// Training perplexity (`exp` of the train CE).
    pub fn train_ppl(&self) -> f64 {
        self.train_ce.exp()
    }

    /// Validation perplexity (`exp` of the validation CE).
    pub fn val_ppl(&self) -> f64 {
        self.val_ce.exp()
    }
}

/// Train `config` with `router` for `steps`, return final smoothed train
/// CE and held-out CE under TC top-K evaluation.
pub fn train_and_eval(
    config: &str,
    router: &str,
    steps: u64,
    lr: f32,
    seed: u64,
) -> Result<QualityRun> {
    let mut t = Trainer::new(TrainerConfig {
        config_name: config.to_string(),
        router: router.to_string(),
        steps,
        warmup: (steps / 10).max(1),
        lr,
        seed,
        log_every: 0,
        eval_every: 0,
        ..Default::default()
    })?;
    let train_ce = t.run()?;
    let val_ce = t.evaluate(8)?;
    Ok(QualityRun {
        config: config.to_string(),
        router: router.to_string(),
        steps,
        train_ce,
        val_ce,
    })
}

/// Number of steps for quality benches, overridable via
/// `SONIC_BENCH_STEPS` (the default keeps `cargo bench` under a few
/// minutes on one core; raise it for tighter comparisons).
pub fn bench_steps() -> u64 {
    std::env::var("SONIC_BENCH_STEPS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(150)
}
