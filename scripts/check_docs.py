#!/usr/bin/env python3
"""Doc-consistency checks for the serving stack (stdlib only).

Two checks, run by CI's python job:

1. **Flag coverage (fatal).** Every CLI flag defined in
   ``rust/src/main.rs`` (each ``.opt("name", ...)`` / ``.req("name",
   ...)`` / ``.multi("name", ...)`` call) must appear as ``--name`` in
   ``docs/OPERATIONS.md``.
   A flag added without documentation fails the build; a documented
   flag that no longer exists in main.rs fails too (stale docs).

2. **Missing-docs baseline (fatal only on regression).** A textual
   ``missing_docs`` lint over the documented serving modules
   (``rust/src/{gateway,spec,memory,coordinator,routing,front,obs}``): public
   items without a preceding ``///`` doc comment are counted and
   compared against ``MISSING_DOCS_BASELINE``. New undocumented public
   items fail; improvements print a reminder to ratchet the baseline
   down. The compiler-grade version of this lint is the opt-in
   ``strict-docs`` cargo feature (``cargo check --features
   strict-docs`` surfaces real ``missing_docs`` warnings); this
   textual mirror exists so the count is enforceable without making
   every local build noisy.

Usage: ``python3 scripts/check_docs.py`` from the repo root (CI), or
from anywhere — paths resolve relative to this script.
"""

import os
import re
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
MAIN_RS = os.path.join(ROOT, "rust", "src", "main.rs")
OPERATIONS = os.path.join(ROOT, "docs", "OPERATIONS.md")

# Serving modules whose public API docs/ARCHITECTURE.md documents and
# the strict-docs feature lints.
LINTED_DIRS = ["gateway", "spec", "memory", "coordinator", "routing", "front", "obs"]

# Undocumented-public-item count accepted today. Lower it when items
# gain docs; never raise it — new public items must be documented.
MISSING_DOCS_BASELINE = 0

FLAG_RE = re.compile(r"\.(?:opt|req|multi)\(\s*\"([a-z0-9-]+)\"")
# flags the Cli type provides on every subcommand without an .opt() call
BUILTIN_FLAGS = {"help"}
PUB_ITEM_RE = re.compile(
    r"^\s*pub\s+(?:unsafe\s+)?(?:async\s+)?"
    r"(?:fn|struct|enum|trait|type|const|static|mod)\b"
)


def check_flags():
    """Every main.rs flag appears as --flag in OPERATIONS.md and the
    docs mention no flag that main.rs no longer defines."""
    with open(MAIN_RS, encoding="utf-8") as f:
        defined = set(FLAG_RE.findall(f.read()))
    with open(OPERATIONS, encoding="utf-8") as f:
        ops = f.read()
    documented = set(re.findall(r"`--([a-z0-9-]+)`", ops))
    missing = sorted(f for f in defined if f"`--{f}`" not in ops)
    stale = sorted(documented - defined - BUILTIN_FLAGS)
    errors = []
    for flag in missing:
        errors.append(f"flag --{flag} (rust/src/main.rs) is not documented in docs/OPERATIONS.md")
    for flag in stale:
        errors.append(f"docs/OPERATIONS.md documents --{flag}, which main.rs no longer defines")
    print(f"check_docs: {len(defined)} CLI flags defined, {len(defined) - len(missing)} documented")
    return errors


def module_has_inner_docs(dirpath, name):
    """True when rust module `name` declared in `dirpath` opens with a
    //! inner doc comment (attributes before it are fine)."""
    for cand in (
        os.path.join(dirpath, f"{name}.rs"),
        os.path.join(dirpath, name, "mod.rs"),
    ):
        if not os.path.exists(cand):
            continue
        with open(cand, encoding="utf-8") as f:
            for line in f:
                s = line.strip()
                if not s or s.startswith("#!["):
                    continue
                return s.startswith("//!")
    return False


def count_undocumented(path):
    """Public items in one .rs file with no preceding /// doc comment.

    Textual heuristic: the file is truncated at its #[cfg(test)]
    module, attributes and derives between the doc comment and the
    item are skipped, and anything not matching a pub item head is
    ignored (pub use re-exports and pub(crate) items carry no doc
    obligation, matching rustc's missing_docs)."""
    with open(path, encoding="utf-8") as f:
        lines = f.read().split("#[cfg(test)]")[0].splitlines()
    undocumented = []
    for i, line in enumerate(lines):
        if not PUB_ITEM_RE.match(line):
            continue
        # `pub mod foo;` is documented when foo's file opens with //!
        # inner docs — that is where this codebase docs its modules,
        # and it satisfies rustc's missing_docs too
        decl = re.match(r"\s*pub\s+mod\s+(\w+)\s*;", line)
        if decl and module_has_inner_docs(os.path.dirname(path), decl.group(1)):
            continue
        j = i - 1
        while j >= 0 and (
            lines[j].lstrip().startswith("#[") or lines[j].lstrip().startswith("#!")
            or (lines[j].strip() == "" and j > 0 and lines[j - 1].lstrip().startswith("//!"))
        ):
            j -= 1
        doc = j >= 0 and (
            lines[j].lstrip().startswith("///") or lines[j].lstrip().startswith("//!")
        )
        if not doc:
            undocumented.append((i + 1, line.strip()))
    return undocumented


def check_missing_docs():
    total = 0
    worst = []
    for d in LINTED_DIRS:
        base = os.path.join(ROOT, "rust", "src", d)
        for dirpath, _dirnames, filenames in os.walk(base):
            for name in sorted(filenames):
                if not name.endswith(".rs"):
                    continue
                path = os.path.join(dirpath, name)
                found = count_undocumented(path)
                total += len(found)
                rel = os.path.relpath(path, ROOT)
                worst.extend(f"  {rel}:{ln}: {text}" for ln, text in found)
    print(
        f"check_docs: {total} undocumented public items in "
        f"{{{','.join(LINTED_DIRS)}}} (baseline {MISSING_DOCS_BASELINE})"
    )
    if total > MISSING_DOCS_BASELINE:
        print("check_docs: new public items need /// docs (or ratchet intentionally):")
        print("\n".join(worst))
        return [
            f"undocumented public items rose to {total} (baseline "
            f"{MISSING_DOCS_BASELINE}); document the new items"
        ]
    if total < MISSING_DOCS_BASELINE:
        print(
            f"check_docs: improved! lower MISSING_DOCS_BASELINE to {total} "
            "in scripts/check_docs.py to lock it in"
        )
    return []


def main():
    errors = check_flags() + check_missing_docs()
    if errors:
        print("check_docs: FAILED")
        for e in errors:
            print(f"  - {e}")
        return 1
    print("check_docs: ok")
    return 0


if __name__ == "__main__":
    sys.exit(main())
