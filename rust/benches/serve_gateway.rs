//! Serving-gateway bench: batching-policy comparison at equal offered
//! load — the serving analogue of the paper's tile-waste ablation.
//!
//! Open-loop phase: `Immediate` vs `Deadline` vs `TileRounded` at the
//! same arrival rate, reporting p50/p99 latency and padding fraction
//! (padded rows / executed rows). `TileRounded` should pad strictly
//! less than `Immediate` by holding batches until the fill hits a
//! row-tile multiple; the price is queueing latency, which the p99
//! column makes visible. A closed-loop phase adds the latency-bound
//! throughput datapoint.
//!
//! Emits one JSON record (line starting with `{"bench":`) for the
//! bench trajectory. `SONIC_GATEWAY_BENCH_REQUESTS` overrides the
//! per-policy request count (CI smoke uses a small value).

use std::collections::BTreeMap;
use std::time::Duration;

use sonic_moe::gateway::loadgen::{run_inprocess, LoadgenConfig, LoadgenReport};
use sonic_moe::gateway::{BatchPolicy, GatewayConfig};
use sonic_moe::util::json::Json;

/// Simulated model latency per batch: dominates the native eval time so
/// the arrivals-per-execution ratio is stable across machines.
const WORKER_DELAY_MS: u64 = 25;
/// Offered load: ~2 arrivals per execution at the simulated latency —
/// the partial-fill regime where batching policy matters most.
const OPEN_RATE_RPS: f64 = 60.0;

fn gw_cfg(policy: BatchPolicy) -> GatewayConfig {
    GatewayConfig {
        artifacts_dir: "/nonexistent-artifacts-dir".to_string(),
        config: "small".to_string(),
        backend: "native".to_string(),
        addr: "127.0.0.1:0".to_string(),
        workers: 1,
        queue_cap: 256, // large: isolate padding from shedding
        policy,
        m_tile: 4, // the model batch — shapes {4, 8}
        checkpoint: None,
        worker_delay_ms: WORKER_DELAY_MS,
        ..GatewayConfig::default()
    }
}

fn run_policy(policy: BatchPolicy, requests: usize, rate: f64, seed: u64) -> LoadgenReport {
    let lg =
        LoadgenConfig { requests, clients: 2, rate, seq_hint: 32, seed, ..LoadgenConfig::default() };
    run_inprocess(gw_cfg(policy), lg).expect("loadgen run")
}

fn main() {
    let requests: usize = std::env::var("SONIC_GATEWAY_BENCH_REQUESTS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(48);
    let hold = Duration::from_millis(120);
    let policies = [
        BatchPolicy::Immediate,
        BatchPolicy::Deadline { max_wait: hold },
        BatchPolicy::TileRounded { m_tile: 4, max_wait: hold },
    ];

    println!(
        "serve_gateway: {} requests/policy, open-loop {OPEN_RATE_RPS} req/s, \
         worker delay {WORKER_DELAY_MS}ms, m_tile=4\n",
        requests
    );
    let mut open_reports = Vec::new();
    let mut tbl = sonic_moe::bench::Table::new(
        "open loop: equal offered load, policy decides padding vs latency",
        &["policy", "ok", "p50 ms", "p99 ms", "padding %", "batches", "tok/s"],
    );
    for p in policies {
        let r = run_policy(p, requests, OPEN_RATE_RPS, 42);
        tbl.row(&[
            r.policy.clone(),
            r.ok.to_string(),
            format!("{:.1}", r.p50_ms),
            format!("{:.1}", r.p99_ms),
            format!("{:.1}", 100.0 * r.padding_frac),
            r.batches.to_string(),
            format!("{:.0}", r.tokens_per_s),
        ]);
        open_reports.push(r);
    }
    tbl.print();

    let mut closed_reports = Vec::new();
    let mut tbl = sonic_moe::bench::Table::new(
        "closed loop: 4 clients, latency-bound throughput",
        &["policy", "ok", "req/s", "p50 ms", "p99 ms", "padding %"],
    );
    for p in [BatchPolicy::Immediate, BatchPolicy::TileRounded { m_tile: 4, max_wait: hold }] {
        let r = run_policy(p, requests, 0.0, 43);
        tbl.row(&[
            r.policy.clone(),
            r.ok.to_string(),
            format!("{:.1}", r.achieved_rps),
            format!("{:.1}", r.p50_ms),
            format!("{:.1}", r.p99_ms),
            format!("{:.1}", 100.0 * r.padding_frac),
        ]);
        closed_reports.push(r);
    }
    tbl.print();

    let imm = &open_reports[0];
    let tile = &open_reports[2];
    let tile_lower = tile.padding_frac < imm.padding_frac;
    println!(
        "tile-aware check: TileRounded padding {:.1}% vs Immediate {:.1}% at equal load — {}",
        100.0 * tile.padding_frac,
        100.0 * imm.padding_frac,
        if tile_lower { "LOWER (as predicted by Algorithm 4's serving analogue)" } else { "NOT lower (rerun with more requests)" }
    );

    let mut rec = BTreeMap::new();
    rec.insert("bench".to_string(), Json::Str("serve_gateway".to_string()));
    rec.insert("requests_per_policy".to_string(), Json::Num(requests as f64));
    rec.insert("open_rate_rps".to_string(), Json::Num(OPEN_RATE_RPS));
    rec.insert("worker_delay_ms".to_string(), Json::Num(WORKER_DELAY_MS as f64));
    rec.insert(
        "open_loop".to_string(),
        Json::Arr(open_reports.iter().map(|r| r.to_json()).collect()),
    );
    rec.insert(
        "closed_loop".to_string(),
        Json::Arr(closed_reports.iter().map(|r| r.to_json()).collect()),
    );
    rec.insert("tile_lower_padding_than_immediate".to_string(), Json::Bool(tile_lower));
    println!("{}", Json::Obj(rec));
}
