//! Leveled structured logging for the serving stack.
//!
//! A tiny `log::Log` backend replacing the ad-hoc stderr logger:
//! plain `[LEVEL] message` lines by default, one JSON object per line
//! under `--log-json` (machine-parseable drill output). The level
//! comes from `SONIC_LOG` (preferred) or `RUST_LOG` (back-compat),
//! defaulting to `info`:
//!
//! ```text
//! {"level":"warn","msg":"replica 1 probe failed","target":"sonic_moe::front","ts":1754560001.250}
//! ```
//!
//! `ts` is wall-clock seconds since the Unix epoch (logs correlate
//! across processes; span timestamps stay monotonic and
//! process-local).

use std::sync::atomic::{AtomicBool, Ordering};
use std::time::{SystemTime, UNIX_EPOCH};

use crate::util::json::Json;

struct ObsLogger {
    json: AtomicBool,
}

impl log::Log for ObsLogger {
    fn enabled(&self, metadata: &log::Metadata) -> bool {
        metadata.level() <= log::max_level()
    }

    fn log(&self, record: &log::Record) {
        if !self.enabled(record.metadata()) {
            return;
        }
        if self.json.load(Ordering::Relaxed) {
            let ts = SystemTime::now()
                .duration_since(UNIX_EPOCH)
                .map(|d| d.as_secs_f64())
                .unwrap_or(0.0);
            let mut m = std::collections::BTreeMap::new();
            m.insert(
                "level".to_string(),
                Json::Str(record.level().as_str().to_ascii_lowercase()),
            );
            m.insert("msg".to_string(), Json::Str(record.args().to_string()));
            m.insert("target".to_string(), Json::Str(record.target().to_string()));
            m.insert("ts".to_string(), Json::Num((ts * 1000.0).round() / 1000.0));
            eprintln!("{}", Json::Obj(m));
        } else {
            eprintln!("[{}] {}", record.level(), record.args());
        }
    }

    fn flush(&self) {}
}

static LOGGER: ObsLogger = ObsLogger { json: AtomicBool::new(false) };

/// Level filter from the environment: `SONIC_LOG` wins, `RUST_LOG` is
/// honored for back-compat, default `info`.
fn env_level() -> log::LevelFilter {
    let v = std::env::var("SONIC_LOG")
        .or_else(|_| std::env::var("RUST_LOG"))
        .unwrap_or_default();
    match v.to_ascii_lowercase().as_str() {
        "off" => log::LevelFilter::Off,
        "error" => log::LevelFilter::Error,
        "warn" => log::LevelFilter::Warn,
        "debug" => log::LevelFilter::Debug,
        "trace" => log::LevelFilter::Trace,
        _ => log::LevelFilter::Info,
    }
}

/// Install the logger (idempotent — a second install keeps the first
/// registration and just refreshes the level).
pub fn init() {
    log::set_max_level(env_level());
    let _ = log::set_logger(&LOGGER);
}

/// Switch line format at runtime (the `--log-json` flag, parsed after
/// [`init`] has already run).
pub fn set_json(json: bool) {
    LOGGER.json.store(json, Ordering::Relaxed);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_parse_defaults_to_info() {
        // can't set env vars safely in parallel tests; exercise the
        // formatter paths instead of the env lookup
        init();
        set_json(true);
        log::info!(target: "obs-log-test", "json line with \"quotes\"");
        set_json(false);
        log::info!(target: "obs-log-test", "plain line");
        let lvl = log::max_level();
        assert!(lvl >= log::LevelFilter::Error || lvl == log::LevelFilter::Off);
    }
}
