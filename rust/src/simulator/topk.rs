//! Top-K kernel bandwidth model (Appendix D / Figure 22).
//!
//! All top-K kernels are memory-bound on the (T, E) score read; they
//! differ in how much non-stream work sits on the critical path:
//!
//! - SonicMoE: register-resident bitonic network, one pass, ~peak BW;
//! - Triton example: same bit-packing idea, slightly lower achieved BW;
//! - PyTorch: radix-select with SMEM scans (two extra passes for large T);
//! - TileLang example: K-pass max-reduction (cost grows with K);
//! - RTop-K: iterative threshold bisection (iteration count ~ 8).

use super::hw::GpuSpec;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TopKImpl {
    SonicMoE,
    Torch,
    TritonEx,
    TileLang,
    RTopK,
}

impl TopKImpl {
    pub const ALL: [TopKImpl; 5] = [
        TopKImpl::SonicMoE,
        TopKImpl::Torch,
        TopKImpl::TritonEx,
        TopKImpl::TileLang,
        TopKImpl::RTopK,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            TopKImpl::SonicMoE => "SonicMoE",
            TopKImpl::Torch => "torch",
            TopKImpl::TritonEx => "triton",
            TopKImpl::TileLang => "tilelang",
            TopKImpl::RTopK => "RTop-K",
        }
    }

    /// Effective number of passes over the (T, E) input.
    fn passes(&self, _e: usize, k: usize) -> f64 {
        match self {
            TopKImpl::SonicMoE => 1.0,
            TopKImpl::TritonEx => 1.15,
            TopKImpl::Torch => 3.0, // radix select: 2 SMEM scans + gather
            TopKImpl::TileLang => k as f64, // K-pass max reduction
            TopKImpl::RTopK => 2.2, // ~8 bisection steps on registers + scan
        }
    }

    /// Fraction of streaming bandwidth reached per pass.
    fn bw_frac(&self, e: usize) -> f64 {
        let base = match self {
            TopKImpl::SonicMoE => 0.92,
            TopKImpl::TritonEx => 0.85,
            TopKImpl::Torch => 0.55,
            TopKImpl::TileLang => 0.80,
            TopKImpl::RTopK => 0.75,
        };
        // all kernels lose some efficiency for very wide rows (register
        // pressure / SMEM tiling); SonicMoE's sorting network grows as
        // log^2 E but stays register-resident.
        let width = 1.0 / (1.0 + (e as f64 / 4096.0) * 0.3);
        base * width
    }

    /// Kernel time for (T, E) scores of `bytes_per` element, selecting K.
    pub fn time_s(&self, t: usize, e: usize, k: usize, bytes_per: f64, hw: &GpuSpec) -> f64 {
        let bytes = t as f64 * e as f64 * bytes_per + 8.0 * (t * k) as f64;
        let eff = self.bw_frac(e);
        hw.stream_s(bytes * self.passes(e, k)) / eff + hw.launch_s
    }

    /// Achieved bandwidth (input bytes / time), the Figure 22 metric.
    pub fn bandwidth_gbps(&self, t: usize, e: usize, k: usize, bytes_per: f64, hw: &GpuSpec) -> f64 {
        let bytes = t as f64 * e as f64 * bytes_per;
        bytes / self.time_s(t, e, k, bytes_per, hw) / 1e9
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::simulator::hw::H100;

    #[test]
    fn sonic_fastest_across_configs() {
        for (t, e, k) in [(40960, 128, 8), (24576, 64, 4), (32768, 256, 16)] {
            let sonic = TopKImpl::SonicMoE.time_s(t, e, k, 4.0, &H100);
            for imp in [TopKImpl::Torch, TopKImpl::TritonEx, TopKImpl::TileLang, TopKImpl::RTopK] {
                assert!(
                    sonic < imp.time_s(t, e, k, 4.0, &H100),
                    "{:?} beat SonicMoE at T={t} E={e} K={k}",
                    imp
                );
            }
        }
    }

    #[test]
    fn tilelang_degrades_with_k() {
        let t = 32768;
        let e = 256;
        let bw8 = TopKImpl::TileLang.bandwidth_gbps(t, e, 8, 4.0, &H100);
        let bw16 = TopKImpl::TileLang.bandwidth_gbps(t, e, 16, 4.0, &H100);
        assert!(bw16 < bw8 * 0.6);
        // SonicMoE is K-independent up to the (T, K) output write
        let s8 = TopKImpl::SonicMoE.bandwidth_gbps(t, e, 8, 4.0, &H100);
        let s16 = TopKImpl::SonicMoE.bandwidth_gbps(t, e, 16, 4.0, &H100);
        assert!((s8 - s16).abs() / s8 < 0.08, "{s8} vs {s16}");
    }

    #[test]
    fn torch_much_slower_for_large_t() {
        let bw_sonic = TopKImpl::SonicMoE.bandwidth_gbps(40960, 128, 8, 4.0, &H100);
        let bw_torch = TopKImpl::Torch.bandwidth_gbps(40960, 128, 8, 4.0, &H100);
        assert!(bw_sonic / bw_torch > 3.0);
    }
}
