//! L3 training coordinator: owns parameters, optimizer state, the data
//! pipeline and the step loop; the grad-step artifact is a pure function
//! `(params, tokens) -> (loss, ce, grads)` executed through the
//! pluggable runtime backend (native CPU by default, PJRT behind the
//! `pjrt` feature).
//!
//! Data parallelism: the coordinator shards each global batch across
//! `workers` data-parallel ranks, runs the grad step per shard, and
//! all-reduces (averages) gradients before the optimizer update —
//! synchronous DP with the exact semantics of the paper's FSDP-2 runs
//! (rank-parallel *execution* is pointless on this 1-core testbed; the
//! wall-clock scaling story lives in `simulator::cluster`).

pub mod checkpoint;
pub mod decode;
pub mod dp;
pub mod metrics;
pub mod quality;
pub mod sampling;
pub mod serve;
pub mod trainer;

pub use trainer::{Trainer, TrainerConfig};
