//! §Perf (L3/L2): PJRT execution latency of the AOT artifacts — the
//! coordinator's hot loop. Reports per-step latency and end-to-end
//! tokens/s for the single-layer forward and the LM grad step.

use sonic_moe::bench::{black_box, BenchConfig, Bencher};
use sonic_moe::coordinator::{Trainer, TrainerConfig};
use sonic_moe::runtime::{artifacts_available, Runtime};
use sonic_moe::util::tensor::Tensor;
use std::time::Duration;

fn main() {
    if !artifacts_available("artifacts") {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    let cfg = BenchConfig {
        warmup: Duration::from_millis(500),
        measure: Duration::from_secs(2),
        min_samples: 5,
        max_samples: 1000,
    };

    // single MoE layer forward (small config)
    let mut rt = Runtime::open("artifacts", "small").unwrap();
    let spec = rt.manifest.artifacts["moe_layer_fwd_tc"].clone();
    let inputs: Vec<Tensor> = spec
        .inputs
        .iter()
        .map(|ts| {
            let mut t = Tensor::zeros(&ts.shape);
            for (i, x) in t.data.iter_mut().enumerate() {
                *x = ((i % 97) as f32 - 48.0) / 97.0;
            }
            t
        })
        .collect();
    let tokens_per = spec.inputs[0].shape[0];
    {
        let art = rt.artifact("moe_layer_fwd_tc").unwrap();
        let refs: Vec<&Tensor> = inputs.iter().collect();
        let mut b = Bencher::with_config("runtime/moe_layer_fwd small", cfg);
        let s = b.iter(|| black_box(art.execute_tensors(&refs).unwrap()));
        println!("{}  ({:.0} tokens/s)", b.report(), tokens_per as f64 / s.median);
    }

    // full LM grad step (small + medium)
    for config in ["small", "medium"] {
        let mut t = Trainer::new(TrainerConfig {
            config_name: config.into(),
            steps: 0,
            log_every: 0,
            ..Default::default()
        })
        .unwrap();
        let tokens = t.rt.manifest.model.batch * t.rt.manifest.model.seq_len;
        let mut b = Bencher::with_config(&format!("runtime/lm_grad_step {config}"), cfg);
        let mut i = 0u64;
        let s = b.iter(|| {
            i += 1;
            black_box(t.step(i).unwrap())
        });
        println!("{}  ({:.0} tokens/s)", b.report(), tokens as f64 / s.median);
    }
}
