//! Replica-balanced front tier: health-watched routing, failover and
//! graceful degradation across N gateway replicas.
//!
//! A `sonic-moe front` process fronts a static list of gateway
//! replicas (`--replica host:port[=model]`) and speaks the existing
//! line-JSON protocol of [`crate::gateway::protocol`] on both sides —
//! replicas see an ordinary client, clients see an ordinary gateway.
//! The front is a *line-level relay*: it peeks only `type`, `id` and
//! the optional `model` tag from each request line and forwards the
//! raw line verbatim, so every gateway feature (speculation, sampling,
//! future fields) passes through untouched.
//!
//! Per replica the front keeps (see [`replica`]):
//! - a **health watcher**: a periodic `stats` probe with timeout feeds
//!   a `Healthy/Degraded/Dead` state machine with a consecutive-failure
//!   circuit breaker; a dead replica keeps being probed (half-open)
//!   and one success restores it;
//! - a **peak-EWMA latency estimate** plus an in-flight count — the
//!   route-choice signal ([`router`]): lowest `ewma * (in_flight + 1)`
//!   among healthy model-matching replicas wins;
//! - a **bounded connection pool** of idle replica connections, kept
//!   warm by the probes and severed when the breaker trips.
//!
//! Request semantics:
//! - `score` is idempotent: on transport failure it retries on a
//!   different replica with jittered exponential backoff, bounded by
//!   `--retry-attempts` and a per-request deadline. Upstream *error
//!   frames* are relayed, never retried — only transport failures are.
//! - `generate` streams pin to their replica for their lifetime; if
//!   the replica dies mid-stream the client receives exactly one
//!   `replica_lost` error frame carrying `last_index` (the last
//!   contiguous token index relayed) so it can resume
//!   deterministically. Streams are never transparently retried.
//! - `reload` broadcasts to every replica; `stats`/`metrics` are
//!   answered by the front itself (`sonic_front_*` series); `shutdown`
//!   drains the front only — replicas are managed separately.
//! - When every replica for a model is unhealthy the front sheds with
//!   `no_healthy_replica` + `retry_after_ms` instead of hanging.
//!
//! Fault injection mirrors the gateway's
//! [`FaultPlan`](crate::gateway::FaultPlan): [`FrontFaultPlan`] scripts
//! deterministic replica kills and probe stalls against replica 0 so
//! the chaos drills can assert failover invariants.

pub mod replica;
pub mod router;
pub mod stats;

pub use replica::{Replica, ReplicaSpec, ReplicaState};
pub use stats::{FrontStats, ReplicaGauge};

use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::thread;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::gateway::protocol::ServerMsg;
use crate::gateway::{send_line, send_raw, LineEvent, LineReader, Sink};
use crate::obs::{self, SpanKind};
use crate::util::json::Json;
use crate::util::prng::Prng;
use replica::HealthEvent;

/// Deterministic fault-injection plan for the front-tier chaos drills,
/// mirroring the gateway's [`crate::gateway::FaultPlan`]. Both knobs
/// target replica 0 (the drills assert the *rest* of the pool absorbs
/// the load); zero values disarm everything — the production default.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct FrontFaultPlan {
    /// After this many *successful* probes of replica 0, force-trip its
    /// breaker and sever its pool as if the process vanished (0 = off).
    /// The replica is not actually touched, so the very next probe
    /// succeeds — deterministically exercising the half-open recovery
    /// path end to end.
    pub kill_replica_after_probes: usize,
    /// After this many probes of replica 0, treat one probe as timed
    /// out (0 = off): a single scripted stall that must leave the
    /// replica `Degraded`, not `Dead`.
    pub stall_replica_after_probes: usize,
}

/// Front-tier deployment configuration.
#[derive(Debug, Clone)]
pub struct FrontConfig {
    /// Bind address; use port 0 for an ephemeral port (tests, loadgen).
    pub addr: String,
    /// Replica gateways to front (at least one).
    pub replicas: Vec<ReplicaSpec>,
    /// Health-probe period per replica.
    pub probe_interval_ms: u64,
    /// Probe / connect timeout (a slower replica counts as failed).
    pub probe_timeout_ms: u64,
    /// Consecutive failures that trip the breaker (`Dead`).
    pub fail_threshold: u32,
    /// Total relay attempts per `score` request (1 = no retry).
    pub retry_attempts: usize,
    /// Base of the jittered exponential retry backoff.
    pub retry_base_ms: u64,
    /// Per-request deadline; for pinned streams, the per-frame
    /// inactivity bound.
    pub request_deadline_ms: u64,
    /// Idle replica connections pooled per replica.
    pub pool_cap: usize,
    /// Scripted faults for the chaos drills (default: disarmed).
    pub fault: FrontFaultPlan,
    /// Default output path for `trace_dump` requests that carry no
    /// `path` of their own (the `--trace-out` flag).
    pub trace_out: Option<String>,
}

impl Default for FrontConfig {
    fn default() -> Self {
        FrontConfig {
            addr: "127.0.0.1:0".to_string(),
            replicas: Vec::new(),
            probe_interval_ms: 200,
            probe_timeout_ms: 1000,
            fail_threshold: 3,
            retry_attempts: 3,
            retry_base_ms: 10,
            request_deadline_ms: 10_000,
            pool_cap: 4,
            fault: FrontFaultPlan::default(),
            trace_out: None,
        }
    }
}

/// State shared by the acceptor, connection threads and probers.
struct Shared {
    replicas: Vec<Arc<Replica>>,
    stats: Mutex<FrontStats>,
    shutdown: AtomicBool,
    probe_interval: Duration,
    probe_timeout: Duration,
    fail_threshold: u32,
    retry_attempts: usize,
    retry_base_ms: u64,
    request_deadline: Duration,
    trace_out: Option<String>,
}

impl Shared {
    /// Fold a breaker transition into the trip/recovery counters.
    fn record_event(&self, ev: &HealthEvent) {
        if ev.tripped || ev.recovered {
            let mut st = self.stats.lock().unwrap();
            if ev.tripped {
                st.breaker_trips += 1;
            }
            if ev.recovered {
                st.breaker_recoveries += 1;
            }
        }
    }

    /// Scripted kill of one replica: breaker trip + severed pool +
    /// kill-epoch bump so pinned streams observe the death.
    fn kill_replica(&self, index: usize) {
        let ev = self.replicas[index].force_kill();
        let mut st = self.stats.lock().unwrap();
        st.injected_replica_kills += 1;
        if ev.tripped {
            st.breaker_trips += 1;
        }
    }

    /// Point-in-time per-replica gauges for `stats`/`metrics`.
    fn gauges(&self) -> Vec<ReplicaGauge> {
        self.replicas
            .iter()
            .map(|r| ReplicaGauge {
                addr: r.spec.addr.clone(),
                model: r.spec.model.clone(),
                state: r.state().as_str(),
                ewma_ms: r.ewma_ms(),
                in_flight: r.in_flight.load(Ordering::Relaxed),
            })
            .collect()
    }

    /// Backoff hint on `no_healthy_replica` refusals: one probe
    /// interval — the soonest the health picture can change.
    fn retry_after_ms(&self) -> u64 {
        (self.probe_interval.as_millis() as u64).max(10)
    }
}

/// A running front tier: bound address plus the thread handles needed
/// to join the drain.
pub struct Front {
    addr: SocketAddr,
    shared: Arc<Shared>,
    threads: Vec<thread::JoinHandle<()>>,
}

impl Front {
    /// Bind, spawn one health prober per replica and the acceptor.
    /// Returns once the port is listening; replicas start optimistically
    /// `Healthy` and converge within one probe interval.
    pub fn start(cfg: FrontConfig) -> Result<Front> {
        anyhow::ensure!(!cfg.replicas.is_empty(), "front needs at least one --replica");
        let replicas: Vec<Arc<Replica>> = cfg
            .replicas
            .iter()
            .cloned()
            .enumerate()
            .map(|(i, spec)| Arc::new(Replica::new(spec, i, cfg.pool_cap.max(1))))
            .collect();
        let listener = TcpListener::bind(&cfg.addr)
            .with_context(|| format!("binding front on {}", cfg.addr))?;
        let addr = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let shared = Arc::new(Shared {
            replicas,
            stats: Mutex::new(FrontStats::default()),
            shutdown: AtomicBool::new(false),
            probe_interval: Duration::from_millis(cfg.probe_interval_ms.max(1)),
            probe_timeout: Duration::from_millis(cfg.probe_timeout_ms.max(1)),
            fail_threshold: cfg.fail_threshold.max(1),
            retry_attempts: cfg.retry_attempts.max(1),
            retry_base_ms: cfg.retry_base_ms,
            request_deadline: Duration::from_millis(cfg.request_deadline_ms.max(1)),
            trace_out: cfg.trace_out.clone(),
        });
        let mut threads = Vec::with_capacity(shared.replicas.len() + 1);
        for r in shared.replicas.iter().cloned() {
            let sh = Arc::clone(&shared);
            let fault = cfg.fault;
            threads.push(thread::spawn(move || prober(sh, r, fault)));
        }
        let sh = Arc::clone(&shared);
        threads.push(thread::spawn(move || accept_loop(listener, sh)));
        log::info!("front listening on {addr} fronting {} replicas", shared.replicas.len());
        Ok(Front { addr, shared, threads })
    }

    /// Address the front is listening on.
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// Initiate the drain (equivalent to a `shutdown` wire message);
    /// replicas are not touched.
    pub fn shutdown(&self) {
        self.shared.shutdown.store(true, Ordering::SeqCst);
    }

    /// Snapshot of the front statistics.
    pub fn stats_snapshot(&self) -> FrontStats {
        self.shared.stats.lock().unwrap().clone()
    }

    /// Current breaker state of one replica (drill assertions).
    pub fn replica_state(&self, index: usize) -> ReplicaState {
        self.shared.replicas[index].state()
    }

    /// Scripted kill of one replica, exactly as
    /// [`FrontFaultPlan::kill_replica_after_probes`] would fire it —
    /// the drills call this at a point of their choosing (e.g. mid-
    /// decode) instead of counting probes.
    pub fn inject_kill(&self, index: usize) {
        self.shared.kill_replica(index);
    }

    /// Wait for the drain to complete and return the final statistics.
    /// Only returns after a shutdown has been initiated.
    pub fn join(self) -> FrontStats {
        for h in self.threads {
            let _ = h.join();
        }
        let stats = self.shared.stats.lock().unwrap().clone();
        log::info!(
            "front drained: {} relayed, {} failovers, {} shed",
            stats.relayed_ok,
            stats.failovers,
            stats.shed_no_healthy
        );
        stats
    }
}

fn ms(d: Duration) -> f64 {
    d.as_secs_f64() * 1000.0
}

fn accept_loop(listener: TcpListener, shared: Arc<Shared>) {
    loop {
        if shared.shutdown.load(Ordering::SeqCst) {
            break;
        }
        match listener.accept() {
            Ok((stream, peer)) => {
                log::debug!("front: connection from {peer}");
                let sh = Arc::clone(&shared);
                thread::spawn(move || handle_conn(stream, sh));
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                thread::sleep(Duration::from_millis(5));
            }
            Err(e) => {
                log::warn!("front accept error: {e}");
                thread::sleep(Duration::from_millis(5));
            }
        }
    }
}

fn handle_conn(stream: TcpStream, shared: Arc<Shared>) {
    let _ = stream.set_nodelay(true);
    let _ = stream.set_read_timeout(Some(Duration::from_millis(50)));
    let _ = stream.set_write_timeout(Some(Duration::from_secs(5)));
    let sink: Sink = match stream.try_clone() {
        Ok(s) => Arc::new(Mutex::new(s)),
        Err(_) => return,
    };
    let mut reader = LineReader::new(stream);
    loop {
        match reader.next_line(&shared.shutdown) {
            LineEvent::Line(line) => {
                if handle_line(&line, &sink, &shared) {
                    break;
                }
            }
            LineEvent::Eof | LineEvent::Shutdown | LineEvent::TimedOut => break,
        }
    }
}

/// Mint a trace id at admission and splice it into the request line as
/// a `"trace"` field, so the replica the line is relayed to joins the
/// same trace. A line that already carries a valid trace (a client
/// propagating its own id) is relayed untouched with that id honored;
/// unsampled requests (`mint_trace` returned 0) relay untouched too.
fn mint_and_inject_trace(line: &str) -> (String, u64) {
    if !obs::recorder::enabled() {
        return (line.to_string(), 0);
    }
    if line.contains("\"trace\"") {
        if let Ok(j) = Json::parse(line) {
            if let Some(t) =
                j.opt("trace").and_then(|v| v.as_str().ok()).and_then(crate::obs::parse_trace_hex)
            {
                return (line.to_string(), t);
            }
        }
    }
    let trace = obs::mint_trace();
    if trace == 0 {
        return (line.to_string(), 0);
    }
    // splice before the closing brace of the (already-validated)
    // top-level object — the relay stays line-level, no re-encode
    let trimmed = line.trim_end();
    let Some(pos) = trimmed.rfind('}') else {
        return (line.to_string(), 0);
    };
    let mut out = String::with_capacity(trimmed.len() + 32);
    out.push_str(&trimmed[..pos]);
    out.push_str(",\"trace\":\"");
    out.push_str(&crate::obs::trace_hex(trace));
    out.push_str("\"}");
    (out, trace)
}

/// Dispatch one client line; returns true when the connection should
/// close. Requests are peeked, not re-encoded: only `type`, `id` and
/// the optional `model` tag are read, and the raw line is forwarded
/// verbatim (the gateway parser ignores unknown keys like `model`) —
/// except for the front-minted `trace` field spliced in at admission.
fn handle_line(line: &str, sink: &Sink, shared: &Shared) -> bool {
    let line = line.trim();
    if line.is_empty() {
        return false;
    }
    let j = match Json::parse(line) {
        Ok(j) => j,
        Err(e) => {
            send_line(sink, &ServerMsg::error(None, "bad_request", format!("{e:#}")).encode());
            return false;
        }
    };
    let ty = j.get("type").ok().and_then(|v| v.as_str().ok()).unwrap_or("").to_string();
    let id = j.opt("id").and_then(|v| v.as_f64().ok()).map(|x| x as u64);
    let model = j.opt("model").and_then(|v| v.as_str().ok()).unwrap_or("").to_string();
    match ty.as_str() {
        "score" | "generate" => {
            let Some(id) = id else {
                send_line(
                    sink,
                    &ServerMsg::error(None, "bad_request", "request needs an id").encode(),
                );
                return false;
            };
            let (line, trace) = mint_and_inject_trace(line);
            if ty == "score" {
                shared.stats.lock().unwrap().requests += 1;
                relay_score(shared, &line, id, trace, &model, sink);
            } else {
                shared.stats.lock().unwrap().gen_requests += 1;
                relay_generate(shared, &line, id, trace, &model, sink);
            }
            false
        }
        "stats" => {
            let gauges = shared.gauges();
            let body = shared.stats.lock().unwrap().to_json(&gauges);
            send_line(sink, &ServerMsg::Stats(body).encode());
            false
        }
        "metrics" => {
            let gauges = shared.gauges();
            let body = shared.stats.lock().unwrap().to_prometheus(&gauges);
            send_raw(sink, &body);
            true
        }
        "reload" => {
            relay_reload(shared, line, sink);
            false
        }
        "trace_dump" => {
            // in-process fronts and gateways share one global flight
            // recorder, so the gateway's dump helper serves both
            let path = j.opt("path").and_then(|v| v.as_str().ok()).map(str::to_string);
            send_line(
                sink,
                &crate::gateway::trace_dump_reply(path, shared.trace_out.as_deref()).encode(),
            );
            false
        }
        "shutdown" => {
            send_line(sink, &ServerMsg::Ok { info: "draining".to_string() }.encode());
            shared.shutdown.store(true, Ordering::SeqCst);
            true
        }
        t => {
            send_line(
                sink,
                &ServerMsg::error(None, "bad_request", format!("unknown message type {t:?}"))
                    .encode(),
            );
            false
        }
    }
}

/// Shed a request: every matching replica is unhealthy.
fn shed(shared: &Shared, sink: &Sink, id: u64) {
    shared.stats.lock().unwrap().shed_no_healthy += 1;
    send_line(
        sink,
        &ServerMsg::refusal(
            Some(id),
            "no_healthy_replica",
            "every matching replica is unhealthy",
            shared.retry_after_ms(),
        )
        .encode(),
    );
}

/// Write `line` and read exactly one reply line. Returns the reply and
/// the stream (when its buffer is clean and it may be pooled again).
fn round_trip(
    mut s: TcpStream,
    line: &str,
    shutdown: &AtomicBool,
    deadline: Instant,
) -> std::result::Result<(String, Option<TcpStream>), ()> {
    use std::io::Write as _;
    if s.write_all(line.as_bytes()).is_err() || s.write_all(b"\n").is_err() || s.flush().is_err() {
        return Err(());
    }
    let mut reader = LineReader::new(s);
    match reader.next_line_until(shutdown, deadline) {
        LineEvent::Line(l) => {
            let (stream, leftover) = reader.into_inner();
            Ok((l, if leftover.is_empty() { Some(stream) } else { None }))
        }
        _ => Err(()),
    }
}

/// One relay attempt against one replica: pooled connection first (a
/// stale pooled conn falls back to a fresh one before the attempt
/// counts as failed), one request line out, one reply line back.
fn relay_once(
    r: &Replica,
    line: &str,
    shutdown: &AtomicBool,
    deadline: Instant,
    connect_timeout: Duration,
) -> std::result::Result<(String, f64), ()> {
    let t0 = Instant::now();
    if let Some(s) = r.checkout() {
        if let Ok((reply, clean)) = round_trip(s, line, shutdown, deadline) {
            if let Some(s) = clean {
                r.checkin(s);
            }
            return Ok((reply, ms(t0.elapsed())));
        }
        // stale pooled connection: retry the same replica fresh
    }
    let s = r.connect_fresh(connect_timeout).map_err(|_| ())?;
    let (reply, clean) = round_trip(s, line, shutdown, deadline)?;
    if let Some(s) = clean {
        r.checkin(s);
    }
    Ok((reply, ms(t0.elapsed())))
}

/// Decrement the owning replica's in-flight count on scope exit.
struct InFlight<'a>(&'a Replica);

impl Drop for InFlight<'_> {
    fn drop(&mut self) {
        self.0.in_flight.fetch_sub(1, Ordering::Relaxed);
    }
}

/// Route and relay one idempotent `score` request with bounded,
/// jittered-backoff retries across replicas. Upstream error frames are
/// relayed verbatim (never retried); only transport failures retry.
fn relay_score(shared: &Shared, line: &str, id: u64, trace: u64, model: &str, sink: &Sink) {
    let t0 = Instant::now();
    let t0_ns = obs::recorder::now_ns();
    let deadline = t0 + shared.request_deadline;
    // per-request deterministic jitter (seeded by the request id, so
    // drills replay identically)
    let mut rng = Prng::new(id ^ 0x4652_4f4e_545f_4a49);
    let mut tried: Vec<usize> = Vec::new();
    let mut exhausted_candidates = false;
    for attempt in 0..shared.retry_attempts {
        let route_t0 = obs::recorder::now_ns();
        let Some(ix) = router::choose(&shared.replicas, model, &tried) else {
            break;
        };
        if trace != 0 {
            let end = obs::recorder::now_ns();
            obs::record_span(trace, SpanKind::RouteDecide, route_t0, end, ix as u64);
        }
        tried.push(ix);
        let r = &shared.replicas[ix];
        r.in_flight.fetch_add(1, Ordering::Relaxed);
        let guard = InFlight(r);
        let res = relay_once(r, line, &shared.shutdown, deadline, shared.probe_timeout);
        drop(guard);
        match res {
            Ok((reply, latency_ms)) => {
                let ev = r.report_success(latency_ms);
                shared.record_event(&ev);
                {
                    let mut st = shared.stats.lock().unwrap();
                    st.relayed_ok += 1;
                    if attempt > 0 {
                        st.record_failover(ms(t0.elapsed()));
                    }
                }
                if trace != 0 && attempt > 0 {
                    // the failover span covers admission → the reply
                    // that finally succeeded (the cost clients paid)
                    obs::record_span(
                        trace,
                        SpanKind::Failover,
                        t0_ns,
                        obs::recorder::now_ns(),
                        tried.len() as u64,
                    );
                }
                send_line(sink, &reply);
                return;
            }
            Err(()) => {
                let ev = r.report_failure(shared.fail_threshold);
                shared.record_event(&ev);
                shared.stats.lock().unwrap().retries += 1;
                let now = Instant::now();
                if now >= deadline || attempt + 1 == shared.retry_attempts {
                    exhausted_candidates = true;
                    break;
                }
                // jittered exponential backoff, bounded by the deadline
                let base = shared.retry_base_ms.saturating_mul(1 << attempt.min(6));
                let jittered = (base as f64 * (0.5 + 0.5 * rng.f64())) as u64;
                let remaining = deadline.saturating_duration_since(now);
                let wait_t0 = obs::recorder::now_ns();
                thread::sleep(Duration::from_millis(jittered).min(remaining));
                if trace != 0 {
                    obs::record_span(
                        trace,
                        SpanKind::RetryWait,
                        wait_t0,
                        obs::recorder::now_ns(),
                        attempt as u64 + 1,
                    );
                }
            }
        }
    }
    if exhausted_candidates {
        shared.stats.lock().unwrap().exhausted += 1;
        send_line(
            sink,
            &ServerMsg::error(
                Some(id),
                "exec_failed",
                format!("all {} relay attempts failed", tried.len()),
            )
            .encode(),
        );
    } else {
        // the loop ended because no routable replica remained
        shed(shared, sink, id);
    }
}

/// Open a pinned stream: pooled-then-fresh connection, request line
/// out, first frame back within the deadline.
fn open_stream(
    r: &Replica,
    line: &str,
    shutdown: &AtomicBool,
    deadline: Instant,
    connect_timeout: Duration,
) -> std::result::Result<(LineReader, String), ()> {
    fn start(
        mut s: TcpStream,
        line: &str,
        shutdown: &AtomicBool,
        deadline: Instant,
    ) -> std::result::Result<(LineReader, String), ()> {
        use std::io::Write as _;
        if s.write_all(line.as_bytes()).is_err()
            || s.write_all(b"\n").is_err()
            || s.flush().is_err()
        {
            return Err(());
        }
        let mut reader = LineReader::new(s);
        match reader.next_line_until(shutdown, deadline) {
            LineEvent::Line(first) => Ok((reader, first)),
            _ => Err(()),
        }
    }
    if let Some(s) = r.checkout() {
        if let Ok(x) = start(s, line, shutdown, deadline) {
            return Ok(x);
        }
    }
    let s = r.connect_fresh(connect_timeout).map_err(|_| ())?;
    start(s, line, shutdown, deadline)
}

/// Route one `generate` request and relay its pinned stream. The
/// stream lives and dies with its replica: on replica death the client
/// gets exactly one `replica_lost` frame carrying the last contiguous
/// token index relayed (`None` encodes "no token was ever streamed").
fn relay_generate(shared: &Shared, line: &str, id: u64, trace: u64, model: &str, sink: &Sink) {
    let route_t0 = obs::recorder::now_ns();
    let Some(ix) = router::choose(&shared.replicas, model, &[]) else {
        shed(shared, sink, id);
        return;
    };
    if trace != 0 {
        let end = obs::recorder::now_ns();
        obs::record_span(trace, SpanKind::RouteDecide, route_t0, end, ix as u64);
    }
    let r = &shared.replicas[ix];
    let epoch0 = r.kill_epoch();
    r.in_flight.fetch_add(1, Ordering::Relaxed);
    let _guard = InFlight(r);
    let t0 = Instant::now();
    let opened =
        open_stream(r, line, &shared.shutdown, t0 + shared.request_deadline, shared.probe_timeout);
    let (mut reader, first) = match opened {
        Ok(x) => x,
        Err(()) => {
            let ev = r.report_failure(shared.fail_threshold);
            shared.record_event(&ev);
            shared.stats.lock().unwrap().replica_lost_streams += 1;
            send_line(
                sink,
                &ServerMsg::replica_lost(id, None, "replica unreachable before the stream started")
                    .encode(),
            );
            return;
        }
    };
    // the replica answered: time-to-first-frame is the routing signal
    let ev = r.report_success(ms(t0.elapsed()));
    shared.record_event(&ev);
    let mut pending = Some(first);
    let mut last_index: Option<u64> = None;
    let mut inactivity_deadline = Instant::now() + shared.request_deadline;
    loop {
        // a scripted kill severs the relay even though the socket is
        // technically alive — the drill's deterministic replica death
        if r.kill_epoch() != epoch0 {
            shared.stats.lock().unwrap().replica_lost_streams += 1;
            send_line(
                sink,
                &ServerMsg::replica_lost(id, last_index, "replica killed mid-stream").encode(),
            );
            return;
        }
        let frame = match pending.take() {
            Some(f) => f,
            // poll in short slices so kills and shutdowns are noticed
            // between frames
            None => match reader
                .next_line_until(&shared.shutdown, Instant::now() + Duration::from_millis(50))
            {
                LineEvent::Line(f) => f,
                LineEvent::TimedOut => {
                    if Instant::now() >= inactivity_deadline {
                        let ev = r.report_failure(shared.fail_threshold);
                        shared.record_event(&ev);
                        shared.stats.lock().unwrap().replica_lost_streams += 1;
                        send_line(
                            sink,
                            &ServerMsg::replica_lost(id, last_index, "replica stalled mid-stream")
                                .encode(),
                        );
                        return;
                    }
                    continue;
                }
                LineEvent::Shutdown => {
                    send_line(
                        sink,
                        &ServerMsg::error(Some(id), "shutting_down", "front is draining").encode(),
                    );
                    return;
                }
                LineEvent::Eof => {
                    let ev = r.report_failure(shared.fail_threshold);
                    shared.record_event(&ev);
                    shared.stats.lock().unwrap().replica_lost_streams += 1;
                    send_line(
                        sink,
                        &ServerMsg::replica_lost(id, last_index, "replica died mid-stream")
                            .encode(),
                    );
                    return;
                }
            },
        };
        inactivity_deadline = Instant::now() + shared.request_deadline;
        // peek the frame type to track the contiguous-token cursor and
        // spot the terminal frame; the raw line is what gets relayed
        let fty = Json::parse(&frame)
            .ok()
            .and_then(|fj| {
                if let Ok(v) = fj.get("type") {
                    if let Ok(t) = v.as_str() {
                        if t == "token" {
                            if let Some(i) = fj.opt("index").and_then(|v| v.as_f64().ok()) {
                                last_index = Some(i as u64);
                            }
                        }
                        return Some(t.to_string());
                    }
                }
                None
            })
            .unwrap_or_default();
        send_line(sink, &frame);
        if fty == "done" || fty == "error" {
            shared.stats.lock().unwrap().gen_done += 1;
            let (stream, leftover) = reader.into_inner();
            if leftover.is_empty() {
                r.checkin(stream);
            }
            return;
        }
    }
}

/// Broadcast a `reload` line to every replica. The client gets one
/// `ok` summarizing how many replicas acknowledged; if none did, the
/// first upstream reply (or a transport error) is relayed instead.
fn relay_reload(shared: &Shared, line: &str, sink: &Sink) {
    let mut acked = 0usize;
    let mut first_refusal: Option<String> = None;
    for r in &shared.replicas {
        let deadline = Instant::now() + shared.probe_timeout;
        match relay_once(r, line, &shared.shutdown, deadline, shared.probe_timeout) {
            Ok((reply, latency_ms)) => {
                let ev = r.report_success(latency_ms);
                shared.record_event(&ev);
                if matches!(ServerMsg::parse(&reply), Ok(ServerMsg::Ok { .. })) {
                    acked += 1;
                } else if first_refusal.is_none() {
                    first_refusal = Some(reply);
                }
            }
            Err(()) => {
                let ev = r.report_failure(shared.fail_threshold);
                shared.record_event(&ev);
            }
        }
    }
    shared.stats.lock().unwrap().reloads += 1;
    if acked == 0 {
        match first_refusal {
            Some(reply) => send_line(sink, &reply),
            None => send_line(
                sink,
                &ServerMsg::error(None, "exec_failed", "no replica acknowledged the reload")
                    .encode(),
            ),
        }
    } else {
        send_line(
            sink,
            &ServerMsg::Ok {
                info: format!("reload relayed: {acked}/{} replicas acknowledged", shared.replicas.len()),
            }
            .encode(),
        );
    }
}

/// One health probe: fresh connection, `stats` request, one reply
/// within the timeout. The connection is pooled afterwards, so probes
/// keep each replica's pool warm.
fn probe_once(
    r: &Replica,
    shutdown: &AtomicBool,
    timeout: Duration,
) -> std::result::Result<f64, ()> {
    let t0 = Instant::now();
    let s = r.connect_fresh(timeout).map_err(|_| ())?;
    let (reply, clean) = round_trip(s, r#"{"type":"stats"}"#, shutdown, t0 + timeout)?;
    if let Some(s) = clean {
        r.checkin(s);
    }
    match ServerMsg::parse(&reply) {
        Ok(ServerMsg::Stats(_)) => Ok(ms(t0.elapsed())),
        _ => Err(()),
    }
}

/// Health-watcher loop for one replica: probe, apply the scripted
/// faults, sleep one interval (in slices, so shutdown is prompt).
fn prober(shared: Arc<Shared>, r: Arc<Replica>, fault: FrontFaultPlan) {
    let mut probes_done = 0usize;
    let mut ok_probes = 0usize;
    let mut killed = false;
    let mut stalled = false;
    while !shared.shutdown.load(Ordering::SeqCst) {
        probes_done += 1;
        let stall_now = r.index == 0
            && fault.stall_replica_after_probes > 0
            && !stalled
            && probes_done > fault.stall_replica_after_probes;
        let res = if stall_now {
            stalled = true;
            Err(())
        } else {
            probe_once(&r, &shared.shutdown, shared.probe_timeout)
        };
        {
            let mut st = shared.stats.lock().unwrap();
            st.probes += 1;
            if stall_now {
                st.injected_replica_stalls += 1;
            }
            if res.is_err() {
                st.probe_failures += 1;
            }
        }
        match res {
            Ok(latency_ms) => {
                ok_probes += 1;
                let ev = r.report_success(latency_ms);
                shared.record_event(&ev);
                if r.index == 0
                    && fault.kill_replica_after_probes > 0
                    && !killed
                    && ok_probes >= fault.kill_replica_after_probes
                {
                    killed = true;
                    shared.kill_replica(r.index);
                }
            }
            Err(()) => {
                let ev = r.report_failure(shared.fail_threshold);
                shared.record_event(&ev);
            }
        }
        let until = Instant::now() + shared.probe_interval;
        while Instant::now() < until && !shared.shutdown.load(Ordering::SeqCst) {
            thread::sleep(Duration::from_millis(10));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn front_refuses_an_empty_replica_list() {
        let err = Front::start(FrontConfig::default()).unwrap_err();
        assert!(format!("{err:#}").contains("at least one"));
    }

    #[test]
    fn config_defaults_are_sane() {
        let c = FrontConfig::default();
        assert_eq!(c.probe_interval_ms, 200);
        assert_eq!(c.fail_threshold, 3);
        assert_eq!(c.retry_attempts, 3);
        assert_eq!(c.fault, FrontFaultPlan::default());
        assert_eq!(c.fault.kill_replica_after_probes, 0);
    }
}
