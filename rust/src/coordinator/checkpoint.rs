//! Checkpointing: parameters + step metadata to a directory
//! (`params.bin` flat f32 + `meta.json`), loadable across runs.

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::util::json::Json;
use crate::util::tensor::Tensor;

/// Save params (+ step/config name) into `dir`.
pub fn save(dir: &str, step: u64, config: &str, names: &[String], params: &[Tensor]) -> Result<()> {
    std::fs::create_dir_all(dir).with_context(|| format!("creating {dir}"))?;
    let mut bytes = Vec::new();
    let mut layout = Vec::new();
    let mut offset = 0usize;
    for (n, p) in names.iter().zip(params) {
        for x in &p.data {
            bytes.extend_from_slice(&x.to_le_bytes());
        }
        let mut o = BTreeMap::new();
        o.insert("name".to_string(), Json::Str(n.clone()));
        o.insert(
            "shape".to_string(),
            Json::Arr(p.shape.iter().map(|&d| Json::Num(d as f64)).collect()),
        );
        o.insert("offset".to_string(), Json::Num(offset as f64));
        o.insert("size".to_string(), Json::Num(p.numel() as f64));
        layout.push(Json::Obj(o));
        offset += p.numel();
    }
    std::fs::write(Path::new(dir).join("params.bin"), bytes)?;
    let mut meta = BTreeMap::new();
    meta.insert("step".to_string(), Json::Num(step as f64));
    meta.insert("config".to_string(), Json::Str(config.to_string()));
    meta.insert("params".to_string(), Json::Arr(layout));
    std::fs::write(Path::new(dir).join("meta.json"), Json::Obj(meta).to_string())?;
    Ok(())
}

/// Load a checkpoint; returns (step, config, names, params).
pub fn load(dir: &str) -> Result<(u64, String, Vec<String>, Vec<Tensor>)> {
    let meta = Json::parse_file(
        Path::new(dir).join("meta.json").to_str().context("bad path")?,
    )?;
    let step = meta.get("step")?.as_usize()? as u64;
    let config = meta.get("config")?.as_str()?.to_string();
    let bytes = std::fs::read(Path::new(dir).join("params.bin"))?;
    let flat: Vec<f32> = bytes
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect();
    let mut names = Vec::new();
    let mut params = Vec::new();
    for p in meta.get("params")?.as_arr()? {
        let name = p.get("name")?.as_str()?.to_string();
        let shape = p.get("shape")?.as_usize_vec()?;
        let offset = p.get("offset")?.as_usize()?;
        let size = p.get("size")?.as_usize()?;
        if offset + size > flat.len() {
            bail!("checkpoint truncated at {name}");
        }
        names.push(name);
        params.push(Tensor::from_vec(&shape, flat[offset..offset + size].to_vec())?);
    }
    Ok((step, config, names, params))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let dir = std::env::temp_dir().join("sonic_ckpt_test");
        let dir = dir.to_str().unwrap();
        let names = vec!["a".to_string(), "b".to_string()];
        let params = vec![
            Tensor::from_vec(&[2, 2], vec![1.0, 2.0, 3.0, 4.0]).unwrap(),
            Tensor::from_vec(&[3], vec![-1.0, 0.5, 9.0]).unwrap(),
        ];
        save(dir, 42, "small", &names, &params).unwrap();
        let (step, cfg, n2, p2) = load(dir).unwrap();
        assert_eq!(step, 42);
        assert_eq!(cfg, "small");
        assert_eq!(n2, names);
        assert_eq!(p2, params);
    }
}
