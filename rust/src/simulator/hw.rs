//! GPU hardware specifications for the performance model.
//!
//! Peaks are the published dense-BF16 tensor-core throughput and HBM
//! bandwidth; efficiency knobs are calibrated so SonicMoE's simulated
//! numbers land near the paper's reported TFLOPS (H100: >550 on 7B
//! configs; B300: >1100), then every *baseline* differs only through the
//! mechanistic feature flags (gather fusion, overlap, dS path...), never
//! through per-method fudge factors.

/// One GPU model.
#[derive(Debug, Clone, Copy)]
pub struct GpuSpec {
    pub name: &'static str,
    /// Dense BF16 tensor-core peak, FLOP/s.
    pub bf16_flops: f64,
    /// HBM bandwidth, bytes/s.
    pub hbm_bps: f64,
    /// Streaming multiprocessors.
    pub sms: usize,
    /// Achievable fraction of peak FLOPs for a well-shaped dense GEMM
    /// (cuBLAS-level; tile/wave overheads are modelled separately).
    pub mma_eff: f64,
    /// Achievable fraction of peak bandwidth for streaming kernels.
    pub mem_eff: f64,
    /// Fixed per-kernel launch + tail latency (seconds).
    pub launch_s: f64,
    /// Default grouped-GEMM tile (M, N, K).
    pub tile: (usize, usize, usize),
    /// Fraction of a non-overlapped epilogue/prologue that Ping-Pong
    /// (Hopper) / TMEM double-buffering (Blackwell) hides when a method
    /// implements MMA-IO overlap (Section 4.2).
    pub overlap_hide: f64,
}

/// NVIDIA H100 SXM (Hopper).
pub const H100: GpuSpec = GpuSpec {
    name: "H100",
    bf16_flops: 989e12,
    hbm_bps: 3.35e12,
    sms: 132,
    mma_eff: 0.80,
    mem_eff: 0.88,
    launch_s: 6e-6,
    tile: (128, 256, 64),
    overlap_hide: 0.85,
};

/// NVIDIA B300 (Blackwell Ultra). TMEM two-stage accumulation gives a
/// slightly better overlap factor than Hopper's ping-pong (Section 4.2).
pub const B300: GpuSpec = GpuSpec {
    name: "B300",
    bf16_flops: 2250e12,
    hbm_bps: 8.0e12,
    sms: 148,
    mma_eff: 0.76,
    mem_eff: 0.88,
    launch_s: 6e-6,
    tile: (256, 256, 64),
    overlap_hide: 0.90,
};

impl GpuSpec {
    /// Effective GEMM throughput for a grouped GEMM whose reduction depth
    /// is `k_dim` and output-tile N extent is `n_dim`: shallow reductions
    /// and narrow N under-utilize the MXU pipeline (the reason DeepGEMM's
    /// cooperative schedule loses on small-n down-proj, App. F.1).
    pub fn gemm_eff(&self, k_dim: usize, n_dim: usize) -> f64 {
        let depth = k_dim as f64 / (k_dim as f64 + 56.0);
        let width = n_dim as f64 / (n_dim as f64 + 12.0);
        self.mma_eff * depth * width
    }

    /// Seconds to stream `bytes` at achievable bandwidth.
    pub fn stream_s(&self, bytes: f64) -> f64 {
        bytes / (self.hbm_bps * self.mem_eff)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn b300_faster_than_h100() {
        assert!(B300.bf16_flops > 2.0 * H100.bf16_flops);
        assert!(B300.hbm_bps > 2.0 * H100.hbm_bps);
    }

    #[test]
    fn gemm_eff_monotone_in_depth_and_width() {
        for hw in [H100, B300] {
            assert!(hw.gemm_eff(4096, 256) > hw.gemm_eff(256, 256));
            assert!(hw.gemm_eff(1024, 1024) > hw.gemm_eff(1024, 64));
            assert!(hw.gemm_eff(8192, 4096) < hw.mma_eff);
        }
    }

    #[test]
    fn stream_time_linear() {
        let t1 = H100.stream_s(1e9);
        let t2 = H100.stream_s(2e9);
        assert!((t2 / t1 - 2.0).abs() < 1e-12);
    }
}
