//! Tiny declarative CLI argument parser (clap is unavailable offline).
//!
//! Supports `--flag`, `--key value`, `--key=value` and positional args,
//! with typed accessors, defaults and an auto-generated `--help`.

use std::collections::BTreeMap;

use anyhow::{bail, Context, Result};

/// One declared option (for help text and validation).
struct Opt {
    name: &'static str,
    help: &'static str,
    default: Option<String>,
    is_flag: bool,
    is_multi: bool,
}

/// Declarative CLI: declare options, then [`Cli::parse`].
pub struct Cli {
    program: &'static str,
    about: &'static str,
    opts: Vec<Opt>,
}

/// Parsed arguments.
pub struct Args {
    values: BTreeMap<String, String>,
    multis: BTreeMap<String, Vec<String>>,
    flags: Vec<String>,
    pub positional: Vec<String>,
}

impl Cli {
    pub fn new(program: &'static str, about: &'static str) -> Self {
        Cli { program, about, opts: Vec::new() }
    }

    /// Declare `--name <value>` with a default.
    pub fn opt(mut self, name: &'static str, default: &str, help: &'static str) -> Self {
        self.opts.push(Opt {
            name,
            help,
            default: Some(default.to_string()),
            is_flag: false,
            is_multi: false,
        });
        self
    }

    /// Declare a required `--name <value>`.
    pub fn req(mut self, name: &'static str, help: &'static str) -> Self {
        self.opts.push(Opt { name, help, default: None, is_flag: false, is_multi: false });
        self
    }

    /// Declare a repeatable `--name <value>` (each occurrence appends;
    /// zero occurrences parse to an empty list — read with
    /// [`Args::get_all`]).
    pub fn multi(mut self, name: &'static str, help: &'static str) -> Self {
        self.opts.push(Opt { name, help, default: None, is_flag: false, is_multi: true });
        self
    }

    /// Declare a boolean `--name` flag.
    pub fn flag(mut self, name: &'static str, help: &'static str) -> Self {
        self.opts.push(Opt { name, help, default: None, is_flag: true, is_multi: false });
        self
    }

    pub fn usage(&self) -> String {
        let mut s = format!("{} — {}\n\nOptions:\n", self.program, self.about);
        for o in &self.opts {
            let head = if o.is_flag {
                format!("  --{}", o.name)
            } else if o.is_multi {
                format!("  --{} <v>...", o.name)
            } else {
                format!("  --{} <v>", o.name)
            };
            let def = match &o.default {
                Some(d) if !o.is_flag => format!(" [default: {d}]"),
                _ => String::new(),
            };
            s.push_str(&format!("{head:<26}{}{def}\n", o.help));
        }
        s
    }

    /// Parse from an iterator of arguments (excluding argv[0]).
    pub fn parse_from<I: IntoIterator<Item = String>>(&self, argv: I) -> Result<Args> {
        let mut values = BTreeMap::new();
        let mut multis: BTreeMap<String, Vec<String>> = BTreeMap::new();
        let mut flags = Vec::new();
        let mut positional = Vec::new();
        for o in &self.opts {
            if let Some(d) = &o.default {
                values.insert(o.name.to_string(), d.clone());
            }
            if o.is_multi {
                multis.insert(o.name.to_string(), Vec::new());
            }
        }
        let mut it = argv.into_iter().peekable();
        while let Some(a) = it.next() {
            if a == "--help" || a == "-h" {
                println!("{}", self.usage());
                std::process::exit(0);
            }
            if let Some(body) = a.strip_prefix("--") {
                let (name, inline) = match body.split_once('=') {
                    Some((n, v)) => (n.to_string(), Some(v.to_string())),
                    None => (body.to_string(), None),
                };
                let decl = self
                    .opts
                    .iter()
                    .find(|o| o.name == name)
                    .with_context(|| format!("unknown option --{name}\n{}", self.usage()))?;
                if decl.is_flag {
                    if inline.is_some() {
                        bail!("flag --{name} takes no value");
                    }
                    flags.push(name);
                } else {
                    let v = match inline {
                        Some(v) => v,
                        None => it
                            .next()
                            .with_context(|| format!("--{name} needs a value"))?,
                    };
                    if decl.is_multi {
                        multis.get_mut(&name).expect("multi pre-seeded").push(v);
                    } else {
                        values.insert(name, v);
                    }
                }
            } else {
                positional.push(a);
            }
        }
        for o in &self.opts {
            if !o.is_flag && !o.is_multi && o.default.is_none() && !values.contains_key(o.name) {
                bail!("missing required option --{}\n{}", o.name, self.usage());
            }
        }
        Ok(Args { values, multis, flags, positional })
    }

    /// Parse from the process arguments.
    pub fn parse(&self) -> Result<Args> {
        self.parse_from(std::env::args().skip(1))
    }
}

impl Args {
    pub fn get(&self, name: &str) -> &str {
        self.values.get(name).map(|s| s.as_str()).unwrap_or_else(|| {
            panic!("option --{name} was not declared with a default")
        })
    }

    pub fn get_usize(&self, name: &str) -> Result<usize> {
        self.get(name).parse().with_context(|| format!("--{name} must be an integer"))
    }

    pub fn get_u64(&self, name: &str) -> Result<u64> {
        self.get(name).parse().with_context(|| format!("--{name} must be an integer"))
    }

    pub fn get_f64(&self, name: &str) -> Result<f64> {
        self.get(name).parse().with_context(|| format!("--{name} must be a number"))
    }

    pub fn has(&self, flag: &str) -> bool {
        self.flags.iter().any(|f| f == flag)
    }

    /// Every occurrence of a repeatable option, in command-line order
    /// (empty when the option never appeared).
    pub fn get_all(&self, name: &str) -> &[String] {
        self.multis.get(name).map(|v| v.as_slice()).unwrap_or_else(|| {
            panic!("option --{name} was not declared with multi()")
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cli() -> Cli {
        Cli::new("t", "test")
            .opt("steps", "100", "steps")
            .opt("lr", "0.001", "learning rate")
            .flag("verbose", "chatty")
    }

    fn parse(args: &[&str]) -> Result<Args> {
        cli().parse_from(args.iter().map(|s| s.to_string()))
    }

    #[test]
    fn defaults_and_overrides() {
        let a = parse(&["--steps", "5"]).unwrap();
        assert_eq!(a.get_usize("steps").unwrap(), 5);
        assert_eq!(a.get_f64("lr").unwrap(), 0.001);
        assert!(!a.has("verbose"));
    }

    #[test]
    fn equals_form_and_flags() {
        let a = parse(&["--lr=0.1", "--verbose", "pos1"]).unwrap();
        assert_eq!(a.get_f64("lr").unwrap(), 0.1);
        assert!(a.has("verbose"));
        assert_eq!(a.positional, vec!["pos1"]);
    }

    #[test]
    fn unknown_option_errors() {
        assert!(parse(&["--nope", "1"]).is_err());
        assert!(parse(&["--steps"]).is_err());
        assert!(parse(&["--verbose=x"]).is_err());
    }

    #[test]
    fn required_option() {
        let c = Cli::new("t", "x").req("path", "a path");
        assert!(c.parse_from(Vec::<String>::new()).is_err());
        let a = c.parse_from(vec!["--path".to_string(), "/x".to_string()]).unwrap();
        assert_eq!(a.get("path"), "/x");
    }

    #[test]
    fn multi_appends_in_order() {
        let c = Cli::new("t", "x").multi("replica", "a replica").opt("steps", "1", "steps");
        let a = c
            .parse_from(
                ["--replica", "a:1", "--steps", "2", "--replica=b:2"]
                    .iter()
                    .map(|s| s.to_string()),
            )
            .unwrap();
        assert_eq!(a.get_all("replica"), ["a:1".to_string(), "b:2".to_string()]);
        assert_eq!(a.get_usize("steps").unwrap(), 2);
        // zero occurrences: empty, not an error
        let a = c.parse_from(Vec::<String>::new()).unwrap();
        assert!(a.get_all("replica").is_empty());
    }
}
