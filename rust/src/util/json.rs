//! Minimal JSON parser/serializer (serde_json is unavailable offline).
//!
//! Supports the full JSON grammar we produce and consume: objects,
//! arrays, strings (with escapes), numbers, booleans, null. Numbers are
//! held as `f64` (all our integer fields fit exactly in the 53-bit
//! mantissa).

use std::collections::BTreeMap;
use std::fmt;

use anyhow::{anyhow, bail, Context, Result};

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            bail!("trailing characters at byte {}", p.i);
        }
        Ok(v)
    }

    pub fn parse_file(path: &str) -> Result<Json> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {path}"))?;
        Self::parse(&text).with_context(|| format!("parsing {path}"))
    }

    // -- typed accessors ---------------------------------------------------

    pub fn get(&self, key: &str) -> Result<&Json> {
        match self {
            Json::Obj(m) => m.get(key).ok_or_else(|| anyhow!("missing key {key:?}")),
            _ => bail!("not an object (looking for {key:?})"),
        }
    }

    pub fn opt(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(x) => Ok(*x),
            _ => bail!("not a number: {self:?}"),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        let x = self.as_f64()?;
        if x < 0.0 || x.fract() != 0.0 {
            bail!("not a non-negative integer: {x}");
        }
        Ok(x as usize)
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            _ => bail!("not a string: {self:?}"),
        }
    }

    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(v) => Ok(v),
            _ => bail!("not an array: {self:?}"),
        }
    }

    pub fn as_obj(&self) -> Result<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Ok(m),
            _ => bail!("not an object: {self:?}"),
        }
    }

    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Json::Bool(b) => Ok(*b),
            _ => bail!("not a bool: {self:?}"),
        }
    }

    /// Shape-style helper: array of integers.
    pub fn as_usize_vec(&self) -> Result<Vec<usize>> {
        self.as_arr()?.iter().map(|v| v.as_usize()).collect()
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.b.get(self.i).copied().ok_or_else(|| anyhow!("unexpected end of input"))
    }

    fn eat(&mut self, c: u8) -> Result<()> {
        if self.peek()? != c {
            bail!("expected {:?} at byte {}, got {:?}", c as char, self.i, self.peek()? as char);
        }
        self.i += 1;
        Ok(())
    }

    fn value(&mut self) -> Result<Json> {
        self.skip_ws();
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            _ => self.number(),
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            bail!("invalid literal at byte {}", self.i)
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek()? == b'}' {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek()? {
                b',' => self.i += 1,
                b'}' => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                c => bail!("expected ',' or '}}' at byte {}, got {:?}", self.i, c as char),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.eat(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek()? == b']' {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            v.push(self.value()?);
            self.skip_ws();
            match self.peek()? {
                b',' => self.i += 1,
                b']' => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                c => bail!("expected ',' or ']' at byte {}, got {:?}", self.i, c as char),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            let c = self.peek()?;
            self.i += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek()?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'n' => s.push('\n'),
                        b'r' => s.push('\r'),
                        b't' => s.push('\t'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                bail!("truncated \\u escape");
                            }
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])?;
                            let cp = u32::from_str_radix(hex, 16)?;
                            self.i += 4;
                            // surrogate pairs: only BMP needed for our files
                            s.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                        }
                        _ => bail!("invalid escape at byte {}", self.i),
                    }
                }
                _ => {
                    // handle multi-byte utf-8 by finding char boundary
                    let start = self.i - 1;
                    let mut end = self.i;
                    while end < self.b.len() && (self.b[end] & 0xc0) == 0x80 {
                        end += 1;
                    }
                    s.push_str(std::str::from_utf8(&self.b[start..end])?);
                    self.i = end;
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        while self.i < self.b.len()
            && matches!(self.b[self.i], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
        {
            self.i += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.i])?;
        Ok(Json::Num(s.parse::<f64>().with_context(|| format!("bad number {s:?}"))?))
    }
}

// ---------------------------------------------------------------------------
// Serialization
// ---------------------------------------------------------------------------

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 9e15 {
                    write!(f, "{}", *x as i64)
                } else {
                    write!(f, "{x}")
                }
            }
            Json::Str(s) => write_escaped(f, s),
            Json::Arr(v) => {
                write!(f, "[")?;
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{x}")?;
                }
                write!(f, "]")
            }
            Json::Obj(m) => {
                write!(f, "{{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write_escaped(f, k)?;
                    write!(f, ":{v}")?;
                }
                write!(f, "}}")
            }
        }
    }
}

fn write_escaped(f: &mut fmt::Formatter<'_>, s: &str) -> fmt::Result {
    write!(f, "\"")?;
    for c in s.chars() {
        match c {
            '"' => write!(f, "\\\"")?,
            '\\' => write!(f, "\\\\")?,
            '\n' => write!(f, "\\n")?,
            '\r' => write!(f, "\\r")?,
            '\t' => write!(f, "\\t")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{c}")?,
        }
    }
    write!(f, "\"")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse(" -1.5e3 ").unwrap(), Json::Num(-1500.0));
        assert_eq!(Json::parse(r#""a\nb""#).unwrap(), Json::Str("a\nb".into()));
    }

    #[test]
    fn parse_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": "x"}], "c": false}"#).unwrap();
        assert_eq!(j.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            j.get("a").unwrap().as_arr().unwrap()[2].get("b").unwrap().as_str().unwrap(),
            "x"
        );
        assert!(!j.get("c").unwrap().as_bool().unwrap());
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"x":[1,2.5,"s",null,true],"y":{"z":[]}}"#;
        let j = Json::parse(src).unwrap();
        let j2 = Json::parse(&j.to_string()).unwrap();
        assert_eq!(j, j2);
    }

    #[test]
    fn unicode_and_escapes() {
        let j = Json::parse(r#""café — ünïcode""#).unwrap();
        assert_eq!(j.as_str().unwrap(), "café — ünïcode");
        let j2 = Json::parse(&j.to_string()).unwrap();
        assert_eq!(j, j2);
    }

    #[test]
    fn errors_are_errors() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12x").is_err());
        assert!(Json::parse(r#"{"a" 1}"#).is_err());
    }

    #[test]
    fn usize_vec() {
        let j = Json::parse("[4, 8, 16]").unwrap();
        assert_eq!(j.as_usize_vec().unwrap(), vec![4, 8, 16]);
        assert!(Json::parse("[1.5]").unwrap().as_usize_vec().is_err());
    }
}
