//! Packed expert-major layout metadata — the rust twin of
//! `python/compile/kernels/metadata.py` (the host-side dispatch that
//! precedes the 8 kernel launches). The simulator consumes the tile map;
//! golden tests cross-check against the python implementation.

use super::Decision;

/// Packed layout for a routing decision (all capacities static given
/// (T, K, E, m_tile), matching the AOT shapes).
#[derive(Debug, Clone)]
pub struct RoutingMeta {
    pub m_tile: usize,
    /// Per-expert padded counts: ceil(g_e / m) * m.
    pub p: Vec<usize>,
    /// Exclusive prefix sum of `p` (len e+1).
    pub offsets: Vec<usize>,
    /// Token id per packed slot; `usize::MAX` marks padding.
    pub slot_token: Vec<usize>,
    /// Score per packed slot (0 for padding).
    pub slot_score: Vec<f32>,
    /// Owning expert per M-tile.
    pub tile_expert: Vec<usize>,
    /// Live tiles (== tile_expert.len()).
    pub num_tiles: usize,
}

/// Build the packed layout. Slot order within an expert is ascending
/// token id (deterministic, same as python).
pub fn build_metadata(dec: &Decision, m_tile: usize) -> RoutingMeta {
    let e = dec.e;
    let p: Vec<usize> = dec.g.iter().map(|&g| (g + m_tile - 1) / m_tile * m_tile).collect();
    let mut offsets = vec![0usize; e + 1];
    for j in 0..e {
        offsets[j + 1] = offsets[j] + p[j];
    }
    let total = offsets[e];
    let mut slot_token = vec![usize::MAX; total];
    let mut slot_score = vec![0f32; total];
    let mut cursor = offsets.clone();
    for tok in 0..dec.t {
        for j in 0..e {
            if dec.mask[tok * e + j] {
                let s = cursor[j];
                slot_token[s] = tok;
                slot_score[s] = dec.scores[tok * e + j];
                cursor[j] += 1;
            }
        }
    }
    let num_tiles = total / m_tile;
    let mut tile_expert = vec![0usize; num_tiles];
    let mut j = 0;
    for (i, te) in tile_expert.iter_mut().enumerate() {
        let start = i * m_tile;
        while offsets[j + 1] <= start {
            j += 1;
        }
        *te = j;
    }
    RoutingMeta { m_tile, p, offsets, slot_token, slot_score, tile_expert, num_tiles }
}

impl RoutingMeta {
    /// Padding slots (rows the grouped GEMM computes but masks).
    pub fn padding_slots(&self) -> usize {
        self.slot_token.iter().filter(|&&t| t == usize::MAX).count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::routing::{tc_topk, token_rounding, synth_scores, RoundingRule};
    use crate::util::prng::Prng;
    use crate::util::propcheck::check;

    #[test]
    fn prop_layout_invariants() {
        check("metadata-invariants", 30, |g| {
            let e = *g.choice(&[4usize, 8]);
            let k = g.usize_in(1, 2);
            let m = *g.choice(&[4usize, 8]);
            let t = *g.choice(&[32usize, 64]);
            let mut rng = Prng::new(g.seed);
            let scores = synth_scores(&mut rng, t, e, 0.5);
            let dec = tc_topk(&scores, t, e, k);
            let meta = build_metadata(&dec, m);
            // offsets consistent, tile-aligned
            for j in 0..e {
                assert_eq!(meta.offsets[j] % m, 0);
                assert_eq!(meta.p[j] % m, 0);
                assert!(meta.p[j] >= dec.g[j] && meta.p[j] - dec.g[j] < m);
            }
            // every routed pair appears exactly once
            let live: usize = meta.slot_token.iter().filter(|&&x| x != usize::MAX).count();
            assert_eq!(live, t * k);
            // tiles never straddle experts
            for (i, &te) in meta.tile_expert.iter().enumerate() {
                let start = i * m;
                assert!(start >= meta.offsets[te] && start + m <= meta.offsets[te + 1]);
            }
            assert_eq!(meta.padding_slots(), dec.padding_rows(m));
        });
    }

    #[test]
    fn tr_layout_has_zero_padding() {
        let (t, e, k, m) = (128, 8, 2, 16);
        let mut rng = Prng::new(3);
        let scores = synth_scores(&mut rng, t, e, 0.8);
        let dec = token_rounding(&scores, t, e, k, m, RoundingRule::NearestFreq, &mut rng);
        let meta = build_metadata(&dec, m);
        assert_eq!(meta.padding_slots(), 0);
        assert_eq!(meta.offsets[e], dec.routed_pairs());
    }

    #[test]
    fn slots_sorted_by_token_within_expert() {
        let (t, e, k, m) = (32, 4, 2, 8);
        let mut rng = Prng::new(4);
        let scores = synth_scores(&mut rng, t, e, 0.0);
        let dec = tc_topk(&scores, t, e, k);
        let meta = build_metadata(&dec, m);
        for j in 0..e {
            let lo = meta.offsets[j];
            let hi = lo + dec.g[j];
            let toks: Vec<usize> = meta.slot_token[lo..hi].to_vec();
            let mut sorted = toks.clone();
            sorted.sort();
            assert_eq!(toks, sorted);
        }
    }
}
