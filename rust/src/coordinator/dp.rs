//! Synchronous data-parallel gradient all-reduce.
//!
//! Implements the collective math the paper's FSDP-2 runs rely on: each
//! rank contributes a gradient set for its shard; `all_reduce_mean`
//! averages them in place. A ring-reduce is used (chunked add + scale)
//! so the code path mirrors a real ring all-reduce's schedule and can be
//! benchmarked for the coordinator's hot loop.

use crate::util::tensor::Tensor;

/// Average `shards` gradient sets into the first one (returned). Every
/// shard must have identical tensor shapes.
pub fn all_reduce_mean(mut shards: Vec<Vec<Tensor>>) -> Vec<Tensor> {
    assert!(!shards.is_empty());
    let w = shards.len();
    if w == 1 {
        return shards.pop().unwrap();
    }
    let mut acc = shards.remove(0);
    for shard in &shards {
        assert_eq!(shard.len(), acc.len(), "rank gradient count mismatch");
        for (a, s) in acc.iter_mut().zip(shard) {
            assert_eq!(a.shape, s.shape);
            // chunked add: the ring all-reduce's reduce-scatter step
            for (x, y) in a.data.iter_mut().zip(&s.data) {
                *x += *y;
            }
        }
    }
    let scale = 1.0 / w as f32;
    for a in &mut acc {
        for x in &mut a.data {
            *x *= scale;
        }
    }
    acc
}

/// Shard a global batch (row-major `(rows, seq)`) into `workers` equal
/// token shards. Rows must divide evenly (the loader guarantees it).
pub fn shard_batch(tokens: &[i32], rows: usize, seq: usize, workers: usize) -> Vec<Vec<i32>> {
    assert_eq!(tokens.len(), rows * seq);
    assert_eq!(rows % workers, 0, "batch rows {rows} not divisible by {workers} workers");
    let per = rows / workers;
    (0..workers)
        .map(|w| tokens[w * per * seq..(w + 1) * per * seq].to_vec())
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn t(v: Vec<f32>) -> Tensor {
        Tensor::from_vec(&[v.len()], v).unwrap()
    }

    #[test]
    fn mean_of_two_ranks() {
        let a = vec![t(vec![1.0, 2.0])];
        let b = vec![t(vec![3.0, 6.0])];
        let r = all_reduce_mean(vec![a, b]);
        assert_eq!(r[0].data, vec![2.0, 4.0]);
    }

    #[test]
    fn single_rank_identity() {
        let a = vec![t(vec![1.5])];
        let r = all_reduce_mean(vec![a.clone()]);
        assert_eq!(r[0].data, a[0].data);
    }

    #[test]
    fn shard_roundtrip() {
        let tokens: Vec<i32> = (0..24).collect();
        let shards = shard_batch(&tokens, 4, 6, 2);
        assert_eq!(shards.len(), 2);
        assert_eq!(shards[0], (0..12).collect::<Vec<i32>>());
        assert_eq!(shards[1], (12..24).collect::<Vec<i32>>());
    }

    #[test]
    #[should_panic(expected = "not divisible")]
    fn shard_rejects_uneven() {
        shard_batch(&[0; 18], 3, 6, 2);
    }
}
