//! Fused grouped-expert kernels: gather-GEMM-scatter in the ScatterMoE
//! style the paper benchmarks against.
//!
//! The MoE block's per-expert compute used to materialize three
//! intermediates per expert: a gathered copy of the routed token rows
//! (`xg` forward, `dog` backward), the expert output `y`, and (in the
//! backward) the gate-scaled activation `a_scaled`. All of them were
//! pure IO — copies feeding a GEMM or an axpy. Here they disappear
//! into the GEMM itself:
//!
//! - **gather**: the A-operand pack reads token rows straight through
//!   the per-expert row-index list (`get_a = |i, l| x[rows[i]*d + l]`),
//!   so the gather costs exactly what the pack already cost;
//! - **activation / gate scaling**: the SwiGLU of the cached
//!   pre-activation `H` and the backward's `gate * A` are evaluated
//!   inside the pack closures, once per element;
//! - **scatter**: the output tile is accumulated into the destination
//!   rows (`o[tok] += gate * tile`) in the GEMM epilogue — `y` is never
//!   written anywhere.
//!
//! The forward keeps experts sequential and parallelizes inside each
//! expert over output rows (disjoint scatter targets, since a token
//! appears at most once per expert and row lists are ascending), so
//! every token's output chain stays "ascending experts, one add at a
//! time" — bitwise identical to the reference loop for any thread
//! count and any batch composition. The backward parallelizes across
//! experts (dW1/dW2/dS are per-expert disjoint) with per-thread `dxn`
//! partials reduced in ascending expert order: deterministic for a
//! fixed `SONIC_NATIVE_THREADS`, within float tolerance across counts.

// index-heavy numeric kernels: explicit loops mirror the math
#![allow(clippy::needless_range_loop)]

use super::super::linalg::sigmoid;
use super::gemm::{gemm_buf, GemmBufs, Out};
use super::{plan_threads, plan_threads_flops, scratch};
use crate::util::dtype::{widen, WView};

/// SwiGLU of one packed element pair: `silu(g) * u`.
#[inline]
fn swiglu_elem(g: f32, u: f32) -> f32 {
    g * sigmoid(g) * u
}

/// Per-expert weight source for [`fused_expert_forward_with`]: hands
/// out one expert's `(w1, w2)` operand views and keeps them alive
/// while that expert's GEMMs run. Dense contiguous weights reborrow
/// slices of the full tensors; a tiered residency provider returns a
/// guard owning the file-backed blob, so eviction can never free the
/// bytes mid-GEMM (the guard drops when the expert's iteration ends).
pub trait ExpertViews {
    /// `[d, 2n]` up-projection operand.
    fn w1(&self) -> WView<'_>;
    /// `[n, d]` down-projection operand.
    fn w2(&self) -> WView<'_>;
}

/// Dense contiguous experts: views sliced out of full `[e, …]`
/// weight tensors.
struct DenseExpert<'a> {
    w1: WView<'a>,
    w2: WView<'a>,
}

impl ExpertViews for DenseExpert<'_> {
    fn w1(&self) -> WView<'_> {
        self.w1
    }

    fn w2(&self) -> WView<'_> {
        self.w2
    }
}

/// Fused MoE expert forward.
///
/// Routing is CSR over experts: expert `j` owns token rows
/// `rows_flat[rows_off[j]..rows_off[j+1]]` (strictly ascending) with
/// gate weights at the same offsets in `gates`. Writes the packed
/// pre-activation `H` (the only residual the backward needs) into
/// `h_out` (CSR-aligned, `pairs * 2n`) and accumulates the gate-scaled
/// expert outputs into `o` (`t * d`, zeroed by the caller).
///
/// The expert weights come in as [`WView`]s: bf16-stored experts widen
/// inside the B panel packs (half the streamed bytes, no convert
/// pass), while the f32 arms keep the exact pre-dtype closures so f32
/// results stay bitwise identical.
#[allow(clippy::too_many_arguments)]
pub fn fused_expert_forward(
    d: usize,
    n: usize,
    e: usize,
    xn: &[f32],
    w1: WView<'_>,
    w2: WView<'_>,
    rows_off: &[usize],
    rows_flat: &[usize],
    gates: &[f32],
    h_out: &mut [f32],
    o: &mut [f32],
) {
    fused_expert_forward_with(
        d,
        n,
        e,
        xn,
        |j| DenseExpert {
            w1: w1.slice(j * d * 2 * n..(j + 1) * d * 2 * n),
            w2: w2.slice(j * n * d..(j + 1) * n * d),
        },
        rows_off,
        rows_flat,
        gates,
        h_out,
        o,
    )
}

/// [`fused_expert_forward`] with the per-expert weight lookup
/// abstracted behind an [`ExpertViews`] provider. The provider is
/// called once per *routed* expert (ascending, experts with no rows
/// are skipped), and the value it returns lives exactly as long as
/// that expert's two GEMMs — which is what lets a tiered provider
/// fault in only the experts this batch needs and release each guard
/// before the next expert runs, keeping the minimum working set at
/// one blob. The per-expert body is byte-for-byte the dense kernel's,
/// so results are bitwise identical for identical weight bits.
#[allow(clippy::too_many_arguments)]
pub fn fused_expert_forward_with<V: ExpertViews>(
    d: usize,
    n: usize,
    e: usize,
    xn: &[f32],
    mut expert: impl FnMut(usize) -> V,
    rows_off: &[usize],
    rows_flat: &[usize],
    gates: &[f32],
    h_out: &mut [f32],
    o: &mut [f32],
) {
    debug_assert_eq!(rows_off.len(), e + 1);
    debug_assert_eq!(h_out.len(), rows_off[e] * 2 * n);
    // one thread-track span over the whole fused forward (both GEMMs
    // of every routed expert); 8*pairs*d*n counts each pair's two
    // multiply-adds through W1 [d,2n] and W2 [n,d]
    let mut span = crate::obs::SpanGuard::thread(crate::obs::SpanKind::FusedExpert);
    span.detail(8 * (rows_off[e] as u64) * (d as u64) * (n as u64));
    super::gemm::with_tls_bufs(|bufs| {
        for j in 0..e {
            let (r0, r1) = (rows_off[j], rows_off[j + 1]);
            let rr = r1 - r0;
            if rr == 0 {
                continue;
            }
            let rows = &rows_flat[r0..r1];
            let ev = expert(j);
            let w1_e = ev.w1();
            let w2_e = ev.w2();
            let h_seg = &mut h_out[r0 * 2 * n..r1 * 2 * n];
            // H = gather(X) @ W1_e — the gather is the pack
            match w1_e {
                WView::F32(w) => gemm_buf(
                    rr,
                    2 * n,
                    d,
                    |i, l| xn[rows[i] * d + l],
                    |c, l| w[l * 2 * n + c],
                    Out::Assign { c: &mut *h_seg, stride: 2 * n },
                    bufs,
                    plan_threads(rr, 2 * n, d),
                ),
                WView::Bf16(w) => gemm_buf(
                    rr,
                    2 * n,
                    d,
                    |i, l| xn[rows[i] * d + l],
                    |c, l| widen(w[l * 2 * n + c]),
                    Out::Assign { c: &mut *h_seg, stride: 2 * n },
                    bufs,
                    plan_threads(rr, 2 * n, d),
                ),
            }
            // O[rows] += gates * (SwiGLU(H) @ W2_e) — A packed through
            // the activation, Y scattered from registers
            let h_ro: &[f32] = h_seg;
            match w2_e {
                WView::F32(w) => gemm_buf(
                    rr,
                    d,
                    n,
                    |i, l| swiglu_elem(h_ro[i * 2 * n + l], h_ro[i * 2 * n + n + l]),
                    |c, l| w[l * d + c],
                    Out::ScatterAdd {
                        c: &mut *o,
                        idx: rows,
                        scales: Some(&gates[r0..r1]),
                        stride: d,
                    },
                    bufs,
                    plan_threads(rr, d, n),
                ),
                WView::Bf16(w) => gemm_buf(
                    rr,
                    d,
                    n,
                    |i, l| swiglu_elem(h_ro[i * 2 * n + l], h_ro[i * 2 * n + n + l]),
                    |c, l| widen(w[l * d + c]),
                    Out::ScatterAdd {
                        c: &mut *o,
                        idx: rows,
                        scales: Some(&gates[r0..r1]),
                        stride: d,
                    },
                    bufs,
                    plan_threads(rr, d, n),
                ),
            }
        }
    });
}

/// Per-thread workspace of the fused backward (checked out of the
/// caller's arena so spawned workers never touch their own TLS).
struct BwdBufs {
    gemm: GemmBufs,
    /// Recomputed SwiGLU activation A of one expert (max_rr * n).
    a: Vec<f32>,
    /// dA' = dO W2^T of one expert (max_rr * n).
    dap: Vec<f32>,
    /// dH of one expert (max_rr * 2n).
    dh: Vec<f32>,
}

fn bwd_bufs(max_rr: usize, d: usize, n: usize) -> BwdBufs {
    let max_k = d.max(2 * n).max(max_rr);
    BwdBufs {
        gemm: GemmBufs {
            ap: scratch::take(max_k * super::gemm::MR),
            bp: scratch::take(
                bp_len(n, d)
                    .max(bp_len(d, max_rr))
                    .max(bp_len(2 * n, max_rr))
                    .max(bp_len(d, 2 * n)),
            ),
            arow: scratch::take(max_k),
            orow: scratch::take(d.max(2 * n)),
        },
        a: scratch::take(max_rr * n),
        dap: scratch::take(max_rr * n),
        dh: scratch::take(max_rr * 2 * n),
    }
}

/// Packed-B panel bytes for an (n_cols, k) GEMM.
fn bp_len(n_cols: usize, k: usize) -> usize {
    n_cols.div_ceil(super::gemm::NR) * super::gemm::NR * k
}

fn recycle_bwd(b: BwdBufs) {
    scratch::put(b.gemm.ap);
    scratch::put(b.gemm.bp);
    scratch::put(b.gemm.arow);
    scratch::put(b.gemm.orow);
    scratch::put(b.a);
    scratch::put(b.dap);
    scratch::put(b.dh);
}

/// Fused MoE expert backward (the paper's Appendix C dataflow).
///
/// Consumes the forward's CSR routing (`rows_off`/`rows_flat`/`gates`)
/// and cached `H`; produces `dr_pairs` (dS per routed pair,
/// CSR-aligned), accumulates `dw1`/`dw2` (per-expert blocks), and
/// accumulates `dxn` (`t * d`). The `dog` gather, `a_scaled` and `dxg`
/// materializations of the reference implementation are all folded
/// into GEMM packs/epilogues.
#[allow(clippy::too_many_arguments)]
pub fn fused_expert_backward(
    d: usize,
    n: usize,
    e: usize,
    xn: &[f32],
    d_o: &[f32],
    w1: &[f32],
    w2: &[f32],
    rows_off: &[usize],
    rows_flat: &[usize],
    gates: &[f32],
    h: &[f32],
    dr_pairs: &mut [f32],
    dw1: &mut [f32],
    dw2: &mut [f32],
    dxn: &mut [f32],
) {
    // fwd-equivalent flops of the four per-pair GEMMs
    let flops = 8.0 * rows_off[e] as f64 * d as f64 * n as f64;
    let threads = plan_threads_flops(flops).min(e);
    fused_expert_backward_with_threads(
        d, n, e, xn, d_o, w1, w2, rows_off, rows_flat, gates, h, dr_pairs, dw1, dw2, dxn,
        threads,
    );
}

/// [`fused_expert_backward`] with an explicit thread count (exposed so
/// tests can drive the expert-sharded parallel branch directly — the
/// FLOP threshold keeps test-sized problems sequential otherwise).
#[allow(clippy::too_many_arguments)]
pub fn fused_expert_backward_with_threads(
    d: usize,
    n: usize,
    e: usize,
    xn: &[f32],
    d_o: &[f32],
    w1: &[f32],
    w2: &[f32],
    rows_off: &[usize],
    rows_flat: &[usize],
    gates: &[f32],
    h: &[f32],
    dr_pairs: &mut [f32],
    dw1: &mut [f32],
    dw2: &mut [f32],
    dxn: &mut [f32],
    threads: usize,
) {
    debug_assert_eq!(rows_off.len(), e + 1);
    let pairs = rows_off[e];
    if pairs == 0 {
        return;
    }
    let max_rr = (0..e).map(|j| rows_off[j + 1] - rows_off[j]).max().unwrap_or(0);
    let ranges = partition_experts(rows_off, e, threads.clamp(1, e));

    if ranges.len() <= 1 {
        let mut bufs = bwd_bufs(max_rr, d, n);
        backward_range(
            0, e, 0, 0, d, n, xn, d_o, w1, w2, rows_off, rows_flat, gates, h, dr_pairs, dw1,
            dw2, dxn, &mut bufs,
        );
        recycle_bwd(bufs);
        return;
    }

    // per-thread workspaces + dxn partials, checked out on the caller
    // thread so the arena keeps serving them across calls
    let mut slots: Vec<(BwdBufs, Vec<f32>)> = ranges
        .iter()
        .map(|_| (bwd_bufs(max_rr, d, n), scratch::take(dxn.len())))
        .collect();
    {
        // split the per-expert outputs at the range boundaries: every
        // shard owns disjoint contiguous blocks
        let mut dr_rest = &mut dr_pairs[..];
        let mut dw1_rest = &mut dw1[..];
        let mut dw2_rest = &mut dw2[..];
        let mut shards = Vec::with_capacity(ranges.len());
        let mut p0 = 0usize;
        let mut j_prev = 0usize;
        for &(j0, j1) in &ranges {
            // skip any gap (empty experts between ranges never occur:
            // ranges are contiguous by construction)
            debug_assert_eq!(j0, j_prev);
            j_prev = j1;
            let (dr_c, r) = dr_rest.split_at_mut(rows_off[j1] - p0);
            dr_rest = r;
            p0 = rows_off[j1];
            let (dw1_c, r) = dw1_rest.split_at_mut((j1 - j0) * d * 2 * n);
            dw1_rest = r;
            let (dw2_c, r) = dw2_rest.split_at_mut((j1 - j0) * n * d);
            dw2_rest = r;
            shards.push((j0, j1, dr_c, dw1_c, dw2_c));
        }
        std::thread::scope(|s| {
            for ((j0, j1, dr_c, dw1_c, dw2_c), (bufs, partial)) in
                shards.into_iter().zip(slots.iter_mut())
            {
                s.spawn(move || {
                    // chunk views are re-based on the range start
                    backward_range(
                        j0,
                        j1,
                        j0,
                        rows_off[j0],
                        d,
                        n,
                        xn,
                        d_o,
                        w1,
                        w2,
                        rows_off,
                        rows_flat,
                        gates,
                        h,
                        dr_c,
                        dw1_c,
                        dw2_c,
                        partial,
                        bufs,
                    );
                });
            }
        });
    }
    // deterministic reduction: ascending expert-range order
    for (bufs, partial) in slots {
        for (a, b) in dxn.iter_mut().zip(&partial) {
            *a += b;
        }
        scratch::put(partial);
        recycle_bwd(bufs);
    }
}

/// Contiguous expert ranges with near-equal routed-pair counts.
fn partition_experts(rows_off: &[usize], e: usize, threads: usize) -> Vec<(usize, usize)> {
    let total = rows_off[e];
    let mut ranges = Vec::with_capacity(threads);
    let mut j0 = 0usize;
    for t in 1..=threads {
        if j0 >= e {
            break;
        }
        let j1 = if t == threads {
            e
        } else {
            let target = total * t / threads;
            rows_off.partition_point(|&x| x < target).clamp(j0 + 1, e)
        };
        ranges.push((j0, j1));
        j0 = j1;
    }
    ranges
}

/// Backward over experts `j0..j1`. `j_base`/`p_base` re-base the
/// expert-block and pair offsets into the provided `dw`/`dr` slices
/// (0/0 for full views, `j0`/`rows_off[j0]` for parallel shard views);
/// `dxn` always spans all tokens.
#[allow(clippy::too_many_arguments)]
fn backward_range(
    j0: usize,
    j1: usize,
    j_base: usize,
    p_base: usize,
    d: usize,
    n: usize,
    xn: &[f32],
    d_o: &[f32],
    w1: &[f32],
    w2: &[f32],
    rows_off: &[usize],
    rows_flat: &[usize],
    gates: &[f32],
    h: &[f32],
    dr_pairs: &mut [f32],
    dw1: &mut [f32],
    dw2: &mut [f32],
    dxn: &mut [f32],
    bufs: &mut BwdBufs,
) {
    let n2 = 2 * n;
    for j in j0..j1 {
        let (r0, r1) = (rows_off[j], rows_off[j + 1]);
        let rr = r1 - r0;
        if rr == 0 {
            continue;
        }
        let rows = &rows_flat[r0..r1];
        let gates_e = &gates[r0..r1];
        let h_e = &h[r0 * n2..r1 * n2];
        let w1_e = &w1[j * d * n2..(j + 1) * d * n2];
        let w2_e = &w2[j * n * d..(j + 1) * n * d];

        // dA' = gather(dO) @ W2_e^T  (Eq. 8; dog gathered in the pack)
        gemm_buf(
            rr,
            n,
            d,
            |i, l| d_o[rows[i] * d + l],
            |c, l| w2_e[c * d + l],
            Out::Assign { c: &mut bufs.dap[..rr * n], stride: n },
            &mut bufs.gemm,
            1,
        );
        // A recomputed from the packed H (Algorithm 3), then per pair:
        // dS = <dA', A> (Eq. 10) and dH = dAct(gate * dA', H) (Eq. 11)
        for i in 0..rr {
            let hr = &h_e[i * n2..(i + 1) * n2];
            let ar = &mut bufs.a[i * n..(i + 1) * n];
            let dapr = &bufs.dap[i * n..(i + 1) * n];
            let gate = gates_e[i];
            let mut ds = 0f32;
            let dhr = &mut bufs.dh[i * n2..(i + 1) * n2];
            for jj in 0..n {
                let g = hr[jj];
                let u = hr[n + jj];
                let sig = sigmoid(g);
                let a = g * sig * u;
                ar[jj] = a;
                ds += dapr[jj] * a;
                let da = gate * dapr[jj];
                let dsilu = sig * (1.0 + g * (1.0 - sig));
                dhr[jj] = da * u * dsilu;
                dhr[n + jj] = da * sig * g;
            }
            dr_pairs[r0 - p_base + i] = ds;
        }
        // dW2_e += (gate * A)^T @ gather(dO)  (Eq. 12; the a_scaled
        // materialization and the dog gather both live in the packs)
        let a_ro: &[f32] = &bufs.a;
        gemm_buf(
            n,
            d,
            rr,
            |i, r| gates_e[r] * a_ro[r * n + i],
            |c, r| d_o[rows[r] * d + c],
            Out::Accum {
                c: &mut dw2[(j - j_base) * n * d..(j - j_base + 1) * n * d],
                stride: d,
            },
            &mut bufs.gemm,
            1,
        );
        // dW1_e += gather(X)^T @ dH  (xg gathered in the pack)
        let dh_ro: &[f32] = &bufs.dh;
        gemm_buf(
            d,
            n2,
            rr,
            |i, r| xn[rows[r] * d + i],
            |c, r| dh_ro[r * n2 + c],
            Out::Accum {
                c: &mut dw1[(j - j_base) * d * n2..(j - j_base + 1) * d * n2],
                stride: n2,
            },
            &mut bufs.gemm,
            1,
        );
        // dX[rows] += dH @ W1_e^T  (dxg scattered from registers)
        gemm_buf(
            rr,
            d,
            n2,
            |i, l| dh_ro[i * n2 + l],
            |c, l| w1_e[c * n2 + l],
            Out::ScatterAdd { c: &mut *dxn, idx: rows, scales: None, stride: d },
            &mut bufs.gemm,
            1,
        );
    }
}
