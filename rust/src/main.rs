//! `sonic-moe` CLI — the L3 leader entrypoint.
//!
//! Subcommands:
//!   train       run the training loop on a config
//!   eval        validation loss of a checkpoint (or initial params)
//!   serve       batched scoring service over the LM
//!   simulate    GPU performance model for one MoE shape
//!   memory      activation-memory report (Figure 10 style)
//!   routing     routing statistics / token-rounding demo on synth scores
//!   info        manifest + artifact inventory
//!
//! All model subcommands run on the execution backend selected by
//! `--backend` / `SONIC_BACKEND` (native pure-rust CPU by default; PJRT
//! when built with `--features pjrt`). With no artifacts directory the
//! native backend uses the built-in configs, so `sonic-moe train` works
//! out of the box.

use anyhow::{bail, Result};

use sonic_moe::coordinator::serve::Server;
use sonic_moe::coordinator::{Trainer, TrainerConfig};
use sonic_moe::gateway::loadgen::{self, LoadgenConfig};
use sonic_moe::gateway::{BatchPolicy, Gateway, GatewayConfig};
use sonic_moe::data::{Corpus, CorpusConfig};
use sonic_moe::memory;
use sonic_moe::routing::{self, RoundingRule};
use sonic_moe::simulator::{self, configs::MoeShape, Method, Pass};
use sonic_moe::util::cli::Cli;
use sonic_moe::util::prng::Prng;

fn main() {
    env_logger_init();
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

/// Minimal env-filter logger (no env_logger crate offline).
fn env_logger_init() {
    struct L;
    impl log::Log for L {
        fn enabled(&self, m: &log::Metadata) -> bool {
            m.level() <= log::max_level()
        }
        fn log(&self, r: &log::Record) {
            if self.enabled(r.metadata()) {
                eprintln!("[{}] {}", r.level(), r.args());
            }
        }
        fn flush(&self) {}
    }
    static LOGGER: L = L;
    let _ = log::set_logger(&LOGGER);
    let level = match std::env::var("RUST_LOG").as_deref() {
        Ok("debug") => log::LevelFilter::Debug,
        Ok("trace") => log::LevelFilter::Trace,
        Ok("warn") => log::LevelFilter::Warn,
        Ok("error") => log::LevelFilter::Error,
        _ => log::LevelFilter::Info,
    };
    log::set_max_level(level);
}

fn run() -> Result<()> {
    let mut argv: Vec<String> = std::env::args().skip(1).collect();
    let sub = if argv.is_empty() { "help".to_string() } else { argv.remove(0) };
    match sub.as_str() {
        "train" => cmd_train(argv),
        "eval" => cmd_eval(argv),
        "serve" => cmd_serve(argv),
        "gateway" => cmd_gateway(argv),
        "loadgen" => cmd_loadgen(argv),
        "simulate" => cmd_simulate(argv),
        "memory" => cmd_memory(argv),
        "routing" => cmd_routing(argv),
        "info" => cmd_info(argv),
        _ => {
            println!(
                "sonic-moe — SonicMoE reproduction CLI\n\n\
                 subcommands:\n\
                 \x20 train     train the MoE LM end to end\n\
                 \x20 eval      validation loss of a checkpoint\n\
                 \x20 serve     batched LM scoring service\n\
                 \x20 gateway   concurrent TCP scoring gateway (line-JSON protocol)\n\
                 \x20 loadgen   drive an in-process gateway with open/closed-loop load\n\
                 \x20 simulate  GPU performance model for one MoE shape\n\
                 \x20 memory    activation-memory report\n\
                 \x20 routing   token-rounding statistics on synthetic scores\n\
                 \x20 info      manifest inventory\n\n\
                 run `sonic-moe <subcommand> --help` for options"
            );
            Ok(())
        }
    }
}

fn cmd_train(argv: Vec<String>) -> Result<()> {
    let cli = Cli::new("sonic-moe train", "train the MoE LM end to end")
        .opt("artifacts", "artifacts", "artifacts directory")
        .opt("config", "small", "AOT config name (small|medium)")
        .opt("router", "tc", "routing method artifact (tc|tr)")
        .opt("steps", "100", "training steps")
        .opt("warmup", "10", "LR warmup steps")
        .opt("lr", "6e-4", "peak learning rate")
        .opt("weight-decay", "0.01", "AdamW weight decay")
        .opt("clip", "1.0", "gradient clipping norm")
        .opt("workers", "1", "data-parallel ranks")
        .opt("seed", "0", "data seed")
        .opt("log-every", "10", "console log interval")
        .opt("eval-every", "0", "validation interval (0 = off)")
        .opt("csv", "", "CSV metrics path (empty = off)")
        .opt("checkpoint", "", "checkpoint dir (empty = off)")
        .opt("backend", "", "execution backend (native|pjrt; default native)");
    let a = cli.parse_from(argv)?;
    let cfg = TrainerConfig {
        artifacts_dir: a.get("artifacts").to_string(),
        config_name: a.get("config").to_string(),
        router: a.get("router").to_string(),
        steps: a.get_u64("steps")?,
        warmup: a.get_u64("warmup")?,
        lr: a.get_f64("lr")? as f32,
        weight_decay: a.get_f64("weight-decay")? as f32,
        clip: a.get_f64("clip")? as f32,
        workers: a.get_usize("workers")?,
        seed: a.get_u64("seed")?,
        log_every: a.get_u64("log-every")?,
        eval_every: a.get_u64("eval-every")?,
        csv_path: non_empty(a.get("csv")),
        checkpoint_dir: non_empty(a.get("checkpoint")),
        backend: a.get("backend").to_string(),
    };
    let mut t = Trainer::new(cfg)?;
    let ema = t.run()?;
    println!("final smoothed CE: {ema:.4}");
    Ok(())
}

fn cmd_eval(argv: Vec<String>) -> Result<()> {
    let cli = Cli::new("sonic-moe eval", "validation CE of a checkpoint")
        .opt("artifacts", "artifacts", "artifacts directory")
        .opt("config", "small", "AOT config name")
        .opt("checkpoint", "", "checkpoint dir (empty = initial params)")
        .opt("batches", "8", "validation microbatches")
        .opt("backend", "", "execution backend (native|pjrt; default native)");
    let a = cli.parse_from(argv)?;
    let mut t = Trainer::new(TrainerConfig {
        artifacts_dir: a.get("artifacts").to_string(),
        config_name: a.get("config").to_string(),
        steps: 0,
        backend: a.get("backend").to_string(),
        ..Default::default()
    })?;
    if let Some(dir) = non_empty(a.get("checkpoint")) {
        let step = t.restore(&dir)?;
        println!("restored checkpoint at step {step}");
    }
    let ce = t.evaluate(a.get_usize("batches")?)?;
    println!("val_ce {ce:.4}  (ppl {:.2})", ce.exp());
    Ok(())
}

fn cmd_serve(argv: Vec<String>) -> Result<()> {
    let cli = Cli::new("sonic-moe serve", "batched LM scoring service")
        .opt("artifacts", "artifacts", "artifacts directory")
        .opt("config", "small", "config name")
        .opt("checkpoint", "", "trained checkpoint dir (empty = initial params)")
        .opt("rows", "32", "synthetic scoring requests to serve")
        .opt("seed", "42", "request stream seed")
        .opt("backend", "", "execution backend (native|pjrt; default native)");
    let a = cli.parse_from(argv)?;
    let mut server =
        Server::new_with_backend(a.get("artifacts"), a.get("config"), a.get("backend"))?;
    if let Some(dir) = non_empty(a.get("checkpoint")) {
        server.load_checkpoint(&dir)?;
        println!("loaded checkpoint from {dir}");
    }
    println!(
        "server up: backend={} config={} batch={} seq={}",
        server.backend_name(),
        a.get("config"),
        server.rows,
        server.seq
    );

    // synthetic request stream: mostly in-distribution corpus tokens,
    // every 4th request out-of-distribution junk
    let n = a.get_usize("rows")?;
    let seed = a.get_u64("seed")?;
    let vocab = server.vocab();
    let mut corpus = Corpus::new(CorpusConfig { vocab, ..Default::default() }, seed);
    let seq = server.seq;
    for id in 0..n as u64 {
        let toks: Vec<i32> = if id % 4 == 3 {
            (0..seq).map(|j| ((id as usize * 131 + j * 7) % vocab) as i32).collect()
        } else {
            corpus.next_batch(1, seq)
        };
        server.submit(id, toks);
    }
    let responses = server.drain()?;

    let mut tbl = sonic_moe::bench::Table::new(
        "scoring responses (first 8)",
        &["request", "ce", "ppl", "latency ms"],
    );
    for r in responses.iter().take(8) {
        tbl.row(&[
            r.id.to_string(),
            format!("{:.4}", r.ce),
            format!("{:.2}", r.ppl),
            format!("{:.2}", r.latency_s * 1e3),
        ]);
    }
    tbl.print();

    let s = server.stats;
    let mut t = sonic_moe::bench::Table::new("service report", &["metric", "value"]);
    t.row(&["requests served".into(), s.requests.to_string()]);
    t.row(&["batches executed".into(), s.batches.to_string()]);
    t.row(&["batch padding".into(), format!("{:.1}%", 100.0 * s.padding_frac())]);
    t.row(&["mean request latency".into(), format!("{:.1} ms", s.mean_latency_s() * 1e3)]);
    t.row(&["throughput".into(), format!("{:.0} tokens/s", s.tokens_per_s())]);
    t.print();
    Ok(())
}

/// Shared gateway options (used by `gateway` and `loadgen`).
fn gateway_cli(cli: Cli) -> Cli {
    cli.opt("artifacts", "artifacts", "artifacts directory")
        .opt("config", "small", "config name")
        .opt("checkpoint", "", "trained checkpoint dir (empty = initial params)")
        .opt("workers", "2", "worker threads (one runtime each)")
        .opt("queue-cap", "64", "admission queue capacity (full = shed)")
        .opt("policy", "tile", "batching policy (immediate|deadline|tile)")
        .opt("max-wait-ms", "20", "batch hold deadline for deadline/tile policies")
        .opt("m-tile", "0", "row tile for executed batch shapes (0 = model batch)")
        .opt("worker-delay-ms", "0", "simulated extra model latency per batch")
        .opt("backend", "", "execution backend (native|pjrt; default native)")
}

fn gateway_config(a: &sonic_moe::util::cli::Args, addr: &str) -> Result<GatewayConfig> {
    let m_tile = a.get_usize("m-tile")?;
    let max_wait = std::time::Duration::from_millis(a.get_u64("max-wait-ms")?);
    // a tile of 0 is resolved by the gateway (model batch) once it
    // knows the config
    let policy = BatchPolicy::parse(a.get("policy"), m_tile, max_wait)?;
    Ok(GatewayConfig {
        artifacts_dir: a.get("artifacts").to_string(),
        config: a.get("config").to_string(),
        backend: a.get("backend").to_string(),
        addr: addr.to_string(),
        workers: a.get_usize("workers")?,
        queue_cap: a.get_usize("queue-cap")?,
        policy,
        m_tile,
        checkpoint: non_empty(a.get("checkpoint")),
        worker_delay_ms: a.get_u64("worker-delay-ms")?,
    })
}

fn cmd_gateway(argv: Vec<String>) -> Result<()> {
    let cli = gateway_cli(Cli::new(
        "sonic-moe gateway",
        "concurrent TCP scoring gateway (line-delimited JSON protocol)",
    ))
    .opt("addr", "127.0.0.1:7433", "bind address (port 0 = ephemeral)");
    let a = cli.parse_from(argv)?;
    let cfg = gateway_config(&a, a.get("addr"))?;
    let policy = cfg.policy;
    let gw = Gateway::start(cfg)?;
    println!(
        "gateway listening on {} (config={} policy={}) — send {{\"type\":\"shutdown\"}} to stop",
        gw.local_addr(),
        a.get("config"),
        policy.name()
    );
    let stats = gw.join(); // blocks until a client sends shutdown
    let p = stats.latency_percentiles();
    let mut t = sonic_moe::bench::Table::new("gateway final stats", &["metric", "value"]);
    t.row(&["requests admitted".into(), stats.requests.to_string()]);
    t.row(&["responses".into(), stats.responses.to_string()]);
    t.row(&["batches".into(), stats.batches.to_string()]);
    t.row(&["shed (queue full)".into(), stats.shed.to_string()]);
    t.row(&["padding".into(), format!("{:.1}%", 100.0 * stats.padding_frac())]);
    t.row(&["p50 / p95 / p99".into(), format!("{:.1} / {:.1} / {:.1} ms", p.p50, p.p95, p.p99)]);
    t.row(&["throughput".into(), format!("{:.0} tokens/s", stats.tokens_per_s())]);
    t.print();
    Ok(())
}

fn cmd_loadgen(argv: Vec<String>) -> Result<()> {
    let cli = gateway_cli(Cli::new(
        "sonic-moe loadgen",
        "drive an in-process gateway with open/closed-loop load",
    ))
    .opt("requests", "64", "total score requests")
    .opt("clients", "3", "concurrent client connections")
    .opt("rate", "0", "aggregate offered requests/s (0 = closed loop)")
    .opt("seq-hint", "0", "synthetic sequence length center (0 = model seq)")
    .opt("seed", "0", "request stream seed");
    let a = cli.parse_from(argv)?;
    let cfg = gateway_config(&a, "127.0.0.1:0")?;
    let lg = LoadgenConfig {
        requests: a.get_usize("requests")?,
        clients: a.get_usize("clients")?,
        rate: a.get_f64("rate")?,
        // 0 resolves to the served model's seq inside run_inprocess
        seq_hint: a.get_usize("seq-hint")?,
        seed: a.get_u64("seed")?,
    };
    let report = loadgen::run_inprocess(cfg, lg)?;
    let mut t = sonic_moe::bench::Table::new("loadgen report", &["metric", "value"]);
    t.row(&["policy / mode".into(), format!("{} / {}", report.policy, report.mode)]);
    t.row(&["sent / ok / shed".into(), format!("{} / {} / {}", report.sent, report.ok, report.shed)]);
    t.row(&["achieved".into(), format!("{:.1} req/s", report.achieved_rps)]);
    t.row(&[
        "latency p50 / p95 / p99".into(),
        format!("{:.1} / {:.1} / {:.1} ms", report.p50_ms, report.p95_ms, report.p99_ms),
    ]);
    t.row(&["padding".into(), format!("{:.1}%", 100.0 * report.padding_frac)]);
    t.row(&["throughput".into(), format!("{:.0} tokens/s", report.tokens_per_s)]);
    t.print();
    println!("{}", report.to_json());
    Ok(())
}

fn cmd_simulate(argv: Vec<String>) -> Result<()> {
    let cli = Cli::new("sonic-moe simulate", "GPU perf model for one MoE shape")
        .opt("t", "24576", "tokens per microbatch")
        .opt("d", "1536", "embedding dim")
        .opt("n", "256", "expert intermediate dim")
        .opt("e", "128", "total experts")
        .opt("k", "8", "activated experts")
        .opt("gpu", "h100", "h100|b300");
    let a = cli.parse_from(argv)?;
    let s = MoeShape::new(
        a.get_usize("t")?,
        a.get_usize("d")?,
        a.get_usize("n")?,
        a.get_usize("e")?,
        a.get_usize("k")?,
    );
    let hw = match a.get("gpu") {
        "h100" => simulator::H100,
        "b300" => simulator::B300,
        g => bail!("unknown gpu {g:?}"),
    };
    println!(
        "shape T={} d={} n={} E={} K={}  G={:.2}  rho={:.3}  on {}",
        s.t, s.d, s.n, s.e, s.k, s.granularity(), s.activation_ratio(), hw.name
    );
    let mut tbl = sonic_moe::bench::Table::new(
        "fwd / bwd model TFLOPS",
        &["method", "fwd TF/s", "bwd TF/s", "fwd ms", "bwd ms"],
    );
    for m in Method::MAIN {
        let f = simulator::evaluate_uniform(m, &s, Pass::Forward, &hw);
        let b = simulator::evaluate_uniform(m, &s, Pass::Backward, &hw);
        tbl.row(&[
            m.name().to_string(),
            format!("{:.0}", f.model_tflops),
            format!("{:.0}", b.model_tflops),
            format!("{:.2}", f.time_s * 1e3),
            format!("{:.2}", b.time_s * 1e3),
        ]);
    }
    tbl.print();
    Ok(())
}

fn cmd_memory(argv: Vec<String>) -> Result<()> {
    let cli = Cli::new("sonic-moe memory", "activation memory per layer")
        .opt("t", "24576", "tokens")
        .opt("d", "1536", "embedding dim")
        .opt("n", "256", "expert intermediate dim")
        .opt("e", "128", "total experts")
        .opt("k", "8", "activated experts");
    let a = cli.parse_from(argv)?;
    let s = MoeShape::new(
        a.get_usize("t")?,
        a.get_usize("d")?,
        a.get_usize("n")?,
        a.get_usize("e")?,
        a.get_usize("k")?,
    );
    let mut tbl = sonic_moe::bench::Table::new(
        "activation memory per MoE layer",
        &["method", "cached GiB", "peak GiB"],
    );
    for m in memory::Method::ALL {
        if !m.supports(&s) {
            tbl.row(&[m.name().to_string(), "n/a".into(), "n/a".into()]);
            continue;
        }
        tbl.row(&[
            m.name().to_string(),
            format!("{:.3}", memory::gib(memory::cached_activation_bytes(m, &s))),
            format!("{:.3}", memory::gib(memory::peak_activation_bytes(m, &s))),
        ]);
    }
    tbl.print();
    Ok(())
}

fn cmd_routing(argv: Vec<String>) -> Result<()> {
    let cli = Cli::new("sonic-moe routing", "token-rounding statistics")
        .opt("t", "16384", "tokens")
        .opt("e", "128", "experts")
        .opt("k", "8", "top-K")
        .opt("m-tile", "128", "GEMM tile size")
        .opt("skew", "0.5", "expert popularity skew")
        .opt("seed", "0", "rng seed");
    let a = cli.parse_from(argv)?;
    let (t, e, k) = (a.get_usize("t")?, a.get_usize("e")?, a.get_usize("k")?);
    let m_tile = a.get_usize("m-tile")?;
    let mut rng = Prng::new(a.get_u64("seed")?);
    let scores = routing::synth_scores(&mut rng, t, e, a.get_f64("skew")?);
    let tc = routing::tc_topk(&scores, t, e, k);
    let mut tbl = sonic_moe::bench::Table::new(
        "routing methods on one microbatch",
        &["method", "routed pairs", "padding rows", "waste %"],
    );
    let waste = |g: &routing::Decision| {
        100.0 * g.padding_rows(m_tile) as f64
            / (g.routed_pairs() + g.padding_rows(m_tile)) as f64
    };
    tbl.row(&[
        "TC top-K".into(),
        tc.routed_pairs().to_string(),
        tc.padding_rows(m_tile).to_string(),
        format!("{:.2}", waste(&tc)),
    ]);
    for rule in RoundingRule::ALL {
        let d = routing::token_rounding(&scores, t, e, k, m_tile, rule, &mut rng);
        tbl.row(&[
            format!("TR ({})", rule.name()),
            d.routed_pairs().to_string(),
            d.padding_rows(m_tile).to_string(),
            format!("{:.2}", waste(&d)),
        ]);
    }
    tbl.print();
    Ok(())
}

fn cmd_info(argv: Vec<String>) -> Result<()> {
    let cli = Cli::new("sonic-moe info", "manifest inventory")
        .opt("artifacts", "artifacts", "artifacts directory");
    let a = cli.parse_from(argv)?;
    let dir = a.get("artifacts");
    let print_cfg = |name: &str, cfg: &sonic_moe::runtime::ConfigManifest| {
        println!(
            "config {name}: vocab={} d={} layers={} E={} K={} n={}  ({} params, {} active)",
            cfg.model.vocab, cfg.model.d, cfg.model.n_layers, cfg.model.e, cfg.model.k,
            cfg.model.n, cfg.num_params, cfg.num_active_params
        );
        for (an, aspec) in &cfg.artifacts {
            let file = if aspec.file.is_empty() { "<native>" } else { &aspec.file };
            println!(
                "  artifact {an}: {file} ({} in, {} out)",
                aspec.inputs.len(),
                aspec.outputs.len()
            );
        }
    };
    if !sonic_moe::runtime::artifacts_available(dir) {
        println!(
            "no manifest in {dir:?} — built-in native configs (run `make artifacts` \
             for the AOT export):"
        );
        for name in sonic_moe::runtime::backend::native::BUILTIN_CONFIGS {
            let cfg = sonic_moe::runtime::backend::native::builtin_manifest(name)
                .expect("BUILTIN_CONFIGS entry must resolve in builtin_cfg");
            print_cfg(name, &cfg);
        }
        return Ok(());
    }
    let path = sonic_moe::runtime::resolve_artifacts_dir(dir).join("manifest.json");
    let m = sonic_moe::runtime::Manifest::load(path.to_str().expect("utf-8 path"))?;
    for (name, cfg) in &m.configs {
        print_cfg(name, cfg);
    }
    Ok(())
}

fn non_empty(s: &str) -> Option<String> {
    if s.is_empty() { None } else { Some(s.to_string()) }
}
