//! Optimizers over flat f32 parameter vectors. The coordinator (not the
//! HLO graph) owns parameter + optimizer state, which is what makes the
//! rust-side data-parallel all-reduce and checkpointing possible (the
//! lm-engine/FSDP role in the paper's end-to-end runs).

use crate::util::tensor::Tensor;

/// AdamW with decoupled weight decay and optional cosine LR schedule.
#[derive(Debug, Clone)]
pub struct AdamW {
    pub lr: f32,
    pub beta1: f32,
    pub beta2: f32,
    pub eps: f32,
    pub weight_decay: f32,
    step: u64,
    m: Vec<Vec<f32>>,
    v: Vec<Vec<f32>>,
}

impl AdamW {
    pub fn new(params: &[Tensor], lr: f32, weight_decay: f32) -> AdamW {
        AdamW {
            lr,
            beta1: 0.9,
            beta2: 0.95,
            eps: 1e-8,
            weight_decay,
            step: 0,
            m: params.iter().map(|p| vec![0.0; p.numel()]).collect(),
            v: params.iter().map(|p| vec![0.0; p.numel()]).collect(),
        }
    }

    pub fn step_count(&self) -> u64 {
        self.step
    }

    /// One update with an explicit learning rate (schedules live in the
    /// trainer). `no_decay` marks params exempt from weight decay
    /// (norms, embeddings) by index.
    pub fn step_with_lr(
        &mut self,
        params: &mut [Tensor],
        grads: &[Tensor],
        lr: f32,
        no_decay: &[bool],
    ) {
        assert_eq!(params.len(), grads.len());
        assert_eq!(params.len(), self.m.len());
        self.step += 1;
        let t = self.step as f32;
        let bc1 = 1.0 - self.beta1.powf(t);
        let bc2 = 1.0 - self.beta2.powf(t);
        for i in 0..params.len() {
            let p = &mut params[i].data;
            let g = &grads[i].data;
            let m = &mut self.m[i];
            let v = &mut self.v[i];
            let wd = if no_decay.get(i).copied().unwrap_or(false) { 0.0 } else { self.weight_decay };
            debug_assert_eq!(p.len(), g.len());
            for j in 0..p.len() {
                m[j] = self.beta1 * m[j] + (1.0 - self.beta1) * g[j];
                v[j] = self.beta2 * v[j] + (1.0 - self.beta2) * g[j] * g[j];
                let mhat = m[j] / bc1;
                let vhat = v[j] / bc2;
                p[j] -= lr * (mhat / (vhat.sqrt() + self.eps) + wd * p[j]);
            }
        }
    }

    pub fn step(&mut self, params: &mut [Tensor], grads: &[Tensor], no_decay: &[bool]) {
        self.step_with_lr(params, grads, self.lr, no_decay)
    }
}

/// Cosine schedule with linear warmup (the paper's LR scheduler, App. I).
pub fn cosine_warmup_lr(base_lr: f32, step: u64, total: u64, warmup: u64) -> f32 {
    if total == 0 {
        return base_lr;
    }
    if step < warmup {
        return base_lr * (step + 1) as f32 / warmup.max(1) as f32;
    }
    let p = (step - warmup) as f32 / (total - warmup).max(1) as f32;
    let min_lr = 0.1 * base_lr;
    min_lr + 0.5 * (base_lr - min_lr) * (1.0 + (std::f32::consts::PI * p.min(1.0)).cos())
}

/// Global-norm gradient clipping; returns the pre-clip norm.
pub fn clip_grad_norm(grads: &mut [Tensor], max_norm: f32) -> f32 {
    let mut sq = 0f64;
    for g in grads.iter() {
        for &x in &g.data {
            sq += (x as f64) * (x as f64);
        }
    }
    let norm = sq.sqrt() as f32;
    if norm > max_norm && norm > 0.0 {
        let scale = max_norm / norm;
        for g in grads.iter_mut() {
            for x in &mut g.data {
                *x *= scale;
            }
        }
    }
    norm
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quad_grad(p: &Tensor) -> Tensor {
        // grad of f(x) = 0.5*||x - 3||^2 is (x - 3)
        Tensor::from_vec(&p.shape, p.data.iter().map(|x| x - 3.0).collect()).unwrap()
    }

    #[test]
    fn adamw_minimizes_quadratic() {
        let mut params = vec![Tensor::from_vec(&[4], vec![0.0, 10.0, -5.0, 3.0]).unwrap()];
        let mut opt = AdamW::new(&params, 0.1, 0.0);
        for _ in 0..500 {
            let g = vec![quad_grad(&params[0])];
            opt.step(&mut params, &g, &[false]);
        }
        for &x in &params[0].data {
            assert!((x - 3.0).abs() < 0.05, "{x}");
        }
    }

    #[test]
    fn weight_decay_shrinks_params() {
        let mut p1 = vec![Tensor::from_vec(&[1], vec![5.0]).unwrap()];
        let mut p2 = vec![Tensor::from_vec(&[1], vec![5.0]).unwrap()];
        let zero_g = vec![Tensor::from_vec(&[1], vec![0.0]).unwrap()];
        let mut o1 = AdamW::new(&p1, 0.01, 0.1);
        let mut o2 = AdamW::new(&p2, 0.01, 0.1);
        for _ in 0..10 {
            o1.step(&mut p1, &zero_g, &[false]);
            o2.step(&mut p2, &zero_g, &[true]); // no_decay
        }
        assert!(p1[0].data[0] < 5.0);
        assert_eq!(p2[0].data[0], 5.0);
    }

    #[test]
    fn cosine_schedule_shape() {
        let base = 1.0;
        assert!(cosine_warmup_lr(base, 0, 100, 10) < 0.2);
        assert!((cosine_warmup_lr(base, 10, 100, 10) - base).abs() < 1e-6);
        let mid = cosine_warmup_lr(base, 55, 100, 10);
        let end = cosine_warmup_lr(base, 99, 100, 10);
        assert!(mid < base && mid > end);
        assert!(end >= 0.1 * base - 1e-6);
    }

    #[test]
    fn clip_grad_norm_scales() {
        let mut g = vec![Tensor::from_vec(&[2], vec![3.0, 4.0]).unwrap()];
        let norm = clip_grad_norm(&mut g, 1.0);
        assert!((norm - 5.0).abs() < 1e-6);
        let new: f32 = g[0].data.iter().map(|x| x * x).sum::<f32>().sqrt();
        assert!((new - 1.0).abs() < 1e-5);
        // under the cap: untouched
        let mut g2 = vec![Tensor::from_vec(&[2], vec![0.3, 0.4]).unwrap()];
        clip_grad_norm(&mut g2, 1.0);
        assert_eq!(g2[0].data, vec![0.3, 0.4]);
    }
}
