//! Front-tier integration: a real `Front` over real TCP gateway
//! replicas, exercising routing, failover, shedding, fault injection
//! and the headline replica-kill drill.
//!
//! | test                         | invariant                                   |
//! |------------------------------|---------------------------------------------|
//! | relay round-trip             | scores/streams through the front bitwise    |
//! |                              | identical to a direct gateway               |
//! | model-tag routing            | tagged requests only reach their replica    |
//! | scripted score failover      | retried scores bitwise identical; failover  |
//! |                              | latency lands in the `sonic_front_*` series |
//! | replica kill mid-decode      | survivors unaffected; exactly one           |
//! |                              | `replica_lost` with the right `last_index`; |
//! |                              | breaker trips and recovers                  |
//! | all replicas down            | `no_healthy_replica` + `retry_after_ms`     |
//! | exhausted retries            | clean `exec_failed`, no hang                |
//! | scripted fault plan          | probe-count kills/stalls fire exactly once  |
//!
//! Replica death is scripted through the front's kill epoch (the
//! gateway process is never actually stopped), so every drill is
//! deterministic and the half-open recovery path runs end to end.
//! `SONIC_TEST_DTYPE=bf16` reruns the suite at bf16 storage precision.

use std::io::{BufRead, BufReader, Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::time::{Duration, Instant};

use sonic_moe::front::{Front, FrontConfig, FrontFaultPlan, ReplicaSpec, ReplicaState};
use sonic_moe::gateway::{BatchPolicy, ClientMsg, Gateway, GatewayConfig, ServerMsg};
use sonic_moe::util::dtype::Dtype;

const NO_ARTIFACTS: &str = "/nonexistent-artifacts-dir";

/// Storage precision under test: `SONIC_TEST_DTYPE` (default f32).
fn test_dtype() -> Dtype {
    match std::env::var("SONIC_TEST_DTYPE") {
        Ok(s) => Dtype::parse(&s).expect("SONIC_TEST_DTYPE must be f32 or bf16"),
        Err(_) => Dtype::F32,
    }
}

fn base_cfg() -> GatewayConfig {
    GatewayConfig {
        artifacts_dir: NO_ARTIFACTS.to_string(),
        config: "small".to_string(),
        backend: "native".to_string(),
        addr: "127.0.0.1:0".to_string(),
        workers: 1,
        queue_cap: 64,
        policy: BatchPolicy::Immediate,
        m_tile: 2,
        gen_max_new: 8,
        dtype: test_dtype(),
        ..GatewayConfig::default()
    }
}

/// A front with test-friendly probing over the given replicas.
fn front_over(replicas: Vec<ReplicaSpec>, tweak: impl FnOnce(&mut FrontConfig)) -> Front {
    let mut cfg = FrontConfig {
        replicas,
        probe_interval_ms: 50,
        probe_timeout_ms: 500,
        retry_base_ms: 1,
        ..FrontConfig::default()
    };
    tweak(&mut cfg);
    Front::start(cfg).expect("start front")
}

fn spec(addr: SocketAddr, model: &str) -> ReplicaSpec {
    ReplicaSpec { addr: addr.to_string(), model: model.to_string() }
}

/// Reserve a loopback port that nothing listens on (bind, read the
/// address, release): a deterministic "dead replica" address that a
/// later gateway can also bind for "the replica came back elsewhere".
fn reserve_addr() -> String {
    let l = std::net::TcpListener::bind("127.0.0.1:0").expect("reserve port");
    let addr = l.local_addr().unwrap().to_string();
    drop(l);
    addr
}

fn wait_until(what: &str, mut cond: impl FnMut() -> bool) {
    let deadline = Instant::now() + Duration::from_secs(10);
    while !cond() {
        assert!(Instant::now() < deadline, "timed out waiting for {what}");
        std::thread::sleep(Duration::from_millis(20));
    }
}

struct Client {
    stream: TcpStream,
    reader: BufReader<TcpStream>,
}

impl Client {
    fn connect(addr: SocketAddr) -> Client {
        let stream = TcpStream::connect(addr).expect("connect");
        stream.set_nodelay(true).unwrap();
        stream.set_read_timeout(Some(Duration::from_secs(60))).unwrap();
        let reader = BufReader::new(stream.try_clone().unwrap());
        Client { stream, reader }
    }

    /// Send a raw request line (the front peeks `model` tags that
    /// [`ClientMsg`] does not carry).
    fn send_raw(&mut self, line: &str) {
        self.stream.write_all(line.as_bytes()).unwrap();
        self.stream.write_all(b"\n").unwrap();
        self.stream.flush().unwrap();
    }

    fn send(&mut self, msg: &ClientMsg) {
        self.send_raw(&msg.encode());
    }

    fn recv(&mut self) -> ServerMsg {
        let mut line = String::new();
        let n = self.reader.read_line(&mut line).expect("read reply");
        assert!(n > 0, "server closed the connection unexpectedly");
        ServerMsg::parse(&line).expect("parse reply")
    }

    /// Expect a score reply for `id` and return its CE.
    fn recv_score(&mut self, id: u64) -> f64 {
        match self.recv() {
            ServerMsg::Score { id: rid, ce, .. } => {
                assert_eq!(rid, id, "score routed to the wrong request");
                ce
            }
            other => panic!("expected score for {id}, got {other:?}"),
        }
    }

    /// Consume one stream to its `done` frame, asserting contiguous
    /// token indices; returns the tokens.
    fn read_stream(&mut self, id: u64) -> Vec<i32> {
        let mut streamed = Vec::new();
        loop {
            match self.recv() {
                ServerMsg::Token { id: rid, token, index } => {
                    assert_eq!(rid, id);
                    assert_eq!(index, streamed.len(), "stream {id} skipped or repeated a frame");
                    streamed.push(token);
                }
                ServerMsg::Done { id: rid, tokens, .. } => {
                    assert_eq!(rid, id);
                    assert_eq!(tokens, streamed, "done frame disagrees with streamed tokens");
                    return streamed;
                }
                other => panic!("unexpected frame on stream {id}: {other:?}"),
            }
        }
    }

    fn generate(&mut self, id: u64, prompt: &[i32], max_new: usize, model: &str) -> Vec<i32> {
        self.send_raw(&raw_generate(id, prompt, max_new, model));
        self.read_stream(id)
    }
}

fn join_tokens(tokens: &[i32]) -> String {
    tokens.iter().map(|t| t.to_string()).collect::<Vec<_>>().join(",")
}

fn raw_score(id: u64, tokens: &[i32], model: &str) -> String {
    format!(r#"{{"type":"score","id":{id},"tokens":[{}],"model":"{model}"}}"#, join_tokens(tokens))
}

fn raw_generate(id: u64, tokens: &[i32], max_new: usize, model: &str) -> String {
    format!(
        r#"{{"type":"generate","id":{id},"tokens":[{}],"max_new":{max_new},"model":"{model}"}}"#,
        join_tokens(tokens)
    )
}

/// Fetch the Prometheus exposition body (the one reply that closes the
/// connection instead of framing a JSON line).
fn fetch_metrics(addr: SocketAddr) -> String {
    let mut stream = TcpStream::connect(addr).expect("connect for metrics");
    stream.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
    stream.write_all(b"{\"type\":\"metrics\"}\n").unwrap();
    stream.flush().unwrap();
    let mut body = String::new();
    stream.read_to_string(&mut body).expect("read metrics body");
    body
}

fn stats_body(addr: SocketAddr) -> sonic_moe::util::json::Json {
    let mut cl = Client::connect(addr);
    cl.send(&ClientMsg::Stats);
    match cl.recv() {
        ServerMsg::Stats(j) => j,
        other => panic!("expected stats reply, got {other:?}"),
    }
}

fn stat(addr: SocketAddr, key: &str) -> f64 {
    stats_body(addr).get(key).unwrap().as_f64().unwrap()
}

fn wire_shutdown(addr: SocketAddr) {
    let mut cl = Client::connect(addr);
    cl.send(&ClientMsg::Shutdown);
    match cl.recv() {
        ServerMsg::Ok { .. } => {}
        other => panic!("expected ok to shutdown, got {other:?}"),
    }
}

/// Deterministic per-request token vector (shared with the reference
/// gateway so responses are comparable bitwise).
fn toks(id: u64, len: usize) -> Vec<i32> {
    (0..len).map(|j| ((id as usize * 31 + j * 7 + 1) % 256) as i32).collect()
}

/// Scores and streams through the front are bitwise identical to a
/// direct gateway, the front answers its own control plane, and a wire
/// `shutdown` drains the front without touching the replicas.
#[test]
fn front_relays_scores_and_streams_bitwise() {
    let cfg = base_cfg();
    let reference = Gateway::start(cfg.clone()).expect("reference gateway");
    let mut rc = Client::connect(reference.local_addr());
    rc.send(&ClientMsg::Score { id: 1, tokens: toks(1, 12) });
    let want_ce = rc.recv_score(1);
    let want_stream = {
        rc.send(&ClientMsg::Generate {
            id: 2,
            tokens: toks(2, 6),
            max_new: 5,
            opts: Default::default(),
        });
        rc.read_stream(2)
    };
    wire_shutdown(reference.local_addr());
    reference.join();

    let gw_a = Gateway::start(cfg.clone()).expect("replica a");
    let gw_b = Gateway::start(cfg).expect("replica b");
    let front = front_over(vec![spec(gw_a.local_addr(), ""), spec(gw_b.local_addr(), "")], |_| {});
    let faddr = front.local_addr();

    let mut cl = Client::connect(faddr);
    cl.send_raw(&raw_score(1, &toks(1, 12), ""));
    assert_eq!(cl.recv_score(1), want_ce, "relayed score diverged from the direct gateway");
    let got = cl.generate(2, &toks(2, 6), 5, "");
    assert_eq!(got, want_stream, "relayed stream diverged from the direct gateway");

    // the front's own control plane: stats JSON with per-replica
    // gauges, and the Prometheus exposition
    let body = stats_body(faddr);
    assert_eq!(body.get("relayed_ok").unwrap().as_usize().unwrap(), 1);
    assert_eq!(body.get("gen_done").unwrap().as_usize().unwrap(), 1);
    let reps = body.get("replicas").unwrap().as_arr().unwrap();
    assert_eq!(reps.len(), 2, "stats must gauge every replica");
    for r in reps {
        assert_eq!(r.get("state").unwrap().as_str().unwrap(), "healthy");
    }
    let text = fetch_metrics(faddr);
    for needle in [
        "sonic_front_relayed_ok_total 1",
        "sonic_front_gen_done_total 1",
        "sonic_front_replicas 2",
        "sonic_front_replica_up{replica=\"",
    ] {
        assert!(text.contains(needle), "metrics body missing {needle:?}:\n{text}");
    }

    // wire shutdown drains the front; the replicas keep serving
    wire_shutdown(faddr);
    front.join();
    let mut direct = Client::connect(gw_a.local_addr());
    direct.send(&ClientMsg::Score { id: 9, tokens: toks(1, 12) });
    assert_eq!(direct.recv_score(9), want_ce, "replica must survive the front's drain");
    for gw in [gw_a, gw_b] {
        wire_shutdown(gw.local_addr());
        gw.join();
    }
}

/// Model tags are a routing constraint: tagged requests only ever
/// reach the replica serving that model.
#[test]
fn model_tags_route_to_their_replica() {
    let cfg = base_cfg();
    let gw_a = Gateway::start(cfg.clone()).expect("replica a");
    let gw_b = Gateway::start(cfg).expect("replica b");
    let front =
        front_over(vec![spec(gw_a.local_addr(), "a"), spec(gw_b.local_addr(), "b")], |_| {});
    let mut cl = Client::connect(front.local_addr());
    for id in 0..3u64 {
        cl.send_raw(&raw_score(id, &toks(id, 10), "a"));
        cl.recv_score(id);
    }
    for id in 10..12u64 {
        cl.send_raw(&raw_score(id, &toks(id, 10), "b"));
        cl.recv_score(id);
    }
    cl.generate(20, &toks(20, 6), 4, "b");

    // the replicas' own gateway stats prove where requests landed
    assert_eq!(stat(gw_a.local_addr(), "requests") as u64, 3);
    assert_eq!(stat(gw_a.local_addr(), "gen_requests") as u64, 0);
    assert_eq!(stat(gw_b.local_addr(), "requests") as u64, 2);
    assert_eq!(stat(gw_b.local_addr(), "gen_requests") as u64, 1);

    front.shutdown();
    front.join();
    for gw in [gw_a, gw_b] {
        wire_shutdown(gw.local_addr());
        gw.join();
    }
}

/// Scripted score failover: the believed-healthy replica dies for real
/// and its replacement lives on a different address. The retried score
/// is bitwise identical to a single-gateway run, and the failover
/// latency lands in the front's percentile window.
#[test]
fn score_failover_is_bitwise_identical_to_a_single_gateway() {
    let cfg = base_cfg();
    let reference = Gateway::start(cfg.clone()).expect("reference gateway");
    let mut rc = Client::connect(reference.local_addr());
    rc.send(&ClientMsg::Score { id: 1, tokens: toks(1, 12) });
    let want1 = rc.recv_score(1);
    rc.send(&ClientMsg::Score { id: 2, tokens: toks(2, 12) });
    let want2 = rc.recv_score(2);
    wire_shutdown(reference.local_addr());
    reference.join();

    let gw0 = Gateway::start(cfg.clone()).expect("replica 0");
    let spare = reserve_addr(); // dead until the replacement binds it
    // probes fire once at startup and then effectively never again, so
    // the front's health beliefs change only through relays — the
    // failover below is scripted, not raced against the prober
    let front = front_over(
        vec![
            spec(gw0.local_addr(), ""),
            ReplicaSpec { addr: spare.clone(), model: String::new() },
        ],
        |c| {
            c.probe_interval_ms = 3_600_000;
            c.fail_threshold = 10;
        },
    );
    wait_until("both startup probes", || front.stats_snapshot().probes >= 2);
    assert_eq!(front.stats_snapshot().probe_failures, 1, "only the dead address may fail");

    let faddr = front.local_addr();
    let mut cl = Client::connect(faddr);
    // replica 0 is the only healthy replica: this score lands there
    cl.send_raw(&raw_score(1, &toks(1, 12), ""));
    assert_eq!(cl.recv_score(1), want1);

    // replica 0 dies for real; the replacement only exists on the
    // other (so-far dead) address — the front's belief is now stale
    wire_shutdown(gw0.local_addr());
    gw0.join();
    let mut cfg1 = cfg;
    cfg1.addr = spare;
    let gw1 = Gateway::start(cfg1).expect("replacement replica");

    // the next score tries stale-healthy replica 0, fails on
    // transport, and retries onto the replacement — bitwise intact
    cl.send_raw(&raw_score(2, &toks(2, 12), ""));
    assert_eq!(cl.recv_score(2), want2, "failed-over score diverged from the single gateway");

    let stats = front.stats_snapshot();
    assert_eq!(stats.relayed_ok, 2, "both scores must be answered");
    assert_eq!(stats.retries, 1, "exactly one transport failure");
    assert_eq!(stats.failovers, 1, "exactly one failover");
    assert!(stats.failover_percentiles().expect("failover window").p99 > 0.0);
    let body = stats_body(faddr);
    assert!(body.get("failover_p99_ms").unwrap().as_f64().unwrap() > 0.0);
    assert!(fetch_metrics(faddr).contains("sonic_front_failovers_total 1"));

    front.shutdown();
    front.join();
    wire_shutdown(gw1.local_addr());
    gw1.join();
}

/// The headline drill: kill a replica mid-decode under mixed load.
///
/// Invariants — the surviving replica's streams and scores are bitwise
/// unaffected; the pinned stream gets exactly one `replica_lost` whose
/// `last_index` is the last token the client received; the breaker
/// trips once and recovers on the next probe (the gateway process was
/// never stopped); the whole story is visible in `sonic_front_*`.
#[test]
fn replica_kill_mid_decode_drill() {
    let mut cfg = base_cfg();
    cfg.worker_delay_ms = 25; // slow decode so the kill lands mid-stream

    let reference = Gateway::start(cfg.clone()).expect("reference gateway");
    let mut rc = Client::connect(reference.local_addr());
    rc.send(&ClientMsg::Generate {
        id: 90,
        tokens: toks(90, 6),
        max_new: 8,
        opts: Default::default(),
    });
    let want_a = rc.read_stream(90);
    rc.send(&ClientMsg::Generate {
        id: 91,
        tokens: toks(91, 6),
        max_new: 8,
        opts: Default::default(),
    });
    let want_b = rc.read_stream(91);
    rc.send(&ClientMsg::Score { id: 92, tokens: toks(92, 10) });
    let want_ce = rc.recv_score(92);
    wire_shutdown(reference.local_addr());
    reference.join();

    let gw_a = Gateway::start(cfg.clone()).expect("replica a");
    let gw_b = Gateway::start(cfg).expect("replica b");
    let front =
        front_over(vec![spec(gw_a.local_addr(), "a"), spec(gw_b.local_addr(), "b")], |_| {});
    let faddr = front.local_addr();

    // pin a stream to replica a and take two tokens off it
    let mut ca = Client::connect(faddr);
    ca.send_raw(&raw_generate(1, &toks(90, 6), 8, "a"));
    let mut received = Vec::new();
    for _ in 0..2 {
        match ca.recv() {
            ServerMsg::Token { id, token, index } => {
                assert_eq!(id, 1);
                assert_eq!(index, received.len());
                received.push(token);
            }
            other => panic!("expected a token frame, got {other:?}"),
        }
    }
    // a survivor stream is mid-flight on replica b when the kill fires
    let mut cb = Client::connect(faddr);
    cb.send_raw(&raw_generate(2, &toks(91, 6), 8, "b"));
    front.inject_kill(0);

    // survivor: bitwise unaffected
    assert_eq!(cb.read_stream(2), want_b, "surviving stream diverged");

    // pinned stream: contiguous tokens, then exactly one replica_lost
    // carrying the last index this client actually received
    let (code, last_index, message) = loop {
        match ca.recv() {
            ServerMsg::Token { id, token, index } => {
                assert_eq!(id, 1);
                assert_eq!(index, received.len(), "pinned stream skipped a frame");
                received.push(token);
            }
            ServerMsg::Error { id, code, message, last_index, .. } => {
                assert_eq!(id, Some(1));
                break (code, last_index, message);
            }
            other => panic!("unexpected frame on the pinned stream: {other:?}"),
        }
    };
    assert_eq!(code, "replica_lost");
    assert!(message.contains("killed"), "unexpected replica_lost message: {message}");
    let expect_last = if received.is_empty() { None } else { Some(received.len() as u64 - 1) };
    assert_eq!(last_index, expect_last, "last_index disagrees with the delivered prefix");
    assert_eq!(
        received[..],
        want_a[..received.len()],
        "pinned stream prefix diverged before the kill"
    );
    assert!(received.len() < want_a.len(), "the kill must truncate the stream");

    // scores for the surviving model keep matching the single gateway
    let mut cs = Client::connect(faddr);
    cs.send_raw(&raw_score(3, &toks(92, 10), "b"));
    assert_eq!(cs.recv_score(3), want_ce, "score during the outage diverged");

    // the replica process was never stopped: the next probe recovers
    // it, and a fresh pinned stream completes bitwise
    wait_until("breaker recovery", || front.replica_state(0) == ReplicaState::Healthy);
    let mut ca2 = Client::connect(faddr);
    assert_eq!(ca2.generate(4, &toks(90, 6), 8, "a"), want_a, "post-recovery stream diverged");

    let stats = front.stats_snapshot();
    assert_eq!(stats.injected_replica_kills, 1);
    assert_eq!(stats.replica_lost_streams, 1, "exactly one stream may be lost");
    assert_eq!(stats.breaker_trips, 1, "the kill trips the breaker exactly once");
    assert!(stats.breaker_recoveries >= 1, "the half-open probe must recover the replica");
    assert_eq!(stats.gen_done, 2, "survivor + post-recovery streams");
    let text = fetch_metrics(faddr);
    for needle in [
        "sonic_front_injected_replica_kills_total 1",
        "sonic_front_replica_lost_streams_total 1",
        "sonic_front_breaker_trips_total 1",
        "sonic_front_breaker_recoveries_total 1",
    ] {
        assert!(text.contains(needle), "metrics body missing {needle:?}:\n{text}");
    }

    front.shutdown();
    front.join();
    for gw in [gw_a, gw_b] {
        wire_shutdown(gw.local_addr());
        gw.join();
    }
}

/// When every replica is dead the front sheds immediately with
/// `no_healthy_replica` and a `retry_after_ms` hint instead of hanging.
#[test]
fn all_replicas_down_shed_with_a_retry_hint() {
    let dead = reserve_addr();
    let front = front_over(vec![ReplicaSpec { addr: dead, model: String::new() }], |c| {
        c.fail_threshold = 1;
    });
    wait_until("the dead replica to trip", || front.replica_state(0) == ReplicaState::Dead);
    let mut cl = Client::connect(front.local_addr());
    for (id, line) in
        [(1u64, raw_score(1, &toks(1, 8), "")), (2u64, raw_generate(2, &toks(2, 6), 4, ""))]
    {
        cl.send_raw(&line);
        match cl.recv() {
            ServerMsg::Error { id: rid, code, retry_after_ms, .. } => {
                assert_eq!(rid, Some(id));
                assert_eq!(code, "no_healthy_replica");
                assert!(
                    retry_after_ms.unwrap_or(0) >= 10,
                    "shedding refusal must carry a backoff hint"
                );
            }
            other => panic!("expected a shedding refusal, got {other:?}"),
        }
    }
    let stats = front.stats_snapshot();
    assert_eq!(stats.shed_no_healthy, 2);
    assert_eq!(stats.breaker_trips, 1);
    front.shutdown();
    front.join();
}

/// A routable-but-unreachable replica exhausts the bounded retry
/// budget and fails cleanly with `exec_failed` (never a hang).
#[test]
fn exhausted_relay_attempts_fail_cleanly() {
    let dead = reserve_addr();
    let front = front_over(vec![ReplicaSpec { addr: dead, model: String::new() }], |c| {
        c.fail_threshold = 100; // stays degraded-routable, never sheds
        c.retry_attempts = 2;
    });
    let mut cl = Client::connect(front.local_addr());
    cl.send_raw(&raw_score(1, &toks(1, 8), ""));
    match cl.recv() {
        ServerMsg::Error { id, code, message, .. } => {
            assert_eq!(id, Some(1));
            assert_eq!(code, "exec_failed");
            assert!(message.contains("relay attempts failed"), "unexpected message: {message}");
        }
        other => panic!("expected exec_failed, got {other:?}"),
    }
    assert_eq!(front.stats_snapshot().exhausted, 1);
    front.shutdown();
    front.join();
}

/// `reload` broadcasts to every replica; with no replica able to
/// acknowledge, the upstream refusal is relayed instead of a fake ok.
#[test]
fn reload_broadcasts_and_relays_refusals() {
    let cfg = base_cfg();
    let gw_a = Gateway::start(cfg.clone()).expect("replica a");
    let gw_b = Gateway::start(cfg).expect("replica b");
    let front = front_over(vec![spec(gw_a.local_addr(), ""), spec(gw_b.local_addr(), "")], |_| {});
    let mut cl = Client::connect(front.local_addr());
    cl.send(&ClientMsg::Reload { dir: "/nonexistent-checkpoint-dir".to_string() });
    match cl.recv() {
        // no replica can acknowledge a bogus checkpoint, so the first
        // upstream refusal is relayed verbatim — proof the broadcast
        // reached a real gateway rather than being answered locally
        ServerMsg::Error { code, message, .. } => {
            assert_eq!(code, "bad_request");
            assert!(message.contains("no checkpoint"), "unexpected refusal: {message}");
        }
        other => panic!("a failed reload must relay the refusal, got {other:?}"),
    }
    assert_eq!(front.stats_snapshot().reloads, 1);
    front.shutdown();
    front.join();
    for gw in [gw_a, gw_b] {
        wire_shutdown(gw.local_addr());
        gw.join();
    }
}

/// The CLI-facing fault plan: a probe-count-scripted kill fires exactly
/// once, trips the breaker, and the untouched replica recovers on the
/// next half-open probe.
#[test]
fn scripted_fault_plan_kills_and_recovers() {
    let gw = Gateway::start(base_cfg()).expect("replica");
    let front = front_over(vec![spec(gw.local_addr(), "")], |c| {
        c.fault = FrontFaultPlan { kill_replica_after_probes: 2, ..FrontFaultPlan::default() };
    });
    wait_until("the scripted kill", || front.stats_snapshot().injected_replica_kills == 1);
    wait_until("half-open recovery", || front.stats_snapshot().breaker_recoveries >= 1);
    let stats = front.stats_snapshot();
    assert_eq!(stats.injected_replica_kills, 1, "the kill is one-shot");
    assert_eq!(stats.breaker_trips, 1);
    assert_eq!(front.replica_state(0), ReplicaState::Healthy);
    front.shutdown();
    front.join();
    wire_shutdown(gw.local_addr());
    gw.join();
}

/// The scripted probe stall degrades the replica without tripping the
/// breaker, and the next clean probe restores it.
#[test]
fn scripted_stall_degrades_without_tripping() {
    let gw = Gateway::start(base_cfg()).expect("replica");
    let front = front_over(vec![spec(gw.local_addr(), "")], |c| {
        c.fault = FrontFaultPlan { stall_replica_after_probes: 1, ..FrontFaultPlan::default() };
    });
    wait_until("the scripted stall", || front.stats_snapshot().injected_replica_stalls == 1);
    assert_eq!(front.stats_snapshot().breaker_trips, 0, "one stall must not trip the breaker");
    wait_until("probe recovery", || front.replica_state(0) == ReplicaState::Healthy);
    assert!(front.stats_snapshot().probe_failures >= 1);
    front.shutdown();
    front.join();
    wire_shutdown(gw.local_addr());
    gw.join();
}
