//! Benchmark configurations: Table 9a/9b families, the open-source MoE
//! configs of Figure 12 / Table 4, and the Figure 13 sparsity sweeps.

/// Shape of one MoE layer's computation over a microbatch.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct MoeShape {
    /// tokens per microbatch
    pub t: usize,
    /// embedding dim
    pub d: usize,
    /// expert intermediate dim
    pub n: usize,
    /// total experts
    pub e: usize,
    /// activated experts per token
    pub k: usize,
}

impl MoeShape {
    pub const fn new(t: usize, d: usize, n: usize, e: usize, k: usize) -> Self {
        MoeShape { t, d, n, e, k }
    }

    /// Model forward FLOPs: 6*T*K*n*d (Section 3.2).
    pub fn flops_fwd(&self) -> u64 {
        6 * (self.t * self.k) as u64 * self.n as u64 * self.d as u64
    }

    /// Model backward FLOPs: 12*T*K*n*d.
    pub fn flops_bwd(&self) -> u64 {
        2 * self.flops_fwd()
    }

    /// Expert granularity G = d/n.
    pub fn granularity(&self) -> f64 {
        self.d as f64 / self.n as f64
    }

    /// Activation ratio rho = K/E.
    pub fn activation_ratio(&self) -> f64 {
        self.k as f64 / self.e as f64
    }

    /// Mean tokens per expert under uniform routing.
    pub fn mean_tokens_per_expert(&self) -> f64 {
        (self.t * self.k) as f64 / self.e as f64
    }
}

/// A named benchmark row (model size label + shape).
#[derive(Debug, Clone, Copy)]
pub struct NamedShape {
    pub label: &'static str,
    pub shape: MoeShape,
}

/// Table 9a: H100 benchmark configurations (Figures 10, 11a, 18–22).
pub const TABLE_9A: [NamedShape; 12] = [
    NamedShape { label: "1.4B n=256", shape: MoeShape::new(40960, 768, 256, 128, 8) },
    NamedShape { label: "1.4B n=512", shape: MoeShape::new(40960, 768, 512, 64, 4) },
    NamedShape { label: "1.4B n=1024", shape: MoeShape::new(40960, 768, 1024, 32, 2) },
    NamedShape { label: "7B n=256", shape: MoeShape::new(24576, 1536, 256, 128, 8) },
    NamedShape { label: "7B n=512", shape: MoeShape::new(24576, 1536, 512, 64, 4) },
    NamedShape { label: "7B n=1024", shape: MoeShape::new(24576, 1536, 1024, 32, 2) },
    NamedShape { label: "30B n=256", shape: MoeShape::new(32768, 4096, 256, 256, 16) },
    NamedShape { label: "30B n=512", shape: MoeShape::new(32768, 4096, 512, 128, 8) },
    NamedShape { label: "30B n=1024", shape: MoeShape::new(32768, 4096, 1024, 64, 4) },
    NamedShape { label: "120B n=512", shape: MoeShape::new(32768, 4096, 512, 256, 16) },
    NamedShape { label: "120B n=1024", shape: MoeShape::new(32768, 4096, 1024, 128, 8) },
    NamedShape { label: "120B n=2048", shape: MoeShape::new(32768, 4096, 2048, 64, 4) },
];

/// Table 9b: B300 benchmark configurations (Figure 11b).
pub const TABLE_9B: [NamedShape; 12] = [
    NamedShape { label: "1.4B n=256", shape: MoeShape::new(131072, 768, 256, 128, 8) },
    NamedShape { label: "1.4B n=512", shape: MoeShape::new(131072, 768, 512, 64, 4) },
    NamedShape { label: "1.4B n=1024", shape: MoeShape::new(131072, 768, 1024, 32, 2) },
    NamedShape { label: "7B n=256", shape: MoeShape::new(81920, 1536, 256, 128, 8) },
    NamedShape { label: "7B n=512", shape: MoeShape::new(81920, 1536, 512, 64, 4) },
    NamedShape { label: "7B n=1024", shape: MoeShape::new(81920, 1536, 1024, 32, 2) },
    NamedShape { label: "30B n=256", shape: MoeShape::new(32768, 4096, 256, 256, 16) },
    NamedShape { label: "30B n=512", shape: MoeShape::new(32768, 4096, 512, 128, 8) },
    NamedShape { label: "30B n=1024", shape: MoeShape::new(32768, 4096, 1024, 64, 4) },
    NamedShape { label: "120B n=512", shape: MoeShape::new(32768, 4096, 512, 256, 16) },
    NamedShape { label: "120B n=1024", shape: MoeShape::new(32768, 4096, 1024, 128, 8) },
    NamedShape { label: "120B n=2048", shape: MoeShape::new(32768, 4096, 2048, 64, 4) },
];

/// Figure 12 / Table 4: open-source MoE configurations (T = 32768 as in
/// the single-layer benchmark; no shared experts / biases).
pub const OPEN_SOURCE: [NamedShape; 6] = [
    NamedShape { label: "OLMoE-1B-7B", shape: MoeShape::new(32768, 2048, 1024, 64, 8) },
    NamedShape { label: "gpt-oss-20b", shape: MoeShape::new(32768, 2880, 2880, 32, 4) },
    NamedShape { label: "Kimi-Linear-48B-A3B", shape: MoeShape::new(32768, 2048, 1408, 256, 8) },
    NamedShape { label: "Qwen3-Next-80B-A3B", shape: MoeShape::new(32768, 2048, 512, 512, 10) },
    NamedShape { label: "Qwen3-235B-A22B", shape: MoeShape::new(32768, 4096, 1536, 128, 8) },
    NamedShape { label: "DeepSeek-V3.2-Exp", shape: MoeShape::new(32768, 7168, 2048, 256, 8) },
];

/// Figure 13 sweep families: (d, n, K, E values). T = 16384 throughout.
pub struct SparsitySweep {
    pub label: &'static str,
    pub d: usize,
    pub n: usize,
    pub k: usize,
    pub e_values: [usize; 4],
}

pub const FIG13_SWEEPS: [SparsitySweep; 4] = [
    SparsitySweep { label: "d=1536 n=256 K=8", d: 1536, n: 256, k: 8, e_values: [64, 128, 256, 512] },
    SparsitySweep { label: "d=1536 n=1024 K=2", d: 1536, n: 1024, k: 2, e_values: [16, 32, 64, 128] },
    SparsitySweep { label: "d=4096 n=512 K=8", d: 4096, n: 512, k: 8, e_values: [64, 128, 256, 512] },
    SparsitySweep { label: "d=4096 n=1024 K=4", d: 4096, n: 1024, k: 4, e_values: [32, 64, 128, 256] },
];

pub const FIG13_T: usize = 16384;

/// Figure 1's 30B granularity/sparsity sweep: vary activated/total as
/// 2/32 ... 16/256 with n*K constant.
pub const FIG1_SWEEP: [NamedShape; 4] = [
    NamedShape { label: "2/32 n=2048", shape: MoeShape::new(32768, 4096, 2048, 32, 2) },
    NamedShape { label: "4/64 n=1024", shape: MoeShape::new(32768, 4096, 1024, 64, 4) },
    NamedShape { label: "8/128 n=512", shape: MoeShape::new(32768, 4096, 512, 128, 8) },
    NamedShape { label: "16/256 n=256", shape: MoeShape::new(32768, 4096, 256, 256, 16) },
];

/// Table 4 rows (release trend data, printed with Figure 12).
pub const TABLE_4: [(&str, &str, f64, f64); 13] = [
    ("Mixtral 8x22B", "11/23", 2.0 / 8.0, 6144.0 / 16384.0),
    ("DBRX", "03/24", 4.0 / 16.0, 6144.0 / 10752.0),
    ("Phi-3.5-MoE", "09/24", 2.0 / 16.0, 4096.0 / 6400.0),
    ("OLMoE", "09/24", 8.0 / 64.0, 2048.0 / 1024.0),
    ("Granite 3.1-MoE", "12/24", 8.0 / 40.0, 1536.0 / 512.0),
    ("DeepSeek-V3", "12/24", 8.0 / 256.0, 7168.0 / 2048.0),
    ("Qwen3 MoE", "04/25", 8.0 / 128.0, 4096.0 / 1536.0),
    ("Qwen3-30B-A3B", "05/25", 8.0 / 128.0, 2048.0 / 768.0),
    ("Kimi K2", "07/25", 8.0 / 384.0, 7168.0 / 2048.0),
    ("gpt-oss-120b", "08/25", 4.0 / 128.0, 2880.0 / 2880.0),
    ("GLM-4.5-Air", "08/25", 8.0 / 128.0, 4096.0 / 1408.0),
    ("Qwen3-Next-80B", "09/25", 10.0 / 512.0, 2048.0 / 512.0),
    ("DeepSeek-V3.2-Exp", "10/25", 8.0 / 256.0, 7168.0 / 2048.0),
];

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn flops_formula() {
        let s = MoeShape::new(100, 8, 4, 4, 2);
        assert_eq!(s.flops_fwd(), 6 * 100 * 2 * 4 * 8);
        assert_eq!(s.flops_bwd(), 2 * s.flops_fwd());
    }

    #[test]
    fn iso_flops_families() {
        // within each Table 9a model size, n*K is constant (iso-FLOPs)
        for group in TABLE_9A.chunks(3) {
            let nk: Vec<usize> = group.iter().map(|c| c.shape.n * c.shape.k).collect();
            assert!(nk.windows(2).all(|w| w[0] == w[1]), "{group:?}");
        }
        for c in FIG1_SWEEP.windows(2) {
            assert_eq!(c[0].shape.n * c[0].shape.k, c[1].shape.n * c[1].shape.k);
        }
    }

    #[test]
    fn sparsity_trend_in_table4() {
        // Newer entries (last 5) are sparser on average than first 3.
        let early: f64 = TABLE_4[..3].iter().map(|r| r.2).sum::<f64>() / 3.0;
        let late: f64 = TABLE_4[8..].iter().map(|r| r.2).sum::<f64>() / 5.0;
        assert!(late < early / 3.0);
    }

    #[test]
    fn fig13_sweeps_iso_flops_in_e() {
        for sw in &FIG13_SWEEPS {
            assert!(sw.e_values.windows(2).all(|w| w[1] == w[0] * 2));
        }
    }
}
