//! # sonic-moe — SonicMoE reproduction (L3 coordinator)
//!
//! Rust coordinator of the three-layer stack reproducing *SonicMoE:
//! Accelerating MoE with IO and Tile-aware Optimizations* (Guo et al.):
//!
//! - [`runtime`] loads and executes the AOT-compiled HLO artifacts
//!   (L2 JAX model + L1 Pallas kernels) through the PJRT C API;
//! - [`coordinator`] owns the training loop, parameter state, data
//!   pipeline and data-parallel workers;
//! - [`routing`] re-implements every routing algorithm of the paper
//!   (token-choice, token rounding with all six rounding subroutines,
//!   expert choice, token drop) for the host-side dispatch, the
//!   simulator and property tests;
//! - [`simulator`] is the GPU performance model that regenerates the
//!   paper's throughput tables and figures (H100/B300 substitution — see
//!   DESIGN.md);
//! - [`memory`] is the activation-memory accounting model (Figure 10);
//! - [`optim`], [`data`], [`bench`], [`util`] are supporting substrates
//!   (AdamW, synthetic corpus, micro-bench harness, and the offline
//!   replacements for serde/clap/criterion/proptest).
//!
//! Python never runs at request time: `make artifacts` is the only
//! python entry point.

pub mod bench;
pub mod coordinator;
pub mod data;
pub mod memory;
pub mod optim;
pub mod routing;
pub mod runtime;
pub mod simulator;
pub mod util;

/// Crate-wide result type.
pub type Result<T> = anyhow::Result<T>;
