"""L2 MoE transformer language model (OLMoE-style, scaled down).

Architecture per the paper's Appendix I: pre-norm transformer blocks of
causal multi-head attention followed by a SonicMoE SwiGLU block, RMSNorm,
tied LM head, auxiliary load-balance loss (coeff 0.01), no z-loss.

The MoE blocks call ``sonic_moe_block`` — i.e. the Pallas L1 kernels with
the memory-efficient custom VJP — so the AOT-exported train step contains
the paper's exact computation path in its HLO.

Parameters live in a *flat ordered dict* (name -> array). The ordering is
the contract with the rust coordinator (manifest.json lists the same
names/shapes/offsets; rust owns the optimizer state).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .kernels import MoEConfig
from . import moe_layer


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Static LM configuration. ``tokens_per_batch = batch * seq_len`` is
    the MoE microbatch size T (routing is applied per microbatch)."""

    vocab: int = 512
    d: int = 64
    n_layers: int = 2
    n_heads: int = 4
    seq_len: int = 64
    batch: int = 4
    # MoE
    n: int = 32
    E: int = 8
    K: int = 2
    m_tile: int = 32
    router: str = "tc"
    aux_coeff: float = 0.01

    @property
    def moe_cfg(self) -> MoEConfig:
        return MoEConfig(
            T=self.batch * self.seq_len,
            d=self.d,
            n=self.n,
            E=self.E,
            K=self.K,
            m_tile=self.m_tile,
        )

    @property
    def head_dim(self) -> int:
        assert self.d % self.n_heads == 0
        return self.d // self.n_heads


# ---------------------------------------------------------------------------
# Parameters
# ---------------------------------------------------------------------------


def param_specs(cfg: ModelConfig) -> Dict[str, Tuple[int, ...]]:
    """Ordered name -> shape map; the AOT/rust parameter contract."""
    specs: Dict[str, Tuple[int, ...]] = {"embed": (cfg.vocab, cfg.d)}
    for i in range(cfg.n_layers):
        p = f"layer{i}."
        specs[p + "attn_norm"] = (cfg.d,)
        specs[p + "wq"] = (cfg.d, cfg.d)
        specs[p + "wk"] = (cfg.d, cfg.d)
        specs[p + "wv"] = (cfg.d, cfg.d)
        specs[p + "wo"] = (cfg.d, cfg.d)
        specs[p + "moe_norm"] = (cfg.d,)
        specs[p + "wr"] = (cfg.d, cfg.E)
        specs[p + "w1"] = (cfg.E, cfg.d, 2 * cfg.n)
        specs[p + "w2"] = (cfg.E, cfg.n, cfg.d)
    specs["final_norm"] = (cfg.d,)
    return specs


def init_params(cfg: ModelConfig, seed: int = 0) -> Dict[str, jnp.ndarray]:
    """Truncated-normal-ish init, norms at 1. Deterministic in ``seed``."""
    rng = np.random.default_rng(seed)
    params = {}
    for name, shape in param_specs(cfg).items():
        if name.endswith("norm"):
            params[name] = jnp.ones(shape, jnp.float32)
        elif name == "embed":
            params[name] = jnp.asarray(
                rng.normal(0, 0.02, size=shape).astype(np.float32)
            )
        elif name.endswith("wr"):
            params[name] = jnp.asarray(
                rng.normal(0, 0.02, size=shape).astype(np.float32)
            )
        else:
            fan_in = shape[-2] if len(shape) >= 2 else shape[0]
            params[name] = jnp.asarray(
                rng.normal(0, fan_in**-0.5, size=shape).astype(np.float32)
            )
    return params


def num_params(cfg: ModelConfig) -> int:
    return sum(int(np.prod(s)) for s in param_specs(cfg).values())


def num_active_params(cfg: ModelConfig) -> int:
    """Parameters touched per token (dense equivalent): full model minus
    the (E-K) unactivated experts' weights per layer."""
    per_expert = cfg.d * 2 * cfg.n + cfg.n * cfg.d
    return num_params(cfg) - cfg.n_layers * (cfg.E - cfg.K) * per_expert


# ---------------------------------------------------------------------------
# Forward
# ---------------------------------------------------------------------------


def rmsnorm(x: jnp.ndarray, scale: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(var + eps) * scale


def attention(cfg: ModelConfig, x: jnp.ndarray, p: Dict[str, jnp.ndarray], prefix: str):
    """Causal MHA over (B, S, d)."""
    b, s, d = x.shape
    h, hd = cfg.n_heads, cfg.head_dim
    q = (x @ p[prefix + "wq"]).reshape(b, s, h, hd).transpose(0, 2, 1, 3)
    k = (x @ p[prefix + "wk"]).reshape(b, s, h, hd).transpose(0, 2, 1, 3)
    v = (x @ p[prefix + "wv"]).reshape(b, s, h, hd).transpose(0, 2, 1, 3)
    att = jnp.einsum("bhqd,bhkd->bhqk", q, k) / jnp.sqrt(float(hd))
    mask = jnp.tril(jnp.ones((s, s), bool))
    att = jnp.where(mask[None, None], att, -1e30)
    att = jax.nn.softmax(att, axis=-1)
    o = jnp.einsum("bhqk,bhkd->bhqd", att, v).transpose(0, 2, 1, 3).reshape(b, s, d)
    return o @ p[prefix + "wo"]


def forward(
    cfg: ModelConfig, params: Dict[str, jnp.ndarray], tokens: jnp.ndarray
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """tokens (B, S) int32 -> (logits (B, S, V), total aux loss)."""
    b, s = tokens.shape
    x = params["embed"][tokens]  # (B, S, d)
    aux_total = jnp.float32(0.0)
    mcfg = cfg.moe_cfg
    for i in range(cfg.n_layers):
        p = f"layer{i}."
        x = x + attention(cfg, rmsnorm(x, params[p + "attn_norm"]), params, p)
        resid = x
        xn = rmsnorm(x, params[p + "moe_norm"]).reshape(b * s, cfg.d)
        o, aux = moe_layer.sonic_moe_block(
            mcfg, xn, params[p + "wr"], params[p + "w1"], params[p + "w2"],
            method=cfg.router,
        )
        aux_total = aux_total + aux
        x = resid + o.reshape(b, s, cfg.d)
    x = rmsnorm(x, params["final_norm"])
    logits = x @ params["embed"].T  # tied head
    return logits, aux_total


def loss_fn(
    cfg: ModelConfig, params: Dict[str, jnp.ndarray], tokens: jnp.ndarray
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Next-token cross entropy (mean over positions) + aux loss.

    Returns ``(total_loss, ce_loss)`` so perplexity can be logged without
    the aux term.
    """
    logits, aux = forward(cfg, params, tokens)
    logp = jax.nn.log_softmax(logits[:, :-1].astype(jnp.float32), axis=-1)
    targets = tokens[:, 1:]
    ce = -jnp.take_along_axis(logp, targets[..., None], axis=-1).mean()
    return ce + cfg.aux_coeff * aux, ce


def grad_step_fn(cfg: ModelConfig):
    """Returns f(params_tuple, tokens) -> (loss, ce, *grads_in_spec_order).

    Tuple-of-arrays signature (not a dict) so the AOT HLO has a stable
    positional interface for the rust runtime.
    """
    names = list(param_specs(cfg).keys())

    def f(*args):
        *flat, tokens = args
        params = dict(zip(names, flat))
        (loss, ce), grads = jax.value_and_grad(
            lambda p: loss_fn(cfg, p, tokens), has_aux=True
        )(params)
        return (loss, ce, *[grads[n] for n in names])

    return f, names


def eval_loss_fn(cfg: ModelConfig):
    """Returns f(params_tuple, tokens) -> (ce_loss,) for validation."""
    names = list(param_specs(cfg).keys())

    def f(*args):
        *flat, tokens = args
        params = dict(zip(names, flat))
        _, ce = loss_fn(cfg, params, tokens)
        return (ce,)

    return f, names
