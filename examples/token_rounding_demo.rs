//! Token rounding demo (Section 5): one microbatch, all rounding
//! subroutines, invariant checks, and the simulated kernel speedup the
//! tile alignment buys on H100.
//!
//!     cargo run --release --example token_rounding_demo -- --e 256

use anyhow::Result;
use sonic_moe::bench::Table;
use sonic_moe::routing::{synth_scores, tc_topk, token_rounding, RoundingRule};
use sonic_moe::simulator::{self, MoeShape, Method, Pass, Routing, H100};
use sonic_moe::util::cli::Cli;
use sonic_moe::util::prng::Prng;

fn main() -> Result<()> {
    let cli = Cli::new("token_rounding_demo", "TR vs TC on one microbatch")
        .opt("t", "16384", "tokens")
        .opt("d", "1536", "embedding dim")
        .opt("n", "256", "expert intermediate dim")
        .opt("e", "128", "experts")
        .opt("k", "8", "top-K")
        .opt("m-tile", "128", "GEMM tile")
        .opt("skew", "0.5", "expert popularity skew")
        .opt("seed", "0", "seed");
    let a = cli.parse()?;
    let (t, e, k) = (a.get_usize("t")?, a.get_usize("e")?, a.get_usize("k")?);
    let (d, n, m) = (a.get_usize("d")?, a.get_usize("n")?, a.get_usize("m-tile")?);
    let shape = MoeShape::new(t, d, n, e, k);

    let mut rng = Prng::new(a.get_u64("seed")?);
    let scores = synth_scores(&mut rng, t, e, a.get_f64("skew")?);
    let tc = tc_topk(&scores, t, e, k);

    println!(
        "microbatch: T={t} E={e} K={k} m_tile={m}  (mean tokens/expert {:.0})",
        shape.mean_tokens_per_expert()
    );
    let mut tbl = Table::new(
        "routing methods (Algorithm 4 subroutines)",
        &["method", "pairs", "Δ pairs", "pad rows", "waste GFLOP", "fwd+bwd ms", "model TF/s"],
    );
    let eval = |counts: Vec<usize>| {
        let r = Routing::from_counts(counts, m);
        let f = simulator::evaluate(Method::SonicMoE, &shape, &r, Pass::Forward, &H100);
        let b = simulator::evaluate(Method::SonicMoE, &shape, &r, Pass::Backward, &H100);
        let ms = (f.time_s + b.time_s) * 1e3;
        let tf = (shape.flops_fwd() + shape.flops_bwd()) as f64 / (f.time_s + b.time_s) / 1e12;
        (ms, tf)
    };
    let (tc_ms, tc_tf) = eval(tc.g.clone());
    tbl.row(&[
        "TC top-K".into(),
        tc.routed_pairs().to_string(),
        "0".into(),
        tc.padding_rows(m).to_string(),
        format!("{:.1}", tc.padding_waste_flops(m, d, n) as f64 / 1e9),
        format!("{tc_ms:.2}"),
        format!("{tc_tf:.0}"),
    ]);
    for rule in RoundingRule::ALL {
        let dec = token_rounding(&scores, t, e, k, m, rule, &mut rng);
        // invariants (Section 5.2)
        assert!(dec.g.iter().all(|&g| g % m == 0));
        assert!(dec
            .g
            .iter()
            .zip(&dec.f)
            .all(|(&g, &f)| (g as i64 - f as i64).unsigned_abs() < m as u64));
        assert_eq!(dec.padding_rows(m), 0);
        let (ms, tf) = eval(dec.g.clone());
        tbl.row(&[
            format!("TR ({})", rule.name()),
            dec.routed_pairs().to_string(),
            format!("{:+}", dec.routed_pairs() as i64 - tc.routed_pairs() as i64),
            "0".into(),
            "0.0".into(),
            format!("{ms:.2}"),
            format!("{tf:.0}"),
        ]);
    }
    tbl.print();

    let nr = token_rounding(&scores, t, e, k, m, RoundingRule::NearestFreq, &mut rng);
    let (nr_ms, _) = eval(nr.g.clone());
    println!(
        "TR (NR-f) end-to-end kernel speedup over TC top-K: {:.1}%  (paper: up to 16% in the sparse regime)",
        (tc_ms / nr_ms - 1.0) * 100.0
    );
    Ok(())
}
