//! Native kernel throughput: blocked vs naive GEMM, and fused vs
//! gather-materialized grouped expert kernels, across paper-relevant
//! shapes (fine-grained small-n/many-expert vs coarse large-n/few-
//! expert MoE blocks).
//!
//! Reports GFLOP/s per kernel and the fused kernel's thread scaling,
//! then emits one JSON record (line starting with `{"bench":`) for the
//! bench trajectory: `scripts/bench_gate.py` gates the `gflops` and
//! `weight_gb_s` leaves as higher-is-better (a >20% *drop* vs the
//! committed record fails).
//!
//! Each dense-GEMM row also reports the weight bytes streamed per call,
//! the arithmetic intensity (FLOPs per weight byte), and the effective
//! weight bandwidth, for both f32 and bf16 storage: bf16 halves
//! `weight_bytes` (doubling arithmetic intensity), so on bandwidth-
//! bound shapes its GFLOP/s should hold while `weight_gb_s` drops by
//! roughly half — the streamed-byte saving the dtype axis is for.
//!
//! `SONIC_KERNEL_BENCH_FAST=1` shrinks the timing windows (CI smoke).

use std::collections::BTreeMap;
use std::time::Duration;

use sonic_moe::bench::{BenchConfig, Bencher};
use sonic_moe::routing;
use sonic_moe::runtime::backend::native::kernels::{self, scratch};
use sonic_moe::runtime::backend::native::linalg;
use sonic_moe::util::dtype::{narrow_slice, Dtype, WView};
use sonic_moe::util::json::Json;
use sonic_moe::util::prng::Prng;

fn bench_cfg() -> BenchConfig {
    if std::env::var("SONIC_KERNEL_BENCH_FAST").is_ok() {
        BenchConfig {
            warmup: Duration::from_millis(20),
            measure: Duration::from_millis(80),
            min_samples: 3,
            max_samples: 10_000,
        }
    } else {
        BenchConfig::default()
    }
}

fn rand_vec(rng: &mut Prng, n: usize) -> Vec<f32> {
    (0..n).map(|_| rng.normal() as f32 * 0.5).collect()
}

/// GFLOP/s of the median sample for a kernel of `flops` per call.
fn gflops(name: &str, flops: f64, mut f: impl FnMut()) -> f64 {
    let mut b = Bencher::with_config(name, bench_cfg());
    let s = b.iter(|| f());
    println!("{}", b.report());
    flops / s.median / 1e9
}

/// One CSR routing for a synthetic MoE block: TC top-k on skewed
/// scores, gates = renormalized top-k scores.
struct Routing {
    rows_off: Vec<usize>,
    rows_flat: Vec<usize>,
    gates: Vec<f32>,
}

fn build_routing(t: usize, e: usize, k: usize, seed: u64) -> Routing {
    let mut rng = Prng::new(seed);
    let scores = routing::synth_scores(&mut rng, t, e, 0.5);
    let dec = routing::tc_topk(&scores, t, e, k);
    let mut rows_off = vec![0usize];
    let mut rows_flat = Vec::new();
    let mut gates = Vec::new();
    for j in 0..e {
        for tok in 0..t {
            if dec.mask[tok * e + j] {
                rows_flat.push(tok);
                gates.push(1.0 / k as f32);
            }
        }
        rows_off.push(rows_flat.len());
    }
    Routing { rows_off, rows_flat, gates }
}

/// The pre-fusion expert forward: materialized gather + GEMM + SwiGLU +
/// GEMM + scatter-axpy (the comparison baseline).
#[allow(clippy::too_many_arguments)]
fn gather_expert_forward(
    d: usize,
    n: usize,
    e: usize,
    xn: &[f32],
    w1: &[f32],
    w2: &[f32],
    r: &Routing,
    o: &mut [f32],
) {
    for j in 0..e {
        let rows = &r.rows_flat[r.rows_off[j]..r.rows_off[j + 1]];
        let rr = rows.len();
        if rr == 0 {
            continue;
        }
        let mut xg = vec![0f32; rr * d];
        for (i, &tok) in rows.iter().enumerate() {
            xg[i * d..(i + 1) * d].copy_from_slice(&xn[tok * d..(tok + 1) * d]);
        }
        let w1_e = &w1[j * d * 2 * n..(j + 1) * d * 2 * n];
        let w2_e = &w2[j * n * d..(j + 1) * n * d];
        let h = linalg::matmul(&xg, w1_e, rr, d, 2 * n);
        let mut a = vec![0f32; rr * n];
        for i in 0..rr {
            for jj in 0..n {
                let g = h[i * 2 * n + jj];
                let u = h[i * 2 * n + n + jj];
                a[i * n + jj] = g * linalg::sigmoid(g) * u;
            }
        }
        let y = linalg::matmul(&a, w2_e, rr, n, d);
        for (i, &tok) in rows.iter().enumerate() {
            linalg::axpy(
                r.gates[r.rows_off[j] + i],
                &y[i * d..(i + 1) * d],
                &mut o[tok * d..(tok + 1) * d],
            );
        }
    }
}

/// Expert-block FLOPs: 2*pairs*d*2n (up) + 2*pairs*n*d (down).
fn expert_flops(pairs: usize, d: usize, n: usize) -> f64 {
    6.0 * pairs as f64 * d as f64 * n as f64
}

fn main() {
    let mut rec = BTreeMap::new();
    rec.insert("bench".to_string(), Json::Str("kernel_throughput".to_string()));

    // -- dense GEMM: blocked (1 thread) vs naive reference, f32 vs bf16
    println!("kernel_throughput: dense GEMM, blocked vs naive (single thread)\n");
    let mut gemm_rows = Vec::new();
    let mut tbl = sonic_moe::bench::Table::new(
        "dense GEMM (m=256 tokens) GFLOP/s",
        &["shape", "naive", "blocked", "speedup", "bf16", "bf16 wGB/s"],
    );
    kernels::set_threads(1);
    let mut rng = Prng::new(11);
    for &d in &[64usize, 128, 256, 384] {
        let (m, k, n) = (256usize, d, d);
        let a = rand_vec(&mut rng, m * k);
        let b = rand_vec(&mut rng, k * n);
        let bq = narrow_slice(&b);
        let flops = 2.0 * m as f64 * k as f64 * n as f64;
        let naive =
            gflops(&format!("gemm_naive/d{d}"), flops, || {
                sonic_moe::bench::black_box(linalg::matmul(&a, &b, m, k, n));
            });
        let blocked = gflops(&format!("gemm_blocked/d{d}"), flops, || {
            scratch::put(sonic_moe::bench::black_box(kernels::matmul(&a, &b, m, k, n)));
        });
        let bf16 = gflops(&format!("gemm_bf16/d{d}"), flops, || {
            scratch::put(sonic_moe::bench::black_box(kernels::matmul_wview(
                &a,
                WView::Bf16(&bq),
                m,
                k,
                n,
            )));
        });
        let speedup = blocked / naive;
        // Weight-operand traffic per call: the B matrix is streamed
        // once per GEMM; GB/s here is that traffic over median time,
        // i.e. gflops * bytes / flops.
        let row = |name: String, gf: f64, dtype: Dtype| {
            let weight_bytes = (k * n * dtype.elem_bytes()) as f64;
            let mut j = BTreeMap::new();
            j.insert("name".to_string(), Json::Str(name));
            j.insert("dtype".to_string(), Json::Str(dtype.as_str().to_string()));
            j.insert("gflops".to_string(), Json::Num(gf));
            j.insert("weight_bytes".to_string(), Json::Num(weight_bytes));
            j.insert("arith_intensity".to_string(), Json::Num(flops / weight_bytes));
            j.insert("weight_gb_s".to_string(), Json::Num(gf * weight_bytes / flops));
            j
        };
        let mut jf = row(format!("gemm_d{d}"), blocked, Dtype::F32);
        jf.insert("naive_gflops".to_string(), Json::Num(naive));
        jf.insert("speedup_vs_naive".to_string(), Json::Num(speedup));
        gemm_rows.push(Json::Obj(jf));
        let mut jb = row(format!("gemm_d{d}_bf16"), bf16, Dtype::Bf16);
        jb.insert("speedup_vs_f32".to_string(), Json::Num(bf16 / blocked));
        gemm_rows.push(Json::Obj(jb));
        let bf16_gbs = bf16 * (k * n * Dtype::Bf16.elem_bytes()) as f64 / flops;
        tbl.row(&[
            format!("{m}x{k}x{n}"),
            format!("{naive:.2}"),
            format!("{blocked:.2}"),
            format!("{speedup:.2}x"),
            format!("{bf16:.2}"),
            format!("{bf16_gbs:.2}"),
        ]);
    }
    tbl.print();
    rec.insert("gemm".to_string(), Json::Arr(gemm_rows));

    // -- grouped expert kernel: fused vs gather, and thread scaling ---
    println!("kernel_throughput: grouped expert kernel, fused vs gather-materialized\n");
    let mut expert_rows = Vec::new();
    let mut tbl = sonic_moe::bench::Table::new(
        "grouped expert kernel (T=1024, d=256) GFLOP/s",
        &[
            "shape",
            "gather",
            "fused t1",
            "fused t2",
            "fused t4",
            "bf16 t1",
            "fused/gather",
            "t4/t1",
        ],
    );
    for &(name, n, e, k) in &[
        // fine-grained: many small experts (paper's small-n regime)
        ("fine_n32_e32", 32usize, 32usize, 4usize),
        // coarse: few wide experts (large-n regime)
        ("coarse_n128_e8", 128usize, 8usize, 2usize),
    ] {
        let (t, d) = (1024usize, 256usize);
        let mut rng = Prng::new(7);
        let xn = rand_vec(&mut rng, t * d);
        let w1 = rand_vec(&mut rng, e * d * 2 * n);
        let w2 = rand_vec(&mut rng, e * n * d);
        let r = build_routing(t, e, k, 3);
        let pairs = r.rows_flat.len();
        let flops = expert_flops(pairs, d, n);
        let mut o = vec![0f32; t * d];
        let mut h = vec![0f32; pairs * 2 * n];

        kernels::set_threads(1);
        let gather = gflops(&format!("expert_gather/{name}"), flops, || {
            o.fill(0.0);
            gather_expert_forward(d, n, e, &xn, &w1, &w2, &r, &mut o);
        });
        let w1q = narrow_slice(&w1);
        let w2q = narrow_slice(&w2);
        let mut fused_at = |threads: usize, wv1: WView<'_>, wv2: WView<'_>, tag: &str| {
            kernels::set_threads(threads);
            gflops(&format!("expert_fused{tag}/{name}/t{threads}"), flops, || {
                o.fill(0.0);
                kernels::fused_expert_forward(
                    d,
                    n,
                    e,
                    &xn,
                    wv1,
                    wv2,
                    &r.rows_off,
                    &r.rows_flat,
                    &r.gates,
                    &mut h,
                    &mut o,
                );
            })
        };
        let f1 = fused_at(1, WView::F32(&w1), WView::F32(&w2), "");
        let f2 = fused_at(2, WView::F32(&w1), WView::F32(&w2), "");
        let f4 = fused_at(4, WView::F32(&w1), WView::F32(&w2), "");
        let fb = fused_at(1, WView::Bf16(&w1q), WView::Bf16(&w2q), "_bf16");
        kernels::set_threads(1);
        tbl.row(&[
            name.to_string(),
            format!("{gather:.2}"),
            format!("{f1:.2}"),
            format!("{f2:.2}"),
            format!("{f4:.2}"),
            format!("{fb:.2}"),
            format!("{:.2}x", f1 / gather),
            format!("{:.2}x", f4 / f1),
        ]);
        // expert weight traffic per call: both expert matrices streamed
        // once (w1: e*d*2n, w2: e*n*d), assuming every expert is hit.
        let w_elems = (e * d * 2 * n + e * n * d) as f64;
        let mut j = BTreeMap::new();
        j.insert("name".to_string(), Json::Str(name.to_string()));
        j.insert("gflops".to_string(), Json::Num(f1));
        j.insert("gather_gflops".to_string(), Json::Num(gather));
        j.insert("speedup_vs_gather".to_string(), Json::Num(f1 / gather));
        j.insert("gflops_t2".to_string(), Json::Num(f2));
        j.insert("gflops_t4".to_string(), Json::Num(f4));
        j.insert("scaling_t4_over_t1".to_string(), Json::Num(f4 / f1));
        j.insert("weight_bytes".to_string(), Json::Num(w_elems * 4.0));
        j.insert("arith_intensity".to_string(), Json::Num(flops / (w_elems * 4.0)));
        j.insert("weight_gb_s".to_string(), Json::Num(f1 * w_elems * 4.0 / flops));
        expert_rows.push(Json::Obj(j));
        let mut jb = BTreeMap::new();
        jb.insert("name".to_string(), Json::Str(format!("{name}_bf16")));
        jb.insert("dtype".to_string(), Json::Str(Dtype::Bf16.as_str().to_string()));
        jb.insert("gflops".to_string(), Json::Num(fb));
        jb.insert("speedup_vs_f32".to_string(), Json::Num(fb / f1));
        jb.insert("weight_bytes".to_string(), Json::Num(w_elems * 2.0));
        jb.insert("arith_intensity".to_string(), Json::Num(flops / (w_elems * 2.0)));
        jb.insert("weight_gb_s".to_string(), Json::Num(fb * w_elems * 2.0 / flops));
        expert_rows.push(Json::Obj(jb));
    }
    tbl.print();
    rec.insert("expert".to_string(), Json::Arr(expert_rows));

    let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    rec.insert("host_cores".to_string(), Json::Num(cores as f64));
    println!("{}", Json::Obj(rec));
}
