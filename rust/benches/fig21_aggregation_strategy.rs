//! Bench: regenerate Figure 21 via the simulator/model and time it.

use sonic_moe::bench::{figures, Bencher};

fn main() {
    figures::fig21().print();
    let mut b = Bencher::new("simulator/fig21_aggregation_strategy");
    b.iter(|| figures::fig21());
    println!("{}", b.report());
}
