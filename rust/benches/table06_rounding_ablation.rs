//! Table 6 (scaled-down): token-rounding subroutine ablation — NR-f vs
//! Balance-f vs UP vs DOWN vs the TC baseline, all evaluated with TC
//! top-K routing.

use sonic_moe::bench::Table;
use sonic_moe::coordinator::quality::{bench_steps, train_and_eval};
use sonic_moe::runtime::artifacts_available;

fn main() {
    if !artifacts_available("artifacts") {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    let steps = bench_steps();
    let mut t = Table::new(
        &format!("Table 6 (scaled down): rounding subroutines, {steps} steps"),
        &["method", "train CE", "val CE", "val PPL"],
    );
    for (label, router) in [
        ("TR (NR-f)", "tr"),
        ("TR (Balance-f)", "trbal"),
        ("TR (UP)", "trup"),
        ("TR (DOWN)", "trdown"),
        ("TC top-K", "tc"),
    ] {
        match train_and_eval("small", router, steps, 3e-3, 0) {
            Ok(r) => t.row(&[
                label.to_string(),
                format!("{:.4}", r.train_ce),
                format!("{:.4}", r.val_ce),
                format!("{:.2}", r.val_ppl()),
            ]),
            Err(e) => t.row(&[label.to_string(), format!("error: {e}"), "-".into(), "-".into()]),
        }
    }
    t.print();
    println!("(paper Table 6: TR is robust to the rounding subroutine; DOWN is worst)");
}
